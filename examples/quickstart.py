"""Quickstart: the paper pipeline on one matrix, in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import RewriteConfig, SpTRSV
from repro.core.levels import build_level_sets
from repro.sparse import lung2_like

# 1. a matrix with the paper's pathology: hundreds of thin levels
L = lung2_like(scale=0.2, dtype=np.float32)
levels = build_level_sets(L)
print(f"matrix: {L.n} rows, {L.nnz} nnz")
print(f"levels: {levels.num_levels} "
      f"({100*levels.thin_fraction(2):.0f}% thin — ≤2 rows)")

# 2. build a matrix-specialized solver WITHOUT the transformation
base = SpTRSV.build(L, strategy="levelset")

# 3. ... and WITH equation rewriting (the paper's graph transformation)
solver = SpTRSV.build(L, strategy="levelset",
                      rewrite=RewriteConfig(thin_threshold=2))
print("rewrite:", solver.rewrite_result.stats.summary())

# 4. solve — rewriting changes the schedule, never the answer
b = jnp.asarray(np.random.default_rng(0).normal(size=L.n).astype(np.float32))
x0, x1 = base.solve(b), solver.solve(b)
err = float(jnp.max(jnp.abs(x0 - x1)))
print(f"max |x_base - x_rewritten| = {err:.2e}")
assert err < 1e-3

# 4b. value-only refresh: same pattern, new values (each numeric
#     re-factorization of an iterative workload) — O(nnz) re-pack, the
#     compiled executable is reused outright
new_data = L.data * 1.1
solver.refresh(new_data)
print("refreshed:", solver.stats()["refreshable_in_place"])

# 5. the backward sweep Lᵀ x = b is first-class and shares the analysis —
#    one build_pair gives both halves of an IC(0)/LU preconditioner apply
fwd, bwd = SpTRSV.build_pair(L, strategy="levelset")
xt = np.asarray(bwd.solve(b))
rt = L.transpose().matvec(xt.astype(np.float64)) - np.asarray(b, np.float64)
print(f"transpose solve residual |Lᵀx - b| = {np.abs(rt).max():.2e}")
assert np.abs(rt).max() < 1e-3

# 6. the same transformation parallelizes linear recurrences (RG-LRU et al.)
from repro.core.recurrence import linear_recurrence
a = jnp.full((16,), 0.9)
u = jnp.ones((16,))
h_scan = linear_recurrence(a, u, method="scan")       # Algorithm 1
h_rw = linear_recurrence(a, u, method="sptrsv")       # rewrite + level solve
print(f"recurrence via SpTRSV rewriting matches scan: "
      f"{bool(jnp.allclose(h_scan, h_rw, rtol=1e-4))}")
