"""Serving driver: continuous-batching engine over a small LM.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma3-1b --requests 8
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models.model import Model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    model = Model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, batch_slots=args.slots, s_cache=64)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 12))
        r = Request(i, rng.integers(0, cfg.vocab_size, (plen,)).astype(np.int32),
                    max_new=args.max_new)
        reqs.append(r)
        eng.submit(r)

    t0 = time.perf_counter()
    eng.run(max_steps=2000)
    dt = time.perf_counter() - t0
    done = sum(r.done for r in reqs)
    toks = sum(len(r.out) for r in reqs)
    print(f"served {done}/{len(reqs)} requests, {toks} tokens "
          f"in {dt:.2f}s ({toks/dt:.1f} tok/s, {eng.steps} engine steps, "
          f"{args.slots} slots continuous batching)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt[:4]={r.prompt[:4].tolist()} "
              f"-> out[:6]={r.out[:6]}")
    assert done == len(reqs)


if __name__ == "__main__":
    main()
