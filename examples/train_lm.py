"""End-to-end LM training driver: data pipeline -> sharded model -> fault-
tolerant loop with checkpointing + straggler watchdog.

    PYTHONPATH=src python examples/train_lm.py --arch gemma3-1b \
        --preset tiny --steps 300

Presets (CPU container has one core; on a real pod use --preset full with
the assigned config):
  tiny   reduced same-family config (~3M params), seq 128   — minutes
  100m   ~100M-param family config, seq 256                 — hours on CPU
  full   the assigned architecture config                   — pod scale
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config, smoke_config
from repro.data import SyntheticLM
from repro.models.model import Model
from repro.optim import get_optimizer
from repro.train import TrainConfig, Trainer


def preset_config(arch: str, preset: str):
    if preset == "full":
        return get_config(arch)
    if preset == "tiny":
        return smoke_config(arch)
    # ~100M: widen the smoke config within the same family
    c = smoke_config(arch)
    return dataclasses.replace(
        c, d_model=512, n_heads=8, n_kv_heads=min(c.n_kv_heads * 2, 8),
        head_dim=64, d_ff=2048 if c.d_ff else 0, vocab_size=32_768,
        num_layers=max(c.num_layers, 2 * len(c.block_pattern)),
        d_rnn=512 if c.d_rnn else None)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m", "full"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor", "sgd", "tripre"])
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", default="auto", choices=["auto", "none"])
    args = ap.parse_args()

    cfg = preset_config(args.arch, args.preset)
    model = Model(cfg, remat=False)
    print(f"arch={cfg.name} preset={args.preset} "
          f"~{cfg.params_B()*1e3:.1f}M params, vocab {cfg.vocab_size}")
    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch,
                       family=cfg.family, d_model=cfg.d_model,
                       prefix_len=cfg.prefix_len)
    opt = get_optimizer(args.optimizer, lr=args.lr, total_steps=args.steps)
    tc = TrainConfig(steps=args.steps, ckpt_every=max(args.steps // 5, 10),
                     ckpt_dir=args.ckpt_dir, log_every=10, resume=args.resume)
    out = Trainer(model, opt, data, tc).run()
    h = out["history"]
    k = max(len(h) // 10, 1)
    print(f"loss: first10={sum(h[:k])/k:.4f}  last10={sum(h[-k:])/k:.4f}")
    print(f"straggler events: {out['straggler_events']}, "
          f"recoveries: {out['recoveries']}")


if __name__ == "__main__":
    main()
