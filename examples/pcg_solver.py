"""IC(0)-preconditioned CG on a 2-D Poisson problem — the classic system
SpTRSV lives inside.  The preconditioner application is two matrix-
specialized triangular solves (with equation rewriting on by default).

    PYTHONPATH=src python examples/pcg_solver.py [--nx 48] [--no-rewrite]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import RewriteConfig
from repro.core.levels import build_level_sets
from repro.core.pcg import make_ic_preconditioner, pcg
from repro.sparse import ic0_factor, poisson2d


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nx", type=int, default=48)
    ap.add_argument("--no-rewrite", action="store_true")
    args = ap.parse_args()

    A = poisson2d(args.nx, args.nx, dtype=np.float32)
    print(f"Poisson {args.nx}x{args.nx}: n={A.n}, nnz={A.nnz}")
    L = ic0_factor(A)
    lv = build_level_sets(L)
    print(f"IC(0) factor: {L.nnz} nnz, {lv.num_levels} levels "
          f"(grid wavefronts)")

    rw = None if args.no_rewrite else RewriteConfig(thin_threshold=4)
    M = make_ic_preconditioner(L, rewrite=rw)

    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.normal(size=A.n).astype(np.float32))

    t0 = time.perf_counter()
    plain = pcg(A, b, None, tol=1e-6, maxiter=2000)
    t_plain = time.perf_counter() - t0
    t0 = time.perf_counter()
    pre = pcg(A, b, M, tol=1e-6, maxiter=2000)
    t_pre = time.perf_counter() - t0

    print(f"CG   (no preconditioner): {plain.iters} iters, "
          f"res {plain.residual:.2e}, {t_plain:.2f}s")
    print(f"PCG  (IC0 via SpTRSV):    {pre.iters} iters, "
          f"res {pre.residual:.2e}, {t_pre:.2f}s")
    assert pre.converged and pre.iters < plain.iters
    x = np.asarray(pre.x, np.float64)
    r = np.asarray(b, np.float64) - A.astype(np.float64).matvec(x)
    print(f"true residual check: {np.linalg.norm(r):.2e}")


if __name__ == "__main__":
    main()
