"""Multi-RHS (batched) SpTRSV coverage: every strategy must solve
``L X = B`` with ``B: (n, m)`` column-wise identically to m single-RHS
solves, including edge cases (m=1, m>n, empty/padded slabs) and the serving
and PCG entry points built on top."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RewriteConfig, SpTRSV, build_schedule
from repro.core.codegen import _pack_rows, build_ell, ell_spmv, make_levelset_solver
from repro.sparse import chain_matrix, lung2_like, random_lower

from test_property_solvers import np_fsolve

BATCH = 64  # acceptance-criterion batch width


def _solve_columns(s, B):
    return np.stack(
        [np.asarray(s.solve(jnp.asarray(B[:, j]))) for j in range(B.shape[1])],
        axis=1)


LOCAL_STRATEGIES = ["serial", "levelset", "levelset_unroll",
                    "pallas_level", "pallas_fused"]


@pytest.mark.parametrize("strategy", LOCAL_STRATEGIES)
@pytest.mark.parametrize("rewrite", [None, RewriteConfig(thin_threshold=3)])
def test_batched_matches_columnwise(strategy, rewrite):
    L = lung2_like(scale=0.02, fat_levels=4, thin_run=6, dtype=np.float32)
    rng = np.random.default_rng(7)
    B = rng.normal(size=(L.n, BATCH)).astype(np.float32)
    s = SpTRSV.build(L, strategy=strategy, rewrite=rewrite)
    X = np.asarray(s.solve_batched(jnp.asarray(B)))
    assert X.shape == (L.n, BATCH)
    np.testing.assert_allclose(X, _solve_columns(s, B), rtol=1e-5, atol=1e-5)
    # and against the float64 oracle
    X_ref = np_fsolve(L.astype(np.float64), B.astype(np.float64))
    np.testing.assert_allclose(X, X_ref, rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("dist_strategy", ["all_gather", "psum"])
@pytest.mark.parametrize("rewrite", [None, RewriteConfig(thin_threshold=4)])
def test_batched_distributed(dist_strategy, rewrite):
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((8,), ("data",))
    L = random_lower(400, avg_offdiag=3.0, seed=4, dtype=np.float32)
    rng = np.random.default_rng(2)
    B = rng.normal(size=(400, BATCH)).astype(np.float32)
    s = SpTRSV.build(L, strategy="distributed", mesh=mesh,
                     dist_strategy=dist_strategy, rewrite=rewrite)
    X = np.asarray(s.solve_batched(jnp.asarray(B)))
    np.testing.assert_allclose(X, _solve_columns(s, B), rtol=1e-5, atol=1e-5)
    X_ref = np_fsolve(L.astype(np.float64), B.astype(np.float64))
    np.testing.assert_allclose(X, X_ref, rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("strategy", LOCAL_STRATEGIES)
def test_batch_width_one(strategy):
    """(n, 1) must equal the (n,) solve with a trailing axis."""
    L = random_lower(120, avg_offdiag=2.5, seed=3, dtype=np.float32)
    b = np.random.default_rng(0).normal(size=L.n).astype(np.float32)
    s = SpTRSV.build(L, strategy=strategy)
    x1 = np.asarray(s.solve(jnp.asarray(b)))
    X = np.asarray(s.solve_batched(jnp.asarray(b[:, None])))
    assert X.shape == (L.n, 1)
    np.testing.assert_allclose(X[:, 0], x1, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("strategy", LOCAL_STRATEGIES)
def test_batch_wider_than_n(strategy):
    """m > n: a 40-row system with a 64-wide batch."""
    L = random_lower(40, avg_offdiag=2.0, seed=8, dtype=np.float32)
    rng = np.random.default_rng(9)
    B = rng.normal(size=(40, 64)).astype(np.float32)
    s = SpTRSV.build(L, strategy=strategy)
    X = np.asarray(s.solve_batched(jnp.asarray(B)))
    X_ref = np_fsolve(L.astype(np.float64), B.astype(np.float64))
    np.testing.assert_allclose(X, X_ref, rtol=2e-3, atol=2e-4)


def test_solve_shape_validation():
    L = random_lower(30, seed=0, dtype=np.float32)
    s = SpTRSV.build(L, strategy="levelset")
    with pytest.raises(ValueError):
        s.solve(jnp.zeros((29,), jnp.float32))
    with pytest.raises(ValueError):
        s.solve(jnp.zeros((30, 2, 2), jnp.float32))
    with pytest.raises(ValueError):
        s.solve_batched(jnp.zeros((30,), jnp.float32))


# --------------------------------------------------------------------------
# packing edge cases
# --------------------------------------------------------------------------
def test_pack_rows_empty_level():
    """_pack_rows on an empty row set: K clamps to 1, R = 0, and the
    resulting slab is a no-op for the executor."""
    L = random_lower(20, seed=1, dtype=np.float32)
    slab = _pack_rows(L, np.array([], dtype=np.int64), sort_by_nnz=True)
    assert slab.R == 0 and slab.K == 1
    assert slab.cols.shape == (1, 0) and slab.vals.shape == (1, 0)


def test_bucket_pad_ratio_batched():
    """bucket_pad_ratio > 1 splits ragged levels into multiple slabs; the
    split schedule must still solve batched RHS exactly (K-padding paths)."""
    L = lung2_like(scale=0.03, fat_levels=5, thin_run=5, dtype=np.float32)
    sched = build_schedule(L, bucket_pad_ratio=1.5)
    assert sched.num_levels > build_schedule(L).num_levels  # levels split
    solve = make_levelset_solver(sched)
    rng = np.random.default_rng(4)
    B = rng.normal(size=(L.n, 9)).astype(np.float32)
    X = np.asarray(solve(jnp.asarray(B)))
    X_ref = np_fsolve(L.astype(np.float64), B.astype(np.float64))
    np.testing.assert_allclose(X, X_ref, rtol=2e-3, atol=2e-4)
    # padded-FLOP accounting must not shrink below the unsplit schedule's
    # useful work
    assert sched.padded_flops() >= L.nnz


def test_ell_spmv_batched():
    """Batched ELL SpMV (the RHS transform B' = E B path) is column-wise
    identical to single SpMVs."""
    L = random_lower(80, avg_offdiag=4.0, seed=5, dtype=np.float32)
    ell = build_ell(L)
    rng = np.random.default_rng(6)
    V = rng.normal(size=(80, 5)).astype(np.float32)
    Y = np.asarray(ell_spmv(ell, jnp.asarray(V)))
    for j in range(5):
        yj = np.asarray(ell_spmv(ell, jnp.asarray(V[:, j])))
        np.testing.assert_allclose(Y[:, j], yj, rtol=1e-6, atol=1e-6)
    # oracle
    np.testing.assert_allclose(Y, L.to_dense() @ V, rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# batched workloads built on top: serving + PCG
# --------------------------------------------------------------------------
def test_solve_engine_micro_batching():
    from repro.serve import SolveEngine

    L = chain_matrix(90, dtype=np.float32)
    s = SpTRSV.build(L, strategy="levelset", rewrite=RewriteConfig(thin_threshold=2))
    eng = SolveEngine(s, max_batch=8)
    rng = np.random.default_rng(11)
    reqs = [eng.submit(rng.normal(size=L.n).astype(np.float32))
            for _ in range(19)]
    assert eng.run() == 19
    assert eng.batches == 3  # 8 + 8 + 3 (bucketed to 4)
    for r in reqs:
        assert r.done
        np.testing.assert_allclose(
            r.x, np.asarray(s.solve(jnp.asarray(r.b))), rtol=1e-6, atol=1e-6)


def test_pcg_batched_matches_single():
    from repro.core.pcg import (make_ic_preconditioner_batched, pcg,
                                pcg_batched)
    from repro.sparse import ic0_factor, poisson2d

    A = poisson2d(10, 10, dtype=np.float64).astype(np.float32)
    Lf = ic0_factor(A)
    M = make_ic_preconditioner_batched(Lf.astype(np.float32))
    rng = np.random.default_rng(12)
    B = rng.normal(size=(A.n, 4)).astype(np.float32)
    res = pcg_batched(A, jnp.asarray(B), M, tol=1e-6, maxiter=200)
    assert res.converged.all()
    assert res.x.shape == B.shape
    for j in range(B.shape[1]):
        single = pcg(A, jnp.asarray(B[:, j]), M, tol=1e-6, maxiter=200)
        assert single.converged
        np.testing.assert_allclose(np.asarray(res.x[:, j]),
                                   np.asarray(single.x),
                                   rtol=1e-3, atol=1e-4)


def test_wide_slab_batched_gather_fallback_matches_oracle():
    """Slabs wider than GATHER_UNROLL_MAX_K silently fall back from the
    K-unrolled 2-D gathers to the fused 3-D gather; the fallback must stay
    correct (it is only slower).  banded_lower with full fill at bandwidth
    40 forces K > 32 in the fat levels."""
    from repro.core.codegen import GATHER_UNROLL_MAX_K
    from repro.sparse import banded_lower

    L = banded_lower(160, bandwidth=GATHER_UNROLL_MAX_K + 8, fill=1.0,
                     seed=3, dtype=np.float32)
    assert int((L.row_nnz() - 1).max()) > GATHER_UNROLL_MAX_K
    rng = np.random.default_rng(9)
    B = rng.normal(size=(L.n, 6)).astype(np.float32)
    X_ref = np_fsolve(L.astype(np.float64), B.astype(np.float64))
    for strategy in ("serial", "levelset"):
        s = SpTRSV.build(L, strategy=strategy)
        assert strategy == "serial" or any(
            slab.K > GATHER_UNROLL_MAX_K for slab in s.schedule.slabs)
        X = np.asarray(s.solve_batched(jnp.asarray(B)))
        np.testing.assert_allclose(X, X_ref, rtol=2e-3, atol=2e-4,
                                   err_msg=strategy)
