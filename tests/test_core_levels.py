"""Level-set construction invariants (property-based)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import build_level_sets, compute_levels
from repro.sparse import banded_lower, chain_matrix, lung2_like, random_lower


@st.composite
def small_lower(draw):
    n = draw(st.integers(5, 120))
    seed = draw(st.integers(0, 2**31 - 1))
    avg = draw(st.floats(0.5, 6.0))
    return random_lower(n, avg_offdiag=avg, seed=seed)


@given(small_lower())
@settings(max_examples=30, deadline=None)
def test_levels_partition_rows(L):
    ls = build_level_sets(L)
    all_rows = np.concatenate(ls.rows) if ls.rows else np.array([])
    assert sorted(all_rows.tolist()) == list(range(L.n))
    assert int(ls.counts.sum()) == L.n


@given(small_lower())
@settings(max_examples=30, deadline=None)
def test_dependencies_strictly_lower_level(L):
    level = compute_levels(L)
    for i in range(L.n):
        cols, _ = L.row(i)
        for j in cols[:-1]:
            assert level[j] < level[i]


@given(small_lower())
@settings(max_examples=30, deadline=None)
def test_level_zero_rows_have_no_deps(L):
    ls = build_level_sets(L)
    for r in ls.rows[0]:
        cols, _ = L.row(int(r))
        assert cols.size == 1  # diagonal only


def test_chain_has_n_levels():
    L = chain_matrix(64)
    assert build_level_sets(L).num_levels == 64


def test_banded_levels_bounded():
    L = banded_lower(256, bandwidth=4, fill=1.0, seed=0)
    ls = build_level_sets(L)
    assert 1 < ls.num_levels <= 256


def _ref_levels_loop(L, *, upper=False):
    """The per-row Python loop the vectorized propagation replaced."""
    n = L.n
    level = np.zeros(n, dtype=np.int64)
    order = range(n - 1, -1, -1) if upper else range(n)
    for i in order:
        cols, _ = L.row(i)
        deps = cols[cols > i] if upper else cols[cols < i]
        if deps.size:
            level[i] = level[deps].max() + 1
    return level


@given(small_lower())
@settings(max_examples=30, deadline=None)
def test_vectorized_levels_match_reference_loop(L):
    from repro.core import compute_reverse_levels, compute_upper_levels

    assert np.array_equal(compute_levels(L), _ref_levels_loop(L))
    U = L.transpose()
    ref_up = _ref_levels_loop(U, upper=True)
    assert np.array_equal(compute_upper_levels(U), ref_up)
    # reverse levels without a forward analysis take the same vectorized path
    assert np.array_equal(compute_reverse_levels(L), ref_up)


def test_vectorized_levels_edge_cases():
    from repro.core import eye_csr

    assert compute_levels(eye_csr(7)).tolist() == [0] * 7
    assert np.array_equal(compute_levels(chain_matrix(50)), np.arange(50))


def test_lung2_like_matches_paper_regime():
    """The structural twin must reproduce lung2's published shape: ~478
    levels, 94% thin (<=2 rows), ~4-5 nnz/row, ~110k rows."""
    L = lung2_like(scale=1.0)
    ls = build_level_sets(L)
    assert 450 <= ls.num_levels <= 550
    assert ls.thin_fraction(2) > 0.90
    assert 100_000 <= L.n <= 120_000
    assert 3.0 <= L.nnz / L.n <= 5.5
