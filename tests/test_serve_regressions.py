"""SolveEngine serving-tier regressions.

Two bugfixes pinned here:

* ``refresh`` must drain the admission queue before swapping factor values —
  an in-flight request is answered with the factor that existed when it was
  enqueued, never silently re-priced against values from the future;
* ``_solve_group`` allocates the batch buffer in the **solver's** dtype, not
  ``np.result_type`` over the requests — one float64 request must not up-cast
  the bucket and miss every jit-cache entry compiled at the solver's dtype.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CSRMatrix, SpTRSV
from repro.serve import SolveEngine
from repro.sparse import chain_matrix


def _regen_values(L, seed):
    rng = np.random.default_rng(seed)
    data = rng.normal(size=L.nnz).astype(L.dtype)
    diag_mask = np.zeros(L.nnz, bool)
    for i in range(L.n):  # keep the factor well-conditioned
        diag_mask[L.indptr[i + 1] - 1] = True
    data[diag_mask] = np.abs(data[diag_mask]) + 1.0
    return data


def test_refresh_drains_queue_before_value_swap():
    L = chain_matrix(80, dtype=np.float64)
    eng = SolveEngine.from_matrix(L, strategy="levelset", transpose_too=False,
                                  max_batch=8)
    rng = np.random.default_rng(3)
    b = rng.normal(size=L.n)
    # submit against the ORIGINAL factor, then refresh without running
    inflight = eng.submit(b)
    data2 = _regen_values(L, seed=9)
    eng.refresh(data2)
    # the drain inside refresh must have answered the in-flight request
    # against the old values
    assert inflight.done
    old = SpTRSV.build(L, strategy="levelset")
    np.testing.assert_allclose(
        inflight.x, np.asarray(old.solve(jnp.asarray(b))),
        rtol=1e-12, atol=1e-12)
    # a post-refresh submit is answered with the NEW values
    after = eng.submit(b)
    eng.run()
    new = SpTRSV.build(CSRMatrix(L.indptr, L.indices, data2, L.shape),
                       strategy="levelset")
    np.testing.assert_allclose(
        after.x, np.asarray(new.solve(jnp.asarray(b))),
        rtol=1e-12, atol=1e-12)
    # and the two factors genuinely differ, or the test proves nothing
    assert not np.allclose(inflight.x, after.x)


def test_mixed_dtype_request_does_not_retrace():
    L = chain_matrix(64, dtype=np.float32)
    s = SpTRSV.build(L, strategy="levelset")
    eng = SolveEngine(s, max_batch=4)
    rng = np.random.default_rng(5)
    # warm the m=4 bucket at the solver's dtype
    f32_reqs = [eng.submit(rng.normal(size=L.n).astype(np.float32))
                for _ in range(4)]
    assert eng.run() == 4
    if not hasattr(s._solve_fn, "_cache_size"):
        pytest.skip("jit cache introspection unavailable on this JAX")
    before = s._solve_fn._cache_size()
    # a float64 request in an otherwise-f32 bucket must be solved at the
    # solver's dtype, hitting the already-compiled bucket
    mixed = [eng.submit(rng.normal(size=L.n).astype(np.float64))
             for _ in range(4)]
    assert eng.run() == 4
    assert s._solve_fn._cache_size() == before
    for r in f32_reqs + mixed:
        assert r.done
        assert r.x.dtype == np.float32
        np.testing.assert_allclose(
            r.x, np.asarray(s.solve(jnp.asarray(r.b, jnp.float32))),
            rtol=1e-6, atol=1e-6)
