"""SolveEngine serving-tier regressions.

Bugfixes pinned here:

* ``refresh`` must drain the admission queue before swapping factor values —
  an in-flight request is answered with the factor that existed when it was
  enqueued, never silently re-priced against values from the future;
* ``_solve_group`` allocates the batch buffer in the **solver's** dtype, not
  ``np.result_type`` over the requests — one float64 request must not up-cast
  the bucket and miss every jit-cache entry compiled at the solver's dtype;
* ``step`` must count errored requests in ``failed``, not ``solved`` —
  ``stats()["solved"]`` means answers, not attempts;
* ``__init__``/``submit`` validation raises ``ValueError`` (asserts are
  stripped under ``python -O`` and a wrong-length RHS would silently
  corrupt the batch buffer);
* the ``_solve_group`` fallback routes per-request re-solves through the
  width-1 *bucket* (no per-RHS retrace) and counts each executor dispatch
  in ``batches`` — counters stay consistent between paths.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import enable_x64
from repro.core import CSRMatrix, GuardBreakdownError, GuardConfig, SpTRSV
from repro.serve import SolveEngine
from repro.sparse import chain_matrix, random_lower


def _regen_values(L, seed):
    rng = np.random.default_rng(seed)
    data = rng.normal(size=L.nnz).astype(L.dtype)
    diag_mask = np.zeros(L.nnz, bool)
    for i in range(L.n):  # keep the factor well-conditioned
        diag_mask[L.indptr[i + 1] - 1] = True
    data[diag_mask] = np.abs(data[diag_mask]) + 1.0
    return data


def test_refresh_drains_queue_before_value_swap():
    L = chain_matrix(80, dtype=np.float64)
    eng = SolveEngine.from_matrix(L, strategy="levelset", transpose_too=False,
                                  max_batch=8)
    rng = np.random.default_rng(3)
    b = rng.normal(size=L.n)
    # submit against the ORIGINAL factor, then refresh without running
    inflight = eng.submit(b)
    data2 = _regen_values(L, seed=9)
    eng.refresh(data2)
    # the drain inside refresh must have answered the in-flight request
    # against the old values
    assert inflight.done
    old = SpTRSV.build(L, strategy="levelset")
    np.testing.assert_allclose(
        inflight.x, np.asarray(old.solve(jnp.asarray(b))),
        rtol=1e-12, atol=1e-12)
    # a post-refresh submit is answered with the NEW values
    after = eng.submit(b)
    eng.run()
    new = SpTRSV.build(CSRMatrix(L.indptr, L.indices, data2, L.shape),
                       strategy="levelset")
    np.testing.assert_allclose(
        after.x, np.asarray(new.solve(jnp.asarray(b))),
        rtol=1e-12, atol=1e-12)
    # and the two factors genuinely differ, or the test proves nothing
    assert not np.allclose(inflight.x, after.x)


def test_mixed_dtype_request_does_not_retrace():
    L = chain_matrix(64, dtype=np.float32)
    s = SpTRSV.build(L, strategy="levelset")
    eng = SolveEngine(s, max_batch=4)
    rng = np.random.default_rng(5)
    # warm the m=4 bucket at the solver's dtype
    f32_reqs = [eng.submit(rng.normal(size=L.n).astype(np.float32))
                for _ in range(4)]
    assert eng.run() == 4
    if not hasattr(s._solve_fn, "_cache_size"):
        pytest.skip("jit cache introspection unavailable on this JAX")
    before = s._solve_fn._cache_size()
    # a float64 request in an otherwise-f32 bucket must be solved at the
    # solver's dtype, hitting the already-compiled bucket
    mixed = [eng.submit(rng.normal(size=L.n).astype(np.float64))
             for _ in range(4)]
    assert eng.run() == 4
    assert s._solve_fn._cache_size() == before
    for r in f32_reqs + mixed:
        assert r.done
        assert r.x.dtype == np.float32
        np.testing.assert_allclose(
            r.x, np.asarray(s.solve(jnp.asarray(r.b, jnp.float32))),
            rtol=1e-6, atol=1e-6)


def _guarded_engine(n=48, seed=1, strategy="levelset", max_batch=8):
    L = random_lower(n, seed=seed)
    s = SpTRSV.build(L, strategy=strategy,
                     guard=GuardConfig(on_breakdown="raise"))
    return L, SolveEngine(s, max_batch=max_batch)


def test_failed_requests_counted_as_failed_not_solved():
    """An errored request used to count in ``solved`` — a breakdown-heavy
    tenant read as healthy throughput.  ``step``'s return stays the number
    of requests *completed* (either way)."""
    with enable_x64():
        L, eng = _guarded_engine()
        rng = np.random.default_rng(2)
        good = [eng.submit(rng.standard_normal(L.n)) for _ in range(3)]
        bad_b = rng.standard_normal(L.n)
        bad_b[0] = np.nan
        bad = eng.submit(bad_b)
        assert eng.step() == 4
        assert (eng.solved, eng.failed) == (3, 1)
        st = eng.stats()
        assert (st["solved"], st["failed"]) == (3, 1)
        assert isinstance(bad.error, GuardBreakdownError) and bad.x is None
        for r in good:
            assert r.error is None and r.x is not None


def test_engine_validation_raises_value_errors():
    L = chain_matrix(16)
    s = SpTRSV.build(L, strategy="serial")
    other = SpTRSV.build(chain_matrix(8), strategy="serial")
    with pytest.raises(ValueError, match="max_batch"):
        SolveEngine(s, max_batch=0)
    with pytest.raises(ValueError, match="must share one factor"):
        SolveEngine(s, other)
    eng = SolveEngine(s)   # no transpose solver
    with pytest.raises(ValueError, match=r"\(16,\)"):
        eng.submit(np.zeros(17))
    with pytest.raises(ValueError, match=r"\(16,\)"):
        eng.submit(np.zeros((16, 1)))
    with pytest.raises(ValueError, match="transpose"):
        eng.submit(np.zeros(16), transpose=True)
    with pytest.raises(ValueError, match="promoted solver solves"):
        eng.swap_solvers(other)
    with pytest.raises(ValueError, match="no transpose solver"):
        SolveEngine(s, s).swap_solvers(s)


def test_fallback_counts_batches_consistently():
    """3 requests, one bad: 1 failed batched attempt + 3 width-1 re-solves
    = 4 executor dispatches, and exactly the culprit carries the error."""
    with enable_x64():
        L, eng = _guarded_engine()
        rng = np.random.default_rng(3)
        reqs = [eng.submit(rng.standard_normal(L.n)) for _ in range(2)]
        bad_b = rng.standard_normal(L.n)
        bad_b[5] = np.inf
        bad = eng.submit(bad_b)
        assert eng.batches == 0
        assert eng.step() == 3
        assert eng.batches == 4
        assert (eng.solved, eng.failed) == (2, 1)
        assert isinstance(bad.error, GuardBreakdownError)
        for r in reqs:
            assert r.error is None and r.x is not None
        # a clean follow-up batch adds exactly one dispatch
        eng.submit(rng.standard_normal(L.n))
        eng.run()
        assert eng.batches == 5 and eng.solved == 3


def test_fallback_resolves_through_width1_bucket():
    """The per-request re-solves must reuse the compiled width-1 bucket —
    a bare 1-D solve would trace a fresh executor per RHS and bypass the
    bounded jit-cache discipline."""
    with enable_x64():
        L, eng = _guarded_engine()
        s = eng.solver
        rng = np.random.default_rng(4)
        # warm the width-1 and width-4 buckets
        eng.submit(rng.standard_normal(L.n))
        assert eng.run() == 1
        for _ in range(4):
            eng.submit(rng.standard_normal(L.n))
        assert eng.run() == 4
        if not hasattr(s._solve_fn, "_cache_size"):
            pytest.skip("jit cache introspection unavailable on this JAX")
        before = s._solve_fn._cache_size()
        # now a failing 4-wide batch: fallback re-solves all 4 at width 1
        bad_b = rng.standard_normal(L.n)
        bad_b[0] = np.nan
        eng.submit(bad_b)
        for _ in range(3):
            eng.submit(rng.standard_normal(L.n))
        assert eng.step() == 4
        assert (eng.solved, eng.failed) == (5 + 3, 1)
        assert s._solve_fn._cache_size() == before
