"""Distribution features on 8 virtual devices: MoE-EP == dense-local,
flash attention == naive reference, GPipe == sequential, compressed
all-reduce ≈ mean with bounded error, sharded train step == single-device."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import smoke_config
from repro.launch.mesh import make_mesh
from repro.models.layers import flash_attention
from repro.models.model import DistContext, Model
from repro.models.moe import init_moe, moe_apply
from repro.models.sharding import param_specs, batch_specs


# --------------------------------------------------------------------------
# flash attention vs naive
# --------------------------------------------------------------------------
def _naive_attn(q, k, v, kind, window=0, prefix_len=0, softcap_val=0.0):
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    qf = q.reshape(B, S, Hkv, g, hd).astype(np.float64)
    kf = np.asarray(k, np.float64)
    vf = np.asarray(v, np.float64)
    s = np.einsum("bqhgd,bkhd->bhgqk", qf, kf) / np.sqrt(hd)
    if softcap_val:
        s = softcap_val * np.tanh(s / softcap_val)
    qpos = np.arange(S)[:, None]
    kpos = np.arange(S)[None, :]
    if kind == "causal":
        mask = kpos <= qpos
    elif kind == "window":
        mask = (kpos <= qpos) & (kpos > qpos - window)
    elif kind == "prefix":
        mask = (kpos <= qpos) | (kpos < prefix_len)
    else:
        mask = np.ones((S, S), bool)
    s = np.where(mask[None, None, None], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    o = np.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return o.reshape(B, S, Hq, hd)


@pytest.mark.parametrize("kind,window,prefix", [
    ("causal", 0, 0), ("window", 7, 0), ("full", 0, 0), ("prefix", 0, 5),
])
@pytest.mark.parametrize("gqa", [1, 4])
def test_flash_matches_naive(kind, window, prefix, gqa):
    rng = np.random.default_rng(0)
    B, S, Hkv, hd = 2, 37, 2, 8
    q = rng.normal(size=(B, S, Hkv * gqa, hd)).astype(np.float32)
    k = rng.normal(size=(B, S, Hkv, hd)).astype(np.float32)
    v = rng.normal(size=(B, S, Hkv, hd)).astype(np.float32)
    got = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          kind=kind, window=window, prefix_len=prefix,
                          block_q=16, block_k=8, softcap_val=2.0)
    ref = _naive_attn(q, k, v, kind, window, prefix, softcap_val=2.0)
    np.testing.assert_allclose(np.asarray(got), ref, rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------------
# MoE: expert-parallel shard_map path == dense local path
# --------------------------------------------------------------------------
def test_moe_ep_matches_local():
    cfg = smoke_config("llama4-scout-17b-a16e")   # 4 experts top-1 + shared
    key = jax.random.key(0)
    params = init_moe(key, cfg)
    x = jax.random.normal(jax.random.key(1), (4, 8, cfg.d_model), jnp.float32)
    y_local, aux_local = moe_apply(params, cfg, x, mesh=None)
    mesh = make_mesh((4, 2), ("data", "model"))
    with mesh:
        y_ep, aux_ep = jax.jit(
            lambda p, x: moe_apply(p, cfg, x, mesh=mesh))(params, x)
    np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_ep),
                               rtol=2e-4, atol=2e-4)
    # aux: local computes E·Σ f_e·p_e over all tokens; EP pmeans per-shard
    # estimates — mean-of-products ≠ product-of-means, both are unbiased
    # Switch estimators, so only require same scale
    np.testing.assert_allclose(float(aux_local), float(aux_ep), rtol=0.3)


def test_moe_top2_dense_residual():
    cfg = smoke_config("arctic-480b")             # 4 experts top-2 + dense
    params = init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model), jnp.float32)
    y, aux = moe_apply(params, cfg, x, mesh=None)
    assert y.shape == x.shape
    assert jnp.isfinite(y).all() and jnp.isfinite(aux)


# --------------------------------------------------------------------------
# sharded forward == single-device forward
# --------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["gemma3-1b", "llama4-scout-17b-a16e"])
def test_sharded_forward_matches_local(arch):
    import dataclasses
    cfg = smoke_config(arch)
    if cfg.n_experts:
        # EP shards tokens before computing capacity, so which tokens drop
        # differs from the local path at tight capacity; test equality in
        # the no-drop regime (drop behaviour is covered by test_moe_*)
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = Model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (4, 16)))
    batch = {"tokens": toks}
    logits_local, _ = jax.jit(model.forward)(params, batch)

    mesh = make_mesh((4, 2), ("data", "model"))
    specs = param_specs(params, mesh, cfg)
    sharded = jax.device_put(params, jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs))
    dist = DistContext(mesh=mesh, dp_axes=("data",))
    with mesh:
        logits_sh, _ = jax.jit(
            lambda p, b: model.forward(p, b, dist=dist))(sharded, batch)
    np.testing.assert_allclose(
        np.asarray(logits_sh, np.float32), np.asarray(logits_local, np.float32),
        rtol=5e-2, atol=5e-2)


# --------------------------------------------------------------------------
# GPipe
# --------------------------------------------------------------------------
def test_gpipe_matches_sequential():
    from repro.distributed.pipeline import make_gpipe

    mesh = make_mesh((8,), ("pipe",))
    P_, M, mb, d = 8, 4, 2, 16
    rng = np.random.default_rng(0)
    stage_w = jnp.asarray(rng.normal(size=(P_, d, d)).astype(np.float32) * 0.3)

    def stage_apply(w, x):
        return jnp.tanh(x @ w)

    xs = jnp.asarray(rng.normal(size=(M, mb, d)).astype(np.float32))
    fn = make_gpipe(stage_apply, mesh, "pipe")
    with mesh:
        out = jax.jit(fn)(stage_w, xs)
    ref = xs
    for s in range(P_):
        ref = jnp.tanh(ref @ stage_w[s])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------
# compressed gradient all-reduce
# --------------------------------------------------------------------------
def test_compressed_allreduce_error_feedback():
    from repro.compat import shard_map
    from repro.distributed.compress import compressed_allreduce

    mesh = make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    gs = rng.normal(size=(8, 64)).astype(np.float32)
    target = gs.mean(0)

    def body(g, r):
        out, rr = compressed_allreduce(g[0], r[0], "data")
        return out, rr[None]

    f = shard_map(body, mesh=mesh, in_specs=(P("data", None), P("data", None)),
                  out_specs=(P(None), P("data", None)), check_vma=False)
    resid = jnp.zeros((8, 64), jnp.float32)
    out, resid = f(jnp.asarray(gs), resid)
    # single round: int8-quantized mean close to true mean
    np.testing.assert_allclose(np.asarray(out), target, atol=0.1)
    # error feedback: residual bounded by a quant step
    assert float(jnp.abs(resid).max()) < 0.2
    # accumulated over rounds, EF keeps the *sum* unbiased
    total_err = np.zeros(64)
    resid = jnp.zeros((8, 64), jnp.float32)
    for _ in range(20):
        out, resid = f(jnp.asarray(gs), resid)
        total_err += np.asarray(out) - target
    assert np.abs(total_err / 20).max() < 0.02
