"""First-class transpose solves (Lᵀ x = b): every strategy × rewrite ×
dtype × single/batched against a NumPy backward-substitution oracle, the
shared-analysis machinery (CSC view, reverse levels), and equivalence of the
shared-analysis IC preconditioner with the legacy reverse-permute
construction."""
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import RewriteConfig, SpTRSV, build_level_sets, rewrite_matrix
from repro.core.csr import from_coo
from repro.core.levels import compute_levels, compute_reverse_levels, compute_upper_levels
from repro.sparse import banded_lower, chain_matrix, lung2_like, random_lower

from test_property_solvers import _make_matrix, matrix_spec


def np_bsolve(L, b):
    """Backward-substitution oracle for Lᵀ x = b (host numpy, float64).

    Handles b of shape (n,) or (n, m)."""
    U = L.to_dense().T.astype(np.float64)
    n = U.shape[0]
    x = np.zeros(np.shape(b), dtype=np.float64)
    for i in range(n - 1, -1, -1):
        x[i] = (b[i] - U[i, i + 1:] @ x[i + 1:]) / U[i, i]
    return x


LOCAL_STRATEGIES = ["serial", "levelset", "levelset_unroll",
                    "pallas_level", "pallas_fused"]


# -- shared analysis building blocks ---------------------------------------
def test_transpose_and_csc_view_match_dense():
    L = random_lower(83, avg_offdiag=3.0, seed=9)
    Lt = L.transpose()
    np.testing.assert_allclose(Lt.to_dense(), L.to_dense().T)
    # upper factor stores the diagonal first in each row
    np.testing.assert_allclose(Lt.diagonal(first=True), L.diagonal())
    colptr, rows, vals = L.csc_view()
    np.testing.assert_array_equal(colptr, Lt.indptr)
    np.testing.assert_array_equal(rows, Lt.indices)
    np.testing.assert_array_equal(vals, Lt.data)


@pytest.mark.parametrize("kind", ["random", "banded", "chain", "lung2"])
def test_reverse_levels_derivations_agree(kind):
    L = _make_matrix(kind, 90, seed=17)
    levels = build_level_sets(L)
    loop = compute_reverse_levels(L)
    derived = compute_reverse_levels(L, levels)       # vectorized wavefront
    gathered = compute_upper_levels(L.transpose())    # gather over Lᵀ rows
    np.testing.assert_array_equal(derived, loop)
    np.testing.assert_array_equal(gathered, loop)
    # and they equal the legacy construction: forward levels of the
    # reverse-permuted transpose, mapped back through the permutation
    n = L.n
    rows = np.repeat(np.arange(n), L.row_nnz())
    Lt_rev = from_coo(n - 1 - L.indices, n - 1 - rows, L.data, (n, n))
    np.testing.assert_array_equal(compute_levels(Lt_rev)[::-1], loop)


def test_rewrite_upper_preserves_solution():
    """L'ᵀ x = E b must solve the same system as Lᵀ x = b."""
    L = lung2_like(scale=0.02, fat_levels=4, thin_run=6)
    Lt = L.transpose()
    res = rewrite_matrix(Lt, config=RewriteConfig(thin_threshold=3), upper=True)
    assert res.stats.levels_after < res.stats.levels_before
    rng = np.random.default_rng(3)
    b = rng.normal(size=L.n)
    x = np_bsolve(L, b)
    bp = res.E.matvec(b)
    # solve the rewritten upper system densely
    Up = res.L.to_dense()
    xp = np.linalg.solve(Up, bp)
    np.testing.assert_allclose(xp, x, rtol=1e-9, atol=1e-10)


# -- solver correctness ----------------------------------------------------
@given(matrix_spec())
@settings(max_examples=4, deadline=None)
def test_transpose_strategies_match_oracle_f32(spec):
    kind, n, seed = spec
    L = _make_matrix(kind, n, seed, dtype=np.float32)
    rng = np.random.default_rng(seed ^ 0xBACD)
    b = rng.normal(size=L.n).astype(np.float32)
    x_ref = np_bsolve(L.astype(np.float64), b.astype(np.float64))
    for strategy in LOCAL_STRATEGIES:
        for rewrite in (None, RewriteConfig(thin_threshold=3)):
            s = SpTRSV.build(L, strategy=strategy, transpose=True, rewrite=rewrite)
            assert s.transpose
            x = np.asarray(s.solve(jnp.asarray(b)))
            np.testing.assert_allclose(
                x, x_ref, rtol=2e-3, atol=2e-4,
                err_msg=f"{kind} n={n} seed={seed} {strategy} "
                        f"rewrite={rewrite is not None}")


@given(matrix_spec())
@settings(max_examples=2, deadline=None)
def test_transpose_strategies_match_oracle_f64(spec):
    from repro.compat import enable_x64

    kind, n, seed = spec
    with enable_x64():
        L = _make_matrix(kind, n, seed, dtype=np.float64)
        rng = np.random.default_rng(seed ^ 0xD00D)
        b = rng.normal(size=L.n)
        x_ref = np_bsolve(L, b)
        for strategy in LOCAL_STRATEGIES:
            for rewrite in (None, RewriteConfig(thin_threshold=3)):
                s = SpTRSV.build(L, strategy=strategy, transpose=True,
                                 rewrite=rewrite)
                x = np.asarray(s.solve(jnp.asarray(b, dtype=jnp.float64)))
                np.testing.assert_allclose(
                    x, x_ref, rtol=1e-9, atol=1e-10,
                    err_msg=f"{kind} n={n} seed={seed} {strategy} "
                            f"rewrite={rewrite is not None}")


@pytest.mark.parametrize("strategy", LOCAL_STRATEGIES)
@pytest.mark.parametrize("rewrite", [None, RewriteConfig(thin_threshold=3)])
def test_transpose_batched_matches_columnwise(strategy, rewrite):
    L = lung2_like(scale=0.02, fat_levels=4, thin_run=6, dtype=np.float32)
    rng = np.random.default_rng(11)
    B = rng.normal(size=(L.n, 16)).astype(np.float32)
    s = SpTRSV.build(L, strategy=strategy, transpose=True, rewrite=rewrite)
    X = np.asarray(s.solve_batched(jnp.asarray(B)))
    assert X.shape == B.shape
    cols = np.stack(
        [np.asarray(s.solve(jnp.asarray(B[:, j]))) for j in range(B.shape[1])],
        axis=1)
    np.testing.assert_allclose(X, cols, rtol=1e-5, atol=1e-5)
    X_ref = np_bsolve(L.astype(np.float64), B.astype(np.float64))
    np.testing.assert_allclose(X, X_ref, rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("dist_strategy", ["all_gather", "psum"])
def test_transpose_distributed(dist_strategy):
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((8,), ("data",))
    L = random_lower(400, avg_offdiag=3.0, seed=4, dtype=np.float32)
    rng = np.random.default_rng(6)
    b = rng.normal(size=400).astype(np.float32)
    x_ref = np_bsolve(L.astype(np.float64), b.astype(np.float64))
    s = SpTRSV.build(L, strategy="distributed", transpose=True, mesh=mesh,
                     dist_strategy=dist_strategy,
                     rewrite=RewriteConfig(thin_threshold=4))
    x = np.asarray(s.solve(jnp.asarray(b)))
    np.testing.assert_allclose(x, x_ref, rtol=2e-3, atol=2e-4)
    B = rng.normal(size=(400, 8)).astype(np.float32)
    X = np.asarray(s.solve_batched(jnp.asarray(B)))
    np.testing.assert_allclose(
        X, np_bsolve(L.astype(np.float64), B.astype(np.float64)),
        rtol=2e-3, atol=2e-4)


def test_build_pair_shares_analysis_and_matches_separate_builds():
    L = banded_lower(150, bandwidth=5, fill=0.6, seed=8, dtype=np.float32)
    fwd, bwd = SpTRSV.build_pair(L, strategy="levelset")
    assert not fwd.transpose and bwd.transpose
    rng = np.random.default_rng(0)
    b = rng.normal(size=L.n).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(bwd.solve(jnp.asarray(b))),
        np.asarray(SpTRSV.build(L, transpose=True).solve(jnp.asarray(b))),
        rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(fwd.solve(jnp.asarray(b))),
        np.asarray(SpTRSV.build(L).solve(jnp.asarray(b))),
        rtol=1e-6, atol=1e-6)


# -- preconditioner equivalence --------------------------------------------
def test_shared_analysis_preconditioner_matches_reverse_permute_on_lung2():
    from repro.core.pcg import make_ic_preconditioner

    L = lung2_like(scale=0.02, fat_levels=4, thin_run=6, dtype=np.float32)
    rewrite = RewriteConfig(thin_threshold=2)

    # legacy construction: transpose + reverse-permute + second full build
    n = L.n
    rows = np.repeat(np.arange(n), L.row_nnz())
    Lt = from_coo(L.indices, rows, L.data, (n, n))
    rows_t = np.repeat(np.arange(n), Lt.row_nnz())
    Lt_rev = from_coo(n - 1 - rows_t, n - 1 - Lt.indices, Lt.data, (n, n))
    fwd = SpTRSV.build(L, rewrite=rewrite)
    bwd = SpTRSV.build(Lt_rev, rewrite=rewrite)

    def legacy(r):
        return bwd.solve(fwd.solve(r)[::-1])[::-1]

    shared = make_ic_preconditioner(L, rewrite=rewrite)
    rng = np.random.default_rng(5)
    r = jnp.asarray(rng.normal(size=n).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(shared(r)), np.asarray(legacy(r)), rtol=1e-4, atol=1e-5)
    # batched applies agree column-wise too
    R = jnp.asarray(rng.normal(size=(n, 4)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(shared(R)), np.asarray(legacy(R)), rtol=1e-4, atol=1e-5)


# -- serving ---------------------------------------------------------------
def test_solve_engine_routes_transpose_requests():
    from repro.serve.engine import SolveEngine

    L = random_lower(120, avg_offdiag=3.0, seed=2, dtype=np.float32)
    fwd, bwd = SpTRSV.build_pair(L, strategy="levelset")
    eng = SolveEngine(fwd, bwd, max_batch=8)
    rng = np.random.default_rng(1)
    reqs = []
    for i in range(10):
        b = rng.normal(size=L.n).astype(np.float32)
        reqs.append((eng.submit(b, transpose=bool(i % 2)), b, bool(i % 2)))
    done = eng.run()
    assert done == 10 and eng.solved == 10
    from test_property_solvers import np_fsolve

    for req, b, transpose in reqs:
        assert req.done
        ref = (np_bsolve if transpose else np_fsolve)(
            L.astype(np.float64), b.astype(np.float64))
        np.testing.assert_allclose(req.x, ref, rtol=2e-3, atol=2e-4)


def test_solve_engine_rejects_transpose_without_solver():
    L = random_lower(30, seed=0, dtype=np.float32)
    from repro.serve.engine import SolveEngine

    eng = SolveEngine(SpTRSV.build(L))
    # a real ValueError, not an assert — asserts are stripped under
    # ``python -O`` and the request would strand in the queue unanswered
    with pytest.raises(ValueError, match="transpose"):
        eng.submit(np.zeros(L.n, np.float32), transpose=True)


# -- validation ------------------------------------------------------------
def test_validate_catches_malformed_row_beyond_spot_check():
    """A row with unsorted/duplicate columns past the old 64-row spot-check
    window must fail validation (it would corrupt _pack_rows' diag-last
    assumption silently)."""
    from repro.core.csr import CSRMatrix, from_dense

    L = random_lower(100, avg_offdiag=2.0, seed=1)
    L.validate()  # well-formed passes the full check
    bad_row = 80
    lo, hi = int(L.indptr[bad_row]), int(L.indptr[bad_row + 1])
    assert hi - lo >= 2, "need an off-diagonal entry to corrupt"
    indices = L.indices.copy()
    indices[lo], indices[hi - 1] = indices[hi - 1], indices[lo]  # unsort
    bad = CSRMatrix(L.indptr, indices, L.data, L.shape)
    with pytest.raises(AssertionError, match=f"row {bad_row}"):
        bad.validate()
