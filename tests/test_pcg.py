"""PCG + IC(0)/SpTRSV preconditioner integration."""
import jax.numpy as jnp
import numpy as np

from repro.core.pcg import make_ic_preconditioner, pcg
from repro.core.rewrite import RewriteConfig
from repro.sparse import ic0_factor, poisson2d


def test_pcg_converges_faster_with_sptrsv_preconditioner():
    A = poisson2d(24, 24, dtype=np.float32)
    L = ic0_factor(A)
    M = make_ic_preconditioner(L, rewrite=RewriteConfig(thin_threshold=4))
    b = jnp.asarray(np.random.default_rng(0).normal(size=A.n).astype(np.float32))
    plain = pcg(A, b, None, tol=1e-5, maxiter=1500)
    pre = pcg(A, b, M, tol=1e-5, maxiter=1500)
    assert pre.converged
    assert pre.iters < plain.iters, (pre.iters, plain.iters)
    x = np.asarray(pre.x, np.float64)
    r = np.asarray(b, np.float64) - A.astype(np.float64).matvec(x)
    assert np.linalg.norm(r) <= 1e-4 * np.linalg.norm(np.asarray(b))


def test_preconditioner_solve_exact_on_triangular_system():
    """(L Lᵀ)^{-1} applied to (L Lᵀ) v must give v back."""
    A = poisson2d(12, 12, dtype=np.float64)
    L = ic0_factor(A)
    M = make_ic_preconditioner(L, rewrite=None)
    rng = np.random.default_rng(1)
    v = rng.normal(size=A.n)
    Ld = L.to_dense()
    w = Ld @ (Ld.T @ v)
    got = np.asarray(M(jnp.asarray(w)))
    np.testing.assert_allclose(got, v, rtol=1e-4, atol=1e-5)  # f32 solves
