"""PCG + IC(0)/SpTRSV preconditioner integration."""
import jax.numpy as jnp
import numpy as np

from repro.core.pcg import make_ic_preconditioner, pcg
from repro.core.rewrite import RewriteConfig
from repro.sparse import ic0_factor, poisson2d


def test_pcg_converges_faster_with_sptrsv_preconditioner():
    A = poisson2d(24, 24, dtype=np.float32)
    L = ic0_factor(A)
    M = make_ic_preconditioner(L, rewrite=RewriteConfig(thin_threshold=4))
    b = jnp.asarray(np.random.default_rng(0).normal(size=A.n).astype(np.float32))
    plain = pcg(A, b, None, tol=1e-5, maxiter=1500)
    pre = pcg(A, b, M, tol=1e-5, maxiter=1500)
    assert pre.converged
    assert pre.iters < plain.iters, (pre.iters, plain.iters)
    x = np.asarray(pre.x, np.float64)
    r = np.asarray(b, np.float64) - A.astype(np.float64).matvec(x)
    assert np.linalg.norm(r) <= 1e-4 * np.linalg.norm(np.asarray(b))


def test_preconditioner_solve_exact_on_triangular_system():
    """(L Lᵀ)^{-1} applied to (L Lᵀ) v must give v back."""
    A = poisson2d(12, 12, dtype=np.float64)
    L = ic0_factor(A)
    M = make_ic_preconditioner(L, rewrite=None)
    rng = np.random.default_rng(1)
    v = rng.normal(size=A.n)
    Ld = L.to_dense()
    w = Ld @ (Ld.T @ v)
    got = np.asarray(M(jnp.asarray(w)))
    np.testing.assert_allclose(got, v, rtol=1e-4, atol=1e-5)  # f32 solves


# --------------------------------------------------------------------------
# regression: degenerate inputs must return well-formed results
# --------------------------------------------------------------------------
def test_pcg_maxiter_zero_returns_wellformed():
    """maxiter=0 used to crash with UnboundLocalError on `res`; it must
    return the initial iterate with a finite residual."""
    A = poisson2d(8, 8, dtype=np.float32)
    b = jnp.asarray(np.random.default_rng(0).normal(size=A.n).astype(np.float32))
    res = pcg(A, b, None, maxiter=0)
    assert not res.converged
    assert res.iters == 0
    assert np.isfinite(res.residual)
    assert np.isfinite(np.asarray(res.x)).all()


def test_pcg_zero_rhs_converges_immediately():
    """b = 0 used to make the tolerance test `res <= 0` (b_norm == 0) and
    spin to maxiter; x = 0 is exact and must converge in 0 iterations."""
    A = poisson2d(8, 8, dtype=np.float32)
    res = pcg(A, jnp.zeros(A.n, jnp.float32), None, maxiter=50)
    assert res.converged
    assert res.iters == 0
    assert res.residual == 0.0
    np.testing.assert_array_equal(np.asarray(res.x), 0.0)
    # with a preconditioner too (exercises M_inv on the zero residual path)
    L = ic0_factor(A)
    M = make_ic_preconditioner(L, rewrite=None)
    res_m = pcg(A, jnp.zeros(A.n, jnp.float32), M, maxiter=50)
    assert res_m.converged and np.isfinite(np.asarray(res_m.x)).all()


def test_pcg_breakdown_returns_wellformed():
    """Lanczos breakdown (pᵀAp = 0, e.g. A = 0): the unbatched path used to
    divide by zero and return NaN x with converged=False unset downstream;
    it must return the last finite iterate as a well-formed non-converged
    result — the same guard pcg_batched always had."""
    from repro.core import from_coo

    n = 8
    Z = from_coo([0], [0], [0.0], (n, n))   # all-zero SPD-shaped matrix
    res = pcg(Z, jnp.ones(n, jnp.float32), None, maxiter=10)
    assert not res.converged
    assert np.isfinite(np.asarray(res.x)).all()
    assert np.isfinite(res.residual)


def test_pcg_stall_window_stops_stagnation():
    """A rank-deficient preconditioner confines the search directions to a
    subspace: the residual component outside it can never shrink, so the
    iteration stagnates at a nonzero floor.  stall_window must cut the loop
    short as non-converged instead of burning all of maxiter (the
    iteration-control companion of the inexact sweeps preconditioner)."""
    A = poisson2d(8, 8, dtype=np.float32)
    b = jnp.asarray(np.random.default_rng(2).normal(size=A.n).astype(np.float32))
    mask = jnp.asarray((np.arange(A.n) % 2 == 0).astype(np.float32))
    frozen = pcg(A, b, lambda r: r * mask, tol=1e-6, maxiter=400,
                 stall_window=5)
    assert not frozen.converged
    assert frozen.iters < 400


def test_pcg_batched_maxiter_zero_and_zero_rhs():
    from repro.core.pcg import pcg_batched

    A = poisson2d(8, 8, dtype=np.float32)
    rng = np.random.default_rng(1)
    b = rng.normal(size=A.n).astype(np.float32)
    # maxiter=0: well-formed, nothing converged
    res0 = pcg_batched(A, jnp.stack([b, b], axis=1), None, maxiter=0)
    assert (~res0.converged).all()
    assert np.isfinite(res0.residual).all()
    assert np.isfinite(np.asarray(res0.x)).all()
    # mixed batch: a zero column converges in 0 iters without perturbing
    # the nonzero column, and produces no NaN
    B = np.stack([np.zeros_like(b), b], axis=1)
    res = pcg_batched(A, jnp.asarray(B), None, tol=1e-5, maxiter=300)
    assert res.converged.all()
    assert res.iters[0] == 0
    assert res.iters[1] > 0
    assert np.isfinite(np.asarray(res.x)).all()
    np.testing.assert_array_equal(np.asarray(res.x[:, 0]), 0.0)
