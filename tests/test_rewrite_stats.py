"""Regression pin for the paper's headline transformation on the lung2
structural twin: level-count collapse, bounded fill, bounded FLOP increase.

The generators are seeded, so these numbers are exact and deterministic; a
change to the rewrite policy that silently weakens the transformation (fewer
levels removed, more fill, costlier RHS update) fails here instead of
showing up as a quiet benchmark regression."""
import numpy as np

from repro.core import RewriteConfig, rewrite_matrix
from repro.sparse import lung2_like

# lung2_like(scale=0.05, fat_levels=6, thin_run=10, seed=0): the tier-1-size
# twin used across the suite (full scale pins the same invariants but takes
# minutes to rewrite on CI hardware).
_CFG = RewriteConfig(thin_threshold=2)


def _stats():
    L = lung2_like(scale=0.05, fat_levels=6, thin_run=10, dtype=np.float64)
    return L, rewrite_matrix(L, config=_CFG).stats


def test_rewrite_stats_exact_pin():
    L, s = _stats()
    # exact pins — update deliberately, with a benchmark run in hand
    assert (s.levels_before, s.levels_after) == (66, 12)
    assert s.nnz_before == 4250
    assert s.nnz_after == 4485
    assert s.e_nnz_offdiag == 540
    assert s.rows_rewritten == 108
    assert s.eliminations == 108


def test_rewrite_budgets_respected():
    L, s = _stats()
    # fill budget: nnz(L') <= max_fill_ratio * nnz(L)
    assert s.nnz_after <= _CFG.max_fill_ratio * s.nnz_before
    # the paper reports ~+10% FLOPs on lung2; our twin stays under +25%
    assert 0.0 <= s.flop_increase < 0.25
    # headline: the thin-level pathology collapses (>75% of barriers gone)
    assert s.level_reduction > 0.75


def test_rewrite_stats_summary_renders():
    _, s = _stats()
    text = s.summary()
    assert "levels 66 -> 12" in text
    assert "rows rewritten 108" in text
