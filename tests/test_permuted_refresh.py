"""Permuted-space packed execution + value-only refresh (tentpole PR).

Covers:

* the schedule-order permutation machinery (``Schedule.perm`` is a true
  permutation with contiguous per-segment spans, coarsening included);
* property tests: permuted-space solve ≡ legacy scatter solve across
  strategy × rewrite × transpose × batch at few-ulp tolerance;
* ``refresh(values)`` ≡ a fresh ``build`` on regenerated values — including
  the rewrite replay (``replay_rewrite_values``), transpose reordering, the
  distributed strategy, and the scatter-layout cold-rebuild fallback;
* refresh does NOT re-trace the compiled executable (the production
  economics: O(nnz) re-pack, jit cache hit);
* the ``gather_unroll_max_k`` build knob (regression: the fallback to the
  fused 3-D gather still logs and stays correct);
* ``SpTRSV.stats()`` reports packed-buffer bytes / padding / permutation.
"""
import logging

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RewriteConfig, SpTRSV
from repro.core.codegen import build_schedule
from repro.core.coarsen import CoarsenConfig, coarsen_schedule
from repro.core.csr import CSRMatrix
from repro.core.packed import build_packed_layout, pack_values
from repro.core.rewrite import replay_rewrite_values, rewrite_matrix
from repro.sparse import banded_lower, chain_matrix, lung2_like, random_lower

LOCAL_STRATEGIES = ["serial", "levelset", "levelset_unroll",
                    "pallas_level", "pallas_fused"]


def _lung2():
    return lung2_like(scale=0.04, fat_levels=5, thin_run=8, dtype=np.float32)


def _regen_values(L: CSRMatrix, seed: int) -> np.ndarray:
    """New values on the same pattern, diagonally dominant either diagonal
    convention (bump the diagonal entries wherever they are stored)."""
    rng = np.random.default_rng(seed)
    data = (L.data + 0.1 * rng.standard_normal(L.nnz)).astype(L.dtype)
    data[L.indptr[1:] - 1] += 3.0   # lower triangular: diagonal last
    return data


# -------------------------------------------------------------------------
# permutation machinery
# -------------------------------------------------------------------------
@pytest.mark.parametrize("coarsen", [False, True])
@pytest.mark.parametrize("bucket", [0.0, 1.5])
def test_schedule_perm_is_contiguous_permutation(coarsen, bucket):
    L = _lung2()
    sched = build_schedule(L, bucket_pad_ratio=bucket)
    if coarsen:
        sched = coarsen_schedule(sched, CoarsenConfig())
    perm = sched.perm()
    assert perm.shape == (L.n,)
    assert np.array_equal(np.sort(perm), np.arange(L.n))  # true permutation
    offs = sched.row_offsets()
    assert offs[-1] == L.n
    for slab, lo, hi in zip(sched.slabs, offs[:-1], offs[1:]):
        assert np.array_equal(perm[lo:hi], slab.rows)      # contiguous span


def test_packed_layout_cols_are_positions_and_src_roundtrip():
    L = _lung2()
    sched = coarsen_schedule(build_schedule(L), CoarsenConfig())
    lay = build_packed_layout(sched)
    assert lay.n_pad >= L.n
    # re-packing the ORIGINAL data must reproduce the packed buffers exactly
    vf, df = pack_values(lay, L.data)
    np.testing.assert_array_equal(vf, lay.vals_flat)
    np.testing.assert_array_equal(df, lay.diag_flat)
    # every non-pad value is addressable through its src index
    assert (lay.vals_src < L.nnz).all() and (lay.diag_src < L.nnz).all()
    st = lay.stats()
    assert st.permutation_applied
    assert st.value_bytes == lay.vals_flat.nbytes + lay.diag_flat.nbytes
    assert st.padded_value_bytes < st.value_bytes


def test_levelsets_row_permutation():
    from repro.core import build_level_sets

    L = _lung2()
    perm = build_level_sets(L).row_permutation()
    assert np.array_equal(np.sort(perm), np.arange(L.n))


# -------------------------------------------------------------------------
# permuted ≡ scatter across strategy × rewrite × transpose × batch
# -------------------------------------------------------------------------
@pytest.mark.parametrize("strategy", LOCAL_STRATEGIES)
@pytest.mark.parametrize("rewrite", [None, RewriteConfig(thin_threshold=2)])
@pytest.mark.parametrize("transpose", [False, True])
def test_permuted_matches_scatter(strategy, rewrite, transpose):
    L = _lung2()
    rng = np.random.default_rng(3)
    b = jnp.asarray(rng.standard_normal(L.n).astype(np.float32))
    B = jnp.asarray(rng.standard_normal((L.n, 4)).astype(np.float32))
    coarsen = True if strategy in ("levelset", "levelset_unroll",
                                   "pallas_level") else None
    kw = dict(strategy=strategy, rewrite=rewrite, transpose=transpose,
              coarsen=coarsen)
    sp = SpTRSV.build(L, layout="permuted", **kw)
    ss = SpTRSV.build(L, layout="scatter", **kw)
    np.testing.assert_allclose(np.asarray(sp.solve(b)),
                               np.asarray(ss.solve(b)),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(sp.solve(B)),
                               np.asarray(ss.solve(B)),
                               rtol=1e-6, atol=1e-6)


def test_permuted_matches_scatter_distributed():
    import jax
    from jax.sharding import Mesh

    L = _lung2()
    mesh = Mesh(np.array(jax.devices()[:4]), ("data",))
    rng = np.random.default_rng(5)
    b = jnp.asarray(rng.standard_normal(L.n).astype(np.float32))
    B = jnp.asarray(rng.standard_normal((L.n, 3)).astype(np.float32))
    for dist_strategy in ("all_gather", "psum"):
        kw = dict(strategy="distributed", mesh=mesh, coarsen=True,
                  dist_strategy=dist_strategy)
        sp = SpTRSV.build(L, layout="permuted", **kw)
        ss = SpTRSV.build(L, layout="scatter", **kw)
        np.testing.assert_allclose(np.asarray(sp.solve(b)),
                                   np.asarray(ss.solve(b)),
                                   rtol=1e-6, atol=1e-6,
                                   err_msg=dist_strategy)
        np.testing.assert_allclose(np.asarray(sp.solve(B)),
                                   np.asarray(ss.solve(B)),
                                   rtol=1e-6, atol=1e-6,
                                   err_msg=dist_strategy)


# -------------------------------------------------------------------------
# refresh ≡ fresh build
# -------------------------------------------------------------------------
@pytest.mark.parametrize("strategy", LOCAL_STRATEGIES)
@pytest.mark.parametrize("rewrite", [None, RewriteConfig(thin_threshold=2)])
@pytest.mark.parametrize("transpose", [False, True])
def test_refresh_matches_fresh_build(strategy, rewrite, transpose):
    L = _lung2()
    data2 = _regen_values(L, seed=11)
    L2 = CSRMatrix(L.indptr, L.indices, data2, L.shape)
    rng = np.random.default_rng(7)
    b = jnp.asarray(rng.standard_normal(L.n).astype(np.float32))
    B = jnp.asarray(rng.standard_normal((L.n, 3)).astype(np.float32))
    kw = dict(strategy=strategy, rewrite=rewrite, transpose=transpose)
    s = SpTRSV.build(L, **kw)
    fresh = SpTRSV.build(L2, **kw)
    assert s.refresh(data2) is s
    np.testing.assert_allclose(np.asarray(s.solve(b)),
                               np.asarray(fresh.solve(b)),
                               rtol=2e-6, atol=2e-6)
    np.testing.assert_allclose(np.asarray(s.solve(B)),
                               np.asarray(fresh.solve(B)),
                               rtol=2e-6, atol=2e-6)
    # refreshed rewrite bookkeeping must carry the NEW values
    if rewrite is not None:
        np.testing.assert_allclose(s.rewrite_result.L.data,
                                   fresh.rewrite_result.L.data,
                                   rtol=1e-6, atol=1e-6)


def test_refresh_distributed():
    import jax
    from jax.sharding import Mesh

    L = _lung2()
    data2 = _regen_values(L, seed=13)
    L2 = CSRMatrix(L.indptr, L.indices, data2, L.shape)
    mesh = Mesh(np.array(jax.devices()[:4]), ("data",))
    rng = np.random.default_rng(9)
    b = jnp.asarray(rng.standard_normal(L.n).astype(np.float32))
    kw = dict(strategy="distributed", mesh=mesh, coarsen=True)
    s = SpTRSV.build(L, **kw)
    fresh = SpTRSV.build(L2, **kw)
    s.refresh(data2)
    np.testing.assert_allclose(np.asarray(s.solve(b)),
                               np.asarray(fresh.solve(b)),
                               rtol=1e-6, atol=1e-6)


def test_refresh_accepts_pattern_identical_csr_and_chains():
    L = _lung2()
    rng = np.random.default_rng(2)
    b = jnp.asarray(rng.standard_normal(L.n).astype(np.float32))
    s = SpTRSV.build(L, strategy="levelset", coarsen=True)
    for seed in (21, 22):   # chained refreshes keep validating/rebuilding
        data2 = _regen_values(L, seed=seed)
        s.refresh(CSRMatrix(L.indptr, L.indices, data2, L.shape))
        fresh = SpTRSV.build(CSRMatrix(L.indptr, L.indices, data2, L.shape),
                             strategy="levelset", coarsen=True)
        np.testing.assert_allclose(np.asarray(s.solve(b)),
                                   np.asarray(fresh.solve(b)),
                                   rtol=1e-6, atol=1e-6)


def test_refresh_rejects_wrong_shape_and_pattern():
    L = _lung2()
    s = SpTRSV.build(L, strategy="levelset")
    with pytest.raises(ValueError, match="one per stored nonzero"):
        s.refresh(np.ones(L.nnz + 1, dtype=np.float32))
    other = random_lower(L.n, seed=1, dtype=np.float32)
    with pytest.raises(ValueError, match="identical sparsity"):
        s.refresh(other)
    # same per-row counts (identical indptr) but a moved column must be
    # rejected too — the cached src maps address the OLD column structure
    idx2 = L.indices.copy()
    moved_one = False
    for i in range(L.n):
        lo, hi = int(L.indptr[i]), int(L.indptr[i + 1])
        if hi - lo >= 2 and idx2[lo + 1] - idx2[lo] > 1:
            idx2[lo + 1] -= 1   # still sorted/unique, different pattern
            moved_one = True
            break
    assert moved_one
    with pytest.raises(ValueError, match="identical sparsity"):
        s.refresh(CSRMatrix(L.indptr, idx2, L.data, L.shape))


def test_refresh_scatter_layout_falls_back_to_rebuild(caplog):
    L = _lung2()
    data2 = _regen_values(L, seed=31)
    rng = np.random.default_rng(4)
    b = jnp.asarray(rng.standard_normal(L.n).astype(np.float32))
    s = SpTRSV.build(L, strategy="levelset", layout="scatter")
    with caplog.at_level(logging.WARNING, logger="repro.core.solver"):
        s.refresh(data2)
    assert any("cold" in r.message for r in caplog.records)
    fresh = SpTRSV.build(CSRMatrix(L.indptr, L.indices, data2, L.shape),
                         strategy="levelset", layout="scatter")
    np.testing.assert_allclose(np.asarray(s.solve(b)),
                               np.asarray(fresh.solve(b)),
                               rtol=1e-6, atol=1e-6)


def test_refresh_does_not_retrace():
    """The production claim: refresh swaps value buffers and hits the jit
    cache — no re-trace, no re-compile."""
    L = _lung2()
    s = SpTRSV.build(L, strategy="levelset", coarsen=True)
    rng = np.random.default_rng(6)
    b = jnp.asarray(rng.standard_normal(L.n).astype(np.float32))
    s.solve(b).block_until_ready()
    if not hasattr(s._solve_fn, "_cache_size"):
        pytest.skip("jit cache introspection unavailable on this JAX")
    before = s._solve_fn._cache_size()
    s.refresh(_regen_values(L, seed=41))
    s.solve(b).block_until_ready()
    assert s._solve_fn._cache_size() == before


def test_replay_rewrite_values_matches_fresh_rewrite():
    L = _lung2()
    res = rewrite_matrix(L, config=RewriteConfig(thin_threshold=2))
    assert res.plan is not None and res.plan.rows
    data2 = _regen_values(L, seed=17)
    L2 = CSRMatrix(L.indptr, L.indices, data2, L.shape)
    lp_data, e_data = replay_rewrite_values(L2, res.plan, res.L, res.E)
    fresh = rewrite_matrix(L2, config=RewriteConfig(thin_threshold=2))
    # same plan on the same pattern → same L'/E patterns, replayed values
    np.testing.assert_array_equal(fresh.L.indptr, res.L.indptr)
    np.testing.assert_array_equal(fresh.L.indices, res.L.indices)
    np.testing.assert_allclose(lp_data, fresh.L.data, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(e_data, fresh.E.data, rtol=1e-6, atol=1e-7)


# -------------------------------------------------------------------------
# gather-unroll knob
# -------------------------------------------------------------------------
def test_gather_unroll_max_k_knob_logs_and_stays_correct(caplog):
    """A per-build cap below a slab's K must route batched gathers through
    the fused 3-D fallback (logged at trace time) without changing
    results."""
    L = banded_lower(96, bandwidth=6, fill=1.0, seed=3, dtype=np.float32)
    rng = np.random.default_rng(8)
    B = jnp.asarray(rng.normal(size=(L.n, 4)).astype(np.float32))
    ref = np.asarray(SpTRSV.build(L, strategy="levelset").solve(B))
    with caplog.at_level(logging.DEBUG, logger="repro.core.codegen"):
        s = SpTRSV.build(L, strategy="levelset", gather_unroll_max_k=2,
                         jit=False)
        X = np.asarray(s.solve(B))
    assert any("falling back" in r.message for r in caplog.records)
    np.testing.assert_allclose(X, ref, rtol=1e-6, atol=1e-6)


# -------------------------------------------------------------------------
# stats surface
# -------------------------------------------------------------------------
def test_stats_reports_packed_bytes_and_permutation():
    L = _lung2()
    s = SpTRSV.build(L, strategy="levelset", coarsen=True)
    st = s.stats()
    assert st["permutation_applied"] and st["layout"] == "permuted"
    assert st["packed_value_bytes"] > 0 and st["packed_index_bytes"] > 0
    assert 0 <= st["padded_value_bytes"] < st["packed_value_bytes"]
    assert st["refreshable_in_place"]
    assert st["segments"] == s.schedule.num_segments
    sc = SpTRSV.build(L, strategy="levelset", layout="scatter").stats()
    assert not sc["permutation_applied"] and not sc["refreshable_in_place"]
    ser = SpTRSV.build(L, strategy="serial").stats()
    assert not ser["permutation_applied"] and ser["refreshable_in_place"]


def test_solve_engine_refresh():
    from repro.serve import SolveEngine

    L = _lung2()
    eng = SolveEngine.from_matrix(L, strategy="levelset")
    rng = np.random.default_rng(12)
    bs = [rng.standard_normal(L.n).astype(np.float32) for _ in range(3)]
    data2 = _regen_values(L, seed=19)
    eng.refresh(data2)
    reqs = [eng.submit(b) for b in bs]
    reqs.append(eng.submit(bs[0], transpose=True))
    eng.run()
    L2 = CSRMatrix(L.indptr, L.indices, data2, L.shape)
    fwd, bwd = SpTRSV.build_pair(L2, strategy="levelset")
    np.testing.assert_allclose(
        reqs[0].x, np.asarray(fwd.solve(jnp.asarray(bs[0]))),
        rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(
        reqs[-1].x, np.asarray(bwd.solve(jnp.asarray(bs[0]))),
        rtol=1e-6, atol=1e-6)
