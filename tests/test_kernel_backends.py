"""Oracle-equivalence of the dormant kernel packages (``spmv_ell``,
``trsm_block``) through the backend interface: both lowering families
(TPU Mosaic and pallas-triton) run under the pallas interpreter against the
packages' pure-jnp ``ref.py`` oracles and SciPy.
"""
import numpy as np
import pytest

import jax.numpy as jnp
import scipy.sparse as sp
import scipy.sparse.linalg as spla

from repro.kernels.spmv_ell import lowering_gpu as spmv_gpu
from repro.kernels.spmv_ell import lowering_tpu as spmv_tpu
from repro.kernels.spmv_ell.ops import make_spmv
from repro.kernels.spmv_ell.ref import spmv_ref
from repro.kernels.trsm_block import lowering_gpu as trsm_gpu
from repro.kernels.trsm_block import lowering_tpu as trsm_tpu
from repro.kernels.trsm_block.ops import make_block_solver
from repro.kernels.trsm_block.ref import block_apply_ref
from repro.sparse import banded_lower, random_lower

BACKENDS = ["interpret", "interpret:gpu"]


def _scipy(L):
    return sp.csr_matrix((L.data, L.indices, L.indptr), shape=L.shape)


# --------------------------------------------------------------------------
# spmv_ell
# --------------------------------------------------------------------------
@pytest.mark.parametrize("low", [spmv_tpu, spmv_gpu],
                         ids=["tpu_lowering", "gpu_lowering"])
def test_spmv_lowerings_match_ref(low):
    rng = np.random.default_rng(0)
    K, n_pad, m_pad = 5, 256, 384
    cols = rng.integers(0, m_pad, size=(K, n_pad)).astype(np.int32)
    vals = rng.standard_normal((K, n_pad)).astype(np.float32)
    v = rng.standard_normal(m_pad).astype(np.float32)
    y = np.asarray(low.spmv(jnp.asarray(v), jnp.asarray(cols),
                            jnp.asarray(vals), block=128, interpret=True))
    y_ref = np.asarray(spmv_ref(jnp.asarray(v), jnp.asarray(cols),
                                jnp.asarray(vals)))
    np.testing.assert_allclose(y, y_ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_make_spmv_matches_scipy(backend):
    rng = np.random.default_rng(1)
    L = random_lower(300, avg_offdiag=4.0, seed=7, dtype=np.float32)
    v = rng.standard_normal(L.n).astype(np.float32)
    y = np.asarray(make_spmv(L, backend=backend, block=128)(jnp.asarray(v)))
    np.testing.assert_allclose(y, _scipy(L) @ v, rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# trsm_block
# --------------------------------------------------------------------------
@pytest.mark.parametrize("low", [trsm_tpu, trsm_gpu],
                         ids=["tpu_lowering", "gpu_lowering"])
def test_block_apply_lowerings_match_ref(low):
    rng = np.random.default_rng(2)
    NB, T = 8, 128
    dinv = rng.standard_normal((NB, T, T)).astype(np.float32)
    rhs = rng.standard_normal((NB, T)).astype(np.float32)
    out = np.asarray(low.block_apply(jnp.asarray(dinv), jnp.asarray(rhs),
                                     batch_block=4, interpret=True))
    ref = np.asarray(block_apply_ref(jnp.asarray(dinv), jnp.asarray(rhs)))
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("make_L", [
    lambda: banded_lower(300, bandwidth=6, seed=3, dtype=np.float32),
    lambda: random_lower(300, avg_offdiag=3.0, seed=4, dtype=np.float32),
], ids=["banded", "random"])
def test_block_solver_matches_scipy(backend, make_L):
    rng = np.random.default_rng(5)
    L = make_L()
    b = rng.standard_normal(L.n).astype(np.float32)
    x = np.asarray(make_block_solver(L, T=128, backend=backend)(
        jnp.asarray(b)))
    x_ref = spla.spsolve_triangular(_scipy(L).tocsr(), b.astype(np.float64),
                                    lower=True)
    scale = max(np.abs(x_ref).max(), 1.0)
    np.testing.assert_allclose(x, x_ref, rtol=2e-4, atol=2e-4 * scale)
