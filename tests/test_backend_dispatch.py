"""Backend abstraction: resolution, lowering dispatch, and planner pricing.

Everything here runs on the CPU host — hardware backends are asserted by
monkeypatching ``jax.default_backend`` (resolution is pure) and by checking
*which lowering module* each kernel package's ``select_lowering`` returns,
never by executing a compiled kernel.  This is the CI story for the backend
matrix: dispatch targets and planner candidate sets are pinned for tpu/gpu
without the hardware.
"""
import warnings

import numpy as np
import pytest

import jax

from repro.core import SpTRSV
from repro.core.analysis import analyze
from repro.core.calibrate import (
    BackendCalibration,
    DEFAULT_CALIBRATIONS,
    get_calibration,
    load_calibrations,
    save_calibrations,
)
from repro.core.coarsen import plan_strategy
from repro.core.codegen import build_schedule
from repro.core.levels import build_level_sets
from repro.kernels.backend import (
    BACKENDS,
    KernelBackend,
    default_backend_name,
    resolve_backend,
)
from repro.sparse import lung2_like


def _mk():
    L = lung2_like(scale=0.02, fat_levels=4, thin_run=6, dtype=np.float32)
    levels = build_level_sets(L)
    an = analyze(L, levels, upper=False)
    sched = build_schedule(L, levels, upper=False)
    return L, an, sched


# --------------------------------------------------------------------------
# resolution
# --------------------------------------------------------------------------
def test_default_backend_mapping(monkeypatch):
    for platform, expected in [("tpu", "tpu"), ("gpu", "gpu"),
                               ("cuda", "gpu"), ("rocm", "gpu"),
                               ("cpu", "interpret")]:
        monkeypatch.setattr(jax, "default_backend", lambda p=platform: p)
        assert default_backend_name() == expected
        bk = resolve_backend(None)
        assert bk is BACKENDS[expected]


def test_resolve_backend_specs():
    assert resolve_backend("tpu") == KernelBackend("tpu", "tpu", False)
    assert resolve_backend("gpu").platform == "gpu"
    assert resolve_backend("cuda") is resolve_backend("gpu")
    assert resolve_backend("interpret").interpret
    assert resolve_backend("interpret").platform == "tpu"
    assert resolve_backend("interpret:gpu").platform == "gpu"
    assert resolve_backend("cpu") is resolve_backend("interpret")
    with pytest.raises(ValueError, match="unknown kernel backend"):
        resolve_backend("vulkan")


def test_resolve_backend_interpret_alias():
    # interpret=True wraps the resolved platform in the interpreter
    assert resolve_backend("tpu", interpret=True).name == "interpret"
    assert resolve_backend("gpu", interpret=True).name == "interpret:gpu"
    # interpret=False forces the compiled twin of the same family
    assert resolve_backend("interpret", interpret=False).name == "tpu"
    assert resolve_backend("interpret:gpu", interpret=False).name == "gpu"
    # passing a resolved backend through is the identity
    bk = resolve_backend("interpret:gpu")
    assert resolve_backend(bk) is bk
    # calibration keys: interpreters are priced as the host
    assert resolve_backend("interpret").calibration_key == "cpu"
    assert resolve_backend("tpu").calibration_key == "tpu"
    assert resolve_backend("gpu").calibration_key == "gpu"


# --------------------------------------------------------------------------
# kernel-package dispatch targets
# --------------------------------------------------------------------------
@pytest.mark.parametrize("pkg", ["sptrsv_level", "sptrsv_fused",
                                 "spmv_ell", "trsm_block"])
def test_select_lowering_dispatch(pkg, monkeypatch):
    import importlib

    ops = importlib.import_module(f"repro.kernels.{pkg}.ops")
    low_tpu = importlib.import_module(f"repro.kernels.{pkg}.lowering_tpu")
    low_gpu = importlib.import_module(f"repro.kernels.{pkg}.lowering_gpu")
    assert ops.select_lowering("tpu") is low_tpu
    assert ops.select_lowering("interpret") is low_tpu
    assert ops.select_lowering("gpu") is low_gpu
    assert ops.select_lowering("interpret:gpu") is low_gpu
    # default resolution follows the (monkeypatched) jax platform
    monkeypatch.setattr(jax, "default_backend", lambda: "gpu")
    assert ops.select_lowering(None) is low_gpu
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert ops.select_lowering(None) is low_tpu


def test_kernel_shims_reexport_tpu_lowering():
    from repro.kernels.sptrsv_level import kernel as k, lowering_tpu as lt

    assert k.level_solve_blocks is lt.level_solve_blocks


# --------------------------------------------------------------------------
# planner pricing per backend
# --------------------------------------------------------------------------
def test_plan_strategy_prices_candidates_per_backend():
    _, an, sched = _mk()
    d_tpu = plan_strategy(an, sched, backend="tpu")
    d_gpu = plan_strategy(an, sched, backend="gpu")
    d_cpu = plan_strategy(an, sched, backend="cpu")
    # named hardware resolves to its compiled lowerings: fused is priced
    assert "pallas_fused" in d_tpu.costs
    assert "pallas_fused" in d_gpu.costs
    # cpu has no compiled pallas path — fused is gated, not outscored
    assert "pallas_fused" not in d_cpu.costs
    # both backends price the full levelset candidate set too
    for d in (d_tpu, d_gpu):
        assert {"serial", "levelset", "levelset_unroll"} <= set(d.costs)
    # the fused dispatch shape differs: one sequential-grid launch on TPU,
    # one launch per wavefront span on GPU — so the priced costs diverge
    assert d_tpu.costs["pallas_fused"] != d_gpu.costs["pallas_fused"]
    assert "backend=tpu" in d_tpu.reason
    assert "backend=gpu" in d_gpu.reason


def test_plan_strategy_accepts_resolved_backend(monkeypatch):
    _, an, sched = _mk()
    d = plan_strategy(an, sched, backend=resolve_backend("gpu"))
    assert "pallas_fused" in d.costs
    # interpret backends are priced as the host: no fused candidate
    d_i = plan_strategy(an, sched, backend=resolve_backend("interpret:gpu"))
    assert "pallas_fused" not in d_i.costs
    # None resolves through jax.default_backend()
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert "pallas_fused" in plan_strategy(an, sched).costs
    with pytest.raises(ValueError, match="unknown planner backend"):
        plan_strategy(an, sched, backend="vulkan")


def test_plan_strategy_fused_gate_is_calibration_driven():
    _, an, sched = _mk()
    # shrink the fused row budget below n: candidate disappears without any
    # platform check involved
    tiny = BackendCalibration(backend="tpu", fused_max_rows=an.n - 1,
                              fused_num_launches="one", lane_width=128)
    d = plan_strategy(an, sched, backend="tpu", calibration=tiny)
    assert "pallas_fused" not in d.costs
    # per-level launch pricing scales with the schedule depth
    one = BackendCalibration(backend="gpu", fused_max_rows=10**9,
                             fused_num_launches="one")
    per = BackendCalibration(backend="gpu", fused_max_rows=10**9,
                             fused_num_launches="per_level")
    c_one = plan_strategy(an, sched, backend="gpu", calibration=one).costs
    c_per = plan_strategy(an, sched, backend="gpu", calibration=per).costs
    assert c_per["pallas_fused"] > c_one["pallas_fused"]


def test_coarsen_module_has_no_hardcoded_platform_checks():
    import inspect

    import repro.core.coarsen as coarsen

    src = inspect.getsource(coarsen)
    assert 'backend == "tpu"' not in src
    assert '_FUSED_VMEM_ROWS' not in src


# --------------------------------------------------------------------------
# calibration table
# --------------------------------------------------------------------------
def test_calibration_defaults_and_roundtrip(tmp_path):
    assert get_calibration("cpu").fused_max_rows == 0
    assert get_calibration("tpu").fused_num_launches == "one"
    assert get_calibration("gpu").fused_num_launches == "per_level"
    with pytest.raises(ValueError, match="no calibration"):
        get_calibration("vulkan")
    path = tmp_path / "calibration.json"
    measured = {"cpu": BackendCalibration(backend="cpu", launch_cost=123.0,
                                          source="measured")}
    save_calibrations(path, measured)
    loaded = load_calibrations(path)
    assert loaded["cpu"] == measured["cpu"]
    # overlay: rows the file carries win, others fall through to defaults
    assert get_calibration("cpu", loaded).launch_cost == 123.0
    assert get_calibration("tpu", loaded) == DEFAULT_CALIBRATIONS["tpu"]


# --------------------------------------------------------------------------
# solver-level knob + deprecation
# --------------------------------------------------------------------------
def test_build_records_backend_and_interpret_deprecation():
    L, _, _ = _mk()
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        s = SpTRSV.build(L, strategy="pallas_level", backend="interpret:gpu")
    assert s.backend == "interpret:gpu"
    assert s.stats()["backend"] == "interpret:gpu"
    # default on this CPU host resolves to the interpret backend
    assert SpTRSV.build(L, strategy="serial").backend == "interpret"
    with pytest.warns(DeprecationWarning, match="interpret= knob is "
                      "deprecated"):
        s2 = SpTRSV.build(L, strategy="serial", interpret=True)
    assert s2.backend == "interpret"


def test_build_pair_threads_backend():
    L, _, _ = _mk()
    fwd, bwd = SpTRSV.build_pair(L, strategy="pallas_level",
                                 backend="interpret:gpu")
    assert fwd.backend == bwd.backend == "interpret:gpu"
    b = np.random.default_rng(3).standard_normal(L.n).astype(np.float32)
    import jax.numpy as jnp

    z = np.asarray(bwd.solve(fwd.solve(jnp.asarray(b))))
    assert np.isfinite(z).all()
