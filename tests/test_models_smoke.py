"""Per-arch smoke tests: reduced same-family config, one forward + one
prefill/decode round-trip on CPU; asserts shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, smoke_config
from repro.models.model import Model


def _batch(cfg, B=2, S=16, key=0):
    rng = np.random.default_rng(key)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))}
    if cfg.family == "audio":
        batch["enc_embed"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)).astype(np.float32))
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.prefix_len, cfg.d_model)).astype(np.float32))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch):
    cfg = smoke_config(arch)
    model = Model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (B, S, cfg.vocab_pad)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch
    assert bool(jnp.isfinite(aux)), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_smoke(arch):
    cfg = smoke_config(arch)
    model = Model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    B, S = 2, 8
    batch = _batch(cfg, B, S)
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, 32))(params, batch)
    assert logits.shape == (B, 1, cfg.vocab_pad)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    step = jax.jit(model.decode_step)
    for _ in range(3):
        logits, cache = step(params, tok, cache)
        assert logits.shape == (B, 1, cfg.vocab_pad)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]


@pytest.mark.parametrize("arch", ["gemma3-1b", "recurrentgemma-2b", "xlstm-350m"])
def test_decode_matches_forward(arch):
    """Greedy decode logits must match teacher-forced forward logits —
    cache correctness for attention, ring-buffer, RG-LRU and xLSTM state."""
    cfg = smoke_config(arch)
    model = Model(cfg, remat=False)
    params = model.init(jax.random.key(1))
    B, S = 1, 12
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))
    full_logits, _ = jax.jit(model.forward)(params, {"tokens": toks})
    n_prefill = 6
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, 32))(
        params, {"tokens": toks[:, :n_prefill]})
    np.testing.assert_allclose(
        np.asarray(logits[:, 0], np.float32),
        np.asarray(full_logits[:, n_prefill - 1], np.float32),
        rtol=2e-2, atol=2e-2)
    step = jax.jit(model.decode_step)
    for t in range(n_prefill, S):
        logits, cache = step(params, toks[:, t : t + 1], cache)
        if t + 1 < S:
            np.testing.assert_allclose(
                np.asarray(logits[:, 0], np.float32),
                np.asarray(full_logits[:, t], np.float32),
                rtol=3e-2, atol=3e-2)
