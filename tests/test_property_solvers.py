"""Property-based correctness harness for every SpTRSV strategy.

Randomized (seeded) sweep over the matrix generator suite asserting each
strategy × {rewrite on/off} × {f32, f64} matches a NumPy forward-substitution
oracle, plus the rewrite invariant ``L' x = E b ⟺ L x = b`` checked directly
on the transformed system (no executor in the loop).

Uses the hypothesis-or-fallback harness in ``_hypothesis_compat`` so the
sweep runs (deterministically) even where hypothesis isn't installed.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.compat import enable_x64
from repro.core import RewriteConfig, SpTRSV, rewrite_matrix
from repro.sparse import banded_lower, chain_matrix, lung2_like, random_lower


def np_fsolve(L, b):
    """Forward-substitution oracle (host numpy, float64).

    Handles b of shape (n,) or (n, m)."""
    x = np.zeros(b.shape, dtype=np.float64)
    for i in range(L.n):
        c, v = L.row(i)
        deps = v[:-1][:, None] * x[c[:-1]] if b.ndim == 2 else v[:-1] * x[c[:-1]]
        x[i] = (b[i] - deps.sum(axis=0)) / v[-1]
    return x


def _make_matrix(kind: str, n: int, seed: int, dtype=np.float64):
    if kind == "random":
        return random_lower(n, avg_offdiag=3.0, seed=seed, dtype=dtype)
    if kind == "banded":
        return banded_lower(n, bandwidth=5, fill=0.5, seed=seed, dtype=dtype)
    if kind == "chain":
        return chain_matrix(n, dtype=dtype)
    if kind == "lung2":
        # lung2_like sizes itself from its level-structure params; map n
        # loosely onto the thin-run length so the sweep varies structure.
        return lung2_like(scale=0.02, fat_levels=3,
                          thin_run=3 + n % 6, seed=seed, dtype=dtype)
    raise ValueError(kind)


@st.composite
def matrix_spec(draw):
    kind = draw(st.sampled_from(["random", "banded", "chain", "lung2"]))
    n = draw(st.integers(20, 120))
    seed = draw(st.integers(0, 2**31 - 1))
    return kind, n, seed


LOCAL_STRATEGIES = ["serial", "levelset", "levelset_unroll",
                    "pallas_level", "pallas_fused"]


@given(matrix_spec())
@settings(max_examples=6, deadline=None)
def test_all_strategies_match_oracle_f32(spec):
    kind, n, seed = spec
    L = _make_matrix(kind, n, seed, dtype=np.float32)
    rng = np.random.default_rng(seed ^ 0x5EED)
    b = rng.normal(size=L.n).astype(np.float32)
    x_ref = np_fsolve(L.astype(np.float64), b.astype(np.float64))
    for strategy in LOCAL_STRATEGIES:
        for rewrite in (None, RewriteConfig(thin_threshold=3)):
            s = SpTRSV.build(L, strategy=strategy, rewrite=rewrite)
            x = np.asarray(s.solve(jnp.asarray(b)))
            np.testing.assert_allclose(
                x, x_ref, rtol=2e-3, atol=2e-4,
                err_msg=f"{kind} n={n} seed={seed} {strategy} "
                        f"rewrite={rewrite is not None}")


@given(matrix_spec())
@settings(max_examples=4, deadline=None)
def test_all_strategies_match_oracle_f64(spec):
    kind, n, seed = spec
    with enable_x64():
        L = _make_matrix(kind, n, seed, dtype=np.float64)
        rng = np.random.default_rng(seed ^ 0xF64)
        b = rng.normal(size=L.n)
        x_ref = np_fsolve(L, b)
        for strategy in LOCAL_STRATEGIES:
            for rewrite in (None, RewriteConfig(thin_threshold=3)):
                s = SpTRSV.build(L, strategy=strategy, rewrite=rewrite)
                x = np.asarray(s.solve(jnp.asarray(b, dtype=jnp.float64)))
                assert x.dtype == np.float64
                np.testing.assert_allclose(
                    x, x_ref, rtol=1e-10, atol=1e-11,
                    err_msg=f"{kind} n={n} seed={seed} {strategy} "
                            f"rewrite={rewrite is not None}")


@given(matrix_spec(), st.integers(1, 6))
@settings(max_examples=8, deadline=None)
def test_rewrite_invariant_direct(spec, thin_threshold):
    """L' x = E b has the same solution as L x = b — checked with the numpy
    oracle on both systems, independent of any executor."""
    kind, n, seed = spec
    L = _make_matrix(kind, n, seed, dtype=np.float64)
    res = rewrite_matrix(L, config=RewriteConfig(thin_threshold=thin_threshold))
    rng = np.random.default_rng(seed ^ 0xE)
    b = rng.normal(size=L.n)
    x_orig = np_fsolve(L, b)
    b_prime = res.E.to_dense() @ b
    x_rewritten = np_fsolve(res.L, b_prime)
    np.testing.assert_allclose(x_rewritten, x_orig, rtol=1e-9, atol=1e-10)
    # and the rewrite must not have grown past its fill budget
    assert res.L.nnz <= 2.0 * L.nnz + L.n


@given(matrix_spec(), st.integers(2, 7))
@settings(max_examples=4, deadline=None)
def test_oracle_batched_consistency(spec, m):
    """The multi-RHS oracle itself: columns of np_fsolve(L, B) are the
    single-RHS solves (guards the harness the batched tests lean on)."""
    kind, n, seed = spec
    L = _make_matrix(kind, n, seed, dtype=np.float64)
    rng = np.random.default_rng(seed)
    B = rng.normal(size=(L.n, m))
    X = np_fsolve(L, B)
    for j in range(m):
        np.testing.assert_allclose(X[:, j], np_fsolve(L, B[:, j]),
                                   rtol=1e-12, atol=1e-12)
