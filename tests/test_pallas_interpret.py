"""Pallas interpret-mode coverage for the TPU-gated solver paths.

The `pallas_level` / `pallas_fused` strategies are the TPU production path,
but CI has no TPU — without interpret-mode runs they would be
test-invisible.  This module drives the *strategy-level* kernel paths
(single + batched RHS, coarsened chains' ``fori_loop``-of-kernel-calls,
permuted packed variants with refresh, x64) explicitly under
``interpret=True`` and skips cleanly where a JAX build does not support
interpreting a construct, instead of failing the suite.

(The per-kernel shape sweeps live in ``test_kernels.py``; this file covers
the composition layers above them, which is where interpret-mode breakages
have actually appeared — e.g. the mixed int32/int64 ``pl.store`` index
under ``jax_enable_x64`` that this suite pinned down.)
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.compat import enable_x64
from repro.core import RewriteConfig, SpTRSV
from repro.core.csr import CSRMatrix
from repro.sparse import lung2_like, pathological

PALLAS_STRATEGIES = ["pallas_level", "pallas_fused"]


def _lung2():
    return lung2_like(scale=0.03, fat_levels=4, thin_run=6, dtype=np.float32)


def _interpret_build(L, **kw):
    """Build with interpret=True, skipping (not failing) when this JAX
    build cannot interpret the construct on CPU."""
    try:
        return SpTRSV.build(L, interpret=True, **kw)
    except (NotImplementedError, jnp.linalg.LinAlgError) as err:  # pragma: no cover
        pytest.skip(f"pallas interpret mode unsupported here: {err}")


def _solve(s, b):
    try:
        return np.asarray(s.solve(jnp.asarray(b)))
    except NotImplementedError as err:  # pragma: no cover
        pytest.skip(f"pallas interpret mode unsupported here: {err}")


@pytest.mark.parametrize("strategy", PALLAS_STRATEGIES)
@pytest.mark.parametrize("layout", ["permuted", "scatter"])
def test_interpret_single_and_batched(strategy, layout):
    L = _lung2()
    rng = np.random.default_rng(0)
    b = rng.standard_normal(L.n).astype(np.float32)
    B = rng.standard_normal((L.n, 4)).astype(np.float32)
    ref = np.asarray(SpTRSV.build(L, strategy="serial").solve(jnp.asarray(b)))
    s = _interpret_build(L, strategy=strategy, layout=layout)
    np.testing.assert_allclose(_solve(s, b), ref, rtol=2e-5, atol=2e-6)
    X = _solve(s, B)
    for j in range(4):
        rj = np.asarray(SpTRSV.build(L, strategy="serial").solve(
            jnp.asarray(B[:, j])))
        np.testing.assert_allclose(X[:, j], rj, rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("strategy", PALLAS_STRATEGIES)
def test_interpret_coarsened_chain_and_rewrite(strategy):
    """Chains execute as a fori_loop whose body launches the kernel — the
    composition most likely to break in interpret mode."""
    L = _lung2()
    rng = np.random.default_rng(1)
    b = rng.standard_normal(L.n).astype(np.float32)
    ref = np.asarray(SpTRSV.build(L, strategy="serial").solve(jnp.asarray(b)))
    coarsen = True if strategy == "pallas_level" else None
    s = _interpret_build(L, strategy=strategy, coarsen=coarsen,
                         rewrite=RewriteConfig(thin_threshold=2))
    np.testing.assert_allclose(_solve(s, b), ref, rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("strategy", PALLAS_STRATEGIES)
def test_interpret_x64(strategy):
    """Regression: x64 mode used to crash the fused kernel's pl.store with
    mixed int32/int64 dynamic-slice indices (found by the differential fuzz
    harness)."""
    L = pathological("arrow", n=72, seed=1)
    rng = np.random.default_rng(2)
    with enable_x64():
        b = rng.standard_normal(L.n)
        B = rng.standard_normal((L.n, 3))
        ref = np.linalg.solve(L.to_dense(), b)
        s = _interpret_build(L, strategy=strategy)
        np.testing.assert_allclose(_solve(s, b), ref, rtol=1e-11, atol=1e-12)
        X = _solve(s, B)
        np.testing.assert_allclose(
            X, np.linalg.solve(L.to_dense(), B), rtol=1e-11, atol=1e-12)


@pytest.mark.parametrize("strategy", PALLAS_STRATEGIES)
def test_interpret_refresh_hits_compiled_kernel(strategy):
    """Value-only refresh must reuse the interpret-compiled executor (same
    jit cache) — the packed pallas variants take runtime value buffers."""
    L = _lung2()
    rng = np.random.default_rng(3)
    b = rng.standard_normal(L.n).astype(np.float32)
    s = _interpret_build(L, strategy=strategy, layout="permuted")
    _solve(s, b)
    data2 = (L.data + 0.1 * rng.standard_normal(L.nnz)).astype(np.float32)
    data2[L.indptr[1:] - 1] += 3.0
    s.refresh(data2)
    fresh = _interpret_build(
        CSRMatrix(L.indptr, L.indices, data2, L.shape), strategy=strategy)
    np.testing.assert_allclose(_solve(s, b), _solve(fresh, b),
                               rtol=2e-6, atol=2e-6)
