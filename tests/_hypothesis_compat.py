"""Property-test harness: real hypothesis when installed, otherwise a
minimal deterministic fallback implementing the subset this suite uses
(``given``, ``settings``, ``st.integers/floats/booleans/sampled_from``,
``st.composite``).

The fallback draws examples from a seeded ``numpy`` Generator, so runs are
reproducible and CI-stable (no shrinking — a failing example prints its
draw seed instead).  Test modules import from here, never from
``hypothesis`` directly.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def example_from(self, rng):
            return self._draw_fn(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: elements[int(rng.integers(0, len(elements)))])

        @staticmethod
        def composite(fn):
            def make(*args, **kwargs):
                def draw_fn(rng):
                    return fn(lambda s: s.example_from(rng), *args, **kwargs)

                return _Strategy(draw_fn)

            return make

    st = _Strategies()

    def settings(*, max_examples: int = 20, deadline=None, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*strategies):
        def deco(fn):
            max_examples = getattr(fn, "_max_examples", 20)

            # NB: no functools.wraps — pytest must see the zero-arg
            # signature, not the wrapped one (whose params look like
            # fixtures).
            def wrapper():
                for example in range(max_examples):
                    rng = np.random.default_rng(0xC0FFEE + 7919 * example)
                    drawn = [s.example_from(rng) for s in strategies]
                    try:
                        fn(*drawn)
                    except Exception:
                        print(f"[property fallback] failing example #{example}: "
                              f"{drawn!r}")
                        raise

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco
