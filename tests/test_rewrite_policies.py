"""Criticality-guided selective rewriting + unified transform planner.

Covers the tentpole surfaces:

* the batched vectorized engine is decision- and pattern-identical to the
  seed dict-loop engine (values to fp tolerance), both triangles;
* ``policy="critical_path"`` targets only (near-)critical chain rows —
  strictly fewer rewrites/fill than ``thin`` when off-critical thin rows
  exist — and cuts the weighted critical path within the default budgets;
* per-row cost/benefit and pivot-skip counts are surfaced in RewriteStats;
* ``pivot_tol`` regression: an exactly-zero (or sub-tolerance) off-level
  pivot is skipped, leaving the row finite and solvable — no NaNs;
* array-form plans replay on new values (and refuse zero pivots);
* ``strategy="auto"`` prices rewrite vs coarsen vs both and records the
  transform on ``solver.plan``; explicit configs stay user directives.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.compat import enable_x64
from repro.core import (
    RewriteConfig,
    RewriteReplayError,
    SpTRSV,
    compute_criticality,
    from_dense,
    replay_rewrite_values,
    rewrite_matrix,
)
from repro.core.csr import CSRMatrix
from repro.core.levels import build_level_sets, build_reverse_level_sets
from repro.sparse import chain_matrix, lung2_like, pathological, random_lower


def np_fsolve(L, b):
    x = np.zeros(L.n)
    for i in range(L.n):
        c, v = L.row(i)
        x[i] = (b[i] - (v[:-1] * x[c[:-1]]).sum()) / v[-1]
    return x


def _lung2():
    return lung2_like(scale=0.05, fat_levels=6, thin_run=10, dtype=np.float64)


def _assert_same_rewrite(ra, rb):
    np.testing.assert_array_equal(ra.L.indptr, rb.L.indptr)
    np.testing.assert_array_equal(ra.L.indices, rb.L.indices)
    np.testing.assert_allclose(ra.L.data, rb.L.data, rtol=1e-12, atol=1e-14)
    np.testing.assert_array_equal(ra.E.indptr, rb.E.indptr)
    np.testing.assert_array_equal(ra.E.indices, rb.E.indices)
    np.testing.assert_allclose(ra.E.data, rb.E.data, rtol=1e-12, atol=1e-14)
    assert ra.stats.rows_rewritten == rb.stats.rows_rewritten
    assert ra.stats.eliminations == rb.stats.eliminations


# -------------------------------------------------------------------------
# engine equivalence
# -------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["thin", "critical_path"])
@pytest.mark.parametrize("mat", ["lung2", "random", "ladder"])
def test_vectorized_matches_loop(policy, mat):
    L = {"lung2": _lung2,
         "random": lambda: random_lower(200, avg_offdiag=3.0, seed=7),
         "ladder": lambda: pathological("singleton_ladder", n=120, seed=2)}[mat]()
    kw = dict(policy=policy, thin_threshold=3)
    rv = rewrite_matrix(L, config=RewriteConfig(engine="vectorized", **kw))
    rl = rewrite_matrix(L, config=RewriteConfig(engine="loop", **kw))
    _assert_same_rewrite(rv, rl)


def test_vectorized_matches_loop_upper():
    L = _lung2()
    U = L.transpose()
    levels = build_reverse_level_sets(L)
    rv = rewrite_matrix(U, levels, RewriteConfig(engine="vectorized"),
                        upper=True)
    rl = rewrite_matrix(U, levels, RewriteConfig(engine="loop"), upper=True)
    _assert_same_rewrite(rv, rl)


def test_engine_auto_uses_loop_for_original_rows():
    L = random_lower(60, seed=3)
    res = rewrite_matrix(
        L, config=RewriteConfig(thin_threshold=2, use_original_rows=True))
    assert res.plan.rounds is None      # loop engine — dict replay path
    with pytest.raises(ValueError, match="use_original_rows"):
        rewrite_matrix(L, config=RewriteConfig(
            use_original_rows=True, engine="vectorized"))


# -------------------------------------------------------------------------
# critical_path policy
# -------------------------------------------------------------------------
def _two_chain_matrix(pool=40, runs=10, depth=6, seed=0):
    """A level-0 pool feeding ``runs`` parallel pairs of chains per level
    band: a HEAVY chain (2 pool deps per row — on the weighted critical
    path) and a LIGHT chain (single dep — same levels, but its through-path
    weight is far below the critical path).  Thin-policy rewriting lifts
    both chains; criticality-guided rewriting must touch only the heavy
    ones."""
    rng = np.random.default_rng(seed)
    r, c, v = [], [], []

    def add(i, j, val):
        r.append(i), c.append(j), v.append(val)

    for p in range(pool):
        add(p, p, 4.0 + rng.random())
    i = pool
    for _ in range(runs):
        prev_a = prev_b = None
        for t in range(depth):
            a = i
            add(a, a, 4.0 + rng.random())
            for j in rng.choice(pool, size=2, replace=False):
                add(a, int(j), rng.normal() * 0.3)
            if prev_a is not None:
                add(a, prev_a, rng.normal() * 0.3)
            prev_a = a
            b = i + 1
            add(b, b, 4.0 + rng.random())
            add(b, prev_b if prev_b is not None
                else int(rng.integers(0, pool)), rng.normal() * 0.3)
            prev_b = b
            i += 2
    from repro.core import from_coo
    return from_coo(r, c, np.asarray(v), (i, i))


def test_critical_path_targets_fewer_rows_same_chain_cut():
    L = _two_chain_matrix()
    # both chains of a level band share a level => width 2*runs
    thin = rewrite_matrix(L, config=RewriteConfig(thin_threshold=20))
    crit = rewrite_matrix(L, config=RewriteConfig(policy="critical_path"))
    # both collapse the weighted critical path...
    assert crit.stats.critical_path_reduction >= 0.25
    assert thin.stats.critical_path_before == crit.stats.critical_path_before
    # ...but the criticality-guided policy touches strictly fewer rows and
    # pays strictly less fill (the off-critical chains stay untouched)
    assert crit.stats.rows_rewritten < thin.stats.rows_rewritten
    assert crit.stats.nnz_after <= thin.stats.nnz_after
    assert crit.stats.policy == "critical_path"
    # within the default fill budget
    assert crit.stats.nnz_after <= 2.0 * crit.stats.nnz_before
    # and still exact
    rng = np.random.default_rng(1)
    b = rng.standard_normal(L.n)
    np.testing.assert_allclose(
        np_fsolve(crit.L, crit.E.matvec(b)), np_fsolve(L, b),
        rtol=1e-9, atol=1e-11)


def test_criticality_membership_matches_definition():
    L = _lung2()
    levels = build_level_sets(L)
    crit = compute_criticality(L, levels)
    # brute-force weighted longest path on the dense DAG
    Ld = L.to_dense()
    w = crit.weights
    cp = np.zeros(L.n, dtype=np.int64)
    for i in range(L.n):
        deps = np.nonzero(Ld[i, :i])[0]
        cp[i] = w[i] + (cp[deps].max() if deps.size else 0)
    np.testing.assert_array_equal(crit.cp_in, cp)
    assert crit.critical_path == cp.max()
    # generic (no-levels) path agrees with the level-based fast path
    crit2 = compute_criticality(L)
    np.testing.assert_array_equal(crit2.cp_in, crit.cp_in)
    np.testing.assert_array_equal(crit2.cp_out, crit.cp_out)


def test_per_row_cost_benefit_surfaced():
    L = _lung2()
    res = rewrite_matrix(L, config=RewriteConfig(policy="critical_path"))
    s = res.stats
    assert s.rewritten_rows is not None and s.rewritten_rows.size == s.rows_rewritten
    assert s.row_fill.shape == s.rewritten_rows.shape
    assert s.row_benefit.shape == s.rewritten_rows.shape
    # fill sums to the global fill; benefit is nonnegative chain shortening
    assert int(s.row_fill.sum()) == s.nnz_after - s.nnz_before
    assert (s.row_benefit >= 0).all()
    assert s.row_benefit.max() > 0
    assert "critical path" in s.summary()


# -------------------------------------------------------------------------
# pivot_tol regression (exactly-zero / sub-tolerance off-level pivots)
# -------------------------------------------------------------------------
def test_zero_pivot_is_skipped_not_nan():
    # row 1 (thin level 1) stores an EXPLICIT zero diagonal (from_coo keeps
    # explicit zeros; from_dense would drop the entry); row 2 depends on it
    Ld = np.array([
        [1.0, 0.0, 0.0, 0.0],
        [0.5, 0.0, 0.0, 0.0],      # zero pivot
        [0.0, 0.7, 2.0, 0.0],
        [0.0, 0.0, 0.3, 3.0],
    ])
    from repro.core import from_coo
    rr, cc = np.nonzero(Ld + np.eye(4))   # include the zero diagonal slot
    L = from_coo(rr, cc, Ld[rr, cc], (4, 4))
    res = rewrite_matrix(L, config=RewriteConfig(thin_threshold=1))
    assert np.isfinite(res.L.data).all() and np.isfinite(res.E.data).all()
    # the elimination of dep 1 was skipped, surfaced in the stats...
    assert res.stats.eliminations_skipped >= 1
    # ...and row 2 still carries its dependency on row 1 (not dropped, not
    # poisoned): the transformed system is algebraically identical
    cols2, vals2 = res.L.row(2)
    assert 1 in cols2.tolist()
    np.testing.assert_allclose(res.E.to_dense() @ Ld, res.L.to_dense(),
                               rtol=1e-12, atol=1e-14)


@pytest.mark.parametrize("engine", ["vectorized", "loop"])
def test_tiny_pivot_under_tolerance_keeps_row_solvable(engine):
    rng = np.random.default_rng(5)
    n = 40
    Ld = np.eye(n) * (3.0 + rng.random(n))
    for i in range(1, n):
        Ld[i, i - 1] = 0.4
    Ld[7, 7] = 1e-12                # sub-tolerance pivot on the chain
    L = from_dense(Ld)
    cfg = RewriteConfig(thin_threshold=1, pivot_tol=1e-8, engine=engine,
                        max_fill_ratio=50.0)
    res = rewrite_matrix(L, config=cfg)
    assert res.stats.eliminations_skipped >= 1
    assert np.isfinite(res.L.data).all() and np.isfinite(res.E.data).all()
    b = rng.standard_normal(n)
    x = np_fsolve(res.L, res.E.matvec(b))
    np.testing.assert_allclose(x, np.linalg.solve(Ld, b), rtol=1e-6, atol=1e-9)


def test_solver_end_to_end_with_pivot_tol():
    L = _lung2()
    data = L.data.copy()
    # shrink one thin-level diagonal below tolerance
    levels = build_level_sets(L)
    thin_rows = np.nonzero((levels.counts <= 2)[levels.level]
                           & (levels.level > 0))[0]
    i = int(thin_rows[3])
    data[L.indptr[i + 1] - 1] = 1e-13
    L2 = CSRMatrix(L.indptr, L.indices, data, L.shape)
    with enable_x64():
        s = SpTRSV.build(L2, strategy="levelset",
                         rewrite=RewriteConfig(thin_threshold=2,
                                               pivot_tol=1e-8))
        assert s.rewrite_result.stats.eliminations_skipped >= 1
        b = np.random.default_rng(2).standard_normal(L.n)
        x = np.asarray(s.solve(jnp.asarray(b)))
        assert np.isfinite(x).all()
        np.testing.assert_allclose(x, np_fsolve(L2, b), rtol=1e-6, atol=1e-8)


# -------------------------------------------------------------------------
# array-form replay
# -------------------------------------------------------------------------
def test_array_plan_replays_and_refuses_zero_pivot():
    L = _lung2()
    res = rewrite_matrix(L, config=RewriteConfig(thin_threshold=2))
    assert res.plan.rounds is not None and len(res.plan.rounds) > 0
    assert res.plan.rows      # legacy summary still populated
    rng = np.random.default_rng(11)
    d2 = L.data + 0.05 * rng.standard_normal(L.nnz)
    d2[L.indptr[1:] - 1] += 2.0
    L2 = CSRMatrix(L.indptr, L.indices, d2, L.shape)
    lp, ed = replay_rewrite_values(L2, res.plan, res.L, res.E)
    fresh = rewrite_matrix(L2, config=RewriteConfig(thin_threshold=2))
    np.testing.assert_allclose(lp, fresh.L.data, rtol=1e-9, atol=1e-11)
    np.testing.assert_allclose(ed, fresh.E.data, rtol=1e-9, atol=1e-11)
    # zero out an eliminated pivot: the plan must refuse, not divide
    piv = int(res.plan.rounds[0].elim_piv[0])
    d3 = d2.copy()
    d3[L.indptr[piv + 1] - 1] = 0.0
    with pytest.raises(RewriteReplayError, match="zero pivot"):
        replay_rewrite_values(CSRMatrix(L.indptr, L.indices, d3, L.shape),
                              res.plan, res.L, res.E)


# -------------------------------------------------------------------------
# transform planner
# -------------------------------------------------------------------------
def test_auto_plans_rewrite_on_lung2():
    L = lung2_like(scale=0.05, fat_levels=6, thin_run=10, dtype=np.float32)
    s = SpTRSV.build(L, strategy="auto")
    assert s.plan.rewrite in ("thin", "critical_path")
    assert s.rewrite_result is not None
    assert s.rewrite_result.stats.policy == s.plan.rewrite
    # both transform families were actually priced
    assert any("+rewrite:" in k for k in s.plan.costs)
    assert any("+coarsen" in k for k in s.plan.costs)
    assert any(("+rewrite:" in k and "+coarsen" in k) for k in s.plan.costs)
    b = np.random.default_rng(0).standard_normal(L.n).astype(np.float32)
    ref = np.asarray(SpTRSV.build(L, strategy="serial").solve(jnp.asarray(b)))
    np.testing.assert_allclose(np.asarray(s.solve(jnp.asarray(b))), ref,
                               rtol=2e-5, atol=2e-6)
    st = s.stats()
    assert st["planned_transform"] == {"rewrite": s.plan.rewrite,
                                       "coarsen": s.plan.coarsen}
    assert st["rewrite_policy"] == s.plan.rewrite


def test_auto_skips_rewrite_candidates_for_chains_and_wavefronts():
    chain = SpTRSV.build(chain_matrix(2000), strategy="auto")
    assert chain.plan.rewrite is None
    assert not any("+rewrite:" in k for k in chain.plan.costs)
    wide = SpTRSV.build(random_lower(300, seed=1), strategy="auto")
    assert wide.plan.rewrite is None


def test_explicit_rewrite_is_a_user_directive():
    L = lung2_like(scale=0.05, fat_levels=6, thin_run=10, dtype=np.float32)
    cfg = RewriteConfig(thin_threshold=2, max_fill_ratio=1.2)
    s = SpTRSV.build(L, strategy="auto", rewrite=cfg)
    # planner did not price alternative policies — it took the directive
    assert s.plan.rewrite is None
    assert not any("+rewrite:" in k for k in s.plan.costs)
    assert s.rewrite_result is not None
    assert s.rewrite_result.stats.policy == "thin"


def test_planner_transform_composes_with_refresh_and_transpose():
    L = lung2_like(scale=0.05, fat_levels=6, thin_run=10, dtype=np.float32)
    rng = np.random.default_rng(4)
    b = rng.standard_normal(L.n).astype(np.float32)
    fwd, bwd = SpTRSV.build_pair(L, strategy="auto")
    assert fwd.plan is not None and bwd.plan is not None
    d2 = (L.data + 0.1 * rng.standard_normal(L.nnz)).astype(np.float32)
    d2[L.indptr[1:] - 1] += 3.0
    fwd.refresh(d2)
    bwd.refresh(d2)
    L2 = CSRMatrix(L.indptr, L.indices, d2, L.shape)
    rf = np.asarray(SpTRSV.build(L2, strategy="serial").solve(jnp.asarray(b)))
    rb = np.asarray(SpTRSV.build(L2, strategy="serial",
                                 transpose=True).solve(jnp.asarray(b)))
    np.testing.assert_allclose(np.asarray(fwd.solve(jnp.asarray(b))), rf,
                               rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(np.asarray(bwd.solve(jnp.asarray(b))), rb,
                               rtol=2e-5, atol=2e-6)


def test_solve_engine_surfaces_transform_stats():
    from repro.serve import SolveEngine

    L = lung2_like(scale=0.04, fat_levels=5, thin_run=8, dtype=np.float32)
    eng = SolveEngine.from_matrix(L)
    st = eng.stats()
    assert st["forward"]["planned_transform"] is not None
    assert st["backward"] is not None
    assert st["queue_depth"] == 0
    b = np.random.default_rng(9).standard_normal(L.n).astype(np.float32)
    req = eng.submit(b)
    eng.run()
    ref = np.asarray(SpTRSV.build(L, strategy="serial").solve(jnp.asarray(b)))
    np.testing.assert_allclose(req.x, ref, rtol=2e-5, atol=2e-6)
    assert eng.stats()["solved"] == 1
