"""The trip-count-aware HLO cost parser must be exact on known programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_parse import parse_module


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_flat_scan_flops_exact():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), ()
        c, _ = jax.lax.scan(body, x, None, length=7)
        return c

    x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    mc = parse_module(_compile(f, x, w).as_text(), 1)
    assert mc.dot_flops == 7 * 2 * 8 * 16 * 16
    assert len(mc.while_info) == 1 and mc.while_info[0][2] == 7


def test_nested_scan_flops_exact():
    def g(x, w):
        def inner(c, _):
            return jnp.tanh(c @ w), ()
        def outer(c, _):
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, ()
        c, _ = jax.lax.scan(outer, x, None, length=3)
        return c

    x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    mc = parse_module(_compile(g, x, w).as_text(), 1)
    assert mc.dot_flops == 15 * 2 * 8 * 16 * 16
    trips = sorted(t for _, _, t in mc.while_info)
    assert trips == [3, 5]


def test_unrolled_matches_scanned():
    w_ = np.random.default_rng(0).normal(size=(16, 16)).astype(np.float32)

    def scanned(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), ()
        return jax.lax.scan(body, x, None, length=6)[0]

    def unrolled(x, w):
        for _ in range(6):
            x = jnp.tanh(x @ w)
        return x

    x = jax.ShapeDtypeStruct((4, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    a = parse_module(_compile(scanned, x, w).as_text(), 1)
    b = parse_module(_compile(unrolled, x, w).as_text(), 1)
    assert a.dot_flops == b.dot_flops


def test_collective_ring_model():
    from repro.launch.mesh import make_mesh
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = make_mesh((8,), ("data",))

    def f(x):
        return jnp.sum(x, axis=0)   # contract the sharded dim -> all-reduce

    x = jax.ShapeDtypeStruct((8, 128), jnp.float32,
                             sharding=NamedSharding(mesh, P("data", None)))
    with mesh:
        comp = jax.jit(f, out_shardings=NamedSharding(mesh, P(None))).lower(x).compile()
    mc = parse_module(comp.as_text(), 8)
    # one all-reduce of a (128,) f32: wire = 2*(7/8)*512 bytes
    assert mc.collective.get("all-reduce", 0) == pytest.approx(2 * 7 / 8 * 512)
