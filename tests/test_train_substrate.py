"""Trainer / checkpoint / optimizer / serving substrate tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_pytree, save_pytree
from repro.configs import smoke_config
from repro.data import SyntheticLM
from repro.models.model import Model
from repro.optim import get_optimizer
from repro.serve.engine import Request, ServeEngine
from repro.train import TrainConfig, Trainer
from repro.train.steps import loss_fn, make_train_step


def _model(arch="gemma3-1b"):
    cfg = smoke_config(arch)
    return Model(cfg, remat=False), cfg


# --------------------------------------------------------------------------
# optimizers
# --------------------------------------------------------------------------
@pytest.mark.parametrize("opt_name", ["adamw", "adafactor", "sgd"])
def test_optimizer_reduces_loss(opt_name):
    model, cfg = _model()
    params = model.init(jax.random.key(0))
    opt = get_optimizer(opt_name, lr=3e-3, total_steps=30)
    state = opt.init(params)
    data = SyntheticLM(cfg.vocab_size, 32, 4, seed=1)
    step = jax.jit(make_train_step(model, opt))
    losses = []
    for i in range(12):
        b = data.batch(i)
        params, state, m = step(params, state,
                                {"tokens": b.tokens, "labels": b.labels})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], (opt_name, losses)
    assert np.isfinite(losses).all()


def test_tripre_optimizer_runs_and_reduces_loss():
    model, cfg = _model("xlstm-350m")
    params = model.init(jax.random.key(0))
    opt = get_optimizer("tripre", lr=1e-3, total_steps=20, band=4,
                        refresh_every=5, max_dim=256)
    state = opt.init(params)
    data = SyntheticLM(cfg.vocab_size, 16, 2, seed=2)
    grads_fn = jax.jit(jax.grad(
        lambda p, b: loss_fn(model, p, b)[0]))
    losses = []
    for i in range(8):
        b = data.batch(i)
        batch = {"tokens": b.tokens, "labels": b.labels}
        g = grads_fn(params, batch)
        params, state = opt.update(g, state, params)
        losses.append(float(loss_fn(model, params, batch)[0]))
    assert np.isfinite(losses).all()
    # integration test: the preconditioned update must stay stable (loss
    # bounded); convergence-rate comparisons live in examples/train_lm.py
    assert losses[-1] < losses[0] * 1.5, losses


def test_microbatch_grad_accum_matches_full_batch():
    model, cfg = _model()
    params = model.init(jax.random.key(0))
    opt = get_optimizer("sgd", lr=1e-2)
    data = SyntheticLM(cfg.vocab_size, 16, 8, seed=3)
    b = data.batch(0)
    batch = {"tokens": b.tokens, "labels": b.labels}
    s1 = jax.jit(make_train_step(model, opt, micro_steps=1))
    s4 = jax.jit(make_train_step(model, opt, micro_steps=4))
    p1, _, _ = s1(params, opt.init(params), batch)
    p4, _, _ = s4(params, opt.init(params), batch)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p4)
    assert max(jax.tree.leaves(d)) < 5e-3


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    save_pytree(tree, str(tmp_path / "ck"))
    got = restore_pytree(jax.tree.map(jnp.zeros_like, tree), str(tmp_path / "ck"))
    assert jnp.allclose(got["a"], tree["a"])
    assert got["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_manager_atomic_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros((3,))}
    for s in (5, 10, 15):
        mgr.save(tree, s)
    assert mgr.steps() == [10, 15]
    # a killed-mid-save tmp dir must be ignored
    os.makedirs(tmp_path / "tmp.99")
    assert mgr.latest_step() == 15
    got, man = mgr.restore({"x": jnp.ones((3,))})
    assert man["step"] == 15
    assert jnp.allclose(got["x"], 0)


def test_checkpoint_mesh_elastic(tmp_path):
    """Save sharded on 8 devices, restore onto a 4-device mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_mesh

    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    m8 = make_mesh((8,), ("data",))
    sharded = jax.device_put(tree, NamedSharding(m8, P("data")))
    save_pytree(sharded, str(tmp_path / "ck"))
    m4 = make_mesh((4, 2), ("data", "model"))
    out = restore_pytree(
        tree, str(tmp_path / "ck"),
        shardings={"w": NamedSharding(m4, P("data", "model"))})
    assert jnp.allclose(out["w"], tree["w"])
    assert len(out["w"].sharding.device_set) == 8


# --------------------------------------------------------------------------
# trainer loop: resume + failure recovery + straggler watchdog
# --------------------------------------------------------------------------
def test_trainer_failure_recovery_and_resume(tmp_path):
    model, cfg = _model("xlstm-350m")
    data = SyntheticLM(cfg.vocab_size, 16, 2, seed=0)
    opt = get_optimizer("adamw", lr=1e-3, total_steps=20)
    fail_at = {7}

    def failure_hook(step):
        if step in fail_at:
            fail_at.discard(step)
            return True
        return False

    tc = TrainConfig(steps=10, ckpt_every=3, ckpt_dir=str(tmp_path),
                     log_every=100, resume="auto")
    tr = Trainer(model, opt, data, tc, failure_hook=failure_hook)
    out = tr.run()
    assert out["final_step"] == 10
    assert out["recoveries"] == 1
    assert np.isfinite(out["history"]).all()
    # fresh trainer resumes from the saved step-10 checkpoint
    tc2 = TrainConfig(steps=12, ckpt_every=100, ckpt_dir=str(tmp_path),
                      log_every=100, resume="auto")
    tr2 = Trainer(model, opt, data, tc2)
    out2 = tr2.run()
    assert out2["final_step"] == 12
    assert len(out2["history"]) == 2  # only steps 10..12 re-run


# --------------------------------------------------------------------------
# serving engine
# --------------------------------------------------------------------------
def test_serve_engine_continuous_batching():
    model, cfg = _model("gemma3-1b")
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, batch_slots=2, s_cache=64)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, (5,)).astype(np.int32),
                    max_new=4) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=200)
    assert all(r.done for r in reqs)
    assert all(len(r.out) >= 4 for r in reqs)
