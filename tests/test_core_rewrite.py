"""Equation-rewriting invariants: the transformation must preserve the
solution exactly, keep L' lower-triangular, never increase level count, and
respect the fill budget."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import RewriteConfig, build_level_sets, rewrite_matrix
from repro.sparse import chain_matrix, lung2_like, random_lower


def np_fsolve(L, b):
    x = np.zeros(L.n)
    for i in range(L.n):
        c, v = L.row(i)
        x[i] = (b[i] - (v[:-1] * x[c[:-1]]).sum()) / v[-1]
    return x


@st.composite
def matrix_and_config(draw):
    n = draw(st.integers(10, 150))
    seed = draw(st.integers(0, 2**31 - 1))
    avg = draw(st.floats(1.0, 5.0))
    thin = draw(st.integers(1, 8))
    orig = draw(st.booleans())
    L = random_lower(n, avg_offdiag=avg, seed=seed)
    cfg = RewriteConfig(thin_threshold=thin, use_original_rows=orig)
    return L, cfg, seed


@given(matrix_and_config())
@settings(max_examples=25, deadline=None)
def test_solution_invariance(args):
    L, cfg, seed = args
    res = rewrite_matrix(L, config=cfg)
    b = np.random.default_rng(seed).normal(size=L.n)
    x0 = np_fsolve(L, b)
    x1 = np_fsolve(res.L, res.E.matvec(b))
    np.testing.assert_allclose(x1, x0, rtol=1e-8, atol=1e-10)


@given(matrix_and_config())
@settings(max_examples=25, deadline=None)
def test_structure_preserved(args):
    L, cfg, _ = args
    res = rewrite_matrix(L, config=cfg)
    assert res.L.is_lower_triangular()
    assert res.E.is_lower_triangular()
    # E is unit lower triangular
    np.testing.assert_allclose(res.E.diagonal(), 1.0)
    # diagonal of L is untouched by eliminations
    np.testing.assert_allclose(res.L.diagonal(), L.diagonal())
    assert res.stats.levels_after <= res.stats.levels_before


@given(matrix_and_config())
@settings(max_examples=15, deadline=None)
def test_fill_budget_respected(args):
    L, cfg, _ = args
    res = rewrite_matrix(L, config=cfg)
    # budget is checked before each elimination, so overshoot is bounded by
    # the size of the single elimination in flight
    assert res.L.nnz <= cfg.max_fill_ratio * L.nnz + 2 * cfg.max_row_nnz


def test_equivalence_as_matrices():
    """L' x = E b must hold simultaneously with L x = b: E L = L' (as
    operators on the solution), i.e. E @ L == L' densely."""
    L = random_lower(60, avg_offdiag=3.0, seed=7)
    res = rewrite_matrix(L, config=RewriteConfig(thin_threshold=4))
    np.testing.assert_allclose(
        res.E.to_dense() @ L.to_dense(), res.L.to_dense(), rtol=1e-9, atol=1e-11
    )


def test_chain_collapses_to_two_levels():
    L = chain_matrix(32)
    res = rewrite_matrix(L, config=RewriteConfig(thin_threshold=1, max_fill_ratio=100.0))
    assert res.levels.num_levels == 2  # level 0 (row 0) + everything else


def test_original_rows_mode_matches_paper_figure2():
    """Paper Fig. 2: row 3 depends on row 1 which depends on row 0; two
    rewritings with ORIGINAL equations lift row 3 to level 1 (dep on row 0
    only via b-updates)."""
    from repro.core import from_dense

    Ld = np.array(
        [
            [1.0, 0, 0, 0],
            [0.5, 2.0, 0, 0],
            [0.0, 0.0, 1.0, 0],
            [0.0, 0.7, 0.0, 3.0],
        ]
    )
    L = from_dense(Ld)
    res = rewrite_matrix(
        L, config=RewriteConfig(thin_threshold=1, use_original_rows=True)
    )
    b = np.array([1.0, 2.0, 3.0, 4.0])
    x0 = np.linalg.solve(Ld, b)
    x1 = np.linalg.solve(res.L.to_dense(), res.E.matvec(b))
    np.testing.assert_allclose(x1, x0, rtol=1e-12)
    # row 3's dependency chain is broken: it no longer depends on row 1
    cols, _ = res.L.row(3)
    assert 1 not in cols.tolist()


def test_lung2_like_rewrite_matches_paper_claims():
    """Paper §V: 478 -> 66 levels (−86% barriers) at +10% FLOPs on lung2.
    The structural twin must land in the same regime: >80% barrier removal
    at <15% FLOP increase."""
    L = lung2_like(scale=0.25)
    res = rewrite_matrix(L, config=RewriteConfig(thin_threshold=2, max_row_nnz=256))
    assert res.stats.level_reduction > 0.80, res.stats.summary()
    assert res.stats.flop_increase < 0.15, res.stats.summary()
