"""Guarded execution layer: residual verification, iterative refinement,
breakdown policies, mixed precision, and the fault-injection matrix.

The fault matrix is the load-bearing part: every
:data:`repro.sparse.faults.VALUE_FAULTS` kind is pushed through
``refresh(..., validate=False)`` into guarded solvers of each strategy
family, and each configured ``on_breakdown`` policy must produce its
*configured* outcome — refine records the breakdown, fallback splices a
finite corrective answer, raise raises :class:`GuardBreakdownError` — not
merely "something happened".
"""
import logging

import numpy as np
import jax.numpy as jnp
import pytest

from repro.compat import enable_x64
from repro.core import (
    GuardBreakdownError,
    GuardConfig,
    SpTRSV,
    repair_pivots,
    scan_values,
)
from repro.sparse import (
    diag_positions,
    inject_values,
    random_lower,
    wrong_pattern,
)

# serial on the permuted layout IS the packed-permuted executor; together
# with levelset / sweep / blocked this covers every executor family the
# acceptance matrix names.
GUARDED_STRATEGIES = ["serial", "levelset", "sweep", "blocked"]


def _mk(n=96, seed=5, m=4):
    L = random_lower(n=n, seed=seed)
    rng = np.random.default_rng(100 + seed)
    return L, rng.standard_normal((n, m))


def _dense_solve(L, B):
    return np.linalg.solve(L.to_dense(), B)


# --------------------------------------------------------------------------
# clean-path behaviour: verification passes, answers match the raw solver
# --------------------------------------------------------------------------
@pytest.mark.parametrize("strategy", GUARDED_STRATEGIES)
@pytest.mark.parametrize("layout", ["permuted", "scatter"])
def test_guard_exactness_on_clean_input(strategy, layout):
    """On a healthy factor the guard is an observer: the guarded answer
    equals the unguarded one bit-for-bit (zero refinement steps taken) and
    the solve verifies."""
    L, B = _mk()
    with enable_x64():
        plain = SpTRSV.build(L, strategy=strategy, layout=layout)
        guarded = SpTRSV.build(L, strategy=strategy, layout=layout, guard=True)
        xp = np.asarray(plain.solve(jnp.asarray(B)))
        xg = np.asarray(guarded.solve(jnp.asarray(B)))
        np.testing.assert_array_equal(xp, xg)
        st = guarded.guard.stats
        assert st.solves == 1 and st.verified == 1
        assert st.last_refine_steps == 0
        assert st.last_residual_ratio <= 128 * np.finfo(np.float64).eps


def test_guard_stats_surface_in_solver_stats():
    L, B = _mk()
    with enable_x64():
        s = SpTRSV.build(L, strategy="levelset", guard=True)
        s.solve(jnp.asarray(B))
        st = s.stats()
        assert st["guard_precision"] == "native"
        assert st["guard_refine_steps"] == 0
        assert st["guard_fallbacks"] == 0
        assert st["guard_pivot_alarms"] == 0
        assert st["guard_residual"] <= 128 * np.finfo(np.float64).eps
        assert st["guard"]["solves"] == 1 and st["guard"]["verified"] == 1
        # unguarded solvers expose the same keys as None (stable dashboards)
        un = SpTRSV.build(L, strategy="levelset").stats()
        assert un["guard"] is None and un["guard_precision"] is None


# --------------------------------------------------------------------------
# fault × policy matrix
# --------------------------------------------------------------------------
@pytest.mark.parametrize("strategy", GUARDED_STRATEGIES)
def test_fault_raise_policy_fires_at_refresh_scan(strategy):
    """Non-finite values and exactly-zero pivots are caught by the O(nnz)
    value scan the moment the faulted values arrive: under
    ``on_breakdown="raise"`` the refresh itself raises (after the swap —
    documented semantics), before any solve runs."""
    L, _ = _mk()
    with enable_x64():
        for kind in ("zero_pivot", "nan_slab", "inf_slab"):
            s = SpTRSV.build(L, strategy=strategy,
                             guard=GuardConfig(on_breakdown="raise"))
            bad = inject_values(L, kind, seed=7)
            with pytest.raises(GuardBreakdownError):
                s.refresh(bad, validate=False)
            assert s.guard.stats.raised == 1


@pytest.mark.parametrize("strategy", GUARDED_STRATEGIES)
def test_fault_raise_policy_fires_at_solve_time(strategy):
    """A subnormal pivot is finite and nonzero, so (at ``pivot_tol=0``) the
    value scan passes — the *residual check* must catch the resulting
    garbage and raise at solve time."""
    L, B = _mk()
    with enable_x64():
        s = SpTRSV.build(L, strategy=strategy,
                         guard=GuardConfig(on_breakdown="raise",
                                           refine_steps=1))
        s.refresh(inject_values(L, "tiny_pivot", seed=7), validate=False)
        with pytest.raises(GuardBreakdownError) as ei:
            s.solve(jnp.asarray(B))
        assert s.guard.stats.raised == 1
        assert ei.value.columns is not None and len(ei.value.columns) > 0


@pytest.mark.parametrize("strategy", GUARDED_STRATEGIES)
def test_fault_fallback_policy_zero_pivot(strategy):
    """Zero pivots + fallback: the scan alarms, the lazily built fallback
    (pivot-repaired) fires, and the answer is finite best-effort — the
    original system is singular, so verification cannot pass, but the
    breakdown is *recorded*, never silent."""
    L, B = _mk()
    with enable_x64():
        s = SpTRSV.build(L, strategy=strategy,
                         guard=GuardConfig(on_breakdown="fallback",
                                           refine_steps=1))
        s.refresh(inject_values(L, "zero_pivot", seed=7), validate=False)
        x = np.asarray(s.solve(jnp.asarray(B)))
        st = s.guard.stats
        assert np.isfinite(x).all()
        assert st.pivot_alarms >= 1
        assert st.fallback_solves == 1 and st.fallback_columns > 0
        assert st.breakdown_columns > 0  # singular original: recorded


@pytest.mark.parametrize("strategy", GUARDED_STRATEGIES)
def test_fault_fallback_policy_nan_slab(strategy):
    """A NaN slab poisons the primary solve; the fallback (NaN values
    zeroed, pivots floored by the repair) must return a finite spliced
    answer with the fallback accounted in stats."""
    L, B = _mk()
    with enable_x64():
        s = SpTRSV.build(L, strategy=strategy,
                         guard=GuardConfig(on_breakdown="fallback",
                                           refine_steps=1))
        s.refresh(inject_values(L, "nan_slab", seed=7), validate=False)
        x = np.asarray(s.solve(jnp.asarray(B)))
        st = s.guard.stats
        assert np.isfinite(x).all()
        assert st.pivot_alarms >= 1 and st.fallback_solves == 1


@pytest.mark.parametrize("strategy", GUARDED_STRATEGIES)
def test_fault_fallback_policy_tiny_pivot_with_pivot_tol(strategy):
    """With ``pivot_tol > 0`` the scan flags sub-tolerance pivots, so the
    fallback is built on *repaired* values (pivots floored) and produces a
    finite answer where the unrepaired factor overflows."""
    L, B = _mk()
    with enable_x64():
        s = SpTRSV.build(L, strategy=strategy,
                         guard=GuardConfig(on_breakdown="fallback",
                                           pivot_tol=1e-10, refine_steps=1))
        s.refresh(inject_values(L, "tiny_pivot", seed=7), validate=False)
        x = np.asarray(s.solve(jnp.asarray(B)))
        st = s.guard.stats
        assert np.isfinite(x).all()
        assert st.pivot_alarms >= 1 and st.fallback_solves == 1


@pytest.mark.parametrize("strategy", GUARDED_STRATEGIES)
def test_fault_refine_policy_is_best_effort(strategy):
    """``on_breakdown="refine"`` never raises and never falls back: a NaN
    slab yields a best-effort answer with the failing columns recorded in
    ``breakdown_columns`` (the healthy columns of the batch still refine —
    one poisoned RHS column must not stop the others)."""
    L, B = _mk()
    with enable_x64():
        s = SpTRSV.build(L, strategy=strategy,
                         guard=GuardConfig(on_breakdown="refine",
                                           refine_steps=1))
        s.refresh(inject_values(L, "nan_slab", seed=7), validate=False)
        s.solve(jnp.asarray(B))  # must not raise
        st = s.guard.stats
        assert st.breakdown_columns > 0
        assert st.fallback_solves == 0 and st.raised == 0


@pytest.mark.parametrize("strategy", ["levelset", "sweep"])
def test_fault_silent_corruption_is_verified_against_current_values(strategy):
    """``perturb_pivot`` and ``denormal_values`` produce *valid* (finite,
    nonzero-pivot) factors — refresh accepts them with ``validate=True``
    and the guard then verifies the solve against the CURRENT system: the
    guarded answer must satisfy the perturbed factor, not the stale one."""
    L, B = _mk()
    with enable_x64():
        for kind in ("perturb_pivot", "denormal_values"):
            s = SpTRSV.build(L, strategy=strategy, guard=True)
            bad = inject_values(L, kind, seed=7)
            s.refresh(bad)          # validate=True: these values are legal
            x = np.asarray(s.solve(jnp.asarray(B)))
            assert s.guard.stats.verified == 1
            L2 = type(L)(L.indptr, L.indices, bad, L.shape)
            np.testing.assert_allclose(x, _dense_solve(L2, B),
                                       rtol=1e-9, atol=1e-9)


# --------------------------------------------------------------------------
# refresh validation (satellite 1)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("layout", ["permuted", "scatter"])
def test_refresh_validation_rejects_broken_values(layout):
    """``refresh`` runs an O(nnz) finiteness + zero-pivot scan by default on
    BOTH layouts; ``validate=False`` admits the same payload (and leaves it
    to a guard, if any)."""
    L, B = _mk()
    with enable_x64():
        for kind in ("zero_pivot", "nan_slab", "inf_slab"):
            s = SpTRSV.build(L, strategy="levelset", layout=layout)
            bad = inject_values(L, kind, seed=7)
            with pytest.raises(ValueError, match="pass validate=False"):
                s.refresh(bad)
            # the rejected refresh must not have touched the live values
            np.testing.assert_allclose(
                np.asarray(s.solve(jnp.asarray(B))), _dense_solve(L, B),
                rtol=1e-10, atol=1e-10)
            s.refresh(bad, validate=False)   # explicitly admitted


@pytest.mark.parametrize("layout", ["permuted", "scatter"])
def test_refresh_validation_accepts_healthy_values(layout):
    L, B = _mk()
    with enable_x64():
        s = SpTRSV.build(L, strategy="levelset", layout=layout)
        good = inject_values(L, "perturb_pivot", seed=7)  # legal values
        s.refresh(good)
        L2 = type(L)(L.indptr, L.indices, good, L.shape)
        np.testing.assert_allclose(
            np.asarray(s.solve(jnp.asarray(B))), _dense_solve(L2, B),
            rtol=1e-9, atol=1e-9)


def test_refresh_rejects_wrong_pattern():
    L, _ = _mk()
    with enable_x64():
        s = SpTRSV.build(L, strategy="levelset", guard=True)
        with pytest.raises(ValueError):
            s.refresh(wrong_pattern(L))


# --------------------------------------------------------------------------
# serving-tier isolation (satellite 2)
# --------------------------------------------------------------------------
def test_engine_isolates_failing_requests():
    """One request whose solve raises (guarded ``on_breakdown="raise"`` with
    a NaN RHS) must not poison its micro-batch: co-batched healthy requests
    still get answers; the culprit carries the exception in ``error``."""
    from repro.serve import SolveEngine

    L, _ = _mk()
    with enable_x64():
        s = SpTRSV.build(L, strategy="levelset",
                         guard=GuardConfig(on_breakdown="raise",
                                           refine_steps=1))
        eng = SolveEngine(s, max_batch=8)
        rng = np.random.default_rng(3)
        good = [eng.submit(rng.standard_normal(L.n)) for _ in range(3)]
        bad_b = rng.standard_normal(L.n)
        bad_b[L.n // 2] = np.nan
        bad = eng.submit(bad_b)
        eng.run()
        for r in good:
            assert r.done and r.error is None
            np.testing.assert_allclose(
                r.x, _dense_solve(L, r.b), rtol=1e-9, atol=1e-9)
        assert bad.done and bad.x is None
        assert isinstance(bad.error, GuardBreakdownError)


def test_engine_refresh_forwards_validate():
    from repro.serve import SolveEngine

    L, _ = _mk()
    with enable_x64():
        s = SpTRSV.build(L, strategy="levelset",
                         guard=GuardConfig(on_breakdown="fallback",
                                           refine_steps=1))
        eng = SolveEngine(s, max_batch=4)
        bad = inject_values(L, "zero_pivot", seed=7)
        with pytest.raises(ValueError, match="pass validate=False"):
            eng.refresh(bad)
        eng.refresh(bad, validate=False)
        r = eng.submit(np.ones(L.n))
        eng.run()
        assert r.done and r.error is None and np.isfinite(r.x).all()
        assert s.guard.stats.fallback_solves >= 1


# --------------------------------------------------------------------------
# mixed precision
# --------------------------------------------------------------------------
def test_mixed_precision_recovers_fp64_accuracy():
    """bf16 value storage + fp32 accumulation + refinement against the fp64
    residual must land within the componentwise residual tolerance of a
    native fp64 solve — the acceptance bar of the guard benchmark."""
    L, B = _mk()
    with enable_x64():
        s = SpTRSV.build(L, strategy="levelset",
                         guard=GuardConfig(precision="mixed",
                                           refine_steps=4))
        x = np.asarray(s.solve(jnp.asarray(B)))
        st = s.guard.stats
        assert st.verified == 1
        assert st.last_residual_ratio <= 128 * np.finfo(np.float64).eps
        assert 1 <= st.last_refine_steps <= 4  # bf16 storage needs refining
        np.testing.assert_allclose(x, _dense_solve(L, B),
                                   rtol=1e-9, atol=1e-9)


def test_mixed_precision_build_pair_both_directions():
    L, B = _mk()
    with enable_x64():
        fwd, bwd = SpTRSV.build_pair(
            L, strategy="levelset",
            guard=GuardConfig(precision="mixed", refine_steps=4))
        y = np.asarray(fwd.solve(jnp.asarray(B)))
        z = np.asarray(bwd.solve(jnp.asarray(y)))
        ref = np.linalg.solve(L.to_dense().T, _dense_solve(L, B))
        np.testing.assert_allclose(z, ref, rtol=1e-8, atol=1e-8)
        assert fwd.guard.stats.verified == 1
        assert bwd.guard.stats.verified == 1


def test_mixed_precision_requires_permuted_runtime_buffers():
    L, _ = _mk()
    with pytest.raises(ValueError, match="mixed"):
        SpTRSV.build(L, strategy="levelset", layout="scatter",
                     guard=GuardConfig(precision="mixed"))


def test_planner_prices_mixed_precision():
    """``plan_strategy(..., precision="mixed")`` discounts every
    gather-bound term by the backend's ``mixed_gather_discount``: gather-
    bound candidates get cheaper, dispatch-bound ones (serial) do not, and
    the decision records the discount."""
    from repro.core.analysis import analyze
    from repro.core.coarsen import plan_strategy
    from repro.core.codegen import build_schedule
    from repro.core.levels import build_level_sets
    from repro.sparse import lung2_like

    L = lung2_like(scale=0.02, fat_levels=4, thin_run=6, dtype=np.float32)
    levels = build_level_sets(L)
    an = analyze(L, levels, upper=False)
    sched = build_schedule(L, levels, upper=False)
    nat = plan_strategy(an, sched, backend="tpu")
    mix = plan_strategy(an, sched, backend="tpu", precision="mixed")
    assert "precision=mixed" in mix.reason and "precision=mixed" not in nat.reason
    assert mix.costs["levelset"] < nat.costs["levelset"]
    assert mix.costs["serial"] == nat.costs["serial"]


# --------------------------------------------------------------------------
# helpers + config validation
# --------------------------------------------------------------------------
def test_scan_values_counts():
    L, _ = _mk()
    dpos = diag_positions(L)
    assert scan_values(L.data, dpos) == (0, 0)
    bad = inject_values(L, "zero_pivot", count=2, seed=7)
    assert scan_values(bad, dpos) == (0, 2)
    nan = inject_values(L, "nan_slab", slab=8, seed=7)
    nonfinite, _ = scan_values(nan, dpos)
    assert nonfinite == 8
    tiny = inject_values(L, "tiny_pivot", count=2, seed=7)
    assert scan_values(tiny, dpos) == (0, 0)          # finite + nonzero
    assert scan_values(tiny, dpos, pivot_tol=1e-10) == (0, 2)


def test_repair_pivots_floors_and_zeroes():
    L, _ = _mk()
    dpos = diag_positions(L)
    bad = inject_values(L, "zero_pivot", count=2, seed=7)
    bad[:4] = np.nan
    rep, n_rep = repair_pivots(bad, dpos)
    assert n_rep >= 2
    assert np.isfinite(rep).all()
    assert (np.abs(rep[dpos]) > 0).all()


def test_guard_config_validation():
    with pytest.raises(AssertionError):
        GuardConfig(on_breakdown="explode")
    with pytest.raises(AssertionError):
        GuardConfig(precision="fp8")
    with pytest.raises(AssertionError):
        GuardConfig(refine_steps=-1)
    with pytest.raises(AssertionError):
        GuardConfig(fallback="pallas_fused")   # not an exact host strategy
    with pytest.raises(AssertionError):
        GuardConfig(pivot_tol=-1e-3)


# --------------------------------------------------------------------------
# guarded preconditioner (tolerance-aware inexact mode)
# --------------------------------------------------------------------------
def test_pcg_with_guarded_preconditioner():
    """The tolerance-aware inexact mode: a guarded preconditioner with a
    loose residual_tol still drives PCG to convergence (flexible-PCG caveat
    covered by stall_window)."""
    from repro.core.pcg import make_ic_preconditioner, pcg
    from repro.sparse import ic0_factor, poisson2d

    with enable_x64():
        A = poisson2d(16, 16)
        Lf = ic0_factor(A)
        M = make_ic_preconditioner(
            Lf, guard=GuardConfig(residual_tol=1e-6, on_breakdown="refine"))
        b = jnp.asarray(np.random.default_rng(0).standard_normal(A.n))
        res = pcg(A, b, M, tol=1e-8, maxiter=400, stall_window=40)
        assert res.converged
