"""Schedule coarsening + auto planner + scheduling-knob regression tests.

Covers the PR-3 tentpole and its bugfixes:

* coarsened schedules produce (near-)bit-identical solutions across
  strategy × rewrite × transpose × batch, checked against the uncoarsened
  executor at a few-ulp tolerance and against the serial oracle;
* the greedy cost model actually removes sync points on lung2-class level
  structure and refuses to pad fat wavefronts onto thin chains at scale;
* ``strategy="auto"`` builds on every matrix kind and records its decision;
* regression: ``bucket_pad_ratio`` reaches every schedule-consuming
  strategy (it was silently dropped for pallas_level / pallas_fused /
  distributed);
* regression: ``Schedule.padded_flops(unroll_threshold)`` counts unrolled
  slabs at their true nnz;
* regression: the distributed solver exchanges solved values only — row
  ids are static host-side constants (no per-level index all_gather) and
  ``collective_bytes`` skips replicated (coarsened) segments.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.compat import enable_x64
from repro.core import SpTRSV, RewriteConfig
from repro.core.codegen import build_schedule, make_levelset_solver
from repro.core.coarsen import (
    CoarsenConfig,
    coarsen_schedule,
    coarsen_stats,
    plan_strategy,
    schedule_cost,
)
from repro.sparse import banded_lower, chain_matrix, lung2_like, random_lower


def _lung2():
    return lung2_like(scale=0.05, fat_levels=6, thin_run=10, dtype=np.float32)


def _oracle(L, b):
    """Host numpy forward-substitution (float64)."""
    Ld = L.to_dense().astype(np.float64)
    x = np.zeros(b.shape, dtype=np.float64)
    for i in range(L.n):
        x[i] = (b[i] - Ld[i, :i] @ x[:i]) / Ld[i, i]
    return x


# -------------------------------------------------------------------------
# coarsening mechanics
# -------------------------------------------------------------------------
def test_coarsen_reduces_segments_and_preserves_depth():
    sched = build_schedule(_lung2())
    co = coarsen_schedule(sched, CoarsenConfig())
    assert co.num_segments * 4 <= sched.num_segments  # >= 4x fewer barriers
    # every original wavefront is still swept exactly once, in order
    assert co.total_depth == sched.num_segments
    assert np.array_equal(
        np.concatenate([s.rows for s in co.slabs]),
        np.concatenate([s.rows for s in sched.slabs]),
    )
    st = coarsen_stats(sched, co)
    assert st.segment_reduction >= 4.0
    assert st.padded_flops_after >= st.padded_flops_before


def test_coarsen_is_idempotent_and_respects_max_depth():
    sched = build_schedule(_lung2())
    cfg = CoarsenConfig(max_depth=4)
    co = coarsen_schedule(sched, cfg)
    assert max(s.depth for s in co.slabs) <= 4
    again = coarsen_schedule(co, cfg)
    assert [s.depth for s in again.slabs] == [s.depth for s in co.slabs]


def test_coarsen_declines_fat_merges_at_scale():
    # full-width fat levels (few thousand rows) must never absorb thin runs:
    # padding every chained sub-step to the fat width dwarfs a saved barrier
    L = lung2_like(scale=0.5, fat_levels=4, thin_run=8, dtype=np.float32)
    co = coarsen_schedule(build_schedule(L), CoarsenConfig())
    for s in co.slabs:
        if s.depth > 1:
            assert max(s.sub_rows) <= 64, s.sub_rows  # chains stay thin
    # the 4 fat wavefronts survive as their own segments
    fat = [s for s in co.slabs if s.depth == 1 and s.R > 1000]
    assert len(fat) == 4


def test_schedule_cost_prefers_coarsened_on_thin_schedules():
    sched = build_schedule(_lung2())
    co = coarsen_schedule(sched, CoarsenConfig())
    assert schedule_cost(co) < schedule_cost(sched)


# -------------------------------------------------------------------------
# numerical equivalence: strategy × rewrite × transpose × batch
# -------------------------------------------------------------------------
COARSEN_STRATEGIES = ["levelset", "levelset_unroll", "pallas_level"]


@pytest.mark.parametrize("strategy", COARSEN_STRATEGIES)
@pytest.mark.parametrize("transpose", [False, True])
def test_coarsened_matches_uncoarsened_and_oracle(strategy, transpose):
    L64 = lung2_like(scale=0.05, fat_levels=6, thin_run=10)
    rng = np.random.default_rng(0)
    with enable_x64():
        for rewrite in (None, RewriteConfig(thin_threshold=2)):
            base = SpTRSV.build(L64, strategy=strategy, transpose=transpose,
                                rewrite=rewrite)
            co = SpTRSV.build(L64, strategy=strategy, transpose=transpose,
                              rewrite=rewrite, coarsen=True)
            # rewriting may already have emptied every mergeable thin level,
            # so only the unrewritten schedule must strictly shrink
            assert co.schedule.num_segments <= base.schedule.num_segments
            if rewrite is None:
                assert co.schedule.num_segments < base.schedule.num_segments
            for shape in ((L64.n,), (L64.n, 4)):
                b = rng.standard_normal(shape)
                xb = np.asarray(base.solve(jnp.asarray(b)))
                xc = np.asarray(co.solve(jnp.asarray(b)))
                # identical operand sets; XLA may re-contract the padded
                # reduction, so allow a few f64 ulp
                np.testing.assert_allclose(
                    xc, xb, rtol=1e-13, atol=1e-15,
                    err_msg=f"{strategy} transpose={transpose} "
                            f"rewrite={rewrite is not None} shape={shape}")
                if rewrite is None and not transpose and b.ndim == 1:
                    np.testing.assert_allclose(
                        xc, _oracle(L64, b), rtol=1e-9, atol=1e-11)


def test_coarsened_distributed_matches_serial():
    L = _lung2()
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:4]), ("data",))
    b = np.random.default_rng(3).standard_normal(L.n).astype(np.float32)
    ref = np.asarray(SpTRSV.build(L, strategy="serial").solve(jnp.asarray(b)))
    for dist_strategy in ("all_gather", "psum"):
        s = SpTRSV.build(L, strategy="distributed", mesh=mesh, coarsen=True,
                         dist_strategy=dist_strategy)
        x = np.asarray(s.solve(jnp.asarray(b)))
        np.testing.assert_allclose(x, ref, rtol=2e-5, atol=2e-6)
        B = np.random.default_rng(4).standard_normal((L.n, 3)).astype(np.float32)
        X = np.asarray(s.solve(jnp.asarray(B)))
        for j in range(3):
            rj = np.asarray(SpTRSV.build(L, strategy="serial").solve(
                jnp.asarray(B[:, j])))
            np.testing.assert_allclose(X[:, j], rj, rtol=2e-5, atol=2e-6)


# -------------------------------------------------------------------------
# auto planner
# -------------------------------------------------------------------------
AUTO_MATRICES = [
    ("chain", lambda: chain_matrix(400)),
    ("random", lambda: random_lower(300, seed=1)),
    ("banded", lambda: banded_lower(256, bandwidth=8)),
    ("lung2", lambda: lung2_like(scale=0.05, fat_levels=6, thin_run=10)),
]


@pytest.mark.parametrize("kind,mk", AUTO_MATRICES)
def test_auto_builds_and_solves_everywhere(kind, mk):
    L = mk()
    rng = np.random.default_rng(7)
    with enable_x64():
        for transpose in (False, True):
            for rewrite in (None, RewriteConfig(thin_threshold=2)):
                s = SpTRSV.build(L, strategy="auto", transpose=transpose,
                                 rewrite=rewrite)
                assert s.plan is not None and s.strategy in (
                    "serial", "levelset", "levelset_unroll", "pallas_fused",
                    "sweep")
                assert s.strategy in s.plan.reason or s.plan.costs
                b = rng.standard_normal(L.n)
                x = np.asarray(s.solve(jnp.asarray(b)))
                ref = np.asarray(SpTRSV.build(
                    L, strategy="serial", transpose=transpose,
                    rewrite=rewrite).solve(jnp.asarray(b)))
                np.testing.assert_allclose(x, ref, rtol=1e-6, atol=1e-9)


def test_auto_picks_serial_for_chains_and_parallel_for_wavefronts():
    with enable_x64():
        # a pure chain is the worst case for level-set executors: the
        # planner must pick a barrier-free strategy — the certified sweep
        # when its convergence certificate holds, else the serial scan
        chain = SpTRSV.build(chain_matrix(2000), strategy="auto")
        assert chain.strategy in ("serial", "sweep"), chain.plan.reason
        # with sweeps opted out the original ordering claim still holds
        chain_ns = SpTRSV.build(chain_matrix(2000), strategy="auto",
                                sweep=False)
        assert chain_ns.strategy == "serial", chain_ns.plan.reason
        # wide wavefronts at a size where the serial scan's cache behavior
        # makes it clearly lose (measured ~5us/row at 33k rows vs ~60ns at
        # 1.5k — small systems legitimately go serial)
        wide = SpTRSV.build(random_lower(4000, avg_offdiag=3.0, seed=0),
                            strategy="auto")
        assert wide.strategy in ("levelset", "levelset_unroll"), wide.plan.reason


def test_auto_never_picks_pallas_on_cpu():
    # interpret-mode Pallas is a correctness harness, not an executor choice
    s = SpTRSV.build(_lung2(), strategy="auto")
    assert s.strategy != "pallas_fused"
    assert "pallas_fused" not in s.plan.costs  # gated, not just outscored


def test_auto_respects_coarsen_opt_out():
    s = SpTRSV.build(_lung2(), strategy="auto", coarsen=False)
    assert s.plan.coarsen is False
    if s.schedule is not None:
        assert all(sl.depth == 1 for sl in s.schedule.slabs)


def test_plan_strategy_gates_fused_on_backend_and_interpret():
    L = _lung2()
    sched = build_schedule(L)
    from repro.core import analyze
    an = analyze(L)
    d_cpu = plan_strategy(an, sched, backend="cpu", interpret=False)
    assert "pallas_fused" not in d_cpu.costs
    # interpret mode models nothing the cost formula describes — gated even
    # on a TPU backend
    d_interp = plan_strategy(an, sched, backend="tpu", interpret=True)
    assert "pallas_fused" not in d_interp.costs
    d_tpu = plan_strategy(an, sched, backend="tpu", interpret=False)
    assert "pallas_fused" in d_tpu.costs


# -------------------------------------------------------------------------
# regression: bucket_pad_ratio reaches every schedule-consuming strategy
# -------------------------------------------------------------------------
def _bucket_matrix():
    # one wavefront with wildly uneven row widths => bucketing must split it
    n = 160
    rows, cols, vals = list(range(n)), list(range(n)), [4.0] * n
    rng = np.random.default_rng(0)
    for i in range(64, n):  # fat rows depend on many roots
        for j in rng.choice(48, size=24, replace=False):
            rows.append(i); cols.append(int(j)); vals.append(0.1)
    for i in range(48, 64):  # thin rows depend on one root
        rows.append(i); cols.append(i - 48); vals.append(0.1)
    from repro.core import from_coo
    return from_coo(rows, cols, np.asarray(vals, np.float32), (n, n))


@pytest.mark.parametrize(
    "strategy", ["levelset", "levelset_unroll", "pallas_level",
                 "pallas_fused", "distributed"])
def test_bucket_pad_ratio_reaches_every_strategy(strategy):
    L = _bucket_matrix()
    kw = {}
    if strategy == "distributed":
        kw["mesh"] = jax.sharding.Mesh(np.array(jax.devices()[:2]), ("data",))
    plain = SpTRSV.build(L, strategy=strategy, **kw)
    split = SpTRSV.build(L, strategy=strategy, bucket_pad_ratio=1.5, **kw)
    # the bucketed slab split must show up in the schedule of EVERY strategy
    # (it used to be silently dropped for pallas_level/pallas_fused/distributed)
    assert split.schedule.num_segments > plain.schedule.num_segments
    assert split.schedule.padded_flops() < plain.schedule.padded_flops()
    b = np.random.default_rng(1).standard_normal(L.n).astype(np.float32)
    x = np.asarray(split.solve(jnp.asarray(b)))
    ref = np.asarray(SpTRSV.build(L, strategy="serial").solve(jnp.asarray(b)))
    np.testing.assert_allclose(x, ref, rtol=2e-5, atol=2e-6)


# -------------------------------------------------------------------------
# regression: padded_flops honors the unroll threshold
# -------------------------------------------------------------------------
def test_padded_flops_counts_unrolled_slabs_at_true_nnz():
    L = _lung2()
    sched = build_schedule(L)
    base = sched.padded_flops()
    unrolled = sched.padded_flops(unroll_threshold=2)
    assert unrolled < base
    # hand-count: thin (R<=2) slabs contribute 2*nnz + R, others 2*K*R + R
    expect = 0
    for s in sched.slabs:
        if s.R <= 2:
            expect += 2 * int(np.count_nonzero(s.vals)) + s.R
        else:
            expect += 2 * s.K * s.R + s.R
    assert unrolled == expect
    # coarsened chains execute depth uniform sub-steps — counted as such
    co = coarsen_schedule(sched, CoarsenConfig())
    expect_co = 0
    for s in co.slabs:
        if s.depth > 1:
            rmax = max(s.sub_rows)
            expect_co += s.depth * (2 * s.K * rmax + rmax)
        else:
            expect_co += 2 * s.K * s.R + s.R
    assert co.padded_flops() == expect_co


# -------------------------------------------------------------------------
# regression: distributed exchanges values only; bytes match the wire
# -------------------------------------------------------------------------
def test_distributed_no_index_collectives():
    from repro.core.dist import make_distributed_solver, shard_schedule

    L = _lung2()
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:4]), ("data",))
    sched = build_schedule(L)
    dsched = shard_schedule(sched, 4)
    fn = make_distributed_solver(dsched, mesh, "data")
    jaxpr = str(jax.make_jaxpr(fn)(jnp.zeros((L.n,), jnp.float32)))
    # one value all_gather per sharded segment — and none for row ids
    # (primitive applications print as "all_gather[..."; the bare substring
    # also matches the all_gather_dimension= param, so anchor on the bracket)
    assert jaxpr.count("all_gather[") == dsched.num_collectives == sched.num_segments
    # with coarsening, replicated chains drop their collectives entirely
    co = coarsen_schedule(sched, CoarsenConfig())
    d_co = shard_schedule(co, 4)
    fn_co = make_distributed_solver(d_co, mesh, "data")
    jaxpr_co = str(jax.make_jaxpr(fn_co)(jnp.zeros((L.n,), jnp.float32)))
    assert jaxpr_co.count("all_gather[") == d_co.num_collectives < dsched.num_collectives


def test_collective_accounting_with_coarsening():
    from repro.core.dist import shard_schedule

    L = _lung2()
    sched = build_schedule(L)
    co = coarsen_schedule(sched, CoarsenConfig())
    d_plain = shard_schedule(sched, 4)
    d_co = shard_schedule(co, 4)
    assert d_plain.num_collectives == sched.num_segments
    assert d_co.num_collectives == sum(
        1 for s in co.slabs if s.depth == 1)
    # replicated chains move zero bytes; sharded segments count value payload
    expect = sum(r.size * 4 for r, rep in zip(d_co.rows, d_co.replicated)
                 if not rep)
    assert d_co.collective_bytes() == expect
    assert d_co.collective_bytes() <= d_plain.collective_bytes()
    assert d_co.collective_bytes(batch=8) == 8 * d_co.collective_bytes()


# -------------------------------------------------------------------------
# serve-engine plumbing
# -------------------------------------------------------------------------
def test_solve_engine_from_matrix_auto():
    from repro.serve.engine import SolveEngine

    L = _lung2()
    eng = SolveEngine.from_matrix(L)
    assert eng.solver.plan is not None and eng.solver_t is not None
    b = np.random.default_rng(5).standard_normal(L.n).astype(np.float32)
    r_f = eng.submit(b)
    r_b = eng.submit(b, transpose=True)
    eng.run()
    ref_f = np.asarray(SpTRSV.build(L, strategy="serial").solve(jnp.asarray(b)))
    ref_b = np.asarray(SpTRSV.build(L, strategy="serial",
                                    transpose=True).solve(jnp.asarray(b)))
    np.testing.assert_allclose(r_f.x, ref_f, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(r_b.x, ref_b, rtol=2e-5, atol=2e-6)
