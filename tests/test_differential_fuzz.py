"""Differential fuzz harness: pathological triangular patterns through every
strategy × rewrite-policy × layout × transpose × batch combination against a
NumPy (dense ``np.linalg.solve``) oracle at few-ulp tolerance.

Two tiers:

* the default (tier-1) run sweeps a deterministic rotating slice of the
  combination grid per pattern — every grid dimension is exercised on every
  CI run, in bounded time;
* ``pytest -m fuzz`` (the nightly job) runs the exhaustive grid — including
  the distributed strategy — over ``FUZZ_SEEDS`` seeds per pattern
  (default 3; the nightly sets a larger budget).

Any failing configuration dumps the matrix + combination to an ``.npz``
repro file (``FUZZ_REPRO_DIR``, default ``tests/_fuzz_repro``) and names the
file in the assertion message, so a nightly failure is replayable without
re-deriving the random state.

Tolerances: solutions are compared in float64.  For well-conditioned
patterns the bound is a few ulp (scaled by the oracle's magnitude); the
``RESIDUAL_PATTERNS`` (``near_singular``'s ~9-decade diagonal spread,
``extreme_scale``'s fp32-edge magnitudes, ``denormal_pivot``'s fp32-subnormal
pivots) make forward error against an oracle the wrong criterion — they
assert the componentwise residual bound
``|L x - b| <= tol * (|L| |x| + |b|)`` instead (the backward stability test
substitution actually satisfies).
"""
import itertools
import json
import os
import pathlib

import numpy as np
import jax.numpy as jnp
import pytest

from repro.compat import enable_x64
from repro.core import GuardConfig, RewriteConfig, SpTRSV
from repro.sparse import PATHOLOGICAL_PATTERNS, pathological

STRATEGIES = ["serial", "levelset", "levelset_unroll",
              "pallas_level", "pallas_fused", "sweep", "blocked"]
POLICIES = {
    "none": None,
    "thin": RewriteConfig(thin_threshold=2),
    "critical_path": RewriteConfig(policy="critical_path"),
}
LAYOUTS = ["permuted", "scatter"]
PATTERNS = sorted(PATHOLOGICAL_PATTERNS)
# patterns whose conditioning makes forward error against the oracle
# meaningless — checked with the componentwise residual criterion instead
RESIDUAL_PATTERNS = {"near_singular", "extreme_scale", "denormal_pivot"}

# (strategy, policy, layout, transpose, batch) — the full local grid
GRID = list(itertools.product(STRATEGIES, sorted(POLICIES), LAYOUTS,
                              [False, True], [0, 3]))
# tier-1 rotating slice: stride through the grid with a per-pattern phase so
# every dimension value appears every run, but each pattern only builds ~7
# solver variants (full grid x all patterns is the nightly's job)
_STRIDE = 17

# GPU (pallas-triton) lowering grid: the pallas strategies re-run under the
# interpret:gpu backend — same layouts/transpose/batch dimensions, so the
# triton-style kernels get the identical oracle treatment without hardware
GPU_STRATEGIES = ["pallas_level", "pallas_fused"]
GPU_GRID = list(itertools.product(GPU_STRATEGIES, sorted(POLICIES), LAYOUTS,
                                  [False, True], [0, 3]))
_GPU_STRIDE = 7


def _combos_for(pattern: str, exhaustive: bool):
    if exhaustive:
        return GRID
    phase = PATTERNS.index(pattern)
    return GRID[phase::_STRIDE]


def _gpu_combos_for(pattern: str, exhaustive: bool):
    if exhaustive:
        return GPU_GRID
    phase = PATTERNS.index(pattern)
    return GPU_GRID[phase::_GPU_STRIDE]


def _oracle(L, b, transpose):
    A = L.to_dense()
    return np.linalg.solve(A.T if transpose else A, b)


def _dump_repro(L, pattern, seed, combo, err_msg):
    out_dir = pathlib.Path(os.environ.get(
        "FUZZ_REPRO_DIR", pathlib.Path(__file__).parent / "_fuzz_repro"))
    out_dir.mkdir(parents=True, exist_ok=True)
    strategy, policy, layout, transpose, batch = combo
    name = f"{pattern}_s{seed}_{strategy}_{policy}_{layout}" \
           f"_t{int(transpose)}_b{batch}.npz"
    path = out_dir / name
    np.savez(path, indptr=L.indptr, indices=L.indices, data=L.data,
             shape=np.asarray(L.shape),
             combo=json.dumps({"pattern": pattern, "seed": seed,
                               "strategy": strategy, "policy": policy,
                               "layout": layout, "transpose": transpose,
                               "batch": batch, "error": err_msg}))
    return path


def _check(L, pattern, x, b, x_ref, transpose, combo, seed):
    x = np.asarray(x)
    assert x.shape == x_ref.shape
    try:
        assert np.isfinite(x).all(), "non-finite entries in solution"
        if pattern in RESIDUAL_PATTERNS:
            # componentwise backward-error bound: |A x - b| <= tol (|A||x| + |b|)
            A = L.to_dense()
            if transpose:
                A = A.T
            resid = np.abs(A @ x - b)
            bound = np.abs(A) @ np.abs(x) + np.abs(b)
            tol = 256 * L.n * np.finfo(np.float64).eps
            worst = (resid / np.maximum(bound, 1e-300)).max()
            assert worst <= tol, f"residual {worst:.2e} > {tol:.2e}"
        else:
            scale = max(np.abs(x_ref).max(), 1.0)
            np.testing.assert_allclose(x, x_ref, rtol=5e-12,
                                       atol=5e-12 * scale)
    except AssertionError as err:
        path = _dump_repro(L, pattern, seed, combo, str(err))
        raise AssertionError(
            f"differential mismatch for {combo} on {pattern}(seed={seed}) "
            f"— repro dumped to {path}\n{err}") from None


def _run_combo(L, pattern, seed, combo, mesh=None, backend=None, guard=None):
    strategy, policy, layout, transpose, batch = combo
    kw = dict(strategy=strategy, layout=layout, transpose=transpose,
              rewrite=POLICIES[policy])
    if strategy == "distributed":
        kw["mesh"] = mesh
    if backend is not None:
        kw["backend"] = backend
    if guard is not None:
        kw["guard"] = guard
    s = SpTRSV.build(L, **kw)
    rng = np.random.default_rng(10_000 + seed)
    if batch:
        b = rng.standard_normal((L.n, batch))
    else:
        b = rng.standard_normal(L.n)
    x = s.solve(jnp.asarray(b))
    _check(L, pattern, x, b, _oracle(L, b, transpose), transpose, combo, seed)


@pytest.mark.parametrize("pattern", PATTERNS)
def test_differential_slice(pattern):
    """Tier-1: rotating slice of the grid on one seed per pattern."""
    L = pathological(pattern, n=72, seed=1)
    with enable_x64():
        for combo in _combos_for(pattern, exhaustive=False):
            _run_combo(L, pattern, 1, combo)


@pytest.mark.parametrize("pattern", PATTERNS)
def test_differential_gpu_backend_slice(pattern):
    """Tier-1: the pallas-triton (GPU) lowerings, executed under the
    interpret backend (``backend="interpret:gpu"``), on a rotating slice of
    the strategy × policy × layout × transpose × batch grid — the same
    oracle and tolerances as the TPU-lowering slice."""
    L = pathological(pattern, n=72, seed=1)
    with enable_x64():
        for combo in _gpu_combos_for(pattern, exhaustive=False):
            _run_combo(L, pattern, 1, combo, backend="interpret:gpu")


# --------------------------------------------------------------------------
# blocked executor: the full blocked × transpose × batch × layout sub-grid
# runs in tier-1 (the rotating slice above only samples it) — supernodal
# schedules have enough moving parts (panel gathers, padded dense blocks,
# block-level DAG) that every pattern gets the complete 8-combo slice,
# including ``jagged_rows`` where amalgamation finds nothing and the
# executor must degrade to all-1×1 blocks.
# --------------------------------------------------------------------------
BLOCKED_GRID = list(itertools.product(["blocked"], ["none"], LAYOUTS,
                                      [False, True], [0, 3]))


@pytest.mark.parametrize("pattern", PATTERNS)
def test_differential_blocked_slice(pattern):
    """Tier-1: blocked strategy over the full layout × transpose × batch
    sub-grid, one seed per pattern."""
    L = pathological(pattern, n=72, seed=1)
    with enable_x64():
        for combo in BLOCKED_GRID:
            _run_combo(L, pattern, 1, combo)


# --------------------------------------------------------------------------
# sweep executor: pathological convergence — the fallback must actually fire
# --------------------------------------------------------------------------
@pytest.mark.parametrize("pattern", ["near_singular", "dense_last_row"])
def test_sweep_fallback_fires_on_pathological(pattern):
    """Patterns the Jacobi sweep iteration cannot certify (a ~9-decade
    diagonal spread / a dense final row accumulating the whole vector):
    k=1 speculation must fail verification, the exact fallback must fire,
    and the corrected answer must still satisfy the same oracle criteria as
    every other strategy."""
    from repro.core import SpTRSV as _S
    from repro.core.sweep import SweepConfig

    L = pathological(pattern, n=72, seed=1)
    with enable_x64():
        rng = np.random.default_rng(10_001)
        b = rng.standard_normal(L.n)
        s = _S.build(L, strategy="sweep", sweep=SweepConfig(k=1))
        x = s.solve(jnp.asarray(b))
        assert s.sweep_stats.fallback_solves == 1, \
            "speculation unexpectedly passed verification at k=1"
        assert s.sweep_stats.fallback_columns == 1
        combo = ("sweep", "none", "permuted", False, 0)
        _check(L, pattern, x, b, _oracle(L, b, False), False, combo, 1)


# --------------------------------------------------------------------------
# guarded execution: fp32-edge patterns and mixed-precision refinement get
# the same differential treatment as the plain strategies
# --------------------------------------------------------------------------
EXTREME_PATTERNS = ["extreme_scale", "denormal_pivot"]
GUARD_STRATEGIES = ["serial", "levelset", "levelset_unroll", "sweep",
                    "blocked"]
GUARD_GRID = list(itertools.product(GUARD_STRATEGIES, ["none"], LAYOUTS,
                                    [False, True], [0, 3]))
_GUARD_STRIDE = 3


@pytest.mark.parametrize("pattern", EXTREME_PATTERNS)
def test_differential_guarded_extremes(pattern):
    """Tier-1: the fp32-edge patterns (values that overflow/underflow any
    float32 pipeline, pivots at the fp32 subnormal floor) through *guarded*
    solvers with ``on_breakdown="fallback"`` — verification must either pass
    outright or route through the corrective path, and the returned solution
    must satisfy the same componentwise residual criterion as every other
    strategy.  Rotating slice of strategy × layout × transpose × batch."""
    L = pathological(pattern, n=72, seed=1)
    phase = EXTREME_PATTERNS.index(pattern)
    with enable_x64():
        for combo in GUARD_GRID[phase::_GUARD_STRIDE]:
            _run_combo(L, pattern, 1, combo,
                       guard=GuardConfig(on_breakdown="fallback"))


MIXED_PATTERNS = ["arrow", "bidiag_chain", "power_law", "singleton_ladder"]
MIXED_GRID = list(itertools.product(["levelset", "sweep", "blocked"],
                                    ["none"], ["permuted"],
                                    [False, True], [0, 3]))
_MIXED_STRIDE = 2


def _run_mixed_combo(L, pattern, seed, combo):
    """precision="mixed" stores the packed values in bf16 (fp32 diagonal) and
    must still match the float64 oracle after guarded iterative refinement —
    forward error here, not just residual, because these patterns are
    well-conditioned and refinement claims fp64-class accuracy."""
    strategy, policy, layout, transpose, batch = combo
    s = SpTRSV.build(L, strategy=strategy, layout=layout, transpose=transpose,
                     rewrite=POLICIES[policy],
                     guard=GuardConfig(precision="mixed", refine_steps=6,
                                       on_breakdown="refine"))
    rng = np.random.default_rng(10_000 + seed)
    b = rng.standard_normal((L.n, batch) if batch else L.n)
    x = np.asarray(s.solve(jnp.asarray(b)))
    x_ref = _oracle(L, b, transpose)
    scale = max(np.abs(x_ref).max(), 1.0)
    try:
        np.testing.assert_allclose(x, x_ref, rtol=1e-9, atol=1e-9 * scale)
    except AssertionError as err:
        path = _dump_repro(L, pattern, seed, combo, str(err))
        raise AssertionError(
            f"mixed-precision mismatch for {combo} on {pattern}(seed={seed})"
            f" — repro dumped to {path}\n{err}") from None


@pytest.mark.parametrize("pattern", MIXED_PATTERNS)
def test_differential_mixed_precision(pattern):
    """Tier-1: guarded ``precision="mixed"`` vs the float64 oracle on the
    well-conditioned patterns, rotating slice of strategy × transpose ×
    batch (permuted layout only — mixed requires runtime value buffers)."""
    L = pathological(pattern, n=72, seed=1)
    phase = MIXED_PATTERNS.index(pattern)
    with enable_x64():
        for combo in MIXED_GRID[phase::_MIXED_STRIDE]:
            _run_mixed_combo(L, pattern, 1, combo)


@pytest.mark.fuzz
@pytest.mark.parametrize("pattern", EXTREME_PATTERNS)
def test_differential_guarded_exhaustive(pattern):
    """Nightly: full guarded grid on the fp32-edge patterns plus the full
    mixed-precision grid on the well-conditioned ones, FUZZ_SEEDS seeds."""
    seeds = int(os.environ.get("FUZZ_SEEDS", "3"))
    with enable_x64():
        for seed in range(seeds):
            L = pathological(pattern, n=96, seed=seed)
            for combo in GUARD_GRID:
                _run_combo(L, pattern, seed, combo,
                           guard=GuardConfig(on_breakdown="fallback"))
            Lw = pathological(MIXED_PATTERNS[seed % len(MIXED_PATTERNS)],
                              n=96, seed=seed)
            for combo in MIXED_GRID:
                _run_mixed_combo(Lw, MIXED_PATTERNS[seed % len(MIXED_PATTERNS)],
                                 seed, combo)


@pytest.mark.fuzz
@pytest.mark.parametrize("pattern", PATTERNS)
def test_differential_exhaustive(pattern):
    """Nightly: the full strategy × policy × layout × transpose × batch grid
    (distributed included) over FUZZ_SEEDS seeds."""
    import jax
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()[:2]), ("data",))
    seeds = int(os.environ.get("FUZZ_SEEDS", "3"))
    with enable_x64():
        for seed in range(seeds):
            L = pathological(pattern, n=96, seed=seed)
            for combo in GRID:
                _run_combo(L, pattern, seed, combo)
            for combo in itertools.product(
                    ["distributed"], sorted(POLICIES), LAYOUTS,
                    [False, True], [0, 3]):
                _run_combo(L, pattern, seed, combo, mesh=mesh)
            for combo in GPU_GRID:
                _run_combo(L, pattern, seed, combo, backend="interpret:gpu")
