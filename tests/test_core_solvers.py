"""All executor strategies must agree with the dense solve, with and
without rewriting, across dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RewriteConfig, SpTRSV
from repro.sparse import banded_lower, chain_matrix, lung2_like, random_lower


def np_fsolve(L, b):
    x = np.zeros(L.n)
    for i in range(L.n):
        c, v = L.row(i)
        x[i] = (b[i] - (v[:-1] * x[c[:-1]]).sum()) / v[-1]
    return x


MATRICES = {
    "random": lambda: random_lower(257, avg_offdiag=3.0, seed=11, dtype=np.float32),
    "banded": lambda: banded_lower(300, bandwidth=6, fill=0.6, seed=2, dtype=np.float32),
    "chain": lambda: chain_matrix(100, dtype=np.float32),
    "lung2_small": lambda: lung2_like(scale=0.02, fat_levels=5, thin_run=8, dtype=np.float32),
}
STRATS = ["serial", "levelset", "levelset_unroll", "pallas_level", "pallas_fused"]


@pytest.mark.parametrize("mat", MATRICES)
@pytest.mark.parametrize("strategy", STRATS)
@pytest.mark.parametrize("rewrite", [None, RewriteConfig(thin_threshold=3)])
def test_solver_matches_reference(mat, strategy, rewrite):
    L = MATRICES[mat]()
    rng = np.random.default_rng(5)
    b = rng.normal(size=L.n).astype(np.float32)
    x_ref = np_fsolve(L.astype(np.float64), b.astype(np.float64))
    s = SpTRSV.build(L, strategy=strategy, rewrite=rewrite)
    x = np.asarray(s.solve(jnp.asarray(b)))
    assert x.shape == (L.n,)
    assert np.isfinite(x).all()
    np.testing.assert_allclose(x, x_ref, rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("dist_strategy", ["all_gather", "psum"])
def test_distributed_solver(dist_strategy):
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((8,), ("data",))
    L = random_lower(400, avg_offdiag=3.0, seed=4, dtype=np.float32)
    b = np.random.default_rng(1).normal(size=400).astype(np.float32)
    x_ref = np_fsolve(L.astype(np.float64), b.astype(np.float64))
    s = SpTRSV.build(
        L,
        strategy="distributed",
        mesh=mesh,
        dist_strategy=dist_strategy,
        rewrite=RewriteConfig(thin_threshold=4),
    )
    x = np.asarray(s.solve(jnp.asarray(b)))
    np.testing.assert_allclose(x, x_ref, rtol=2e-3, atol=2e-4)


def test_rewrite_reduces_distributed_collectives():
    """The paper's story at scale: fewer levels => fewer collectives."""
    from repro.core import build_level_sets, build_schedule, rewrite_matrix
    from repro.core.dist import shard_schedule

    L = lung2_like(scale=0.05, fat_levels=6, thin_run=10, dtype=np.float32)
    base = build_schedule(L)
    res = rewrite_matrix(L, config=RewriteConfig(thin_threshold=2))
    opt = build_schedule(res.L, res.levels)
    d_base = shard_schedule(base, 8)
    d_opt = shard_schedule(opt, 8)
    assert d_opt.num_levels < d_base.num_levels * 0.5
    assert d_opt.collective_bytes() < d_base.collective_bytes() * 0.8


def test_float64_path():
    from repro.compat import enable_x64

    with enable_x64():
        L = random_lower(150, avg_offdiag=3.0, seed=9, dtype=np.float64)
        b = np.random.default_rng(3).normal(size=150)
        x_ref = np_fsolve(L, b)
        s = SpTRSV.build(L, strategy="levelset")
        x = np.asarray(s.solve(jnp.asarray(b, dtype=jnp.float64)))
        np.testing.assert_allclose(x, x_ref, rtol=1e-12, atol=1e-13)
