"""SolverRegistry: the pattern-keyed memory tier of the solve service.

What must hold (PR-10 acceptance):

* admission is keyed by sparsity pattern + dtype — same pattern, new
  values is a *hit* (O(nnz) refresh onto the resident compiled pair),
  different pattern or dtype is a *miss*;
* LRU + byte-budget eviction in recency order, never evicting the
  just-touched entry or one with queued requests;
* a value refresh that lands while the planned build is in flight is
  re-applied to the built pair before promotion — promotion must never
  resurrect stale numerics;
* the cold serial pair and the promoted planned pair answer the same RHS
  identically (vs the NumPy dense oracle), including when the planned
  build runs on a background worker thread (which does NOT inherit the
  main thread's thread-local ``jax.enable_x64`` — the registry has to
  propagate it);
* a failed planned build leaves the entry serving through the cold pair
  with ``build_error`` set — it never takes down admission.
"""
import threading

import numpy as np
import pytest

from repro.compat import enable_x64
from repro.core import CSRMatrix, SpTRSV
from repro.serve import SolverRegistry, pattern_key
from repro.sparse import random_lower, refresh_values


def _dense_solve(L, b):
    return np.linalg.solve(L.to_dense(), b)


def _revalued(L, seed):
    return CSRMatrix(L.indptr, L.indices, refresh_values(L, seed=seed),
                     L.shape)


# --------------------------------------------------------------------------
# keying: pattern + dtype
# --------------------------------------------------------------------------
def test_pattern_key_ignores_values_but_not_dtype():
    L = random_lower(48, seed=0)
    same_pattern = _revalued(L, seed=9)
    other_pattern = random_lower(48, seed=1)
    f32 = CSRMatrix(L.indptr, L.indices, L.data.astype(np.float32), L.shape)
    assert pattern_key(L) == pattern_key(same_pattern)
    assert pattern_key(L) != pattern_key(other_pattern)
    assert pattern_key(L) != pattern_key(f32)


def test_hit_refreshes_values_onto_resident_pair():
    with enable_x64():
        L = random_lower(64, seed=2)
        reg = SolverRegistry(strategy="levelset", background=False)
        e1 = reg.get(L)
        L2 = _revalued(L, seed=11)
        e2 = reg.get(L2)
        assert e2 is e1
        assert (reg.hits, reg.misses) == (1, 1)
        assert e1.value_refreshes == 1
        b = np.random.default_rng(3).standard_normal(L.n)
        req = e1.engine.submit(b)
        e1.engine.run()
        np.testing.assert_allclose(req.x, _dense_solve(L2, b),
                                   rtol=1e-10, atol=1e-12)
        # bit-identical values → refresh skipped (cheap no-op hit)
        e3 = reg.get(L2)
        assert e3 is e1 and e1.value_refreshes == 1


# --------------------------------------------------------------------------
# LRU + byte-budget eviction
# --------------------------------------------------------------------------
def test_lru_eviction_order_and_touch_protection():
    with enable_x64():
        mats = [random_lower(48, seed=s) for s in range(3)]
        reg = SolverRegistry(strategy="serial", background=False,
                             max_entries=2)
        e0, e1 = reg.get(mats[0]), reg.get(mats[1])
        # touch mats[0] so mats[1] becomes LRU
        assert reg.get(mats[0]) is e0
        reg.get(mats[2])
        assert reg.evictions == 1
        assert e1.evicted and not e0.evicted
        assert reg.keys() == [pattern_key(mats[0]), pattern_key(mats[2])]
        # the evicted pattern re-admits as a fresh miss
        e1b = reg.get(mats[1])
        assert e1b is not e1 and reg.misses == 4


def test_byte_budget_enforced_on_admission():
    with enable_x64():
        mats = [random_lower(64, seed=10 + s) for s in range(3)]
        probe = SolverRegistry(strategy="serial", background=False)
        entry_bytes = probe.get(mats[0]).packed_bytes
        assert entry_bytes > 0
        # room for two entries, not three
        reg = SolverRegistry(strategy="serial", background=False,
                             max_bytes=int(entry_bytes * 2.5))
        for m in mats:
            reg.get(m)
            assert reg.resident_bytes() <= reg.max_bytes
        assert reg.evictions == 1
        assert reg.keys() == [pattern_key(mats[1]), pattern_key(mats[2])]


def test_eviction_skips_entries_with_queued_requests():
    with enable_x64():
        mats = [random_lower(48, seed=20 + s) for s in range(2)]
        reg = SolverRegistry(strategy="serial", background=False,
                             max_entries=1)
        e0 = reg.get(mats[0])
        rng = np.random.default_rng(0)
        req = e0.engine.submit(rng.standard_normal(mats[0].n))
        # e0 is LRU but has queued work — admission must defer, not evict
        reg.get(mats[1])
        assert reg.evictions == 0 and len(reg.keys()) == 2
        e0.engine.run()
        assert req.done
        # once drained, the next admission evicts down to the budget
        m3 = random_lower(48, seed=30)
        reg.get(m3)
        assert reg.evictions == 2
        assert reg.keys() == [pattern_key(m3)]


# --------------------------------------------------------------------------
# cold serial pair vs promoted planned pair
# --------------------------------------------------------------------------
def test_cold_answers_match_promoted_vs_numpy_oracle():
    """The gate pins 'answered while cold' as a fact, not a race; the
    promoted pair must then agree with both the cold answer and the dense
    oracle at f64 tightness — which also pins the x64 propagation onto the
    background build worker (jax.enable_x64 is thread-local)."""
    with enable_x64():
        L = random_lower(96, seed=4)
        gate = threading.Event()
        reg = SolverRegistry(strategy="levelset", background=True,
                             build_gate=gate)
        entry = reg.get(L)
        b = np.random.default_rng(7).standard_normal(L.n)
        req_cold = entry.engine.submit(b)
        entry.engine.run()
        assert req_cold.done and entry.state == "cold"
        assert entry.engine.solver.strategy == "serial"
        oracle = _dense_solve(L, b)
        np.testing.assert_allclose(req_cold.x, oracle, rtol=1e-10,
                                   atol=1e-12)
        gate.set()
        assert entry.wait_ready(timeout=120)
        assert entry.state == "ready" and entry.build_error is None
        assert entry.engine.solver.strategy == "levelset"
        assert entry.cold_completed == 1
        req_warm = entry.engine.submit(b)
        entry.engine.run()
        np.testing.assert_allclose(req_warm.x, oracle, rtol=1e-10,
                                   atol=1e-12)
        np.testing.assert_allclose(req_warm.x, req_cold.x, rtol=1e-12,
                                   atol=1e-13)
        assert reg.wait_idle(timeout=120)


def test_refresh_during_inflight_build_reapplied_before_promotion():
    """Values refreshed while the planned build is in flight must be
    re-applied to the built pair before the swap — promotion may never
    resurrect the admission-time numerics."""
    with enable_x64():
        L = random_lower(72, seed=5)
        reg = SolverRegistry(strategy="levelset", background=True)
        started, proceed = threading.Event(), threading.Event()
        inner = reg._build_planned

        def stalled(snapshot):
            started.set()
            assert proceed.wait(timeout=120)
            return inner(snapshot)

        reg._build_planned = stalled
        entry = reg.get(L)
        assert started.wait(timeout=120)
        # the build snapshotted L's values; move them while it runs
        L2 = _revalued(L, seed=41)
        assert reg.get(L2) is entry    # hit → refresh, version bump
        proceed.set()
        assert entry.wait_ready(timeout=120)
        assert entry.state == "ready" and entry.build_error is None
        b = np.random.default_rng(9).standard_normal(L.n)
        req = entry.engine.submit(b)
        entry.engine.run()
        np.testing.assert_allclose(req.x, _dense_solve(L2, b),
                                   rtol=1e-10, atol=1e-12)
        assert reg.wait_idle(timeout=120)


def test_failed_planned_build_keeps_serving_cold():
    with enable_x64():
        L = random_lower(48, seed=6)
        reg = SolverRegistry(strategy="levelset", background=True)

        def boom(snapshot):
            raise RuntimeError("planner exploded")

        reg._build_planned = boom
        entry = reg.get(L)
        assert entry.wait_ready(timeout=120)      # fires on failure too
        assert entry.state == "cold"
        assert isinstance(entry.build_error, RuntimeError)
        assert reg.build_failures == 1 and reg.promotions == 0
        b = np.random.default_rng(1).standard_normal(L.n)
        req = entry.engine.submit(b)
        entry.engine.run()
        np.testing.assert_allclose(req.x, _dense_solve(L, b),
                                   rtol=1e-10, atol=1e-12)
        assert entry.stats()["build_error"] is not None


def test_evicted_entry_discards_inflight_build():
    with enable_x64():
        L = random_lower(48, seed=7)
        gate = threading.Event()
        reg = SolverRegistry(strategy="levelset", background=True,
                             build_gate=gate, max_entries=1)
        entry = reg.get(L)
        reg.get(random_lower(48, seed=8))      # evicts L (no queued work)
        assert entry.evicted
        gate.set()
        assert reg.wait_idle(timeout=120)
        # the build completed but must not have promoted the evicted entry
        assert entry.state == "cold"
        assert reg.promotions <= 1             # only the survivor's build


def test_registry_stats_shape():
    with enable_x64():
        reg = SolverRegistry(strategy="serial", background=False,
                             max_entries=4)
        L = random_lower(32, seed=0)
        entry = reg.get(L)
        st = reg.stats()
        assert st["entries"] == 1 and st["misses"] == 1
        assert st["resident_packed_bytes"] == entry.packed_bytes > 0
        es = st["per_entry"][entry.key]
        assert es["state"] == "ready"          # serial: promoted in place
        assert es["strategy"] == "serial"
        assert es["cold_build_s"] > 0
        assert st["cold_build"]["count"] == 1


def test_registry_validates_bounds():
    with pytest.raises(ValueError, match="max_entries"):
        SolverRegistry(max_entries=0)
    with pytest.raises(ValueError, match="max_bytes"):
        SolverRegistry(max_bytes=-1)
