"""Pallas flash-attention kernel vs pure-jnp oracle: shape/dtype/mask sweep
(interpret mode) + model-layer integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import attention_ref, flash_attention_kernel


@pytest.mark.parametrize("S,hd,Hq,Hkv", [
    (128, 64, 2, 2), (256, 128, 4, 1), (384, 32, 8, 2),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_sweep(S, hd, Hq, Hkv, dtype):
    rng = np.random.default_rng(0)
    B = 2
    q = jnp.asarray(rng.normal(size=(B, S, Hq, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)), dtype)
    got = flash_attention_kernel(q, k, v, causal=True, interpret=True)
    g = Hq // Hkv
    kk = jnp.repeat(k, g, 2) if g > 1 else k
    vv = jnp.repeat(v, g, 2) if g > 1 else v

    def bh(x):
        return x.transpose(0, 2, 1, 3).reshape(B * Hq, S, hd)

    ref = attention_ref(bh(q), bh(kk), bh(vv), causal=True)
    ref = ref.reshape(B, Hq, S, hd).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [0, 128])
def test_flash_kernel_window_and_ragged(window):
    rng = np.random.default_rng(1)
    B, S, H, hd = 1, 200, 2, 64          # S not a block multiple (padding path)
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    got = flash_attention_kernel(q, k, v, causal=True, window=window,
                                 interpret=True)

    def bh(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, hd)

    ref = attention_ref(bh(q), bh(k), bh(v), causal=True, window=window)
    ref = ref.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_kernel_matches_model_flash():
    """Kernel output == the model library's scan-based flash attention."""
    from repro.models.layers import flash_attention

    rng = np.random.default_rng(2)
    B, S, H, hd = 2, 256, 4, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    a = flash_attention_kernel(q, k, v, causal=True, interpret=True)
    b = flash_attention(q, k, v, kind="causal")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)
