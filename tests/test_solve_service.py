"""SolveService: the multi-tenant continuous-batching front-end.

Pins the tenancy contract on top of the registry tests:

* tenants sharing a (pattern, dtype) share one numeric factor and one
  engine queue — their requests are co-batched and a refresh by one is
  visible to all;
* a tenant whose entry was evicted while idle is transparently
  re-admitted on its next submit (cold path again);
* failures are isolated per request AND per tenant: one tenant's
  ``GuardBreakdownError`` (bad RHS under ``on_breakdown="raise"``) lands
  on that tenant's counters only — co-batched neighbours from other
  tenants still get oracle-correct answers;
* the deterministic mixed-traffic stream (:func:`repro.sparse.
  serve_traffic`) drains completely with every answer matching the dense
  oracle for the values in effect at submission time.
"""
import numpy as np
import pytest

from repro.compat import enable_x64
from repro.core import CSRMatrix, GuardBreakdownError, GuardConfig
from repro.serve import SolveService, SolverRegistry
from repro.sparse import random_lower, refresh_values, serve_traffic


def _dense_solve(L, b, transpose=False):
    A = L.to_dense()
    return np.linalg.solve(A.T if transpose else A, b)


def _revalued(L, seed):
    return CSRMatrix(L.indptr, L.indices, refresh_values(L, seed=seed),
                     L.shape)


def test_tenants_sharing_pattern_share_factor_and_batch():
    with enable_x64():
        L = random_lower(64, seed=0)
        svc = SolveService(strategy="levelset", background=False)
        ka = svc.register("a", L)
        kb = svc.register("b", _revalued(L, seed=5))  # same pattern: hit
        assert ka == kb
        assert (svc.registry.misses, svc.registry.hits) == (1, 1)
        # b's registration refreshed the shared values — both tenants now
        # solve against b's factor (the documented sharing semantics)
        L_now = _revalued(L, seed=5)
        rng = np.random.default_rng(1)
        ba, bb = rng.standard_normal(L.n), rng.standard_normal(L.n)
        ra, rb = svc.submit("a", ba), svc.submit("b", bb)
        done = svc.step()          # ONE drained batch answers both tenants
        assert done == 2 and svc.batches_completed == 1
        np.testing.assert_allclose(ra.x, _dense_solve(L_now, ba),
                                   rtol=1e-10, atol=1e-12)
        np.testing.assert_allclose(rb.x, _dense_solve(L_now, bb),
                                   rtol=1e-10, atol=1e-12)
        st = svc.stats()
        assert st["completed"] == 2 and st["failed"] == 0
        assert st["per_tenant"]["a"]["completed"] == 1


def test_refresh_visible_across_tenants_and_counted():
    with enable_x64():
        L = random_lower(56, seed=2)
        svc = SolveService(strategy="levelset", background=False)
        svc.register("a", L)
        svc.register("b", L)
        new_vals = refresh_values(L, seed=9)
        svc.refresh("a", new_vals)
        b = np.random.default_rng(3).standard_normal(L.n)
        req = svc.submit("b", b)
        svc.run()
        L2 = CSRMatrix(L.indptr, L.indices, new_vals, L.shape)
        np.testing.assert_allclose(req.x, _dense_solve(L2, b),
                                   rtol=1e-10, atol=1e-12)
        st = svc.stats()
        assert st["per_tenant"]["a"]["refreshes"] == 1
        assert st["per_tenant"]["b"]["refreshes"] == 0


def test_evicted_tenant_readmitted_on_submit():
    with enable_x64():
        La, Lb = random_lower(48, seed=4), random_lower(48, seed=5)
        svc = SolveService(strategy="serial", background=False,
                           max_entries=1)
        svc.register("a", La)
        svc.register("b", Lb)                 # evicts a's entry
        assert svc.registry.evictions == 1
        b = np.random.default_rng(6).standard_normal(La.n)
        req = svc.submit("a", b)              # transparent re-admission
        svc.run()
        assert svc.registry.misses == 3
        np.testing.assert_allclose(req.x, _dense_solve(La, b),
                                   rtol=1e-10, atol=1e-12)


def test_breakdown_isolated_per_tenant():
    """One tenant's GuardBreakdownError must not poison a co-batched
    neighbour from another tenant — the neighbour's answer stays
    oracle-correct and only the offender's failed counter moves."""
    with enable_x64():
        L = random_lower(64, seed=7)
        svc = SolveService(strategy="levelset", background=False,
                           guard=GuardConfig(on_breakdown="raise"))
        svc.register("good", L)
        svc.register("bad", L)
        rng = np.random.default_rng(8)
        b_good = rng.standard_normal(L.n)
        b_bad = rng.standard_normal(L.n)
        b_bad[L.n // 2] = np.nan
        r_good = svc.submit("good", b_good)
        r_bad = svc.submit("bad", b_bad)
        done = svc.step()
        assert done == 2
        assert r_good.done and r_good.error is None
        np.testing.assert_allclose(r_good.x, _dense_solve(L, b_good),
                                   rtol=1e-10, atol=1e-12)
        assert r_bad.done and isinstance(r_bad.error, GuardBreakdownError)
        assert r_bad.x is None
        st = svc.stats()
        assert st["per_tenant"]["good"] == dict(
            st["per_tenant"]["good"], completed=1, failed=0)
        assert st["per_tenant"]["bad"] == dict(
            st["per_tenant"]["bad"], completed=0, failed=1)
        assert st["completed"] == 1 and st["failed"] == 1


def test_transpose_requests_route_to_backward_solver():
    with enable_x64():
        L = random_lower(56, seed=9)
        svc = SolveService(strategy="levelset", background=False)
        svc.register("t", L)
        b = np.random.default_rng(10).standard_normal(L.n)
        req = svc.submit("t", b, transpose=True)
        svc.run()
        np.testing.assert_allclose(req.x, _dense_solve(L, b, transpose=True),
                                   rtol=1e-10, atol=1e-12)


def test_mixed_traffic_drains_with_oracle_answers():
    """Drive the shared deterministic workload end to end (inline builds)
    and check every solve against the dense oracle for the values in
    effect when it was submitted."""
    with enable_x64():
        patterns, events = serve_traffic(num_patterns=2, num_tenants=3,
                                         num_events=40, n=48, seed=13)
        svc = SolveService(strategy="levelset", background=False,
                           max_batch=8)
        current = {}                     # tenant -> dense factor snapshot
        shared_key = {}                  # tenant -> registry key
        expected = []
        for ev in events:
            t = ev["tenant"]
            if ev["op"] == "register":
                key = svc.register(t, ev["matrix"])
                dense = ev["matrix"].to_dense()
                # registration refreshes shared values: every tenant on
                # this key sees the new factor
                shared_key[t] = key
                for other, k in shared_key.items():
                    if k == key:
                        current[other] = dense
            elif ev["op"] == "refresh":
                svc.refresh(t, ev["values"])
                m = svc.registry.lookup(shared_key[t]).pattern
                dense = m.to_dense()
                for other, k in shared_key.items():
                    if k == shared_key[t]:
                        current[other] = dense
            else:
                req = svc.submit(t, ev["b"], transpose=ev["transpose"])
                A = current[t].T if ev["transpose"] else current[t]
                expected.append((req, np.linalg.solve(A, ev["b"])))
                svc.step()
        svc.run()
        st = svc.stats()
        assert st["queue_depth"] == 0 and st["failed"] == 0
        assert st["completed"] == len(expected) > 0
        for req, x_ref in expected:
            np.testing.assert_allclose(req.x, x_ref, rtol=1e-9, atol=1e-11)
        assert st["solve_latency"]["count"] == svc.batches_completed > 0


def test_service_validates_tenancy_and_construction():
    svc = SolveService(strategy="serial", background=False)
    with pytest.raises(ValueError, match="no registered factor"):
        svc.submit("ghost", np.zeros(4))
    with pytest.raises(ValueError, match="no registered factor"):
        svc.refresh("ghost", np.zeros(4))
    with pytest.raises(ValueError, match="not both"):
        SolveService(registry=SolverRegistry(), strategy="serial")
