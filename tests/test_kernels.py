"""Per-kernel allclose vs the pure-jnp oracle, sweeping shapes/dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.spmv_ell.kernel import spmv
from repro.kernels.spmv_ell.ref import spmv_ref
from repro.kernels.sptrsv_fused.kernel import fused_solve
from repro.kernels.sptrsv_fused.ref import fused_solve_ref
from repro.kernels.sptrsv_level.kernel import level_solve_blocks
from repro.kernels.sptrsv_level.ref import level_solve_ref
from repro.kernels.trsm_block.kernel import block_apply
from repro.kernels.trsm_block.ref import block_apply_ref


@pytest.mark.parametrize("K", [1, 3, 8, 17])
@pytest.mark.parametrize("R", [128, 512, 1536])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_level_kernel_sweep(K, R, dtype):
    rng = np.random.default_rng(K * 1000 + R)
    n_pad = 1024
    x = rng.normal(size=n_pad).astype(np.float32)
    cols = rng.integers(0, n_pad, size=(K, R)).astype(np.int32)
    vals = rng.normal(size=(K, R)).astype(np.float32)
    bl = rng.normal(size=R).astype(np.float32)
    diag = (2.0 + rng.random(R)).astype(np.float32)
    args = [jnp.asarray(a, dtype) for a in (x, bl, vals, diag)]
    x_d, bl_d, vals_d, diag_d = args
    got = level_solve_blocks(
        x_d, bl_d, jnp.asarray(cols), vals_d, diag_d,
        block_rows=min(512, R), interpret=True,
    )
    want = level_solve_ref(x_d, bl_d, jnp.asarray(cols), vals_d, diag_d)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("K", [1, 4, 9])
@pytest.mark.parametrize("nchunks", [1, 3, 7])
def test_fused_kernel_sweep(K, nchunks):
    """Chunks form a dependency chain: chunk c may read any position < c*C."""
    rng = np.random.default_rng(K * 31 + nchunks)
    C = 256
    n_pad = nchunks * C
    cols = np.zeros((K, n_pad), np.int32)
    for c in range(1, nchunks):  # deps only into earlier chunks
        cols[:, c * C : (c + 1) * C] = rng.integers(0, c * C, size=(K, C))
    vals = rng.normal(size=(K, n_pad)).astype(np.float32) * 0.3
    vals[:, :C] = 0.0  # first chunk has no deps
    bl = rng.normal(size=n_pad).astype(np.float32)
    diag = (2.0 + rng.random(n_pad)).astype(np.float32)
    got = fused_solve(
        jnp.asarray(bl), jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(diag),
        chunk=C, interpret=True,
    )
    want = fused_solve_ref(
        jnp.asarray(bl), jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(diag), chunk=C
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("K", [1, 2, 6, 13])
@pytest.mark.parametrize("n_pad", [1024, 4096])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spmv_kernel_sweep(K, n_pad, dtype):
    rng = np.random.default_rng(K + n_pad)
    m_pad = 512
    v = rng.normal(size=m_pad).astype(np.float32)
    cols = rng.integers(0, m_pad, size=(K, n_pad)).astype(np.int32)
    vals = rng.normal(size=(K, n_pad)).astype(np.float32)
    v_d = jnp.asarray(v, dtype)
    vals_d = jnp.asarray(vals, dtype)
    got = spmv(v_d, jnp.asarray(cols), vals_d, block=1024, interpret=True)
    want = spmv_ref(v_d, jnp.asarray(cols), vals_d)
    tol = 1e-5 if dtype == jnp.float32 else 8e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("NB,T,BB", [(8, 128, 8), (16, 128, 4), (4, 256, 2)])
def test_block_apply_sweep(NB, T, BB):
    rng = np.random.default_rng(NB * T)
    dinv = rng.normal(size=(NB, T, T)).astype(np.float32)
    rhs = rng.normal(size=(NB, T)).astype(np.float32)
    got = block_apply(jnp.asarray(dinv), jnp.asarray(rhs), batch_block=BB, interpret=True)
    want = block_apply_ref(jnp.asarray(dinv), jnp.asarray(rhs))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_block_solver_end_to_end():
    from repro.kernels.trsm_block.ops import make_block_solver
    from repro.sparse import banded_lower

    L = banded_lower(384, bandwidth=20, fill=0.7, seed=3, dtype=np.float32)
    b = np.random.default_rng(0).normal(size=384).astype(np.float32)
    x = np.asarray(make_block_solver(L, T=128)(jnp.asarray(b)))
    want = np.linalg.solve(L.to_dense().astype(np.float64), b)
    np.testing.assert_allclose(x, want, rtol=1e-3, atol=1e-4)
