"""Supernodal/blocked solves: supernode detection, the blocked schedule,
both blocked executors (scatter + packed), planner integration, and the
stats surface.

Regression pins (ISSUE 8): ``lung2_like`` amalgamates to *nothing* (its thin
2-row chains never share structure with their neighbours), and the
``jagged_rows`` pathological pattern is all-singleton by construction — both
must report ``mean_block_size == 1.0`` and be excluded from the planner's
blocked candidacy, so adding the blocked executor cannot change any
previously-planned decision on lung2-class inputs.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import enable_x64
from repro.core import SpTRSV, analyze
from repro.core.coarsen import blocked_candidate, build_block_schedule
from repro.core.levels import SupernodeConfig, Supernodes, detect_supernodes
from repro.sparse import pathological
from repro.sparse.generate import banded_lower, ic0_factor, lung2_like, poisson2d


def _oracle(L, b, transpose=False):
    A = L.to_dense()
    return np.linalg.solve(A.T if transpose else A, b)


def _check_partition(sn: Supernodes):
    """Structural invariants every detection result must satisfy."""
    assert sn.block_ptr[0] == 0 and sn.block_ptr[-1] == sn.n
    assert (np.diff(sn.block_ptr) >= 1).all()
    for b in range(sn.num_supernodes):
        lo, hi = sn.block_ptr[b], sn.block_ptr[b + 1]
        assert (sn.super_of_row[lo:hi] == b).all()
    assert sn.sizes().sum() == sn.n
    assert sn.max_block_size <= sn.config.max_block


# --------------------------------------------------------------------------
# detection
# --------------------------------------------------------------------------
def test_detection_dense_band_needs_relaxation():
    """Past its ramp-up triangle, a fully dense band has mismatch exactly 1
    between every adjacent row pair (the window slides by one), so exact
    matching (relax=0) merges only the leading bw+1 identical-structure rows
    and any relax >= 1/(bw+1) amalgamates the whole band."""
    L = banded_lower(256, bandwidth=16, fill=1.0, seed=0)
    strict = detect_supernodes(L, config=SupernodeConfig(relax=0.0))
    assert strict.num_supernodes == L.n - 16  # one 17-row ramp block
    assert strict.mean_block_size < 1.1
    relaxed = detect_supernodes(L, config=SupernodeConfig(relax=0.25))
    assert relaxed.mean_block_size > 8.0
    assert relaxed.dense_block_fraction > 0.9
    _check_partition(strict)
    _check_partition(relaxed)


def test_detection_max_block_cap():
    L = banded_lower(256, bandwidth=16, fill=1.0, seed=0)
    sn = detect_supernodes(L, config=SupernodeConfig(relax=0.25, max_block=8))
    assert sn.max_block_size <= 8
    assert sn.mean_block_size > 4.0
    _check_partition(sn)


def test_detection_upper_matches_transposed_lower():
    """Detecting on the upper factor (transpose solve) must find the same
    partition the lower factor does — the criterion is mirrored."""
    L = banded_lower(192, bandwidth=8, fill=1.0, seed=2)
    U = L.transpose()
    lo = detect_supernodes(L, upper=False)
    up = detect_supernodes(U, upper=True)
    np.testing.assert_array_equal(lo.block_ptr, up.block_ptr)


def test_detection_pins_lung2_all_singleton():
    """Regression pin: lung2-class inputs amalgamate to nothing, so the
    planner's blocked gate (mean block size >= 1.5) excludes them."""
    L = lung2_like(scale=0.02, seed=3)
    sn = detect_supernodes(L)
    assert sn.num_supernodes == L.n
    assert sn.mean_block_size == 1.0
    assert sn.dense_block_fraction == 0.0
    _check_partition(sn)


def test_detection_pins_jagged_rows_all_singleton():
    """Regression pin: the engineered no-amalgamatable pattern stays
    all-singleton even under a generous relaxation budget."""
    L = pathological("jagged_rows", n=96, seed=1)
    sn = detect_supernodes(L, config=SupernodeConfig(relax=0.5))
    assert sn.num_supernodes == L.n
    assert sn.mean_block_size == 1.0
    assert sn.dense_block_fraction == 0.0


# --------------------------------------------------------------------------
# analysis / stats surface
# --------------------------------------------------------------------------
def test_analysis_reports_supernode_metrics():
    L = banded_lower(128, bandwidth=8, fill=1.0, seed=0)
    a = analyze(L)
    rep = a.report()
    assert rep["supernode_count"] == a.supernodes.num_supernodes
    assert rep["supernode_count"] < L.n
    assert rep["mean_block_size"] > 1.5
    assert 0.0 < rep["dense_block_fraction"] <= 1.0

    a2 = analyze(lung2_like(scale=0.02, seed=3))
    rep2 = a2.report()
    assert rep2["supernode_count"] == a2.n
    assert rep2["mean_block_size"] == 1.0
    assert rep2["dense_block_fraction"] == 0.0


def test_solver_stats_expose_supernode_metrics():
    L = banded_lower(128, bandwidth=8, fill=1.0, seed=0)
    with enable_x64():
        s = SpTRSV.build(L, strategy="blocked")
        st = s.stats()
        assert st["supernode_count"] == s.supernodes.num_supernodes
        assert st["mean_block_size"] == s.supernodes.mean_block_size
        assert st["dense_block_fraction"] == s.supernodes.dense_block_fraction
        assert st["segments"] == s.block_schedule.num_segments
        # non-blocked solvers fall back to the analysis-level metrics
        s2 = SpTRSV.build(L, strategy="levelset")
        assert s2.stats()["supernode_count"] == st["supernode_count"]


# --------------------------------------------------------------------------
# block schedule
# --------------------------------------------------------------------------
def test_block_schedule_invariants():
    L = banded_lower(200, bandwidth=6, fill=0.9, seed=4)
    sn = detect_supernodes(L)
    bs = build_block_schedule(L, sn)
    perm = bs.perm()
    assert sorted(perm.tolist()) == list(range(L.n))
    assert bs.num_blocks == sn.num_supernodes
    assert bs.n == L.n and bs.nnz == L.nnz
    # every cross-block dependency points from an earlier super-level
    order = {b: lvl for b, lvl in enumerate(bs.level_of_block)}
    for i in range(L.n):
        cols, _ = L.row(i)
        for j in cols[cols < i]:
            if sn.super_of_row[j] != sn.super_of_row[i]:
                assert order[sn.super_of_row[j]] < order[sn.super_of_row[i]]
    cand = blocked_candidate(bs)
    assert cand.segments == bs.num_segments
    assert cand.panel_flops > 0 and cand.gemm_flops > 0


# --------------------------------------------------------------------------
# executors
# --------------------------------------------------------------------------
@pytest.mark.parametrize("layout", ["scatter", "permuted"])
@pytest.mark.parametrize("batch", [0, 3])
def test_blocked_executor_matches_oracle(layout, batch):
    L = banded_lower(150, bandwidth=6, fill=0.95, seed=1)
    rng = np.random.default_rng(7)
    b = rng.standard_normal((L.n, batch) if batch else L.n)
    with enable_x64():
        s = SpTRSV.build(L, strategy="blocked", layout=layout)
        x = np.asarray(s.solve(jnp.asarray(b)))
    np.testing.assert_allclose(x, _oracle(L, b), rtol=1e-12, atol=1e-12)


def test_blocked_build_pair_transpose():
    L = banded_lower(150, bandwidth=6, fill=0.95, seed=1)
    rng = np.random.default_rng(8)
    b = rng.standard_normal(L.n)
    with enable_x64():
        fwd, bwd = SpTRSV.build_pair(L, strategy="blocked", layout="permuted")
        np.testing.assert_allclose(np.asarray(fwd.solve(jnp.asarray(b))),
                                   _oracle(L, b), rtol=1e-12, atol=1e-12)
        np.testing.assert_allclose(np.asarray(bwd.solve(jnp.asarray(b))),
                                   _oracle(L, b, transpose=True),
                                   rtol=1e-12, atol=1e-12)
        assert bwd.transpose and bwd.supernodes is not None


def test_blocked_refresh_is_value_only_on_permuted():
    L = banded_lower(150, bandwidth=6, fill=0.95, seed=1)
    data2 = L.data * 1.3 + 0.01
    from repro.core import CSRMatrix
    L2 = CSRMatrix(L.indptr, L.indices, data2, L.shape)
    rng = np.random.default_rng(9)
    b = rng.standard_normal(L.n)
    with enable_x64():
        s = SpTRSV.build(L, strategy="blocked", layout="permuted")
        assert s.stats()["refreshable_in_place"]
        assert s.refresh(data2) is s
        np.testing.assert_allclose(np.asarray(s.solve(jnp.asarray(b))),
                                   _oracle(L2, b), rtol=1e-12, atol=1e-12)
        # scatter embeds values at trace time -> cold rebuild, same answer
        s2 = SpTRSV.build(L, strategy="blocked", layout="scatter")
        s2.refresh(data2)
        np.testing.assert_allclose(np.asarray(s2.solve(jnp.asarray(b))),
                                   _oracle(L2, b), rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("backend", ["interpret", "interpret:gpu"])
def test_blocked_pallas_kernel_both_families(backend):
    """``block_kernel="pallas"`` drives kernels/trsm_block through both
    lowering families under the interpreter.  The kernels accumulate in
    float32, so the tolerance is loose."""
    L = banded_lower(96, bandwidth=8, fill=1.0, seed=2)
    rng = np.random.default_rng(11)
    b = rng.standard_normal(L.n)
    with enable_x64():
        s = SpTRSV.build(L, strategy="blocked", layout="permuted",
                         block_kernel="pallas", backend=backend)
        x = np.asarray(s.solve(jnp.asarray(b)))
    np.testing.assert_allclose(x, _oracle(L, b), rtol=1e-4, atol=1e-4)


def test_blocked_composes_with_ic0():
    """The classic IC(0)-preconditioner workload end-to-end: factor a 2-D
    Poisson operator and run the blocked executor on the incomplete
    factor."""
    L = ic0_factor(poisson2d(10, 10))
    rng = np.random.default_rng(12)
    b = rng.standard_normal(L.n)
    with enable_x64():
        s = SpTRSV.build(L, strategy="blocked", layout="permuted")
        x = np.asarray(s.solve(jnp.asarray(b)))
    np.testing.assert_allclose(x, _oracle(L, b), rtol=1e-11, atol=1e-11)


def test_blocked_serves_through_solve_engine():
    from repro.serve import SolveEngine

    L = banded_lower(120, bandwidth=6, fill=0.95, seed=5)
    rng = np.random.default_rng(13)
    with enable_x64():
        eng = SolveEngine.from_matrix(L, strategy="blocked", layout="permuted")
        reqs = [eng.submit(rng.standard_normal(L.n)) for _ in range(3)]
        eng.run()
        for r in reqs:
            assert r.done
            np.testing.assert_allclose(r.x, _oracle(L, r.b),
                                       rtol=1e-11, atol=1e-11)


# --------------------------------------------------------------------------
# planner integration
# --------------------------------------------------------------------------
def test_auto_picks_blocked_on_dense_band():
    """Acceptance gate: on a dense banded factor the planner's calibrated
    gemm/trsm pricing must put the blocked executor below serial and every
    level-set candidate."""
    L = banded_lower(2048, bandwidth=24, fill=1.0, seed=1)
    with enable_x64():
        s = SpTRSV.build(L, strategy="auto")
        assert s.strategy == "blocked", s.plan.reason
        assert "blocked" in s.plan.reason
        rng = np.random.default_rng(14)
        b = rng.standard_normal(L.n)
        np.testing.assert_allclose(np.asarray(s.solve(jnp.asarray(b))),
                                   _oracle(L, b), rtol=1e-11, atol=1e-11)


def test_auto_unchanged_on_lung2_class():
    """Acceptance gate: lung2-class inputs are all-singleton, the blocked
    gate excludes them from candidacy, and the planner's decision is
    byte-identical to a build with supernodes disabled."""
    L = lung2_like(scale=0.02, seed=3)
    with enable_x64():
        s = SpTRSV.build(L, strategy="auto")
        baseline = SpTRSV.build(L, strategy="auto", supernodes=False)
        assert s.strategy == baseline.strategy
        assert s.plan.reason == baseline.plan.reason
        assert "blocked" not in s.plan.reason


def test_relax_knob_threads_through_build():
    L = banded_lower(128, bandwidth=8, fill=1.0, seed=0)
    with enable_x64():
        strict = SpTRSV.build(L, strategy="blocked",
                              supernodes=SupernodeConfig(relax=0.0))
        assert strict.supernodes.mean_block_size < 1.1
        relaxed = SpTRSV.build(L, strategy="blocked",
                               supernodes=SupernodeConfig(relax=0.25))
        assert relaxed.supernodes.mean_block_size > 1.5
        rng = np.random.default_rng(15)
        b = rng.standard_normal(L.n)
        np.testing.assert_allclose(np.asarray(strict.solve(jnp.asarray(b))),
                                   np.asarray(relaxed.solve(jnp.asarray(b))),
                                   rtol=1e-12, atol=1e-12)
