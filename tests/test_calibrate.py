"""Calibration-table persistence: JSON save/load roundtrip, overlay
precedence over the shipped defaults, unknown-backend fallback, and the
malformed-file error paths ``benchmarks/calibrate.py`` relies on."""
import dataclasses
import json

import pytest

from repro.core.calibrate import (
    DEFAULT_CALIBRATIONS,
    BackendCalibration,
    get_calibration,
    load_calibrations,
    refresh,
    save_calibrations,
)


def test_save_load_roundtrip(tmp_path):
    path = tmp_path / "cal.json"
    table = {
        "cpu": BackendCalibration(backend="cpu", launch_cost=1234.0,
                                  gemm_cost=0.5, trsm_cost=10.0,
                                  source="measured"),
        "tpu": DEFAULT_CALIBRATIONS["tpu"],
    }
    save_calibrations(path, table)
    loaded = load_calibrations(path)
    assert loaded == table
    # every field survives, not just the ones we set explicitly
    for key in table:
        assert dataclasses.asdict(loaded[key]) == dataclasses.asdict(table[key])


def test_overlay_precedence(tmp_path):
    """``refresh`` merges a measured table over the defaults: measured rows
    win, rows the file does not carry fall through to the defaults."""
    path = tmp_path / "cal.json"
    measured = BackendCalibration(backend="cpu", gather_cost=0.125,
                                  source="measured")
    save_calibrations(path, {"cpu": measured})
    table = refresh(path)
    assert table["cpu"] == measured
    assert table["cpu"].source == "measured"
    # untouched rows are the shipped defaults
    assert table["tpu"] == DEFAULT_CALIBRATIONS["tpu"]
    assert table["gpu"] == DEFAULT_CALIBRATIONS["gpu"]
    # get_calibration honours the same precedence
    assert get_calibration("cpu", table).gather_cost == 0.125
    assert get_calibration("gpu", table) == DEFAULT_CALIBRATIONS["gpu"]


def test_refresh_missing_file_is_defaults(tmp_path):
    table = refresh(tmp_path / "does_not_exist.json")
    assert table == DEFAULT_CALIBRATIONS


def test_unknown_backend_raises():
    with pytest.raises(ValueError, match="no calibration for backend"):
        get_calibration("quantum")
    # a table override does not mask the fallback error for absent keys
    with pytest.raises(ValueError, match="quantum"):
        get_calibration("quantum", {"cpu": DEFAULT_CALIBRATIONS["cpu"]})


def test_forward_compat_ignores_unknown_row_keys(tmp_path):
    """Old planners must load tables written by newer code: unknown keys in
    a row are dropped, missing fields take dataclass defaults."""
    path = tmp_path / "cal.json"
    path.write_text(json.dumps({
        "cpu": {"launch_cost": 999.0, "a_future_field": 42},
    }))
    table = load_calibrations(path)
    assert table["cpu"].launch_cost == 999.0
    assert table["cpu"].backend == "cpu"          # defaulted from the key
    assert table["cpu"].gemm_cost == BackendCalibration("cpu").gemm_cost


def test_malformed_file_raises_valueerror(tmp_path):
    bad_json = tmp_path / "bad.json"
    bad_json.write_text("{not json")
    with pytest.raises(ValueError, match=str(bad_json)):
        load_calibrations(bad_json)

    not_object = tmp_path / "list.json"
    not_object.write_text(json.dumps([1, 2, 3]))
    with pytest.raises(ValueError, match="expected a JSON object"):
        load_calibrations(not_object)

    bad_row = tmp_path / "row.json"
    bad_row.write_text(json.dumps({"cpu": "fast"}))
    with pytest.raises(ValueError, match="row 'cpu'"):
        load_calibrations(bad_row)


def test_blocked_pricing_fields_in_every_default_row():
    """The blocked executor's gemm/trsm coefficients exist on every shipped
    row, and accelerator rows price dense block flops below gathered flops."""
    for key, row in DEFAULT_CALIBRATIONS.items():
        assert row.gemm_cost > 0, key
        assert row.trsm_cost > 0, key
        assert row.gemm_cost < row.gather_cost, key
    assert DEFAULT_CALIBRATIONS["tpu"].gemm_cost < \
        DEFAULT_CALIBRATIONS["cpu"].gemm_cost
