"""Test session config.

8 virtual CPU devices so distributed/pipeline tests can build small meshes.
(Deliberately NOT 512 — the production-mesh device count is set only inside
launch/dryrun.py, which owns its own process.)

``jax_num_cpu_devices`` only exists on newer JAX; older builds need the
``--xla_force_host_platform_device_count`` XLA flag set *before* the JAX
backend initializes, so this must run at conftest import time (before any
test module imports jax and touches devices).
"""
import os

_N_DEVICES = 8

try:
    import jax

    jax.config.update("jax_num_cpu_devices", _N_DEVICES)
except AttributeError:
    # Older JAX: force host devices via XLA_FLAGS. Safe only if the backend
    # has not initialized yet — conftest runs before test modules import jax
    # for real work, so append the flag and let first use pick it up.
    flags = os.environ.get("XLA_FLAGS", "")
    flag = f"--xla_force_host_platform_device_count={_N_DEVICES}"
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (flags + " " + flag).strip()
