"""Test session config.

8 virtual CPU devices so distributed/pipeline tests can build small meshes.
(Deliberately NOT 512 — the production-mesh device count is set only inside
launch/dryrun.py, which owns its own process.)
"""
import jax

jax.config.update("jax_num_cpu_devices", 8)
