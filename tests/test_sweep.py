"""Sync-free speculative solve-then-correct executor (strategy="sweep").

The claims under test, in the order the module docstring makes them:
speculation is exact on diagonally-dominant systems (verified residual, no
fallback), the executor's program has no per-level loop/collective structure
at all, non-converged solves are corrected by the exact fallback
(oracle-equivalence), refresh re-packs the D + N value buffers without
re-tracing, the auto planner prices sweeps against level-set execution, and
the k-sweep inexact preconditioner keeps PCG convergent within 2x of the
exact preconditioner's iteration count.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import enable_x64
from repro.core import RewriteConfig, SpTRSV, SweepConfig
from repro.core.csr import CSRMatrix
from repro.core.sweep import (
    build_sweep_layout,
    contraction_factor,
    default_residual_tol,
    planned_sweeps,
)
from repro.sparse import chain_matrix, ic0_factor, lung2_like, poisson2d


def _lung2(dtype=np.float64):
    return lung2_like(scale=0.05, fat_levels=6, thin_run=10, dtype=dtype)


def _oracle(L, b, transpose=False):
    A = L.to_dense()
    return np.linalg.solve(A.T if transpose else A, b)


# --------------------------------------------------------------------------
# speculation converges: oracle equivalence with zero fallbacks
# --------------------------------------------------------------------------
@pytest.mark.parametrize("transpose", [False, True])
@pytest.mark.parametrize("batch", [0, 3])
def test_sweep_exact_on_dominant_system(transpose, batch):
    """lung2-class (diagonally dominant, q ≈ 0.2): the k-sweep speculative
    solve must pass verification outright — componentwise-residual-exact
    with the fallback never firing."""
    with enable_x64():
        L = _lung2()
        rng = np.random.default_rng(0)
        b = rng.standard_normal((L.n, batch) if batch else L.n)
        s = SpTRSV.build(L, strategy="sweep", transpose=transpose)
        x = np.asarray(s.solve(jnp.asarray(b)))
        np.testing.assert_allclose(x, _oracle(L, b, transpose),
                                   rtol=1e-12, atol=1e-12)
        assert s.sweep_stats.solves == 1
        assert s.sweep_stats.fallback_solves == 0
        assert s.sweep_stats.last_residual_ratio <= \
            default_residual_tol(np.float64)


def test_sweep_scatter_layout_matches():
    with enable_x64():
        L = _lung2()
        b = np.random.default_rng(1).standard_normal(L.n)
        s = SpTRSV.build(L, strategy="sweep", layout="scatter")
        np.testing.assert_allclose(np.asarray(s.solve(jnp.asarray(b))),
                                   _oracle(L, b), rtol=1e-12, atol=1e-12)


def test_sweep_composes_with_rewrite():
    """Explicit rewrite: sweeps run on the rewritten system L' with the
    b' = E b transform applied upstream — same contract as every other
    executor."""
    with enable_x64():
        L = _lung2()
        b = np.random.default_rng(2).standard_normal(L.n)
        s = SpTRSV.build(L, strategy="sweep",
                         rewrite=RewriteConfig(thin_threshold=2))
        assert s.rewrite_result is not None
        np.testing.assert_allclose(np.asarray(s.solve(jnp.asarray(b))),
                                   _oracle(L, b), rtol=1e-11, atol=1e-11)


# --------------------------------------------------------------------------
# zero intra-solve barriers: program structure
# --------------------------------------------------------------------------
def test_sweep_jaxpr_has_no_level_structure():
    """The acceptance criterion stated structurally: the executor's jaxpr
    contains no loop or collective primitive — no while/scan/fori over
    levels, no per-segment anything.  Per-solve program shape is independent
    of the schedule's depth."""
    with enable_x64():
        L = _lung2()
        s = SpTRSV.build(L, strategy="sweep")
        b = jnp.asarray(np.random.default_rng(3).standard_normal(L.n))
        txt = str(jax.make_jaxpr(lambda bb, vv: s._sweep_exec(bb, vv))(
            b, s._values))
        for prim in ("while", "scan(", "fori", "all_gather", "psum",
                     "ppermute"):
            assert prim not in txt, f"found {prim!r} in sweep jaxpr"
        assert s.stats()["segments"] == 1
        assert s.schedule is None  # no level schedule was even built


def test_sweep_program_size_independent_of_depth():
    """Two chains, 4x apart in level count, produce sweep executors with the
    same number of jaxpr equations (same k) — per-solve cost decoupled from
    depth, which no level-set executor can do."""
    with enable_x64():
        sizes = []
        for n in (200, 800):
            C = chain_matrix(n)
            s = SpTRSV.build(C, strategy="sweep", sweep=SweepConfig(k=8))
            b = jnp.asarray(np.zeros(n))
            jaxpr = jax.make_jaxpr(lambda bb, vv: s._sweep_exec(bb, vv))(
                b, s._values)
            sizes.append(len(jaxpr.jaxpr.eqns))
        assert sizes[0] == sizes[1], sizes


# --------------------------------------------------------------------------
# solve-then-correct: fallback splices exact columns in
# --------------------------------------------------------------------------
def test_sweep_fallback_fires_and_corrects():
    """k=1 on a pure chain cannot converge (information travels one level
    per sweep); verification must reject it and the exact fallback must
    deliver the oracle answer anyway."""
    with enable_x64():
        C = chain_matrix(96)
        b = np.random.default_rng(4).standard_normal(96)
        s = SpTRSV.build(C, strategy="sweep", sweep=SweepConfig(k=1))
        x = np.asarray(s.solve(jnp.asarray(b)))
        np.testing.assert_allclose(x, _oracle(C, b), rtol=1e-12, atol=1e-12)
        assert s.sweep_stats.fallback_solves == 1
        assert s.sweep_stats.fallback_columns == 1
        assert s.sweep_stats.last_residual_ratio > \
            default_residual_tol(np.float64)


def test_sweep_fallback_splices_per_column():
    """Batched verification is per-column: converged speculative columns are
    kept, only offending columns are re-solved.  A zero RHS column converges
    after one sweep even on a chain; a random column does not."""
    with enable_x64():
        C = chain_matrix(96)
        rng = np.random.default_rng(5)
        B = np.stack([np.zeros(96), rng.standard_normal(96)], axis=1)
        s = SpTRSV.build(C, strategy="sweep", sweep=SweepConfig(k=1))
        X = np.asarray(s.solve(jnp.asarray(B)))
        np.testing.assert_allclose(X, _oracle(C, B), rtol=1e-12, atol=1e-12)
        assert s.sweep_stats.fallback_solves == 1
        assert s.sweep_stats.fallback_columns == 1  # only the random column


def test_sweep_fallback_strategy_is_configurable():
    with enable_x64():
        C = chain_matrix(64)
        b = np.random.default_rng(6).standard_normal(64)
        s = SpTRSV.build(C, strategy="sweep",
                         sweep=SweepConfig(k=1, fallback="serial"))
        np.testing.assert_allclose(np.asarray(s.solve(jnp.asarray(b))),
                                   _oracle(C, b), rtol=1e-12, atol=1e-12)
        assert s.sweep_stats.fallback_solves == 1


# --------------------------------------------------------------------------
# refresh: value-only re-pack, no re-trace, fallback stays in sync
# --------------------------------------------------------------------------
def test_sweep_refresh_matches_fresh_build():
    with enable_x64():
        L = _lung2()
        rng = np.random.default_rng(7)
        b = jnp.asarray(rng.standard_normal(L.n))
        s = SpTRSV.build(L, strategy="sweep")
        s.solve(b)
        data2 = L.data * (1.0 + 0.25 * rng.standard_normal(L.nnz))
        # keep diagonal dominance so speculation still converges
        s.refresh(data2)
        L2 = CSRMatrix(L.indptr, L.indices, data2, L.shape)
        np.testing.assert_allclose(np.asarray(s.solve(b)),
                                   _oracle(L2, np.asarray(b)),
                                   rtol=1e-11, atol=1e-11)
        assert s.sweep_stats.fallback_solves == 0


def test_sweep_refresh_does_not_retrace():
    with enable_x64():
        L = _lung2()
        s = SpTRSV.build(L, strategy="sweep")
        b = jnp.asarray(np.random.default_rng(8).standard_normal(L.n))
        s.solve(b)
        if not hasattr(s._sweep_exec, "_cache_size"):
            pytest.skip("jit cache introspection unavailable on this JAX")
        before = s._sweep_exec._cache_size()
        s.refresh(L.data * 1.5)
        s.solve(b)
        assert s._sweep_exec._cache_size() == before


def test_sweep_refresh_updates_lazy_fallback():
    """The exact fallback is built lazily; once built, a refresh must swap
    its values too — otherwise a later correction would solve against stale
    numbers."""
    with enable_x64():
        C = chain_matrix(64)
        rng = np.random.default_rng(9)
        b = rng.standard_normal(64)
        s = SpTRSV.build(C, strategy="sweep", sweep=SweepConfig(k=1))
        s.solve(jnp.asarray(b))          # fallback fires → built
        assert s.sweep_stats.fallback_solves == 1
        data2 = C.data * 3.0
        s.refresh(data2)
        C2 = CSRMatrix(C.indptr, C.indices, data2, C.shape)
        x = np.asarray(s.solve(jnp.asarray(b)))   # fallback fires again
        np.testing.assert_allclose(x, _oracle(C2, b), rtol=1e-12, atol=1e-12)
        assert s.sweep_stats.fallback_solves == 2


# --------------------------------------------------------------------------
# planner: sweeps priced against level-set from the depth/contraction profile
# --------------------------------------------------------------------------
def test_planner_picks_sweep_on_long_dominant_chain():
    """A long diagonally-dominant chain (q = 0.125): the serial scan pays
    O(n) latency-bound steps, level-set pays a barrier per level — the
    certified ~15-sweep speculative solve is modelled far cheaper than
    either, and the decision records the planned k."""
    with enable_x64():
        C = chain_matrix(4000)
        s = SpTRSV.build(C, strategy="auto")
        assert s.strategy == "sweep", s.plan.reason
        assert s.plan.sweep_k is not None and 1 <= s.plan.sweep_k <= 32
        assert "sweep" in s.plan.costs
        assert s.stats()["planned_sweeps"] == s.plan.sweep_k
        # the planner-chosen k must actually converge (no fallback)
        b = np.random.default_rng(10).standard_normal(C.n)
        x = np.asarray(s.solve(jnp.asarray(b)))
        np.testing.assert_allclose(x, _oracle(C, b), rtol=1e-12, atol=1e-12)
        assert s.sweep_stats.fallback_solves == 0


def test_planner_excludes_sweep_without_certified_convergence():
    """Non-dominant system (off-diagonal mass ≥ diagonal): no contraction
    certificate and depth exceeds the cap, so sweeps must not be priced —
    and sweep=False opts out even when they would be."""
    with enable_x64():
        # chain with off-diag 2.0 > diag 1.0: q = 2, depth = n > default cap
        n = 300
        rows = list(range(n)) + list(range(1, n))
        cols = list(range(n)) + list(range(n - 1))
        vals = [1.0] * n + [2.0] * (n - 1)
        from repro.core import from_coo
        C = from_coo(rows, cols, vals, (n, n))
        s = SpTRSV.build(C, strategy="auto")
        assert "sweep" not in s.plan.costs
        # opting out removes sweep from the candidate set on dominant input
        D = chain_matrix(4000)
        s2 = SpTRSV.build(D, strategy="auto", sweep=False)
        assert "sweep" not in s2.plan.costs


def test_planned_sweeps_bounds():
    # nilpotency bound: exact after depth sweeps regardless of contraction
    assert planned_sweeps(2.0, 5, 1e-14, 32) == 5
    # contraction improves on depth when it certifies an earlier stop
    # (⌈log(tol/256)/log q⌉ — margin for the initial-error constant)
    assert planned_sweeps(0.1, 500, 1e-14, 32) == 17
    # neither bound within the cap → no candidate
    assert planned_sweeps(0.99, 500, 1e-14, 32) is None
    assert planned_sweeps(2.0, 500, 1e-14, 32) is None


def test_contraction_factor_matches_dense():
    with enable_x64():
        L = _lung2()
        d = np.abs(np.diag(L.to_dense()))
        off = np.abs(L.to_dense()).sum(axis=1) - d
        np.testing.assert_allclose(contraction_factor(L), (off / d).max())
        # transpose storage reads the diagonal from the front of each row
        Lt = L.transpose()
        dt = np.abs(np.diag(Lt.to_dense()))
        offt = np.abs(Lt.to_dense()).sum(axis=1) - dt
        np.testing.assert_allclose(contraction_factor(Lt, upper=True),
                                   (offt / dt).max())


# --------------------------------------------------------------------------
# layout invariants
# --------------------------------------------------------------------------
def test_sweep_layout_roundtrip():
    """D + N split reassembles to the original matrix, forward and
    transpose."""
    with enable_x64():
        L = _lung2()
        for M, upper in ((L, False), (L.transpose(), True)):
            lay = build_sweep_layout(M, upper=upper)
            dense = np.zeros((M.n, M.n))
            for kk in range(lay.K):
                mask = lay.ell.val_src[kk] >= 0
                dense[np.nonzero(mask)[0],
                      lay.ell.cols[kk][mask]] += lay.ell.vals[kk][mask]
            dense[np.arange(M.n), np.arange(M.n)] += lay.diag
            np.testing.assert_allclose(dense, M.to_dense())


# --------------------------------------------------------------------------
# PCG with the k-sweep inexact preconditioner
# --------------------------------------------------------------------------
def test_pcg_inexact_sweep_preconditioner_within_2x():
    """Acceptance criterion: PCG with the k-sweep inexact M⁻¹ converges on
    the SPD suite within 2x the exact preconditioner's iterations."""
    from repro.core.pcg import make_ic_preconditioner, pcg

    A = poisson2d(24, 24, dtype=np.float32)
    L = ic0_factor(A)
    b = jnp.asarray(
        np.random.default_rng(0).normal(size=A.n).astype(np.float32))
    exact = pcg(A, b, make_ic_preconditioner(L, rewrite=None),
                tol=1e-5, maxiter=1500)
    inexact = pcg(A, b, make_ic_preconditioner(L, sweeps=8),
                  tol=1e-5, maxiter=1500, stall_window=25)
    assert exact.converged and inexact.converged
    assert inexact.iters <= 2 * exact.iters, (inexact.iters, exact.iters)
    x = np.asarray(inexact.x, np.float64)
    r = np.asarray(b, np.float64) - A.astype(np.float64).matvec(x)
    assert np.linalg.norm(r) <= 1e-4 * np.linalg.norm(np.asarray(b))


def test_pcg_inexact_sweep_preconditioner_batched():
    from repro.core.pcg import make_ic_preconditioner_batched, pcg_batched

    A = poisson2d(16, 16, dtype=np.float32)
    L = ic0_factor(A)
    B = jnp.asarray(
        np.random.default_rng(1).normal(size=(A.n, 3)).astype(np.float32))
    res = pcg_batched(A, B, make_ic_preconditioner_batched(L, sweeps=8),
                      tol=1e-5, maxiter=1500)
    assert res.converged.all()


def test_sweep_config_validation():
    with pytest.raises(AssertionError):
        SweepConfig(k=0)
    with pytest.raises(AssertionError):
        SweepConfig(fallback="auto")   # exact strategies only
    cfg = SweepConfig(k=4, fallback=None)
    assert dataclasses.replace(cfg, k=8).k == 8
