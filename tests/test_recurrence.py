"""The paper-technique ⇄ linear-recurrence bridge: scan == doubling ==
literal SpTRSV-with-rewriting pipeline, and the chain matrix's level count
collapses under rewriting exactly like recursive doubling predicts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.levels import build_level_sets
from repro.core.recurrence import linear_recurrence, recurrence_as_sptrsv
from repro.core.rewrite import RewriteConfig, rewrite_matrix


def _ref(a, u):
    h = np.zeros_like(u)
    acc = np.zeros(u.shape[1:])
    for t in range(u.shape[0]):
        acc = a[t] * acc + u[t]
        h[t] = acc
    return h


@pytest.mark.parametrize("method", ["scan", "doubling", "sptrsv"])
def test_linear_recurrence_methods_agree(method):
    rng = np.random.default_rng(0)
    T, D = 33, 3
    a = rng.uniform(0.2, 0.99, (T, D))
    u = rng.normal(size=(T, D))
    ref = _ref(a, u)
    got = linear_recurrence(jnp.asarray(a), jnp.asarray(u), method=method)
    np.testing.assert_allclose(np.asarray(got), ref, rtol=1e-5, atol=1e-5)


def test_h0_fold_in():
    rng = np.random.default_rng(1)
    T, D = 9, 4
    a = rng.uniform(0.2, 0.99, (T, D))
    u = rng.normal(size=(T, D))
    h0 = rng.normal(size=(D,))
    got = linear_recurrence(jnp.asarray(a), jnp.asarray(u), jnp.asarray(h0),
                            method="doubling")
    ref = np.zeros_like(u)
    acc = h0.copy()
    for t in range(T):
        acc = a[t] * acc + u[t]
        ref[t] = acc
    np.testing.assert_allclose(np.asarray(got), ref, rtol=1e-5, atol=1e-5)


@given(st.integers(4, 64), st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_doubling_matches_scan_property(T, seed):
    rng = np.random.default_rng(seed)
    a = rng.uniform(-1.0, 1.0, (T,))
    u = rng.normal(size=(T,))
    s = linear_recurrence(jnp.asarray(a), jnp.asarray(u), method="scan")
    d = linear_recurrence(jnp.asarray(a), jnp.asarray(u), method="doubling")
    np.testing.assert_allclose(np.asarray(s), np.asarray(d), rtol=1e-4, atol=1e-5)


def test_chain_levels_collapse_under_rewriting():
    """The recurrence's bidiagonal matrix has T levels; the paper transform
    (thin_threshold=1 == rewrite every chain row) collapses them to 2:
    row 0 (the only kept level) plus one fat wavefront of all other rows,
    each now depending only on row 0 — the equation-rewriting derivation of
    the parallel scan (T-1 barriers -> 1)."""
    a = np.random.default_rng(2).uniform(0.5, 0.9, (64,))
    L = recurrence_as_sptrsv(a)
    lv = build_level_sets(L)
    assert lv.num_levels == 64
    res = rewrite_matrix(L, lv, RewriteConfig(
        thin_threshold=1, max_row_nnz=65, max_fill_ratio=64.0))
    assert res.levels.num_levels == 2
    assert res.levels.counts[1] == 63
    # FLOP increase is the scan's O(T^2) dense-row cost in the limit —
    # the paper's +FLOPs-for-fewer-barriers bargain, taken to the extreme
    assert res.stats.flops_after > res.stats.flops_before
