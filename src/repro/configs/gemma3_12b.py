"""[dense] Gemma-3-12B (hf:google/gemma-3-1b-pt family; unverified).
48 layers, 5:1 local:global attention, window 1024, d_model=3840, 16 heads /
8 kv, d_ff=15360, vocab 262144, logit softcap 30.

Selectable as ``--arch gemma3-12b``.
"""
from repro.models.config import ARCHS, smoke_config

NAME = "gemma3-12b"
CONFIG = ARCHS[NAME]
SMOKE = smoke_config(NAME)
