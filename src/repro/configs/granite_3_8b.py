"""[dense] Granite-3.0-8B (hf:ibm-granite/granite-3.0-2b-base family; hf).
40 layers, d_model=4096, 32 heads / 8 kv (GQA), d_ff=12800, vocab 49155
(padded to 49408 for sharding).

Selectable as ``--arch granite-3-8b``.
"""
from repro.models.config import ARCHS, smoke_config

NAME = "granite-3-8b"
CONFIG = ARCHS[NAME]
SMOKE = smoke_config(NAME)
