"""Per-architecture configs (one module per assigned arch) + shape registry.

``get("<arch-id>")`` accepts the public dashed id (e.g. "gemma3-12b").
"""
from repro.models.config import ARCHS, get_config, smoke_config
from .shapes import SHAPES, ShapeSpec, runs_cell, skip_reason

ARCH_IDS = tuple(ARCHS)

__all__ = ["ARCHS", "ARCH_IDS", "get_config", "smoke_config",
           "SHAPES", "ShapeSpec", "runs_cell", "skip_reason"]


def get(name: str):
    return get_config(name)
