"""[audio] Whisper-medium encoder-decoder backbone (arXiv:2212.04356; unverified).
24 decoder + 24 encoder layers, d_model=1024, 16 heads (MHA, kv=16), d_ff=4096,
vocab 51865.  The log-mel conv frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (B, enc_len, 1024); enc/dec split a cell's
seq_len budget 50/50.  Sinusoidal positions, no RoPE.

Selectable as ``--arch whisper-medium``.
"""
from repro.models.config import ARCHS, smoke_config

NAME = "whisper-medium"
CONFIG = ARCHS[NAME]
SMOKE = smoke_config(NAME)
