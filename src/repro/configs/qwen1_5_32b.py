"""[dense] Qwen1.5-32B (hf:Qwen/Qwen1.5-0.5B family; hf).
64 layers, d_model=5120, 40 heads / 40 kv (full MHA), QKV bias, d_ff=27392,
vocab 152064.  decode_32k uses the int8 KV cache (full-MHA cache at
32k x 128 would be 5.5 TB bf16).

Selectable as ``--arch qwen1.5-32b``.
"""
from repro.models.config import ARCHS, smoke_config

NAME = "qwen1.5-32b"
CONFIG = ARCHS[NAME]
SMOKE = smoke_config(NAME)
