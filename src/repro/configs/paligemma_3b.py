"""[vlm] PaliGemma-3B (arXiv:2407.07726; hf).
18 layers, d_model=2048, 8 heads / 1 kv, head_dim 256, d_ff=16384,
vocab 257216.  SigLIP is a STUB: 256 precomputed patch embeddings are
prefixed to the text tokens; prefix-LM mask (bidirectional over the prefix).

Selectable as ``--arch paligemma-3b``.
"""
from repro.models.config import ARCHS, smoke_config

NAME = "paligemma-3b"
CONFIG = ARCHS[NAME]
SMOKE = smoke_config(NAME)
