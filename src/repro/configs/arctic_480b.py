"""[moe] Snowflake Arctic 480B (hf:Snowflake/snowflake-arctic-base; hf).
35 layers, d_model=7168, 56 heads / 8 kv, d_ff=4864, vocab 32000.
MoE: 128 experts top-2 PLUS a parallel dense residual MLP per layer.
Trains with Adafactor (AdamW moments for 480B exceed a 256-chip pod).

Selectable as ``--arch arctic-480b``.
"""
from repro.models.config import ARCHS, smoke_config

NAME = "arctic-480b"
CONFIG = ARCHS[NAME]
SMOKE = smoke_config(NAME)
