"""[moe] Llama-4-Scout-17B-16E (hf:meta-llama/Llama-4-Scout-17B-16E; unverified).
48 layers, d_model=5120, 40 heads / 8 kv, d_ff=8192, vocab 202048.
MoE: 16 experts top-1 + always-on shared expert.  Early-fusion modality
stub not exercised (assigned shapes are text-only).

Selectable as ``--arch llama4-scout-17b-a16e``.
"""
from repro.models.config import ARCHS, smoke_config

NAME = "llama4-scout-17b-a16e"
CONFIG = ARCHS[NAME]
SMOKE = smoke_config(NAME)
