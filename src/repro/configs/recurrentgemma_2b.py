"""[hybrid] RecurrentGemma-2B / Griffin (arXiv:2402.19427; hf).
26 layers in a (RG-LRU, RG-LRU, local-attn) 2:1 pattern, d_model=2560,
d_rnn=2560, 10 q heads / 1 kv head (MQA), head_dim 256, d_ff=7680,
vocab 256000, window 2048.  The RG-LRU gated recurrence is executed with the
equation-rewriting-derived parallel scan (repro.core.recurrence).

Selectable as ``--arch recurrentgemma-2b``.
"""
from repro.models.config import ARCHS, smoke_config

NAME = "recurrentgemma-2b"
CONFIG = ARCHS[NAME]
SMOKE = smoke_config(NAME)
