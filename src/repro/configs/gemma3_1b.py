"""[dense] Gemma-3-1B (hf:google/gemma-3-1b-pt; unverified).
26 layers, 5:1 local:global, window 1024, d_model=1152, 4 heads / 1 kv,
head_dim 256, d_ff=6912, vocab 262144, logit softcap 30.

Selectable as ``--arch gemma3-1b``.
"""
from repro.models.config import ARCHS, smoke_config

NAME = "gemma3-1b"
CONFIG = ARCHS[NAME]
SMOKE = smoke_config(NAME)
