"""Assigned input-shape set (identical for every LM-family arch).

``train_*``  lowers ``train_step``; ``prefill_*`` lowers the serving prefill;
``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of ``seq_len``).

``long_500k`` requires sub-quadratic attention: it runs only for archs whose
layers are recurrent / local-window dominated (see ``runs_cell``); pure
full-attention archs skip it (recorded per cell in EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig

__all__ = ["ShapeSpec", "SHAPES", "runs_cell", "skip_reason"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# archs with sub-quadratic sequence mixing (recurrent state and/or
# local-window-dominated attention) — the only ones long_500k runs for
_SUBQUADRATIC = {
    "recurrentgemma-2b",   # RG-LRU + 2048-window local attn
    "xlstm-350m",          # mLSTM/sLSTM state, O(1) per token
    "gemma3-12b",          # 5:1 local:global — local dominated
    "gemma3-1b",
}


def runs_cell(cfg: ModelConfig, shape: str) -> bool:
    if shape == "long_500k":
        return cfg.name in _SUBQUADRATIC
    return True


def skip_reason(cfg: ModelConfig, shape: str) -> str:
    if shape == "long_500k" and cfg.name not in _SUBQUADRATIC:
        if cfg.family == "audio":
            return "enc-dec over 30s audio frames; 500k-token decode is out of domain AND every layer is full attention"
        return "pure full-attention arch: 0.5M-token KV in every layer is the quadratic regime the assignment excludes"
    return ""
