"""[ssm] xLSTM-350M (arXiv:2405.04517; unverified).
24 layers in a 7:1 mLSTM:sLSTM pattern, d_model=1024, 4 state heads,
d_ff=0 (blocks own their pf=2 / pf=4/3 expansions), vocab 50304.
mLSTM trains chunkwise-parallel; sLSTM is inherently sequential (scan).

Selectable as ``--arch xlstm-350m``.
"""
from repro.models.config import ARCHS, smoke_config

NAME = "xlstm-350m"
CONFIG = ARCHS[NAME]
SMOKE = smoke_config(NAME)
