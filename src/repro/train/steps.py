"""Train / eval steps.

``make_train_step`` builds the jittable step:
  * next-token cross-entropy with label masking (-1) + z-loss + MoE aux
  * optional microbatch gradient accumulation (``lax.scan`` over chunks —
    the DP all-reduce stays off the critical path until the last chunk
    because XLA sees one summed gradient)
  * global-norm clipping, then the optimizer update (state mirrors params,
    so FSDP specs apply unchanged)
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..models.model import DistContext, Model
from ..optim.optimizers import Optimizer, clip_by_global_norm

__all__ = ["loss_fn", "make_train_step", "make_eval_step"]


def loss_fn(model: Model, params, batch, *, dist: Optional[DistContext] = None,
            z_loss: float = 1e-4, aux_weight: float = 1e-2):
    logits, aux = model.forward(params, batch, dist=dist)
    labels = batch["labels"]
    mask = labels >= 0
    lab = jnp.where(mask, labels, 0)
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(lg, lab[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * mask
    ntok = jnp.maximum(mask.sum(), 1)
    ce = nll.sum() / ntok
    zl = z_loss * ((lse * mask) ** 2).sum() / ntok
    total = ce + zl + aux_weight * aux
    return total, {"loss": total, "ce": ce, "z_loss": zl, "aux": aux,
                   "ntok": ntok}


def _split_batch(batch, micro_steps: int):
    def sp(x):
        B = x.shape[0]
        assert B % micro_steps == 0, (B, micro_steps)
        return x.reshape((micro_steps, B // micro_steps) + x.shape[1:])
    return jax.tree.map(sp, batch)


def make_train_step(model: Model, optimizer: Optimizer, *,
                    dist: Optional[DistContext] = None,
                    micro_steps: int = 1, clip_norm: float = 1.0,
                    cast_params: bool = True):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``cast_params``: cast f32 master weights to the model compute dtype
    *before* the forward pass, so FSDP weight all-gathers (and the matching
    gradient reductions) travel in bf16, not f32 — §Perf iteration 2
    (measured 2x on weight-collective wire bytes).  Masters stay f32; the
    bf16 cast's VJP accumulates the gradient back to f32.
    """
    import os
    if os.environ.get("REPRO_DISABLE_PERF_OPTS"):
        cast_params = False
    comp_dtype = model.dtype

    def _cast(p):
        if cast_params and p.dtype == jnp.float32 and p.ndim >= 2:
            return p.astype(comp_dtype)
        return p

    def grads_of(params, batch):
        def lf(p):
            return loss_fn(model, jax.tree.map(_cast, p), batch, dist=dist)
        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        return grads, metrics

    def step(params, opt_state, batch):
        if micro_steps == 1:
            grads, metrics = grads_of(params, batch)
        else:
            micro = _split_batch(batch, micro_steps)

            def body(acc, mb):
                g, m = grads_of(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return acc, m

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, ms = jax.lax.scan(body, zeros, micro)
            grads = jax.tree.map(lambda g: g / micro_steps, grads)
            metrics = jax.tree.map(lambda x: x[-1], ms)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        params, opt_state = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics, grad_norm=gnorm)
        return params, opt_state, metrics

    return step


def make_eval_step(model: Model, *, dist: Optional[DistContext] = None):
    def step(params, batch):
        _, metrics = loss_fn(model, params, batch, dist=dist)
        return metrics
    return step
