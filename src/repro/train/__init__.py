from .steps import loss_fn, make_train_step, make_eval_step
from .loop import Trainer, TrainConfig

__all__ = ["loss_fn", "make_train_step", "make_eval_step", "Trainer", "TrainConfig"]
