"""Training loop with fault tolerance & straggler mitigation.

* checkpoint every N steps (async save overlapped with compute), atomic
* ``resume="auto"``: restores the latest good checkpoint onto *whatever*
  mesh the current job built (elastic re-shard)
* step failures (including injected ones via ``failure_hook``) roll back to
  the last checkpoint instead of crashing the job
* straggler watchdog: trailing-median wall time; steps slower than
  ``straggler_factor`` × median raise a counted event (on real multi-slice
  deployments this feeds the rescheduler; here it is logged + tested)
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Optional

import jax
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..data.pipeline import SyntheticLM
from ..models.model import DistContext, Model
from ..models.sharding import batch_specs, dp_axes, param_specs
from ..optim.optimizers import Optimizer
from .steps import make_train_step

__all__ = ["TrainConfig", "Trainer"]


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    micro_steps: int = 1
    log_every: int = 10
    straggler_factor: float = 3.0
    resume: str = "auto"           # auto | none
    seed: int = 0


class Trainer:
    def __init__(self, model: Model, optimizer: Optimizer, data: SyntheticLM,
                 cfg: TrainConfig, *, mesh=None,
                 failure_hook: Optional[Callable[[int], bool]] = None):
        self.model = model
        self.optimizer = optimizer
        self.data = data
        self.cfg = cfg
        self.mesh = mesh
        self.failure_hook = failure_hook
        self.ckpt = CheckpointManager(cfg.ckpt_dir)
        self.straggler_events = 0
        self.recoveries = 0
        self._times: deque = deque(maxlen=32)

        dist = None
        if mesh is not None:
            dist = DistContext(mesh=mesh, dp_axes=dp_axes(mesh))
        self.dist = dist
        step_fn = make_train_step(model, optimizer, dist=dist,
                                  micro_steps=cfg.micro_steps)
        if mesh is not None:
            from jax.sharding import NamedSharding
            pspecs = param_specs(
                jax.eval_shape(model.init, jax.random.key(0)), mesh, model.cfg)
            self._pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
            self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
        else:
            self._pshard = None
            self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    # ---- state ------------------------------------------------------------
    def init_state(self):
        params = self.model.init(jax.random.key(self.cfg.seed))
        if self._pshard is not None:
            params = jax.device_put(params, self._pshard)
        opt_state = self.optimizer.init(params)
        return params, opt_state, 0

    def _restore(self, params, opt_state):
        manifest_step = self.ckpt.latest_step()
        if manifest_step is None:
            return params, opt_state, 0
        tree, manifest = self.ckpt.restore(
            {"params": params, "opt": opt_state},
            shardings={"params": self._pshard, "opt": None}
            if self._pshard is not None else None)
        return tree["params"], tree["opt"], int(manifest["step"])

    # ---- loop -------------------------------------------------------------
    def run(self) -> dict:
        params, opt_state, start = self.init_state()
        if self.cfg.resume == "auto":
            params, opt_state, start = self._restore(params, opt_state)
        step = start
        history = []
        while step < self.cfg.steps:
            batch_np = self.data.batch(step)
            batch = {"tokens": batch_np.tokens, "labels": batch_np.labels}
            if batch_np.extras:
                batch.update(batch_np.extras)
            t0 = time.perf_counter()
            try:
                if self.failure_hook and self.failure_hook(step):
                    raise RuntimeError(f"injected failure at step {step}")
                params, opt_state, metrics = self.step_fn(params, opt_state, batch)
                jax.block_until_ready(metrics["loss"])
            except Exception as e:  # noqa: BLE001 — step failure => recover
                self.recoveries += 1
                self.ckpt.wait()
                params, opt_state, step = None, None, None
                p, o, s = self.init_state()
                p, o, s = self._restore(p, o)
                params, opt_state, step = p, o, s
                print(f"[trainer] recovered from failure ({e}) -> step {step}")
                continue
            dt = time.perf_counter() - t0
            if len(self._times) >= 4:
                med = float(np.median(self._times))
                if dt > self.cfg.straggler_factor * med:
                    self.straggler_events += 1
                    print(f"[trainer] straggler: step {step} took {dt:.3f}s "
                          f"(median {med:.3f}s)")
            self._times.append(dt)
            step += 1
            loss = float(metrics["loss"])
            history.append(loss)
            if step % self.cfg.log_every == 0:
                print(f"[trainer] step {step} loss {loss:.4f} ({dt*1e3:.0f} ms)")
            if step % self.cfg.ckpt_every == 0:
                self.ckpt.save_async({"params": params, "opt": opt_state}, step)
        self.ckpt.wait()
        self.ckpt.save({"params": params, "opt": opt_state}, step)
        return {"history": history, "final_step": step,
                "straggler_events": self.straggler_events,
                "recoveries": self.recoveries}
