"""Version compatibility shims for the range of JAX builds we run on.

``shard_map`` moved from ``jax.experimental.shard_map`` to the ``jax``
top level, and its ``check_rep`` kwarg was renamed ``check_vma`` along the
way.  All repro code imports it from here and uses the *new* spelling;
this shim adapts downward for older builds.
"""
from __future__ import annotations

import functools

try:  # new API (jax >= 0.6): top-level export, check_vma kwarg
    from jax import shard_map as _shard_map

    _CHECK_KWARG = "check_vma"
except ImportError:  # older builds: experimental module, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KWARG = "check_rep"

try:  # jax.enable_x64 context manager is jax.experimental.enable_x64 on old builds
    import jax as _jax

    enable_x64 = _jax.enable_x64
except AttributeError:
    from jax.experimental import enable_x64

try:  # pltpu.CompilerParams was TPUCompilerParams on older builds
    from jax.experimental.pallas import tpu as _pltpu

    CompilerParams = getattr(_pltpu, "CompilerParams", None) or _pltpu.TPUCompilerParams
except ImportError:  # pragma: no cover - pallas always present in this image
    CompilerParams = None

__all__ = ["shard_map", "CompilerParams", "enable_x64"]


@functools.wraps(_shard_map)
def shard_map(f=None, *, mesh, in_specs, out_specs, check_vma=None, **kw):
    if check_vma is not None:
        kw[_CHECK_KWARG] = check_vma
    if f is None:  # support partial application, mirroring jax.shard_map
        return lambda g: _shard_map(
            g, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
