"""Unified model configuration covering all 10 assigned architectures.

Every config is exactly the assigned spec (see the per-arch files in
``repro.configs`` for provenance).  ``block_pattern`` is cycled over
``num_layers``; parameters of full pattern repetitions are stacked and
executed with ``lax.scan`` (compile time O(pattern), not O(depth)).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ARCHS", "get_config"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    block_pattern: Tuple[str, ...] = ("attn",)
    head_dim: Optional[int] = None          # default d_model // n_heads
    # attention
    window: int = 1024                      # sliding window for attn_local
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # MoE
    n_experts: int = 0
    top_k: int = 1
    capacity_factor: float = 1.25
    moe_dense_residual: bool = False        # arctic: dense MLP in parallel
    shared_expert: bool = False             # llama4: always-on shared expert
    # recurrent / ssm
    d_rnn: Optional[int] = None             # RG-LRU width (recurrentgemma)
    conv_width: int = 4
    n_state_heads: int = 4                  # xLSTM heads
    # families with special topology
    encoder_layers: int = 0                 # whisper: encoder depth
    prefix_len: int = 0                     # paligemma: image patch prefix
    tied_embeddings: bool = True
    # numerics / serving
    dtype: str = "bfloat16"
    kv_cache_dtype: str = "bfloat16"        # int8 for qwen decode_32k
    logit_softcap: float = 0.0

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def vocab_pad(self) -> int:
        """Vocab padded to a multiple of 256 so the embedding shards over any
        mesh axis (granite 49155→49408, whisper 51865→51968; labels never
        index the pad slots)."""
        return -(-self.vocab_size // 256) * 256

    def kinds(self) -> Tuple[str, ...]:
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    def params_B(self) -> float:
        """Approximate parameter count (billions) — dense part + experts."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        hq, hkv, hd = self.n_heads, self.n_kv_heads, self.hd
        attn = D * hq * hd * 2 + D * hkv * hd * 2
        mlp = 3 * D * F
        per_layer = 0.0
        for kind in self.kinds():
            if kind in ("attn", "attn_local", "attn_bidir"):
                per_layer += attn + (mlp if self.n_experts == 0 else 0)
            elif kind == "rec":
                dr = self.d_rnn or D
                per_layer += 2 * D * dr + dr * D + 4 * dr + (3 * D * F)
            elif kind in ("mlstm", "slstm"):
                per_layer += 8 * D * D
            if self.n_experts and kind.startswith("attn"):
                per_layer += self.n_experts * 3 * D * F
                if self.moe_dense_residual or self.shared_expert:
                    per_layer += 3 * D * F
        embed = V * D * (1 if self.tied_embeddings else 2)
        enc = self.encoder_layers * (attn * 2 + mlp)
        return (per_layer * 1 + embed + enc) / 1e9 * (1.0)

    def active_params_B(self) -> float:
        """Active per-token params (MoE: top_k experts only) for 6ND."""
        if not self.n_experts:
            return self.params_B()
        D, F = self.d_model, self.d_ff
        total = self.params_B()
        inactive = (self.n_experts - self.top_k) * 3 * D * F * self.num_layers
        return total - inactive / 1e9


def _g():  # local:global 5:1 (gemma3)
    return ("attn_local",) * 5 + ("attn",)


ARCHS = {
    # [audio] enc-dec; conv frontend stubbed (precomputed frame embeddings)
    "whisper-medium": ModelConfig(
        name="whisper-medium", family="audio", num_layers=24, d_model=1024,
        n_heads=16, n_kv_heads=16, d_ff=4096, vocab_size=51865,
        block_pattern=("attn",), encoder_layers=24, tied_embeddings=True,
    ),
    # [hybrid] Griffin: 2 RG-LRU blocks : 1 local-attn block
    "recurrentgemma-2b": ModelConfig(
        name="recurrentgemma-2b", family="hybrid", num_layers=26, d_model=2560,
        n_heads=10, n_kv_heads=1, d_ff=7680, vocab_size=256_000,
        block_pattern=("rec", "rec", "attn_local"), d_rnn=2560, window=2048,
        head_dim=256,
    ),
    # [dense] 5:1 local:global, 128k context
    "gemma3-12b": ModelConfig(
        name="gemma3-12b", family="dense", num_layers=48, d_model=3840,
        n_heads=16, n_kv_heads=8, d_ff=15360, vocab_size=262_144,
        block_pattern=_g(), window=1024, logit_softcap=30.0,
    ),
    "gemma3-1b": ModelConfig(
        name="gemma3-1b", family="dense", num_layers=26, d_model=1152,
        n_heads=4, n_kv_heads=1, d_ff=6912, vocab_size=262_144,
        block_pattern=_g(), window=1024, head_dim=256, logit_softcap=30.0,
    ),
    # [dense] GQA
    "granite-3-8b": ModelConfig(
        name="granite-3-8b", family="dense", num_layers=40, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=12800, vocab_size=49_155,
    ),
    # [dense] full MHA with QKV bias
    "qwen1.5-32b": ModelConfig(
        name="qwen1.5-32b", family="dense", num_layers=64, d_model=5120,
        n_heads=40, n_kv_heads=40, d_ff=27392, vocab_size=152_064,
        qkv_bias=True, kv_cache_dtype="int8",
    ),
    # [vlm] SigLIP stub prefix + gemma-style decoder, prefix-LM mask
    "paligemma-3b": ModelConfig(
        name="paligemma-3b", family="vlm", num_layers=18, d_model=2048,
        n_heads=8, n_kv_heads=1, d_ff=16384, vocab_size=257_216,
        prefix_len=256, head_dim=256,
    ),
    # [ssm] xLSTM 7:1 mLSTM:sLSTM
    "xlstm-350m": ModelConfig(
        name="xlstm-350m", family="ssm", num_layers=24, d_model=1024,
        n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=50_304,
        block_pattern=("mlstm",) * 7 + ("slstm",), n_state_heads=4,
    ),
    # [moe] 16 experts top-1 + shared expert
    "llama4-scout-17b-a16e": ModelConfig(
        name="llama4-scout-17b-a16e", family="moe", num_layers=48, d_model=5120,
        n_heads=40, n_kv_heads=8, d_ff=8192, vocab_size=202_048,
        n_experts=16, top_k=1, shared_expert=True,
    ),
    # [moe] 128 experts top-2 + dense residual
    "arctic-480b": ModelConfig(
        name="arctic-480b", family="moe", num_layers=35, d_model=7168,
        n_heads=56, n_kv_heads=8, d_ff=4864, vocab_size=32_000,
        n_experts=128, top_k=2, moe_dense_residual=True,
    ),
}


def get_config(name: str) -> ModelConfig:
    return ARCHS[name]


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: small widths, few
    layers/experts, tiny vocab — structure preserved."""
    c = ARCHS[name]
    pat = c.block_pattern
    nl = max(len(pat), 2)
    return dataclasses.replace(
        c,
        num_layers=nl if nl % len(pat) == 0 else len(pat),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(c.n_kv_heads, 2) if c.n_kv_heads > 1 else 1,
        d_ff=128 if c.d_ff else 0,
        head_dim=16,
        vocab_size=256,
        n_experts=min(c.n_experts, 4) if c.n_experts else 0,
        d_rnn=64 if c.d_rnn else None,
        encoder_layers=2 if c.encoder_layers else 0,
        prefix_len=4 if c.prefix_len else 0,
        window=8,
        dtype="float32",
    )
