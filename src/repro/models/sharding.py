"""Sharding rules: param / batch / cache PartitionSpecs for any mesh.

Logical axes:
  ``dp``    batch        -> ("pod","data") on the multi-pod mesh, else "data"
  ``fsdp``  param shards -> "data"  (ZeRO-3; pod-replicated so the gradient
                            all-reduce is the only cross-pod collective)
  ``tp``    tensor       -> "model" (Megatron: heads / d_ff / vocab)
  ``ep``    experts      -> "model"

Dims are sharded **only when divisible** by the mesh axis size; otherwise the
dim is replicated (e.g. qwen's 40 heads on model=16 → attention projections
stay fsdp-only and TP lives in d_ff/vocab).  This keeps every (arch × mesh)
cell compilable without per-arch special cases.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .config import ModelConfig

__all__ = [
    "ShardingPolicy", "POLICIES", "dp_axes", "axis_size", "param_specs",
    "batch_specs", "cache_specs", "shard_params", "opt_state_specs",
]

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Logical->mesh axis mapping.

    ``2d`` (default): batch over data, FSDP over data, TP/EP over model —
    the Megatron+ZeRO hybrid.
    ``fsdp_only``: batch AND parameters sharded over (data, model) jointly —
    pure ZeRO-3.  No tensor parallelism, so the per-sublayer Megatron
    all-reduces disappear; the only collectives are per-layer weight
    all-gathers + gradient reduce-scatter (Perf iteration 4: on
    gemma3-12b train_4k this cut the collective term ~5x).  Requires
    global_batch % 256 == 0; MoE archs keep ``2d`` (experts need the model
    axis for EP).
    """
    name: str = "2d"
    fsdp: tuple = ("data",)
    tp: str | None = "model"
    dp: tuple = ("data",)


POLICIES = {
    "2d": ShardingPolicy(),
    "fsdp_only": ShardingPolicy(name="fsdp_only", fsdp=("data", "model"),
                                tp=None, dp=("data", "model")),
}


def dp_axes(mesh: Mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        return int(np.prod([axis_size(mesh, n) for n in name]))
    return int(mesh.shape[name]) if name in mesh.axis_names else 1


def _div(dim: int, mesh: Mesh, ax) -> bool:
    return dim % axis_size(mesh, ax) == 0 and axis_size(mesh, ax) > 1


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _rule(ps: str, shape: tuple, mesh: Mesh, cfg: ModelConfig,
          policy: "ShardingPolicy" = None) -> P:
    """Spec for one param given its path string and (unstacked) shape."""
    policy = policy or POLICIES["2d"]
    fsdp = policy.fsdp if len(policy.fsdp) > 1 else policy.fsdp[0]
    tp = policy.tp

    def ax(dim_size, name):
        if name is None:
            return None
        return name if _div(dim_size, mesh, name) else None

    # embeddings: (V_pad, D)
    if ps.endswith("embed/tok") or ps.endswith("embed/out"):
        return P(ax(shape[0], tp), ax(shape[1], fsdp))
    if "patch_proj" in ps:
        return P(ax(shape[0], fsdp), ax(shape[1], tp))
    # MoE stacked experts: (E, D, F) / (E, F, D)
    if any(ps.endswith(f"ffn/{w}") for w in ("wi", "wg", "wo")) and len(shape) == 3:
        return P(ax(shape[0], tp), ax(shape[1], fsdp), None)
    if "router" in ps:
        return P(ax(shape[0], fsdp), None)
    # attention projections
    if any(f"/{n}/w" in ps for n in ("q", "k", "v")) and len(shape) == 3:
        return P(ax(shape[0], fsdp), ax(shape[1], tp), None)
    if any(f"/{n}/b" in ps for n in ("q", "k", "v")) and len(shape) == 2:
        return P(ax(shape[0], tp), None)
    if "/o/w" in ps:
        return P(ax(shape[0], tp), ax(shape[1], fsdp))
    # MLP
    if any(ps.endswith(f"/{n}/w") for n in ("wi", "wg")) and len(shape) == 2:
        return P(ax(shape[0], fsdp), ax(shape[1], tp))
    if ps.endswith("/wo/w") and len(shape) == 2:
        return P(ax(shape[0], tp), ax(shape[1], fsdp))
    # RG-LRU / LSTM / conv / misc dense (D_in, D_out)
    if len(shape) == 2 and shape[0] >= 128 and shape[1] >= 128:
        return P(ax(shape[0], fsdp), ax(shape[1], tp))
    if len(shape) == 3 and min(shape[1], shape[2]) >= 128:   # (H, dh, dh) blocks
        # tiny per-head recurrent weights used *inside* lax.scan: replicate —
        # sharding them forces an all-gather every timestep (measured: the
        # dominant collective term on xlstm before this rule)
        if int(np.prod(shape)) * 4 <= 16 * 2**20:
            return P(None, None, None)
        return P(None, ax(shape[1], fsdp), ax(shape[2], tp))
    if len(shape) == 1 and shape[0] >= 1024:
        return P(ax(shape[0], tp))
    return P(*([None] * len(shape)))


def param_specs(params: Any, mesh: Mesh, cfg: ModelConfig,
                policy: "ShardingPolicy" = None):
    """PartitionSpec pytree matching ``params`` (stacked blocks get a leading
    None for the reps axis)."""

    def one(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        stacked = ps.startswith("blocks/") or "/blocks/" in ps
        if stacked:
            spec = _rule(ps, shape[1:], mesh, cfg, policy)
            return P(None, *spec)
        return _rule(ps, shape, mesh, cfg, policy)

    return jax.tree_util.tree_map_with_path(one, params)


def shard_params(params, mesh: Mesh, cfg: ModelConfig,
                 policy: "ShardingPolicy" = None):
    specs = param_specs(params, mesh, cfg, policy)
    return jax.device_put(
        params, jax.tree.map(lambda s: NamedSharding(mesh, s), specs))


def batch_specs(mesh: Mesh, batch_shape: dict) -> dict:
    """Input specs: batch dim over dp when divisible, else replicated."""
    dp = dp_axes(mesh)
    ndp = axis_size(mesh, dp)

    def one(leaf):
        B = leaf.shape[0] if leaf.shape else 1
        if B % ndp == 0 and B >= ndp:
            return P(dp if len(dp) > 1 else dp[0], *([None] * (len(leaf.shape) - 1)))
        return P(*([None] * len(leaf.shape)))

    return jax.tree.map(one, batch_shape)


def cache_specs(cache: Any, mesh: Mesh, cfg: ModelConfig):
    """KV caches: batch over dp when divisible; otherwise (long-context,
    batch=1) the sequence dim is sharded over (data, model) — sequence
    parallelism for decode.  Recurrent state: batch over dp, feature over
    model when divisible."""
    dp = dp_axes(mesh)
    ndp = axis_size(mesh, dp)
    dp_name = dp if len(dp) > 1 else dp[0]

    def one(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        stacked = "blocks/" in ps
        core = shape[1:] if stacked else shape
        if ps.endswith("idx") or not core:
            return P(*([None] * len(shape)))
        B = core[0]
        spec: list = [None] * len(core)
        if B % ndp == 0 and B >= ndp:
            spec[0] = dp_name
            if len(core) == 4 and _div(core[1], mesh, "model"):      # (B,S,H,hd)
                spec[1] = "model"
            elif len(core) >= 2 and _div(core[-1], mesh, "model"):
                spec[-1] = "model"
        else:
            # batch too small: shard the biggest dim over everything divisible
            if len(core) == 4:                                        # (B,S,H,hd)
                both = tuple(dp) + ("model",)
                if core[1] % axis_size(mesh, both) == 0:
                    spec[1] = both
                elif _div(core[1], mesh, "data"):
                    spec[1] = "data"
            elif len(core) >= 2 and _div(core[-1], mesh, "model"):
                spec[-1] = "model"
        if stacked:
            spec = [None] + spec
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, cache)


def opt_state_specs(param_spec_tree, opt_state):
    """Optimizer moments share their param's spec; scalars replicated."""

    def match(spec, leaf):
        if leaf.ndim == len(spec):
            return spec
        return P(*([None] * leaf.ndim))

    import jax.tree_util as jtu

    flat_specs = jtu.tree_leaves(param_spec_tree)

    # opt states are pytrees whose array leaves mirror params in order where
    # shaped like them; fall back to replication otherwise.
    def one_state(state_tree, specs):
        leaves, treedef = jtu.tree_flatten(state_tree)
        out = []
        for l in leaves:
            cand = None
            for s in specs:
                if len(s) == l.ndim:
                    cand = s
                    break
            out.append(cand if cand is not None else P(*([None] * l.ndim)))
        return jtu.tree_unflatten(treedef, out)

    return one_state(opt_state, flat_specs)
