"""Mixture-of-Experts with expert parallelism (EP).

Two execution paths sharing one sort-based dispatch (no S×E×C one-hot —
token→capacity-slot packing is computed with an argsort + cummax-free
position-in-run trick, so the dispatch buffers are O(E·C·D)):

* ``ep_shard_map``: production path.  Experts are sharded over the ``model``
  mesh axis; tokens are exchanged with two ``all_to_all``s (dispatch +
  return).  Expert weights arrive FSDP-sharded over ``data`` and are
  all-gathered inside the block (the per-layer FSDP gather).
* ``dense local``: no-mesh fallback used by CPU smoke tests — identical
  math, no collectives.

Router: softmax top-k with load-balance auxiliary loss (Switch-style).
llama4-scout adds a shared (always-on) expert; arctic adds a parallel dense
residual MLP — both are plain MLPs applied outside the EP region.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map

from .config import ModelConfig
from .layers import dense, init_dense, init_mlp, init_rms_norm, mlp_apply, rms_norm

__all__ = ["init_moe", "moe_apply"]


def init_moe(key, cfg: ModelConfig) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 6)
    p = {
        "ln": init_rms_norm(D),
        "router": init_dense(ks[0], D, E),
        "wi": jax.random.normal(ks[1], (E, D, F), jnp.float32) * D ** -0.5,
        "wg": jax.random.normal(ks[2], (E, D, F), jnp.float32) * D ** -0.5,
        "wo": jax.random.normal(ks[3], (E, F, D), jnp.float32) * F ** -0.5,
    }
    if cfg.shared_expert:
        p["shared"] = init_mlp(ks[4], cfg)
    if cfg.moe_dense_residual:
        p["dense_mlp"] = init_mlp(ks[5], cfg)
    return p


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    c = int(np.ceil(tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)


def _dispatch_indices(eid: jnp.ndarray, capacity: int):
    """eid: (N,) expert id per (token, choice).  Returns (slot, kept):
    slot[n] in [0, capacity] — capacity == dropped (overflow) sentinel."""
    N = eid.shape[0]
    order = jnp.argsort(eid)                       # stable
    se = eid[order]
    first = jnp.searchsorted(se, se, side="left")  # start of each run
    pos = jnp.arange(N) - first                    # position within expert run
    slot_sorted = jnp.where(pos < capacity, pos, capacity)
    slot = jnp.zeros((N,), jnp.int32).at[order].set(slot_sorted.astype(jnp.int32))
    return slot


def _expert_ffn(x: jnp.ndarray, wi, wg, wo, dtype) -> jnp.ndarray:
    """x: (E, C, D) -> (E, C, D); batched over experts (feeds the MXU)."""
    h = jnp.einsum("ecd,edf->ecf", x, wg.astype(dtype))
    u = jnp.einsum("ecd,edf->ecf", x, wi.astype(dtype))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, wo.astype(dtype))


def _moe_local(params, cfg: ModelConfig, x2: jnp.ndarray):
    """Dense fallback: x2 (T, D) local tokens, full expert weights."""
    T, D = x2.shape
    E, k = cfg.n_experts, cfg.top_k
    C = _capacity(T, cfg)
    dt = x2.dtype
    logits = dense(params["router"], x2).astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eid = jax.lax.top_k(probs, k)                         # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    eflat = eid.reshape(-1)                                     # (T*k,)
    slot = _dispatch_indices(eflat, C)                          # (T*k,)
    src = jnp.repeat(jnp.arange(T), k)
    buf = jnp.zeros((E, C + 1, D), dt).at[eflat, slot].set(x2[src])
    y_buf = _expert_ffn(buf[:, :C], params["wi"], params["wg"], params["wo"], dt)
    y_buf = jnp.concatenate([y_buf, jnp.zeros((E, 1, D), dt)], axis=1)
    y = y_buf[eflat, slot] * gate.reshape(-1)[:, None].astype(dt)
    y = jnp.zeros((T, D), dt).at[src].add(y)

    # Switch aux loss: E * sum_e f_e * p_e
    me = probs.mean(0)
    ce = jnp.zeros((E,), jnp.float32).at[eflat].add(1.0) / (T * k)
    aux = E * jnp.sum(me * ce)
    return y, aux


def moe_apply(
    params: dict,
    cfg: ModelConfig,
    x: jnp.ndarray,                     # (B, S, D)
    *,
    mesh: Optional[Mesh] = None,
    dp_axes: tuple = ("data",),
    ep_axis: str = "model",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (x + moe_out [+ shared/dense residual], aux_loss)."""
    B, S, D = x.shape
    h = rms_norm(params["ln"], x)

    if mesh is None or ep_axis not in mesh.axis_names:
        y, aux = _moe_local(params, cfg, h.reshape(B * S, D))
        y = y.reshape(B, S, D)
    else:
        E = cfg.n_experts
        ep = int(mesh.shape[ep_axis])
        fsdp = "data" if "data" in mesh.axis_names else dp_axes[0]
        dp_spec = P(dp_axes if len(dp_axes) > 1 else dp_axes[0], None, None)
        w_spec = P(ep_axis, fsdp, None)
        ndp = int(np.prod([mesh.shape[a] for a in dp_axes]))
        T_loc = (B // ndp) * S
        C = _capacity(T_loc, cfg)

        @partial(
            shard_map, mesh=mesh,
            in_specs=(dp_spec, P(None, None), w_spec, w_spec, w_spec),
            out_specs=(dp_spec, P()),
            check_vma=False,
        )
        def ep_block(hl, router_w, wi, wg, wo):
            dt = hl.dtype
            Bl, Sl, _ = hl.shape
            x2 = hl.reshape(Bl * Sl, D)
            T = Bl * Sl
            k = cfg.top_k
            # FSDP all-gather of this layer's expert shards (over data axis)
            wi = jax.lax.all_gather(wi, fsdp, axis=1, tiled=True)
            wg = jax.lax.all_gather(wg, fsdp, axis=1, tiled=True)
            wo = jax.lax.all_gather(wo, fsdp, axis=1, tiled=True)

            logits = (x2 @ router_w.astype(dt)).astype(jnp.float32)
            probs = jax.nn.softmax(logits, axis=-1)
            gate, eid = jax.lax.top_k(probs, k)
            gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
            eflat = eid.reshape(-1)
            slot = _dispatch_indices(eflat, C)
            src = jnp.repeat(jnp.arange(T), k)
            buf = jnp.zeros((E, C + 1, D), dt).at[eflat, slot].set(x2[src])
            buf = buf[:, :C]                                   # (E, C, D)
            # dispatch: split experts across EP peers, collect their tokens
            recv = jax.lax.all_to_all(buf, ep_axis, split_axis=0,
                                      concat_axis=1, tiled=True)
            y_loc = _expert_ffn(recv, wi, wg, wo, dt)          # (E/ep, ep*C, D)
            back = jax.lax.all_to_all(y_loc, ep_axis, split_axis=1,
                                      concat_axis=0, tiled=True)
            back = jnp.concatenate([back, jnp.zeros((E, 1, D), dt)], axis=1)
            y = back[eflat, slot] * gate.reshape(-1)[:, None].astype(dt)
            y = jnp.zeros((T, D), dt).at[src].add(y).reshape(Bl, Sl, D)

            me = probs.mean(0)
            ce = jnp.zeros((E,), jnp.float32).at[eflat].add(1.0) / (T * k)
            aux = E * jnp.sum(me * ce)
            aux = jax.lax.pmean(aux, dp_axes)
            aux = jax.lax.pmean(aux, ep_axis)   # identical on all; keep replicated
            return y, aux

        y, aux = ep_block(h, params["router"]["w"], params["wi"],
                          params["wg"], params["wo"])

    out = x + y
    if "shared" in params:          # llama4: always-on shared expert
        out = out + mlp_apply(params["shared"], h, residual=False)
    if "dense_mlp" in params:       # arctic: parallel dense residual MLP
        out = out + mlp_apply(params["dense_mlp"], h, residual=False)
    return out, aux
