"""Model assembly for all 10 assigned architectures.

Layer stacks execute as ``lax.scan`` over *pattern repetitions* (compile time
O(len(pattern)), not O(depth)); the `num_layers % len(pattern)` remainder
runs as individually-traced tail blocks.  Params / KV caches for scanned
blocks carry a leading ``reps`` axis.

Three entry points (all pure functions, jit/pjit-able):
  ``forward(params, cfg, batch, ...)``      -> (logits, aux)        training
  ``prefill(params, cfg, batch, ...)``      -> (last_logits, cache) serving
  ``decode_step(params, cfg, tok, cache)``  -> (logits, cache)      serving
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import runtime_flags
from .config import ModelConfig
from .layers import (
    attention_apply, attention_decode, apply_rope, decode_attention, dense,
    embed_apply, flash_attention, init_attention, init_embedding, init_mlp,
    init_rms_norm, mlp_apply, rms_norm, sinusoidal_positions, unembed_apply,
    RopeSpec,
)
from .moe import init_moe, moe_apply
from .recurrent import (
    init_mlstm_block, init_rglru_block, init_slstm_block,
    mlstm_block_apply, mlstm_block_decode, rglru_block_apply,
    rglru_block_decode, slstm_block_apply, slstm_block_decode,
)

__all__ = ["Model", "DistContext"]


@dataclasses.dataclass(frozen=True)
class DistContext:
    """Mesh context threaded to layers that open shard_map regions (MoE-EP)
    and to the activation sharding constraints."""
    mesh: Any = None
    dp_axes: tuple = ("data",)
    ep_axis: str = "model"

    def constrain(self, x):
        import os
        if os.environ.get("REPRO_DISABLE_PERF_OPTS"):
            return x
        """Pin activations to (batch over dp, replicated elsewhere).  Without
        this XLA may resolve an FSDP-sharded weight contraction by
        all-reducing the full activation instead of all-gathering the weight
        (measured: 77 s collective term on gemma3-12b train_4k - Perf it.1)."""
        if self.mesh is None:
            return x
        from jax.sharding import NamedSharding, PartitionSpec as P
        dp = self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]
        spec = P(dp, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))


# --------------------------------------------------------------------------
# Block init / apply / decode dispatch
# --------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, kind: str, *, cross: bool = False) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p: dict = {}
    if kind.startswith("attn"):
        p["mix"] = init_attention(k1, cfg)
        if cfg.n_experts:
            p["ffn"] = init_moe(k2, cfg)
        elif cfg.d_ff:
            p["ffn"] = init_mlp(k2, cfg)
    elif kind == "rec":
        p["mix"] = init_rglru_block(k1, cfg)
        if cfg.d_ff:
            p["ffn"] = init_mlp(k2, cfg)
    elif kind == "mlstm":
        p["mix"] = init_mlstm_block(k1, cfg)
    elif kind == "slstm":
        p["mix"] = init_slstm_block(k1, cfg)
    else:  # pragma: no cover
        raise ValueError(kind)
    if cross:
        p["xattn"] = init_attention(k3, cfg, cross=True)
    return p


def _mask_kind(cfg: ModelConfig, kind: str) -> tuple[str, int]:
    if kind == "attn_bidir":
        return "full", 0
    if kind == "attn_local":
        return "window", 0
    # full-attention layer: vlm uses prefix-LM mask
    if cfg.family == "vlm" and cfg.prefix_len:
        return "prefix", cfg.prefix_len
    return "causal", 0


def _apply_block(params, cfg: ModelConfig, kind: str, x, positions, *,
                 enc_out=None, dist: Optional[DistContext] = None,
                 rope: bool = True):
    aux = jnp.zeros((), jnp.float32)
    if kind.startswith("attn"):
        mk, plen = _mask_kind(cfg, kind)
        x = attention_apply(params["mix"], cfg, x, positions, kind=mk,
                            rope=rope, prefix_len=plen)
        if "xattn" in params:
            x = attention_apply(params["xattn"], cfg, x, positions,
                                kind="full", kv_src=enc_out, rope=False)
        if "ffn" in params:
            if cfg.n_experts:
                x, aux = moe_apply(
                    params["ffn"], cfg, x,
                    mesh=dist.mesh if dist else None,
                    dp_axes=dist.dp_axes if dist else ("data",),
                    ep_axis=dist.ep_axis if dist else "model")
            else:
                x = mlp_apply(params["ffn"], x)
    elif kind == "rec":
        x = rglru_block_apply(params["mix"], cfg, x)
        if "ffn" in params:
            x = mlp_apply(params["ffn"], x)
    elif kind == "mlstm":
        x = mlstm_block_apply(params["mix"], cfg, x)
    elif kind == "slstm":
        x = slstm_block_apply(params["mix"], cfg, x)
    return x, aux


def _decode_block(params, cfg: ModelConfig, kind: str, x, cache, idx, *,
                  enc_out=None, rope: bool = True):
    if kind.startswith("attn"):
        local = kind == "attn_local"
        x, cache_a = attention_decode(params["mix"], cfg, x, cache["attn"],
                                      idx, local=local, rope=rope)
        cache = dict(cache, attn=cache_a)
        if "xattn" in params:
            x, _ = attention_decode(params["xattn"], cfg, x, {}, idx,
                                    enc_out=enc_out)
        if "ffn" in params:
            if cfg.n_experts:
                x, _ = moe_apply(params["ffn"], cfg, x, mesh=None)
            else:
                x = mlp_apply(params["ffn"], x)
    elif kind == "rec":
        x, cache_r = rglru_block_decode(params["mix"], cfg, x, cache["rec"])
        cache = dict(cache, rec=cache_r)
        if "ffn" in params:
            x = mlp_apply(params["ffn"], x)
    elif kind == "mlstm":
        x, cache_m = mlstm_block_decode(params["mix"], cfg, x, cache["mlstm"])
        cache = dict(cache, mlstm=cache_m)
    elif kind == "slstm":
        x, cache_s = slstm_block_decode(params["mix"], cfg, x, cache["slstm"])
        cache = dict(cache, slstm=cache_s)
    return x, cache


def _init_block_cache(cfg: ModelConfig, kind: str, B: int, s_cache: int,
                      kv_dtype) -> dict:
    """Empty per-layer cache.  Local-attn layers get a ring buffer of exactly
    min(window, s_cache); recurrent layers O(1) state."""
    hd, Hkv = cfg.hd, cfg.n_kv_heads
    c: dict = {}
    if kind.startswith("attn"):
        S = min(cfg.window, s_cache) if kind == "attn_local" else s_cache
        kv = {
            "k": jnp.zeros((B, S, Hkv, hd), kv_dtype),
            "v": jnp.zeros((B, S, Hkv, hd), kv_dtype),
        }
        if kv_dtype == jnp.int8:
            kv["scale"] = jnp.zeros((B, S, Hkv, 2), jnp.float32)
        c["attn"] = kv
    elif kind == "rec":
        R = cfg.d_rnn or cfg.d_model
        c["rec"] = {"h": jnp.zeros((B, R), jnp.float32),
                    "conv": jnp.zeros((B, cfg.conv_width - 1, R), jnp.bfloat16)}
    elif kind == "mlstm":
        H = cfg.n_state_heads
        d = 2 * cfg.d_model // H
        c["mlstm"] = {
            "C": jnp.zeros((B, H, d, d), jnp.float32),
            "n": jnp.zeros((B, H, d), jnp.float32),
            "m": jnp.full((B, H), -1e30, jnp.float32),
            "conv": jnp.zeros((B, cfg.conv_width - 1, 2 * cfg.d_model), jnp.bfloat16),
        }
    elif kind == "slstm":
        D = cfg.d_model
        c["slstm"] = {
            "c": jnp.zeros((B, D), jnp.float32),
            "n": jnp.zeros((B, D), jnp.float32),
            "m": jnp.full((B, D), -1e30, jnp.float32),
            "h": jnp.zeros((B, D), jnp.float32),
            "conv": jnp.zeros((B, cfg.conv_width - 1, D), jnp.bfloat16),
        }
    return c


# --------------------------------------------------------------------------
# Model
# --------------------------------------------------------------------------
class Model:
    """Stateless assembly bound to a ModelConfig."""

    def __init__(self, cfg: ModelConfig, *, remat: bool = True):
        self.cfg = cfg
        self.remat = remat
        kinds = cfg.kinds()
        P = len(cfg.block_pattern)
        self.reps = cfg.num_layers // P
        self.tail_kinds = kinds[self.reps * P:]
        self.pattern = cfg.block_pattern
        self.dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        self.use_rope = cfg.family != "audio"
        self.cross = cfg.family == "audio"    # whisper decoder blocks

    # ---- init -------------------------------------------------------------
    def init(self, key) -> dict:
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        params: dict = {"embed": init_embedding(keys[0], cfg),
                        "final_ln": init_rms_norm(cfg.d_model)}
        # scanned blocks: dict pos -> stacked params over reps
        blocks = {}
        for pos, kind in enumerate(self.pattern):
            ks = jax.random.split(jax.random.fold_in(keys[1], pos), max(self.reps, 1))
            if self.reps:
                blocks[f"p{pos}"] = jax.vmap(
                    lambda k: _init_block(k, cfg, kind, cross=self.cross)
                )(ks)
        params["blocks"] = blocks
        params["tail"] = [
            _init_block(jax.random.fold_in(keys[2], j), cfg, kind, cross=self.cross)
            for j, kind in enumerate(self.tail_kinds)
        ]
        if cfg.family == "audio":
            enc_blocks = {}
            ks = jax.random.split(keys[3], cfg.encoder_layers)
            enc_blocks["p0"] = jax.vmap(
                lambda k: _init_block(k, cfg, "attn_bidir")
            )(ks)
            params["encoder"] = {"blocks": enc_blocks,
                                 "ln": init_rms_norm(cfg.d_model)}
        if cfg.family == "vlm":
            # frontend stub: projection of precomputed patch embeddings
            params["patch_proj"] = {"w": jax.random.normal(
                keys[4], (cfg.d_model, cfg.d_model), jnp.float32) * cfg.d_model ** -0.5}
        return params

    # ---- shared stack runner ------------------------------------------------
    def _run_stack(self, params, x, positions, *, enc_out=None, dist=None):
        cfg = self.cfg
        aux0 = jnp.zeros((), jnp.float32)

        def rep_body(carry, block_params):
            x, aux = carry
            for pos, kind in enumerate(self.pattern):
                x, a = _apply_block(block_params[f"p{pos}"], cfg, kind, x,
                                    positions, enc_out=enc_out, dist=dist,
                                    rope=self.use_rope)
                if dist is not None:
                    x = dist.constrain(x)       # §Perf it.1
                aux = aux + a
            return (x, aux), ()

        body = jax.checkpoint(rep_body) if self.remat else rep_body
        if self.reps:
            (x, aux), _ = jax.lax.scan(body, (x, aux0), params["blocks"],
                                       unroll=runtime_flags.scan_unroll())
        else:
            aux = aux0
        for j, kind in enumerate(self.tail_kinds):
            x, a = _apply_block(params["tail"][j], cfg, kind, x, positions,
                                enc_out=enc_out, dist=dist, rope=self.use_rope)
            if dist is not None:
                x = dist.constrain(x)
            aux = aux + a
        return x, aux

    def _encode(self, params, enc_embed):
        """Whisper encoder over precomputed frame embeddings (conv stub)."""
        cfg = self.cfg
        Se = enc_embed.shape[1]
        pos_tab = jnp.asarray(sinusoidal_positions(Se, cfg.d_model))
        x = enc_embed.astype(self.dtype) + pos_tab[None].astype(self.dtype)
        positions = jnp.broadcast_to(jnp.arange(Se), enc_embed.shape[:2])

        def body(x, bp):
            x = attention_apply(bp["p0"]["mix"], cfg, x, positions,
                                kind="full", rope=False)
            x = mlp_apply(bp["p0"]["ffn"], x)
            return x, ()

        x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"],
                            unroll=runtime_flags.scan_unroll())
        return rms_norm(params["encoder"]["ln"], x)

    def _embed_inputs(self, params, batch):
        """tokens (+ modality stubs) -> (x, positions, enc_out)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B = tokens.shape[0]
        enc_out = None
        x = embed_apply(params["embed"], cfg, tokens, self.dtype)
        if cfg.family == "audio":
            enc_out = self._encode(params, batch["enc_embed"])
            S = tokens.shape[1]
            x = x + jnp.asarray(sinusoidal_positions(S, cfg.d_model))[None].astype(self.dtype)
        if cfg.family == "vlm":
            patches = dense(params["patch_proj"], batch["patches"].astype(self.dtype))
            x = jnp.concatenate([patches, x], axis=1)
        S = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        return x, positions, enc_out

    # ---- training forward ---------------------------------------------------
    def forward(self, params, batch, *, dist: Optional[DistContext] = None):
        """-> (logits (B,S,V), aux_loss scalar)."""
        x, positions, enc_out = self._embed_inputs(params, batch)
        if dist is not None:
            x = dist.constrain(x)
        x, aux = self._run_stack(params, x, positions, enc_out=enc_out, dist=dist)
        x = rms_norm(params["final_ln"], x)
        if self.cfg.family == "vlm":
            x = x[:, self.cfg.prefix_len:]          # loss on text positions only
        logits = unembed_apply(params["embed"], self.cfg, x)
        return logits, aux

    # ---- serving ------------------------------------------------------------
    def init_cache(self, B: int, s_cache: int) -> dict:
        cfg = self.cfg
        kv_dtype = {"bfloat16": jnp.bfloat16, "int8": jnp.int8,
                    "float32": jnp.float32}[cfg.kv_cache_dtype]
        cache: dict = {"idx": jnp.zeros((), jnp.int32)}
        blocks = {}
        for pos, kind in enumerate(self.pattern):
            if self.reps:
                one = _init_block_cache(cfg, kind, B, s_cache, kv_dtype)
                blocks[f"p{pos}"] = jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None], (self.reps,) + a.shape), one)
        cache["blocks"] = blocks
        cache["tail"] = [
            _init_block_cache(cfg, kind, B, s_cache, kv_dtype)
            for kind in self.tail_kinds
        ]
        if cfg.family == "audio":
            cache["enc_out"] = jnp.zeros((B, 1, cfg.d_model), self.dtype)  # set by prefill
        return cache

    def prefill(self, params, batch, s_cache: int,
                *, dist: Optional[DistContext] = None):
        """Run the full prompt, build the decode cache.

        Implemented as forward + per-layer KV extraction: blocks are re-run
        through the decode path token-block-wise would be slow; instead we
        recompute K/V projections from the final pre-block activations is
        *incorrect* — so we simply run the stack once and additionally
        collect each attention layer's K/V via a second pass of the scanned
        params with collection enabled.
        """
        # Simple and correct: run the stack collecting K/V as scan outputs.
        cfg = self.cfg
        x, positions, enc_out = self._embed_inputs(params, batch)
        B, S = x.shape[:2]
        kv_dtype = {"bfloat16": jnp.bfloat16, "int8": jnp.int8,
                    "float32": jnp.float32}[cfg.kv_cache_dtype]
        cache = self.init_cache(B, s_cache)
        if cfg.family == "audio":
            cache["enc_out"] = enc_out

        def collect_block(bp, kind, x, cache_slot):
            """apply block, return (x, filled cache slot)."""
            aux_ignored = None
            if kind.startswith("attn"):
                # recompute K/V exactly as attention_apply does
                from .layers import _qkv, rms_norm as _rn, RopeSpec as _RS
                h = _rn(bp["mix"]["ln"], x)
                q, k, v = _qkv(bp["mix"], cfg, h)
                if self.use_rope:
                    spec = _RS(cfg.hd, cfg.rope_theta)
                    k = apply_rope(k, positions, spec)
                slot = cache_slot["attn"]
                Sc = slot["k"].shape[1]
                if kind == "attn_local" and S > Sc:
                    sel = jnp.arange(S - Sc, S)
                else:
                    sel = jnp.arange(min(S, Sc))
                ks, vs = k[:, sel], v[:, sel]
                wslot = sel % Sc if kind == "attn_local" else sel
                if kv_dtype == jnp.int8:
                    kq, ksc = _q8(ks)
                    vq, vsc = _q8(vs)
                    slot = {
                        "k": slot["k"].at[:, wslot].set(kq),
                        "v": slot["v"].at[:, wslot].set(vq),
                        "scale": slot["scale"].at[:, wslot].set(
                            jnp.stack([ksc, vsc], -1)),
                    }
                else:
                    slot = {"k": slot["k"].at[:, wslot].set(ks.astype(kv_dtype)),
                            "v": slot["v"].at[:, wslot].set(vs.astype(kv_dtype))}
                cache_slot = dict(cache_slot, attn=slot)
                x, aux_ignored = _apply_block(bp, cfg, kind, x, positions,
                                              enc_out=enc_out, dist=dist,
                                              rope=self.use_rope)
            elif kind == "rec":
                x2 = x
                x, _ = _apply_block(bp, cfg, kind, x2, positions, dist=dist)
                cache_slot = dict(cache_slot, rec=_rec_state_from_prefill(
                    bp["mix"], cfg, x2, cache_slot["rec"]))
            elif kind == "mlstm":
                x2 = x
                x, _ = _apply_block(bp, cfg, kind, x2, positions, dist=dist)
                cache_slot = dict(cache_slot, mlstm=_mlstm_state_from_prefill(
                    bp["mix"], cfg, x2, cache_slot["mlstm"]))
            elif kind == "slstm":
                x2 = x
                x, _ = _apply_block(bp, cfg, kind, x2, positions, dist=dist)
                cache_slot = dict(cache_slot, slstm=_slstm_state_from_prefill(
                    bp["mix"], cfg, x2, cache_slot["slstm"]))
            del aux_ignored
            return x, cache_slot

        def rep_body(x, scan_in):
            bp, cslot = scan_in
            for pos, kind in enumerate(self.pattern):
                x, new_slot = collect_block(bp[f"p{pos}"], kind, x, cslot[f"p{pos}"])
                cslot = dict(cslot, **{f"p{pos}": new_slot})
            return x, cslot

        if self.reps:
            x, new_blocks = jax.lax.scan(rep_body, x, (params["blocks"], cache["blocks"]),
                                         unroll=runtime_flags.scan_unroll())
            cache["blocks"] = new_blocks
        for j, kind in enumerate(self.tail_kinds):
            x, cache["tail"][j] = collect_block(params["tail"][j], kind, x,
                                                cache["tail"][j])
        x = rms_norm(params["final_ln"], x)
        logits = unembed_apply(params["embed"], cfg, x[:, -1:])
        cache["idx"] = jnp.asarray(S, jnp.int32)
        return logits, cache

    def decode_step(self, params, tokens, cache):
        """tokens (B,1) -> (logits (B,1,V), new cache)."""
        cfg = self.cfg
        idx = cache["idx"]
        x = embed_apply(params["embed"], cfg, tokens, self.dtype)
        if cfg.family == "audio":
            # sinusoidal position for the current step
            tab = jnp.asarray(sinusoidal_positions(1, cfg.d_model, 0))
            x = x + tab[None].astype(self.dtype)    # offset handled by rope-free attn
        enc_out = cache.get("enc_out")

        def rep_body(x, scan_in):
            bp, cslot = scan_in
            for pos, kind in enumerate(self.pattern):
                x, new_slot = _decode_block(bp[f"p{pos}"], cfg, kind, x,
                                            cslot[f"p{pos}"], idx,
                                            enc_out=enc_out, rope=self.use_rope)
                cslot = dict(cslot, **{f"p{pos}": new_slot})
            return x, cslot

        new_cache = dict(cache)
        if self.reps:
            x, new_blocks = jax.lax.scan(rep_body, x, (params["blocks"], cache["blocks"]),
                                         unroll=runtime_flags.scan_unroll())
            new_cache["blocks"] = new_blocks
        new_tail = []
        for j, kind in enumerate(self.tail_kinds):
            x, ct = _decode_block(params["tail"][j], cfg, kind, x,
                                  cache["tail"][j], idx, enc_out=enc_out,
                                  rope=self.use_rope)
            new_tail.append(ct)
        new_cache["tail"] = new_tail
        x = rms_norm(params["final_ln"], x)
        logits = unembed_apply(params["embed"], cfg, x)
        new_cache["idx"] = idx + 1
        return logits, new_cache


# ---- prefill state extraction for recurrent layers -------------------------

def _q8(t):
    amax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    return jnp.round(t.astype(jnp.float32) / scale[..., None]).astype(jnp.int8), scale


def _rec_state_from_prefill(p, cfg, x, slot):
    from .recurrent import _causal_conv, _rglru_gates
    from ..core.recurrence import linear_recurrence
    h = rms_norm(p["ln"], x)
    u = dense(p["in_x"], h)
    uc, conv_state = _conv_tail(p["conv"], u)
    log_a, xin = _rglru_gates(p, uc)
    hs = linear_recurrence(jnp.exp(log_a), xin, axis=1)
    return {"h": hs[:, -1], "conv": conv_state.astype(slot["conv"].dtype)}


def _mlstm_state_from_prefill(p, cfg, x, slot):
    from .recurrent import _mlstm_chunk_scan, _mlstm_qkv
    h = rms_norm(p["ln"], x)
    up = dense(p["up"], h)
    xi, _gate = jnp.split(up, 2, axis=-1)
    xic, conv_state = _conv_tail(p["conv"], xi)
    xic = jax.nn.silu(xic)
    q, k, v, li, lf = _mlstm_qkv(p, xic)
    S = q.shape[1]
    from . import runtime_flags as _rf
    chunk = min(256, S) if S <= 16384 else -(-S // _rf.UNROLL_LIMIT)
    if S % chunk:
        chunk = 1
    _, (C, n, m) = _mlstm_chunk_scan(q, k, v, li, lf, chunk)
    return {"C": C, "n": n, "m": m, "conv": conv_state.astype(slot["conv"].dtype)}


def _slstm_state_from_prefill(p, cfg, x, slot):
    from .recurrent import _causal_conv, _slstm_cell
    B, S, D = x.shape
    H = cfg.n_state_heads
    dh = D // H
    h0 = rms_norm(p["ln"], x)
    u, conv_state = _conv_tail(p["conv"], h0)
    u = jax.nn.silu(u)
    wz = dense(p["wz"], h0).astype(jnp.float32)
    wi = dense(p["wi"], u).astype(jnp.float32)
    wf = dense(p["wf"], u).astype(jnp.float32)
    wo = dense(p["wo"], h0).astype(jnp.float32)

    def body(carry, t_in):
        z, i, f, o = t_in
        return _slstm_cell(p, H, dh, {"z": z, "i": i, "f": f, "o": o}, carry), ()

    zero = jnp.zeros((B, D), jnp.float32)
    init = (zero, zero, jnp.full((B, D), -1e30, jnp.float32), zero)
    xs = tuple(t.transpose(1, 0, 2) for t in (wz, wi, wf, wo))
    (c, n, m, h), _ = jax.lax.scan(body, init, xs)
    return {"c": c, "n": n, "m": m, "h": h,
            "conv": conv_state.astype(slot["conv"].dtype)}


def _conv_tail(p, x):
    """Run the causal conv over the full sequence and return (output,
    conv state = last W-1 inputs) for the decode cache."""
    from .recurrent import _causal_conv
    y, state = _causal_conv(p, x)
    return y, state
