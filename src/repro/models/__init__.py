"""Model zoo: 10 assigned architectures on one shared layer library.

Block kinds (cycled per-layer from ``ModelConfig.block_pattern``):
  attn          full causal GQA attention
  attn_local    sliding-window GQA attention
  attn_bidir    bidirectional attention (encoder / prefix)
  rec           RG-LRU recurrent block (Griffin/RecurrentGemma)
  mlstm         xLSTM matrix-memory block (chunked parallel / recurrent decode)
  slstm         xLSTM scalar-memory block (sequential scan)

Families: decoder-only LM (dense & MoE), encoder-decoder (whisper), prefix-LM
VLM (paligemma).  Modality frontends are stubs per assignment: input_specs()
provide precomputed frame/patch embeddings.
"""
from .config import ARCHS, ModelConfig, get_config, smoke_config
from .model import DistContext, Model

__all__ = ["ARCHS", "ModelConfig", "get_config", "smoke_config",
           "DistContext", "Model"]
