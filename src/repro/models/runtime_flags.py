"""Process-wide execution-mode flags.

``UNROLL_SCANS`` — set by the dry-run driver.  XLA's ``cost_analysis``
counts a ``while`` body ONCE regardless of trip count, so a scanned-layers
model under-reports FLOPs/collective-bytes by ~num_reps×.  The dry-run
therefore unrolls the layer-repetition scan, the flash-attention KV scan and
the mLSTM chunk scan (trace-time ``lax.scan(..., unroll=True)``) so the
roofline terms are exact.  Training keeps scans rolled (compile time
O(pattern), not O(depth)).

The sLSTM timestep scan (T = seq_len iterations) is never unrolled — its
FLOPs are added analytically in the roofline report (documented in
EXPERIMENTS.md; xlstm-350m only).
"""
UNROLL_SCANS = False

# cap for unrolling inner scans (kv blocks / chunks); beyond this the scan
# stays rolled and the undercount is corrected analytically
UNROLL_LIMIT = 64


def scan_unroll() -> bool | int:
    return True if UNROLL_SCANS else 1
