"""Recurrent blocks: RG-LRU (recurrentgemma/Griffin), mLSTM & sLSTM (xLSTM).

Training-time parallelization of the RG-LRU gated recurrence uses
`repro.core.recurrence.linear_recurrence(method="doubling")` — the paper's
equation-rewriting transformation specialized to the chain dependency graph
(see that module's docstring).  mLSTM uses the chunkwise-parallel form
(intra-chunk quasi-attention + inter-chunk state scan).  sLSTM is inherently
sequential (scalar memory mixing) and runs as a `lax.scan` — its O(T) levels
are exactly the un-rewritable part of the DAG.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.recurrence import linear_recurrence
from . import runtime_flags
from .config import ModelConfig
from .layers import dense, init_dense, init_rms_norm, rms_norm

__all__ = [
    "init_rglru_block", "rglru_block_apply", "rglru_block_decode",
    "init_mlstm_block", "mlstm_block_apply", "mlstm_block_decode",
    "init_slstm_block", "slstm_block_apply", "slstm_block_decode",
]

_RGLRU_C = 8.0


# --------------------------------------------------------------------------
# causal depthwise temporal conv
# --------------------------------------------------------------------------

def _init_conv(key, d: int, width: int) -> dict:
    return {"w": jax.random.normal(key, (width, d), jnp.float32) * width ** -0.5,
            "b": jnp.zeros((d,), jnp.float32)}


def _causal_conv(p: dict, x: jnp.ndarray, state: Optional[jnp.ndarray] = None):
    """x (B,S,d); state (B,W-1,d) carries history for decode. Returns y, new_state."""
    W = p["w"].shape[0]
    w = p["w"].astype(x.dtype)
    if state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, k : k + x.shape[1]] * w[k] for k in range(W))
    y = y + p["b"].astype(x.dtype)
    return y, xp[:, -(W - 1):]


# --------------------------------------------------------------------------
# RG-LRU block (Griffin recurrent block)
# --------------------------------------------------------------------------

def init_rglru_block(key, cfg: ModelConfig) -> dict:
    D = cfg.d_model
    R = cfg.d_rnn or D
    ks = jax.random.split(key, 7)
    return {
        "ln": init_rms_norm(D),
        "in_x": init_dense(ks[0], D, R),
        "in_gate": init_dense(ks[1], D, R),
        "conv": _init_conv(ks[2], R, cfg.conv_width),
        "w_a": init_dense(ks[3], R, R),          # recurrence gate
        "w_i": init_dense(ks[4], R, R),          # input gate
        # Λ init so that a = sigmoid(Λ) ∈ [0.9, 0.999]
        "lam": jnp.asarray(
            np.log(np.linspace(0.9, 0.999, R) / (1 - np.linspace(0.9, 0.999, R))),
            jnp.float32),
        "out": init_dense(ks[5], R, D, scale=R ** -0.5),
    }


def _rglru_gates(p, u):
    """u (.., R) conv output -> (log_a, gated input) both f32."""
    r = jax.nn.sigmoid(dense(p["w_a"], u).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(p["w_i"], u).astype(jnp.float32))
    log_a = -_RGLRU_C * r * jax.nn.softplus(p["lam"])          # log a_t  (<0)
    x_in = i * u.astype(jnp.float32)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return log_a, mult * x_in


def rglru_block_apply(params, cfg: ModelConfig, x: jnp.ndarray,
                      *, method: str = "doubling") -> jnp.ndarray:
    h = rms_norm(params["ln"], x)
    gate = jax.nn.gelu(dense(params["in_gate"], h))
    u = dense(params["in_x"], h)
    u, _ = _causal_conv(params["conv"], u)
    log_a, xin = _rglru_gates(params, u)
    # h_t = a_t h_{t-1} + xin_t  — equation-rewriting-derived parallel scan
    hs = linear_recurrence(jnp.exp(log_a), xin, method=method, axis=1)
    y = hs.astype(x.dtype) * gate
    return x + dense(params["out"], y)


def rglru_block_decode(params, cfg: ModelConfig, x: jnp.ndarray, cache: dict):
    """x (B,1,D); cache {"h": (B,R) f32, "conv": (B,W-1,R)}."""
    h = rms_norm(params["ln"], x)
    gate = jax.nn.gelu(dense(params["in_gate"], h))
    u = dense(params["in_x"], h)
    u, conv_state = _causal_conv(params["conv"], u, cache["conv"])
    log_a, xin = _rglru_gates(params, u)
    h_new = jnp.exp(log_a[:, 0]) * cache["h"] + xin[:, 0]       # (B,R)
    y = h_new[:, None].astype(x.dtype) * gate
    out = x + dense(params["out"], y)
    return out, {"h": h_new, "conv": conv_state.astype(cache["conv"].dtype)}


# --------------------------------------------------------------------------
# mLSTM block (xLSTM): matrix memory, chunkwise-parallel training
# --------------------------------------------------------------------------

def init_mlstm_block(key, cfg: ModelConfig) -> dict:
    D = cfg.d_model
    Din = 2 * D                 # pf=2 up-projection
    H = cfg.n_state_heads
    ks = jax.random.split(key, 9)
    return {
        "ln": init_rms_norm(D),
        "up": init_dense(ks[0], D, 2 * Din),        # (inner, gate)
        "conv": _init_conv(ks[1], Din, cfg.conv_width),
        "q": init_dense(ks[2], Din, (H, Din // H)),
        "k": init_dense(ks[3], Din, (H, Din // H)),
        "v": init_dense(ks[4], Din, (H, Din // H)),
        "ig": init_dense(ks[5], Din, H),            # log-space input gate
        "fg": init_dense(ks[6], Din, H),            # forget gate (pre-sigmoid)
        "down": init_dense(ks[7], Din, D, scale=Din ** -0.5),
        "skip": init_dense(ks[8], Din, Din),
    }


def _mlstm_qkv(params, xi):
    q = dense(params["q"], xi)
    k = dense(params["k"], xi) * (params["q"]["w"].shape[-1]) ** -0.5
    v = dense(params["v"], xi)
    li = dense(params["ig"], xi).astype(jnp.float32)                 # log i_t
    lf = jax.nn.log_sigmoid(dense(params["fg"], xi).astype(jnp.float32))  # log f_t
    return q, k, v, li, lf


def _mlstm_chunk_scan(q, k, v, li, lf, chunk: int, state=None):
    """Chunkwise-parallel stabilized mLSTM.

    q,k,v: (B,S,H,d); li,lf: (B,S,H).  Returns h (B,S,H,d) and final
    (C (B,H,d,d), n (B,H,d), m (B,H)).
    """
    B, S, H, d = q.shape
    W = min(chunk, S)
    assert S % W == 0, (S, W)
    nc = S // W
    f32 = jnp.float32
    qc = q.reshape(B, nc, W, H, d).astype(f32)
    kc = k.reshape(B, nc, W, H, d).astype(f32)
    vc = v.reshape(B, nc, W, H, d).astype(f32)
    lic = li.reshape(B, nc, W, H)
    lfc = lf.reshape(B, nc, W, H)
    if state is None:
        C0 = jnp.zeros((B, H, d, d), f32)
        n0 = jnp.zeros((B, H, d), f32)
        m0 = jnp.full((B, H), -1e30, f32)
    else:
        C0, n0, m0 = state

    tri = jnp.tril(jnp.ones((W, W), bool))

    def chunk_step(carry, inp):
        C0, n0, m0 = carry
        qw, kw, vw, liw, lfw = inp          # (B,W,H,d), (B,W,H)
        b = jnp.cumsum(lfw, axis=1)         # (B,W,H)  cumulative log-forget
        # intra-chunk log weights:  D[t,s] = b_t - b_s + li_s  (s<=t)
        Dm = b[:, :, None] - b[:, None, :, :] + liw[:, None]   # (B,W,W,H)
        Dm = jnp.where(tri[None, :, :, None], Dm, -1e30)
        m_intra = Dm.max(axis=2)                                # (B,W,H)
        m_t = jnp.maximum(b + m0[:, None], m_intra)             # (B,W,H)
        m_t = jnp.maximum(m_t, -1e30)
        wgt = jnp.exp(Dm - m_t[:, :, None])                     # (B,W,W,H)
        scores = jnp.einsum("bthd,bshd->btsh", qw, kw)          # (B,W,W,H)
        inter_scale = jnp.exp(b + m0[:, None] - m_t)            # (B,W,H)
        h_num = (jnp.einsum("btsh,btsh,bshd->bthd", wgt, scores, vw)
                 + inter_scale[..., None]
                 * jnp.einsum("bhde,bthd->bthe", C0, qw))
        # denominator: n_t^T q_t with the same weights
        n_q = (jnp.einsum("btsh,btsh->bth", wgt, scores)
               + inter_scale * jnp.einsum("bhd,bthd->bth", n0, qw))
        denom = jnp.maximum(jnp.abs(n_q), jnp.exp(-m_t))
        h = h_num / denom[..., None]
        # chunk-end state
        bW = b[:, -1]                                           # (B,H)
        m_end = jnp.maximum(bW + m0, (bW[:, None] - b + liw).max(axis=1))
        g_in = jnp.exp(bW[:, None] - b + liw - m_end[:, None])  # (B,W,H)
        C1 = (jnp.exp(bW + m0 - m_end)[:, :, None, None] * C0
              + jnp.einsum("bwh,bwhd,bwhe->bhde", g_in, kw, vw))
        n1 = (jnp.exp(bW + m0 - m_end)[:, :, None] * n0
              + jnp.einsum("bwh,bwhd->bhd", g_in, kw))
        return (C1, n1, m_end), h

    xs = (qc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
          vc.transpose(1, 0, 2, 3, 4), lic.transpose(1, 0, 2, 3),
          lfc.transpose(1, 0, 2, 3))
    unroll = (True if runtime_flags.UNROLL_SCANS
              and nc <= runtime_flags.UNROLL_LIMIT else 1)
    (C1, n1, m1), hs = jax.lax.scan(chunk_step, (C0, n0, m0), xs, unroll=unroll)
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, d)
    return h, (C1, n1, m1)


def mlstm_block_apply(params, cfg: ModelConfig, x: jnp.ndarray,
                      *, chunk: int = 0) -> jnp.ndarray:
    B, S, D = x.shape
    if chunk == 0:   # adaptive: keep chunk count <= UNROLL_LIMIT
        chunk = 256 if S <= 16384 else -(-S // runtime_flags.UNROLL_LIMIT)
    h = rms_norm(params["ln"], x)
    up = dense(params["up"], h)
    xi, gate = jnp.split(up, 2, axis=-1)
    xi, _ = _causal_conv(params["conv"], xi)
    xi = jax.nn.silu(xi)
    q, k, v, li, lf = _mlstm_qkv(params, xi)
    hh, _ = _mlstm_chunk_scan(q, k, v, li, lf, chunk)
    H, d = q.shape[2], q.shape[3]
    y = hh.astype(x.dtype).reshape(B, S, H * d) + dense(params["skip"], xi)
    y = y * jax.nn.silu(gate)
    return x + dense(params["down"], y)


def mlstm_block_decode(params, cfg: ModelConfig, x: jnp.ndarray, cache: dict):
    """cache {"C": (B,H,d,d) f32, "n": (B,H,d), "m": (B,H), "conv": (B,W-1,Din)}."""
    B = x.shape[0]
    h = rms_norm(params["ln"], x)
    up = dense(params["up"], h)
    xi, gate = jnp.split(up, 2, axis=-1)
    xi, conv_state = _causal_conv(params["conv"], xi, cache["conv"])
    xi = jax.nn.silu(xi)
    q, k, v, li, lf = _mlstm_qkv(params, xi)
    q0, k0, v0 = (t[:, 0].astype(jnp.float32) for t in (q, k, v))  # (B,H,d)
    li0, lf0 = li[:, 0], lf[:, 0]                                   # (B,H)
    C, n, m = cache["C"], cache["n"], cache["m"]
    m_new = jnp.maximum(lf0 + m, li0)
    fs = jnp.exp(lf0 + m - m_new)
    is_ = jnp.exp(li0 - m_new)
    C1 = fs[..., None, None] * C + is_[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", k0, v0)
    n1 = fs[..., None] * n + is_[..., None] * k0
    num = jnp.einsum("bhde,bhd->bhe", C1, q0)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n1, q0)), jnp.exp(-m_new))
    hh = (num / den[..., None]).astype(x.dtype)                     # (B,H,d)
    H, d = hh.shape[1], hh.shape[2]
    y = hh.reshape(B, 1, H * d) + dense(params["skip"], xi)
    y = y * jax.nn.silu(gate)
    out = x + dense(params["down"], y)
    return out, {"C": C1, "n": n1, "m": m_new,
                 "conv": conv_state.astype(cache["conv"].dtype)}


# --------------------------------------------------------------------------
# sLSTM block (xLSTM): scalar memory, sequential scan
# --------------------------------------------------------------------------

def init_slstm_block(key, cfg: ModelConfig) -> dict:
    D = cfg.d_model
    H = cfg.n_state_heads
    dh = D // H
    ks = jax.random.split(key, 10)
    F = int(D * 4 / 3) // 8 * 8         # pf = 4/3 post-FFN
    return {
        "ln": init_rms_norm(D),
        "conv": _init_conv(ks[0], D, cfg.conv_width),
        "wz": init_dense(ks[1], D, D),
        "wi": init_dense(ks[2], D, D),
        "wf": init_dense(ks[3], D, D),
        "wo": init_dense(ks[4], D, D),
        # block-diagonal recurrent weights, one (dh, dh) block per head
        "rz": jax.random.normal(ks[5], (H, dh, dh), jnp.float32) * dh ** -0.5,
        "ri": jax.random.normal(ks[6], (H, dh, dh), jnp.float32) * dh ** -0.5,
        "rf": jax.random.normal(ks[7], (H, dh, dh), jnp.float32) * dh ** -0.5,
        "ro": jax.random.normal(ks[8], (H, dh, dh), jnp.float32) * dh ** -0.5,
        "gn": init_rms_norm(D),
        "ffn": {"wi": init_dense(ks[9], D, F),
                "wo": init_dense(jax.random.fold_in(ks[9], 1), F, D, scale=F ** -0.5)},
    }


def _slstm_cell(params, H, dh, wx, carry):
    """One time step.  wx: dict of (B,D) pre-activations from inputs;
    carry: (c, n, m, h) each (B,D)-ish f32."""
    c, n, m, h = carry
    hb = h.reshape(h.shape[0], H, dh)

    def rec(name):
        return jnp.einsum("bhd,hde->bhe", hb, params[name]).reshape(h.shape)

    z = jnp.tanh(wx["z"] + rec("rz"))
    li = wx["i"] + rec("ri")                       # log-space input gate
    lf = jax.nn.log_sigmoid(wx["f"] + rec("rf"))
    o = jax.nn.sigmoid(wx["o"] + rec("ro"))
    m_new = jnp.maximum(lf + m, li)
    i_ = jnp.exp(li - m_new)
    f_ = jnp.exp(lf + m - m_new)
    c_new = f_ * c + i_ * z
    n_new = f_ * n + i_
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, m_new, h_new)


def slstm_block_apply(params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    B, S, D = x.shape
    H = cfg.n_state_heads
    dh = D // H
    h0 = rms_norm(params["ln"], x)
    u, _ = _causal_conv(params["conv"], h0)
    u = jax.nn.silu(u)
    wz = dense(params["wz"], h0).astype(jnp.float32)
    wi = dense(params["wi"], u).astype(jnp.float32)
    wf = dense(params["wf"], u).astype(jnp.float32)
    wo = dense(params["wo"], h0).astype(jnp.float32)

    def body(carry, t_in):
        z, i, f, o = t_in
        carry = _slstm_cell(params, H, dh, {"z": z, "i": i, "f": f, "o": o}, carry)
        return carry, carry[3]

    zero = jnp.zeros((B, D), jnp.float32)
    init = (zero, zero, jnp.full((B, D), -1e30, jnp.float32), zero)
    xs = tuple(t.transpose(1, 0, 2) for t in (wz, wi, wf, wo))
    _, hs = jax.lax.scan(body, init, xs)
    y = hs.transpose(1, 0, 2).astype(x.dtype)
    y = rms_norm(params["gn"], y)
    x = x + y
    # gated FFN (pf = 4/3)
    f = dense(params["ffn"]["wo"], jax.nn.gelu(dense(params["ffn"]["wi"], x)))
    return x + f


def slstm_block_decode(params, cfg: ModelConfig, x: jnp.ndarray, cache: dict):
    """cache {"c","n","m","h": (B,D) f32, "conv": (B,W-1,D)}."""
    B, _, D = x.shape
    H = cfg.n_state_heads
    dh = D // H
    h0 = rms_norm(params["ln"], x)
    u, conv_state = _causal_conv(params["conv"], h0, cache["conv"])
    u = jax.nn.silu(u)
    wx = {
        "z": dense(params["wz"], h0)[:, 0].astype(jnp.float32),
        "i": dense(params["wi"], u)[:, 0].astype(jnp.float32),
        "f": dense(params["wf"], u)[:, 0].astype(jnp.float32),
        "o": dense(params["wo"], h0)[:, 0].astype(jnp.float32),
    }
    carry = (cache["c"], cache["n"], cache["m"], cache["h"])
    c, n, m, h = _slstm_cell(params, H, dh, wx, carry)
    y = rms_norm(params["gn"], h[:, None].astype(x.dtype))
    x = x + y
    f = dense(params["ffn"]["wo"], jax.nn.gelu(dense(params["ffn"]["wi"], x)))
    out = x + f
    return out, {"c": c, "n": n, "m": m, "h": h,
                 "conv": conv_state.astype(cache["conv"].dtype)}
