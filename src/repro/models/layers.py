"""Shared layer library for the 10-arch model zoo.

Pure-functional: ``init_*`` builds param pytrees (plain dicts of jnp arrays,
float32 masters), ``*_apply`` runs the layer in the compute dtype.  All
attention goes through one flash implementation (`flash_attention`): an
online-softmax ``lax.scan`` over key blocks with mask-aware block skipping,
so full 32k prefill never materializes an S×S score matrix and sliding-window
layers do sub-quadratic *compute* (skipped blocks are never executed).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import runtime_flags
from .config import ModelConfig

__all__ = [
    "RopeSpec", "rms_norm", "init_rms_norm", "init_dense", "dense",
    "apply_rope", "flash_attention", "decode_attention",
    "init_attention", "attention_apply", "attention_decode",
    "init_mlp", "mlp_apply", "init_embedding", "embed_apply", "unembed_apply",
    "sinusoidal_positions", "softcap",
]

# --------------------------------------------------------------------------
# Primitives
# --------------------------------------------------------------------------

def init_rms_norm(d: int) -> dict:
    return {"scale": jnp.zeros((d,), jnp.float32)}


def rms_norm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * (1.0 + params["scale"])
    return y.astype(dt)


def init_dense(key, d_in: int, d_out, *, bias: bool = False, scale: float | None = None) -> dict:
    shape = (d_in,) + (tuple(d_out) if isinstance(d_out, (tuple, list)) else (d_out,))
    fan_in = d_in
    std = scale if scale is not None else fan_in ** -0.5
    p = {"w": jax.random.normal(key, shape, jnp.float32) * std}
    if bias:
        p["b"] = jnp.zeros(shape[1:], jnp.float32)
    return p


def dense(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    w = params["w"].astype(x.dtype)
    ndim_out = w.ndim - 1
    y = jax.lax.dot_general(x, w, (((x.ndim - 1,), (0,)), ((), ())))
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    del ndim_out
    return y


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap <= 0.0:
        return x
    return cap * jnp.tanh(x / cap)


def sinusoidal_positions(length: int, d: int, offset: int = 0) -> np.ndarray:
    """Whisper-style fixed sinusoidal position table (host constant)."""
    pos = np.arange(offset, offset + length, dtype=np.float64)[:, None]
    dim = np.arange(0, d, 2, dtype=np.float64)[None, :]
    inv = np.exp(-np.log(10000.0) * dim / d)
    tab = np.zeros((length, d), dtype=np.float32)
    tab[:, 0::2] = np.sin(pos * inv)
    tab[:, 1::2] = np.cos(pos * inv)
    return tab


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RopeSpec:
    dim: int
    theta: float = 10_000.0


def _rope_angles(positions: jnp.ndarray, spec: RopeSpec) -> tuple[jnp.ndarray, jnp.ndarray]:
    half = spec.dim // 2
    freq = spec.theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq  # (..., half)
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, spec: RopeSpec) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: (..., S)."""
    sin, cos = _rope_angles(positions, spec)      # (..., S, half)
    sin = sin[..., None, :]                       # broadcast over heads
    cos = cos[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# Flash attention (pure-JAX online softmax over key blocks)
# --------------------------------------------------------------------------
NEG_INF = -1e30


def _block_mask(q_pos, k_pos, kind: str, window: int, prefix_len: int):
    """(Bq, Bk) boolean mask for one (query-block, key-block) pair."""
    q = q_pos[:, None]
    k = k_pos[None, :]
    if kind == "full":
        return jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if kind == "causal":
        return k <= q
    if kind == "window":          # causal sliding window
        return (k <= q) & (k > q - window)
    if kind == "prefix":          # bidirectional prefix, causal after
        return (k <= q) | (k < prefix_len)
    raise ValueError(kind)


def _blocks_needed(kind: str, qb: int, n_kb: int, bq: int, bk: int,
                   window: int, seq_offset: int) -> range:
    """Key-block range that can contain unmasked entries for query block qb
    (static — computed at trace time; this is where window layers go
    sub-quadratic in compute)."""
    if kind == "full":
        return range(n_kb)
    q_lo = seq_offset + qb * bq
    q_hi = q_lo + bq - 1
    if kind in ("causal", "prefix"):
        # prefix-LM: the bidirectional prefix lives in block 0 (prefix_len
        # <= bk always holds for our configs), which causal already visits
        return range(0, min(n_kb, q_hi // bk + 1))
    if kind == "window":
        lo = max(0, (q_lo - window + 1) // bk)
        return range(lo, min(n_kb, q_hi // bk + 1))
    raise ValueError(kind)


def flash_attention(
    q: jnp.ndarray,             # (B, Sq, Hq, hd)
    k: jnp.ndarray,             # (B, Sk, Hkv, hd)
    v: jnp.ndarray,             # (B, Sk, Hkv, hd)
    *,
    kind: str = "causal",       # full | causal | window | prefix
    window: int = 0,
    prefix_len: int = 0,
    seq_offset: int = 0,        # absolute position of q[0] (cross/cache use)
    block_q: int = 0,           # 0 = auto (HLO-size-aware)
    block_k: int = 0,
    softcap_val: float = 0.0,
) -> jnp.ndarray:
    """Memory O(S·block); compute skips fully-masked key blocks."""
    B, Sq, Hq, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    g = Hq // Hkv
    if block_k == 0:       # auto: cap trace-time unrolling at long seq_len
        if kind == "window" and window >= 128:
            block_k = min(window, 2048)
        else:
            block_k = 2048 if Sk > 8192 else 512
    if block_q == 0:
        block_q = 2048 if Sq > 8192 else 512
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    # pad to block multiples
    Sq_p = -(-Sq // bq) * bq
    Sk_p = -(-Sk // bk) * bk
    qp = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, 0)))
    n_qb, n_kb = Sq_p // bq, Sk_p // bk
    scale = hd ** -0.5

    # (B, Hkv, g, n_qb, bq, hd)
    q4 = qp.reshape(B, n_qb, bq, Hkv, g, hd).transpose(0, 3, 4, 1, 2, 5)
    k4 = kp.reshape(B, n_kb, bk, Hkv, hd).transpose(0, 3, 1, 2, 4)
    v4 = vp.reshape(B, n_kb, bk, Hkv, hd).transpose(0, 3, 1, 2, 4)

    k_valid = (jnp.arange(Sk_p) < Sk).reshape(n_kb, bk)

    out_blocks = []
    for qb in range(n_qb):
        qb_q = q4[:, :, :, qb]                        # (B, Hkv, g, bq, hd)
        q_pos = seq_offset + qb * bq + jnp.arange(bq)
        kbs = list(_blocks_needed(kind, qb, n_kb, bq, bk, window, seq_offset))
        acc = jnp.zeros(qb_q.shape, jnp.float32)
        m = jnp.full(qb_q.shape[:-1], NEG_INF, jnp.float32)
        l = jnp.zeros(qb_q.shape[:-1], jnp.float32)

        def kv_step(carry, inp):
            acc, m, l = carry
            kb_k, kb_v, mask = inp                    # (B,Hkv,bk,hd) (bq,bk)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb_q.astype(jnp.float32),
                           kb_k.astype(jnp.float32)) * scale
            if softcap_val > 0.0:
                s = softcap(s, softcap_val)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, kb_v.astype(jnp.float32))
            return (acc, m_new, l), ()

        # remat the kv step: without this, the scan's VJP stores every
        # (bq, bk) score tile via dynamic-update-slice — 2x182 GB/dev of
        # HBM traffic on qwen train_4k (flash attention must recompute
        # tiles in backward, that is the whole point)
        kv_step = jax.checkpoint(kv_step)
        if kbs:
            masks = []
            for kb in kbs:
                k_pos = kb * bk + jnp.arange(bk)
                mask = _block_mask(q_pos, k_pos, kind, window, prefix_len)
                masks.append(mask & k_valid[kb][None, :])
            ks = jnp.stack([k4[:, :, kb] for kb in kbs], 0)
            vs = jnp.stack([v4[:, :, kb] for kb in kbs], 0)
            ms = jnp.stack(masks, 0)
            unroll = (True if runtime_flags.UNROLL_SCANS
                      and len(kbs) <= runtime_flags.UNROLL_LIMIT else 1)
            (acc, m, l), _ = jax.lax.scan(kv_step, (acc, m, l), (ks, vs, ms),
                                          unroll=unroll)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        out_blocks.append(out.astype(q.dtype))

    o = jnp.stack(out_blocks, axis=3)                 # (B,Hkv,g,n_qb,bq,hd)
    o = o.transpose(0, 3, 4, 1, 2, 5).reshape(B, Sq_p, Hq, hd)
    return o[:, :Sq]


def decode_attention(
    q: jnp.ndarray,            # (B, 1, Hq, hd)
    k_cache: jnp.ndarray,      # (B, S, Hkv, hd)
    v_cache: jnp.ndarray,
    cur_index: jnp.ndarray,    # scalar int — number of valid cache entries
    *,
    window: int = 0,           # 0 = full causal over cache
    softcap_val: float = 0.0,
    kv_scale: Optional[jnp.ndarray] = None,  # int8 cache dequant (B,S,Hkv)
) -> jnp.ndarray:
    """Single-token decode against a (possibly int8) KV cache."""
    B, S, Hkv, hd = k_cache.shape
    Hq = q.shape[2]
    g = Hq // Hkv
    kf = k_cache
    vf = v_cache
    if kv_scale is not None:
        kf = kf.astype(jnp.float32) * kv_scale[..., 0][..., None]
        vf = vf.astype(jnp.float32) * kv_scale[..., 1][..., None]
    qf = q.reshape(B, Hkv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bhgd,bshd->bhgs", qf, kf.astype(jnp.float32)) * hd ** -0.5
    if softcap_val > 0.0:
        s = softcap(s, softcap_val)
    pos = jnp.arange(S)
    valid = pos[None, None, None, :] < cur_index
    if window > 0:
        valid = valid & (pos[None, None, None, :] >= cur_index - window)
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, vf.astype(jnp.float32))
    return o.reshape(B, 1, Hq, hd).astype(q.dtype)


# --------------------------------------------------------------------------
# Attention block (GQA, optional cross-attention)
# --------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, *, cross: bool = False) -> dict:
    D, hd = cfg.d_model, cfg.hd
    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 5)
    p = {
        "ln": init_rms_norm(D),
        "q": init_dense(ks[0], D, (Hq, hd), bias=cfg.qkv_bias),
        "k": init_dense(ks[1], D, (Hkv, hd), bias=cfg.qkv_bias),
        "v": init_dense(ks[2], D, (Hkv, hd), bias=cfg.qkv_bias),
        "o": init_dense(ks[3], Hq * hd, D, scale=(Hq * hd) ** -0.5),
    }
    if cross:
        p["ln_kv"] = init_rms_norm(D)
    return p


def _qkv(params, cfg: ModelConfig, x, kv_src=None):
    xq = x if kv_src is None else x
    xkv = x if kv_src is None else kv_src
    q = dense(params["q"], xq)
    k = dense(params["k"], xkv)
    v = dense(params["v"], xkv)
    return q, k, v


def attention_apply(
    params: dict,
    cfg: ModelConfig,
    x: jnp.ndarray,             # (B, S, D)
    positions: jnp.ndarray,     # (B, S)
    *,
    kind: str = "causal",
    kv_src: Optional[jnp.ndarray] = None,   # encoder states for cross-attn
    rope: bool = True,
    prefix_len: int = 0,
) -> jnp.ndarray:
    h = rms_norm(params["ln"], x)
    src = rms_norm(params["ln_kv"], kv_src) if kv_src is not None else None
    q, k, v = _qkv(params, cfg, h, src)
    if rope and kv_src is None:
        spec = RopeSpec(cfg.hd, cfg.rope_theta)
        q = apply_rope(q, positions, spec)
        k = apply_rope(k, positions, spec)
    o = flash_attention(
        q, k, v, kind=kind, window=cfg.window, prefix_len=prefix_len,
        softcap_val=cfg.logit_softcap,
    )
    B, S = x.shape[:2]
    o = o.reshape(B, S, cfg.n_heads * cfg.hd)
    return x + dense(params["o"], o)


def attention_decode(
    params: dict,
    cfg: ModelConfig,
    x: jnp.ndarray,             # (B, 1, D)
    cache: dict,                # {"k","v": (B,S,Hkv,hd)[, "scale": (B,S,Hkv,2)]}
    idx: jnp.ndarray,           # scalar int32 — tokens decoded so far
    *,
    local: bool = False,        # cache is a ring buffer of exactly window size
    enc_out: Optional[jnp.ndarray] = None,
    rope: bool = True,
) -> tuple[jnp.ndarray, dict]:
    h = rms_norm(params["ln"], x)
    if enc_out is not None:
        # cross-attention: static encoder KV, recomputed (nothing cached)
        src = rms_norm(params["ln_kv"], enc_out)
        q = dense(params["q"], h)
        k = dense(params["k"], src)
        v = dense(params["v"], src)
        o = decode_attention(q, k, v, jnp.asarray(k.shape[1]),
                             softcap_val=cfg.logit_softcap)
        new_cache = cache
    else:
        q, k, v = _qkv(params, cfg, h)
        if rope:
            spec = RopeSpec(cfg.hd, cfg.rope_theta)
            pos = jnp.broadcast_to(idx.astype(jnp.int32), (x.shape[0], 1))
            q = apply_rope(q, pos, spec)
            k = apply_rope(k, pos, spec)
        S = cache["k"].shape[1]
        slot = idx % S if local else idx            # ring buffer for local
        cur = jnp.minimum(idx + 1, S) if local else idx + 1
        if "scale" in cache:                        # int8 KV quantization
            kq, ksc = _quantize_int8(k)
            vq, vsc = _quantize_int8(v)
            k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, slot, 1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, slot, 1)
            sc = jnp.stack([ksc[:, 0], vsc[:, 0]], axis=-1)[:, None]  # (B,1,Hkv,2)
            scale = jax.lax.dynamic_update_slice_in_dim(cache["scale"], sc, slot, 1)
            new_cache = {"k": k_cache, "v": v_cache, "scale": scale}
            o = decode_attention(q, k_cache, v_cache, cur,
                                 softcap_val=cfg.logit_softcap, kv_scale=scale)
        else:
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), slot, 1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), slot, 1)
            new_cache = {"k": k_cache, "v": v_cache}
            o = decode_attention(q, k_cache, v_cache, cur,
                                 softcap_val=cfg.logit_softcap)
    B = x.shape[0]
    o = o.reshape(B, 1, cfg.n_heads * cfg.hd)
    return x + dense(params["o"], o), new_cache


def _quantize_int8(t: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(B,1,H,hd) -> int8 values + per (B,1,H) scale."""
    amax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1, keepdims=False)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.round(t.astype(jnp.float32) / scale[..., None]).astype(jnp.int8)
    return q, scale


# --------------------------------------------------------------------------
# MLP (SwiGLU)
# --------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    D = cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "ln": init_rms_norm(D),
        "wi": init_dense(ks[0], D, F),
        "wg": init_dense(ks[1], D, F),
        "wo": init_dense(ks[2], F, D, scale=F ** -0.5),
    }


def mlp_apply(params: dict, x: jnp.ndarray, *, residual: bool = True) -> jnp.ndarray:
    h = rms_norm(params["ln"], x)
    y = dense(params["wo"], jax.nn.silu(dense(params["wg"], h)) * dense(params["wi"], h))
    return x + y if residual else y


# --------------------------------------------------------------------------
# Embedding / unembedding
# --------------------------------------------------------------------------

def init_embedding(key, cfg: ModelConfig) -> dict:
    V = cfg.vocab_pad
    p = {"tok": jax.random.normal(key, (V, cfg.d_model), jnp.float32)}
    if not cfg.tied_embeddings:
        p["out"] = jax.random.normal(
            jax.random.fold_in(key, 1), (V, cfg.d_model), jnp.float32
        ) * cfg.d_model ** -0.5
    return p


def embed_apply(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
                dtype=jnp.bfloat16) -> jnp.ndarray:
    e = params["tok"].astype(dtype)[tokens]
    return e * jnp.asarray(cfg.d_model ** 0.5, dtype)


def unembed_apply(params: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    table = params.get("out", params["tok"]).astype(x.dtype)
    logits = jnp.einsum("bsd,vd->bsv", x, table)
    if cfg.logit_softcap > 0.0:
        logits = softcap(logits, cfg.logit_softcap)
    return logits
