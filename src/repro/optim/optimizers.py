"""Optimizers, hand-rolled (no optax offline) with FSDP-friendly state.

Every optimizer's state is a dict of pytrees **mirroring the param tree**
(``{"m": like_params, "v": like_params, "step": scalar}``), so optimizer
state inherits the parameter PartitionSpecs unchanged — ZeRO-3 for free.

Adafactor keeps a factored second moment (row/col means) for ≥2-D params:
for arctic-480b the AdamW moments alone would exceed a 256-chip pod, the
factored state is ~0.1% of it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "adamw", "adafactor", "sgd_momentum",
           "clip_by_global_norm", "cosine_schedule", "get_optimizer"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]   # (grads, state, params) -> (new_params, new_state)
    name: str = "opt"


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * (step + 1) / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return lr


def adamw(lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          schedule: Optional[Callable] = None) -> Optimizer:
    def init(params):
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = schedule(step) if schedule else lr

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / (1 - b1 ** step.astype(jnp.float32))
            vh = v / (1 - b2 ** step.astype(jnp.float32))
            u = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_p, {"m": new_m, "v": new_v, "step": step}

    return Optimizer(init, update, "adamw")


def sgd_momentum(lr=1e-2, momentum=0.9) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        def upd(g, m, p):
            m = momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m
        out = jax.tree.map(upd, grads, state["m"], params)
        new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_p, {"m": new_m, "step": state["step"] + 1}

    return Optimizer(init, update, "sgd")


def adafactor(lr=1e-2, decay=0.8, eps=1e-30, clip_threshold=1.0,
              weight_decay=0.0, schedule: Optional[Callable] = None) -> Optimizer:
    """Factored second moment for ndim>=2 (factored over the last two dims),
    full second moment for vectors.  No first moment (momentum-free)."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def one(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return {"f": jax.tree.map(one, params,
                                  is_leaf=lambda x: isinstance(x, jnp.ndarray)),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        beta = 1.0 - step.astype(jnp.float32) ** -decay
        lr_t = schedule(step) if schedule else lr

        def upd(g, f, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                vr = beta * f["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * f["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
                u = g / jnp.sqrt(jnp.maximum(
                    vr[..., None] * vc[..., None, :] / denom[..., None], eps))
                new_f = {"vr": vr, "vc": vc}
            else:
                v = beta * f["v"] + (1 - beta) * g2
                u = g / jnp.sqrt(jnp.maximum(v, eps))
                new_f = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), new_f

        out = jax.tree_util.tree_map_with_path(
            lambda path, g, p: upd(g, _get(state["f"], path), p), grads, params)
        new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_f = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_p, {"f": new_f, "step": step}

    return Optimizer(init, update, "adafactor")


def _get(tree, path):
    for p in path:
        key = p.key if hasattr(p, "key") else p.idx
        tree = tree[key]
    return tree


def get_optimizer(name: str, lr: float = 3e-4, total_steps: int = 10_000,
                  **kw) -> Optimizer:
    sched = cosine_schedule(lr, min(100, total_steps // 10), total_steps)
    if name == "adamw":
        return adamw(lr, schedule=sched, **kw)
    if name == "adafactor":
        return adafactor(lr, schedule=sched, **kw)
    if name == "sgd":
        return sgd_momentum(lr, **kw)
    if name == "tripre":
        from .tripre import tripre
        return tripre(lr, schedule=sched, **kw)
    raise ValueError(name)
