from .optimizers import (Optimizer, adamw, adafactor, sgd_momentum,
                         clip_by_global_norm, cosine_schedule, get_optimizer)
from .tripre import tripre

__all__ = ["Optimizer", "adamw", "adafactor", "sgd_momentum", "tripre",
           "clip_by_global_norm", "cosine_schedule", "get_optimizer"]
