"""``tripre`` — triangular-solve-preconditioned optimizer.

The paper's kernel as a first-class *training* feature: a Shampoo-lite
second-order method whose inverse-root application is replaced by two
sparse triangular solves.

Per 2-D parameter W (d_in × d_out), maintain a Gram accumulator
``G ← β G + (1-β) g gᵀ`` over the smaller dimension, sparsified to a banded
pattern (keep a ``band``-wide diagonal band — the IC(0)-style pattern).  Each
update factors ``G + λI ≈ L Lᵀ`` (incomplete Cholesky on the band) and
preconditions the gradient by solving

    L y = g,   Lᵀ z = y            (two SpTRSVs)

with the **level-set executor from repro.core** — including equation
rewriting when the band structure produces thin levels.  For banded L the
dependency DAG is near-chain, i.e. exactly the regime the paper targets.

This is deliberately a demonstration-grade optimizer (small/medium models;
the factorization runs on host at refresh steps), wired into train.py via
``--optimizer tripre`` and exercised by tests + the `examples/tripre_lm.py`
driver.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .optimizers import Optimizer

__all__ = ["tripre", "banded_ichol", "make_banded_solvers"]


def banded_ichol(G: np.ndarray, band: int, shift: float = 1e-3) -> np.ndarray:
    """Incomplete Cholesky restricted to a band; returns dense banded L."""
    n = G.shape[0]
    A = G + shift * np.eye(n) * max(np.trace(G) / n, 1.0)
    L = np.zeros_like(A)
    for i in range(n):
        lo = max(0, i - band)
        for j in range(lo, i + 1):
            s = A[i, j] - L[i, lo:j] @ L[j, lo:j]
            if j < i:
                L[i, j] = s / L[j, j] if L[j, j] != 0 else 0.0
            else:
                L[i, i] = np.sqrt(max(s, 1e-12))
    return L


def make_banded_solvers(L_np: np.ndarray, *, use_rewrite: bool = True):
    """Build matrix-specialized forward/backward solvers for banded L using
    the paper pipeline (level sets + equation rewriting + codegen)."""
    from repro.core.csr import from_dense
    from repro.core.rewrite import RewriteConfig
    from repro.core.solver import SpTRSV

    L = from_dense(L_np)
    Lt = from_dense(L_np.T.copy())
    # upper-triangular solve == lower-triangular solve on the reversed system
    P = np.arange(L_np.shape[0])[::-1]
    Lt_rev = from_dense(L_np.T[np.ix_(P, P)].copy())
    rw = RewriteConfig(thin_threshold=2, max_fill_ratio=4.0) if use_rewrite else None
    fwd = SpTRSV.build(L, strategy="levelset", rewrite=rw)
    bwd = SpTRSV.build(Lt_rev, strategy="levelset", rewrite=rw)

    def solve(g: jnp.ndarray) -> jnp.ndarray:
        y = fwd.solve(g)
        z_rev = bwd.solve(y[::-1])
        return z_rev[::-1]

    del Lt
    return solve, fwd, bwd


def tripre(lr=3e-4, b1=0.9, beta_g=0.95, band: int = 8,
           refresh_every: int = 20, max_dim: int = 4096,
           weight_decay: float = 0.0,
           schedule: Optional[Callable] = None) -> Optimizer:
    """Momentum + banded-Gram triangular preconditioning.

    State: momentum m (like params), Gram G per eligible 2-D param (d×d on
    the smaller side, d <= max_dim), step counter.  The L factors live
    host-side in a closure cache keyed by param path, refreshed every
    ``refresh_every`` steps (host callback pattern — factorization is a
    preprocessing step, exactly like the paper's matrix-analysis module).
    """
    cache: dict = {}

    def eligible(p):
        return p.ndim == 2 and min(p.shape) <= max_dim

    def init(params):
        def gram(p):
            if eligible(p):
                d = min(p.shape)
                return jnp.zeros((d, d), jnp.float32)
            return jnp.zeros((0, 0), jnp.float32)
        return {
            "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "G": jax.tree.map(gram, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        """NOTE: not fully jittable (host factorization at refresh); the
        train loop calls tripre outside jit or via io_callback — documented
        trade-off of the demonstration optimizer."""
        step = int(state["step"]) + 1
        lr_t = float(schedule(jnp.asarray(step)) if schedule else lr)

        flat_g, treedef = jax.tree_util.tree_flatten_with_path(grads)
        flat_p = jax.tree_util.tree_leaves(params)
        flat_m = jax.tree_util.tree_leaves(state["m"])
        flat_G = jax.tree_util.tree_leaves(state["G"])

        new_p, new_m, new_G = [], [], []
        for (path, g), p, m, G in zip(flat_g, flat_p, flat_m, flat_G):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            u = m
            if eligible(p):
                gm = g if p.shape[0] <= p.shape[1] else g.T  # (d, big)
                G = beta_g * G + (1 - beta_g) * (gm @ gm.T) / gm.shape[1]
                key = jax.tree_util.keystr(path)
                if step % refresh_every == 1 or key not in cache:
                    L_np = banded_ichol(np.asarray(jax.device_get(G)), band)
                    solve, *_ = make_banded_solvers(L_np)
                    cache[key] = jax.jit(jax.vmap(solve, in_axes=1, out_axes=1))
                mm = m if p.shape[0] <= p.shape[1] else m.T
                um = cache[key](mm)
                u = um if p.shape[0] <= p.shape[1] else um.T
                # trust-region: rescale to momentum norm
                u = u * (jnp.linalg.norm(m) / jnp.maximum(jnp.linalg.norm(u), 1e-12))
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            new_p.append((p.astype(jnp.float32) - lr_t * u).astype(p.dtype))
            new_m.append(m)
            new_G.append(G)

        unflat = jax.tree_util.tree_unflatten
        return (
            unflat(treedef, new_p),
            {"m": unflat(treedef, new_m), "G": unflat(treedef, new_G),
             "step": jnp.asarray(step, jnp.int32)},
        )

    return Optimizer(init, update, "tripre")
