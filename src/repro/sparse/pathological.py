"""Pathological triangular patterns for the differential fuzz harness.

Each generator is seeded and deterministic, and targets a structural corner
the regular suite's matrices do not reach:

``arrow``           column 0 dense + a dense last row: two-level DAG with one
                    maximal-fan-in row (K spans the whole matrix)
``dense_last_row``  identity apart from one dense final row — the widest
                    possible single-slab gather over an otherwise empty DAG
``bidiag_chain``    strict bidiagonal chain with random skip links: maximal
                    level count, 1-row levels (serial worst case)
``singleton_ladder``interleaved 1-row chains of random length anchored at
                    random earlier rows — runs of singleton levels, the
                    degenerate thin-level shape below even lung2's pairs
``power_law``       row degree ~ Zipf, preferential attachment to low ids:
                    a few huge rows over a mostly-sparse DAG (bucketing and
                    gather-unroll stress)
``near_singular``   diagonal magnitudes log-uniform over ~9 decades with a
                    few entries at the pivot-tolerance floor — conditioning
                    and pivot-skip stress
``jagged_rows``     alternating diagonal-only / far-deps-only rows — no two
                    adjacent rows share structure under any relaxation
                    below 1.0, so supernode amalgamation finds nothing (the
                    blocked executor's all-singleton degenerate case)
``extreme_scale``   diagonal magnitudes pinned at the fp32 format's edges
                    (~10^±38, plus mid decades): every value is exactly
                    representable in float64 but overflows/underflows a
                    float32 pipeline — the storage-precision stress case the
                    guarded execution layer's verification exists to catch
``denormal_pivot``  a few pivots at the float32 smallest subnormal (~1.4e-45,
                    a perfectly normal float64): flush-to-zero or
                    reduced-precision storage turns them into zero pivots
                    while the float64 oracle solves cleanly

All are lower-triangular with nonzero diagonals (solvable); ``near_singular``,
``extreme_scale`` and ``denormal_pivot`` are ill-conditioned by design, so
comparisons against an oracle must use the componentwise residual criterion
rather than forward error (see ``diag_condition`` and the fuzz harness's
``RESIDUAL_PATTERNS``).
"""
from __future__ import annotations

import numpy as np

from repro.core.csr import CSRMatrix, from_coo

__all__ = ["PATHOLOGICAL_PATTERNS", "pathological", "diag_condition"]


def _finalize(rows, cols, vals, n, dtype):
    return from_coo(rows, cols, np.asarray(vals, dtype=dtype), (n, n))


def _arrow(n: int, rng: np.random.Generator, dtype) -> CSRMatrix:
    rows = list(range(n)) + list(range(1, n - 1)) + [n - 1] * (n - 1)
    cols = list(range(n)) + [0] * (n - 2) + list(range(n - 1))
    vals = ([4.0 + rng.random()] + list(4.0 + rng.random(n - 1))
            + list(rng.normal(size=n - 2) * 0.3)
            + list(rng.normal(size=n - 1) * 0.1))
    return _finalize(rows, cols, vals, n, dtype)


def _dense_last_row(n: int, rng: np.random.Generator, dtype) -> CSRMatrix:
    rows = list(range(n)) + [n - 1] * (n - 1)
    cols = list(range(n)) + list(range(n - 1))
    vals = list(4.0 + rng.random(n)) + list(rng.normal(size=n - 1) * 0.2)
    return _finalize(rows, cols, vals, n, dtype)


def _bidiag_chain(n: int, rng: np.random.Generator, dtype) -> CSRMatrix:
    rows = list(range(n)) + list(range(1, n))
    cols = list(range(n)) + list(range(n - 1))
    vals = list(4.0 + rng.random(n)) + list(rng.normal(size=n - 1) * 0.5)
    # occasional skip link back to a random ancestor
    for i in range(2, n):
        if rng.random() < 0.2:
            j = int(rng.integers(0, i - 1))
            rows.append(i)
            cols.append(j)
            vals.append(rng.normal() * 0.2)
    return _finalize(rows, cols, vals, n, dtype)


def _singleton_ladder(n: int, rng: np.random.Generator, dtype) -> CSRMatrix:
    rows, cols, vals = list(range(n)), list(range(n)), list(4.0 + rng.random(n))
    i = 1
    while i < n:
        length = int(rng.integers(2, 9))
        anchor = int(rng.integers(0, i))
        prev = anchor
        for _ in range(length):
            if i >= n:
                break
            rows.append(i)
            cols.append(prev)
            vals.append(rng.normal() * 0.4)
            prev = i
            i += 1
    return _finalize(rows, cols, vals, n, dtype)


def _power_law(n: int, rng: np.random.Generator, dtype) -> CSRMatrix:
    rows, cols, vals = list(range(n)), list(range(n)), list(4.0 + rng.random(n))
    for i in range(1, n):
        k = min(i, int(rng.zipf(1.6)))
        if k <= 0:
            continue
        # preferential attachment to low row ids (power-law in-degree too)
        deps = np.unique(
            (rng.random(k) ** 2 * i).astype(np.int64).clip(0, i - 1))
        for j in deps:
            rows.append(i)
            cols.append(int(j))
            vals.append(rng.normal() * 0.25)
    return _finalize(rows, cols, vals, n, dtype)


def _near_singular(n: int, rng: np.random.Generator, dtype) -> CSRMatrix:
    rows, cols = list(range(n)), list(range(n))
    # diagonal magnitudes spread over ~9 decades, a few pinned at the floor
    expo = rng.uniform(-6.0, 3.0, size=n)
    expo[rng.integers(0, n, size=max(1, n // 50))] = -6.0
    diag = (10.0 ** expo) * np.where(rng.random(n) < 0.5, -1.0, 1.0)
    vals = list(diag)
    for i in range(1, n):
        for j in rng.choice(i, size=min(i, int(rng.integers(1, 4))),
                            replace=False):
            rows.append(i)
            cols.append(int(j))
            # off-diagonals scaled to the row's diagonal keep the system
            # solvable but heavily graded
            vals.append(rng.normal() * 0.3 * abs(diag[i]))
    return _finalize(rows, cols, vals, n, dtype)


def _jagged_rows(n: int, rng: np.random.Generator, dtype) -> CSRMatrix:
    """No-amalgamatable-rows pattern: odd rows are diagonal-only, even rows
    carry several dependencies that deliberately exclude row ``i-1``.  Every
    adjacent pair then mismatches by at least max(|A|, |B|) + 1 (a diag-only
    predecessor never appears in its successor's columns and vice versa), so
    the supernode similarity criterion fails for ANY relaxation below 1.0 —
    detection must degrade to all-singleton blocks and the blocked executor
    to the scalar-row case."""
    rows, cols, vals = list(range(n)), list(range(n)), list(4.0 + rng.random(n))
    for i in range(2, n, 2):
        for j in rng.choice(i - 1, size=min(i - 1, 3), replace=False):
            rows.append(i)
            cols.append(int(j))
            vals.append(rng.normal() * 0.3)
    return _finalize(rows, cols, vals, n, dtype)


def _extreme_scale(n: int, rng: np.random.Generator, dtype) -> CSRMatrix:
    """Diagonal magnitudes at the float32 format's extremes: ~10^±38 (right
    at fp32 overflow / underflow), with mid decades mixed in.  Off-diagonals
    are scaled to each row's own diagonal, which keeps the system solvable
    (|x_i| tops out near 10^38·poly(n), far inside float64 range) while any
    float32 storage of the values would overflow or flush to zero."""
    rows, cols = list(range(n)), list(range(n))
    expo = rng.choice(np.array([-38.0, -19.0, 0.0, 19.0, 38.0]), size=n)
    expo += rng.uniform(-0.5, 0.5, size=n)
    diag = (10.0 ** expo) * np.where(rng.random(n) < 0.5, -1.0, 1.0)
    vals = list(diag)
    for i in range(1, n):
        for j in rng.choice(i, size=min(i, int(rng.integers(1, 4))),
                            replace=False):
            rows.append(i)
            cols.append(int(j))
            vals.append(rng.normal() * 0.3 * abs(diag[i]))
    return _finalize(rows, cols, vals, n, dtype)


def _denormal_pivot(n: int, rng: np.random.Generator, dtype) -> CSRMatrix:
    """Well-scaled factor apart from a few pivots at the float32 smallest
    subnormal (~1.4e-45) — a perfectly ordinary float64 number the oracle
    divides by without drama, but one that flushes to exactly zero in bf16
    and sits on the flush-to-zero boundary of fp32 pipelines.  Row 0 is
    never hit (same rationale as the fault harness: a broken root proves
    nothing about propagation)."""
    rows, cols = list(range(n)), list(range(n))
    diag = (4.0 + rng.random(n)) * np.where(rng.random(n) < 0.5, -1.0, 1.0)
    k = max(2, n // 24)
    picked = 1 + rng.choice(n - 1, size=k, replace=False)
    diag[picked] = (np.float64(np.finfo(np.float32).smallest_subnormal)
                    * (1.0 + rng.random(k))
                    * np.sign(diag[picked]))
    vals = list(diag)
    for i in range(1, n):
        for j in rng.choice(i, size=min(i, int(rng.integers(1, 4))),
                            replace=False):
            rows.append(i)
            cols.append(int(j))
            vals.append(rng.normal() * 0.3)
    return _finalize(rows, cols, vals, n, dtype)


PATHOLOGICAL_PATTERNS = {
    "arrow": _arrow,
    "dense_last_row": _dense_last_row,
    "bidiag_chain": _bidiag_chain,
    "singleton_ladder": _singleton_ladder,
    "power_law": _power_law,
    "near_singular": _near_singular,
    "jagged_rows": _jagged_rows,
    "extreme_scale": _extreme_scale,
    "denormal_pivot": _denormal_pivot,
}


def pathological(kind: str, n: int = 96, seed: int = 0,
                 dtype=np.float64) -> CSRMatrix:
    """Build the named pathological pattern (see module docstring)."""
    gen = PATHOLOGICAL_PATTERNS[kind]
    return gen(n, np.random.default_rng(seed), dtype).validate()


def diag_condition(L: CSRMatrix) -> float:
    """max|diag| / min|diag| — a cheap lower bound on the triangular
    condition number, used to scale fuzz tolerances for ``near_singular``."""
    d = np.abs(L.diagonal())
    return float(d.max() / d.min())
