"""Synthetic sparse lower-triangular matrix suite.

The SuiteSparse collection is not available offline, so we generate matrices
with controlled level structure.  ``lung2_like`` mimics the paper's lung2
(109,460 rows, 492,564 nnz, 478 levels, 94% of levels with only 2 rows): a
few fat wavefronts interleaved with long runs of thin 2-row levels.

All generators produce diagonally-dominant matrices so forward substitution
is well-conditioned (tight allclose in tests).
"""
from __future__ import annotations

import numpy as np

from repro.core.csr import CSRMatrix, from_coo

__all__ = [
    "random_lower",
    "banded_lower",
    "chain_matrix",
    "lung2_like",
    "poisson2d",
    "ic0_factor",
    "refresh_values",
    "serve_traffic",
]


def _finalize(rows, cols, vals, n, dtype):
    return from_coo(rows, cols, np.asarray(vals, dtype=dtype), (n, n))


def random_lower(
    n: int, avg_offdiag: float = 3.0, seed: int = 0, dtype=np.float64
) -> CSRMatrix:
    """Random lower-triangular, ~avg_offdiag nonzeros below the diagonal per
    row, strongly diagonally dominant."""
    rng = np.random.default_rng(seed)
    rows, cols, vals = list(range(n)), list(range(n)), list(4.0 + rng.random(n))
    for i in range(1, n):
        k = min(i, rng.poisson(avg_offdiag))
        if k:
            deps = rng.choice(i, size=k, replace=False)
            for j in deps:
                rows.append(i)
                cols.append(int(j))
                vals.append(rng.normal() * 0.3)
    return _finalize(rows, cols, vals, n, dtype)


def banded_lower(n: int, bandwidth: int = 8, fill: float = 0.5, seed: int = 0,
                 dtype=np.float64) -> CSRMatrix:
    rng = np.random.default_rng(seed)
    rows, cols, vals = list(range(n)), list(range(n)), list(4.0 + rng.random(n))
    for i in range(n):
        lo = max(0, i - bandwidth)
        for j in range(lo, i):
            if rng.random() < fill:
                rows.append(i)
                cols.append(j)
                vals.append(rng.normal() * 0.3)
    return _finalize(rows, cols, vals, n, dtype)


def chain_matrix(n: int, dtype=np.float64) -> CSRMatrix:
    """Pure serial chain: row i depends only on row i-1.  n levels — the
    worst case for level-set SpTRSV."""
    rows = list(range(n)) + list(range(1, n))
    cols = list(range(n)) + list(range(0, n - 1))
    vals = [4.0] * n + [0.5] * (n - 1)
    return _finalize(rows, cols, vals, n, dtype)


def lung2_like(
    scale: float = 1.0,
    fat_levels: int = 29,
    fat_rows: int = 3770,
    thin_run: int = 16,
    seed: int = 0,
    dtype=np.float64,
) -> CSRMatrix:
    """Structural twin of lung2 (paper §V).

    Pattern: ``fat_levels`` fat wavefronts; between consecutive fat levels a
    run of ``thin_run`` thin levels of 2 chained rows each.  At scale=1.0:
    ~110k rows, ~480 levels, ~94% of levels thin with 2 rows, ~4.5 nnz/row.
    Thin rows depend on the previous thin pair (chain) plus a row of the
    nearest fat level, so equation rewriting lifts them with bounded fill.
    """
    rng = np.random.default_rng(seed)
    fat_rows = max(4, int(fat_rows * scale))
    rows, cols, vals = [], [], []
    next_id = 0
    prev_fat: np.ndarray | None = None
    prev_thin: list[int] = []

    def add(i, j, v):
        rows.append(i)
        cols.append(j)
        vals.append(v)

    for _ in range(fat_levels):
        # --- fat wavefront.  Every fat row depends on the preceding thin
        # run's tail pair (the whole wavefront waits for the thin chain —
        # this is what makes lung2 "very serial") plus 1-3 rows of the
        # previous fat wavefront.
        ids = np.arange(next_id, next_id + fat_rows)
        next_id += fat_rows
        for i in ids:
            add(i, i, 4.0 + rng.random())
            if prev_thin:
                add(i, int(prev_thin[-2 + int(rng.integers(0, 2))]), rng.normal() * 0.25)
            if prev_fat is not None:
                k = int(rng.integers(1, 4))
                for j in rng.choice(prev_fat, size=min(k, prev_fat.size), replace=False):
                    add(i, int(j), rng.normal() * 0.25)
        prev_fat = ids
        # --- thin run: pairs of rows, each pair chained to the previous pair
        prev_thin = []
        pair_prev: list[int] = []
        for _t in range(thin_run):
            pair = [next_id, next_id + 1]
            next_id += 2
            for idx, i in enumerate(pair):
                add(i, i, 4.0 + rng.random())
                if pair_prev:
                    add(i, pair_prev[idx], rng.normal() * 0.25)
                else:
                    j = int(rng.choice(prev_fat))
                    add(i, j, rng.normal() * 0.25)
                # occasional extra dep into the fat level keeps nnz/row ~4.5
                if rng.random() < 0.5:
                    j = int(rng.choice(prev_fat))
                    if j != i:
                        add(i, j, rng.normal() * 0.1)
            pair_prev = pair
            prev_thin.extend(pair)
    return _finalize(rows, cols, vals, next_id, dtype)


def refresh_values(L: CSRMatrix, seed: int = 0, scale: float = 0.3) -> np.ndarray:
    """Fresh well-conditioned values on ``L``'s sparsity pattern — the
    numeric-refactorization payload a serving tier refreshes solvers with.
    Off-diagonal entries are ``N(0, scale)``; diagonal entries (the last
    stored entry of each lower-triangular row) are shifted away from zero
    so forward substitution stays well-conditioned."""
    rng = np.random.default_rng(seed)
    data = (rng.normal(size=L.nnz) * scale).astype(L.dtype, copy=False)
    diag = L.indptr[1:] - 1
    data[diag] = np.abs(data[diag]) + 1.0
    return data


def serve_traffic(
    *,
    num_patterns: int = 3,
    num_tenants: int = 4,
    num_events: int = 200,
    refresh_fraction: float = 0.15,
    rotate_fraction: float = 0.05,
    transpose_fraction: float = 0.25,
    n: int = 96,
    avg_offdiag: float = 3.0,
    seed: int = 0,
    dtype=np.float64,
):
    """Mixed cold/warm multi-tenant workload for the solve service.

    Generates ``num_patterns`` distinct sparsity patterns (same size,
    different structure — so the registry key genuinely distinguishes
    them) and a deterministic event stream over ``num_tenants`` tenants:

    * ``{"op": "register", "tenant", "pattern", "matrix"}`` — tenant binds
      to a factor (first touch of a pattern is a registry *miss* → cold
      path; later touches are *hits*).  Rotation events re-register a
      tenant onto another pattern, which is what churns the LRU.
    * ``{"op": "solve", "tenant", "b", "transpose"}`` — one RHS vector.
    * ``{"op": "refresh", "tenant", "values"}`` — same-pattern numeric
      refresh (:func:`refresh_values` payload), the warm path.

    Returns ``(patterns, events)``; every tenant's first event is its
    initial ``register``.  The stream is reproducible from ``seed`` — the
    serve benchmark and the service tests share it.
    """
    if num_patterns < 1 or num_tenants < 1:
        raise ValueError(
            f"need >= 1 pattern and tenant; got {num_patterns} pattern(s), "
            f"{num_tenants} tenant(s)")
    rng = np.random.default_rng(seed)
    patterns = [
        random_lower(n, avg_offdiag=avg_offdiag, seed=seed + 101 * p,
                     dtype=dtype)
        for p in range(num_patterns)
    ]
    events = []
    bound = {}
    values_seed = seed + 7_000

    def register(t: int, p: int):
        nonlocal values_seed
        values_seed += 1
        m = patterns[p]
        mat = CSRMatrix(m.indptr, m.indices,
                        refresh_values(m, seed=values_seed), m.shape)
        bound[t] = p
        events.append({"op": "register", "tenant": f"tenant-{t}",
                       "pattern": p, "matrix": mat})

    for t in range(num_tenants):
        register(t, t % num_patterns)
    for _ in range(num_events):
        t = int(rng.integers(num_tenants))
        u = rng.random()
        if u < rotate_fraction and num_patterns > 1:
            p = int(rng.integers(num_patterns - 1))
            register(t, p if p < bound[t] else p + 1)  # a different pattern
        elif u < rotate_fraction + refresh_fraction:
            values_seed += 1
            m = patterns[bound[t]]
            events.append({"op": "refresh", "tenant": f"tenant-{t}",
                           "values": refresh_values(m, seed=values_seed)})
        else:
            b = rng.normal(size=n).astype(dtype, copy=False)
            events.append({"op": "solve", "tenant": f"tenant-{t}", "b": b,
                           "transpose": bool(rng.random()
                                             < transpose_fraction)})
    return patterns, events


def poisson2d(nx: int, ny: int, dtype=np.float64) -> CSRMatrix:
    """5-point Laplacian on an nx*ny grid (SPD), returned as full matrix in
    CSR (not triangular) — input to :func:`ic0_factor`."""
    n = nx * ny
    rows, cols, vals = [], [], []
    for y in range(ny):
        for x in range(nx):
            i = y * nx + x
            rows.append(i); cols.append(i); vals.append(4.0)
            for dx, dy in ((-1, 0), (1, 0), (0, -1), (0, 1)):
                xx, yy = x + dx, y + dy
                if 0 <= xx < nx and 0 <= yy < ny:
                    j = yy * nx + xx
                    rows.append(i); cols.append(j); vals.append(-1.0)
    return _finalize(rows, cols, vals, n, dtype)


def ic0_factor(A: CSRMatrix, shift: float = 0.05) -> CSRMatrix:
    """Incomplete Cholesky IC(0): lower factor L with the sparsity pattern of
    tril(A), A_shifted = A + shift*diag(A).  Classic SpTRSV workload (its
    level sets are the grid wavefronts)."""
    n = A.n
    dense_rows = {}
    for i in range(n):
        c, v = A.row(i)
        keep = c <= i
        dense_rows[i] = dict(zip(c[keep].tolist(), v[keep].tolist()))
        dense_rows[i][i] = dense_rows[i][i] * (1.0 + shift)
    Lrows = [dict() for _ in range(n)]
    for i in range(n):
        pat = sorted(dense_rows[i].keys())
        for j in pat:
            s = dense_rows[i][j]
            # s -= sum_k L[i,k] * L[j,k]  over shared k < j
            li, lj = Lrows[i], Lrows[j]
            small, big = (li, lj) if len(li) < len(lj) else (lj, li)
            for k, v in small.items():
                if k < j and k in big:
                    s -= li[k] * lj[k]
            if j < i:
                Lrows[i][j] = s / Lrows[j][j]
            else:
                Lrows[i][i] = float(np.sqrt(max(s, 1e-8)))
    rows, cols, vals = [], [], []
    for i in range(n):
        for j in sorted(Lrows[i]):
            rows.append(i); cols.append(j); vals.append(Lrows[i][j])
    return _finalize(rows, cols, vals, n, A.dtype)
