"""Fault-injection harness for the guarded execution layer.

Every fault a production refresh stream can deliver, as a deterministic
seeded generator — CI uses these (``tests/test_guard.py``) to prove each
:class:`repro.core.guard.GuardConfig` breakdown path actually fires instead
of trusting that it would:

``zero_pivot``       ``count`` diagonal entries set to exactly 0.0 — the
                     substitution divides produce inf/NaN downstream
``tiny_pivot``       diagonal entries at the dtype's smallest subnormal —
                     denormal divides that overflow the quotient
``perturb_pivot``    diagonal entries scaled by ``factor`` (default 1e-8) —
                     finite but wildly wrong pivots, the silent-corruption
                     case residual verification exists for
``nan_slab``         a contiguous run of ``slab`` stored values set to NaN
``inf_slab``         same run set to ±inf alternating
``denormal_values``  a contiguous run of off-diagonal values scaled into the
                     subnormal range — exercises flush-to-zero divergence
                     between storage precisions
``wrong_pattern``    a structurally different matrix with the same shape and
                     near-identical values — what ``refresh`` must REJECT
                     (pattern identity check), not absorb

Value faults (:func:`inject_values`) return a new ``data`` array aligned
with the source factor's CSR storage — feed it to
``SpTRSV.refresh(data, validate=False)`` to push the fault past the O(nnz)
validation scan and into the guard's breakdown machinery (with
``validate=True`` the scan rejects non-finite/zero-pivot payloads outright,
which is its own tested path).  Diagonal positions assume lower-triangular
CSR with sorted column indices (the diagonal is the last stored entry of
each row), matching :class:`repro.core.csr.CSRMatrix` factors.
"""
from __future__ import annotations

import numpy as np

from repro.core.csr import CSRMatrix, from_coo

__all__ = ["FAULT_KINDS", "VALUE_FAULTS", "diag_positions", "inject_values",
           "wrong_pattern"]

VALUE_FAULTS = ("zero_pivot", "tiny_pivot", "perturb_pivot", "nan_slab",
                "inf_slab", "denormal_values")
FAULT_KINDS = VALUE_FAULTS + ("wrong_pattern",)


def diag_positions(L: CSRMatrix) -> np.ndarray:
    """Indices of the diagonal entries inside ``L.data`` (lower-triangular
    CSR with sorted columns: last stored entry of every row)."""
    return np.asarray(L.indptr[1:]) - 1


def inject_values(L: CSRMatrix, kind: str, *, count: int = 2, slab: int = 8,
                  factor: float = 1e-8, seed: int = 0) -> np.ndarray:
    """Return a faulted copy of ``L.data`` (same pattern) for a value-fault
    ``kind`` from :data:`VALUE_FAULTS`.

    ``count`` pivots are hit for the pivot faults; a contiguous run of
    ``slab`` stored entries for the slab faults.  Row 0's pivot is never
    chosen (a broken root makes EVERY strategy fail identically, which
    proves nothing about downstream propagation)."""
    assert kind in VALUE_FAULTS, kind
    rng = np.random.default_rng(seed)
    data = np.array(L.data, copy=True)
    dpos = diag_positions(L)
    if kind in ("zero_pivot", "tiny_pivot", "perturb_pivot"):
        rows = 1 + rng.choice(L.n - 1, size=min(count, L.n - 1),
                              replace=False)
        if kind == "zero_pivot":
            data[dpos[rows]] = 0.0
        elif kind == "tiny_pivot":
            data[dpos[rows]] = np.finfo(data.dtype).smallest_subnormal
        else:
            data[dpos[rows]] = data[dpos[rows]] * factor
        return data
    start = int(rng.integers(0, max(L.nnz - slab, 1)))
    run = np.arange(start, min(start + slab, L.nnz))
    if kind == "nan_slab":
        data[run] = np.nan
    elif kind == "inf_slab":
        data[run] = np.where(np.arange(run.size) % 2 == 0, np.inf, -np.inf)
    else:  # denormal_values: off-diagonal entries only, pivots stay sane
        off = np.setdiff1d(run, dpos, assume_unique=False)
        data[off] = (np.sign(data[off]) + (data[off] == 0)) \
            * np.finfo(data.dtype).smallest_subnormal * 2
    return data


def wrong_pattern(L: CSRMatrix, *, seed: int = 0) -> CSRMatrix:
    """A same-shape factor whose sparsity pattern differs from ``L`` by one
    extra off-diagonal entry (placed in the last row at a column it does not
    already use).  ``refresh`` must reject it with the pattern-identity
    error — silently re-packing values against a stale pattern is exactly
    the corruption class the validation layer exists to stop."""
    assert L.n >= 2, "need at least 2 rows to add an off-diagonal entry"
    rng = np.random.default_rng(seed)
    rows, cols = [], []
    for i in range(L.n):
        for k in range(L.indptr[i], L.indptr[i + 1]):
            rows.append(i)
            cols.append(int(L.indices[k]))
    vals = list(np.asarray(L.data))
    last = L.n - 1
    used = set(L.indices[L.indptr[last]:L.indptr[last + 1]])
    free = [c for c in range(last) if c not in used]
    assert free, "last row is already dense"
    rows.append(last)
    cols.append(int(rng.choice(free)))
    vals.append(0.125)
    return from_coo(rows, cols, np.asarray(vals, dtype=L.dtype),
                    (L.n, L.n))
