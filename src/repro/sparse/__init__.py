from .generate import (
    banded_lower,
    chain_matrix,
    ic0_factor,
    lung2_like,
    poisson2d,
    random_lower,
    refresh_values,
    serve_traffic,
)
from .faults import (
    FAULT_KINDS,
    VALUE_FAULTS,
    diag_positions,
    inject_values,
    wrong_pattern,
)
from .pathological import PATHOLOGICAL_PATTERNS, diag_condition, pathological

__all__ = [
    "FAULT_KINDS",
    "VALUE_FAULTS",
    "diag_positions",
    "inject_values",
    "wrong_pattern",
    "banded_lower",
    "chain_matrix",
    "ic0_factor",
    "lung2_like",
    "poisson2d",
    "random_lower",
    "refresh_values",
    "serve_traffic",
    "PATHOLOGICAL_PATTERNS",
    "diag_condition",
    "pathological",
]
