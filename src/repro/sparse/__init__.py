from .generate import (
    banded_lower,
    chain_matrix,
    ic0_factor,
    lung2_like,
    poisson2d,
    random_lower,
)
from .pathological import PATHOLOGICAL_PATTERNS, diag_condition, pathological

__all__ = [
    "banded_lower",
    "chain_matrix",
    "ic0_factor",
    "lung2_like",
    "poisson2d",
    "random_lower",
    "PATHOLOGICAL_PATTERNS",
    "diag_condition",
    "pathological",
]
