from .generate import (
    banded_lower,
    chain_matrix,
    ic0_factor,
    lung2_like,
    poisson2d,
    random_lower,
)

__all__ = [
    "banded_lower",
    "chain_matrix",
    "ic0_factor",
    "lung2_like",
    "poisson2d",
    "random_lower",
]
