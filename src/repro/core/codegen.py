"""Specialized code generation (paper §IV), adapted to TPU/JAX.

The paper's code generator emits per-level C functions with the matrix
structure *embedded as constants* (no indirect indexing for rewritten rows).
The TPU analogue: we generate, per matrix, a specialized executor whose
XLA/Mosaic program bakes the level structure in at trace time:

* each level is packed into an ELL *slab* — rows sorted by nnz, dependency
  columns/values padded to the level's max row width, stored transposed
  ``(K, R)`` so the row dimension maps to TPU lanes;
* fat levels execute as vectorized gather/FMA/reduce segments (one per level
  — the generated "function per level");
* tiny levels (``R <= unroll_threshold``) are unrolled into scalar ops with
  literal indices and values — the paper's constant-embedding, verbatim;
* the slab index arrays are closure constants, so XLA sees them as literals.

Executors produced here are pure JAX; the Pallas kernels in
:mod:`repro.kernels` consume the same :class:`Schedule`.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .csr import CSRMatrix
from .levels import LevelSets, build_level_sets, compute_upper_levels
from .rewrite import RewriteResult

__all__ = [
    "LevelSlab",
    "Schedule",
    "EllMatrix",
    "GATHER_UNROLL_MAX_K",
    "build_schedule",
    "build_ell",
    "build_offdiag_ell",
    "slab_padded_flops",
    "stack_sub_slabs",
    "serial_arrays",
    "make_serial_solver",
    "make_levelset_solver",
    "make_blocked_solver",
    "make_rhs_transform",
    "ell_spmv",
]

logger = logging.getLogger(__name__)

# Batched gathers are unrolled over the ELL width K into K two-dimensional
# row gathers (see _gather_sum) — ~50x faster on CPU than one (K, R, m)
# gather.  Past this width the unrolled program would bloat compile time, so
# _gather_sum falls back to the single fused 3-D gather (and logs it).
GATHER_UNROLL_MAX_K = 32


# --------------------------------------------------------------------------
# Packed structures
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LevelSlab:
    """One level's rows in padded ELL form, transposed for TPU lanes.

    ``rows`` (R,) row ids;  ``cols``/``vals`` (K, R) with zero-padding
    (col 0 / val 0.0 is a safe no-op gather);  ``diag`` (R,).

    ``sub_rows`` is the slab's intra-slab dependency chain (schedule
    coarsening, :mod:`repro.core.coarsen`): when non-empty it partitions the
    R rows into consecutive *sub-slabs* that must execute back-to-back in
    order — sub-slab ``t`` may depend on rows of sub-slabs ``< t`` — but the
    whole chain forms **one** segment: a single barrier/launch/collective
    covers all of it.  An empty tuple means the classic one-level slab (all
    rows mutually independent).

    ``val_src``/``diag_src`` map each packed value back to its index in the
    source matrix's ``data`` array (-1 for zero padding).  They are the
    symbolic side of value-only numeric refresh (:meth:`SpTRSV.refresh`):
    re-packing a slab for new values with the same sparsity pattern is one
    vectorized gather instead of a re-analysis.
    """

    rows: np.ndarray
    cols: np.ndarray
    vals: np.ndarray
    diag: np.ndarray
    sub_rows: tuple = ()
    val_src: Optional[np.ndarray] = None   # (K, R) int64, -1 = padding
    diag_src: Optional[np.ndarray] = None  # (R,) int64

    @property
    def R(self) -> int:
        return self.rows.shape[0]

    @property
    def K(self) -> int:
        return self.cols.shape[0]

    @property
    def depth(self) -> int:
        """Length of the intra-slab dependency chain (1 = plain level)."""
        return len(self.sub_rows) if self.sub_rows else 1

    def sub_slabs(self):
        """Iterate the chain as plain (depth-1) :class:`LevelSlab` views —
        consumers that need per-wavefront slabs (fused layout, replicated
        distributed execution) remain agnostic to coarsening."""
        if self.depth == 1:
            yield dataclasses.replace(self, sub_rows=())
            return
        off = 0
        for r in self.sub_rows:
            yield LevelSlab(
                rows=self.rows[off : off + r],
                cols=self.cols[:, off : off + r],
                vals=self.vals[:, off : off + r],
                diag=self.diag[off : off + r],
                val_src=None if self.val_src is None
                else self.val_src[:, off : off + r],
                diag_src=None if self.diag_src is None
                else self.diag_src[off : off + r],
            )
            off += r


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Level-set execution schedule for a (possibly rewritten) matrix."""

    n: int
    slabs: List[LevelSlab]
    level_of_row: np.ndarray
    nnz: int

    @property
    def num_levels(self) -> int:
        return len(self.slabs)

    @property
    def num_segments(self) -> int:
        """Barrier-separated execution units.  Every slab — coarsened or not
        — is one segment: one generated code region, one kernel launch, one
        collective.  This is the schedule's synchronization-point count."""
        return len(self.slabs)

    @property
    def total_depth(self) -> int:
        """Sum of intra-slab chain depths = wavefront count actually swept
        (equals the level count of the uncoarsened schedule)."""
        return sum(s.depth for s in self.slabs)

    def perm(self) -> np.ndarray:
        """Schedule-order row permutation: ``perm[p]`` = original row id at
        permuted position ``p``.  Each segment's output rows are a
        *contiguous* slice of the permuted space (see :func:`row_offsets`),
        which is what lets the permuted-space executors replace per-segment
        row scatters with ``lax.dynamic_update_slice``.  Concatenating slab
        row arrays is exact because every row appears in exactly one slab
        and slabs execute in this order."""
        if not self.slabs:
            return np.zeros(0, dtype=np.int64)
        return np.concatenate([s.rows for s in self.slabs]).astype(np.int64)

    def row_offsets(self) -> np.ndarray:
        """(num_segments + 1,) permuted-space start offset of each segment:
        segment ``i`` owns positions ``[row_offsets[i], row_offsets[i+1])``."""
        return np.concatenate(
            [[0], np.cumsum([s.R for s in self.slabs])]).astype(np.int64)

    def padded_flops(self, unroll_threshold: int = 0) -> int:
        """FLOPs actually executed including padding waste (load-balance
        metric — the TPU analogue of idle cores).

        ``unroll_threshold``: plain slabs with that few rows execute as
        constant-embedded scalar code (``_apply_slab_unrolled``) which skips
        zero padding entirely, so they count at their true nnz — without this
        the ``auto`` planner would charge unrolled thin levels for padding
        they never execute.  Coarsened slabs execute ``depth`` uniform
        sub-steps padded to the widest sub-slab."""
        return sum(slab_padded_flops(s, unroll_threshold) for s in self.slabs)


def slab_padded_flops(s: LevelSlab, unroll_threshold: int = 0) -> int:
    """Executed FLOPs of one slab as the executors actually run it: chains
    do ``depth`` uniform sub-steps padded to the widest sub-slab, unrolled
    slabs skip zero padding (true nnz), plain slabs pay the full ELL pad.
    The single source of the per-slab cost — both ``Schedule.padded_flops``
    and the coarsening/planner cost model sum this."""
    if s.depth > 1:
        rmax = max(s.sub_rows)
        return s.depth * (2 * s.K * rmax + rmax)
    if s.R <= unroll_threshold:
        return 2 * int(np.count_nonzero(s.vals)) + s.R
    return 2 * s.K * s.R + s.R


@dataclasses.dataclass(frozen=True)
class EllMatrix:
    """Whole-matrix ELL (used for the RHS operator E and for SpMV).

    ``val_src`` (optional) maps each packed value to its index in the source
    matrix's ``data`` array (-1 padding) — the refresh map for re-packing
    new values of the same pattern in one vectorized gather."""

    cols: np.ndarray  # (K, n)
    vals: np.ndarray  # (K, n)
    val_src: Optional[np.ndarray] = None  # (K, n) int64, -1 = padding

    @property
    def K(self) -> int:
        return self.cols.shape[0]


def _pack_rows(
    L: CSRMatrix, rows: np.ndarray, sort_by_nnz: bool, *, diag_first: bool = False
) -> LevelSlab:
    """Pack the given rows into one ELL slab.

    ``diag_first=False`` assumes lower-triangular storage (diagonal last in
    each row, the forward-solve layout); ``diag_first=True`` assumes
    upper-triangular storage (diagonal first — rows of ``L.transpose()``,
    i.e. columns of ``L``, the backward-solve layout).  Either way the slab
    comes out identical in shape, so every executor downstream is
    direction-agnostic."""
    row_nnz = L.indptr[rows + 1] - L.indptr[rows] - 1  # off-diagonal count
    if sort_by_nnz and rows.size > 1:
        order = np.argsort(row_nnz, kind="stable")
        rows = rows[order]
        row_nnz = row_nnz[order]
    K = max(int(row_nnz.max()) if rows.size else 0, 1)
    R = rows.size
    cols = np.zeros((K, R), dtype=np.int32)
    vals = np.zeros((K, R), dtype=L.dtype)
    diag = np.empty((R,), dtype=L.dtype)
    val_src = np.full((K, R), -1, dtype=np.int64)
    diag_src = np.empty((R,), dtype=np.int64)
    for r, i in enumerate(rows):
        lo, hi = int(L.indptr[int(i)]), int(L.indptr[int(i) + 1])
        c, v = L.indices[lo:hi], L.data[lo:hi]
        if diag_first:
            diag[r] = v[0]
            diag_src[r] = lo
            c, v = c[1:], v[1:]
            src = np.arange(lo + 1, hi, dtype=np.int64)
        else:
            diag[r] = v[-1]
            diag_src[r] = hi - 1
            c, v = c[:-1], v[:-1]
            src = np.arange(lo, hi - 1, dtype=np.int64)
        k = c.size
        cols[:k, r] = c
        vals[:k, r] = v
        val_src[:k, r] = src
    return LevelSlab(rows=rows.astype(np.int32), cols=cols, vals=vals,
                     diag=diag, val_src=val_src, diag_src=diag_src)


def build_schedule(
    L: CSRMatrix,
    levels: Optional[LevelSets] = None,
    *,
    sort_by_nnz: bool = True,
    bucket_pad_ratio: float = 0.0,
    upper: bool = False,
) -> Schedule:
    """Pack each level into ELL slabs.

    ``bucket_pad_ratio`` > 1 splits a level into several slabs so that within
    a slab ``max_nnz <= ratio * max(min_nnz, 1)`` — the paper's "multiple
    functions per thick level", applied to padding: after equation rewriting,
    rewritten rows carry fill-in and a single max-width slab pays their K for
    every native row (measured 3.5x serial slowdown on lung2-like before this
    split; §Perf solver iteration 1).  Slabs of one level stay mutually
    independent — only level boundaries synchronize.

    ``upper=True`` packs an upper-triangular matrix (diagonal stored first
    per row) over its backward-substitution levels — the transpose-solve
    schedule.  Pass ``L.transpose()`` (whose rows are columns of ``L``) plus
    the reverse level sets derived from the forward analysis; the resulting
    slabs feed the *same* executors/kernels as forward schedules.
    """
    if levels is None:
        level = compute_upper_levels(L) if upper else None
        levels = build_level_sets(L, level=level)
    slabs = []
    for rows in levels.rows:
        if bucket_pad_ratio and bucket_pad_ratio > 1.0 and rows.size > 1:
            nnz = L.indptr[rows + 1] - L.indptr[rows] - 1
            order = np.argsort(nnz, kind="stable")
            rows_sorted = rows[order]
            nnz_sorted = nnz[order]
            start = 0
            while start < rows_sorted.size:
                kmin = max(int(nnz_sorted[start]), 1)
                end = int(np.searchsorted(
                    nnz_sorted, kmin * bucket_pad_ratio, side="right"))
                end = max(end, start + 1)
                slabs.append(_pack_rows(L, np.sort(rows_sorted[start:end]),
                                        sort_by_nnz, diag_first=upper))
                start = end
        else:
            slabs.append(_pack_rows(L, rows, sort_by_nnz, diag_first=upper))
    return Schedule(n=L.n, slabs=slabs, level_of_row=levels.level, nnz=L.nnz)


def build_ell(M: CSRMatrix) -> EllMatrix:
    """Whole matrix (diagonal included) as ELL, transposed (K, n), with the
    value-source map recorded for value-only refresh."""
    row_nnz = M.row_nnz()
    K = max(int(row_nnz.max()), 1)
    cols = np.zeros((K, M.n), dtype=np.int32)
    vals = np.zeros((K, M.n), dtype=M.dtype)
    val_src = np.full((K, M.n), -1, dtype=np.int64)
    for i in range(M.n):
        lo, hi = int(M.indptr[i]), int(M.indptr[i + 1])
        k = hi - lo
        cols[:k, i] = M.indices[lo:hi]
        vals[:k, i] = M.data[lo:hi]
        val_src[:k, i] = np.arange(lo, hi, dtype=np.int64)
    return EllMatrix(cols=cols, vals=vals, val_src=val_src)


def build_offdiag_ell(M: CSRMatrix, *, upper: bool = False):
    """Split a triangular matrix into its strictly-triangular ELL part ``N``
    and diagonal ``D`` — the ``L = D + N`` decomposition the sync-free sweep
    executor iterates on (:mod:`repro.core.sweep`).

    Returns ``(ell, diag, diag_src)``: ``ell`` is the off-diagonal part as a
    transposed ``(K, n)`` :class:`EllMatrix` with its value-source map
    recorded, ``diag`` the ``(n,)`` diagonal, ``diag_src`` its indices into
    ``M.data`` — so a value-only refresh re-packs both with one masked
    gather.  ``upper=True`` reads upper-triangular storage (diagonal first
    per row, e.g. ``L.transpose()``)."""
    row_nnz = M.row_nnz() - 1
    K = max(int(row_nnz.max()) if row_nnz.size else 0, 1)
    cols = np.zeros((K, M.n), dtype=np.int32)
    vals = np.zeros((K, M.n), dtype=M.dtype)
    val_src = np.full((K, M.n), -1, dtype=np.int64)
    for i in range(M.n):
        lo, hi = int(M.indptr[i]), int(M.indptr[i + 1])
        sl = slice(lo + 1, hi) if upper else slice(lo, hi - 1)
        k = sl.stop - sl.start
        cols[:k, i] = M.indices[sl]
        vals[:k, i] = M.data[sl]
        val_src[:k, i] = np.arange(sl.start, sl.stop, dtype=np.int64)
    diag = M.diagonal(first=upper)
    diag_src = (M.indptr[:-1] if upper else M.indptr[1:] - 1).astype(np.int64)
    return EllMatrix(cols=cols, vals=vals, val_src=val_src), diag, diag_src


# --------------------------------------------------------------------------
# Executors (pure JAX)
#
# Every executor accepts either a single RHS ``(n,)`` or a multi-RHS batch
# ``(n, m)`` (columns are independent systems L x_j = b_j).  The batch axis
# rides along as a trailing dimension of the solution vector, so a slab's
# gather/FMA/reduce becomes ``(K, R, m)`` and the TPU lane dimension is
# ``R * m`` instead of ``R`` — thin levels no longer underfeed the lanes.
# --------------------------------------------------------------------------
def _coef(a: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Broadcast a per-row coefficient array over the batch axis of x (a
    no-op for single-RHS solves)."""
    return a if x.ndim == 1 else a[..., None]


def _gather_sum(
    vals: jnp.ndarray,
    cols: jnp.ndarray,
    x: jnp.ndarray,
    *,
    unroll_max_k: int = GATHER_UNROLL_MAX_K,
) -> jnp.ndarray:
    """``sum_k vals[k] * x[cols[k]]`` over the static ELL width K.

    Single-RHS stays the paper's fused one-gather + reduce.  Batched x
    ``(n, m)`` instead unrolls the K axis into K row-gathers of ``(R, m)``:
    XLA's CPU gather of (K, R, m) row slices runs ~50x slower per element
    than the same work as K two-dimensional gathers.  Slabs wider than
    ``unroll_max_k`` (default :data:`GATHER_UNROLL_MAX_K`) fall back to the
    fused 3-D gather — correct but slower; the fallback is logged at trace
    time so wide-slab batched solves are diagnosable."""
    if x.ndim == 1 or cols.shape[0] > unroll_max_k:
        if x.ndim > 1:
            logger.debug(
                "_gather_sum: K=%d > unroll_max_k=%d — falling back to the "
                "fused 3-D gather for this batched slab (slower on CPU)",
                cols.shape[0], unroll_max_k,
            )
        # single RHS, or rows wide enough that unrolling K gathers would
        # bloat the program: one fused gather + reduce
        return jnp.sum(_coef(vals, x) * x[cols], axis=0)
    acc = vals[0][:, None] * x[cols[0]]
    for k in range(1, cols.shape[0]):
        acc = acc + vals[k][:, None] * x[cols[k]]
    return acc


def ell_spmv(ell: EllMatrix, v: jnp.ndarray) -> jnp.ndarray:
    """y = M v for ELL-packed M.  Fully parallel (one gather + reduce per
    ELL slot).  ``v`` may be ``(n,)`` or batched ``(n, m)`` (one SpMV per
    column)."""
    cols = jnp.asarray(ell.cols)
    vals = jnp.asarray(ell.vals, dtype=v.dtype)
    return _gather_sum(vals, cols, v)


def serial_arrays(L: CSRMatrix, *, upper: bool = False):
    """Row-major serial-scan arrays plus their refresh source maps.

    Returns ``(cols (n, K), vals (n, K), diag (n,), val_src (n, K),
    diag_src (n,), order (n,))`` — ``order`` is the scan order (reversed for
    backward substitution).  ``val_src``/``diag_src`` index ``L.data``
    (-1 = padding), so a value-only refresh re-packs the scan operands with
    one vectorized gather."""
    row_nnz = L.row_nnz() - 1
    K = max(int(row_nnz.max()), 1)
    n = L.n
    cols = np.zeros((n, K), dtype=np.int32)
    vals = np.zeros((n, K), dtype=L.dtype)
    val_src = np.full((n, K), -1, dtype=np.int64)
    for i in range(n):
        lo, hi = int(L.indptr[i]), int(L.indptr[i + 1])
        k = hi - lo - 1
        if upper:
            cols[i, :k] = L.indices[lo + 1 : hi]
            vals[i, :k] = L.data[lo + 1 : hi]
            val_src[i, :k] = np.arange(lo + 1, hi, dtype=np.int64)
        else:
            cols[i, :k] = L.indices[lo : hi - 1]
            vals[i, :k] = L.data[lo : hi - 1]
            val_src[i, :k] = np.arange(lo, hi - 1, dtype=np.int64)
    diag = L.diagonal(first=upper)
    diag_src = (L.indptr[:-1] if upper else L.indptr[1:] - 1).astype(np.int64)
    order = np.arange(n, dtype=np.int32)
    if upper:
        order = order[::-1]
    return cols, vals, diag, val_src, diag_src, order


def make_serial_solver(
    L: CSRMatrix, *, upper: bool = False
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Algorithm 1 of the paper: row-serial substitution as a ``lax.scan``
    over rows (the paper's serial baseline).  ``b`` may be ``(n,)`` or
    ``(n, m)``; the scan carries all columns at once.

    ``upper=True`` takes an upper-triangular matrix (diagonal first per row,
    e.g. ``L.transpose()``) and scans rows in *reverse* order — backward
    substitution for the transpose solve ``Lᵀ x = b``."""
    cols, vals, diag, _, _, order = serial_arrays(L, upper=upper)
    cols_d = jnp.asarray(cols[order])
    vals_d = jnp.asarray(vals[order])
    diag_d = jnp.asarray(diag[order])
    idx = jnp.asarray(order)

    def solve(b: jnp.ndarray) -> jnp.ndarray:
        dt = b.dtype
        vals_l = vals_d.astype(dt)
        diag_l = diag_d.astype(dt)

        def body(x, inp):
            c, v, d, bi, i = inp
            s = jnp.sum(_coef(v, x) * x[c], axis=0)
            xi = (bi - s) / d
            x = x.at[i].set(xi)
            return x, ()

        x0 = jnp.zeros(b.shape, dtype=dt)
        x, _ = jax.lax.scan(body, x0, (cols_d, vals_l, diag_l, b[idx], idx))
        return x

    return solve


def _apply_slab(
    x: jnp.ndarray, b: jnp.ndarray, slab: LevelSlab,
    unroll_max_k: int = GATHER_UNROLL_MAX_K,
) -> jnp.ndarray:
    """One level as a vectorized gather/FMA/reduce segment.  For batched
    solves the gather is ``(K, R, m)`` and the reduce yields ``(R, m)``."""
    cols = jnp.asarray(slab.cols)
    vals = jnp.asarray(slab.vals, dtype=x.dtype)
    rows = jnp.asarray(slab.rows)
    diag = jnp.asarray(slab.diag, dtype=x.dtype)
    s = _gather_sum(vals, cols, x, unroll_max_k=unroll_max_k)  # (R,) or (R, m)
    xl = (b[rows] - s) / _coef(diag, x)
    return x.at[rows].set(xl)


def _apply_slab_unrolled(x: jnp.ndarray, b: jnp.ndarray, slab: LevelSlab) -> jnp.ndarray:
    """Tiny level unrolled with literal indices/values — the generated-code
    path of the paper (Fig. 4): no indirect indexing, constants embedded.
    Batched solves broadcast naturally: each scalar op becomes an (m,)
    vector op over the RHS columns."""
    new_vals = []
    for r in range(slab.R):
        i = int(slab.rows[r])
        s = b[i]
        for k in range(slab.K):
            v = float(slab.vals[k, r])
            if v != 0.0:
                s = s - v * x[int(slab.cols[k, r])]
        new_vals.append(s / float(slab.diag[r]))
    rows = jnp.asarray(slab.rows.astype(np.int32))
    return x.at[rows].set(jnp.stack(new_vals).astype(x.dtype))


def stack_sub_slabs(slab: LevelSlab, n: int, *, with_src: bool = False):
    """Uniform stacked arrays for a coarsened slab's chain: every sub-slab
    zero-padded to the widest one so the chain can run as ONE ``fori_loop``
    (one XLA while op — segment count and program size independent of depth).

    Returns ``(rows, cols, vals, diag)`` of shapes ``(d, Rmax)``,
    ``(d, K, Rmax)``, ``(d, K, Rmax)``, ``(d, Rmax)``.  Padding rows carry
    the sentinel id ``n`` (they read ``b_ext[n] = 0``, divide by diag 1, and
    scatter into the scratch slot ``n`` — never read back, masked off at the
    end of the solve).  ``with_src=True`` appends the stacked
    ``(val_src, diag_src)`` refresh maps (-1 padding)."""
    d = slab.depth
    rmax = max(slab.sub_rows) if slab.sub_rows else slab.R
    rows = np.full((d, rmax), n, dtype=np.int32)
    cols = np.zeros((d, slab.K, rmax), dtype=np.int32)
    vals = np.zeros((d, slab.K, rmax), dtype=slab.vals.dtype)
    diag = np.ones((d, rmax), dtype=slab.diag.dtype)
    val_src = np.full((d, slab.K, rmax), -1, dtype=np.int64)
    diag_src = np.full((d, rmax), -1, dtype=np.int64)
    for t, sub in enumerate(slab.sub_slabs()):
        rows[t, : sub.R] = sub.rows
        cols[t, :, : sub.R] = sub.cols
        vals[t, :, : sub.R] = sub.vals
        diag[t, : sub.R] = sub.diag
        if with_src and sub.val_src is not None:
            val_src[t, :, : sub.R] = sub.val_src
            diag_src[t, : sub.R] = sub.diag_src
    if with_src:
        return rows, cols, vals, diag, val_src, diag_src
    return rows, cols, vals, diag


def _apply_slab_chain(
    x: jnp.ndarray, b_ext: jnp.ndarray, slab: LevelSlab, n: int,
    unroll_max_k: int = GATHER_UNROLL_MAX_K,
) -> jnp.ndarray:
    """A coarsened slab: ``depth`` dependent sub-slabs executed back-to-back
    inside one segment — a single ``fori_loop`` over the stacked uniform
    sub-arrays, so the XLA program holds one gather/FMA/scatter body per
    *super*-level instead of one per level.  ``x`` is ``(n+1, [m])`` with the
    scratch slot last; ``b_ext`` is b with a zero appended."""
    rows_h, cols_h, vals_h, diag_h = stack_sub_slabs(slab, n)
    rows_s = jnp.asarray(rows_h)
    cols_s = jnp.asarray(cols_h)
    vals_s = jnp.asarray(vals_h, dtype=x.dtype)
    diag_s = jnp.asarray(diag_h, dtype=x.dtype)

    def body(t, xc):
        s = _gather_sum(vals_s[t], cols_s[t], xc, unroll_max_k=unroll_max_k)
        xl = (b_ext[rows_s[t]] - s) / _coef(diag_s[t], xc)
        return xc.at[rows_s[t]].set(xl)

    return jax.lax.fori_loop(0, slab.depth, body, x)


def make_levelset_solver(
    schedule: Schedule,
    *,
    unroll_threshold: int = 0,
    gather_unroll_max_k: int = GATHER_UNROLL_MAX_K,
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Level-set executor: one generated segment per level (paper's
    function-per-level), executed in level order.  ``unroll_threshold`` > 0
    additionally unrolls levels with that few rows into constant-embedded
    scalar code.  ``b`` may be ``(n,)`` or ``(n, m)``.

    Coarsened slabs (``depth > 1``, see :mod:`repro.core.coarsen`) execute
    their sub-slab chain as one ``fori_loop`` segment; the solution vector
    gains a scratch slot ``n`` for their pad rows (sliced off on return).
    Chained slabs are never unrolled — their rows are not mutually
    independent.  ``gather_unroll_max_k`` bounds the batched per-k gather
    unrolling of :func:`_gather_sum` (wider slabs fall back to the fused
    3-D gather, logged at trace time)."""
    n = schedule.n
    chained = any(s.depth > 1 for s in schedule.slabs)

    def solve(b: jnp.ndarray) -> jnp.ndarray:
        ext = 1 if chained else 0
        x = jnp.zeros((n + ext,) + b.shape[1:], dtype=b.dtype)
        if chained:
            b_ext = jnp.concatenate(
                [b, jnp.zeros((1,) + b.shape[1:], dtype=b.dtype)])
        for slab in schedule.slabs:
            if slab.depth > 1:
                x = _apply_slab_chain(x, b_ext, slab, n, gather_unroll_max_k)
            elif slab.R <= unroll_threshold:
                x = _apply_slab_unrolled(x, b, slab)
            else:
                x = _apply_slab(x, b, slab, gather_unroll_max_k)
        return x[:n] if chained else x

    return solve


def make_blocked_solver(
    bsched,
    *,
    backend=None,
    kernel: str = "auto",
    gather_unroll_max_k: int = GATHER_UNROLL_MAX_K,
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Blocked (supernodal) executor over a
    :class:`~repro.core.coarsen.BlockSchedule`, scatter layout: per
    super-level one padded ELL panel gather-sum (the off-block update) and
    one batched dense diagonal-block apply

        x_blk = D⁻¹_blk (b_blk − Panel · x_prev)

    through :func:`repro.kernels.trsm_block.ops.make_block_apply` — the
    batched-TRSM step of the supernodal decomposition.  ``b`` may be
    ``(n,)`` or ``(n, m)``.  Lanes are block-major with sentinel row ``n``
    for padding, so ``x`` carries one scratch slot (sliced off on return);
    scalar rows are simply T=1 blocks — the same code path."""
    from repro.kernels.trsm_block.ops import make_block_apply

    apply_blocks = make_block_apply(backend, kernel=kernel)
    n = bsched.n

    def solve(b: jnp.ndarray) -> jnp.ndarray:
        dt = b.dtype
        b_ext = jnp.concatenate(
            [b, jnp.zeros((1,) + b.shape[1:], dtype=dt)])
        x = jnp.zeros((n + 1,) + b.shape[1:], dtype=dt)
        for slab in bsched.slabs:
            lane = jnp.asarray(slab.lane_row)
            s = _gather_sum(jnp.asarray(slab.vals, dt),
                            jnp.asarray(slab.cols), x,
                            unroll_max_k=gather_unroll_max_k)
            rhs = b_ext[lane] - s                       # (B*T[, m])
            rhs = rhs.reshape((slab.B, slab.T) + b.shape[1:])
            xb = apply_blocks(jnp.asarray(slab.dinv, dt), rhs)
            x = x.at[lane].set(
                xb.reshape((slab.B * slab.T,) + b.shape[1:]))
            x = x.at[n].set(jnp.zeros(b.shape[1:], dtype=dt))
        return x[:n]

    return solve


def make_rhs_transform(res: RewriteResult) -> Optional[Callable]:
    """b' = E b — the per-solve RHS update of the rewriting method, as one
    fully-parallel ELL SpMV.  For a batch ``B: (n, m)`` this is a single
    batched SpMV ``B' = E B`` (not m separate ones).  Returns ``None`` when
    E is the identity (no rewrites survived the budgets)."""
    if res.stats.e_nnz_offdiag == 0:
        return None
    ell = build_ell(res.E)

    def transform(b: jnp.ndarray) -> jnp.ndarray:
        return ell_spmv(ell, b)

    return transform
