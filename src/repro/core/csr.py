"""Host-side CSR containers for lower-triangular sparse matrices.

Preprocessing (DAG/level analysis, equation rewriting) runs on host numpy —
the paper's "matrix analysis module". Execution-side structures (ELL slabs)
are built by :mod:`repro.core.codegen` and live on device.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Tuple

import numpy as np

__all__ = ["CSRMatrix", "from_dense", "from_coo", "eye_csr"]


@dataclasses.dataclass(frozen=True)
class CSRMatrix:
    """Compressed-sparse-row matrix (host numpy).

    ``indptr``  int64 (n+1,)
    ``indices`` int64 (nnz,)  column ids, sorted within each row
    ``data``    float (nnz,)
    ``shape``   (n, m)
    """

    indptr: np.ndarray
    indices: np.ndarray
    data: np.ndarray
    shape: Tuple[int, int]

    # -- basic properties ---------------------------------------------------
    @property
    def n(self) -> int:
        return self.shape[0]

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    @property
    def dtype(self):
        return self.data.dtype

    def row(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        """(cols, vals) of row ``i``."""
        lo, hi = self.indptr[i], self.indptr[i + 1]
        return self.indices[lo:hi], self.data[lo:hi]

    def row_nnz(self) -> np.ndarray:
        return np.diff(self.indptr)

    def pattern_hash(self) -> str:
        """Stable digest of the sparsity *pattern* (shape + indptr +
        indices; values excluded) — the key a serving tier uses to route
        same-pattern numeric refreshes onto already-compiled solvers
        (:class:`repro.serve.SolverRegistry`).

        The digest is content-based (blake2b over the canonical int64 index
        arrays), so it is stable across processes, sessions, and transports
        — unlike ``id()`` or Python ``hash()``.  Memoized per instance; the
        index arrays of a built matrix are treated as immutable, like every
        other consumer in this package treats them."""
        cached = getattr(self, "_pattern_hash", None)
        if cached is not None:
            return cached
        h = hashlib.blake2b(digest_size=16)
        h.update(np.asarray(self.shape, dtype=np.int64).tobytes())
        h.update(np.ascontiguousarray(self.indptr, dtype=np.int64).tobytes())
        h.update(np.ascontiguousarray(self.indices, dtype=np.int64).tobytes())
        digest = h.hexdigest()
        object.__setattr__(self, "_pattern_hash", digest)  # frozen dataclass
        return digest

    # -- validation ---------------------------------------------------------
    def validate(self) -> "CSRMatrix":
        n, m = self.shape
        assert self.indptr.shape == (n + 1,)
        assert self.indptr[0] == 0 and self.indptr[-1] == self.nnz
        assert np.all(np.diff(self.indptr) >= 0)
        assert self.indices.shape == self.data.shape
        if self.nnz:
            assert self.indices.min() >= 0 and self.indices.max() < m
            # sorted/unique columns within every row, O(nnz) vectorized:
            # adjacent column ids must increase except across row boundaries
            # (_pack_rows assumes the diagonal is the LAST entry of a row, so
            # an unsorted row anywhere — not just in the first 64 — would
            # silently corrupt the packed slabs).
            increasing = np.diff(self.indices) > 0
            starts = self.indptr[1:-1]
            boundary = starts[(starts > 0) & (starts < self.nnz)] - 1
            increasing[boundary] = True
            bad = np.nonzero(~increasing)[0]
            if bad.size:
                i = int(np.searchsorted(self.indptr, bad[0], side="right")) - 1
                raise AssertionError(f"row {i} columns not sorted/unique")
        return self

    def is_lower_triangular(self, *, strict_diag: bool = True) -> bool:
        """True iff all entries have col <= row and (optionally) every
        diagonal entry exists and is nonzero."""
        rows = np.repeat(np.arange(self.n), self.row_nnz())
        if np.any(self.indices > rows):
            return False
        if strict_diag:
            last = self.indptr[1:] - 1
            has_diag = (self.indptr[1:] > self.indptr[:-1]) & (
                self.indices[np.maximum(last, 0)] == np.arange(self.n)
            )
            if not np.all(has_diag):
                return False
            if np.any(self.data[last] == 0.0):
                return False
        return True

    # -- conversions ----------------------------------------------------------
    def diagonal(self, *, first: bool = False) -> np.ndarray:
        """Diagonal entries of a triangular matrix with stored diagonal.

        ``first=False`` (default) assumes lower-triangular storage — the
        diagonal is the *last* entry of each row.  ``first=True`` assumes
        upper-triangular storage (e.g. :meth:`transpose` of a lower factor) —
        the diagonal is the *first* entry of each row.
        """
        if first:
            return self.data[self.indptr[:-1]]
        last = self.indptr[1:] - 1
        return self.data[last]

    def csc_view(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """``(colptr, row_indices, data)`` — CSC arrays of this matrix, which
        are exactly the CSR arrays of its transpose.  O(nnz) (single stable
        counting pass; no lexsort), with row ids ascending within each column.
        """
        n, m = self.shape
        colptr = np.zeros(m + 1, dtype=np.int64)
        np.add.at(colptr, self.indices + 1, 1)
        colptr = np.cumsum(colptr)
        rows = np.repeat(np.arange(n, dtype=np.int64), self.row_nnz())
        order = np.argsort(self.indices, kind="stable")
        return colptr, rows[order], self.data[order]

    def transpose(self) -> "CSRMatrix":
        """CSR of the transpose (= :meth:`csc_view` rebound as CSR).  For a
        lower-triangular matrix this yields the upper-triangular factor with
        the diagonal stored *first* in each row (``diagonal(first=True)``)."""
        colptr, rows, vals = self.csc_view()
        return CSRMatrix(colptr, rows, vals, (self.shape[1], self.shape[0]))

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=self.data.dtype)
        rows = np.repeat(np.arange(self.n), self.row_nnz())
        out[rows, self.indices] = self.data
        return out

    def matvec(self, v: np.ndarray) -> np.ndarray:
        rows = np.repeat(np.arange(self.n), self.row_nnz())
        out = np.zeros(self.n, dtype=np.result_type(self.data, v))
        np.add.at(out, rows, self.data * v[self.indices])
        return out

    def astype(self, dtype) -> "CSRMatrix":
        return CSRMatrix(self.indptr, self.indices, self.data.astype(dtype), self.shape)

    def memory_accesses(self) -> int:
        """Per-solve memory access count (paper's analysis metric): each nnz
        reads L.data, L.indices and x[col]; each row reads b and writes x."""
        return 3 * self.nnz + 2 * self.n

    def solve_flops(self) -> int:
        """FLOPs of one forward substitution: mul+sub per off-diagonal nnz,
        one divide per row (paper's FLOP accounting for Fig. 6)."""
        return 2 * (self.nnz - self.n) + self.n


def from_coo(rows, cols, vals, shape) -> CSRMatrix:
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    vals = np.asarray(vals)
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    # combine duplicates
    if rows.size:
        key_same = np.zeros(rows.size, dtype=bool)
        key_same[1:] = (rows[1:] == rows[:-1]) & (cols[1:] == cols[:-1])
        if key_same.any():
            grp = np.cumsum(~key_same) - 1
            out_vals = np.zeros(grp[-1] + 1, dtype=vals.dtype)
            np.add.at(out_vals, grp, vals)
            keep = ~key_same
            rows, cols, vals = rows[keep], cols[keep], out_vals
    indptr = np.zeros(shape[0] + 1, dtype=np.int64)
    np.add.at(indptr, rows + 1, 1)
    indptr = np.cumsum(indptr)
    return CSRMatrix(indptr, cols, vals, tuple(shape))


def from_dense(a: np.ndarray) -> CSRMatrix:
    n, m = a.shape
    rows, cols = np.nonzero(a)
    return from_coo(rows, cols, a[rows, cols], (n, m))


def eye_csr(n: int, dtype=np.float64) -> CSRMatrix:
    idx = np.arange(n, dtype=np.int64)
    return CSRMatrix(np.arange(n + 1, dtype=np.int64), idx, np.ones(n, dtype=dtype), (n, n))
