"""Matrix analysis module (paper §IV).

Extracts the properties the code generator consumes: size, nnz, level
structure, per-level memory-access totals/averages, thin-level fraction, and
FLOP counts.  The output feeds :mod:`repro.core.codegen` (executor choice,
unroll thresholds, slab packing) and the benchmark reports.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import numpy as np

from .csr import CSRMatrix
from .levels import (
    LevelSets,
    Supernodes,
    build_level_sets,
    compute_critical_path,
    detect_supernodes,
)

__all__ = ["MatrixAnalysis", "analyze"]


@dataclasses.dataclass(frozen=True)
class MatrixAnalysis:
    n: int
    nnz: int
    nnz_offdiag: int
    avg_nnz_per_row: float
    num_levels: int
    max_level_rows: int
    thin_levels_2: int              # levels with <= 2 rows (paper's metric)
    thin_fraction_2: float
    level_counts: np.ndarray
    mem_accesses_total: int
    mem_accesses_per_level: np.ndarray
    mem_accesses_per_level_avg: float
    solve_flops: int
    serial_fraction: float          # rows on the critical path / n
    # weighted-critical-path thunk: the per-level propagation costs
    # O(num_levels) Python iterations, which chain-like matrices (levels ~ n)
    # would pay on EVERY build — so it runs lazily, on first access (the
    # transform planner, rewrite pricing, and stats() are the consumers)
    _cp_thunk: Optional[Callable[[], int]] = dataclasses.field(
        default=None, repr=False, compare=False)
    _cp_cache: Optional[int] = dataclasses.field(
        default=None, repr=False, compare=False)
    # supernode-detection thunk: same lazy pattern — amalgamation is
    # O(nnz log nnz) and only the blocked planner / stats() consume it
    _sn_thunk: Optional[Callable[[], Supernodes]] = dataclasses.field(
        default=None, repr=False, compare=False)
    _sn_cache: Optional[Supernodes] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def critical_path_flops(self) -> int:
        """Weighted critical path of the dependency DAG (Böhnlein et al.) —
        computed lazily on first access and cached."""
        if self._cp_cache is None:
            cp = self._cp_thunk() if self._cp_thunk is not None else 0
            object.__setattr__(self, "_cp_cache", cp)
        return self._cp_cache

    @property
    def supernodes(self) -> Optional[Supernodes]:
        """Supernode partition at the default relaxation (lazy, cached);
        ``None`` when the analysis was built without a matrix handle."""
        if self._sn_cache is None and self._sn_thunk is not None:
            object.__setattr__(self, "_sn_cache", self._sn_thunk())
        return self._sn_cache

    @property
    def supernode_count(self) -> int:
        sn = self.supernodes
        return sn.num_supernodes if sn is not None else self.n

    @property
    def mean_block_size(self) -> float:
        sn = self.supernodes
        return sn.mean_block_size if sn is not None else 1.0

    @property
    def dense_block_fraction(self) -> float:
        sn = self.supernodes
        return sn.dense_block_fraction if sn is not None else 0.0

    @property
    def critical_fraction(self) -> float:
        """critical_path_flops / solve_flops — 1.0 for a pure chain."""
        return self.critical_path_flops / max(self.solve_flops, 1)

    def report(self) -> Dict:
        return {
            "n": self.n,
            "nnz": self.nnz,
            "avg_nnz_per_row": round(self.avg_nnz_per_row, 3),
            "num_levels": self.num_levels,
            "max_level_rows": self.max_level_rows,
            "thin_levels(<=2 rows)": self.thin_levels_2,
            "thin_fraction": round(self.thin_fraction_2, 4),
            "mem_accesses_total": self.mem_accesses_total,
            "mem_accesses_per_level_avg": round(self.mem_accesses_per_level_avg, 1),
            "solve_flops": self.solve_flops,
            "serial_fraction": round(self.serial_fraction, 6),
            "critical_path_flops": self.critical_path_flops,
            "critical_fraction": round(self.critical_fraction, 6),
            "supernode_count": self.supernode_count,
            "mean_block_size": round(self.mean_block_size, 3),
            "dense_block_fraction": round(self.dense_block_fraction, 4),
        }

    def pretty(self) -> str:
        return "\n".join(f"{k:>28s}: {v}" for k, v in self.report().items())

    def traffic_bytes(self, itemsize: int = 4, index_size: int = 4) -> Dict:
        """Per-solve streaming-traffic floor implied by the analysis: matrix
        values + column indices + the solution/RHS vectors, in bytes.  The
        packed permuted layout approaches this floor (one flat value stream,
        contiguous b̂/x̂ slices); ``SpTRSV.stats()`` reports the *actual*
        packed-buffer bytes including padding for comparison."""
        return {
            "value_bytes": self.nnz * itemsize,
            "index_bytes": self.nnz_offdiag * index_size,
            "vector_bytes": 2 * self.n * itemsize,
        }


def analyze(
    L: CSRMatrix, levels: Optional[LevelSets] = None, *, upper: bool = False
) -> MatrixAnalysis:
    """Analyze a triangular system.  ``upper=True`` marks an
    upper-triangular matrix (a transpose-solve system, diagonal stored
    first) so the dependency edges of the weighted critical path point the
    right way; every other metric is direction-agnostic."""
    if levels is None:
        levels = build_level_sets(L)
    row_nnz = L.row_nnz()
    counts = levels.counts
    # per-level memory accesses: 3 per nnz (L.data, L.indices, x[col]) plus
    # 2 per row (read b, write x) — the paper's analysis-module metric.
    # One bincount over level ids instead of a Python loop over levels.
    per_level = 3 * np.bincount(
        levels.level, weights=row_nnz, minlength=levels.num_levels
    ).astype(np.int64) + 2 * counts.astype(np.int64)
    thin2 = int((counts <= 2).sum())
    solve_flops = L.solve_flops()
    return MatrixAnalysis(
        n=L.n,
        nnz=L.nnz,
        nnz_offdiag=L.nnz - L.n,
        avg_nnz_per_row=L.nnz / max(L.n, 1),
        num_levels=levels.num_levels,
        max_level_rows=int(counts.max()) if counts.size else 0,
        thin_levels_2=thin2,
        thin_fraction_2=thin2 / max(levels.num_levels, 1),
        level_counts=counts,
        mem_accesses_total=L.memory_accesses(),
        mem_accesses_per_level=per_level,
        mem_accesses_per_level_avg=float(per_level.mean()) if per_level.size else 0.0,
        solve_flops=solve_flops,
        serial_fraction=levels.num_levels / max(L.n, 1),
        _cp_thunk=lambda: compute_critical_path(L, levels, upper=upper),
        _sn_thunk=lambda: detect_supernodes(L, upper=upper),
    )
