"""Per-backend cost calibration for the transform planner.

:func:`repro.core.coarsen.plan_strategy` prices every strategy × transform
combination with a launch-cost/padded-FLOP model.  The coefficients of that
model are *device* properties — how expensive a kernel launch (barrier) is
relative to a gathered FMA, how wide the vector lanes are, whether a fused
single-dispatch solve exists at all — so they live here in one
:class:`BackendCalibration` row per backend family instead of as constants
scattered through the planner.

Rows are keyed by the **calibration key** of a resolved
:class:`repro.kernels.backend.KernelBackend` (``cpu`` / ``tpu`` / ``gpu``;
interpret-mode backends execute on the host and are priced as ``cpu``).

``DEFAULT_CALIBRATIONS`` ships conservative defaults:

``cpu``   the historical planner constants (the interpreter / XLA:CPU path
          the test-suite runs) — ``fused_max_rows=0`` because pallas has no
          compiled CPU lowering, so the fused kernel is never a candidate
``tpu``   one sequential-grid dispatch for the fused solve
          (``fused_num_launches="one"``), VMEM-bounded at ~2M f32 rows,
          128-wide lanes
``gpu``   kernel launches are the synchronization primitive (pricier than a
          TPU grid step), the fused layout executes as one launch **per
          wavefront span** (``fused_num_launches="per_level"``), 32-wide
          warps, no VMEM residency bound (x lives in GMEM)

A machine-measured table can replace the defaults: ``benchmarks/calibrate.py``
times launch overhead and gather throughput on the live device and writes
``calibration.json``; :func:`load_calibrations` / :func:`refresh` merge it
over the defaults (rows keep ``source="measured"`` so ``plan.reason`` lines
stay auditable).
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, Optional, Union

__all__ = [
    "BackendCalibration",
    "DEFAULT_CALIBRATIONS",
    "get_calibration",
    "load_calibrations",
    "save_calibrations",
    "refresh",
]


@dataclasses.dataclass(frozen=True)
class BackendCalibration:
    """Planner pricing coefficients for one backend family.

    All costs are in FLOP-equivalents (the planner's common currency).

    ``launch_cost``            one barrier-separated kernel launch /
                               collective / generated code region
    ``substep_cost``           one intra-chain ``fori_loop`` sub-step of a
                               coarsened segment (no barrier, no new region)
    ``gather_cost``            relative price of one padded gather/FMA flop
                               (1.0 = the model's reference throughput)
    ``serial_step_cost``       per-row base cost of the ``lax.scan`` serial
                               solver (latency-bound)
    ``serial_step_cost_scale`` its growth with n (the scan carries the whole
                               x vector; big systems fall out of cache)
    ``lane_width``             vector/warp lane width rows are padded to
    ``fused_max_rows``         largest n the fused single-dispatch solve can
                               hold (0 = fused never a candidate on this
                               backend — e.g. cpu, where pallas has no
                               compiled lowering)
    ``fused_num_launches``     ``"one"`` — the whole fused solve is a single
                               dispatch (TPU sequential grid); ``"per_level"``
                               — one launch per wavefront span (GPU
                               level-scheduled walk)
    ``gemm_cost``              relative price of one *dense* batched-GEMM /
                               TRSM flop of the blocked executor's diagonal-
                               block apply — contiguous, no index stream, so
                               cheaper than a gathered flop everywhere and
                               dramatically so on MXU/tensor-core hardware
    ``trsm_cost``              fixed per-diagonal-block overhead of the
                               blocked apply (reshape + batched dispatch
                               bookkeeping), in FLOP-equivalents
    ``mixed_gather_discount``  multiplier on ``gather_cost`` when the guard's
                               ``precision="mixed"`` mode stores values in
                               bf16 — half the value-stream bytes, so
                               gather-bound terms cheapen by however much of
                               the stream is values rather than indices on
                               this backend (host caches benefit less than
                               bandwidth-bound accelerators)
    ``source``                 ``"default"`` (shipped) or ``"measured"``
                               (``benchmarks/calibrate.py`` micro-run)
    """

    backend: str
    launch_cost: float = 4096.0
    substep_cost: float = 2048.0
    gather_cost: float = 1.0
    serial_step_cost: float = 16.0
    serial_step_cost_scale: float = 0.06
    lane_width: int = 8
    fused_max_rows: int = 0
    fused_num_launches: str = "per_level"
    gemm_cost: float = 0.25
    trsm_cost: float = 64.0
    mixed_gather_discount: float = 0.75
    source: str = "default"

    def __post_init__(self):
        assert self.fused_num_launches in ("one", "per_level"), \
            self.fused_num_launches


# f32 VMEM budget for the TPU fused kernel's resident x (~16 MiB, leave half
# for slab blocks).
_TPU_FUSED_VMEM_ROWS = 2_000_000

DEFAULT_CALIBRATIONS: Dict[str, BackendCalibration] = {
    # Historical planner constants — the host path every CI run exercises.
    "cpu": BackendCalibration(backend="cpu"),
    # One sequential-grid dispatch covers the whole fused solve; x resident
    # in VMEM bounds n.
    "tpu": BackendCalibration(
        backend="tpu",
        lane_width=128,
        fused_max_rows=_TPU_FUSED_VMEM_ROWS,
        fused_num_launches="one",
        gemm_cost=0.05,   # MXU: dense block flops are nearly free
        trsm_cost=32.0,
        mixed_gather_discount=0.55,  # HBM-bound gathers: bytes ≈ time
    ),
    # Kernel launches ARE the barriers (pricier than a TPU grid step); the
    # fused layout runs one launch per wavefront span; x in GMEM, so the
    # row bound is memory- not VMEM-limited.
    "gpu": BackendCalibration(
        backend="gpu",
        launch_cost=6144.0,
        gather_cost=0.5,
        serial_step_cost=32.0,
        lane_width=32,
        fused_max_rows=50_000_000,
        fused_num_launches="per_level",
        gemm_cost=0.1,    # tensor cores; still pays GMEM block loads
        trsm_cost=48.0,
        mixed_gather_discount=0.55,  # GMEM-bound gathers: bytes ≈ time
    ),
}


def get_calibration(
    key: str,
    table: Optional[Dict[str, BackendCalibration]] = None,
) -> BackendCalibration:
    """Calibration row for a backend family (``cpu`` / ``tpu`` / ``gpu``).
    ``table`` overrides the shipped defaults row-by-row (rows it does not
    carry fall through to the defaults)."""
    if table is not None and key in table:
        return table[key]
    try:
        return DEFAULT_CALIBRATIONS[key]
    except KeyError:
        raise ValueError(
            f"no calibration for backend family {key!r}; expected one of "
            f"{sorted(DEFAULT_CALIBRATIONS)}") from None


def save_calibrations(path: Union[str, Path],
                      table: Dict[str, BackendCalibration]) -> None:
    """Write a calibration table as JSON (one object per backend family)."""
    payload = {k: dataclasses.asdict(v) for k, v in sorted(table.items())}
    Path(path).write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")


def load_calibrations(path: Union[str, Path]) -> Dict[str, BackendCalibration]:
    """Read a calibration table written by :func:`save_calibrations` (or by
    ``benchmarks/calibrate.py``).  Unknown keys in a row are ignored so old
    tables survive field additions; a file that is not a JSON object of
    per-backend rows raises ``ValueError`` naming the path."""
    try:
        raw = json.loads(Path(path).read_text())
    except json.JSONDecodeError as err:
        raise ValueError(f"malformed calibration file {path}: {err}") from None
    if not isinstance(raw, dict):
        raise ValueError(
            f"malformed calibration file {path}: expected a JSON object of "
            f"backend rows, got {type(raw).__name__}")
    fields = {f.name for f in dataclasses.fields(BackendCalibration)}
    table = {}
    for key, row in raw.items():
        if not isinstance(row, dict):
            raise ValueError(
                f"malformed calibration file {path}: row {key!r} is not an "
                f"object")
        kw = {k: v for k, v in row.items() if k in fields}
        kw.setdefault("backend", key)
        table[key] = BackendCalibration(**kw)
    return table


def refresh(path: Union[str, Path]) -> Dict[str, BackendCalibration]:
    """Defaults overlaid with a measured table (missing file → defaults)."""
    table = dict(DEFAULT_CALIBRATIONS)
    p = Path(path)
    if p.exists():
        table.update(load_calibrations(p))
    return table
