"""Preconditioned conjugate gradients with an IC(0)/SpTRSV preconditioner —
the classic workload SpTRSV sits inside (paper §I: "the building block for
several numerical solutions").

``M^{-1} r`` = two triangular solves with the incomplete-Cholesky factor,
each executed by the matrix-specialized (optionally rewritten) level-set
solver.  The upper solve L^T z = y runs as a *lower* solve on the
reverse-permuted system, so both solves share one executor family.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .csr import CSRMatrix, from_dense
from .rewrite import RewriteConfig
from .solver import SpTRSV

__all__ = ["PCGResult", "make_ic_preconditioner", "pcg"]


@dataclasses.dataclass
class PCGResult:
    x: jnp.ndarray
    iters: int
    residual: float
    converged: bool


def _transpose_csr(L: CSRMatrix) -> CSRMatrix:
    n = L.n
    rows = np.repeat(np.arange(n), L.row_nnz())
    from .csr import from_coo
    return from_coo(L.indices, rows, L.data, (n, n))


def make_ic_preconditioner(
    L: CSRMatrix,
    *,
    strategy: str = "levelset",
    rewrite: Optional[RewriteConfig] = RewriteConfig(thin_threshold=2),
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Given lower factor L (A ≈ L Lᵀ) build z = (L Lᵀ)^{-1} r."""
    n = L.n
    P = np.arange(n)[::-1]
    Lt = _transpose_csr(L)
    # reverse-permute Lᵀ so it becomes lower-triangular
    dense = None
    # build permuted CSR without densifying: rows/cols reversed
    from .csr import from_coo
    rows = np.repeat(np.arange(n), Lt.row_nnz())
    perm_rows = n - 1 - rows
    perm_cols = n - 1 - Lt.indices
    Lt_rev = from_coo(perm_rows, perm_cols, Lt.data, (n, n))

    fwd = SpTRSV.build(L, strategy=strategy, rewrite=rewrite)
    bwd = SpTRSV.build(Lt_rev, strategy=strategy, rewrite=rewrite)

    def apply(r: jnp.ndarray) -> jnp.ndarray:
        y = fwd.solve(r)
        z_rev = bwd.solve(y[::-1])
        return z_rev[::-1]

    return apply


def pcg(A: CSRMatrix, b: jnp.ndarray,
        M_inv: Optional[Callable] = None,
        *, tol: float = 1e-8, maxiter: int = 500) -> PCGResult:
    """Standard PCG on SPD A (host loop; each iteration jit-executed)."""
    from .codegen import build_ell, ell_spmv

    ell = build_ell(A)

    @jax.jit
    def matvec(v):
        return ell_spmv(ell, v)

    x = jnp.zeros_like(b)
    r = b - matvec(x)
    z = M_inv(r) if M_inv else r
    p = z
    rz = jnp.vdot(r, z)
    b_norm = float(jnp.linalg.norm(b))
    for it in range(maxiter):
        Ap = matvec(p)
        alpha = rz / jnp.vdot(p, Ap)
        x = x + alpha * p
        r = r - alpha * Ap
        res = float(jnp.linalg.norm(r))
        if res <= tol * b_norm:
            return PCGResult(x, it + 1, res, True)
        z = M_inv(r) if M_inv else r
        rz_new = jnp.vdot(r, z)
        p = z + (rz_new / rz) * p
        rz = rz_new
    return PCGResult(x, maxiter, res, False)
