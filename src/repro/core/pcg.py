"""Preconditioned conjugate gradients with an IC(0)/SpTRSV preconditioner —
the classic workload SpTRSV sits inside (paper §I: "the building block for
several numerical solutions").

``M^{-1} r`` = two triangular solves with the incomplete-Cholesky factor,
each executed by the matrix-specialized (optionally rewritten) level-set
solver.  The backward sweep ``Lᵀ z = y`` is a first-class transpose solve
(``SpTRSV.build_pair``): its level sets are derived from the *same* forward
DAG analysis, so one symbolic analysis serves both sweeps — no transposed
copy, no reverse-permutation, no second analysis pipeline.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .csr import CSRMatrix
from .rewrite import RewriteConfig
from .solver import SpTRSV

__all__ = [
    "PCGResult",
    "BatchedPCGResult",
    "make_ic_preconditioner",
    "make_ic_preconditioner_batched",
    "pcg",
    "pcg_batched",
]


@dataclasses.dataclass
class PCGResult:
    x: jnp.ndarray
    iters: int
    residual: float
    converged: bool


@dataclasses.dataclass
class BatchedPCGResult:
    """m independent PCG solves sharing one matrix/preconditioner build.

    ``x`` (n, m); ``iters``/``residual``/``converged`` are per-column —
    iteration count is where each column first hit tolerance (maxiter if
    it never did)."""

    x: jnp.ndarray
    iters: np.ndarray          # (m,) int
    residual: np.ndarray       # (m,) float
    converged: np.ndarray      # (m,) bool


def make_ic_preconditioner(
    L: CSRMatrix,
    *,
    strategy: str = "levelset",
    rewrite: Optional[RewriteConfig] = RewriteConfig(thin_threshold=2),
    sweeps: Optional[int] = None,
    sweep_tol: Optional[float] = None,
    backend=None,
    guard=None,
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Given lower factor L (A ≈ L Lᵀ) build z = (L Lᵀ)^{-1} r.

    Exactly **one** level-set analysis serves both sweeps: the backward
    solver's level sets are the forward DAG's reverse levels and its slabs
    are packed from an O(nnz) CSC view of ``L`` (``SpTRSV.build_pair``).
    The legacy construction — transpose + reverse-permute + a second full
    ``SpTRSV.build`` — is benchmarked against this one in
    ``benchmarks/preconditioner.py``.

    ``sweeps=k`` switches to the **inexact** stale-synchronous mode: each
    triangular solve becomes ``k`` sync-free Jacobi sweeps
    (:mod:`repro.core.sweep`, ``fallback=None`` — no verification, no
    correction, ONE fused dispatch per apply).  A k-sweep apply is a *fixed
    linear operator* — the same truncated Neumann polynomial of ``L``
    every call — so standard (non-flexible) PCG remains valid with it; an
    inexact ``M⁻¹`` only needs to stay a contraction, not an exact solve.
    Pair it with ``pcg(..., stall_window=...)`` so iteration control notices
    if ``k`` was chosen too small to keep helping.  ``sweep_tol`` is
    accepted for config symmetry but only matters if verification is
    re-enabled.  ``rewrite`` is ignored in sweep mode — the sweeps consume
    the factor directly and an RHS transform would add a dispatch to the
    apply for nothing.

    ``guard`` (``True`` or a :class:`repro.core.guard.GuardConfig`) wraps
    both sweeps in the guarded execution layer.  The **tolerance-aware
    inexact** mode is ``GuardConfig(residual_tol=τ, on_breakdown="refine")``
    with a loose ``τ``: each apply is verified and refined only *up to* the
    requested tolerance — cheaper than an exact solve, but never the silent
    garbage an unverified inexact apply can produce (zero extra inner solves
    when the tolerance already holds).  Because the refinement count may
    vary call-to-call, a guarded ``M⁻¹`` with loose ``τ`` is no longer a
    strictly fixed linear operator — pair it with ``pcg(...,
    stall_window=...)`` just like the sweep mode."""
    if sweeps is not None:
        from .sweep import SweepConfig

        fwd, bwd = SpTRSV.build_pair(
            L, strategy="sweep", rewrite=None, backend=backend,
            sweep=SweepConfig(k=sweeps, residual_tol=sweep_tol,
                              fallback=None),
            guard=guard)
    else:
        fwd, bwd = SpTRSV.build_pair(L, strategy=strategy, rewrite=rewrite,
                                     backend=backend, guard=guard)

    def apply(r: jnp.ndarray) -> jnp.ndarray:
        return bwd.solve(fwd.solve(r))

    return apply


def make_ic_preconditioner_batched(
    L: CSRMatrix,
    *,
    strategy: str = "levelset",
    rewrite: Optional[RewriteConfig] = RewriteConfig(thin_threshold=2),
    sweeps: Optional[int] = None,
    sweep_tol: Optional[float] = None,
    backend=None,
    guard=None,
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Batched z = (L Lᵀ)^{-1} R for R: (n, m).

    The executors are batch-polymorphic, so this *is*
    :func:`make_ic_preconditioner` — both triangular solves (forward and
    transpose) operate column-wise on (n, m) arrays.  Kept as a named entry
    point so batched PCG call sites read explicitly and stay stable if the
    single-RHS path ever specializes."""
    return make_ic_preconditioner(L, strategy=strategy, rewrite=rewrite,
                                  sweeps=sweeps, sweep_tol=sweep_tol,
                                  backend=backend, guard=guard)


def pcg(A: CSRMatrix, b: jnp.ndarray,
        M_inv: Optional[Callable] = None,
        *, tol: float = 1e-8, maxiter: int = 500,
        stall_window: int = 0) -> PCGResult:
    """Standard PCG on SPD A (host loop; each iteration jit-executed).

    ``stall_window`` (0 = off) enables tolerance-aware iteration control for
    inexact preconditioners (``make_ic_preconditioner(..., sweeps=k)``): if
    the residual norm fails to improve on its running best for that many
    consecutive iterations, the loop stops and returns the best-so-far
    iterate as non-converged instead of burning the rest of ``maxiter`` on a
    stagnated recurrence — the signature that ``k`` sweeps stopped being a
    useful contraction at the requested ``tol``."""
    from .codegen import build_ell, ell_spmv

    ell = build_ell(A)

    @jax.jit
    def matvec(v):
        return ell_spmv(ell, v)

    x = jnp.zeros_like(b)
    r = b - matvec(x)
    # Initialize the residual before the loop (maxiter=0 must return a
    # well-formed result, not hit an unbound `res`), and guard b_norm == 0
    # the same way pcg_batched does — otherwise b = 0 makes the tolerance
    # test `res <= 0`, which never fires despite x = 0 being exact.
    res = float(jnp.linalg.norm(r))
    b_norm = float(jnp.linalg.norm(b))
    if b_norm == 0.0:
        b_norm = 1.0
    if res <= tol * b_norm:
        return PCGResult(x, 0, res, True)
    z = M_inv(r) if M_inv else r
    p = z
    rz = jnp.vdot(r, z)
    best_res = res
    stall = 0
    for it in range(maxiter):
        Ap = matvec(p)
        pap = jnp.vdot(p, Ap)
        if float(pap) == 0.0:
            # Lanczos breakdown (p in the null space of the Krylov
            # recurrence, e.g. A = 0 or an indefinite M).  pcg_batched
            # guards this division; the unbatched path silently produced
            # NaN x with converged=False unset.  Return the last finite
            # iterate as a well-formed non-converged result.
            return PCGResult(x, it, res, False)
        alpha = rz / pap
        x = x + alpha * p
        r = r - alpha * Ap
        res = float(jnp.linalg.norm(r))
        if res <= tol * b_norm:
            return PCGResult(x, it + 1, res, True)
        if stall_window > 0:
            if res < 0.999 * best_res:
                best_res, stall = res, 0
            else:
                stall += 1
                if stall >= stall_window:
                    return PCGResult(x, it + 1, res, False)
        z = M_inv(r) if M_inv else r
        rz_new = jnp.vdot(r, z)
        p = z + (rz_new / rz) * p
        rz = rz_new
    return PCGResult(x, maxiter, res, False)


def pcg_batched(A: CSRMatrix, B: jnp.ndarray,
                M_inv: Optional[Callable] = None,
                *, tol: float = 1e-8,
                maxiter: int = 500) -> BatchedPCGResult:
    """m independent PCG solves A x_j = B[:, j], advanced in lockstep.

    One batched SpMV and one batched preconditioner apply (two multi-RHS
    SpTRSVs) per iteration serve *all* columns — the analysis/rewriting cost
    and every kernel launch amortize over the batch, which is the workload
    the paper's specialization story targets (same L, many b).  Per-column
    α/β keep the recurrences mathematically identical to m separate runs;
    converged columns freeze (masked updates) so late columns can keep
    iterating without perturbing early ones.
    """
    from .codegen import build_ell, ell_spmv

    assert B.ndim == 2, f"pcg_batched expects B: (n, m); got {B.shape}"
    m = B.shape[1]
    ell = build_ell(A)

    @jax.jit
    def matvec(V):
        return ell_spmv(ell, V)

    X = jnp.zeros_like(B)
    R = B - matvec(X)
    Z = M_inv(R) if M_inv else R
    P = Z
    rz = jnp.sum(R * Z, axis=0)                      # (m,)
    b_norm = np.asarray(jnp.linalg.norm(B, axis=0))  # (m,)
    b_norm = np.where(b_norm == 0.0, 1.0, b_norm)
    iters = np.full((m,), maxiter, dtype=np.int64)
    done = np.zeros((m,), dtype=bool)
    res = np.asarray(jnp.linalg.norm(R, axis=0))
    # columns already at tolerance (e.g. zero RHS) converge in 0 iterations
    done |= res <= tol * b_norm
    iters[done] = 0
    for it in range(maxiter):
        if done.all():
            break
        AP = matvec(P)
        pap = jnp.sum(P * AP, axis=0)
        active = jnp.asarray(~done)
        # frozen columns get α = 0 (their P may be degenerate — guard the
        # division as well so no NaN leaks into X via 0 * inf)
        alpha = jnp.where(active, rz / jnp.where(pap == 0, 1.0, pap), 0.0)
        X = X + alpha[None, :] * P
        R = R - alpha[None, :] * AP
        res = np.asarray(jnp.linalg.norm(R, axis=0))
        newly = (~done) & (res <= tol * b_norm)
        iters[newly] = it + 1
        done |= newly
        if done.all():
            break
        Z = M_inv(R) if M_inv else R
        rz_new = jnp.sum(R * Z, axis=0)
        beta = jnp.where(jnp.asarray(~done), rz_new / jnp.where(rz == 0, 1.0, rz), 0.0)
        P = Z + beta[None, :] * P
        rz = rz_new
    return BatchedPCGResult(
        x=X, iters=iters, residual=res, converged=done.copy())
