"""Equation rewriting — the paper's graph transformation (§III).

Rewriting row ``i`` using its dependency ``j`` substitutes row ``j``'s equation
into row ``i``.  Rearranged back into ``L x = b`` form (paper Fig. 3) this is
the elementary elimination

    row_i <- row_i - (L[i,j]/L[j,j]) * row_j
    b_i   <- b_i   - (L[i,j]/L[j,j]) * b_j

which breaks edge ``j -> i`` in DAG_L (adding fill-in at ``cols(row_j)``) and
lifts row ``i`` to an earlier level.  Applied to rows of *thin* levels it
empties those levels, removing their synchronization barriers (paper: lung2
478 -> 66 levels, +10% FLOPs).

Because ``b`` changes between solves, the RHS update must be replayed per
solve.  We track, for every rewritten row, its expression in the *original*
equations:  ``E`` (unit-lower-triangular, sparse) with ``b' = E b`` applied as
one fully-parallel SpMV.  Solution invariance:  ``L' x = E b  <=>  L x = b``.

Policies
--------
``policy="thin"`` (paper §V) rewrites every row of a thin level.
``policy="critical_path"`` rewrites only rows on (near-)maximal *weighted*
dependency chains (:func:`repro.core.levels.compute_criticality`) — Böhnlein
et al. show the weighted critical path, not the level count, is what bounds
parallel solve time, so this policy buys the same chain-shortening for a
fraction of the fill when off-chain thin levels exist.

Engines
-------
The default engine runs *batched elimination rounds*: all rows whose
eliminations have settled sources are rewritten together with vectorized
NumPy/CSR kernels (gather original rows, substitute source rows, accumulate
by (row, col), zero-filter, materialize) — a lung2-scale rewrite builds in
milliseconds.  ``engine="loop"`` keeps the seed-era per-row dict loop as the
semantics baseline (and as the fixed-point engine for
``use_original_rows=True``, whose substitutions can reintroduce eliminable
dependencies mid-row).  Both engines make identical elimination decisions
when the fill budgets do not bind; when a budget binds, the batched engine
applies it per elimination round (conservatively, with upper-bound fill
projections) while the loop engine applies it per elimination — both respect
``max_fill_ratio``/``max_row_nnz``, partial rewrites stay exact either way.

The batched engine records its elimination rounds in array form
(:class:`RewritePlan.rounds`), so :func:`replay_rewrite_values` replays the
numeric transformation on new values of the same pattern with O(nnz)
vectorized passes — no dicts, no policy re-decisions.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from .csr import CSRMatrix, from_coo
from .levels import (
    LevelSets,
    _cp_in_from_levels,
    _propagate_levels,
    build_level_sets,
    compute_criticality,
    compute_upper_levels,
    solve_weights,
)

__all__ = [
    "RewriteConfig",
    "RewriteStats",
    "RewriteResult",
    "RewritePlan",
    "ReplayRound",
    "RewriteReplayError",
    "rewrite_matrix",
    "replay_rewrite_values",
    "POLICIES",
    "ENGINES",
]

POLICIES = ("thin", "critical_path")
ENGINES = ("auto", "vectorized", "loop")


@dataclasses.dataclass(frozen=True)
class RewriteConfig:
    """Policy for which rows to rewrite.

    ``policy="thin"``            rewrite every row of a thin level (§V)
    ``policy="critical_path"``   rewrite only rows on (near-)maximal weighted
                                 dependency chains; ``crit_slack`` is the
                                 near-criticality tolerance as a fraction of
                                 the weighted critical path
    ``engine``                   "vectorized" (batched NumPy rounds),
                                 "loop" (seed-era per-row dict loop), or
                                 "auto" (vectorized unless
                                 ``use_original_rows`` needs the loop's
                                 fixed-point semantics)
    """

    thin_threshold: int = 2         # level is thin if rows <= threshold
    max_row_nnz: int = 512          # stop rewriting a row that grows past this
    max_fill_ratio: float = 2.0     # global budget: nnz(L') <= ratio * nnz(L)
    use_original_rows: bool = False  # paper Fig.2 substitutes original
    # equations (may need chains of eliminations); False substitutes the
    # current (already-rewritten) row — one elimination per offending dep.
    pivot_tol: float = 0.0          # skip eliminations with |L_jj| <= tol
    policy: str = "thin"            # "thin" | "critical_path"
    crit_slack: float = 0.05        # near-critical slack fraction of the CP
    crit_max_level_rows: int = 32   # critical rows in wider levels stay put:
    # a wide wavefront executes for its sibling rows regardless, so
    # eliminating its critical member buys no schedule shortening — only
    # fill (and each fat->fat elimination compounds: substituting a wide
    # ancestor row grows the dependent's own weight faster than it shortens
    # the chain, measured +318% FLOPs and a *longer* weighted critical path
    # on the lung2 twin without this cap)
    engine: str = "auto"            # "auto" | "vectorized" | "loop"


@dataclasses.dataclass(frozen=True)
class RewriteStats:
    levels_before: int
    levels_after: int
    nnz_before: int
    nnz_after: int
    e_nnz_offdiag: int
    flops_before: int
    flops_after: int            # solve(L') + spmv(E) per paper-style counting
    rows_rewritten: int
    eliminations: int
    eliminations_skipped: int = 0   # pivot-skipped opportunities (|diag|<=tol)
    policy: str = "thin"
    critical_path_before: int = 0   # weighted critical path of L (FLOPs)
    critical_path_after: int = 0    # ... of L' (E's one parallel SpMV excluded)
    rewritten_rows: Optional[np.ndarray] = None  # (r,) row ids
    row_fill: Optional[np.ndarray] = None        # (r,) nnz added per row (cost)
    row_benefit: Optional[np.ndarray] = None     # (r,) weighted cp_in shortening

    @property
    def level_reduction(self) -> float:
        return 1.0 - self.levels_after / max(self.levels_before, 1)

    @property
    def flop_increase(self) -> float:
        return self.flops_after / max(self.flops_before, 1) - 1.0

    @property
    def critical_path_reduction(self) -> float:
        return 1.0 - self.critical_path_after / max(self.critical_path_before, 1)

    def summary(self) -> str:
        return (
            f"levels {self.levels_before} -> {self.levels_after} "
            f"(-{100*self.level_reduction:.1f}% barriers), "
            f"FLOPs {self.flops_before} -> {self.flops_after} "
            f"(+{100*self.flop_increase:.1f}%), "
            f"critical path {self.critical_path_before} -> "
            f"{self.critical_path_after} "
            f"(-{100*self.critical_path_reduction:.1f}%), "
            f"rows rewritten {self.rows_rewritten}, "
            f"eliminations {self.eliminations}"
            + (f" ({self.eliminations_skipped} pivot-skipped)"
               if self.eliminations_skipped else "")
        )


@dataclasses.dataclass(frozen=True)
class ReplayRound:
    """One batched elimination round in replayable array form: the rows
    rewritten this round (ascending (level, row) order — the m-store order),
    and per approved elimination its target row, pivot row, and the CSR
    position of the coefficient ``L[i, j]`` in the *original* pattern.
    Coefficients of approved eliminations are original values by
    construction (settled sources contain no eliminable columns), so a
    replay on new values recomputes every ``t = data[coef] / diag[piv]``
    without re-running the policy."""

    rows: np.ndarray        # (r,) int64 rewritten row ids
    elim_row: np.ndarray    # (e,) int64 target row per elimination
    elim_piv: np.ndarray    # (e,) int64 pivot (eliminated dependency) row
    coef_pos: np.ndarray    # (e,) int64 position of L[i, j] in original data


@dataclasses.dataclass(frozen=True)
class RewritePlan:
    """Symbolic record of the eliminations a :func:`rewrite_matrix` run
    performed.  ``rounds`` (batched engine) holds the array-form elimination
    program replayed by :func:`replay_rewrite_values` in O(nnz) vectorized
    passes; ``rows`` keeps the per-row ``(i, (j0, j1, ...))`` summary (and is
    the replay source for legacy loop-engine plans, which replay through the
    per-row dict path)."""

    rows: tuple              # ((i, (j0, j1, ...)), ...) in processing order
    use_original_rows: bool
    upper: bool
    rounds: Optional[tuple] = None   # tuple[ReplayRound, ...] — array form


class RewriteReplayError(ValueError):
    """The recorded plan does not numerically transfer to the new values
    (zero pivot, or fill produced outside the cached L' pattern — e.g. an
    exact cancellation in the original values that no longer cancels).
    Callers should fall back to a cold rebuild."""


@dataclasses.dataclass(frozen=True)
class RewriteResult:
    L: CSRMatrix            # transformed matrix L'
    E: CSRMatrix            # RHS operator, b' = E b (unit lower triangular)
    levels: LevelSets       # level sets of L'
    stats: RewriteStats
    plan: Optional[RewritePlan] = None   # replayable elimination record


# --------------------------------------------------------------------------
# policy: which rows participate in the rewrite
# --------------------------------------------------------------------------
def _participants(
    L: CSRMatrix, levels: LevelSets, config: RewriteConfig, *, upper: bool
) -> np.ndarray:
    """Boolean row mask of the rewrite participant set S.  Rows in S are
    rewritten by eliminating their dependencies in S — a row-set formulation
    that guarantees settled (already-rewritten) rows contain no eliminable
    columns, which is what lets the batched engine run one round per row
    and freeze all elimination coefficients at their original values."""
    if config.policy == "thin":
        removed = levels.counts <= config.thin_threshold
        if removed.size:
            removed[0] = False      # level 0 is always a valid destination
        return removed[levels.level]
    if config.policy == "critical_path":
        crit = compute_criticality(L, levels, upper=upper)
        narrow = levels.counts[levels.level] <= config.crit_max_level_rows
        return (crit.near_critical(config.crit_slack) & narrow
                & (levels.level > 0))
    raise ValueError(f"unknown rewrite policy {config.policy!r}; "
                     f"expected one of {POLICIES}")


# --------------------------------------------------------------------------
# shared helpers
# --------------------------------------------------------------------------
def _expand_pos(starts: np.ndarray, lens: np.ndarray) -> np.ndarray:
    """Vectorized ``concat(arange(s, s+l))`` — positions only."""
    lens = lens.astype(np.int64)
    total = int(lens.sum())
    off = np.cumsum(lens) - lens
    return np.repeat(starts.astype(np.int64) - off, lens) + np.arange(total)


def _expand_ranges(starts: np.ndarray, lens: np.ndarray):
    """Vectorized ``concat(arange(s, s+l))``: positions plus the owning
    range index per position."""
    lens = lens.astype(np.int64)
    pos = _expand_pos(starts, lens)
    owner = np.repeat(np.arange(lens.size, dtype=np.int64), lens)
    return pos, owner


def _row_dict(L: CSRMatrix, i: int) -> Dict[int, float]:
    cols, vals = L.row(i)
    return dict(zip(cols.tolist(), vals.tolist()))


def _count_pivot_skips(L: CSRMatrix, part: np.ndarray, diag: np.ndarray,
                       pivot_tol: float) -> int:
    """Pivot-skipped elimination opportunities in the original system:
    entries (i, j) with both rows in the participant set whose pivot is too
    small to divide by.  Skipping leaves the dependency in place — the row
    stays exactly solvable, it just is not lifted (regression-tested)."""
    row_of = np.repeat(np.arange(L.n, dtype=np.int64), L.row_nnz())
    m = (part[row_of] & part[L.indices] & (L.indices != row_of)
         & (np.abs(diag[L.indices]) <= pivot_tol))
    return int(np.count_nonzero(m))


# --------------------------------------------------------------------------
# batched vectorized engine
# --------------------------------------------------------------------------
def _rewrite_vectorized(
    L: CSRMatrix,
    levels: LevelSets,
    config: RewriteConfig,
    *,
    upper: bool,
    part: np.ndarray,
    diag: np.ndarray,
):
    """Batched elimination rounds (see module docstring).  Returns
    ``(Lp, E, rounds, eliminations, rows_rewritten)``."""
    n = L.n
    indptr, indices, data = L.indptr, L.indices, L.data
    level = levels.level
    elim_dep = part & (np.abs(diag) > config.pivot_tol)
    row_of = np.repeat(np.arange(n, dtype=np.int64), L.row_nnz())
    cand = part[row_of] & elim_dep[indices] & (indices != row_of)

    nnz_budget = int(config.max_fill_ratio * L.nnz)
    fill_added = 0
    eliminations = 0

    # round assignment: a row substitutes only settled sources, so its round
    # is its longest elimination-chain depth (lung2: the depth of its thin
    # run, ~16 — NOT the global level count)
    depth = _propagate_levels(n, indices[cand], row_of[cand])

    # growing store of modified rows (and their RHS/E rows)
    tainted = np.zeros(n, dtype=bool)   # rewrite truncated by a budget
    excl = np.zeros(L.nnz, dtype=bool)  # scratch: approved-elimination marks
    mpos = np.full(n, -1, dtype=np.int64)
    m_start_l, m_len_l = [], []
    m_cols = np.zeros(0, np.int64)
    m_vals = np.zeros(0, data.dtype)
    e_start_l, e_len_l = [], []
    e_cols = np.zeros(0, np.int64)
    e_vals = np.zeros(0, data.dtype)
    m_total = e_total = 0
    rounds = []

    dmax = int(depth[part].max()) if part.any() else 0
    for d in range(1, dmax + 1):
        I = np.nonzero(part & (depth == d))[0]
        if I.size == 0:
            continue
        # processing order (level asc, row asc) — the budget scan order
        I = I[np.lexsort((I, level[I]))]
        lo, hi = indptr[I], indptr[I + 1]
        cnt = (hi - lo).astype(np.int64)
        pos, erow = _expand_ranges(lo, cnt)
        ecol = indices[pos].astype(np.int64)
        is_cand = cand[pos]

        g = np.nonzero(is_cand)[0]
        el_row, el_j, el_pos = erow[g], ecol[g], pos[g]
        # per-row elimination order: dependency level desc, column asc — the
        # loop engine's "highest-level offending dep first"
        o = np.lexsort((el_j, -level[el_j], el_row))
        el_row, el_j, el_pos = el_row[o], el_j[o], el_pos[o]
        all_rows = el_row

        # A budget-truncated (tainted) source still carries eliminable
        # columns; substituting it would break this engine's invariant that
        # every approved coefficient is an original value.  Drop those
        # eliminations (the row stays exact, merely less lifted) and mark
        # the dependents tainted in turn.
        okT = ~tainted[el_j]
        el_row, el_j, el_pos = el_row[okT], el_j[okT], el_pos[okT]

        # source row length (diagonal excluded) — settled row if modified
        mp = mpos[el_j]
        src_len = ((indptr[el_j + 1] - indptr[el_j]) - 1).astype(np.int64)
        if m_len_l:
            sm0 = mp >= 0
            src_len[sm0] = _take_list(m_len_l, mp[sm0]) - 1

        # --- budgets ---------------------------------------------------
        # per-row width: emulate the loop's break-on-first-violation with an
        # upper-bound current-length projection (each elimination removes the
        # pivot entry and adds at most the source width)
        if el_row.size:
            delta = src_len - 1
            csum = np.cumsum(delta) - delta            # exclusive prefix
            row_start = np.concatenate([[True], el_row[1:] != el_row[:-1]])
            grp = np.cumsum(row_start) - 1
            base = csum[np.nonzero(row_start)[0]][grp]
            cur_len_ub = cnt[el_row] + (csum - base)
            ok = cur_len_ub <= config.max_row_nnz
            badc = np.cumsum(~ok) - (~ok)
            ok = ok & ((badc - badc[np.nonzero(row_start)[0]][grp]) == 0)
            # loose global guard only (4x the remaining fill budget, on the
            # no-cancellation upper bound) — it bounds round assembly memory;
            # the REAL global budget is applied post-assembly on exact
            # per-row fill, so overlap/cancellation credit is not lost and
            # decisions stay aligned with the loop engine near the budget
            gdelta = np.where(ok, np.maximum(delta, 0), 0)
            gcs = np.cumsum(gdelta) - gdelta
            ok &= (fill_added + gcs) <= 4 * max(nnz_budget - L.nnz, 0) + 64
            el_row, el_j, el_pos = el_row[ok], el_j[ok], el_pos[ok]
        # rows with any dropped elimination keep eliminable columns: tainted
        approved_per_row = np.bincount(el_row, minlength=I.size)
        cand_per_row = np.bincount(all_rows, minlength=I.size)
        tainted[I[approved_per_row < cand_per_row]] = True
        if el_row.size == 0:
            continue

        mp = mpos[el_j]
        t = data[el_pos] / diag[el_j]
        rew = np.zeros(I.size, dtype=bool)
        rew[el_row] = True
        rew_local = np.nonzero(rew)[0]

        # --- gather substitution sources -------------------------------
        d_off = 1 if upper else 0           # diagonal-first vs diagonal-last
        om = mp < 0
        crows, ccols, cvals = [], [], []
        erows_c, ecols_c, evals_c = [], [], []
        if om.any():
            oj = el_j[om]
            ostart = indptr[oj] + d_off
            olen = (indptr[oj + 1] - indptr[oj]) - 1
            spos, owner = _expand_ranges(ostart, olen)
            ot = t[om][owner]
            crows.append(el_row[om][owner])
            ccols.append(indices[spos].astype(np.int64))
            cvals.append(-ot * data[spos])
            # E source of an unmodified row is the unit vector δ_j
            erows_c.append(el_row[om])
            ecols_c.append(oj)
            evals_c.append(-t[om])
        mm = ~om
        if mm.any():
            mpi = mp[mm]
            mstart = _take_list(m_start_l, mpi) + d_off
            mlen = _take_list(m_len_l, mpi) - 1
            spos, owner = _expand_ranges(mstart, mlen)
            mt = t[mm][owner]
            crows.append(el_row[mm][owner])
            ccols.append(m_cols[spos])
            cvals.append(-mt * m_vals[spos])
            estart = _take_list(e_start_l, mpi)
            elen = _take_list(e_len_l, mpi)
            spos_e, owner_e = _expand_ranges(estart, elen)
            et = t[mm][owner_e]
            erows_c.append(el_row[mm][owner_e])
            ecols_c.append(e_cols[spos_e])
            evals_c.append(-et * e_vals[spos_e])

        # --- base entries: original rows minus approved eliminations ---
        excl[el_pos] = True
        drop = excl[pos]
        excl[el_pos] = False
        base_keep = rew[erow] & ~drop
        arow = np.concatenate([erow[base_keep]] + crows)
        acol = np.concatenate([ecol[base_keep]] + ccols)
        aval = np.concatenate([data[pos[base_keep]]] + cvals)

        new_cols, new_vals, new_len = _accumulate_rows(
            arow, acol, aval, I, rew_local, n)

        # --- exact global fill budget (post-assembly) -------------------
        # per-row fill is now exact (duplicates merged, zeros cancelled);
        # cut whole rows past the budget point in processing order, exactly
        # like the loop engine's pre-elimination check
        fill_r = new_len - cnt[rew_local]
        cumfill = np.cumsum(fill_r)
        row_ok = (L.nnz + fill_added + cumfill - fill_r) <= nnz_budget
        if not row_ok.all():
            tainted[I[rew_local[~row_ok]]] = True
            keep_entry = np.repeat(row_ok, new_len)
            new_cols, new_vals = new_cols[keep_entry], new_vals[keep_entry]
            el_keep = row_ok[np.searchsorted(rew_local, el_row)]
            el_row, el_j, el_pos = (el_row[el_keep], el_j[el_keep],
                                    el_pos[el_keep])
            rew_local, new_len = rew_local[row_ok], new_len[row_ok]
            rew = np.zeros(I.size, dtype=bool)
            rew[rew_local] = True
            if rew_local.size == 0:
                continue

        # E rows: base δ_i plus contributions (dropped rows filtered the
        # same way — their E row stays the unit diagonal)
        e_arow = np.concatenate([rew_local] + erows_c)
        e_acol = np.concatenate([I[rew_local]] + ecols_c)
        e_aval = np.concatenate(
            [np.ones(rew_local.size, data.dtype)] + evals_c)
        e_keep = rew[e_arow]
        e_ncols, e_nvals, e_nlen = _accumulate_rows(
            e_arow[e_keep], e_acol[e_keep], e_aval[e_keep], I, rew_local, n)

        # --- append to the modified-row store ---------------------------
        rew_rows = I[rew_local]
        starts = m_total + np.concatenate([[0], np.cumsum(new_len[:-1])]) \
            if new_len.size else np.zeros(0, np.int64)
        mpos[rew_rows] = len(m_start_l) + np.arange(rew_rows.size)
        m_start_l.extend(starts.tolist())
        m_len_l.extend(new_len.tolist())
        m_cols = np.concatenate([m_cols, new_cols])
        m_vals = np.concatenate([m_vals, new_vals])
        m_total += int(new_len.sum())
        e_starts = e_total + np.concatenate([[0], np.cumsum(e_nlen[:-1])]) \
            if e_nlen.size else np.zeros(0, np.int64)
        e_start_l.extend(e_starts.tolist())
        e_len_l.extend(e_nlen.tolist())
        e_cols = np.concatenate([e_cols, e_ncols])
        e_vals = np.concatenate([e_vals, e_nvals])
        e_total += int(e_nlen.sum())

        fill_added += int(new_len.sum() - cnt[rew_local].sum())
        eliminations += int(el_row.size)
        rounds.append(ReplayRound(
            rows=rew_rows.astype(np.int64),
            elim_row=I[el_row].astype(np.int64),
            elim_piv=el_j.astype(np.int64),
            coef_pos=el_pos.astype(np.int64),
        ))

    # --- materialize L' and E (vectorized) ------------------------------
    m_start = np.asarray(m_start_l, dtype=np.int64)
    m_len = np.asarray(m_len_l, dtype=np.int64)
    e_start = np.asarray(e_start_l, dtype=np.int64)
    e_len = np.asarray(e_len_l, dtype=np.int64)
    Lp = _materialize(L, mpos, m_start, m_len, m_cols, m_vals)
    E = _materialize_e(L, mpos, e_start, e_len, e_cols, e_vals)
    rows_rewritten = int((mpos >= 0).sum())
    return Lp, E, tuple(rounds), eliminations, rows_rewritten


def _take_list(lst, idx: np.ndarray) -> np.ndarray:
    """Fancy-index a growing python list of ints (the modified-row store
    geometry) without re-materializing it on every round."""
    if not lst:
        return np.zeros(idx.shape, dtype=np.int64)
    return np.asarray(lst, dtype=np.int64)[idx]


def _accumulate_rows(arow, acol, aval, I, rew_local, n):
    """Accumulate (local row, col, val) triplets: sum duplicates, sort by
    (row, col), drop exact zeros (diagonal exempt — the loop engine's
    ``del row[c]`` semantics).  Returns flattened cols/vals plus per-
    rewritten-row lengths aligned with ``rew_local``."""
    key = arow.astype(np.int64) * n + acol
    o = np.argsort(key, kind="stable")
    key_s, val_s = key[o], aval[o]
    first = np.concatenate([[True], key_s[1:] != key_s[:-1]]) \
        if key_s.size else np.zeros(0, bool)
    starts = np.nonzero(first)[0]
    sums = np.add.reduceat(val_s, starts) if starts.size else val_s[:0]
    ukey = key_s[starts]
    urow = ukey // n
    ucol = ukey % n
    keep = (sums != 0.0) | (ucol == I[urow])
    urow, ucol, sums = urow[keep], ucol[keep], sums[keep]
    # per rewritten-row lengths, in rew_local order
    cnt = np.bincount(urow, minlength=I.size)[rew_local].astype(np.int64)
    return ucol, sums, cnt


def _materialize(L, mpos, m_start, m_len, m_cols, m_vals) -> CSRMatrix:
    """Assemble L' from the original CSR plus the modified-row store.
    Unmodified rows are contiguous runs between (few) modified rows, so the
    bulk of the matrix moves as one slice copy per run instead of a
    per-entry gather — O(nnz(L')) with memcpy constants."""
    n = L.n
    row_len = L.row_nnz().astype(np.int64)
    mod = np.nonzero(mpos >= 0)[0]
    row_len[mod] = m_len[mpos[mod]]
    indptr = np.concatenate([[0], np.cumsum(row_len)]).astype(np.int64)
    nnz = int(indptr[-1])
    out_cols = np.empty(nnz, dtype=np.int64)
    out_vals = np.empty(nnz, dtype=L.dtype)
    if mod.size <= max(n // 16, 64):
        run_lo = np.concatenate([[0], mod + 1])
        run_hi = np.concatenate([mod, [n]])
        for a, b in zip(run_lo, run_hi):
            if a >= b:
                continue
            s0, s1 = int(L.indptr[a]), int(L.indptr[b])
            d0 = int(indptr[a])
            out_cols[d0:d0 + (s1 - s0)] = L.indices[s0:s1]
            out_vals[d0:d0 + (s1 - s0)] = L.data[s0:s1]
    else:
        # densely rewritten: per-run slicing would mean ~n tiny Python
        # copies; the vectorized gather wins
        um = np.nonzero(mpos < 0)[0]
        dpos = _expand_pos(indptr[um], row_len[um])
        spos = _expand_pos(L.indptr[um], row_len[um])
        out_cols[dpos] = L.indices[spos]
        out_vals[dpos] = L.data[spos]
    if mod.size:
        dpos = _expand_pos(indptr[mod], row_len[mod])
        spos = _expand_pos(m_start[mpos[mod]], m_len[mpos[mod]])
        out_cols[dpos] = m_cols[spos]
        out_vals[dpos] = m_vals[spos]
    return CSRMatrix(indptr, out_cols, out_vals, L.shape)


def _materialize_e(L, mpos, e_start, e_len, e_cols, e_vals) -> CSRMatrix:
    """Assemble E: unit diagonal for untouched rows, stored RHS rows for
    rewritten ones."""
    n = L.n
    row_len = np.ones(n, dtype=np.int64)
    mod = np.nonzero(mpos >= 0)[0]
    row_len[mod] = e_len[mpos[mod]]
    indptr = np.concatenate([[0], np.cumsum(row_len)]).astype(np.int64)
    nnz = int(indptr[-1])
    out_cols = np.empty(nnz, dtype=np.int64)
    out_vals = np.empty(nnz, dtype=L.dtype)
    um = np.nonzero(mpos < 0)[0]
    out_cols[indptr[um]] = um
    out_vals[indptr[um]] = 1.0
    if mod.size:
        dpos = _expand_pos(indptr[mod], row_len[mod])
        spos = _expand_pos(e_start[mpos[mod]], e_len[mpos[mod]])
        out_cols[dpos] = e_cols[spos]
        out_vals[dpos] = e_vals[spos]
    return CSRMatrix(indptr, out_cols, out_vals, L.shape)


# --------------------------------------------------------------------------
# loop engine (seed-era semantics baseline; fixed-point for original-rows)
# --------------------------------------------------------------------------
def _rewrite_loop(
    L: CSRMatrix,
    levels: LevelSets,
    config: RewriteConfig,
    *,
    upper: bool,
    part: np.ndarray,
    diag: np.ndarray,
):
    """Per-row dict elimination loop (the seed implementation, generalized
    from thin levels to an arbitrary participant set).  Kept as the
    benchmark baseline and as the engine for ``use_original_rows=True``."""
    n = L.n
    orig_level = levels.level
    nnz_budget = int(config.max_fill_ratio * L.nnz)

    mod_rows: Dict[int, Dict[int, float]] = {}
    mod_rhs: Dict[int, Dict[int, float]] = {}

    def current_row(j: int) -> Dict[int, float]:
        return mod_rows[j] if j in mod_rows else _row_dict(L, j)

    def current_rhs(j: int) -> Dict[int, float]:
        return mod_rhs[j] if j in mod_rhs else {j: 1.0}

    def source_row(j: int) -> Dict[int, float]:
        if config.use_original_rows:
            return _row_dict(L, j)
        return current_row(j)

    def source_rhs(j: int) -> Dict[int, float]:
        if config.use_original_rows:
            return {j: 1.0}
        return current_rhs(j)

    fill_added = 0
    eliminations = 0
    rows_rewritten = 0
    plan_rows: list = []   # (i, tuple(js)) — the replayable elimination log

    targets = np.nonzero(part)[0]
    targets = targets[np.lexsort((targets, orig_level[targets]))]
    # Level-ascending order: every dependency j of a participant row lives
    # in a strictly lower level, so its final (possibly rewritten) equation
    # is already settled when we reach it.
    for i in targets:
        i = int(i)
        row = _row_dict(L, i)
        rhs = {i: 1.0}
        changed = False
        js: list = []
        # Deps needing elimination: rows in the participant set.  With
        # use_original_rows=True an elimination can reintroduce such deps,
        # so loop to a fixed point; otherwise one pass suffices.
        guard = 0
        while True:
            guard += 1
            bad = [
                j
                for j in row
                if j != i
                and part[j]
                and abs(diag[j]) > config.pivot_tol
            ]
            if not bad or guard > n:
                break
            if len(row) > config.max_row_nnz or fill_added + L.nnz > nnz_budget:
                break  # budget hit: keep the partially rewritten row (still exact)
            # eliminate the highest-level offending dep first
            j = max(bad, key=lambda c: orig_level[c])
            t = row[j] / diag[j]
            before = len(row)
            for c, v in source_row(j).items():
                row[c] = row.get(c, 0.0) - t * v
                if row[c] == 0.0 and c != i:
                    del row[c]
            row.pop(j, None)  # exact cancellation of the eliminated entry
            for c, v in source_rhs(j).items():
                rhs[c] = rhs.get(c, 0.0) - t * v
                if rhs[c] == 0.0 and c != i:
                    del rhs[c]
            fill_added += len(row) - before
            eliminations += 1
            js.append(j)
            changed = True
            if not config.use_original_rows:
                # current-row elimination never reintroduces participant
                # deps (row_j was already settled); loop continues for any
                # remaining original participant deps of row i.
                continue
        if changed:
            mod_rows[i] = row
            mod_rhs[i] = rhs
            rows_rewritten += 1
            plan_rows.append((i, tuple(js)))

    # ---- materialize L' and E as CSR --------------------------------------
    r_rows, r_cols, r_vals = [], [], []
    e_rows, e_cols, e_vals = [], [], []
    for i in range(n):
        if i in mod_rows:
            items = sorted(mod_rows[i].items())
        else:
            cols, vals = L.row(i)
            items = list(zip(cols.tolist(), vals.tolist()))
        for c, v in items:
            r_rows.append(i)
            r_cols.append(c)
            r_vals.append(v)
        for c, v in sorted(current_rhs(i).items()):
            e_rows.append(i)
            e_cols.append(c)
            e_vals.append(v)

    Lp = from_coo(r_rows, r_cols, np.asarray(r_vals, dtype=L.dtype), L.shape)
    E = from_coo(e_rows, e_cols, np.asarray(e_vals, dtype=L.dtype), L.shape)
    return Lp, E, tuple(plan_rows), eliminations, rows_rewritten


# --------------------------------------------------------------------------
# public entry point
# --------------------------------------------------------------------------
def rewrite_matrix(
    L: CSRMatrix,
    levels: Optional[LevelSets] = None,
    config: RewriteConfig = RewriteConfig(),
    *,
    upper: bool = False,
) -> RewriteResult:
    """Apply the equation-rewriting transformation.

    ``upper=True`` rewrites an upper-triangular system (e.g. the transpose
    factor ``L.transpose()`` of the backward sweep, whose diagonal is stored
    first per row) over its backward-substitution levels.  The elimination
    machinery is direction-agnostic — the only invariant it needs is that a
    dependency always lives in a strictly lower level than its dependent row,
    which holds for both DAG orientations — so the transposed system reuses
    this function wholesale instead of a reverse-permuted copy of itself.
    """
    if levels is None:
        level = compute_upper_levels(L) if upper else None
        levels = build_level_sets(L, level=level)
    assert config.engine in ENGINES, config.engine
    diag = L.diagonal(first=upper)
    part = _participants(L, levels, config, upper=upper)
    skipped = _count_pivot_skips(L, part, diag, config.pivot_tol)

    use_loop = (config.engine == "loop"
                or (config.engine == "auto" and config.use_original_rows))
    if use_loop:
        Lp, E, plan_rows, eliminations, rows_rewritten = _rewrite_loop(
            L, levels, config, upper=upper, part=part, diag=diag)
        plan = RewritePlan(rows=plan_rows,
                           use_original_rows=config.use_original_rows,
                           upper=upper)
    else:
        if config.use_original_rows:
            raise ValueError(
                "engine='vectorized' does not implement use_original_rows "
                "fixed-point substitution; use engine='loop' (or 'auto')")
        Lp, E, rounds, eliminations, rows_rewritten = _rewrite_vectorized(
            L, levels, config, upper=upper, part=part, diag=diag)
        plan_rows = _rounds_to_rows(rounds)
        plan = RewritePlan(rows=plan_rows, use_original_rows=False,
                           upper=upper, rounds=rounds)

    new_levels = build_level_sets(
        Lp, level=compute_upper_levels(Lp) if upper else None)

    # weighted critical path before/after + per-row cost/benefit (the
    # quantities the transform planner and the critical_path policy trade)
    cp0 = _cp_in_from_levels(L, levels, solve_weights(L), upper=upper)
    cp1 = _cp_in_from_levels(Lp, new_levels, solve_weights(Lp), upper=upper)
    rew_ids = np.asarray(sorted(i for i, _ in plan_rows), dtype=np.int64)
    row_fill = (Lp.row_nnz()[rew_ids] - L.row_nnz()[rew_ids]).astype(np.int64) \
        if rew_ids.size else np.zeros(0, np.int64)
    row_benefit = (cp0[rew_ids] - cp1[rew_ids]).astype(np.int64) \
        if rew_ids.size else np.zeros(0, np.int64)

    e_off = E.nnz - L.n
    stats = RewriteStats(
        levels_before=levels.num_levels,
        levels_after=new_levels.num_levels,
        nnz_before=L.nnz,
        nnz_after=Lp.nnz,
        e_nnz_offdiag=e_off,
        flops_before=L.solve_flops(),
        # solve(L') plus the per-solve SpMV b' = E b (2 flops per off-diag nnz)
        flops_after=Lp.solve_flops() + 2 * e_off,
        rows_rewritten=rows_rewritten,
        eliminations=eliminations,
        eliminations_skipped=skipped,
        policy=config.policy,
        critical_path_before=int(cp0.max()) if cp0.size else 0,
        critical_path_after=int(cp1.max()) if cp1.size else 0,
        rewritten_rows=rew_ids,
        row_fill=row_fill,
        row_benefit=row_benefit,
    )
    return RewriteResult(L=Lp, E=E, levels=new_levels, stats=stats, plan=plan)


def _rounds_to_rows(rounds) -> tuple:
    """Per-row ``(i, (js...))`` summary of the batched rounds, in round/
    processing order (for introspection parity with the loop engine)."""
    out = []
    for r in rounds:
        if r.elim_row.size == 0:
            continue
        first = np.concatenate(
            [[True], r.elim_row[1:] != r.elim_row[:-1]])
        starts = np.nonzero(first)[0]
        bounds = np.concatenate([starts, [r.elim_row.size]])
        for k, s in enumerate(starts):
            out.append((int(r.elim_row[s]),
                        tuple(int(j) for j in r.elim_piv[s:bounds[k + 1]])))
    return tuple(out)


# --------------------------------------------------------------------------
# value-only replay
# --------------------------------------------------------------------------
def replay_rewrite_values(
    system: CSRMatrix,
    plan: RewritePlan,
    Lp: CSRMatrix,
    E: CSRMatrix,
) -> tuple[np.ndarray, np.ndarray]:
    """Replay a recorded elimination plan on **new values** of the same
    sparsity pattern.

    ``system`` carries the original pattern with the *new* data; ``Lp``/``E``
    are the cached rewrite outputs whose patterns the new values must land
    in.  Returns ``(lp_data, e_data)`` aligned to ``Lp``/``E`` — the numeric
    half of :meth:`SpTRSV.refresh`: no level analysis, no elimination-policy
    decisions.  Array-form plans (the batched engine) replay as vectorized
    per-round passes, O(nnz) total; legacy loop-engine plans replay through
    the per-row dict path.

    Raises :class:`RewriteReplayError` when the plan does not transfer (a
    zero pivot, or fill landing outside the cached pattern — possible only
    when the *original* values produced an exact cancellation that the new
    values do not).  Callers should treat that as "rebuild cold".
    """
    if plan.rounds is not None:
        return _replay_vectorized(system, plan, Lp, E)
    return _replay_loop(system, plan, Lp, E)


def _copy_unmodified(system, M, um, out, fill_diag=None):
    """Pattern-aligned vectorized value copy for unmodified rows (with the
    pattern-drift guard), shared by both replay paths."""
    indptr = system.indptr
    cnt = (M.indptr[um + 1] - M.indptr[um]).astype(np.int64)
    if fill_diag is None:
        if not np.array_equal(cnt,
                              (indptr[um + 1] - indptr[um]).astype(np.int64)):
            raise RewriteReplayError("pattern drift in unmodified rows")
        dpos = _expand_pos(M.indptr[um], cnt)
        spos = _expand_pos(indptr[um], cnt)
        out[dpos] = system.data[spos]
    else:
        out[M.indptr[um]] = fill_diag


def _replay_vectorized(system, plan, Lp, E):
    n = system.n
    data = system.data
    indptr, indices = system.indptr, system.indices
    upper = plan.upper
    diag = system.diagonal(first=upper)
    d_off = 1 if upper else 0

    lp_data = np.zeros(Lp.nnz, dtype=data.dtype)
    e_data = np.zeros(E.nnz, dtype=data.dtype)
    mod_any = np.zeros(n, dtype=bool)
    for r in plan.rounds:
        mod_any[r.rows] = True
    um = np.nonzero(~mod_any)[0]
    _copy_unmodified(system, Lp, um, lp_data)
    _copy_unmodified(system, E, um, e_data, fill_diag=1.0)

    settled = np.zeros(n, dtype=bool)
    excl = np.zeros(system.nnz, dtype=bool)
    for r in plan.rounds:
        piv = diag[r.elim_piv]
        if np.any(piv == 0.0):
            bad = int(r.elim_piv[np.nonzero(piv == 0.0)[0][0]])
            raise RewriteReplayError(f"zero pivot at row {bad}")
        t = data[r.coef_pos] / piv
        rows = r.rows
        loc = np.full(n, -1, dtype=np.int64)
        loc[rows] = np.arange(rows.size)
        el_row = loc[r.elim_row]
        el_j = r.elim_piv

        # base entries: original rows minus the eliminated coefficients
        lo, hi = indptr[rows], indptr[rows + 1]
        cnt = (hi - lo).astype(np.int64)
        pos, erow = _expand_ranges(lo, cnt)
        excl[r.coef_pos] = True
        base_keep = ~excl[pos]
        excl[r.coef_pos] = False
        arow = [erow[base_keep]]
        acol = [indices[pos[base_keep]].astype(np.int64)]
        aval = [data[pos[base_keep]]]
        e_arow = [np.arange(rows.size, dtype=np.int64)]
        e_acol = [rows.astype(np.int64)]
        e_aval = [np.ones(rows.size, data.dtype)]

        sm = settled[el_j]
        if (~sm).any():
            oj = el_j[~sm]
            spos, owner = _expand_ranges(
                indptr[oj] + d_off, (indptr[oj + 1] - indptr[oj]) - 1)
            arow.append(el_row[~sm][owner])
            acol.append(indices[spos].astype(np.int64))
            aval.append(-t[~sm][owner] * data[spos])
            e_arow.append(el_row[~sm])
            e_acol.append(oj)
            e_aval.append(-t[~sm])
        if sm.any():
            mj = el_j[sm]
            spos, owner = _expand_ranges(
                Lp.indptr[mj] + d_off, (Lp.indptr[mj + 1] - Lp.indptr[mj]) - 1)
            arow.append(el_row[sm][owner])
            acol.append(Lp.indices[spos].astype(np.int64))
            aval.append(-t[sm][owner] * lp_data[spos])
            spos_e, owner_e = _expand_ranges(
                E.indptr[mj], E.indptr[mj + 1] - E.indptr[mj])
            e_arow.append(el_row[sm][owner_e])
            e_acol.append(E.indices[spos_e].astype(np.int64))
            e_aval.append(-t[sm][owner_e] * e_data[spos_e])

        _scatter_round(np.concatenate(arow), np.concatenate(acol),
                       np.concatenate(aval), rows, Lp, lp_data, n)
        _scatter_round(np.concatenate(e_arow), np.concatenate(e_acol),
                       np.concatenate(e_aval), rows, E, e_data, n)
        settled[rows] = True
    return lp_data, e_data


def _scatter_round(arow, acol, aval, rows, M, out, n):
    """Accumulate round triplets and scatter them into the cached pattern
    rows of ``M``; a nonzero landing outside the pattern means the plan does
    not transfer to these values."""
    key = arow.astype(np.int64) * n + acol
    o = np.argsort(key, kind="stable")
    key_s, val_s = key[o], aval[o]
    first = np.concatenate([[True], key_s[1:] != key_s[:-1]]) \
        if key_s.size else np.zeros(0, bool)
    starts = np.nonzero(first)[0]
    sums = np.add.reduceat(val_s, starts) if starts.size else val_s[:0]
    ukey = key_s[starts]

    cnt = (M.indptr[rows + 1] - M.indptr[rows]).astype(np.int64)
    cpos, cowner = _expand_ranges(M.indptr[rows], cnt)
    ckey = cowner * n + M.indices[cpos]
    idx = np.searchsorted(ckey, ukey)
    idx_c = np.clip(idx, 0, max(ckey.size - 1, 0))
    hit = (idx < ckey.size) & (ckey[idx_c] == ukey) if ckey.size \
        else np.zeros(ukey.shape, bool)
    stray = ~hit & (sums != 0.0)
    if np.any(stray):
        k = int(np.nonzero(stray)[0][0])
        i = int(rows[ukey[k] // n])
        c = int(ukey[k] % n)
        raise RewriteReplayError(
            f"row {i}: fill outside the cached pattern (col {c})")
    out[cpos[idx_c[hit]]] = sums[hit]


def _replay_loop(system, plan, Lp, E):
    """Legacy per-row dict replay for loop-engine plans."""
    n = system.n
    data = system.data
    diag = system.diagonal(first=plan.upper)
    indptr, indices = system.indptr, system.indices

    def orig_row(j: int) -> Dict[int, float]:
        lo, hi = int(indptr[j]), int(indptr[j + 1])
        return dict(zip(indices[lo:hi].tolist(), data[lo:hi].tolist()))

    mod_rows: Dict[int, Dict[int, float]] = {}
    mod_rhs: Dict[int, Dict[int, float]] = {}
    for i, js in plan.rows:
        row = orig_row(i)
        rhs = {i: 1.0}
        for j in js:
            dj = float(diag[j])
            if dj == 0.0:
                raise RewriteReplayError(f"zero pivot at row {j}")
            t = row.get(j, 0.0) / dj
            src_row = (orig_row(j) if plan.use_original_rows
                       else mod_rows.get(j) or orig_row(j))
            for c, v in src_row.items():
                row[c] = row.get(c, 0.0) - t * v
            row.pop(j, None)   # exact cancellation of the eliminated entry
            src_rhs = ({j: 1.0} if plan.use_original_rows
                       else mod_rhs.get(j, {j: 1.0}))
            for c, v in src_rhs.items():
                rhs[c] = rhs.get(c, 0.0) - t * v
        mod_rows[i] = row
        mod_rhs[i] = rhs

    # --- untouched rows: vectorized pattern-aligned copy -------------------
    is_mod = np.zeros(n, dtype=bool)
    if mod_rows:
        is_mod[list(mod_rows)] = True
    lp_data = np.zeros(Lp.nnz, dtype=data.dtype)
    e_data = np.zeros(E.nnz, dtype=data.dtype)
    um = np.nonzero(~is_mod)[0]
    _copy_unmodified(system, Lp, um, lp_data)
    e_data[E.indptr[um]] = 1.0   # unmodified rows: E row is the unit diagonal

    # --- rewritten rows: scatter the replayed dicts into the patterns ------
    for i in mod_rows:
        for M, src, out in ((Lp, mod_rows[i], lp_data),
                            (E, mod_rhs[i], e_data)):
            lo, hi = int(M.indptr[i]), int(M.indptr[i + 1])
            cols_p = M.indices[lo:hi]
            for p in range(lo, hi):
                out[p] = src.get(int(M.indices[p]), 0.0)
            extra = set(src) - set(cols_p.tolist())
            if any(src[c] != 0.0 for c in extra):
                raise RewriteReplayError(
                    f"row {i}: fill outside the cached pattern "
                    f"(cols {sorted(c for c in extra if src[c] != 0.0)})")
    return lp_data, e_data
