"""Equation rewriting — the paper's graph transformation (§III).

Rewriting row ``i`` using its dependency ``j`` substitutes row ``j``'s equation
into row ``i``.  Rearranged back into ``L x = b`` form (paper Fig. 3) this is
the elementary elimination

    row_i <- row_i - (L[i,j]/L[j,j]) * row_j
    b_i   <- b_i   - (L[i,j]/L[j,j]) * b_j

which breaks edge ``j -> i`` in DAG_L (adding fill-in at ``cols(row_j)``) and
lifts row ``i`` to an earlier level.  Applied to rows of *thin* levels it
empties those levels, removing their synchronization barriers (paper: lung2
478 -> 66 levels, +10% FLOPs).

Because ``b`` changes between solves, the RHS update must be replayed per
solve.  We track, for every rewritten row, its expression in the *original*
equations:  ``E`` (unit-lower-triangular, sparse) with ``b' = E b`` applied as
one fully-parallel SpMV.  Solution invariance:  ``L' x = E b  <=>  L x = b``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from .csr import CSRMatrix, from_coo
from .levels import LevelSets, build_level_sets, compute_levels, compute_upper_levels

__all__ = [
    "RewriteConfig",
    "RewriteStats",
    "RewriteResult",
    "RewritePlan",
    "RewriteReplayError",
    "rewrite_matrix",
    "replay_rewrite_values",
]


@dataclasses.dataclass(frozen=True)
class RewriteConfig:
    """Policy for which rows to rewrite (paper: chosen manually; here: the
    thin-level policy of §V plus safety budgets)."""

    thin_threshold: int = 2         # level is thin if rows <= threshold
    max_row_nnz: int = 512          # stop rewriting a row that grows past this
    max_fill_ratio: float = 2.0     # global budget: nnz(L') <= ratio * nnz(L)
    use_original_rows: bool = False  # paper Fig.2 substitutes original
    # equations (may need chains of eliminations); False substitutes the
    # current (already-rewritten) row — one elimination per offending dep.
    pivot_tol: float = 0.0          # skip eliminations with |L_jj| <= tol


@dataclasses.dataclass(frozen=True)
class RewriteStats:
    levels_before: int
    levels_after: int
    nnz_before: int
    nnz_after: int
    e_nnz_offdiag: int
    flops_before: int
    flops_after: int            # solve(L') + spmv(E) per paper-style counting
    rows_rewritten: int
    eliminations: int

    @property
    def level_reduction(self) -> float:
        return 1.0 - self.levels_after / max(self.levels_before, 1)

    @property
    def flop_increase(self) -> float:
        return self.flops_after / max(self.flops_before, 1) - 1.0

    def summary(self) -> str:
        return (
            f"levels {self.levels_before} -> {self.levels_after} "
            f"(-{100*self.level_reduction:.1f}% barriers), "
            f"FLOPs {self.flops_before} -> {self.flops_after} "
            f"(+{100*self.flop_increase:.1f}%), "
            f"rows rewritten {self.rows_rewritten}, "
            f"eliminations {self.eliminations}"
        )


@dataclasses.dataclass(frozen=True)
class RewritePlan:
    """Symbolic record of the eliminations a :func:`rewrite_matrix` run
    performed: for each rewritten row, the ordered dependency rows that were
    eliminated into it.  Replaying the plan on *new values of the same
    sparsity pattern* (:func:`replay_rewrite_values`) reproduces the numeric
    transformation in O(rewritten nnz) without re-running level analysis or
    the elimination policy — the rewrite half of value-only refresh."""

    rows: tuple              # ((i, (j0, j1, ...)), ...) in processing order
    use_original_rows: bool
    upper: bool


class RewriteReplayError(ValueError):
    """The recorded plan does not numerically transfer to the new values
    (zero pivot, or fill produced outside the cached L' pattern — e.g. an
    exact cancellation in the original values that no longer cancels).
    Callers should fall back to a cold rebuild."""


@dataclasses.dataclass(frozen=True)
class RewriteResult:
    L: CSRMatrix            # transformed matrix L'
    E: CSRMatrix            # RHS operator, b' = E b (unit lower triangular)
    levels: LevelSets       # level sets of L'
    stats: RewriteStats
    plan: Optional[RewritePlan] = None   # replayable elimination record


def _row_dict(L: CSRMatrix, i: int) -> Dict[int, float]:
    cols, vals = L.row(i)
    return dict(zip(cols.tolist(), vals.tolist()))


def rewrite_matrix(
    L: CSRMatrix,
    levels: Optional[LevelSets] = None,
    config: RewriteConfig = RewriteConfig(),
    *,
    upper: bool = False,
) -> RewriteResult:
    """Apply the equation-rewriting transformation to rows of thin levels.

    ``upper=True`` rewrites an upper-triangular system (e.g. the transpose
    factor ``L.transpose()`` of the backward sweep, whose diagonal is stored
    first per row) over its backward-substitution levels.  The elimination
    machinery is direction-agnostic — the only invariant it needs is that a
    dependency always lives in a strictly lower level than its dependent row,
    which holds for both DAG orientations — so the transposed system reuses
    this function wholesale instead of a reverse-permuted copy of itself.
    """
    if levels is None:
        level = compute_upper_levels(L) if upper else None
        levels = build_level_sets(L, level=level)
    n = L.n
    orig_level = levels.level
    counts = levels.counts
    kept_levels = set(np.nonzero(counts > config.thin_threshold)[0].tolist())
    kept_levels.add(0)  # level 0 is always a valid destination

    diag = L.diagonal(first=upper)
    nnz_budget = int(config.max_fill_ratio * L.nnz)

    # Rows modified so far: row expression over x-columns, and over b-entries.
    mod_rows: Dict[int, Dict[int, float]] = {}
    mod_rhs: Dict[int, Dict[int, float]] = {}

    def current_row(j: int) -> Dict[int, float]:
        return mod_rows[j] if j in mod_rows else _row_dict(L, j)

    def current_rhs(j: int) -> Dict[int, float]:
        return mod_rhs[j] if j in mod_rhs else {j: 1.0}

    def source_row(j: int) -> Dict[int, float]:
        if config.use_original_rows:
            return _row_dict(L, j)
        return current_row(j)

    def source_rhs(j: int) -> Dict[int, float]:
        if config.use_original_rows:
            return {j: 1.0}
        return current_rhs(j)

    fill_added = 0
    eliminations = 0
    rows_rewritten = 0
    plan_rows: list = []   # (i, tuple(js)) — the replayable elimination log

    # Level-ascending order: every dependency j of row i lives in a strictly
    # lower level (j < i for lower-triangular systems, j > i for upper), so
    # its final (possibly rewritten) equation is already settled when we
    # reach i — thin levels below i's were processed in earlier iterations
    # and kept-level rows are never modified.
    for lv in np.nonzero(counts <= config.thin_threshold)[0]:
        if lv == 0:
            continue  # level-0 rows have no dependencies to break
        for i in levels.rows[lv]:
            i = int(i)
            row = _row_dict(L, i)
            rhs = {i: 1.0}
            changed = False
            js: list = []
            # Deps needing elimination: rows living in removed (thin) levels.
            # With use_original_rows=True an elimination can reintroduce thin
            # deps, so loop to a fixed point; otherwise one pass suffices.
            guard = 0
            while True:
                guard += 1
                bad = [
                    j
                    for j in row
                    if j != i
                    and int(orig_level[j]) not in kept_levels
                    and abs(diag[j]) > config.pivot_tol
                ]
                if not bad or guard > n:
                    break
                if len(row) > config.max_row_nnz or fill_added + L.nnz > nnz_budget:
                    break  # budget hit: keep the partially rewritten row (still exact)
                # eliminate the highest-level offending dep first
                j = max(bad, key=lambda c: orig_level[c])
                t = row[j] / diag[j]
                before = len(row)
                for c, v in source_row(j).items():
                    row[c] = row.get(c, 0.0) - t * v
                    if row[c] == 0.0 and c != i:
                        del row[c]
                row.pop(j, None)  # exact cancellation of the eliminated entry
                for c, v in source_rhs(j).items():
                    rhs[c] = rhs.get(c, 0.0) - t * v
                    if rhs[c] == 0.0 and c != i:
                        del rhs[c]
                fill_added += len(row) - before
                eliminations += 1
                js.append(j)
                changed = True
                if not config.use_original_rows:
                    # current-row elimination never reintroduces thin deps
                    # (row_j was already settled); loop continues for any
                    # remaining original thin deps of row i.
                    continue
            if changed:
                mod_rows[i] = row
                mod_rhs[i] = rhs
                rows_rewritten += 1
                plan_rows.append((i, tuple(js)))

    # ---- materialize L' and E as CSR --------------------------------------
    r_rows, r_cols, r_vals = [], [], []
    e_rows, e_cols, e_vals = [], [], []
    for i in range(n):
        if i in mod_rows:
            items = sorted(mod_rows[i].items())
        else:
            cols, vals = L.row(i)
            items = list(zip(cols.tolist(), vals.tolist()))
        for c, v in items:
            r_rows.append(i)
            r_cols.append(c)
            r_vals.append(v)
        for c, v in sorted(current_rhs(i).items()):
            e_rows.append(i)
            e_cols.append(c)
            e_vals.append(v)

    Lp = from_coo(r_rows, r_cols, np.asarray(r_vals, dtype=L.dtype), L.shape)
    E = from_coo(e_rows, e_cols, np.asarray(e_vals, dtype=L.dtype), L.shape)
    new_levels = build_level_sets(
        Lp, level=compute_upper_levels(Lp) if upper else None)

    e_off = E.nnz - n
    stats = RewriteStats(
        levels_before=levels.num_levels,
        levels_after=new_levels.num_levels,
        nnz_before=L.nnz,
        nnz_after=Lp.nnz,
        e_nnz_offdiag=e_off,
        flops_before=L.solve_flops(),
        # solve(L') plus the per-solve SpMV b' = E b (2 flops per off-diag nnz)
        flops_after=Lp.solve_flops() + 2 * e_off,
        rows_rewritten=rows_rewritten,
        eliminations=eliminations,
    )
    plan = RewritePlan(rows=tuple(plan_rows),
                       use_original_rows=config.use_original_rows,
                       upper=upper)
    return RewriteResult(L=Lp, E=E, levels=new_levels, stats=stats, plan=plan)


def replay_rewrite_values(
    system: CSRMatrix,
    plan: RewritePlan,
    Lp: CSRMatrix,
    E: CSRMatrix,
) -> tuple[np.ndarray, np.ndarray]:
    """Replay a recorded elimination plan on **new values** of the same
    sparsity pattern.

    ``system`` carries the original pattern with the *new* data; ``Lp``/``E``
    are the cached rewrite outputs whose patterns the new values must land
    in.  Returns ``(lp_data, e_data)`` aligned to ``Lp``/``E`` — the numeric
    half of :meth:`SpTRSV.refresh`: no level analysis, no elimination-policy
    decisions, O(nnz) vectorized copy for untouched rows plus a dict replay
    over the (few) rewritten ones.

    Raises :class:`RewriteReplayError` when the plan does not transfer (a
    zero pivot, or fill landing outside the cached pattern — possible only
    when the *original* values produced an exact cancellation that the new
    values do not).  Callers should treat that as "rebuild cold".
    """
    n = system.n
    data = system.data
    diag = system.diagonal(first=plan.upper)
    indptr, indices = system.indptr, system.indices

    def orig_row(j: int) -> Dict[int, float]:
        lo, hi = int(indptr[j]), int(indptr[j + 1])
        return dict(zip(indices[lo:hi].tolist(), data[lo:hi].tolist()))

    mod_rows: Dict[int, Dict[int, float]] = {}
    mod_rhs: Dict[int, Dict[int, float]] = {}
    for i, js in plan.rows:
        row = orig_row(i)
        rhs = {i: 1.0}
        for j in js:
            dj = float(diag[j])
            if dj == 0.0:
                raise RewriteReplayError(f"zero pivot at row {j}")
            t = row.get(j, 0.0) / dj
            src_row = (orig_row(j) if plan.use_original_rows
                       else mod_rows.get(j) or orig_row(j))
            for c, v in src_row.items():
                row[c] = row.get(c, 0.0) - t * v
            row.pop(j, None)   # exact cancellation of the eliminated entry
            src_rhs = ({j: 1.0} if plan.use_original_rows
                       else mod_rhs.get(j, {j: 1.0}))
            for c, v in src_rhs.items():
                rhs[c] = rhs.get(c, 0.0) - t * v
        mod_rows[i] = row
        mod_rhs[i] = rhs

    # --- untouched rows: vectorized pattern-aligned copy -------------------
    is_mod = np.zeros(n, dtype=bool)
    if mod_rows:
        is_mod[list(mod_rows)] = True
    lp_data = np.zeros(Lp.nnz, dtype=data.dtype)
    e_data = np.zeros(E.nnz, dtype=data.dtype)
    um = np.nonzero(~is_mod)[0]
    cnt = (Lp.indptr[um + 1] - Lp.indptr[um]).astype(np.int64)
    if not np.array_equal(cnt, (indptr[um + 1] - indptr[um]).astype(np.int64)):
        raise RewriteReplayError("pattern drift in unmodified rows")
    total = int(cnt.sum())
    off = np.cumsum(cnt) - cnt
    rel = np.arange(total, dtype=np.int64) - np.repeat(off, cnt)
    lp_data[np.repeat(Lp.indptr[um], cnt) + rel] = \
        data[np.repeat(indptr[um], cnt) + rel]
    e_data[E.indptr[um]] = 1.0   # unmodified rows: E row is the unit diagonal

    # --- rewritten rows: scatter the replayed dicts into the patterns ------
    for i in mod_rows:
        for M, src, out in ((Lp, mod_rows[i], lp_data),
                            (E, mod_rhs[i], e_data)):
            lo, hi = int(M.indptr[i]), int(M.indptr[i + 1])
            cols_p = M.indices[lo:hi]
            for p in range(lo, hi):
                out[p] = src.get(int(M.indices[p]), 0.0)
            extra = set(src) - set(cols_p.tolist())
            if any(src[c] != 0.0 for c in extra):
                raise RewriteReplayError(
                    f"row {i}: fill outside the cached pattern "
                    f"(cols {sorted(c for c in extra if src[c] != 0.0)})")
    return lp_data, e_data
