"""Dependency-DAG level-set construction (paper §II, refs [2,18,19]).

The dependency graph ``DAG_L`` has a node per row and an edge ``j -> i`` for
every off-diagonal nonzero ``L[i, j]``.  ``level(i) = 1 + max(level(deps))``
(0 if none).  Rows of a level are mutually independent — the parallel
wavefront; levels execute serially with a barrier between them.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from .csr import CSRMatrix

__all__ = [
    "LevelSets",
    "compute_levels",
    "compute_reverse_levels",
    "compute_upper_levels",
    "build_level_sets",
    "build_reverse_level_sets",
]


def compute_levels(L: CSRMatrix) -> np.ndarray:
    """Level of each row. O(nnz) single pass (rows are topologically ordered
    in a lower-triangular matrix)."""
    n = L.n
    level = np.zeros(n, dtype=np.int64)
    indptr, indices = L.indptr, L.indices
    for i in range(n):
        lo, hi = indptr[i], indptr[i + 1]
        cols = indices[lo:hi]
        # off-diagonal dependencies only
        if hi - lo > 1:
            deps = cols[cols < i]
            if deps.size:
                level[i] = level[deps].max() + 1
    return level


def compute_reverse_levels(
    L: CSRMatrix, forward: "LevelSets | None" = None
) -> np.ndarray:
    """Level of each row in the *transpose* solve ``Lᵀ x = b``, derived from
    the forward CSR.

    ``DAG_{Lᵀ}`` is ``DAG_L`` with every edge reversed (transpose row ``j``
    depends on ``x[i]`` for each nonzero ``L[i, j]``, ``i > j``), so the
    backward level sets come out of the *same* symbolic analysis as the
    forward ones, scattering ``rlevel[j] = max(rlevel[j], rlevel[i] + 1)``
    over ``L``'s own CSR arrays — no transpose matrix, no
    reverse-permutation, no second DAG traversal.

    When the forward :class:`LevelSets` are passed, the scatter runs as one
    vectorized ``maximum.at`` per forward wavefront, highest level first
    (every edge ``j -> i`` has ``level(j) < level(i)``, so by the time level
    ``lv`` is swept all consumers of its rows are settled).  This is the
    shared-analysis fast path — the per-row python loop only remains as the
    fallback when no forward analysis exists.
    """
    n = L.n
    rlevel = np.zeros(n, dtype=np.int64)
    indptr, indices = L.indptr, L.indices
    if forward is not None:
        for rows in reversed(forward.rows):
            starts = indptr[rows]
            cnt = indptr[rows + 1] - starts
            total = int(cnt.sum())
            if total == 0:
                continue
            off = np.cumsum(cnt) - cnt
            pos = np.repeat(starts - off, cnt) + np.arange(total)
            cols = indices[pos]
            mask = cols < np.repeat(rows, cnt)  # off-diagonal entries only
            np.maximum.at(
                rlevel, cols[mask], np.repeat(rlevel[rows] + 1, cnt)[mask])
        return rlevel
    for i in range(n - 1, -1, -1):
        lo, hi = indptr[i], indptr[i + 1]
        if hi - lo > 1:
            cols = indices[lo:hi]
            deps = cols[cols < i]
            if deps.size:
                np.maximum.at(rlevel, deps, rlevel[i] + 1)
    return rlevel


def compute_upper_levels(U: CSRMatrix) -> np.ndarray:
    """Levels of the backward-substitution DAG of an *upper*-triangular CSR
    (row ``i`` depends on columns ``j > i``).  ``compute_upper_levels(L.transpose())``
    equals :func:`compute_reverse_levels(L)`; this gather form exists for
    matrices that are only available in upper form (e.g. a rewritten Lᵀ)."""
    n = U.n
    level = np.zeros(n, dtype=np.int64)
    indptr, indices = U.indptr, U.indices
    for i in range(n - 1, -1, -1):
        lo, hi = indptr[i], indptr[i + 1]
        if hi - lo > 1:
            cols = indices[lo:hi]
            deps = cols[cols > i]
            if deps.size:
                level[i] = level[deps].max() + 1
    return level


@dataclasses.dataclass(frozen=True)
class LevelSets:
    """Rows grouped by level.

    ``level``       (n,) level id per row
    ``rows``        list over levels of row-id arrays (sorted)
    ``counts``      (num_levels,) rows per level
    """

    level: np.ndarray
    rows: List[np.ndarray]
    counts: np.ndarray

    @property
    def num_levels(self) -> int:
        return len(self.rows)

    def thin_levels(self, threshold: int) -> np.ndarray:
        """Level ids whose row count is <= threshold (the paper's thin levels;
        94% of lung2's 478 levels have only 2 rows)."""
        return np.nonzero(self.counts <= threshold)[0]

    def thin_fraction(self, threshold: int) -> float:
        return float((self.counts <= threshold).mean()) if self.num_levels else 0.0

    def histogram(self) -> dict:
        uniq, cnt = np.unique(self.counts, return_counts=True)
        return {int(u): int(c) for u, c in zip(uniq, cnt)}


def build_level_sets(L: CSRMatrix, level: np.ndarray | None = None) -> LevelSets:
    if level is None:
        level = compute_levels(L)
    num_levels = int(level.max()) + 1 if level.size else 0
    order = np.argsort(level, kind="stable")
    counts = np.bincount(level, minlength=num_levels)
    rows: List[np.ndarray] = []
    off = 0
    for lv in range(num_levels):
        c = int(counts[lv])
        rows.append(np.sort(order[off : off + c]))
        off += c
    return LevelSets(level=level, rows=rows, counts=counts)


def build_reverse_level_sets(
    L: CSRMatrix,
    rlevel: np.ndarray | None = None,
    *,
    forward: "LevelSets | None" = None,
) -> LevelSets:
    """Backward (``Lᵀ x = b``) level sets of a lower-triangular ``L``,
    sharing the forward analysis (see :func:`compute_reverse_levels`; pass
    ``forward`` to hit the vectorized per-wavefront derivation)."""
    if rlevel is None:
        rlevel = compute_reverse_levels(L, forward)
    return build_level_sets(L, level=rlevel)
