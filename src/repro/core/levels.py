"""Dependency-DAG level-set construction (paper §II, refs [2,18,19]).

The dependency graph ``DAG_L`` has a node per row and an edge ``j -> i`` for
every off-diagonal nonzero ``L[i, j]``.  ``level(i) = 1 + max(level(deps))``
(0 if none).  Rows of a level are mutually independent — the parallel
wavefront; levels execute serially with a barrier between them.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from .csr import CSRMatrix

__all__ = ["LevelSets", "compute_levels", "build_level_sets"]


def compute_levels(L: CSRMatrix) -> np.ndarray:
    """Level of each row. O(nnz) single pass (rows are topologically ordered
    in a lower-triangular matrix)."""
    n = L.n
    level = np.zeros(n, dtype=np.int64)
    indptr, indices = L.indptr, L.indices
    for i in range(n):
        lo, hi = indptr[i], indptr[i + 1]
        cols = indices[lo:hi]
        # off-diagonal dependencies only
        if hi - lo > 1:
            deps = cols[cols < i]
            if deps.size:
                level[i] = level[deps].max() + 1
    return level


@dataclasses.dataclass(frozen=True)
class LevelSets:
    """Rows grouped by level.

    ``level``       (n,) level id per row
    ``rows``        list over levels of row-id arrays (sorted)
    ``counts``      (num_levels,) rows per level
    """

    level: np.ndarray
    rows: List[np.ndarray]
    counts: np.ndarray

    @property
    def num_levels(self) -> int:
        return len(self.rows)

    def thin_levels(self, threshold: int) -> np.ndarray:
        """Level ids whose row count is <= threshold (the paper's thin levels;
        94% of lung2's 478 levels have only 2 rows)."""
        return np.nonzero(self.counts <= threshold)[0]

    def thin_fraction(self, threshold: int) -> float:
        return float((self.counts <= threshold).mean()) if self.num_levels else 0.0

    def histogram(self) -> dict:
        uniq, cnt = np.unique(self.counts, return_counts=True)
        return {int(u): int(c) for u, c in zip(uniq, cnt)}


def build_level_sets(L: CSRMatrix, level: np.ndarray | None = None) -> LevelSets:
    if level is None:
        level = compute_levels(L)
    num_levels = int(level.max()) + 1 if level.size else 0
    order = np.argsort(level, kind="stable")
    counts = np.bincount(level, minlength=num_levels)
    rows: List[np.ndarray] = []
    off = 0
    for lv in range(num_levels):
        c = int(counts[lv])
        rows.append(np.sort(order[off : off + c]))
        off += c
    return LevelSets(level=level, rows=rows, counts=counts)
