"""Dependency-DAG level-set construction (paper §II, refs [2,18,19]).

The dependency graph ``DAG_L`` has a node per row and an edge ``j -> i`` for
every off-diagonal nonzero ``L[i, j]``.  ``level(i) = 1 + max(level(deps))``
(0 if none).  Rows of a level are mutually independent — the parallel
wavefront; levels execute serially with a barrier between them.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from .csr import CSRMatrix

__all__ = [
    "LevelSets",
    "Criticality",
    "SupernodeConfig",
    "Supernodes",
    "compute_levels",
    "compute_reverse_levels",
    "compute_upper_levels",
    "build_level_sets",
    "build_reverse_level_sets",
    "detect_supernodes",
    "solve_weights",
    "compute_critical_path",
    "compute_criticality",
]


def _propagate_levels(n: int, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    """Longest-path layering of the DAG with edges ``src -> dst``, fully
    vectorized per wavefront (one ``maximum.at`` scatter per level).

    Each edge is touched exactly once across all wavefronts, so the total
    work is O(nnz + n) numpy ops — the analysis phase stops being bound by a
    per-row Python loop (arXiv:1710.04985's point: analysis must be cheap for
    specialization economics to hold).  The number of Python iterations
    equals the number of levels, but each is a handful of array ops.
    """
    level = np.zeros(n, dtype=np.int64)
    if src.size == 0:
        return level
    indeg = np.bincount(dst, minlength=n)
    # group edges by source (CSR-of-the-edge-list): out-edges of one node
    # are contiguous in dst_sorted
    cnt_src = np.bincount(src, minlength=n)
    outptr = np.concatenate([[0], np.cumsum(cnt_src)])
    dst_sorted = dst[np.argsort(src, kind="stable")]
    frontier = np.nonzero(indeg == 0)[0]
    while frontier.size:
        starts = outptr[frontier]
        cnt = outptr[frontier + 1] - starts
        total = int(cnt.sum())
        if total == 0:
            break
        off = np.cumsum(cnt) - cnt
        pos = np.repeat(starts - off, cnt) + np.arange(total)
        targets = dst_sorted[pos]
        np.maximum.at(level, targets, np.repeat(level[frontier] + 1, cnt))
        np.subtract.at(indeg, targets, 1)
        # a target may appear several times in this wavefront's edge list —
        # dedupe before it becomes a frontier node
        frontier = np.unique(targets[indeg[targets] == 0])
    return level


def _propagate_weighted(
    n: int, src: np.ndarray, dst: np.ndarray, w: np.ndarray
) -> np.ndarray:
    """Weighted longest-path accumulation over the DAG with edges
    ``src -> dst``: ``cp[i] = w[i] + max(cp[deps(i)], default 0)``.

    Same per-wavefront vectorization as :func:`_propagate_levels` (each edge
    touched once, O(nnz + n) total); with unit weights this reduces to
    ``level + 1``.  This is the quantity Böhnlein et al. show actually bounds
    parallel solve time — the *weighted critical path* — as opposed to the
    raw level count."""
    w = np.asarray(w, dtype=np.int64)
    cp = w.copy()
    if src.size == 0:
        return cp
    indeg = np.bincount(dst, minlength=n)
    cnt_src = np.bincount(src, minlength=n)
    outptr = np.concatenate([[0], np.cumsum(cnt_src)])
    dst_sorted = dst[np.argsort(src, kind="stable")]
    frontier = np.nonzero(indeg == 0)[0]
    while frontier.size:
        starts = outptr[frontier]
        cnt = outptr[frontier + 1] - starts
        total = int(cnt.sum())
        if total == 0:
            break
        off = np.cumsum(cnt) - cnt
        pos = np.repeat(starts - off, cnt) + np.arange(total)
        targets = dst_sorted[pos]
        np.maximum.at(cp, targets,
                      np.repeat(cp[frontier], cnt) + w[targets])
        np.subtract.at(indeg, targets, 1)
        frontier = np.unique(targets[indeg[targets] == 0])
    return cp


def solve_weights(M: CSRMatrix) -> np.ndarray:
    """Per-row substitution cost in FLOPs (mul+sub per off-diagonal nonzero,
    one divide) — the default weights of the weighted critical path."""
    return (2 * (M.row_nnz() - 1) + 1).astype(np.int64)


def _edge_arrays(M: CSRMatrix, *, upper: bool) -> tuple[np.ndarray, np.ndarray]:
    """Dependency edges ``src -> dst`` of the substitution DAG: for a lower
    matrix row ``i`` depends on cols ``j < i`` (edge j -> i); for an upper
    matrix on cols ``j > i``."""
    row_of = np.repeat(np.arange(M.n, dtype=np.int64), M.row_nnz())
    mask = (M.indices > row_of) if upper else (M.indices < row_of)
    return M.indices[mask], row_of[mask]


def compute_levels(L: CSRMatrix) -> np.ndarray:
    """Level of each row of a lower-triangular matrix: ``1 + max`` over
    off-diagonal dependencies.  Vectorized per wavefront — O(nnz) total, no
    per-row Python loop (see :func:`_propagate_levels`)."""
    src, dst = _edge_arrays(L, upper=False)
    return _propagate_levels(L.n, src, dst)


def compute_reverse_levels(
    L: CSRMatrix, forward: "LevelSets | None" = None
) -> np.ndarray:
    """Level of each row in the *transpose* solve ``Lᵀ x = b``, derived from
    the forward CSR.

    ``DAG_{Lᵀ}`` is ``DAG_L`` with every edge reversed (transpose row ``j``
    depends on ``x[i]`` for each nonzero ``L[i, j]``, ``i > j``), so the
    backward level sets come out of the *same* symbolic analysis as the
    forward ones, scattering ``rlevel[j] = max(rlevel[j], rlevel[i] + 1)``
    over ``L``'s own CSR arrays — no transpose matrix, no
    reverse-permutation, no second DAG traversal.

    When the forward :class:`LevelSets` are passed, the scatter runs as one
    vectorized ``maximum.at`` per forward wavefront, highest level first
    (every edge ``j -> i`` has ``level(j) < level(i)``, so by the time level
    ``lv`` is swept all consumers of its rows are settled).  This is the
    shared-analysis fast path; without a forward analysis the same
    vectorized wavefront propagation runs on the reversed edge list.
    """
    n = L.n
    if forward is not None:
        rlevel = np.zeros(n, dtype=np.int64)
        indptr, indices = L.indptr, L.indices
        for rows in reversed(forward.rows):
            starts = indptr[rows]
            cnt = indptr[rows + 1] - starts
            total = int(cnt.sum())
            if total == 0:
                continue
            off = np.cumsum(cnt) - cnt
            pos = np.repeat(starts - off, cnt) + np.arange(total)
            cols = indices[pos]
            mask = cols < np.repeat(rows, cnt)  # off-diagonal entries only
            np.maximum.at(
                rlevel, cols[mask], np.repeat(rlevel[rows] + 1, cnt)[mask])
        return rlevel
    # no forward analysis: the reversed DAG has edges i -> j for every
    # off-diagonal L[i, j] — same vectorized wavefront propagation
    src, dst = _edge_arrays(L, upper=False)
    return _propagate_levels(n, dst, src)


def compute_upper_levels(U: CSRMatrix) -> np.ndarray:
    """Levels of the backward-substitution DAG of an *upper*-triangular CSR
    (row ``i`` depends on columns ``j > i``).  ``compute_upper_levels(L.transpose())``
    equals :func:`compute_reverse_levels(L)`; this form exists for matrices
    that are only available in upper form (e.g. a rewritten Lᵀ).  Vectorized
    per wavefront like :func:`compute_levels`."""
    src, dst = _edge_arrays(U, upper=True)
    return _propagate_levels(U.n, src, dst)


@dataclasses.dataclass(frozen=True)
class LevelSets:
    """Rows grouped by level.

    ``level``       (n,) level id per row
    ``rows``        list over levels of row-id arrays (sorted)
    ``counts``      (num_levels,) rows per level
    """

    level: np.ndarray
    rows: List[np.ndarray]
    counts: np.ndarray

    @property
    def num_levels(self) -> int:
        return len(self.rows)

    def thin_levels(self, threshold: int) -> np.ndarray:
        """Level ids whose row count is <= threshold (the paper's thin levels;
        94% of lung2's 478 levels have only 2 rows)."""
        return np.nonzero(self.counts <= threshold)[0]

    def thin_fraction(self, threshold: int) -> float:
        return float((self.counts <= threshold).mean()) if self.num_levels else 0.0

    def histogram(self) -> dict:
        uniq, cnt = np.unique(self.counts, return_counts=True)
        return {int(u): int(c) for u, c in zip(uniq, cnt)}

    def row_permutation(self) -> np.ndarray:
        """Level-order row permutation: original row id at each position when
        rows are laid out level by level.  This is the *analysis-side* view
        of the permuted execution space; the executed permutation comes from
        :meth:`repro.core.codegen.Schedule.perm` (which additionally reflects
        in-slab nnz sorting and bucket splits) — both place every level's
        rows in one contiguous span."""
        if not self.rows:
            return np.zeros(0, dtype=np.int64)
        return np.concatenate(self.rows).astype(np.int64)


def build_level_sets(L: CSRMatrix, level: np.ndarray | None = None) -> LevelSets:
    if level is None:
        level = compute_levels(L)
    num_levels = int(level.max()) + 1 if level.size else 0
    order = np.argsort(level, kind="stable")
    counts = np.bincount(level, minlength=num_levels)
    rows: List[np.ndarray] = []
    off = 0
    for lv in range(num_levels):
        c = int(counts[lv])
        rows.append(np.sort(order[off : off + c]))
        off += c
    return LevelSets(level=level, rows=rows, counts=counts)


@dataclasses.dataclass(frozen=True)
class Criticality:
    """Weighted longest-chain membership of every row (Böhnlein et al.:
    the *weighted critical path* of DAG_L bounds parallel solve time, not
    the level count).

    ``cp_in``   (n,) weight of the heaviest dependency chain ENDING at each
                row (row's own weight included)
    ``cp_out``  (n,) weight of the heaviest chain STARTING at each row
    ``weights`` (n,) per-row weights used (default: row solve FLOPs)

    ``through(i) = cp_in[i] + cp_out[i] - weights[i]`` is the heaviest
    complete chain passing through row ``i``; rows with
    ``critical_path - through(i) <= slack`` lie on (near-)critical chains —
    exactly the rows whose equation rewriting shortens the bound.
    """

    cp_in: np.ndarray
    cp_out: np.ndarray
    weights: np.ndarray

    @property
    def critical_path(self) -> int:
        return int(self.cp_in.max()) if self.cp_in.size else 0

    def through(self) -> np.ndarray:
        return self.cp_in + self.cp_out - self.weights

    def slack(self) -> np.ndarray:
        return self.critical_path - self.through()

    def near_critical(self, slack_fraction: float = 0.05) -> np.ndarray:
        """Rows whose heaviest through-chain is within ``slack_fraction`` of
        the critical path — the rewrite targets of ``policy="critical_path"``."""
        if not self.cp_in.size:
            return np.zeros(0, dtype=bool)
        return self.slack() <= slack_fraction * self.critical_path


def _offdiag_entries(M: CSRMatrix, rows: np.ndarray, upper: bool):
    """Positions of the off-diagonal (dependency) entries of ``rows`` plus
    per-row counts — the diagonal is stored last (lower) or first (upper),
    so the dependency span of every row is one contiguous slice.  Rows
    without a stored diagonal (degenerate inputs) count as dependency-free
    rather than producing negative spans."""
    lo = M.indptr[rows] + (1 if upper else 0)
    ln = np.maximum((M.indptr[rows + 1] - M.indptr[rows]) - 1, 0)
    total = int(ln.sum())
    off = np.cumsum(ln) - ln
    pos = np.repeat(lo - off, ln) + np.arange(total)
    return pos, ln


def _cp_in_from_levels(
    M: CSRMatrix, levels: "LevelSets", w: np.ndarray, *, upper: bool = False
) -> np.ndarray:
    """``cp_in`` computed one level set at a time: one gather +
    ``maximum.reduceat`` per wavefront — no edge-list sort, no in-degree
    bookkeeping.  The fast path when level sets already exist (they always
    do inside the rewrite/planner)."""
    cp = np.asarray(w, np.int64).copy()
    for rows in levels.rows[1:]:
        pos, ln = _offdiag_entries(M, rows, upper)
        has = ln > 0
        if not has.any():
            continue
        starts = (np.cumsum(ln) - ln)[has]
        best = np.maximum.reduceat(cp[M.indices[pos]], starts)
        r = rows[has]
        cp[r] = w[r] + best
    return cp


def _cp_out_from_levels(
    M: CSRMatrix, levels: "LevelSets", w: np.ndarray, *, upper: bool = False
) -> np.ndarray:
    """``cp_out`` by sweeping level sets highest-first and scattering each
    row's settled chain weight onto its dependencies (every consumer of a
    row lives in a strictly higher level, so it is settled first)."""
    cp = np.asarray(w, np.int64).copy()
    for rows in reversed(levels.rows[1:]):
        pos, ln = _offdiag_entries(M, rows, upper)
        cols = M.indices[pos]
        np.maximum.at(cp, cols, np.repeat(cp[rows], ln) + w[cols])
    return cp


def compute_criticality(
    M: CSRMatrix,
    levels: "LevelSets | None" = None,
    *,
    upper: bool = False,
    weights: np.ndarray | None = None,
) -> Criticality:
    """Weighted criticality of every row of a triangular system.  With
    ``levels`` given, both directions run as per-level-set reductions (the
    fast path); otherwise two generic wavefront propagations."""
    w = solve_weights(M) if weights is None else np.asarray(weights, np.int64)
    if levels is not None:
        return Criticality(
            cp_in=_cp_in_from_levels(M, levels, w, upper=upper),
            cp_out=_cp_out_from_levels(M, levels, w, upper=upper),
            weights=w,
        )
    src, dst = _edge_arrays(M, upper=upper)
    return Criticality(
        cp_in=_propagate_weighted(M.n, src, dst, w),
        cp_out=_propagate_weighted(M.n, dst, src, w),
        weights=w,
    )


def compute_critical_path(
    M: CSRMatrix,
    levels: "LevelSets | None" = None,
    *,
    upper: bool = False,
    weights: np.ndarray | None = None,
) -> int:
    """Weighted critical path of the substitution DAG (one forward
    propagation — cheaper than :func:`compute_criticality` when only the
    scalar bound is needed, e.g. by :func:`repro.core.analysis.analyze`)."""
    if M.n == 0:
        return 0
    w = solve_weights(M) if weights is None else np.asarray(weights, np.int64)
    if levels is not None:
        return int(_cp_in_from_levels(M, levels, w, upper=upper).max())
    src, dst = _edge_arrays(M, upper=upper)
    return int(_propagate_weighted(M.n, src, dst, w).max())


def build_reverse_level_sets(
    L: CSRMatrix,
    rlevel: np.ndarray | None = None,
    *,
    forward: "LevelSets | None" = None,
) -> LevelSets:
    """Backward (``Lᵀ x = b``) level sets of a lower-triangular ``L``,
    sharing the forward analysis (see :func:`compute_reverse_levels`; pass
    ``forward`` to hit the vectorized per-wavefront derivation)."""
    if rlevel is None:
        rlevel = compute_reverse_levels(L, forward)
    return build_level_sets(L, level=rlevel)


# ---------------------------------------------------------------------------
# Supernode detection (node-granular schedules)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SupernodeConfig:
    """Amalgamation policy for supernode detection.

    ``relax``      relative structural-mismatch budget per row pair: rows
                   ``i-1`` and ``i`` amalgamate when
                   ``|pattern(i-1) Δ pattern(i)\\{i-1}| <= relax * max(|..|)``.
                   ``0.0`` demands exact column-structure match (classic
                   supernodes); larger values admit *padded* amalgamation —
                   mismatched positions become explicit zeros in the dense
                   diagonal block (Tacho-style relaxed supernodes).  A banded
                   factor of bandwidth ``bw`` needs ``relax >= 1/(bw+1)`` for
                   interior rows to merge.
    ``max_block``  hard cap on rows per supernode — bounds the ``T x T``
                   dense diagonal block the executor inverts and applies.
    """

    relax: float = 0.25
    max_block: int = 64

    def __post_init__(self) -> None:
        assert self.relax >= 0.0, "relax must be non-negative"
        assert self.max_block >= 1, "max_block must be >= 1"


@dataclasses.dataclass(frozen=True)
class Supernodes:
    """Partition of the rows into contiguous supernodes (dense blocks).

    Any contiguous run of rows of a triangular matrix is a *valid* block —
    for a lower block ``r0 .. r0+s-1`` every off-block dependency is a column
    ``< r0`` (already solved when the block runs), so detection is purely a
    profitability heuristic, never a correctness condition.  The scalar-row
    schedule is the all-singleton special case of this partition.

    ``super_of_row``  (n,) supernode id of each row
    ``block_ptr``     (num_supernodes+1,) row span of block ``k`` is
                      ``block_ptr[k] : block_ptr[k+1]``
    """

    n: int
    super_of_row: np.ndarray
    block_ptr: np.ndarray
    config: SupernodeConfig

    @property
    def num_supernodes(self) -> int:
        return len(self.block_ptr) - 1

    def sizes(self) -> np.ndarray:
        return np.diff(self.block_ptr)

    @property
    def max_block_size(self) -> int:
        return int(self.sizes().max()) if self.num_supernodes else 0

    @property
    def mean_block_size(self) -> float:
        return self.n / max(self.num_supernodes, 1)

    @property
    def dense_block_fraction(self) -> float:
        """Fraction of rows living in blocks of >= 2 rows — 0.0 when the
        blocked schedule degenerates to scalar rows."""
        if self.n == 0:
            return 0.0
        sz = self.sizes()
        return float(sz[sz >= 2].sum()) / self.n


def _pair_mismatch(M: CSRMatrix, *, upper: bool) -> np.ndarray:
    """Structural mismatch of every adjacent row pair, vectorized.

    For pair ``p`` (rows ``p-1`` and ``p``, ``p in [1, n)``) compare the sets

    * lower: A = all stored cols of row ``p-1`` (diag col ``p-1`` included),
      B = strict-lower cols of row ``p`` — equal sets mean row ``p``'s
      off-diagonal pattern is row ``p-1``'s pattern plus the in-block column,
      the classic supernode criterion;
    * upper: A = strict-upper cols of row ``p-1``, B = all stored cols of
      row ``p`` (diag col ``p`` included).

    ``mismatch[p] = |A| + |B| - 2 |A ∩ B|`` (symmetric difference).  All
    pairs at once: each (pair, col) entry keys to ``p * n + col``; both key
    arrays are duplicate-free, so one ``intersect1d(assume_unique=True)``
    plus a ``bincount`` of ``common // n`` yields every intersection size in
    O(nnz log nnz).
    """
    n = M.n
    mismatch = np.zeros(n, dtype=np.int64)
    if n <= 1:
        return mismatch
    row_nnz = M.row_nnz()
    row_of = np.repeat(np.arange(n, dtype=np.int64), row_nnz)
    strict = (M.indices > row_of) if upper else (M.indices < row_of)
    if upper:
        a_mask = strict & (row_of < n - 1)          # offdiag cols of row p-1
        b_mask = row_of >= 1                        # full cols of row p
        pair_a, pair_b = row_of + 1, row_of
        len_a = np.maximum(row_nnz[:-1] - 1, 0)
        len_b = row_nnz[1:]
    else:
        a_mask = row_of < n - 1                     # full cols of row p-1
        b_mask = strict & (row_of >= 1)             # offdiag cols of row p
        pair_a, pair_b = row_of + 1, row_of
        len_a = row_nnz[:-1]
        len_b = np.maximum(row_nnz[1:] - 1, 0)
    a_keys = pair_a[a_mask] * n + M.indices[a_mask]
    b_keys = pair_b[b_mask] * n + M.indices[b_mask]
    common = np.intersect1d(a_keys, b_keys, assume_unique=True)
    inter = np.bincount(common // n, minlength=n)[1:]
    mismatch[1:] = len_a + len_b - 2 * inter
    return mismatch


def detect_supernodes(
    M: CSRMatrix,
    *,
    upper: bool = False,
    config: SupernodeConfig | None = None,
) -> Supernodes:
    """Amalgamate contiguous runs of rows with identical (``relax=0``) or
    near-identical column structure into supernodes, fully vectorized.

    A pair merges when its structural mismatch stays within the relaxation
    budget (see :class:`SupernodeConfig`); runs are then cut every
    ``max_block`` rows.  Matrices with no amalgamatable rows degrade to the
    all-singleton partition — the scalar-row schedule."""
    cfg = config if config is not None else SupernodeConfig()
    n = M.n
    if n == 0:
        return Supernodes(n=0, super_of_row=np.zeros(0, np.int64),
                          block_ptr=np.zeros(1, np.int64), config=cfg)
    mismatch = _pair_mismatch(M, upper=upper)
    row_nnz = M.row_nnz()
    if upper:
        len_a = np.maximum(row_nnz[:-1] - 1, 0)
        len_b = row_nnz[1:]
    else:
        len_a = row_nnz[:-1]
        len_b = np.maximum(row_nnz[1:] - 1, 0)
    budget = cfg.relax * np.maximum(np.maximum(len_a, len_b), 1)
    breaks = np.ones(n, dtype=bool)
    breaks[1:] = mismatch[1:] > budget
    # cut merge runs every max_block rows: offset of each row inside its run
    run_starts = np.nonzero(breaks)[0]
    run_id = np.cumsum(breaks) - 1
    offset_in_run = np.arange(n) - run_starts[run_id]
    breaks |= (offset_in_run % cfg.max_block) == 0
    super_of_row = np.cumsum(breaks) - 1
    block_ptr = np.concatenate([np.nonzero(breaks)[0], [n]]).astype(np.int64)
    return Supernodes(n=n, super_of_row=super_of_row.astype(np.int64),
                      block_ptr=block_ptr, config=cfg)
