"""The paper's contribution: dependency-graph transformation (equation
rewriting) and specialized code generation for SpTRSV, adapted to TPU/JAX."""
from .analysis import MatrixAnalysis, analyze
from .csr import CSRMatrix, eye_csr, from_coo, from_dense
from .levels import (
    LevelSets,
    build_level_sets,
    build_reverse_level_sets,
    compute_levels,
    compute_reverse_levels,
    compute_upper_levels,
)
from .rewrite import (
    RewriteConfig,
    RewritePlan,
    RewriteReplayError,
    RewriteResult,
    RewriteStats,
    replay_rewrite_values,
    rewrite_matrix,
)
from .codegen import Schedule, build_schedule, make_levelset_solver, make_serial_solver
from .packed import (
    PackedLayout,
    PackedStats,
    build_packed_layout,
    make_packed_levelset_solver,
    pack_values,
)
from .coarsen import (
    CoarsenConfig,
    CoarsenStats,
    PlanDecision,
    coarsen_schedule,
    coarsen_stats,
    plan_strategy,
    schedule_cost,
)
from .solver import LAYOUTS, STRATEGIES, SpTRSV

__all__ = [
    "MatrixAnalysis",
    "analyze",
    "CSRMatrix",
    "eye_csr",
    "from_coo",
    "from_dense",
    "LevelSets",
    "build_level_sets",
    "build_reverse_level_sets",
    "compute_levels",
    "compute_reverse_levels",
    "compute_upper_levels",
    "RewriteConfig",
    "RewritePlan",
    "RewriteReplayError",
    "RewriteResult",
    "RewriteStats",
    "replay_rewrite_values",
    "rewrite_matrix",
    "PackedLayout",
    "PackedStats",
    "build_packed_layout",
    "make_packed_levelset_solver",
    "pack_values",
    "Schedule",
    "build_schedule",
    "make_levelset_solver",
    "make_serial_solver",
    "CoarsenConfig",
    "CoarsenStats",
    "PlanDecision",
    "coarsen_schedule",
    "coarsen_stats",
    "plan_strategy",
    "schedule_cost",
    "LAYOUTS",
    "STRATEGIES",
    "SpTRSV",
]
