"""Sync-free speculative solve-then-correct SpTRSV (ROADMAP item 2).

Every other strategy in the repo is barrier-synchronous per segment:
coarsening (PR 3) cut a lung2-class schedule from ~493 sync points to ~58,
but each remaining segment is still a separate dispatch whose consumers wait
on it.  This module drops intra-solve synchronization itself, following the
stale-synchronous line of Li (arXiv:1710.04985, sync-free self-scheduling)
and Steiner et al. (arXiv:2607.02324, bounded staleness + correction):

**Speculate.**  Split ``L = D + N`` (diagonal + strictly-triangular part)
and run ``k`` Jacobi-style triangular sweeps

    x ← D⁻¹ (b − N x),        x₀ = D⁻¹ b

each sweep ONE fused vectorized update over all rows — a single ELL
gather/FMA/divide with no per-level loop, no segments, no barriers.  The
``k`` sweeps are unrolled at trace time, so the executor's jaxpr contains no
loop or collective structure at all and its per-solve cost is **independent
of the level count** — the first executor in the repo for which that holds.

Why this converges: the iteration matrix ``D⁻¹N`` is strictly triangular,
hence nilpotent — after ``depth`` sweeps (the schedule's level count) the
solve is *exact* in exact arithmetic, because each sweep propagates
information one wavefront further.  Long before that, rows whose
off-diagonal mass is small relative to the diagonal contract geometrically:
with ``q = ‖D⁻¹N‖_∞ < 1`` the error shrinks by ``q`` per sweep, so a
diagonally-dominant lung2-class factor reaches machine precision in ~10-20
sweeps despite its ~480 levels.

**Verify.**  After the k-th sweep one more fused pass evaluates the
componentwise residual ratio

    max_i |b − L x|_i / (|N||x| + |D||x| + |b|)_i

(the standard componentwise backward-error bound — tight enough that an
accepted solution is backward-stable like substitution itself).

**Correct.**  Columns whose ratio exceeds ``residual_tol`` are re-solved by
an exact strategy (``SweepConfig.fallback``, built lazily from the same
analysis) and spliced in, making the executor oracle-equivalent: fast when
speculation lands, never wrong when it does not.  ``fallback=None`` skips
verification entirely — the *inexact preconditioner* mode
(:func:`repro.core.pcg.make_ic_preconditioner` with ``sweeps=k``), where
``M⁻¹`` only needs to be a fixed linear contraction.

The ELL value/diag buffers are runtime jit arguments with recorded source
maps (``layout="permuted"`` default), so :meth:`SpTRSV.refresh` re-packs
them in O(nnz) with a jit-cache hit, exactly like the packed level-set
executors.
"""
from __future__ import annotations

import dataclasses
import logging
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .codegen import (GATHER_UNROLL_MAX_K, EllMatrix, _coef,
                      build_offdiag_ell)
from .csr import CSRMatrix
from .packed import gather_src

__all__ = [
    "SweepConfig",
    "SweepStats",
    "SweepLayout",
    "SWEEP_FALLBACK_STRATEGIES",
    "build_sweep_layout",
    "pack_sweep_values",
    "contraction_factor",
    "planned_sweeps",
    "default_residual_tol",
    "residual_terms",
    "make_sweep_executor",
    "make_sweep_solver",
]

logger = logging.getLogger(__name__)

# Exact strategies a non-converged speculative solve may fall back to.
SWEEP_FALLBACK_STRATEGIES = (
    "serial", "levelset", "levelset_unroll", "pallas_level", "pallas_fused")

# Default componentwise residual tolerance, in units of the solve dtype's
# machine epsilon.  A converged fixed point of the sweep iteration sits at a
# ratio of ~(K+2)*eps (one rounding per ELL term); 128*eps accepts that floor
# with margin while still rejecting anything meaningfully short of
# substitution-grade backward stability.
DEFAULT_TOL_EPS_FACTOR = 128.0

# Headroom folded into the contraction-based sweep-count certificate: the
# verified residual ratio behaves like C·q^k with C the (componentwise)
# magnitude of the initial error x* − D⁻¹b relative to the solution — a
# constant in the tens on observed inputs, not 1.  Planning to C = 256
# keeps the certified k from landing exactly on the tolerance boundary and
# paying the fallback it promised to avoid.
PLAN_MARGIN = 256.0


def default_residual_tol(dtype) -> float:
    """Componentwise residual acceptance threshold for ``dtype`` solves."""
    return DEFAULT_TOL_EPS_FACTOR * float(np.finfo(np.dtype(dtype)).eps)


@dataclasses.dataclass(frozen=True)
class SweepConfig:
    """Knobs of the speculative solve-then-correct executor.

    ``k``             number of Jacobi-style triangular sweeps (unrolled at
                      trace time; also the cap the ``auto`` planner prices
                      sweeps under).  The default 32 reaches f64
                      componentwise tolerance for contraction factors up to
                      ``q ≈ 0.36`` (``q³² ≤ 128·eps``); strongly dominant
                      factors converge much earlier and merely waste the
                      tail sweeps, weakly dominant ones need an explicit
                      larger ``k`` or they pay the exact fallback
    ``residual_tol``  componentwise residual-ratio acceptance threshold;
                      ``None`` → :func:`default_residual_tol` of the solve
                      dtype
    ``fallback``      exact strategy used to re-solve non-converged columns
                      (one of :data:`SWEEP_FALLBACK_STRATEGIES`).  ``None``
                      disables verification + correction outright — the
                      inexact-preconditioner mode, where the k-sweep apply is
                      used as a fixed linear contraction.
    """

    k: int = 32
    residual_tol: Optional[float] = None
    fallback: Optional[str] = "levelset"

    def __post_init__(self):
        assert self.k >= 1, self.k
        assert self.fallback is None or \
            self.fallback in SWEEP_FALLBACK_STRATEGIES, self.fallback


@dataclasses.dataclass
class SweepStats:
    """Per-solver speculation accounting (mutated by the solve wrapper).

    ``fallback_solves`` counts solves where at least one column failed
    verification; ``fallback_columns`` the total corrected columns (a
    single-RHS solve counts as one column).  ``last_residual_ratio`` is the
    worst componentwise ratio of the most recent verified solve — the
    observable the benchmark asserts on."""

    k: int
    solves: int = 0
    fallback_solves: int = 0
    fallback_columns: int = 0
    last_residual_ratio: float = 0.0

    def report(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class SweepLayout:
    """``L = D + N`` split in ELL form, with refresh source maps.

    ``ell`` is the strictly-triangular part transposed ``(K, n)``; ``diag``
    the diagonal; ``*_src`` index the source matrix's ``data`` array so
    :func:`pack_sweep_values` re-packs new same-pattern values with one
    masked gather."""

    n: int
    nnz: int
    ell: EllMatrix
    diag: np.ndarray
    diag_src: np.ndarray

    @property
    def K(self) -> int:
        return self.ell.K


def build_sweep_layout(L: CSRMatrix, *, upper: bool = False) -> SweepLayout:
    """Lower a triangular system into the sweep executor's ``D + N`` split.
    No level analysis is consumed — the layout is row-order, segment-free."""
    ell, diag, diag_src = build_offdiag_ell(L, upper=upper)
    return SweepLayout(n=L.n, nnz=L.nnz, ell=ell, diag=diag,
                      diag_src=diag_src)


def pack_sweep_values(layout: SweepLayout, data: np.ndarray):
    """Runtime value buffers ``(vals (K, n), diag (n,))`` for new ``data`` of
    the same pattern — the sweep refresh hot path (two masked gathers)."""
    vals = gather_src(data, layout.ell.val_src, 0.0, layout.ell.vals.dtype)
    diag = np.asarray(data)[layout.diag_src].astype(
        layout.diag.dtype, copy=False)
    return jnp.asarray(vals), jnp.asarray(diag)


def contraction_factor(L: CSRMatrix, *, upper: bool = False) -> float:
    """``q = ‖D⁻¹N‖_∞ = max_i Σ_{j≠i} |a_ij| / |a_ii|`` — the per-sweep
    error contraction factor of the Jacobi triangular iteration.  ``q < 1``
    (diagonal dominance) guarantees geometric convergence regardless of
    depth; ``q >= 1`` still converges after ``depth`` sweeps (nilpotency)
    but the planner cannot certify an early stop."""
    if L.n == 0:
        return 0.0
    d = np.abs(L.diagonal(first=upper))
    rows = np.repeat(np.arange(L.n), L.row_nnz())
    offsum = np.bincount(rows, weights=np.abs(L.data), minlength=L.n) - d
    return float((offsum / d).max())


def planned_sweeps(contraction: float, depth: int, tol: float,
                   cap: int) -> Optional[int]:
    """Sweep count the model certifies reaches componentwise ``tol``:
    structural exactness after ``depth`` sweeps (nilpotency), improved to
    ``⌈log(tol / C) / log q⌉`` when the iteration contracts (``q < 1``,
    with ``C`` = :data:`PLAN_MARGIN` headroom for the initial-error
    constant).  Returns ``None`` when neither bound lands within ``cap`` —
    the planner then keeps sweeps off the table rather than pricing a solve
    that would routinely pay the exact fallback on top."""
    k = int(depth)
    if 0.0 < contraction < 1.0:
        k_conv = int(math.ceil(math.log(tol / PLAN_MARGIN)
                               / math.log(contraction)))
        k = min(k, max(k_conv, 1))
    return k if 1 <= k <= cap else None


def residual_terms(b: jnp.ndarray, x: jnp.ndarray, vals: jnp.ndarray,
                   diag: jnp.ndarray, cols: jnp.ndarray):
    """Componentwise backward-error terms of a candidate solution ``x`` of
    ``(D + N) x = b`` against the ``D + N`` ELL split (``vals``/``cols`` the
    strictly-triangular part transposed ``(K, n)``, ``diag`` the diagonal).

    Returns ``(r, ratio)``: the signed residual ``r = b − N x − D x`` (same
    shape as ``b``) and the per-column worst componentwise ratio
    ``max_i |r|_i / (|N||x| + |D||x| + |b|)_i`` (scalar for a single RHS).
    Columns containing non-finite ``x`` entries report ``ratio = inf`` —
    a NaN solution would otherwise zero the ``denom > 0`` mask and pass
    verification silently.  Shared by the sweep verifier and the guard's
    residual checker (:mod:`repro.core.guard`): one fused gather/FMA pass,
    no per-level structure.  The residual and the denominator share the
    ``x[cols]`` gather and the coefficient product (``|v·x| = |v|·|x|``
    exactly in IEEE arithmetic, NaN/inf included), so the verification pass
    reads the value stream once, not twice.  Batched ``x`` unrolls the K
    axis into K row-gathers for the same reason :func:`~.codegen._gather_sum`
    does — XLA's CPU 3-D gather of ``(K, n, m)`` row slices is far slower
    than K two-dimensional gathers."""
    dt = b.dtype
    vf = vals.astype(dt)
    df = diag.astype(dt)
    dx = _coef(df, b) * x
    if x.ndim > 1 and vf.shape[0] <= GATHER_UNROLL_MAX_K:
        s = jnp.zeros_like(x)
        a = jnp.zeros_like(x)
        for k in range(vf.shape[0]):
            pk = vf[k][:, None] * x[cols[k]]
            s = s + pk
            a = a + jnp.abs(pk)
    else:
        px = _coef(vf, x) * x[cols]
        s = jnp.sum(px, axis=0)
        a = jnp.sum(jnp.abs(px), axis=0)
    r = b - s - dx
    denom = a + jnp.abs(dx) + jnp.abs(b)
    ratio = jnp.max(
        jnp.where(denom > 0, jnp.abs(r) / jnp.where(denom > 0, denom, 1),
                  0.0),
        axis=0)
    bad = ~jnp.all(jnp.isfinite(x), axis=0)
    return r, jnp.where(bad, jnp.inf, ratio)


def make_sweep_executor(
    layout: SweepLayout,
    k: int,
    *,
    verify: bool = True,
    runtime_values: bool = True,
) -> Callable:
    """Trace-time-unrolled k-sweep executor.

    Returns ``run(b, values)`` (``values=None`` when ``runtime_values`` is
    off — the scatter layout embeds them as constants).  With ``verify`` the
    result is ``(x, ratio)`` where ``ratio`` is the per-column worst
    componentwise residual ratio (scalar for a single RHS); without it, just
    ``x``.  The whole body — k sweeps plus the verification pass — is
    straight-line fused vector code: no ``fori_loop``/``scan``/``while``, no
    per-level structure, zero intra-solve barriers."""
    cols = jnp.asarray(layout.ell.cols)
    const_vals = jnp.asarray(layout.ell.vals)
    const_diag = jnp.asarray(layout.diag)

    def run(b: jnp.ndarray, values=None):
        if values is None:
            vals, diag = const_vals, const_diag
        else:
            vals, diag = values
        dt = b.dtype
        vf = vals.astype(dt)
        df = diag.astype(dt)

        def gsum(v, xx):
            # Always the fused one-gather + reduce form — for batched RHS
            # too.  The per-K unrolled 2-D gathers the segment executors
            # prefer (codegen._gather_sum) trigger an exponential XLA
            # fusion search once ~8 sweeps of them chain back-to-back
            # (>100s compile at k=8 vs linear ~0.6s at k=33 fused).
            return jnp.sum(_coef(v, xx) * xx[cols], axis=0)

        d = _coef(df, b)
        x = b / d
        for _ in range(k - 1):
            x = (b - gsum(vf, x)) / d
        if not verify:
            return x
        _, ratio = residual_terms(b, x, vals, diag, cols)
        return x, ratio

    return run


def make_sweep_solver(
    layout: SweepLayout,
    config: SweepConfig,
    *,
    fallback: Optional[Callable[[], Callable]] = None,
    jit: bool = True,
    runtime_values: bool = True,
):
    """Build the speculative solve-then-correct wrapper.

    ``fallback`` is a zero-arg provider of an exact ``solve(b) -> x``
    callable (built lazily — the common case never pays for it); required
    unless ``config.fallback is None``.  Returns ``(solve, stats, exec_fn)``
    where ``solve(b, values=None)`` matches the packed-executor calling
    convention, ``stats`` is the live :class:`SweepStats`, and ``exec_fn``
    the (jitted) barrier-free executor — exposed so tests can assert on its
    jaxpr.

    The verification readback is the solve's ONE host synchronization point
    — per solve, not per level — and is what buys the speculation its safety
    net."""
    verify = config.fallback is not None
    assert fallback is not None or not verify, \
        "a verified sweep solver needs a fallback provider"
    run = make_sweep_executor(
        layout, config.k, verify=verify, runtime_values=runtime_values)
    run_j = jax.jit(run) if jit else run
    stats = SweepStats(k=config.k)

    def solve(b: jnp.ndarray, values=None) -> jnp.ndarray:
        out = run_j(b, values) if runtime_values else run_j(b)
        stats.solves += 1
        if not verify:
            return out
        x, ratio = out
        tol = (config.residual_tol if config.residual_tol is not None
               else default_residual_tol(b.dtype))
        ratio_h = np.asarray(ratio)
        stats.last_residual_ratio = float(ratio_h.max())
        ok = ratio_h <= tol
        if bool(np.all(ok)):
            return x
        nbad = int(ratio_h.size - np.count_nonzero(ok))
        stats.fallback_solves += 1
        stats.fallback_columns += nbad
        logger.info(
            "sweep: %d/%d column(s) above residual tol %.1e after k=%d "
            "sweeps (worst %.1e) — correcting via %r",
            nbad, ratio_h.size, tol, config.k, stats.last_residual_ratio,
            config.fallback)
        xf = fallback()(b)
        if x.ndim == 1:
            return xf
        # keep the verified speculative columns, splice exact ones in
        return jnp.where(jnp.asarray(ok)[None, :], x, xf)

    return solve, stats, run_j
