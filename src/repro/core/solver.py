"""Public SpTRSV API — ties analysis, rewriting, and codegen together.

    solver = SpTRSV.build(L, strategy="levelset", rewrite=RewriteConfig())
    x = solver.solve(b)          # jit-compiled, matrix-specialized
    X = solver.solve(B)          # B: (n, m) — m systems in one pass

    bwd = SpTRSV.build(L, transpose=True)    # solves Lᵀ x = b
    fwd, bwd = SpTRSV.build_pair(L)          # both sweeps, one analysis

Every strategy solves one RHS ``b: (n,)`` or a multi-RHS batch
``B: (n, m)`` (m independent systems sharing L).  Batching amortizes the
per-level launch/synchronization cost over columns and widens the TPU lane
dimension from R to R*m, which is where thin levels (the paper's lung2
pathology) leave throughput on the table.

``transpose=True`` makes the solver execute the *backward* sweep
``Lᵀ x = b`` (the second half of every IC(0)/LU preconditioner apply).
The transpose DAG is the forward DAG with its edges reversed, so the
backward level sets are derived from the same symbolic analysis — no
reverse-permuted copy of the matrix, no second ``from_coo``; the backward
schedule packs columns of ``L`` (rows of ``L.transpose()``) into the same
ELL slabs every executor/kernel already consumes.

Strategy × capability matrix
----------------------------
=================  ==========  =========  =========  =========  =========  ============
strategy           single RHS  batched    rewrite    transpose  coarsen    distributed
=================  ==========  =========  =========  =========  =========  ============
serial             yes         yes        yes        yes        n/a        no
levelset           yes         yes        yes        yes        yes        no
levelset_unroll    yes         yes        yes        yes        yes        no
pallas_level       yes         yes        yes        yes        yes        no
pallas_fused       yes         yes        yes        yes        n/a (1 seg) no
distributed        yes         yes        yes        yes        yes        yes (mesh axis)
auto               planner: picks serial / levelset / levelset_unroll /
                   pallas_fused from the analysis + schedule cost model
=================  ==========  =========  =========  =========  =========  ============

Strategies
----------
``serial``         row-serial scan (paper Algorithm 1 — correctness baseline)
``levelset``       generated per-level vectorized segments (paper codegen)
``levelset_unroll``same, with tiny levels unrolled as constant-embedded code
``pallas_level``   per-level Pallas TPU kernel (kernels/sptrsv_level)
``pallas_fused``   whole solve in one Pallas kernel, x in VMEM (beyond-paper)
``distributed``    shard_map level solve over a mesh axis (one collective
                   per *segment* — rewriting and coarsening both reduce
                   collective count; a batch multiplies collective payload,
                   not count)
``auto``           cost-model planner (:func:`repro.core.coarsen.plan_strategy`):
                   serial for chain-like DAGs, (coarsened) level-set
                   executors for wavefront-parallel matrices, the fused
                   Pallas kernel for VMEM-sized systems on a real TPU.  The
                   decision is recorded on ``solver.plan``.

Schedule coarsening (``coarsen=...``)
-------------------------------------
``coarsen=True`` (or a :class:`~repro.core.coarsen.CoarsenConfig`) merges
adjacent levels into super-level slabs under a launch-vs-padding cost model:
a lung2-class schedule drops from ~478 segments (sync points) to a few
dozen, with each merged slab executing its intra-slab dependency chain
back-to-back inside one segment.  Every row is computed from exactly the
same operands as uncoarsened (only zero padding is added), so results are
typically bit-identical and always within a few ulp — XLA may re-contract
the padded reduction (FMA/tree shape) when it recompiles the merged
segment.  ``strategy="auto"`` enables coarsening whenever the cost model
says it pays.

Batched quickstart (PCG with many right-hand sides)::

    from repro.core.pcg import make_ic_preconditioner_batched, pcg_batched
    M_inv = make_ic_preconditioner_batched(Lfactor, strategy="levelset")
    res = pcg_batched(A, B, M_inv)     # B: (n, m); res.x: (n, m)

Shared-analysis preconditioner quickstart (forward + backward sweep from one
analysis)::

    fwd, bwd = SpTRSV.build_pair(L, strategy="levelset",
                                 rewrite=RewriteConfig(thin_threshold=2))
    z = bwd.solve(fwd.solve(r))        # z = (L Lᵀ)^{-1} r
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .analysis import MatrixAnalysis, analyze
from .coarsen import CoarsenConfig, PlanDecision, coarsen_schedule, plan_strategy
from .codegen import (
    Schedule,
    build_schedule,
    make_levelset_solver,
    make_rhs_transform,
    make_serial_solver,
)
from .csr import CSRMatrix
from .levels import LevelSets, build_level_sets, build_reverse_level_sets
from .rewrite import RewriteConfig, RewriteResult, rewrite_matrix

__all__ = ["SpTRSV", "STRATEGIES"]

STRATEGIES = (
    "serial",
    "levelset",
    "levelset_unroll",
    "pallas_level",
    "pallas_fused",
    "distributed",
    "auto",
)


def _as_coarsen_config(coarsen) -> Optional[CoarsenConfig]:
    """Normalize the ``coarsen`` build knob: None/False → off, True → default
    config, a CoarsenConfig → itself."""
    if coarsen is None or coarsen is False:
        return None
    if coarsen is True:
        return CoarsenConfig()
    assert isinstance(coarsen, CoarsenConfig), coarsen
    return coarsen


@dataclasses.dataclass
class SpTRSV:
    """A matrix-specialized, jit-compiled triangular solver.

    ``transpose=True`` solvers execute the backward sweep ``Lᵀ x = b``; the
    executor machinery is identical — only the schedule (backward level sets,
    column-packed slabs) differs."""

    n: int
    strategy: str
    analysis: MatrixAnalysis
    schedule: Optional[Schedule]
    rewrite_result: Optional[RewriteResult]
    _solve_fn: Callable[[jnp.ndarray], jnp.ndarray]
    _rhs_fn: Optional[Callable[[jnp.ndarray], jnp.ndarray]]
    transpose: bool = False
    plan: Optional[PlanDecision] = None   # set when strategy="auto" planned

    @staticmethod
    def build(
        L: CSRMatrix,
        *,
        strategy: str = "levelset",
        transpose: bool = False,
        rewrite: Optional[RewriteConfig] = None,
        unroll_threshold: int = 4,
        bucket_pad_ratio: float = 0.0,   # >1: split levels into nnz buckets
        coarsen=None,                    # True / CoarsenConfig: merge levels
        mesh=None,
        mesh_axis: str = "data",
        dist_strategy: str = "all_gather",
        interpret: bool = True,
        jit: bool = True,
    ) -> "SpTRSV":
        """Build a solver for ``L x = b`` (or ``Lᵀ x = b`` with
        ``transpose=True``).  ``L`` is always the lower-triangular factor.

        ``coarsen`` merges adjacent levels into super-level slabs under the
        :mod:`repro.core.coarsen` cost model (fewer segments / sync points;
        consumed by the levelset, pallas_level and distributed executors —
        serial has no segments and pallas_fused is already one segment).
        ``strategy="auto"`` lets the planner pick both the strategy and
        whether coarsening pays; the decision lands on ``solver.plan``."""
        assert L.is_lower_triangular(), "SpTRSV requires lower-triangular L with nonzero diagonal"
        if transpose:
            system, levels = L.transpose(), build_reverse_level_sets(L)
        else:
            system, levels = L, build_level_sets(L)
        return SpTRSV._build_system(
            system, levels, upper=transpose,
            strategy=strategy, rewrite=rewrite,
            unroll_threshold=unroll_threshold,
            bucket_pad_ratio=bucket_pad_ratio,
            coarsen=coarsen,
            mesh=mesh, mesh_axis=mesh_axis, dist_strategy=dist_strategy,
            interpret=interpret, jit=jit,
        )

    @staticmethod
    def build_pair(L: CSRMatrix, **kwargs) -> tuple["SpTRSV", "SpTRSV"]:
        """Build ``(forward, backward)`` solvers — ``L y = b`` and
        ``Lᵀ z = y`` — from **one** shared symbolic analysis.

        The backward level sets are derived from the forward DAG arrays
        (:func:`repro.core.levels.compute_reverse_levels`) and the backward
        schedule is packed from an O(nnz) CSC view of ``L`` — the whole
        reverse-permute + second-analysis pipeline of the legacy
        preconditioner path is gone.  Accepts the same keyword arguments as
        :meth:`build` (except ``transpose``)."""
        assert "transpose" not in kwargs, "build_pair builds both directions"
        assert L.is_lower_triangular(), "SpTRSV requires lower-triangular L with nonzero diagonal"
        levels = build_level_sets(L)
        fwd = SpTRSV._build_system(L, levels, upper=False, **kwargs)
        # backward levels derived from the forward wavefronts — the shared
        # analysis; no second per-row DAG traversal
        bwd = SpTRSV._build_system(
            L.transpose(), build_reverse_level_sets(L, forward=levels),
            upper=True, **kwargs)
        return fwd, bwd

    @staticmethod
    def _build_system(
        system: CSRMatrix,
        levels: LevelSets,
        *,
        upper: bool,
        strategy: str = "levelset",
        rewrite: Optional[RewriteConfig] = None,
        unroll_threshold: int = 4,
        bucket_pad_ratio: float = 0.0,
        coarsen=None,
        mesh=None,
        mesh_axis: str = "data",
        dist_strategy: str = "all_gather",
        interpret: bool = True,
        jit: bool = True,
    ) -> "SpTRSV":
        """Shared builder: ``system`` is the triangular matrix of the system
        actually solved (``L`` forward, ``L.transpose()`` backward) with its
        level sets already analyzed."""
        assert strategy in STRATEGIES, strategy
        analysis = analyze(system, levels)
        ccfg = _as_coarsen_config(coarsen)

        rres: Optional[RewriteResult] = None
        rhs_fn = None
        target, target_levels = system, levels
        if rewrite is not None:
            rres = rewrite_matrix(system, levels, rewrite, upper=upper)
            rhs_fn = make_rhs_transform(rres)
            target, target_levels = rres.L, rres.levels

        _memo: dict = {}

        def _schedule() -> Schedule:
            # every schedule-consuming strategy gets the bucketed slab split
            # (bucket_pad_ratio was silently dropped for pallas_*/distributed
            # before — schedules are executor-agnostic)
            if "base" not in _memo:
                _memo["base"] = build_schedule(
                    target, target_levels, upper=upper,
                    bucket_pad_ratio=bucket_pad_ratio)
            return _memo["base"]

        def _coarsened(cfg: CoarsenConfig) -> Schedule:
            if "coarse" not in _memo:
                _memo["coarse"] = coarsen_schedule(
                    _schedule(), cfg, unroll_threshold=unroll_threshold)
            return _memo["coarse"]

        plan: Optional[PlanDecision] = None
        if strategy == "auto":
            # let the planner weigh coarsening unless explicitly disabled
            plan_ccfg = ccfg if ccfg is not None else (
                None if coarsen is False else CoarsenConfig())
            plan = plan_strategy(
                analysis, _schedule(),
                _coarsened(plan_ccfg) if plan_ccfg is not None else None,
                unroll_threshold=unroll_threshold, interpret=interpret)
            strategy = plan.strategy
            if ccfg is not None and strategy in ("levelset", "levelset_unroll"):
                # an explicit coarsen config is a user directive — coarsening
                # stays on even if the planner costed it out; record what
                # actually executes so solver.plan stays auditable
                plan = dataclasses.replace(plan, coarsen=True)
            elif plan.coarsen:
                ccfg = plan_ccfg

        def _maybe_coarsen(schedule: Schedule) -> Schedule:
            return _coarsened(ccfg) if ccfg is not None else schedule

        schedule: Optional[Schedule] = None
        if strategy == "serial":
            fn = make_serial_solver(target, upper=upper)
        elif strategy in ("levelset", "levelset_unroll"):
            schedule = _maybe_coarsen(_schedule())
            fn = make_levelset_solver(
                schedule,
                unroll_threshold=unroll_threshold if strategy == "levelset_unroll" else 0,
            )
        elif strategy == "pallas_level":
            from repro.kernels.sptrsv_level import ops as level_ops

            schedule = _maybe_coarsen(_schedule())
            fn = level_ops.make_solver(schedule, interpret=interpret)
        elif strategy == "pallas_fused":
            from repro.kernels.sptrsv_fused import ops as fused_ops

            # fused is already a single segment; coarsening would only
            # re-partition its chunk walk, so the layout consumes sub-slabs
            schedule = _schedule()
            fn = fused_ops.make_solver(schedule, interpret=interpret)
        elif strategy == "distributed":
            from .dist import make_distributed_solver, shard_schedule

            assert mesh is not None, "distributed strategy needs a mesh"
            schedule = _maybe_coarsen(_schedule())
            ndev = int(np.prod([mesh.shape[a] for a in (mesh_axis,)]))
            dsched = shard_schedule(schedule, ndev)
            fn = make_distributed_solver(dsched, mesh, mesh_axis, strategy=dist_strategy)
        else:  # pragma: no cover
            raise ValueError(strategy)

        if rhs_fn is not None:
            # Compose b' = E b with the solve as two separate XLA programs.
            # A single jit over both lets XLA fuse the batched SpMV into the
            # per-level consumers and recompute it, a >10x slowdown at m=64
            # on CPU; the extra dispatch costs microseconds.
            base_c = jax.jit(fn) if jit else fn
            rhs_c = jax.jit(rhs_fn) if jit else rhs_fn
            solve_fn = lambda b, _r=rhs_c, _s=base_c: _s(_r(b))  # noqa: E731
        else:
            solve_fn = jax.jit(fn) if jit else fn
        return SpTRSV(
            n=system.n,
            strategy=strategy,
            analysis=analysis,
            schedule=schedule,
            rewrite_result=rres,
            _solve_fn=solve_fn,
            _rhs_fn=rhs_fn,
            transpose=upper,
            plan=plan,
        )

    def solve(self, b: jnp.ndarray) -> jnp.ndarray:
        """Solve L x = b (or Lᵀ x = b for a ``transpose`` solver).  ``b``
        may be ``(n,)`` (one system) or ``(n, m)`` (m independent systems
        solved in one batched pass).  Each distinct batch width compiles
        once (shapes are trace-time constants — the executor is matrix-
        *and* batch-specialized)."""
        if b.ndim not in (1, 2) or b.shape[0] != self.n:
            raise ValueError(
                f"b must be ({self.n},) or ({self.n}, m); got {b.shape}")
        return self._solve_fn(b)

    def solve_batched(self, B: jnp.ndarray) -> jnp.ndarray:
        """Explicitly-batched alias: ``B: (n, m)`` → ``X: (n, m)``.

        ``solve`` already dispatches on ndim; this entry point exists so
        call sites that *require* the multi-RHS path fail loudly when handed
        a single vector."""
        if B.ndim != 2:
            raise ValueError(f"solve_batched expects (n, m); got {B.shape}")
        return self.solve(B)

    @property
    def stats(self):
        return self.rewrite_result.stats if self.rewrite_result else None
