"""Public SpTRSV API — ties analysis, rewriting, and codegen together.

    solver = SpTRSV.build(L, strategy="levelset", rewrite=RewriteConfig())
    x = solver.solve(b)          # jit-compiled, matrix-specialized
    X = solver.solve(B)          # B: (n, m) — m systems in one pass

    bwd = SpTRSV.build(L, transpose=True)    # solves Lᵀ x = b
    fwd, bwd = SpTRSV.build_pair(L)          # both sweeps, one analysis

Every strategy solves one RHS ``b: (n,)`` or a multi-RHS batch
``B: (n, m)`` (m independent systems sharing L).  Batching amortizes the
per-level launch/synchronization cost over columns and widens the TPU lane
dimension from R to R*m, which is where thin levels (the paper's lung2
pathology) leave throughput on the table.

``transpose=True`` makes the solver execute the *backward* sweep
``Lᵀ x = b`` (the second half of every IC(0)/LU preconditioner apply).
The transpose DAG is the forward DAG with its edges reversed, so the
backward level sets are derived from the same symbolic analysis — no
reverse-permuted copy of the matrix, no second ``from_coo``; the backward
schedule packs columns of ``L`` (rows of ``L.transpose()``) into the same
ELL slabs every executor/kernel already consumes.

Strategy × capability matrix
----------------------------
=================  ==========  =========  =========  =========  =========  =========  ============
strategy           single RHS  batched    rewrite    transpose  coarsen    refresh    distributed
=================  ==========  =========  =========  =========  =========  =========  ============
serial             yes         yes        yes        yes        n/a        yes        no
levelset           yes         yes        yes        yes        yes        yes        no
levelset_unroll    yes         yes        yes        yes        yes        yes        no
pallas_level       yes         yes        yes        yes        yes        yes        no
pallas_fused       yes         yes        yes        yes        n/a (1 seg) yes       no
distributed        yes         yes        yes        yes        yes        yes        yes (mesh axis)
sweep              yes         yes        yes        yes        n/a (0 seg) yes       no
auto               transform planner: picks serial / levelset /
                   levelset_unroll / pallas_fused / sweep AND the matrix
                   transform (rewrite policy x coarsening) from one cost
                   model
=================  ==========  =========  =========  =========  =========  =========  ============

Transform planner (``strategy="auto"``)
---------------------------------------
``plan_strategy`` (:mod:`repro.core.coarsen`) prices *rewrite vs coarsen vs
both* with one launch-cost/padded-FLOP model: rewriting shortens the
dependency chain but adds fill and a per-solve RHS SpMV; coarsening removes
syncs but pads.  Candidate rewrites (``policy="thin"`` and
``policy="critical_path"``) are actually built — the vectorized rewrite
engine makes that a milliseconds-scale probe — and their schedules priced
like every other alternative.  The decision is recorded on ``solver.plan``
(:class:`repro.core.coarsen.PlanDecision`):

``plan.strategy``   executor chosen (``serial``/``levelset``/
                    ``levelset_unroll``/``pallas_fused``/``sweep``)
``plan.coarsen``    whether schedule coarsening is applied
``plan.rewrite``    winning rewrite-policy tag (``"thin"`` /
                    ``"critical_path"``) or ``None`` for no rewrite
``plan.sweep_k``    certified sweep count when the sync-free speculative
                    executor won (``plan.strategy == "sweep"``), else None.
                    Sweeps are priced against level-set execution from the
                    depth/contraction profile: ``k`` fused whole-matrix
                    updates + 1 verification pass vs. per-segment launch
                    cost — the sweeps-vs-levels decision.
``plan.costs``      modelled per-solve cost of every candidate, keyed
                    ``<strategy>[+rewrite:<tag>][+coarsen]`` (plus
                    ``sweep``)
``plan.reason``     human-readable audit line (also in ``stats()["plan"]``)

An explicit ``rewrite=RewriteConfig(...)`` is a user directive: the rewrite
is applied unconditionally and the planner only weighs strategy/coarsening
on the transformed system.  ``SolveEngine.from_matrix`` serves the planner
decision by default, and the chosen transform composes with permuted/packed
layout, transpose pairs, batching, and value-only refresh.

Permuted layout + value-only refresh (``layout=``, ``refresh``)
---------------------------------------------------------------
``layout="permuted"`` (default) executes in schedule-order permuted space:
each segment's rows are a contiguous slice of ``x̂`` (static-offset
``dynamic_update_slice`` writes, static RHS slices), ``b`` is permuted and
``x`` un-permuted exactly once at the boundary, and all slab values stream
from ONE packed flat buffer passed as a runtime jit argument.  Because the
values are arguments — not trace-time constants — ``solver.refresh(new_data)``
swaps in new values of the same sparsity pattern with one O(nnz) re-pack
and a jit cache hit: no level analysis, no re-trace, no re-compile.  That
is the dominant production pattern (numeric re-factorization between PCG /
Newton steps).  ``layout="scatter"`` keeps the legacy per-segment scatter
executors; refresh on it falls back to a cold rebuild.  ``solver.stats()``
reports the packed-buffer bytes, padding waste and permutation status.

Kernel backend (``backend=``)
-----------------------------
Pallas-backed strategies (``pallas_level`` / ``pallas_fused`` and the auto
planner's pricing) dispatch through :mod:`repro.kernels.backend`:
``backend=None`` (default) resolves from ``jax.default_backend()`` — ``tpu``
→ compiled Mosaic lowerings, ``gpu`` → compiled pallas-triton lowerings,
``cpu`` → the interpret backend (pallas has no CPU codegen).  Explicit specs
``"tpu"`` / ``"gpu"`` / ``"interpret"`` / ``"interpret:gpu"`` pin the
lowering family; the interpret variants run it under the pallas interpreter
(how CI exercises both families without hardware).  The planner prices
candidates from the backend's calibration row
(:mod:`repro.core.calibrate` — launch cost, gather throughput, lane width,
fused-dispatch shape).  The legacy ``interpret: bool`` knob remains as a
deprecated alias: ``interpret=True`` maps to the resolved platform's
interpret backend, ``interpret=False`` forces the compiled path.

Strategies
----------
``serial``         row-serial scan (paper Algorithm 1 — correctness baseline)
``levelset``       generated per-level vectorized segments (paper codegen)
``levelset_unroll``same, with tiny levels unrolled as constant-embedded code
``pallas_level``   per-level Pallas TPU kernel (kernels/sptrsv_level)
``pallas_fused``   whole solve in one Pallas kernel, x in VMEM (beyond-paper)
``distributed``    shard_map level solve over a mesh axis (one collective
                   per *segment* — rewriting and coarsening both reduce
                   collective count; a batch multiplies collective payload,
                   not count)
``sweep``          sync-free speculative solve-then-correct
                   (:mod:`repro.core.sweep`): k Jacobi-style triangular
                   sweeps ``x ← D⁻¹(b − N x)`` as ONE fused dispatch with
                   zero intra-solve barriers, componentwise residual
                   verification, exact-strategy fallback for non-converged
                   columns (``sweep=SweepConfig(k, residual_tol,
                   fallback)``).  The only executor whose per-solve cost is
                   independent of the level count.
``blocked``        supernodal/blocked solve: contiguous row runs with
                   (near-)identical column structure are amalgamated into
                   dense diagonal blocks (:func:`repro.core.levels.
                   detect_supernodes`, relaxation knob
                   ``supernodes=SupernodeConfig(relax=...)``); each
                   super-level applies the off-diagonal panel as one
                   gather/FMA pass and the inverted diagonal blocks as a
                   batched small-TRSM (``kernels/trsm_block``,
                   ``block_kernel="auto"|"pallas"|"jnp"``).  A scalar row
                   is just a 1×1 block, so the executor degrades
                   gracefully on unstructured factors.
``auto``           transform planner (:func:`repro.core.coarsen.plan_strategy`):
                   serial for chain-like DAGs, (coarsened) level-set
                   executors for wavefront-parallel matrices, the fused
                   Pallas kernel for VMEM-sized systems on a real TPU,
                   sync-free sweeps when the convergence model certifies a
                   cheap-enough sweep count, the blocked executor when
                   supernode amalgamation finds dense-enough diagonal
                   blocks (mean block size ≥ 1.5) and the calibrated
                   gemm/trsm rates price it below the level-set
                   candidates — and, for barrier-dominated
                   schedules, whether to rewrite the matrix first (``thin``
                   vs ``critical_path`` policy) under the same cost model.
                   The decision is recorded on ``solver.plan`` (see
                   "Transform planner" above).

Schedule coarsening (``coarsen=...``)
-------------------------------------
``coarsen=True`` (or a :class:`~repro.core.coarsen.CoarsenConfig`) merges
adjacent levels into super-level slabs under a launch-vs-padding cost model:
a lung2-class schedule drops from ~478 segments (sync points) to a few
dozen, with each merged slab executing its intra-slab dependency chain
back-to-back inside one segment.  Every row is computed from exactly the
same operands as uncoarsened (only zero padding is added), so results are
typically bit-identical and always within a few ulp — XLA may re-contract
the padded reduction (FMA/tree shape) when it recompiles the merged
segment.  ``strategy="auto"`` enables coarsening whenever the cost model
says it pays.

Batched quickstart (PCG with many right-hand sides)::

    from repro.core.pcg import make_ic_preconditioner_batched, pcg_batched
    M_inv = make_ic_preconditioner_batched(Lfactor, strategy="levelset")
    res = pcg_batched(A, B, M_inv)     # B: (n, m); res.x: (n, m)

Shared-analysis preconditioner quickstart (forward + backward sweep from one
analysis)::

    fwd, bwd = SpTRSV.build_pair(L, strategy="levelset",
                                 rewrite=RewriteConfig(thin_threshold=2))
    z = bwd.solve(fwd.solve(r))        # z = (L Lᵀ)^{-1} r
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .analysis import MatrixAnalysis, analyze
from .coarsen import (
    SEGMENT_COST,
    BlockSchedule,
    CoarsenConfig,
    PlanDecision,
    RewriteCandidate,
    SweepCandidate,
    blocked_candidate,
    build_block_schedule,
    coarsen_schedule,
    plan_strategy,
    should_consider_rewrite,
)
from .codegen import (
    GATHER_UNROLL_MAX_K,
    Schedule,
    build_schedule,
    make_blocked_solver,
    make_levelset_solver,
    make_rhs_transform,
    make_serial_solver,
)
from .csr import CSRMatrix
from .levels import (
    LevelSets,
    SupernodeConfig,
    Supernodes,
    build_level_sets,
    build_reverse_level_sets,
    detect_supernodes,
)
from repro.kernels.backend import (
    KernelBackend,
    resolve_backend,
    warn_interpret_deprecated,
)
from .guard import GuardConfig, SolveGuard, scan_values
from .packed import (
    PackedStats,
    build_packed_blocked_layout,
    build_packed_layout,
    cast_value_buffers,
    ell_packed_stats,
    make_packed_blocked_solver,
    make_packed_levelset_solver,
    make_packed_rhs_transform,
    make_packed_serial_solver,
    pack_blocked_values,
    pack_values,
)
from .rewrite import (
    RewriteConfig,
    RewriteReplayError,
    RewriteResult,
    replay_rewrite_values,
    rewrite_matrix,
)
from .sweep import (
    SweepConfig,
    SweepStats,
    build_sweep_layout,
    contraction_factor,
    default_residual_tol,
    make_sweep_solver,
    pack_sweep_values,
    planned_sweeps,
)

__all__ = ["SpTRSV", "STRATEGIES", "LAYOUTS"]

logger = logging.getLogger(__name__)

STRATEGIES = (
    "serial",
    "levelset",
    "levelset_unroll",
    "pallas_level",
    "pallas_fused",
    "distributed",
    "sweep",
    "blocked",
    "auto",
)

# Execution-space layouts.  "permuted" (default) runs the whole solve in
# schedule-order permuted space with one packed streaming value buffer
# (:mod:`repro.core.packed`): contiguous dynamic-update-slice writes instead
# of per-segment row scatters, b permuted / x un-permuted exactly once at the
# API boundary, and value-only ``refresh`` without re-tracing.  "scatter" is
# the PR-3 layout (per-segment row-id scatters, values embedded as trace-time
# constants) — kept as the equivalence/benchmark baseline.
LAYOUTS = ("permuted", "scatter")


def _as_coarsen_config(coarsen) -> Optional[CoarsenConfig]:
    """Normalize the ``coarsen`` build knob: None/False → off, True → default
    config, a CoarsenConfig → itself."""
    if coarsen is None or coarsen is False:
        return None
    if coarsen is True:
        return CoarsenConfig()
    assert isinstance(coarsen, CoarsenConfig), coarsen
    return coarsen


def _as_supernode_config(supernodes) -> Optional[SupernodeConfig]:
    """Normalize the ``supernodes`` build knob: None/True → default
    detection config (``False`` additionally keeps the blocked executor out
    of the auto planner's candidate set), a SupernodeConfig → itself."""
    if supernodes is None or supernodes is True or supernodes is False:
        return SupernodeConfig()
    assert isinstance(supernodes, SupernodeConfig), supernodes
    return supernodes


def _as_guard_config(guard) -> Optional[GuardConfig]:
    """Normalize the ``guard`` build knob: None/False → unguarded, True →
    default :class:`repro.core.guard.GuardConfig`, a GuardConfig → itself."""
    if guard is None or guard is False:
        return None
    if guard is True:
        return GuardConfig()
    assert isinstance(guard, GuardConfig), guard
    return guard


def _as_sweep_config(sweep) -> Optional[SweepConfig]:
    """Normalize the ``sweep`` build knob: None/False → default off
    (``strategy="sweep"`` still gets a default config; ``False`` additionally
    keeps sweeps out of the auto planner's candidate set), True → default
    config, a SweepConfig → itself."""
    if sweep is None or sweep is False:
        return None
    if sweep is True:
        return SweepConfig()
    assert isinstance(sweep, SweepConfig), sweep
    return sweep


@dataclasses.dataclass
class _RefreshCtx:
    """Cached symbolic state for value-only refresh.

    ``source`` is the user's original factor (pattern reference for
    validating new values); ``values_map`` reorders its data into the solved
    system's storage (the CSC permutation for transpose solvers, identity
    otherwise); ``rewrite`` carries the replayable elimination plan and the
    cached L'/E patterns; ``repack``/``e_repack`` turn target-system data
    into the executor's runtime value buffers; ``rebuild`` is the cold
    fallback (scatter layout, or a rewrite plan that does not numerically
    transfer)."""

    source: CSRMatrix
    system: CSRMatrix
    values_map: Optional[np.ndarray]
    rewrite: Optional[RewriteResult]
    repack: Optional[Callable]
    e_repack: Optional[Callable]
    rebuild: Callable


@dataclasses.dataclass
class SpTRSV:
    """A matrix-specialized, jit-compiled triangular solver.

    ``transpose=True`` solvers execute the backward sweep ``Lᵀ x = b``; the
    executor machinery is identical — only the schedule (backward level sets,
    column-packed slabs) differs.

    ``layout="permuted"`` (default) executes in schedule-order permuted
    space with packed streaming value buffers and supports value-only
    :meth:`refresh`; ``layout="scatter"`` is the legacy per-segment
    row-scatter executor with values embedded as constants."""

    n: int
    strategy: str
    analysis: MatrixAnalysis
    schedule: Optional[Schedule]
    rewrite_result: Optional[RewriteResult]
    _solve_fn: Callable
    _rhs_fn: Optional[Callable]
    block_schedule: Optional[BlockSchedule] = None  # strategy="blocked" only
    supernodes: Optional[Supernodes] = None         # partition actually run
    transpose: bool = False
    plan: Optional[PlanDecision] = None   # set when strategy="auto" planned
    layout: str = "scatter"
    backend: str = "interpret"            # resolved kernel backend name
    packed_stats: Optional[PackedStats] = None
    sweep_stats: Optional[SweepStats] = None   # live, strategy="sweep" only
    guard: Optional[SolveGuard] = None    # guarded execution layer (guard=)
    _values: Optional[tuple] = None       # runtime value buffers (permuted)
    _e_values: Optional[jnp.ndarray] = None
    _refresh_ctx: Optional[_RefreshCtx] = None
    _sweep_exec: Optional[Callable] = None  # jitted barrier-free executor

    @staticmethod
    def build(
        L: CSRMatrix,
        *,
        strategy: str = "levelset",
        transpose: bool = False,
        rewrite: Optional[RewriteConfig] = None,
        unroll_threshold: int = 4,
        bucket_pad_ratio: float = 0.0,   # >1: split levels into nnz buckets
        coarsen=None,                    # True / CoarsenConfig: merge levels
        sweep=None,                      # True / SweepConfig: see below
        guard=None,                      # True / GuardConfig: see below
        supernodes=None,                 # SupernodeConfig / False: see below
        block_kernel: str = "auto",      # blocked apply: auto / pallas / jnp
        mesh=None,
        mesh_axis: str = "data",
        dist_strategy: str = "all_gather",
        backend=None,
        interpret: Optional[bool] = None,
        jit: bool = True,
        layout: str = "permuted",
        gather_unroll_max_k: int = GATHER_UNROLL_MAX_K,
    ) -> "SpTRSV":
        """Build a solver for ``L x = b`` (or ``Lᵀ x = b`` with
        ``transpose=True``).  ``L`` is always the lower-triangular factor.

        ``sweep`` configures the sync-free speculative executor
        (:class:`repro.core.sweep.SweepConfig` — sweep count ``k``,
        componentwise ``residual_tol``, exact ``fallback`` strategy).  With
        ``strategy="sweep"`` the config (default if omitted) drives the
        executor directly; with ``strategy="auto"`` it caps the sweep count
        the planner may certify (``sweep=False`` keeps sweeps out of the
        candidate set entirely).

        ``guard`` wraps the built solver in the guarded execution layer
        (``True`` or a :class:`repro.core.guard.GuardConfig`): every solve
        is verified with one fused componentwise residual pass against the
        ORIGINAL system, refined up to ``refine_steps`` times
        (``x += solve(r)``), and columns still above ``residual_tol``
        (default ``128·eps`` of the RHS dtype) are handled by
        ``on_breakdown`` — ``"refine"`` returns the best iterate and records
        the breakdown in ``stats()``, ``"fallback"`` re-solves the failed
        RHS columns with a lazily built exact solver (pivot-repaired when
        the build/refresh value scan tripped) and splices them in like the
        sweep executor's correction, ``"raise"`` raises
        :class:`repro.core.guard.GuardBreakdownError`.
        ``GuardConfig(precision="mixed")`` additionally stores the packed
        off-diagonal value buffer in bf16 (half the value-stream bytes) with
        the diagonal buffer in fp32, accumulates inner solves in fp32, and
        relies on refinement to recover fp64-class accuracy — requires
        ``layout="permuted"``.  Guard accounting (refinement steps taken,
        fallbacks fired, residual achieved, pivot alarms) lands in
        ``stats()`` under the ``guard_*`` keys.

        ``supernodes`` configures supernode amalgamation for the blocked
        (node-granular) executor — a
        :class:`repro.core.levels.SupernodeConfig` tunes the relaxation /
        block-size knobs, ``False`` keeps the blocked executor out of the
        auto planner's candidate set.  With ``strategy="blocked"`` each
        super-level runs as a batched dense diagonal-block apply (small
        TRSM via precomputed inverses) plus a padded ELL panel update;
        ``block_kernel`` picks the apply implementation (``"auto"`` —
        pallas on compiled tpu/gpu, ``dot_general`` elsewhere; ``"pallas"``
        / ``"jnp"`` force it).  A matrix with no amalgamatable rows
        degrades to all-singleton blocks — the scalar-row schedule.

        ``coarsen`` merges adjacent levels into super-level slabs under the
        :mod:`repro.core.coarsen` cost model (fewer segments / sync points;
        consumed by the levelset, pallas_level and distributed executors —
        serial has no segments and pallas_fused is already one segment).
        ``strategy="auto"`` lets the planner pick both the strategy and
        whether coarsening pays; the decision lands on ``solver.plan``.

        ``layout="permuted"`` (default) runs the solve in schedule-order
        permuted space (``b`` permuted in / ``x`` un-permuted out exactly
        once; contiguous slice writes per segment; one packed streaming
        value buffer) and enables :meth:`refresh`.  ``layout="scatter"``
        keeps the legacy per-segment scatter executors.

        ``gather_unroll_max_k`` bounds the batched per-k gather unrolling
        (see :data:`repro.core.codegen.GATHER_UNROLL_MAX_K`); wider slabs
        fall back to the fused 3-D gather and log the fallback."""
        assert L.is_lower_triangular(), "SpTRSV requires lower-triangular L with nonzero diagonal"
        if transpose:
            system, levels = L.transpose(), build_reverse_level_sets(L)
            values_map = np.argsort(L.indices, kind="stable")
        else:
            system, levels = L, build_level_sets(L)
            values_map = None
        return SpTRSV._build_system(
            system, levels, upper=transpose,
            strategy=strategy, rewrite=rewrite,
            unroll_threshold=unroll_threshold,
            bucket_pad_ratio=bucket_pad_ratio,
            coarsen=coarsen, sweep=sweep, guard=guard,
            supernodes=supernodes, block_kernel=block_kernel,
            mesh=mesh, mesh_axis=mesh_axis, dist_strategy=dist_strategy,
            backend=backend, interpret=interpret, jit=jit,
            layout=layout, gather_unroll_max_k=gather_unroll_max_k,
            source=L, values_map=values_map,
        )

    @staticmethod
    def build_cold(L: CSRMatrix, *, transpose_too: bool = False,
                   **build_kwargs) -> tuple["SpTRSV", Optional["SpTRSV"]]:
        """Cheapest-possible build for *cold* serving traffic: the
        row-serial scan executor, no planner probes, no rewrite candidates,
        no supernode detection, no schedule packing — just the O(nnz) level
        analysis and a ``lax.scan``.

        This is the path a :class:`repro.serve.SolverRegistry` uses to
        answer requests for a never-seen sparsity pattern *immediately*
        while the planned (``strategy="auto"``) build runs on a background
        worker; the serial solver is exact, refreshable (permuted layout
        keeps the scan operands as runtime buffers), and orders of
        magnitude cheaper to stand up than a planned build.

        Returns ``(forward, backward)`` — ``backward`` is ``None`` unless
        ``transpose_too=True`` (then both directions come from one shared
        analysis via :meth:`build_pair`).  Extra keyword arguments
        (``guard=``, ``backend=``, ...) pass through to the builder;
        ``strategy`` is pinned to ``"serial"``."""
        build_kwargs.pop("strategy", None)
        if transpose_too:
            return SpTRSV.build_pair(L, strategy="serial", **build_kwargs)
        return SpTRSV.build(L, strategy="serial", **build_kwargs), None

    @staticmethod
    def build_pair(L: CSRMatrix, **kwargs) -> tuple["SpTRSV", "SpTRSV"]:
        """Build ``(forward, backward)`` solvers — ``L y = b`` and
        ``Lᵀ z = y`` — from **one** shared symbolic analysis.

        The backward level sets are derived from the forward DAG arrays
        (:func:`repro.core.levels.compute_reverse_levels`) and the backward
        schedule is packed from an O(nnz) CSC view of ``L`` — the whole
        reverse-permute + second-analysis pipeline of the legacy
        preconditioner path is gone.  Accepts the same keyword arguments as
        :meth:`build` (except ``transpose``).  Both solvers support
        :meth:`refresh` against new values of ``L`` (the backward solver
        reorders them through the shared CSC map)."""
        assert "transpose" not in kwargs, "build_pair builds both directions"
        assert L.is_lower_triangular(), "SpTRSV requires lower-triangular L with nonzero diagonal"
        levels = build_level_sets(L)
        fwd = SpTRSV._build_system(L, levels, upper=False,
                                   source=L, values_map=None, **kwargs)
        # backward levels derived from the forward wavefronts — the shared
        # analysis; no second per-row DAG traversal
        bwd = SpTRSV._build_system(
            L.transpose(), build_reverse_level_sets(L, forward=levels),
            upper=True, source=L,
            values_map=np.argsort(L.indices, kind="stable"), **kwargs)
        return fwd, bwd

    @staticmethod
    def _build_system(
        system: CSRMatrix,
        levels: LevelSets,
        *,
        upper: bool,
        strategy: str = "levelset",
        rewrite: Optional[RewriteConfig] = None,
        unroll_threshold: int = 4,
        bucket_pad_ratio: float = 0.0,
        coarsen=None,
        sweep=None,
        guard=None,
        supernodes=None,
        block_kernel: str = "auto",
        mesh=None,
        mesh_axis: str = "data",
        dist_strategy: str = "all_gather",
        backend=None,
        interpret: Optional[bool] = None,
        jit: bool = True,
        layout: str = "permuted",
        gather_unroll_max_k: int = GATHER_UNROLL_MAX_K,
        source: Optional[CSRMatrix] = None,
        values_map: Optional[np.ndarray] = None,
    ) -> "SpTRSV":
        """Shared builder: ``system`` is the triangular matrix of the system
        actually solved (``L`` forward, ``L.transpose()`` backward) with its
        level sets already analyzed.  ``source``/``values_map`` record where
        the system's values came from (the user's factor and the data
        reordering into system storage) for :meth:`refresh`."""
        assert strategy in STRATEGIES, strategy
        assert layout in LAYOUTS, layout
        if interpret is not None and not isinstance(backend, KernelBackend):
            # internal recursion passes a resolved KernelBackend; only an
            # actual caller-supplied bool earns the deprecation notice
            warn_interpret_deprecated("SpTRSV.build")
        bk = resolve_backend(backend, interpret=interpret)
        strategy_arg = strategy
        build_kwargs = dict(
            upper=upper, strategy=strategy_arg, rewrite=rewrite,
            unroll_threshold=unroll_threshold,
            bucket_pad_ratio=bucket_pad_ratio, coarsen=coarsen, sweep=sweep,
            guard=guard, supernodes=supernodes, block_kernel=block_kernel,
            mesh=mesh, mesh_axis=mesh_axis, dist_strategy=dist_strategy,
            backend=bk, jit=jit, layout=layout,
            gather_unroll_max_k=gather_unroll_max_k,
        )
        if source is None:
            source, values_map = system, None
        analysis = analyze(system, levels, upper=upper)
        ccfg = _as_coarsen_config(coarsen)
        scfg = _as_sweep_config(sweep)
        gcfg = _as_guard_config(guard)
        if gcfg is not None and gcfg.precision == "mixed" \
                and layout != "permuted":
            raise ValueError(
                "guard precision='mixed' requires layout='permuted' — "
                "mixed storage lowers the runtime value buffers, and the "
                "scatter layout embeds values as trace-time constants")
        if strategy == "sweep" and scfg is None:
            scfg = SweepConfig()

        rres: Optional[RewriteResult] = None
        rhs_fn = None
        e_values = None
        e_repack = None
        target, target_levels = system, levels
        if rewrite is not None:
            # an explicit rewrite config is a user directive — applied
            # unconditionally; the auto planner then prices strategies on
            # the transformed system (and only weighs coarsening)
            rres = rewrite_matrix(system, levels, rewrite, upper=upper)
            target, target_levels = rres.L, rres.levels

        _memo: dict = {}

        def _schedule() -> Schedule:
            # every schedule-consuming strategy gets the bucketed slab split
            # (bucket_pad_ratio was silently dropped for pallas_*/distributed
            # before — schedules are executor-agnostic)
            if "base" not in _memo:
                _memo["base"] = build_schedule(
                    target, target_levels, upper=upper,
                    bucket_pad_ratio=bucket_pad_ratio)
            return _memo["base"]

        def _coarsened(cfg: CoarsenConfig) -> Schedule:
            if "coarse" not in _memo:
                _memo["coarse"] = coarsen_schedule(
                    _schedule(), cfg, unroll_threshold=unroll_threshold)
            return _memo["coarse"]

        sncfg = _as_supernode_config(supernodes)

        def _supernodes() -> Supernodes:
            # detection + packing run on the (possibly rewritten) target, so
            # blocked composes with an explicit rewrite directive like every
            # other executor
            if "sn" not in _memo:
                _memo["sn"] = detect_supernodes(target, upper=upper,
                                                config=sncfg)
            return _memo["sn"]

        def _block_schedule() -> BlockSchedule:
            if "blocked" not in _memo:
                _memo["blocked"] = build_block_schedule(
                    target, _supernodes(), upper=upper)
            return _memo["blocked"]

        plan: Optional[PlanDecision] = None
        if strategy == "auto":
            # let the planner weigh coarsening unless explicitly disabled
            plan_ccfg = ccfg if ccfg is not None else (
                None if coarsen is False else CoarsenConfig())
            # Price rewrite candidates (the transform planner): only when the
            # user left the rewrite choice open and the analysis says the
            # schedule is barrier-dominated enough for rewriting to plausibly
            # pay.  Candidates run the (vectorized, milliseconds-scale)
            # rewrite and schedule build so they are priced with the same
            # launch-cost/padded-FLOP model as everything else.
            cands: dict = {}
            cand_artifacts: dict = {}
            if rewrite is None and should_consider_rewrite(analysis):
                for policy in ("thin", "critical_path"):
                    cfg_r = RewriteConfig(policy=policy)
                    rr = rewrite_matrix(system, levels, cfg_r, upper=upper)
                    if rr.stats.rows_rewritten == 0:
                        continue
                    sched_r = build_schedule(
                        rr.L, rr.levels, upper=upper,
                        bucket_pad_ratio=bucket_pad_ratio)
                    co_r = (coarsen_schedule(sched_r, plan_ccfg,
                                             unroll_threshold=unroll_threshold)
                            if plan_ccfg is not None else None)
                    # per-solve price of b' = E b: one padded ELL SpMV plus
                    # one extra dispatch
                    k_e = int(np.diff(rr.E.indptr).max())
                    cands[policy] = RewriteCandidate(
                        schedule=sched_r, coarsened=co_r,
                        rhs_cost=2.0 * k_e * system.n + SEGMENT_COST)
                    cand_artifacts[policy] = (cfg_r, rr, sched_r, co_r)
            # Price the sync-free sweep executor when its convergence model
            # certifies a sweep count within the configured budget: exact
            # after depth sweeps (D⁻¹N nilpotent), earlier when the iteration
            # contracts (q = ‖D⁻¹N‖_∞ < 1).  ``sweep=False`` opts out.
            sweep_cand = None
            if sweep is not False:
                scfg0 = scfg if scfg is not None else SweepConfig()
                q = contraction_factor(target, upper=upper)
                tol = (scfg0.residual_tol if scfg0.residual_tol is not None
                       else default_residual_tol(target.dtype))
                k_plan = planned_sweeps(q, target_levels.num_levels, tol,
                                        scfg0.k)
                if k_plan is not None:
                    row_off = target.row_nnz() - 1
                    sweep_cand = SweepCandidate(
                        k=k_plan,
                        ell_k=max(int(row_off.max()) if row_off.size else 0,
                                  1),
                        n=target.n, contraction=q)
            # Price the blocked (supernodal) executor when amalgamation
            # finds substance: detection is a cheap O(nnz log nnz) probe,
            # but packing dense blocks is only worth the build cost when
            # rows actually merge.  ``supernodes=False`` opts out; an
            # all-singleton partition (mean block size 1) never competes —
            # it is the scalar schedule with extra reshapes.
            blocked_cand = None
            if supernodes is not False and _supernodes().mean_block_size >= 1.5:
                blocked_cand = blocked_candidate(_block_schedule())
            plan = plan_strategy(
                analysis, _schedule(),
                _coarsened(plan_ccfg) if plan_ccfg is not None else None,
                unroll_threshold=unroll_threshold, backend=bk,
                rewritten=cands or None, sweep=sweep_cand,
                blocked=blocked_cand,
                precision=gcfg.precision if gcfg is not None else "native")
            strategy = plan.strategy
            if strategy == "sweep":
                scfg = dataclasses.replace(
                    scfg if scfg is not None else SweepConfig(),
                    k=plan.sweep_k)
            if plan.rewrite is not None:
                # adopt the winning rewrite: its result and schedules were
                # already built for pricing — no recompute
                _, rres, sched_r, co_r = cand_artifacts[plan.rewrite]
                target, target_levels = rres.L, rres.levels
                _memo.clear()
                _memo["base"] = sched_r
                if co_r is not None:
                    _memo["coarse"] = co_r
            if ccfg is not None and strategy in ("levelset", "levelset_unroll"):
                # an explicit coarsen config is a user directive — coarsening
                # stays on even if the planner costed it out; record what
                # actually executes so solver.plan stays auditable
                plan = dataclasses.replace(plan, coarsen=True)
            elif plan.coarsen:
                ccfg = plan_ccfg

        if rres is not None and rres.stats.e_nnz_offdiag > 0:
            # the per-solve RHS transform b' = E b; skipped outright when E
            # is the identity (no rewrites survived the budgets) so no-op
            # transforms cost nothing per solve
            if layout == "permuted":
                rhs_fn, e_values, e_repack = make_packed_rhs_transform(rres)
            else:
                rhs_fn = make_rhs_transform(rres)

        def _maybe_coarsen(schedule: Schedule) -> Schedule:
            return _coarsened(ccfg) if ccfg is not None else schedule

        permuted = layout == "permuted"
        values: Optional[tuple] = None
        repack: Optional[Callable] = None
        packed_stats: Optional[PackedStats] = None
        schedule: Optional[Schedule] = None
        block_schedule: Optional[BlockSchedule] = None
        sweep_stats: Optional[SweepStats] = None
        sweep_exec: Optional[Callable] = None
        if strategy == "serial":
            if permuted:
                # no level segments to permute, but the scan operands become
                # runtime buffers so refresh skips the re-trace
                fn, values, repack = make_packed_serial_solver(
                    target, upper=upper)
                packed_stats = PackedStats(
                    permutation_applied=False,
                    value_bytes=sum(int(v.nbytes) for v in values),
                    index_bytes=0,
                    padded_value_bytes=0,
                    n_pad=system.n,
                    num_segments=1,
                )
            else:
                fn = make_serial_solver(target, upper=upper)
        elif strategy in ("levelset", "levelset_unroll"):
            schedule = _maybe_coarsen(_schedule())
            ut = unroll_threshold if strategy == "levelset_unroll" else 0
            if permuted:
                playout = build_packed_layout(schedule)
                fn = make_packed_levelset_solver(
                    playout, unroll_threshold=ut,
                    gather_unroll_max_k=gather_unroll_max_k)
                values = (jnp.asarray(playout.vals_flat),
                          jnp.asarray(playout.diag_flat))
                repack = lambda data, _pl=playout: tuple(  # noqa: E731
                    jnp.asarray(a) for a in pack_values(_pl, data))
                packed_stats = playout.stats()
            else:
                fn = make_levelset_solver(
                    schedule, unroll_threshold=ut,
                    gather_unroll_max_k=gather_unroll_max_k)
        elif strategy == "pallas_level":
            from repro.kernels.sptrsv_level import ops as level_ops

            schedule = _maybe_coarsen(_schedule())
            if permuted:
                fn, values, repack, playout = level_ops.make_packed_solver(
                    schedule, backend=bk)
                packed_stats = playout.stats()
            else:
                fn = level_ops.make_solver(schedule, backend=bk)
        elif strategy == "pallas_fused":
            from repro.kernels.sptrsv_fused import ops as fused_ops

            # fused is already a single segment; coarsening would only
            # re-partition its chunk walk, so the layout consumes sub-slabs
            schedule = _schedule()
            if permuted:
                fn, values, repack, flay = fused_ops.make_packed_solver(
                    schedule, backend=bk)
                packed_stats = PackedStats(
                    permutation_applied=True,
                    value_bytes=int(flay.vals.nbytes + flay.diag.nbytes),
                    index_bytes=int(flay.cols.nbytes),
                    padded_value_bytes=int(
                        ((flay.val_src < 0).sum() + (flay.diag_src < 0).sum())
                        * flay.vals.itemsize),
                    n_pad=flay.n_pad,
                    num_segments=1,
                )
            else:
                fn = fused_ops.make_solver(schedule, backend=bk)
        elif strategy == "distributed":
            from .dist import (
                build_packed_dist_layout,
                make_distributed_solver,
                make_packed_distributed_solver,
                shard_schedule,
            )

            assert mesh is not None, "distributed strategy needs a mesh"
            schedule = _maybe_coarsen(_schedule())
            ndev = int(np.prod([mesh.shape[a] for a in (mesh_axis,)]))
            if permuted:
                playout = build_packed_dist_layout(schedule, ndev)
                fn, values, repack = make_packed_distributed_solver(
                    playout, mesh, mesh_axis, strategy=dist_strategy,
                    gather_unroll_max_k=gather_unroll_max_k)
                packed_stats = playout.stats()
            else:
                dsched = shard_schedule(schedule, ndev)
                fn = make_distributed_solver(
                    dsched, mesh, mesh_axis, strategy=dist_strategy)
        elif strategy == "blocked":
            # node-granular (supernodal) executor: batched dense diagonal-
            # block apply + padded ELL panel update per super-level.  The
            # dense block inverses live in the runtime value buffers, so the
            # permuted layout refreshes value-only (re-gather + re-invert +
            # swap) with a jit cache hit.
            block_schedule = _block_schedule()
            if permuted:
                blay = build_packed_blocked_layout(block_schedule)
                fn = make_packed_blocked_solver(
                    blay, backend=bk, kernel=block_kernel,
                    gather_unroll_max_k=gather_unroll_max_k)
                values = pack_blocked_values(blay, target.data)
                repack = lambda data, _bl=blay: pack_blocked_values(  # noqa: E731
                    _bl, data)
                packed_stats = blay.stats()
            else:
                fn = make_blocked_solver(
                    block_schedule, backend=bk, kernel=block_kernel,
                    gather_unroll_max_k=gather_unroll_max_k)
        elif strategy == "sweep":
            # sync-free speculative solve-then-correct (repro.core.sweep):
            # whole-matrix D + N split, k fused sweeps, no schedule at all.
            # The exact-fallback solver is built lazily on first use — the
            # converged common case never pays its build.
            slayout = build_sweep_layout(target, upper=upper)
            cur_target = [target]
            fb_holder: dict = {}

            def _fallback():
                if "s" not in fb_holder:
                    fb_holder["s"] = SpTRSV._build_system(
                        cur_target[0], target_levels, upper=upper,
                        strategy=scfg.fallback, rewrite=None,
                        unroll_threshold=unroll_threshold,
                        bucket_pad_ratio=bucket_pad_ratio, coarsen=coarsen,
                        backend=bk, jit=jit, layout=layout,
                        gather_unroll_max_k=gather_unroll_max_k)
                return fb_holder["s"].solve

            fn, sweep_stats, sweep_exec = make_sweep_solver(
                slayout, scfg,
                fallback=_fallback if scfg.fallback is not None else None,
                jit=jit, runtime_values=permuted)
            if permuted:
                values = (jnp.asarray(slayout.ell.vals),
                          jnp.asarray(slayout.diag))

                def repack(target_data, _sl=slayout, _t=target):
                    # keep the lazily-built exact fallback numerically in
                    # sync with the refreshed values
                    cur_target[0] = CSRMatrix(
                        _t.indptr, _t.indices,
                        np.asarray(target_data).astype(_t.dtype, copy=False),
                        _t.shape)
                    if "s" in fb_holder:
                        fb_holder["s"].refresh(cur_target[0].data)
                    return pack_sweep_values(_sl, target_data)

                packed_stats = ell_packed_stats(
                    slayout.ell, slayout.diag, n=system.n)
        else:  # pragma: no cover
            raise ValueError(strategy)

        if gcfg is not None and gcfg.precision == "mixed":
            if values is None:
                raise ValueError(
                    f"guard precision='mixed' is not supported for "
                    f"strategy={strategy!r} (no runtime value buffers)")
            # bf16 off-diagonal stream + fp32 diagonal buffer; executors
            # cast to the RHS dtype at solve time, and the guard runs inner
            # solves in fp32 with fp64 refinement recovering full accuracy
            values = cast_value_buffers(values)
            if repack is not None:
                _repack_full = repack
                repack = lambda data: cast_value_buffers(  # noqa: E731
                    _repack_full(data))

        # jit the RHS transform b' = E b separately from the solve.  A
        # single jit over both lets XLA fuse the batched SpMV into the
        # per-level consumers and recompute it, a >10x slowdown at m=64 on
        # CPU; the extra dispatch costs microseconds.  The sweep wrapper is
        # a host function (verification readback + fallback dispatch) whose
        # pure executor is already jitted inside make_sweep_solver — an
        # outer jit would trace the data-dependent fallback branch away.
        solve_fn = fn if strategy == "sweep" else \
            (jax.jit(fn) if jit else fn)
        rhs_c = (jax.jit(rhs_fn) if jit else rhs_fn) if rhs_fn is not None \
            else None

        def _rebuild(data: np.ndarray) -> "SpTRSV":
            sys_data = data[values_map] if values_map is not None else data
            sys2 = CSRMatrix(system.indptr, system.indices,
                             sys_data.astype(system.dtype, copy=False),
                             system.shape)
            return SpTRSV._build_system(
                sys2, levels, source=CSRMatrix(
                    source.indptr, source.indices,
                    data.astype(source.dtype, copy=False), source.shape),
                values_map=values_map, **build_kwargs)

        ctx = _RefreshCtx(
            source=source, system=system, values_map=values_map,
            rewrite=rres, repack=repack, e_repack=e_repack,
            rebuild=_rebuild,
        )
        solver = SpTRSV(
            n=system.n,
            strategy=strategy,
            analysis=analysis,
            schedule=schedule,
            block_schedule=block_schedule,
            supernodes=(block_schedule.supernodes
                        if block_schedule is not None else None),
            rewrite_result=rres,
            _solve_fn=solve_fn,
            _rhs_fn=rhs_c,
            transpose=upper,
            plan=plan,
            layout=layout,
            backend=bk.name,
            packed_stats=packed_stats,
            sweep_stats=sweep_stats,
            _values=values,
            _e_values=e_values,
            _refresh_ctx=ctx,
            _sweep_exec=sweep_exec,
        )
        if gcfg is not None:
            # The guard verifies against the ORIGINAL (pre-rewrite) system —
            # end-to-end coverage of rewrite replay and E-SpMV fill — and its
            # exact fallback is built on that same system, so eliminated-
            # pivot divisions cannot poison the corrective path.  The inner
            # solve is the live pipeline (`_solve_raw` reads the current
            # value buffers), so refresh keeps the guard coherent.
            def _guard_fallback(data, _sys=system, _lv=levels):
                fb = SpTRSV._build_system(
                    CSRMatrix(_sys.indptr, _sys.indices,
                              np.asarray(data).astype(_sys.dtype, copy=False),
                              _sys.shape),
                    _lv, upper=upper, strategy=gcfg.fallback, rewrite=None,
                    unroll_threshold=unroll_threshold,
                    bucket_pad_ratio=bucket_pad_ratio,
                    backend=bk, jit=jit, layout=layout,
                    gather_unroll_max_k=gather_unroll_max_k)
                return fb.solve

            solver.guard = SolveGuard(
                system, upper=upper, config=gcfg,
                inner_solve=solver._solve_raw,
                fallback_builder=_guard_fallback, jit=jit)
        return solver

    @property
    def dtype(self) -> np.dtype:
        """Numeric dtype of the solved system's stored values — what batch
        buffers should be allocated in to hit the compiled executable's
        jit-cache bucket (see ``SolveEngine._solve_group``)."""
        if self._refresh_ctx is not None:
            return self._refresh_ctx.system.dtype
        return np.dtype(np.float64)

    @property
    def pattern_hash(self) -> Optional[str]:
        """Stable sparsity-pattern digest of the *source* factor this solver
        was built from (:meth:`CSRMatrix.pattern_hash`) — the registry key a
        serving tier routes same-pattern refreshes by.  ``None`` only for a
        solver built without refresh state."""
        if self._refresh_ctx is None:
            return None
        return self._refresh_ctx.source.pattern_hash()

    def solve(self, b: jnp.ndarray) -> jnp.ndarray:
        """Solve L x = b (or Lᵀ x = b for a ``transpose`` solver).  ``b``
        may be ``(n,)`` (one system) or ``(n, m)`` (m independent systems
        solved in one batched pass).  Each distinct batch width compiles
        once (shapes are trace-time constants — the executor is matrix-
        *and* batch-specialized).

        Permuted-layout solvers permute ``b`` and un-permute ``x`` exactly
        once inside the executor (two O(n) gathers at the API boundary —
        the price of contiguous per-segment reads/writes).

        Guarded solvers (``guard=``) route through
        :meth:`repro.core.guard.SolveGuard.solve`: the result is verified
        against the original system's componentwise residual, iteratively
        refined, and columns that stay above tolerance are handled by the
        configured ``on_breakdown`` policy (best-effort / exact per-column
        fallback / :class:`repro.core.guard.GuardBreakdownError`)."""
        if b.ndim not in (1, 2) or b.shape[0] != self.n:
            raise ValueError(
                f"b must be ({self.n},) or ({self.n}, m); got {b.shape}")
        if self.guard is not None:
            return self.guard.solve(b)
        return self._solve_raw(b)

    def _solve_raw(self, b: jnp.ndarray) -> jnp.ndarray:
        """The unguarded solve pipeline (RHS transform + executor) against
        the LIVE value buffers — what the guard wraps and refines."""
        if self._rhs_fn is not None:
            b = (self._rhs_fn(b, self._e_values)
                 if self._e_values is not None else self._rhs_fn(b))
        if self._values is not None:
            return self._solve_fn(b, self._values)
        return self._solve_fn(b)

    def solve_batched(self, B: jnp.ndarray) -> jnp.ndarray:
        """Explicitly-batched alias: ``B: (n, m)`` → ``X: (n, m)``.

        ``solve`` already dispatches on ndim; this entry point exists so
        call sites that *require* the multi-RHS path fail loudly when handed
        a single vector."""
        if B.ndim != 2:
            raise ValueError(f"solve_batched expects (n, m); got {B.shape}")
        return self.solve(B)

    def refresh(self, new_values, *, validate: bool = True) -> "SpTRSV":
        """Value-only numeric refresh: swap in new matrix **values** of the
        same sparsity pattern, reusing the whole cached symbolic state —
        level analysis, permutation, packed-buffer offsets, coarsening, the
        ``auto`` planner decision, and (crucially) the compiled executable.

        ``new_values`` is the new ``data`` array aligned with the original
        factor's CSR storage (or a :class:`CSRMatrix` with the identical
        pattern).  For transpose solvers the values are reordered through
        the cached CSC map; for rewritten solvers the recorded elimination
        plan is replayed numerically
        (:func:`repro.core.rewrite.replay_rewrite_values`) to produce new
        L'/E values in the cached patterns.  The executor's packed value
        buffers are then re-packed with one vectorized O(nnz) gather and
        swapped in — no re-trace, no re-compile; this is what a production
        PCG/IC server needs after each numeric re-factorization.

        Scatter-layout solvers (values embedded as trace-time constants)
        fall back to a cold rebuild, as does the rare case of a rewrite
        plan that does not numerically transfer (zero pivot / exact-zero
        cancellation in the *original* values).  Returns ``self``.

        ``validate`` (default on) runs a cheap O(nnz) value health scan —
        finiteness of every entry plus an exact-zero diagonal check — and
        raises ``ValueError`` on failure, because a refreshed executor would
        otherwise silently divide by zero or propagate NaN through the whole
        schedule.  ``validate=False`` skips the scan (e.g. to let a guarded
        solver's breakdown policy handle the bad values at solve time
        instead); a guarded solver additionally re-runs its own
        ``pivot_tol``-aware scan and re-packs its residual checker after
        every refresh."""
        ctx = self._refresh_ctx
        if ctx is None:
            raise ValueError("solver was built without refresh state")
        if isinstance(new_values, CSRMatrix):
            src = ctx.source
            if (new_values.nnz != src.nnz
                    or not np.array_equal(new_values.indptr, src.indptr)
                    or not np.array_equal(new_values.indices, src.indices)):
                raise ValueError(
                    "refresh requires the identical sparsity pattern; "
                    "rebuild for structural changes")
            data = np.asarray(new_values.data)
        else:
            data = np.asarray(new_values)
        if data.shape != ctx.source.data.shape:
            raise ValueError(
                f"new values must have shape {ctx.source.data.shape} "
                f"(one per stored nonzero); got {data.shape}")
        if validate:
            # O(nnz) health scan of the incoming values.  The source factor
            # is lower-triangular CSR with sorted columns, so its diagonal
            # is the last stored entry of every row.
            diag_idx = ctx.source.indptr[1:] - 1
            nonfinite, zero_piv = scan_values(data, diag_idx)
            if nonfinite or zero_piv:
                raise ValueError(
                    f"refresh: new values contain {nonfinite} non-finite "
                    f"entry(ies) and {zero_piv} zero/non-finite diagonal "
                    f"pivot(s); pass validate=False to accept them anyway "
                    f"(a guarded solver then applies its breakdown policy "
                    f"at solve time)")

        def _cold(reason: str) -> "SpTRSV":
            logger.warning("SpTRSV.refresh: %s — falling back to a cold "
                           "rebuild", reason)
            fresh = ctx.rebuild(data)
            self.__dict__.update(fresh.__dict__)
            return self

        if ctx.repack is None:
            return _cold(f"layout={self.layout!r} embeds values as "
                         "trace-time constants")
        sys_data = (data[ctx.values_map] if ctx.values_map is not None
                    else data).astype(ctx.system.dtype, copy=False)
        if ctx.rewrite is not None:
            system = CSRMatrix(ctx.system.indptr, ctx.system.indices,
                               sys_data, ctx.system.shape)
            try:
                target_data, e_data = replay_rewrite_values(
                    system, ctx.rewrite.plan, ctx.rewrite.L, ctx.rewrite.E)
            except RewriteReplayError as err:
                return _cold(f"rewrite plan did not transfer ({err})")
            if ctx.e_repack is not None:
                self._e_values = ctx.e_repack(e_data)
            self.rewrite_result = dataclasses.replace(
                ctx.rewrite,
                L=CSRMatrix(ctx.rewrite.L.indptr, ctx.rewrite.L.indices,
                            target_data, ctx.rewrite.L.shape),
                E=CSRMatrix(ctx.rewrite.E.indptr, ctx.rewrite.E.indices,
                            e_data, ctx.rewrite.E.shape))
        else:
            target_data = sys_data
        self._values = ctx.repack(target_data)
        # keep the cached source in sync so chained refreshes validate
        # against (and rebuild from) the latest values
        self._refresh_ctx = dataclasses.replace(
            ctx, source=CSRMatrix(ctx.source.indptr, ctx.source.indices,
                                  data, ctx.source.shape))
        if self.guard is not None:
            # re-pack the guard's full-precision residual buffers and re-run
            # its pivot_tol-aware value scan (breakdown policy applies)
            self.guard.refresh(sys_data)
        return self

    def stats(self) -> dict:
        """Execution-layout and schedule statistics, including the packed
        streaming-buffer bytes, padding waste, and whether the permuted
        layout is active — so benchmarks stop recomputing them ad hoc."""
        ps = self.packed_stats
        return {
            "strategy": self.strategy,
            "layout": self.layout,
            "backend": self.backend,
            "transpose": self.transpose,
            "n": self.n,
            "nnz": self.analysis.nnz,
            "segments": (self.schedule.num_segments
                         if self.schedule is not None
                         else self.block_schedule.num_segments
                         if self.block_schedule is not None else 1),
            "supernode_count": (self.supernodes.num_supernodes
                                if self.supernodes is not None
                                else self.analysis.supernode_count),
            "mean_block_size": (self.supernodes.mean_block_size
                                if self.supernodes is not None
                                else self.analysis.mean_block_size),
            "dense_block_fraction": (self.supernodes.dense_block_fraction
                                     if self.supernodes is not None
                                     else self.analysis.dense_block_fraction),
            "permutation_applied": bool(ps and ps.permutation_applied),
            "packed_value_bytes": ps.value_bytes if ps else None,
            "packed_index_bytes": ps.index_bytes if ps else None,
            # total resident packed-buffer footprint of this executor —
            # what a serving registry's byte budget charges per solver
            "packed_bytes": ((ps.value_bytes + ps.index_bytes)
                             if ps else None),
            "pattern_hash": self.pattern_hash,
            "padded_value_bytes": ps.padded_value_bytes if ps else None,
            "n_pad": ps.n_pad if ps else None,
            "refreshable_in_place": (self._refresh_ctx is not None
                                     and self._refresh_ctx.repack is not None),
            "rewrite": (self.rewrite_result.stats.summary()
                        if self.rewrite_result else None),
            "rewrite_policy": (self.rewrite_result.stats.policy
                               if self.rewrite_result else None),
            "critical_path_flops": self.analysis.critical_path_flops,
            "plan": self.plan.reason if self.plan else None,
            "planned_transform": (
                {"rewrite": self.plan.rewrite, "coarsen": self.plan.coarsen}
                if self.plan else None),
            "sweep": (self.sweep_stats.report()
                      if self.sweep_stats is not None else None),
            "planned_sweeps": self.plan.sweep_k if self.plan else None,
            # guarded-execution accounting (guard=GuardConfig(...)): the
            # full report plus the headline observables — refinement steps
            # taken, fallbacks fired, residual achieved, pivot alarms
            "guard": (self.guard.stats.report()
                      if self.guard is not None else None),
            "guard_precision": (self.guard.stats.precision
                                if self.guard is not None else None),
            "guard_refine_steps": (self.guard.stats.refine_steps_total
                                   if self.guard is not None else None),
            "guard_fallbacks": (self.guard.stats.fallback_solves
                                if self.guard is not None else None),
            "guard_residual": (self.guard.stats.last_residual_ratio
                               if self.guard is not None else None),
            "guard_pivot_alarms": (self.guard.stats.pivot_alarms
                                   if self.guard is not None else None),
        }
