"""Linear recurrences as bidiagonal SpTRSV — equation rewriting at work.

The gated linear recurrence used by RG-LRU / mLSTM-style layers,

    h_t = a_t * h_{t-1} + u_t ,        t = 1..T

is exactly a *lower-bidiagonal triangular solve*:

    [ 1                ] [h_1]   [u_1 (+ a_1 h_0)]
    [-a_2  1           ] [h_2]   [u_2]
    [     -a_3  1      ] [h_3] = [u_3]
    [          ...  1  ] [...]   [...]

whose dependency DAG is a pure chain — T levels, the worst case for
level-set SpTRSV (`repro.sparse.generate.chain_matrix`).  Applying the
paper's **equation rewriting** to every row simultaneously — substitute row
t-1's equation into row t — breaks each odd dependency and lifts every row
one level:

    h_t = (a_t a_{t-1}) h_{t-2} + (a_t u_{t-1} + u_t)

i.e. one rewriting sweep squares the "gap": after k sweeps each row depends
on h_{t-2^k}; ceil(log2 T) sweeps empty *all* intermediate levels.  That is
precisely recursive doubling / Blelloch's parallel scan with the associative
combine

    (a2, u2) ∘ (a1, u1) = (a1*a2, a2*u1 + u2)

So the paper's transformation, specialized to the chain matrix, *derives*
the parallel scan that makes RG-LRU / mLSTM training parallel on TPU.  The
FLOP increase the paper reports (+10% on lung2) appears here as the
O(T log T)-vs-O(T) work trade of the scan — paid to eliminate T−1
synchronization points, the same bargain.

`linear_recurrence` exposes three executors (all tested equal):
  * ``scan``      sequential `lax.scan` — paper Algorithm 1 on the chain
  * ``doubling``  `lax.associative_scan` — equation rewriting to fixpoint
  * ``sptrsv``    materialize the bidiagonal matrix and call the level-set
                  solver after `rewrite_matrix` — the literal paper pipeline
                  (small T only; used by tests to close the loop)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["linear_recurrence", "recurrence_as_sptrsv"]


def _combine(elem2, elem1):
    # note: associative_scan applies combine(carry, new) with elements
    # ordered along the axis; combine must be associative (it is).
    a1, u1 = elem2
    a2, u2 = elem1
    return a1 * a2, a2 * u1 + u2


def linear_recurrence(
    a: jnp.ndarray,        # (T, ...) gates
    u: jnp.ndarray,        # (T, ...) inputs
    h0: jnp.ndarray | None = None,   # (...,) initial state
    *,
    method: str = "doubling",
    axis: int = 0,
) -> jnp.ndarray:
    """h_t = a_t h_{t-1} + u_t along ``axis``; returns all h_t (T, ...)."""
    if h0 is not None:
        # fold h0 into the first input: u_1 += a_1 * h0
        first = jax.lax.index_in_dim(u, 0, axis) + jax.lax.index_in_dim(a, 0, axis) * h0[None]
        u = jax.lax.dynamic_update_index_in_dim(u, jnp.squeeze(first, axis), 0, axis)
    if method == "doubling":
        _, h = jax.lax.associative_scan(_combine, (a, u), axis=axis)
        return h
    if method == "scan":
        a_m = jnp.moveaxis(a, axis, 0)
        u_m = jnp.moveaxis(u, axis, 0)

        def body(h, au):
            at, ut = au
            h = at * h + ut
            return h, h

        h0_ = jnp.zeros(u_m.shape[1:], u.dtype)
        _, h = jax.lax.scan(body, h0_, (a_m, u_m))
        return jnp.moveaxis(h, 0, axis)
    if method == "sptrsv":
        return _recurrence_via_solver(a, u, axis=axis)
    raise ValueError(method)


def _recurrence_via_solver(a, u, *, axis=0):
    """Literal paper pipeline: build the bidiagonal L, run equation rewriting,
    solve with the generated level-set executor.  Gates must be concrete
    (trace-time constants) — this path exists to *prove the equivalence*,
    not for production (tests / tiny T)."""
    from .csr import from_coo
    from .rewrite import RewriteConfig, rewrite_matrix
    from .solver import SpTRSV

    a_np = np.asarray(jax.device_get(a))
    a_m = np.moveaxis(a_np, axis, 0)
    T = a_m.shape[0]
    flat_a = a_m.reshape(T, -1)
    u_m = jnp.moveaxis(u, axis, 0).reshape(T, -1)
    outs = []
    for j in range(flat_a.shape[1]):
        rows = list(range(T)) + list(range(1, T))
        cols = list(range(T)) + list(range(0, T - 1))
        vals = [1.0] * T + (-flat_a[1:, j]).tolist()
        L = from_coo(rows, cols, np.asarray(vals, np.float64), (T, T))
        solver = SpTRSV.build(
            L, strategy="levelset",
            rewrite=RewriteConfig(thin_threshold=1, max_row_nnz=T + 1,
                                  max_fill_ratio=float(T)),
        )
        outs.append(solver.solve(u_m[:, j].astype(jnp.float64)))
    h = jnp.stack(outs, -1).reshape((T,) + a_m.shape[1:]).astype(u.dtype)
    return jnp.moveaxis(h, 0, axis)


def recurrence_as_sptrsv(a: np.ndarray):
    """Return the bidiagonal CSR matrix of the recurrence with gates ``a``
    (T,) — exposed so benchmarks/tests can inspect its level structure."""
    from .csr import from_coo

    T = a.shape[0]
    rows = list(range(T)) + list(range(1, T))
    cols = list(range(T)) + list(range(0, T - 1))
    vals = [1.0] * T + (-np.asarray(a)[1:]).tolist()
    return from_coo(rows, cols, np.asarray(vals, np.float64), (T, T))
