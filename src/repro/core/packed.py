"""Permuted-space packed execution + value-only numeric refresh.

PR-3 removed most synchronization points (a lung2-class schedule runs as
~58 segments instead of ~493); what remains on the hot path is *memory
irregularity inside each segment* — every segment scatters its solved rows
into ``x`` at arbitrary ids and gathers ``b`` the same way — plus
build/compile time when the same sparsity pattern is re-solved with new
values (the dominant case in iterative workloads: each numeric
re-factorization of a PCG/IC server changes values, never structure).

This module addresses both:

**Permuted space.**  The slab order of a :class:`~repro.core.codegen.Schedule`
already visits every row exactly once, so it defines a row permutation
``perm`` (:meth:`Schedule.perm`) under which each segment's output rows are a
*contiguous slice*.  Executors here run entirely in that space: ``b`` is
permuted once at entry (``b̂ = b[perm]``), every segment reads its RHS with a
static slice and writes its solution with ``lax.dynamic_update_slice`` — no
per-segment scatter/gather of row ids — and ``x`` is un-permuted once at exit
(``x = x̂[pos]``).  ELL dependency columns are remapped to permuted positions
once at build.  (This generalizes the fused Pallas kernel's level-order
layout trick to *every* executor.)

**One packed streaming buffer.**  All per-segment ``vals`` slabs are packed
into one flat buffer with static offsets (same for ``diag`` and the column
indices), and the value buffers are passed to the jitted executor as
*runtime arguments* rather than trace-time constants.  XLA holds one
streaming input instead of ~58 embedded constants, and — the refresh payoff —
new values with the same pattern reuse the compiled executable outright:
``SpTRSV.refresh`` re-packs the buffers with one vectorized gather
(:func:`pack_values`, O(nnz)) and swaps them in.  No level analysis, no
re-trace, no re-compile.

Padding discipline: a segment may write its full padded width ``R_pad``;
padding lanes compute finite garbage (val 0 / diag 1) that lands *forward* —
on positions whose owning segment has not yet executed and always overwrites
them before any consumer reads them — so only writes past position ``n``
need scratch, provided by the ``n_pad - n`` tail.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .codegen import (
    GATHER_UNROLL_MAX_K,
    Schedule,
    _coef,
    _gather_sum,
    build_ell,
    serial_arrays,
    stack_sub_slabs,
)
from .csr import CSRMatrix
from .rewrite import RewriteResult

__all__ = [
    "PackedSegment",
    "PackedLayout",
    "PackedStats",
    "PackedBlockSegment",
    "PackedBlockedLayout",
    "build_packed_layout",
    "build_packed_blocked_layout",
    "ell_packed_stats",
    "gather_src",
    "pack_values",
    "pack_blocked_values",
    "cast_value_buffers",
    "MIXED_VALS_DTYPE",
    "MIXED_DIAG_DTYPE",
    "make_packed_levelset_solver",
    "make_packed_blocked_solver",
    "make_packed_serial_solver",
    "make_packed_rhs_transform",
]


@dataclasses.dataclass(frozen=True)
class PackedSegment:
    """Geometry of one segment inside the packed flat buffers.

    ``off`` is the segment's first position in permuted space; its rows own
    positions ``[off, off + R)``.  ``R_pad`` is the padded lane width the
    executor computes/writes (equals ``R`` unless an executor-specific row
    alignment was requested).  Chains (``depth > 1``) store the stacked
    uniform sub-slab arrays ``(d, K, R_pad)``; ``sub_offs`` are the
    per-sub-slab permuted-space offsets driving the ``fori_loop``."""

    kind: str                 # "plain" | "chain"
    off: int
    R: int
    R_pad: int
    K: int
    depth: int
    val_off: int
    col_off: int
    diag_off: int
    sub_offs: Optional[np.ndarray] = None  # (depth,) int64, chains only
    block_rows: int = 0       # pallas row-block size (0 = not a kernel path)

    @property
    def val_size(self) -> int:
        return self.depth * self.K * self.R_pad

    @property
    def diag_size(self) -> int:
        return self.depth * self.R_pad


@dataclasses.dataclass(frozen=True)
class PackedStats:
    """Byte-level accounting of a packed layout — surfaced by
    ``SpTRSV.stats()`` so benchmarks stop recomputing it ad hoc."""

    permutation_applied: bool
    value_bytes: int          # packed vals + diag buffers
    index_bytes: int          # packed column-position buffer
    padded_value_bytes: int   # zero-padding share of value_bytes
    n_pad: int                # permuted vector length incl. scratch tail
    num_segments: int

    def report(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class PackedLayout:
    """Permuted-space packed form of a :class:`Schedule`.

    ``perm[p]`` = original row at permuted position ``p``; ``pos[i]`` =
    position of original row ``i``.  ``cols_flat`` holds *positions* (already
    remapped through ``pos``).  ``vals_src``/``diag_src`` map every packed
    value back into the target matrix's ``data`` array (-1 = padding) — the
    refresh maps consumed by :func:`pack_values`."""

    n: int
    n_pad: int
    nnz: int
    perm: np.ndarray
    pos: np.ndarray
    segments: tuple
    cols_flat: np.ndarray
    vals_flat: np.ndarray
    diag_flat: np.ndarray
    vals_src: np.ndarray
    diag_src: np.ndarray

    def stats(self) -> PackedStats:
        item = self.vals_flat.itemsize
        pad = int((self.vals_src < 0).sum() + (self.diag_src < 0).sum())
        return PackedStats(
            permutation_applied=True,
            value_bytes=self.vals_flat.nbytes + self.diag_flat.nbytes,
            index_bytes=self.cols_flat.nbytes,
            padded_value_bytes=pad * item,
            n_pad=self.n_pad,
            num_segments=len(self.segments),
        )


def build_packed_layout(
    schedule: Schedule,
    *,
    pad_rows: Optional[Callable[[int], int]] = None,
    pad_chain_rows: Optional[Callable[[int], int]] = None,
    block_rows_for: Optional[Callable[[int], int]] = None,
) -> PackedLayout:
    """Lower a schedule into the permuted-space packed layout.

    ``pad_rows(R) -> R_pad`` lets kernel executors request row alignment
    (TPU lane multiples, mesh-axis divisibility); default is no padding.
    ``pad_chain_rows`` applies to the widest sub-slab of a chain (defaults
    to ``pad_rows``).  ``block_rows_for(R_pad)`` records a per-segment
    kernel block size for Pallas executors."""
    pad_rows = pad_rows or (lambda r: r)
    pad_chain_rows = pad_chain_rows or pad_rows
    n = schedule.n
    perm = schedule.perm()
    assert perm.size == n, (perm.size, n)
    pos = np.empty(n, dtype=np.int64)
    pos[perm] = np.arange(n, dtype=np.int64)
    pos32 = pos.astype(np.int32)

    segments = []
    cols_b, vals_b, diag_b, vsrc_b, dsrc_b = [], [], [], [], []
    off = voff = doff = 0
    write_end_max = n
    dtype = schedule.slabs[0].vals.dtype if schedule.slabs else np.float64
    for slab in schedule.slabs:
        R = slab.R
        if R == 0:
            continue
        if slab.depth > 1:
            _, cols_s, vals_s, diag_s, vsrc_s, dsrc_s = stack_sub_slabs(
                slab, n, with_src=True)
            d, K, rmax = cols_s.shape
            Rp = int(pad_chain_rows(rmax))
            cols_p = np.zeros((d, K, Rp), dtype=np.int32)
            cols_p[:, :, :rmax] = pos32[cols_s]
            vals_p = np.zeros((d, K, Rp), dtype=vals_s.dtype)
            vals_p[:, :, :rmax] = vals_s
            diag_p = np.ones((d, Rp), dtype=diag_s.dtype)
            diag_p[:, :rmax] = diag_s
            vsrc_p = np.full((d, K, Rp), -1, dtype=np.int64)
            vsrc_p[:, :, :rmax] = vsrc_s
            dsrc_p = np.full((d, Rp), -1, dtype=np.int64)
            dsrc_p[:, :rmax] = dsrc_s
            sub_offs = off + np.concatenate(
                [[0], np.cumsum(slab.sub_rows[:-1])]).astype(np.int64)
            write_end = int(sub_offs[-1]) + Rp
            seg = PackedSegment(
                kind="chain", off=off, R=R, R_pad=Rp, K=K, depth=d,
                val_off=voff, col_off=voff, diag_off=doff, sub_offs=sub_offs,
                block_rows=block_rows_for(Rp) if block_rows_for else 0)
        else:
            K = slab.K
            Rp = int(pad_rows(R))
            cols_p = np.zeros((K, Rp), dtype=np.int32)
            cols_p[:, :R] = pos32[slab.cols]
            vals_p = np.zeros((K, Rp), dtype=slab.vals.dtype)
            vals_p[:, :R] = slab.vals
            diag_p = np.ones((Rp,), dtype=slab.diag.dtype)
            diag_p[:R] = slab.diag
            vsrc_p = np.full((K, Rp), -1, dtype=np.int64)
            dsrc_p = np.full((Rp,), -1, dtype=np.int64)
            if slab.val_src is not None:
                vsrc_p[:, :R] = slab.val_src
                dsrc_p[:R] = slab.diag_src
            write_end = off + Rp
            seg = PackedSegment(
                kind="plain", off=off, R=R, R_pad=Rp, K=K, depth=1,
                val_off=voff, col_off=voff, diag_off=doff,
                block_rows=block_rows_for(Rp) if block_rows_for else 0)
        segments.append(seg)
        cols_b.append(cols_p.ravel())
        vals_b.append(vals_p.ravel())
        diag_b.append(diag_p.ravel())
        vsrc_b.append(vsrc_p.ravel())
        dsrc_b.append(dsrc_p.ravel())
        write_end_max = max(write_end_max, write_end)
        off += R
        voff += seg.val_size
        doff += seg.diag_size
    assert off == n, (off, n)

    def cat(blocks, dt):
        return (np.concatenate(blocks).astype(dt, copy=False) if blocks
                else np.zeros(0, dtype=dt))

    return PackedLayout(
        n=n, n_pad=write_end_max, nnz=schedule.nnz,
        perm=perm, pos=pos,
        segments=tuple(segments),
        cols_flat=cat(cols_b, np.int32),
        vals_flat=cat(vals_b, dtype),
        diag_flat=cat(diag_b, dtype),
        vals_src=cat(vsrc_b, np.int64),
        diag_src=cat(dsrc_b, np.int64),
    )


def ell_packed_stats(ell, diag: np.ndarray, *, n: int) -> PackedStats:
    """:class:`PackedStats` for a whole-matrix ELL layout (the ``sweep``
    executor's ``D + N`` split): one segment, no permutation, padding share
    read off the value-source map."""
    pad = int((ell.val_src < 0).sum())
    return PackedStats(
        permutation_applied=False,
        value_bytes=ell.vals.nbytes + diag.nbytes,
        index_bytes=ell.cols.nbytes,
        padded_value_bytes=pad * ell.vals.itemsize,
        n_pad=n,
        num_segments=1,
    )


def gather_src(data: np.ndarray, src: np.ndarray, fill, dtype) -> np.ndarray:
    """Masked source-map gather: ``out[i] = data[src[i]]`` where ``src >= 0``
    and ``fill`` at padding slots (``src < 0``).  The single re-pack idiom
    every refresh path shares (flat slabs, serial scan operands, the E
    operator, the fused layout)."""
    data = np.asarray(data)
    out = np.where(src >= 0, data[np.clip(src, 0, None)], fill)
    return out.astype(dtype, copy=False)


def pack_values(layout: PackedLayout, data: np.ndarray):
    """Re-pack the flat value buffers for new ``data`` of the same pattern —
    the numeric-refresh hot path: two vectorized gathers, O(nnz + padding),
    no analysis, no executor rebuild."""
    return (gather_src(data, layout.vals_src, 0.0, layout.vals_flat.dtype),
            gather_src(data, layout.diag_src, 1.0, layout.diag_flat.dtype))


# Mixed-precision storage dtypes (guard ``precision="mixed"``): bf16 for the
# large off-diagonal/panel stream, fp32 for the diagonal / inverted-diagonal
# buffer.  The diagonal stays fp32 because the refinement error-iteration
# matrix (A − Ã)Ã⁻¹ has the relative diagonal storage error on ITS diagonal
# — bf16 diagonals stall refinement near 4e-3/step while fp32 diagonals
# contract ~1e-3–1e-4/step; the diagonal is O(n) of O(nnz) bytes, so the
# saving lives in the off-diagonal stream either way.
MIXED_VALS_DTYPE = jnp.bfloat16
MIXED_DIAG_DTYPE = jnp.float32


def cast_value_buffers(values, *, vals_dtype=MIXED_VALS_DTYPE,
                       diag_dtype=MIXED_DIAG_DTYPE):
    """Lower a packed runtime value tuple to mixed-precision storage: the
    first buffer (off-diagonal / panel values — the O(nnz) stream) to
    ``vals_dtype``, every remaining buffer (diagonal, inverted diagonal
    blocks) to ``diag_dtype``.  Works for every permuted-layout executor —
    they all pass ``(offdiag_buffer, diag_buffer)`` 2-tuples and cast to the
    RHS dtype at solve time."""
    vals, *rest = values
    return (jnp.asarray(vals).astype(vals_dtype),
            *(jnp.asarray(r).astype(diag_dtype) for r in rest))


# --------------------------------------------------------------------------
# Permuted-space executors (pure JAX)
# --------------------------------------------------------------------------
def _slice_seg(flat, start, size):
    return jax.lax.slice_in_dim(flat, start, start + size)


def _plain_segment(x, bhat, seg, cols_flat, vf, df, gk):
    K, Rp = seg.K, seg.R_pad
    cols = _slice_seg(cols_flat, seg.col_off, K * Rp).reshape(K, Rp)
    vals = _slice_seg(vf, seg.val_off, K * Rp).reshape(K, Rp)
    diag = _slice_seg(df, seg.diag_off, Rp)
    s = _gather_sum(vals, cols, x, unroll_max_k=gk)
    bw = jax.lax.slice_in_dim(bhat, seg.off, seg.off + Rp)
    xl = (bw - s) / _coef(diag, x)
    return jax.lax.dynamic_update_slice_in_dim(x, xl, seg.off, 0)


def _chain_segment(x, bhat, seg, cols_flat, vf, df, gk):
    d, K, Rp = seg.depth, seg.K, seg.R_pad
    cols = _slice_seg(cols_flat, seg.col_off, d * K * Rp).reshape(d, K, Rp)
    vals = _slice_seg(vf, seg.val_off, d * K * Rp).reshape(d, K, Rp)
    diag = _slice_seg(df, seg.diag_off, d * Rp).reshape(d, Rp)
    sub = jnp.asarray(seg.sub_offs)

    def body(t, xc):
        s = _gather_sum(vals[t], cols[t], xc, unroll_max_k=gk)
        o = sub[t]
        bw = jax.lax.dynamic_slice_in_dim(bhat, o, Rp)
        xl = (bw - s) / _coef(diag[t], xc)
        return jax.lax.dynamic_update_slice_in_dim(xc, xl, o, 0)

    return jax.lax.fori_loop(0, d, body, x)


def _unrolled_segment(x, bhat, seg, layout, vf, df):
    """Tiny segment as generated scalar code — the paper's constant-embedded
    path, adapted to refresh: column *positions* stay literal constants, the
    values are scalar reads of the runtime buffer at literal offsets, so the
    unrolled program survives a value swap without re-tracing."""
    K, Rp, R = seg.K, seg.R_pad, seg.R
    cols = layout.cols_flat[seg.col_off: seg.col_off + K * Rp].reshape(K, Rp)
    nz = layout.vals_src[seg.val_off: seg.val_off + K * Rp].reshape(K, Rp) >= 0
    outs = []
    for r in range(R):
        s = bhat[seg.off + r]
        for k in range(K):
            if nz[k, r]:
                s = s - vf[seg.val_off + k * Rp + r] * x[int(cols[k, r])]
        outs.append(s / df[seg.diag_off + r])
    xl = jnp.stack(outs)
    return jax.lax.dynamic_update_slice_in_dim(x, xl, seg.off, 0)


def make_packed_levelset_solver(
    layout: PackedLayout,
    *,
    unroll_threshold: int = 0,
    gather_unroll_max_k: int = GATHER_UNROLL_MAX_K,
):
    """Permuted-space level-set executor.

    Returns ``solve(b, values)`` with ``values = (vals_flat, diag_flat)`` as
    runtime buffers (see module docstring).  ``b`` may be ``(n,)`` or
    ``(n, m)``; the permute/un-permute happens exactly once at the
    boundaries regardless of segment count."""
    n, n_pad = layout.n, layout.n_pad
    cols_flat = jnp.asarray(layout.cols_flat)
    perm = jnp.asarray(layout.perm)
    pos = jnp.asarray(layout.pos)

    def solve(b: jnp.ndarray, values) -> jnp.ndarray:
        vals_flat, diag_flat = values
        dt = b.dtype
        vf = vals_flat.astype(dt)
        df = diag_flat.astype(dt)
        bhat = b[perm]
        if n_pad > n:
            bhat = jnp.concatenate(
                [bhat, jnp.zeros((n_pad - n,) + b.shape[1:], dt)])
        x = jnp.zeros((n_pad,) + b.shape[1:], dt)
        for seg in layout.segments:
            if seg.kind == "chain":
                x = _chain_segment(x, bhat, seg, cols_flat, vf, df,
                                   gather_unroll_max_k)
            elif seg.R <= unroll_threshold:
                x = _unrolled_segment(x, bhat, seg, layout, vf, df)
            else:
                x = _plain_segment(x, bhat, seg, cols_flat, vf, df,
                                   gather_unroll_max_k)
        return x[pos]

    return solve


# --------------------------------------------------------------------------
# Blocked (supernodal) packed layout
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PackedBlockSegment:
    """Geometry of one super-level inside the packed blocked buffers.

    The segment's real rows own permuted positions ``[off, off + R)``; its
    lane space is ``B * T`` block-major lanes, of which ``lane_idx`` are the
    real ones (the rest are padding).  ``val_off`` indexes the flat panel
    buffers (``K * B * T`` entries), ``dinv_off`` the flat dense-block
    buffers (``B * T * T`` entries)."""

    off: int
    R: int
    B: int
    T: int
    K: int
    val_off: int
    dinv_off: int
    lane_idx: np.ndarray      # (R,) int32


@dataclasses.dataclass(frozen=True)
class PackedBlockedLayout:
    """Permuted-space packed form of a
    :class:`~repro.core.coarsen.BlockSchedule`.

    Same contract as :class:`PackedLayout`: ``cols_flat`` holds permuted
    *positions*; ``vals_src`` (panel values) and ``diag_src`` (dense
    diagonal-block entries) map every packed value back into the target
    matrix's ``data`` array (−1 = padding / structural zero), so
    :func:`pack_blocked_values` re-packs both runtime buffers — including
    the batched block re-inversion — from new values alone.  ``pad_eye_flat``
    is the identity padding added before every inversion."""

    n: int
    nnz: int
    perm: np.ndarray
    pos: np.ndarray
    segments: tuple
    cols_flat: np.ndarray
    vals_flat: np.ndarray
    vals_src: np.ndarray
    dinv_flat: np.ndarray     # float64 inverted blocks, concatenated raveled
    diag_src: np.ndarray      # int64, aligned with dinv_flat
    pad_eye_flat: np.ndarray  # float64, aligned with dinv_flat

    def stats(self) -> PackedStats:
        item = self.vals_flat.itemsize
        pad = int((self.vals_src < 0).sum() + (self.diag_src < 0).sum())
        return PackedStats(
            permutation_applied=True,
            value_bytes=self.vals_flat.nbytes + self.dinv_flat.nbytes,
            index_bytes=self.cols_flat.nbytes,
            padded_value_bytes=pad * item,
            n_pad=self.n,
            num_segments=len(self.segments),
        )


def build_packed_blocked_layout(bsched) -> PackedBlockedLayout:
    """Lower a blocked schedule into permuted-space flat buffers: the
    blocked execution order (super-level by super-level, block-major)
    defines ``perm``; panel columns are remapped to positions once here."""
    n = bsched.n
    perm = bsched.perm()
    assert perm.size == n, (perm.size, n)
    pos = np.empty(n, dtype=np.int64)
    pos[perm] = np.arange(n, dtype=np.int64)
    pos32 = pos.astype(np.int32)

    segments = []
    cols_b, vals_b, vsrc_b, dinv_b, dsrc_b, eye_b = [], [], [], [], [], []
    off = voff = doff = 0
    dtype = (bsched.slabs[0].vals.dtype if bsched.slabs else np.float64)
    for slab in bsched.slabs:
        B, T, K, R = slab.B, slab.T, slab.K, slab.R
        lane_idx = np.nonzero(slab.lane_row < n)[0].astype(np.int32)
        segments.append(PackedBlockSegment(
            off=off, R=R, B=B, T=T, K=K, val_off=voff, dinv_off=doff,
            lane_idx=lane_idx))
        # padded panel lanes keep column 0 -> position pos[0]: its value is
        # 0 and x starts zero-filled, so the gather is a no-op everywhere
        cols_b.append(pos32[slab.cols].ravel())
        vals_b.append(slab.vals.ravel())
        vsrc_b.append(slab.val_src.ravel())
        dinv_b.append(slab.dinv.ravel())
        dsrc_b.append(slab.diag_src.ravel())
        eye_b.append(slab.pad_eye.ravel())
        off += R
        voff += K * B * T
        doff += B * T * T
    assert off == n, (off, n)

    def cat(blocks, dt):
        return (np.concatenate(blocks).astype(dt, copy=False) if blocks
                else np.zeros(0, dtype=dt))

    return PackedBlockedLayout(
        n=n, nnz=bsched.nnz, perm=perm, pos=pos, segments=tuple(segments),
        cols_flat=cat(cols_b, np.int32),
        vals_flat=cat(vals_b, dtype),
        vals_src=cat(vsrc_b, np.int64),
        dinv_flat=cat(dinv_b, np.float64),
        diag_src=cat(dsrc_b, np.int64),
        pad_eye_flat=cat(eye_b, np.float64),
    )


def pack_blocked_values(layout: PackedBlockedLayout, data: np.ndarray):
    """Re-pack the blocked runtime buffers for new ``data`` of the same
    pattern: one vectorized gather for the panel values, one gather +
    identity padding + batched ``np.linalg.inv`` (float64, host-side) for
    the dense diagonal blocks.  O(nnz + Σ B·T³) with no analysis and no
    executor re-trace — the compiled solve is reused outright."""
    vals = gather_src(data, layout.vals_src, 0.0, layout.vals_flat.dtype)
    dense = (gather_src(data, layout.diag_src, 0.0, np.float64)
             + layout.pad_eye_flat)
    dinv = np.empty_like(layout.dinv_flat)
    for seg in layout.segments:
        size = seg.B * seg.T * seg.T
        blk = dense[seg.dinv_off : seg.dinv_off + size].reshape(
            seg.B, seg.T, seg.T)
        try:
            inv = np.linalg.inv(blk)
        except np.linalg.LinAlgError:
            # A singular/non-finite diagonal block (zero pivot admitted via
            # refresh(validate=False)) must not abort the re-pack: invert
            # the healthy blocks, poison the broken ones with NaN so the
            # solve produces NaN rows a guarded solver's breakdown policy
            # can see and handle.
            inv = np.empty_like(blk)
            for i in range(blk.shape[0]):
                try:
                    inv[i] = np.linalg.inv(blk[i])
                except np.linalg.LinAlgError:
                    inv[i] = np.nan
        dinv[seg.dinv_off : seg.dinv_off + size] = inv.ravel()
    return jnp.asarray(vals), jnp.asarray(dinv)


def make_packed_blocked_solver(
    layout: PackedBlockedLayout,
    *,
    backend=None,
    kernel: str = "auto",
    gather_unroll_max_k: int = GATHER_UNROLL_MAX_K,
):
    """Permuted-space blocked (supernodal) executor.

    Returns ``solve(b, values)`` with ``values = (vals_flat, dinv_flat)`` as
    runtime buffers (from :func:`pack_blocked_values`).  Per super-level:
    one panel gather-sum, one batched dense diagonal-block apply
    (:func:`repro.kernels.trsm_block.ops.make_block_apply`), one contiguous
    ``dynamic_update_slice`` write.  ``b`` may be ``(n,)`` or ``(n, m)``."""
    from repro.kernels.trsm_block.ops import make_block_apply

    apply_blocks = make_block_apply(backend, kernel=kernel)
    n = layout.n
    cols_flat = jnp.asarray(layout.cols_flat)
    perm = jnp.asarray(layout.perm)
    pos = jnp.asarray(layout.pos)

    def solve(b: jnp.ndarray, values) -> jnp.ndarray:
        vals_flat, dinv_flat = values
        dt = b.dtype
        vf = vals_flat.astype(dt)
        dvf = dinv_flat.astype(dt)
        bhat = b[perm]
        x = jnp.zeros((n,) + b.shape[1:], dt)
        for seg in layout.segments:
            BT = seg.B * seg.T
            cols = _slice_seg(cols_flat, seg.val_off, seg.K * BT).reshape(
                seg.K, BT)
            vals = _slice_seg(vf, seg.val_off, seg.K * BT).reshape(
                seg.K, BT)
            s = _gather_sum(vals, cols, x, unroll_max_k=gather_unroll_max_k)
            bw = jax.lax.slice_in_dim(bhat, seg.off, seg.off + seg.R)
            lane = jnp.asarray(seg.lane_idx)
            rhs = jnp.zeros((BT,) + b.shape[1:], dt).at[lane].set(bw) - s
            dinv = _slice_seg(dvf, seg.dinv_off, BT * seg.T).reshape(
                seg.B, seg.T, seg.T)
            xb = apply_blocks(dinv, rhs.reshape((seg.B, seg.T) + b.shape[1:]))
            xl = xb.reshape((BT,) + b.shape[1:])[lane]
            x = jax.lax.dynamic_update_slice_in_dim(x, xl, seg.off, 0)
        return x[pos]

    return solve


def make_packed_serial_solver(L: CSRMatrix, *, upper: bool = False):
    """Serial ``lax.scan`` solver with the scan operands as runtime buffers.

    Returns ``(solve(b, values), values0, repack)`` — ``repack(new_data)``
    rebuilds ``values`` for new matrix values of the same pattern (the
    serial strategy has no permuted space to exploit, but refresh must not
    re-trace its scan either)."""
    cols, vals, diag, val_src, diag_src, order = serial_arrays(L, upper=upper)
    cols_d = jnp.asarray(cols[order])
    idx = jnp.asarray(order)

    def repack(data: np.ndarray):
        v = gather_src(data, val_src, 0.0, vals.dtype)
        d = np.asarray(data)[diag_src].astype(diag.dtype, copy=False)
        return jnp.asarray(v[order]), jnp.asarray(d[order])

    values0 = (jnp.asarray(vals[order]), jnp.asarray(diag[order]))

    def solve(b: jnp.ndarray, values) -> jnp.ndarray:
        vals_o, diag_o = values
        dt = b.dtype
        vals_l = vals_o.astype(dt)
        diag_l = diag_o.astype(dt)

        def body(x, inp):
            c, v, d, bi, i = inp
            s = jnp.sum(_coef(v, x) * x[c], axis=0)
            x = x.at[i].set((bi - s) / d)
            return x, ()

        x0 = jnp.zeros(b.shape, dtype=dt)
        x, _ = jax.lax.scan(body, x0, (cols_d, vals_l, diag_l, b[idx], idx))
        return x

    return solve, values0, repack


def make_packed_rhs_transform(res: RewriteResult):
    """``b' = E b`` with the ELL values as a runtime buffer.

    Returns ``(transform(b, e_vals), e_vals0, repack)`` where
    ``repack(e_data)`` re-packs new E values (from
    :func:`repro.core.rewrite.replay_rewrite_values`) into the buffer.
    When E is the identity (no rewrites survived the budgets) returns
    ``(None, None, None)`` — a no-op SpMV would still cost a dispatch and a
    packed buffer per solve."""
    if res.stats.e_nnz_offdiag == 0:
        return None, None, None
    ell = build_ell(res.E)
    cols = jnp.asarray(ell.cols)
    src = ell.val_src

    def transform(b: jnp.ndarray, e_vals: jnp.ndarray) -> jnp.ndarray:
        return _gather_sum(e_vals.astype(b.dtype), cols, b)

    def repack(e_data: np.ndarray):
        return jnp.asarray(gather_src(e_data, src, 0.0, ell.vals.dtype))

    return transform, jnp.asarray(ell.vals), repack
