"""Distributed SpTRSV over a mesh axis (beyond-paper, required at scale).

Rows of each segment are sharded across the ``data`` axis with ``shard_map``.
After a segment solves its rows, the newly computed ``x`` entries are
exchanged.  On a pod, **each segment boundary is one collective** — the
direct analogue of the paper's per-level CPU barrier.  Equation rewriting
reduces the number of levels and schedule coarsening merges the survivors,
so both shrink the collective count; §Perf of EXPERIMENTS.md measures
exactly this.

Two exchange strategies (hillclimb pair):

* ``psum``       — naive: every device scatters its solved rows into an
                   n-vector of zeros and a full ``psum`` combines them.
                   Bytes/segment = O(n).  Paper-faithful port of "barrier".
* ``all_gather`` — each device contributes only its R/ndev solved values;
                   bytes/segment = O(R_segment).  The optimized schedule.

Row ids are static host-known constants, so only solved *values* ever move
on the wire: the full row order each device needs after the exchange is
precomputed host-side in :func:`shard_schedule` (a ring ``all_gather(tiled)``
of contiguous row shards reproduces the slab's own row array), and each
device slices its shard out of the replicated constant with
``lax.axis_index`` — there is no runtime collective over index arrays.

Coarsened slabs (``depth > 1``, :mod:`repro.core.coarsen`) execute
**replicated**: every device redundantly computes the whole intra-slab chain
(thin levels are latency-bound, so the redundant FLOPs are noise) and the
solution stays consistent on all devices with **zero** collectives for those
slabs — a run of thin levels that used to cost one collective per level now
costs none.

Transpose solves (``SpTRSV.build(L, transpose=True, strategy="distributed")``)
flow through unchanged: a backward :class:`Schedule` packs columns of L over
the reverse level sets, and sharding/collectives are schedule-agnostic —
the collective count equals the number of *sharded backward segments*.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map

from .codegen import GATHER_UNROLL_MAX_K, Schedule, _gather_sum, stack_sub_slabs
from .packed import PackedLayout, build_packed_layout, pack_values

__all__ = [
    "DistributedSchedule",
    "shard_schedule",
    "make_distributed_solver",
    "build_packed_dist_layout",
    "make_packed_distributed_solver",
]


@dataclasses.dataclass(frozen=True)
class DistributedSchedule:
    """Per-segment slabs.

    Sharded segments are padded so the row dimension splits evenly over the
    mesh axis; padding rows are no-ops (col 0 / val 0 / diag 1) writing to
    the scratch slot ``n`` of the x vector (length n+1).  Replicated
    segments (coarsened chains) hold the uniform *stacked* sub-slab arrays
    of :func:`repro.core.codegen.stack_sub_slabs` — ``rows (d, Rmax)``,
    ``cols/vals (d, K, Rmax)``, ``diag (d, Rmax)`` — executed as one
    ``fori_loop`` per chain, same as the levelset/pallas executors, so the
    traced program holds one body per chain rather than one per wavefront.
    ``rows`` of a sharded segment is the **full** row order — the host-side
    precomputed gather order; devices never exchange indices.
    """

    n: int
    ndev: int
    rows: List[np.ndarray]   # (R_pad,) sharded / (d, Rmax) replicated; pad -> n
    cols: List[np.ndarray]   # (K, R_pad) sharded / (d, K, Rmax) replicated
    vals: List[np.ndarray]
    diag: List[np.ndarray]
    replicated: List[bool]   # True: executed redundantly, no collective

    @property
    def num_levels(self) -> int:
        return len(self.rows)

    @property
    def num_collectives(self) -> int:
        """Collectives per solve — sharded segments only (replicated chains
        exchange nothing; row ids never move)."""
        return sum(not r for r in self.replicated)

    def collective_bytes(self, itemsize: int = 4, strategy: str = "all_gather",
                         batch: int = 1) -> int:
        """Predicted on-wire bytes per solve (per device, ring all-gather):
        the §Roofline collective term for the distributed solver.  Counts
        what actually moves: solved values of *sharded* segments only —
        replicated segments exchange nothing, and row ids are static
        host-side constants (they used to ride an extra runtime
        ``all_gather`` per level).  A batched solve multiplies the payload
        by ``batch`` but keeps the collective *count* fixed — latency-bound
        thin levels amortize over columns."""
        if strategy == "psum":
            return self.num_collectives * 2 * (self.n + 1) * batch * itemsize
        return sum(r.size * batch * itemsize
                   for r, rep in zip(self.rows, self.replicated) if not rep)


def _pad_to(x: np.ndarray, size: int, fill) -> np.ndarray:
    pad = size - x.shape[-1]
    if pad == 0:
        return x
    width = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return np.pad(x, width, constant_values=fill)


def shard_schedule(schedule: Schedule, ndev: int) -> DistributedSchedule:
    rows, cols, vals, diag, replicated = [], [], [], [], []
    for slab in schedule.slabs:
        if slab.depth > 1:
            # coarsened chain: replicated execution (stacked uniform
            # sub-slabs, fori_loop'd per device), no exchange
            r_s, c_s, v_s, d_s = stack_sub_slabs(slab, schedule.n)
            rows.append(r_s)
            cols.append(c_s)
            vals.append(v_s)
            diag.append(d_s)
            replicated.append(True)
            continue
        rpad = int(np.ceil(slab.R / ndev) * ndev)
        rows.append(_pad_to(slab.rows.astype(np.int32), rpad, schedule.n))
        cols.append(_pad_to(slab.cols, rpad, 0))
        vals.append(_pad_to(slab.vals, rpad, 0.0))
        diag.append(_pad_to(slab.diag, rpad, 1.0))
        replicated.append(False)
    return DistributedSchedule(
        n=schedule.n, ndev=ndev, rows=rows, cols=cols, vals=vals, diag=diag,
        replicated=replicated,
    )


def make_distributed_solver(
    dsched: DistributedSchedule,
    mesh: Mesh,
    axis: str = "data",
    *,
    strategy: str = "all_gather",
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Build a jit-able distributed level-set solve(b) over ``mesh[axis]``.

    x is replicated (n+1, scratch slot last); per sharded segment each
    device solves an R/ndev shard of rows and the solved values are
    exchanged — values only: the device's row shard is a static slice
    (``lax.axis_index``) of the replicated host-precomputed row order, and
    the post-exchange scatter uses that same constant, so the index
    ``all_gather`` that used to run every level of every solve is gone.
    Replicated (coarsened) segments run their whole chain on every device
    with no collective at all.

    ``b`` may be ``(n,)`` or batched ``(n, m)``: the batch axis rides through
    the shard_map region unsharded (columns are independent systems), so the
    per-segment collective moves ``R * m`` values instead of ``R`` — the
    collective *count* (the paper's barrier analogue) is unchanged while the
    per-solve payload amortizes over the batch.
    """
    assert strategy in ("all_gather", "psum")
    n = dsched.n
    ndev = dsched.ndev
    # Per-segment constants, device-side.  Sharded segments split their slabs
    # over the axis; rows stay replicated everywhere (static gather order).
    cols_d = [jnp.asarray(c) for c in dsched.cols]
    vals_d = [jnp.asarray(v) for v in dsched.vals]
    diag_d = [jnp.asarray(d) for d in dsched.diag]
    rows_d = [jnp.asarray(r) for r in dsched.rows]
    rep = list(dsched.replicated)

    in_specs = (
        P(),  # b (replicated)
        [P() if r else P(None, axis) for r in rep],  # cols (K, R)
        [P() if r else P(None, axis) for r in rep],  # vals
        [P() if r else P(axis) for r in rep],        # diag
        [P()] * dsched.num_levels,                   # rows: always replicated
    )

    def _solve(b, cols, vals, diag, rows):
        dt = b.dtype
        batched = b.ndim == 2
        bx = jnp.concatenate([b, jnp.zeros((1,) + b.shape[1:], dt)])  # scratch
        x = jnp.zeros((n + 1,) + b.shape[1:], dt)
        me = jax.lax.axis_index(axis)
        for lv in range(len(cols)):
            v = vals[lv].astype(dt)
            d = diag[lv].astype(dt)
            if rep[lv]:
                # coarsened chain, replicated on every device: one fori_loop
                # over the stacked sub-slabs (deterministic => consistent x,
                # no exchange; pad rows write the scratch slot n) — the
                # traced program holds one body per chain, not one per level
                def chain_body(t, xc, _r=rows[lv], _c=cols[lv], _v=v, _d=d):
                    d_t = _d[t][:, None] if batched else _d[t]
                    s = _gather_sum(_v[t], _c[t], xc)
                    return xc.at[_r[t]].set((bx[_r[t]] - s) / d_t)

                x = jax.lax.fori_loop(0, rows[lv].shape[0], chain_body, x)
                x = x.at[n].set(0.0)
                continue
            if batched:
                d = d[:, None]
            shard = rows[lv].shape[0] // ndev
            rows_me = jax.lax.dynamic_slice_in_dim(rows[lv], me * shard, shard)
            s = _gather_sum(v, cols[lv], x)             # (R/ndev[, m])
            xl = (bx[rows_me] - s) / d
            if strategy == "all_gather":
                # values only; the gathered row order is the replicated
                # constant rows[lv] (host-precomputed)
                xg = jax.lax.all_gather(xl, axis, tiled=True)        # (R[, m])
                x = x.at[rows[lv]].set(xg)
            else:  # psum: full-vector exchange — the naive barrier port
                contrib = jnp.zeros_like(x).at[rows_me].set(xl)
                x = x + jax.lax.psum(contrib, axis)
            x = x.at[n].set(0.0)  # clear pad-row scratch writes
        return x[:n]

    fn = shard_map(
        _solve,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=P(),
        check_vma=False,
    )

    def solve(b: jnp.ndarray) -> jnp.ndarray:
        return fn(b, cols_d, vals_d, diag_d, rows_d)

    return solve


# ==========================================================================
# Permuted-space packed distributed solver (refresh-capable)
# ==========================================================================
def build_packed_dist_layout(schedule: Schedule, ndev: int) -> PackedLayout:
    """Packed layout whose sharded segments are row-padded to a multiple of
    the mesh axis size (chains execute replicated and need no alignment)."""
    return build_packed_layout(
        schedule,
        pad_rows=lambda r: int(np.ceil(r / ndev) * ndev),
        pad_chain_rows=lambda r: r,
    )


def make_packed_distributed_solver(
    layout: PackedLayout,
    mesh: Mesh,
    axis: str = "data",
    *,
    strategy: str = "all_gather",
    gather_unroll_max_k: int = GATHER_UNROLL_MAX_K,
):
    """Permuted-space distributed solve over ``mesh[axis]``.

    Identical exchange structure to :func:`make_distributed_solver` — one
    value ``all_gather`` (or ``psum``) per *sharded* segment, replicated
    chains exchange nothing — but executed in permuted space: ``b`` is
    permuted once on entry, each device solves a contiguous shard of its
    segment's positions, and the gathered window lands with one
    ``dynamic_update_slice`` at a static offset (the per-segment row-id
    scatter and its replicated row-order constants are gone entirely).

    Returns ``(solve(b, values), values0, repack)``: the per-segment value
    arrays ride as runtime arguments, so ``SpTRSV.refresh`` swaps them
    (via ``repack(new_target_data)``) without re-tracing the shard_map."""
    assert strategy in ("all_gather", "psum")
    n, n_pad = layout.n, layout.n_pad
    ndev = int(np.prod([mesh.shape[a] for a in (axis,)]))
    segs = layout.segments

    def _seg_slices(flat, kind):
        """Per-segment views of one flat buffer, honoring that buffer's own
        offset field (``val_off``/``col_off``/``diag_off``)."""
        out = []
        for s in segs:
            if kind == "diag":
                a = flat[s.diag_off: s.diag_off + s.diag_size]
                shape = (s.depth, s.R_pad) if s.kind == "chain" else (s.R_pad,)
            else:
                off = s.val_off if kind == "val" else s.col_off
                a = flat[off: off + s.val_size]
                shape = ((s.depth, s.K, s.R_pad) if s.kind == "chain"
                         else (s.K, s.R_pad))
            out.append(a.reshape(shape))
        return out

    def _seg_arrays(vals_flat, diag_flat):
        return (_seg_slices(vals_flat, "val"), _seg_slices(diag_flat, "diag"))

    vals_h, diag_h = _seg_arrays(layout.vals_flat, layout.diag_flat)
    cols_d = tuple(jnp.asarray(c)
                   for c in _seg_slices(layout.cols_flat, "col"))
    values0 = (tuple(jnp.asarray(v) for v in vals_h),
               tuple(jnp.asarray(d) for d in diag_h))
    perm_d = jnp.asarray(layout.perm)
    pos_d = jnp.asarray(layout.pos)

    def repack(target_data: np.ndarray):
        vf, df = pack_values(layout, target_data)
        vs, ds = _seg_arrays(vf, df)
        return (tuple(jnp.asarray(v) for v in vs),
                tuple(jnp.asarray(d) for d in ds))

    rep = [s.kind == "chain" for s in segs]
    in_specs = (
        P(),                                              # b (replicated)
        P(),                                              # perm
        P(),                                              # pos
        tuple(P() if r else P(None, axis) for r in rep),  # vals
        tuple(P() if r else P(axis) for r in rep),        # diag
        tuple(P() if r else P(None, axis) for r in rep),  # cols (positions)
    )

    def _solve(b, perm, pos, vals_t, diag_t, cols_t):
        dt = b.dtype
        batched = b.ndim == 2
        bhat = b[perm]
        if n_pad > n:
            bhat = jnp.concatenate(
                [bhat, jnp.zeros((n_pad - n,) + b.shape[1:], dt)])
        x = jnp.zeros((n_pad,) + b.shape[1:], dt)
        me = jax.lax.axis_index(axis)
        for i, seg in enumerate(segs):
            v = vals_t[i].astype(dt)
            d = diag_t[i].astype(dt)
            c = cols_t[i]
            if rep[i]:
                # coarsened chain, replicated on every device: deterministic
                # => consistent x, zero collectives (pad lanes write forward
                # into positions their owners overwrite before any read)
                sub = jnp.asarray(seg.sub_offs)
                Rp = seg.R_pad

                def chain_body(t, xc, _c=c, _v=v, _d=d, _sub=sub, _Rp=Rp):
                    s = _gather_sum(_v[t], _c[t], xc,
                                    unroll_max_k=gather_unroll_max_k)
                    o = _sub[t]
                    bw = jax.lax.dynamic_slice_in_dim(bhat, o, _Rp)
                    dd = _d[t][:, None] if batched else _d[t]
                    xl = (bw - s) / dd
                    return jax.lax.dynamic_update_slice_in_dim(xc, xl, o, 0)

                x = jax.lax.fori_loop(0, seg.depth, chain_body, x)
                continue
            shard = seg.R_pad // ndev
            if batched:
                d = d[:, None]
            s = _gather_sum(v, c, x, unroll_max_k=gather_unroll_max_k)
            bw = jax.lax.dynamic_slice_in_dim(bhat, seg.off + me * shard, shard)
            xl = (bw - s) / d
            if strategy == "all_gather":
                # values only, in position order — the gathered window IS
                # the segment's contiguous permuted-space slice
                win = jax.lax.all_gather(xl, axis, tiled=True)  # (R_pad[, m])
            else:  # psum: full-vector exchange — the naive barrier port
                lane = me * shard + jnp.arange(shard)
                mask = lane < seg.R
                xl = jnp.where(mask[:, None] if batched else mask, xl, 0)
                contrib = jnp.zeros_like(x)
                contrib = jax.lax.dynamic_update_slice_in_dim(
                    contrib, xl, seg.off + me * shard, 0)
                summed = jax.lax.psum(contrib, axis)
                win = jax.lax.slice_in_dim(
                    summed, seg.off, seg.off + seg.R_pad)
            x = jax.lax.dynamic_update_slice_in_dim(x, win, seg.off, 0)
        return x[pos]

    fn = shard_map(
        _solve,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=P(),
        check_vma=False,
    )

    def solve(b: jnp.ndarray, values) -> jnp.ndarray:
        vals_t, diag_t = values
        return fn(b, perm_d, pos_d, vals_t, diag_t, cols_d)

    return solve, values0, repack
