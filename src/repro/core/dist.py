"""Distributed SpTRSV over a mesh axis (beyond-paper, required at scale).

Rows of each level are sharded across the ``data`` axis with ``shard_map``.
After a level solves its rows, the newly computed ``x`` entries are exchanged.
On a pod, **each level boundary is one collective** — the direct analogue of
the paper's per-level CPU barrier.  Equation rewriting reduces the number of
levels and therefore the number of collectives; §Perf of EXPERIMENTS.md
measures exactly this.

Two exchange strategies (hillclimb pair):

* ``psum``       — naive: every device scatters its solved rows into an
                   n-vector of zeros and a full ``psum`` combines them.
                   Bytes/level = O(n).  Paper-faithful port of "barrier".
* ``all_gather`` — each device contributes only its R/ndev solved values;
                   bytes/level = O(R_level).  The optimized schedule.

Transpose solves (``SpTRSV.build(L, transpose=True, strategy="distributed")``)
flow through unchanged: a backward :class:`Schedule` packs columns of L over
the reverse level sets, and sharding/collectives are schedule-agnostic —
the collective count equals the number of *backward* levels.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map

from .codegen import Schedule, LevelSlab, _gather_sum

__all__ = ["DistributedSchedule", "shard_schedule", "make_distributed_solver"]


@dataclasses.dataclass(frozen=True)
class DistributedSchedule:
    """Per-level slabs padded so the row dimension splits evenly over the
    mesh axis.  Padding rows are no-ops (col 0 / val 0 / diag 1) writing to
    the scratch slot ``n`` of the x vector (length n+1)."""

    n: int
    ndev: int
    rows: List[np.ndarray]   # (R_pad,) per level, pad -> n (scratch slot)
    cols: List[np.ndarray]   # (K, R_pad)
    vals: List[np.ndarray]
    diag: List[np.ndarray]

    @property
    def num_levels(self) -> int:
        return len(self.rows)

    def collective_bytes(self, itemsize: int = 4, strategy: str = "all_gather",
                         batch: int = 1) -> int:
        """Predicted on-wire bytes per solve (per device, ring all-gather):
        the §Roofline collective term for the distributed solver.  A batched
        solve multiplies the payload by ``batch`` but keeps the collective
        *count* fixed — latency-bound thin levels amortize over columns."""
        if strategy == "psum":
            return self.num_levels * 2 * (self.n + 1) * batch * itemsize
        return sum(r.size * batch * itemsize for r in self.rows)


def _pad_to(x: np.ndarray, size: int, fill) -> np.ndarray:
    pad = size - x.shape[-1]
    if pad == 0:
        return x
    width = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return np.pad(x, width, constant_values=fill)


def shard_schedule(schedule: Schedule, ndev: int) -> DistributedSchedule:
    rows, cols, vals, diag = [], [], [], []
    for slab in schedule.slabs:
        rpad = int(np.ceil(slab.R / ndev) * ndev)
        rows.append(_pad_to(slab.rows.astype(np.int32), rpad, schedule.n))
        cols.append(_pad_to(slab.cols, rpad, 0))
        vals.append(_pad_to(slab.vals, rpad, 0.0))
        diag.append(_pad_to(slab.diag, rpad, 1.0))
    return DistributedSchedule(
        n=schedule.n, ndev=ndev, rows=rows, cols=cols, vals=vals, diag=diag
    )


def make_distributed_solver(
    dsched: DistributedSchedule,
    mesh: Mesh,
    axis: str = "data",
    *,
    strategy: str = "all_gather",
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Build a jit-able distributed level-set solve(b) over ``mesh[axis]``.

    x is replicated (n+1, scratch slot last); per level each device solves an
    R/ndev shard of rows and the solved values are exchanged.

    ``b`` may be ``(n,)`` or batched ``(n, m)``: the batch axis rides through
    the shard_map region unsharded (columns are independent systems), so the
    per-level collective moves ``R * m`` values instead of ``R`` — the
    collective *count* (the paper's barrier analogue) is unchanged while the
    per-solve payload amortizes over the batch.
    """
    assert strategy in ("all_gather", "psum")
    n = dsched.n
    ndev = dsched.ndev
    # Per-level constants, device-side. Row-shard the slabs over the axis.
    cols_d = [jnp.asarray(c) for c in dsched.cols]
    vals_d = [jnp.asarray(v) for v in dsched.vals]
    diag_d = [jnp.asarray(d) for d in dsched.diag]
    rows_d = [jnp.asarray(r) for r in dsched.rows]

    in_specs = (
        P(),  # b (replicated)
        [P(None, axis)] * dsched.num_levels,  # cols (K, R)
        [P(None, axis)] * dsched.num_levels,  # vals
        [P(axis)] * dsched.num_levels,        # diag
        [P(axis)] * dsched.num_levels,        # rows
    )

    def _solve(b, cols, vals, diag, rows):
        dt = b.dtype
        batched = b.ndim == 2
        bx = jnp.concatenate([b, jnp.zeros((1,) + b.shape[1:], dt)])  # scratch
        x = jnp.zeros((n + 1,) + b.shape[1:], dt)
        for lv in range(len(cols)):
            v = vals[lv].astype(dt)
            d = diag[lv].astype(dt)
            if batched:
                d = d[:, None]
            s = _gather_sum(v, cols[lv], x)             # (R/ndev[, m])
            xl = (bx[rows[lv]] - s) / d
            if strategy == "all_gather":
                xg = jax.lax.all_gather(xl, axis, tiled=True)        # (R[, m])
                rg = jax.lax.all_gather(rows[lv], axis, tiled=True)  # (R,)
                x = x.at[rg].set(xg)
            else:  # psum: full-vector exchange — the naive barrier port
                contrib = jnp.zeros_like(x).at[rows[lv]].set(xl)
                x = x + jax.lax.psum(contrib, axis)
            x = x.at[n].set(0.0)  # clear pad-row scratch writes
        return x[:n]

    fn = shard_map(
        _solve,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=P(),
        check_vma=False,
    )

    def solve(b: jnp.ndarray) -> jnp.ndarray:
        return fn(b, cols_d, vals_d, diag_d, rows_d)

    return solve
