"""Synchronization-aware schedule coarsening + cost-model strategy planner.

The paper removes barriers by *rewriting equations* so thin levels empty
out.  This module applies the complementary lever (Böhnlein et al.,
arXiv:2503.05408): *merge* adjacent levels under a cost model instead of
changing the matrix.  A run of (mostly thin) levels becomes one **super-level
slab** carrying an intra-slab dependency chain (``LevelSlab.sub_rows``): the
sub-slabs execute back-to-back inside a single segment — one generated code
region / kernel launch / collective — so a lung2-class schedule collapses
from ~478 segments to a few dozen while the floating-point work per row is
**unchanged** (same gather-sum, same operands, same order; only zero padding
is added).  Results are typically bit-identical and always within a few ulp
of the uncoarsened executor — XLA may re-contract the zero-padded reduction
(FMA / tree shape) when compiling the merged segment.

Cost model
----------
Executing a slab costs ``segment_cost`` (launch + barrier + its share of XLA
program size / compile time, in FLOP-equivalents) plus its padded FLOPs.  A
merged group of ``d`` levels executes ``d`` uniform sub-steps padded to the
widest member — FLOP waste ``d*(2*Kmax*Rmax + Rmax) - sum_i work_i`` — but
pays ``segment_cost`` once instead of ``d`` times.  The greedy pass extends a
group while the waste stays below the segments saved.  Thin runs (R=2) merge
essentially for free; a fat wavefront next to a thin run is rejected because
padding every sub-step to the fat width would dwarf the saved barriers.

Strategy planner
----------------
:func:`plan_strategy` picks serial / levelset / levelset_unroll /
pallas_fused for ``SpTRSV.build(..., strategy="auto")`` from the
:class:`~repro.core.analysis.MatrixAnalysis` and schedule cost — chains go to
the ``lax.scan`` serial solver, level-parallel matrices to the (coarsened)
level-set executors, VMEM-sized matrices on a real TPU to the fused kernel.

Pricing is **backend-aware**: the model's coefficients (launch cost, gather
throughput, lane width, serial-scan cost, whether/how a fused single-dispatch
solve exists) come from the per-device calibration table in
:mod:`repro.core.calibrate`, keyed by the resolved
:class:`repro.kernels.backend.KernelBackend` — there are no hard-coded
platform checks in the planner.  The keys that differ across families:
``launch_cost`` (a GPU kernel launch is the barrier and is pricier than a
TPU grid step), ``gather_cost`` (relative padded-FLOP price),
``fused_max_rows`` (0 on cpu — pallas has no compiled CPU lowering;
VMEM-bounded on TPU; GMEM-bounded on GPU) and ``fused_num_launches``
(``"one"`` TPU sequential grid vs ``"per_level"`` GPU span walk).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from .analysis import MatrixAnalysis
from .calibrate import BackendCalibration, get_calibration
from .codegen import LevelSlab, Schedule, slab_padded_flops
from .csr import CSRMatrix
from .levels import Supernodes, _propagate_levels

__all__ = [
    "CoarsenConfig",
    "CoarsenStats",
    "coarsen_schedule",
    "coarsen_stats",
    "schedule_cost",
    "BlockSlab",
    "BlockSchedule",
    "build_block_schedule",
    "PlanDecision",
    "RewriteCandidate",
    "SweepCandidate",
    "BlockedCandidate",
    "blocked_candidate",
    "plan_strategy",
    "should_consider_rewrite",
    "SEGMENT_COST",
    "SUBSTEP_COST",
    "SERIAL_STEP_COST",
    "SERIAL_STEP_COST_SCALE",
]

# Cost of one barrier-separated segment, in FLOP-equivalents: dispatch of a
# gather/FMA/scatter group plus its share of program size.  Microseconds of
# launch/sync overhead at ~1 GFLOP/s effective SpTRSV throughput lands in the
# low thousands; the exact value only needs to separate "thin level" (work
# ~10 flops) from "fat level" (work >> segment_cost).
SEGMENT_COST = 4096.0

# Cost of one intra-chain sub-step (a fori_loop iteration: dynamic-slice of
# the stacked constants + the gather/FMA/scatter body).  Cheaper than a full
# segment — no barrier, no new program region — but not free; without this
# term the model would happily chain a fat wavefront onto a thin run and pay
# its padded width once per sub-step.
SUBSTEP_COST = SEGMENT_COST / 2

# Cost of one lax.scan step of the serial solver, FLOP-equivalents.  Rows of
# the serial scan are latency- not throughput-bound, and the measured
# per-row cost GROWS with n (the scan carries the whole x vector, so big
# systems fall out of cache): ~60ns/row at n=1.5k but ~5us/row at n=33k on
# CPU.  Modelled as base + scale*n per row — small systems legitimately
# solve fastest serially, large ones never do.
SERIAL_STEP_COST = 16.0
SERIAL_STEP_COST_SCALE = 0.06


@dataclasses.dataclass(frozen=True)
class CoarsenConfig:
    """Knobs of the coarsening cost model.

    ``max_depth``       longest intra-slab chain (bounds stacked-constant
                        memory ``d * K * Rmax`` and fori_loop trip count)
    ``max_chain_rows``  widest slab allowed inside a chain.  Chains exist to
                        absorb *thin* levels; a fat wavefront executes its
                        full width once per chained sub-step it rides along
                        with, which the flop terms under-bill when its K is
                        small (level-0 fat slabs have K=1), so wide slabs
                        always stand alone as plain parallel segments.
    ``segment_cost``    launch/sync/program-size cost per segment,
                        FLOP-equivalents (see :data:`SEGMENT_COST`)
    ``step_cost``       per-sub-step chain overhead (:data:`SUBSTEP_COST`)
    """

    max_depth: int = 32
    max_chain_rows: int = 128
    segment_cost: float = SEGMENT_COST
    step_cost: float = SUBSTEP_COST


@dataclasses.dataclass(frozen=True)
class CoarsenStats:
    segments_before: int
    segments_after: int
    padded_flops_before: int
    padded_flops_after: int

    @property
    def segment_reduction(self) -> float:
        return self.segments_before / max(self.segments_after, 1)

    def summary(self) -> str:
        return (
            f"segments {self.segments_before} -> {self.segments_after} "
            f"({self.segment_reduction:.1f}x fewer sync points), "
            f"padded FLOPs {self.padded_flops_before} -> "
            f"{self.padded_flops_after} "
            f"(+{100 * (self.padded_flops_after / max(self.padded_flops_before, 1) - 1):.1f}%)"
        )


def _slab_work(s: LevelSlab, unroll_threshold: int) -> float:
    """Executed FLOPs of one slab — the same per-slab formula
    ``Schedule.padded_flops`` sums, so merge decisions and planner costs
    can never drift apart."""
    return float(slab_padded_flops(s, unroll_threshold))


def _merge_group(group: list) -> LevelSlab:
    """Concatenate a group of plain slabs into one super-slab.  Sub-slab t
    keeps its exact packing (row order, values); only zero padding up to the
    group-wide K is added, so executors consume the identical operand sets
    the uncoarsened slabs would."""
    if len(group) == 1:
        return group[0]
    K = max(s.K for s in group)
    R = sum(s.R for s in group)
    rows = np.concatenate([s.rows for s in group]).astype(np.int32)
    diag = np.concatenate([s.diag for s in group])
    cols = np.zeros((K, R), dtype=np.int32)
    vals = np.zeros((K, R), dtype=group[0].vals.dtype)
    with_src = all(s.val_src is not None for s in group)
    val_src = np.full((K, R), -1, dtype=np.int64) if with_src else None
    diag_src = (np.concatenate([s.diag_src for s in group])
                if with_src else None)
    off = 0
    for s in group:
        cols[: s.K, off : off + s.R] = s.cols
        vals[: s.K, off : off + s.R] = s.vals
        if with_src:
            val_src[: s.K, off : off + s.R] = s.val_src
        off += s.R
    return LevelSlab(rows=rows, cols=cols, vals=vals, diag=diag,
                     sub_rows=tuple(s.R for s in group),
                     val_src=val_src, diag_src=diag_src)


def coarsen_schedule(
    schedule: Schedule,
    config: CoarsenConfig = CoarsenConfig(),
    *,
    unroll_threshold: int = 0,
) -> Schedule:
    """Greedy synchronization-aware level merging.

    Walks the slab sequence in order (any prefix-respecting grouping is
    correct: slab order is a topological order of the dependency DAG, and a
    chain over slabs that happen to be independent is merely conservative).
    A slab joins the open group iff the group's merged execution cost —
    ``d * (2*Kmax*Rmax + Rmax)`` for ``d`` uniform chained sub-steps — does
    not exceed executing it separately plus the ``segment_cost`` the merge
    saves.  Already-coarsened slabs pass through untouched (idempotent).
    """
    slabs = schedule.slabs
    if len(slabs) <= 1 or config.max_depth <= 1:
        return schedule
    out: list = []
    group: list = []
    g_kmax = g_rmax = 0

    def flush():
        nonlocal group, g_kmax, g_rmax
        if group:
            out.append(_merge_group(group))
        group, g_kmax, g_rmax = [], 0, 0

    for s in slabs:
        # pre-coarsened input and fat wavefronts stay their own segments
        if s.depth > 1 or s.R > config.max_chain_rows:
            flush()
            out.append(s)
            continue
        if group:
            d2 = len(group) + 1
            k2 = max(g_kmax, s.K)
            r2 = max(g_rmax, s.R)
            merged = d2 * (2 * k2 * r2 + r2 + config.step_cost)
            prev_merged = len(group) * (
                2 * g_kmax * g_rmax + g_rmax + config.step_cost)
            separate = prev_merged + _slab_work(s, unroll_threshold) \
                + config.segment_cost
            if d2 <= config.max_depth and merged <= separate:
                group.append(s)
                g_kmax, g_rmax = k2, r2
                continue
            flush()
        group = [s]
        g_kmax, g_rmax = s.K, s.R
    flush()
    return Schedule(n=schedule.n, slabs=out,
                    level_of_row=schedule.level_of_row, nnz=schedule.nnz)


def coarsen_stats(before: Schedule, after: Schedule,
                  unroll_threshold: int = 0) -> CoarsenStats:
    return CoarsenStats(
        segments_before=before.num_segments,
        segments_after=after.num_segments,
        padded_flops_before=before.padded_flops(unroll_threshold),
        padded_flops_after=after.padded_flops(unroll_threshold),
    )


# --------------------------------------------------------------------------
# Blocked (supernodal) schedule: the node-granular generalization
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BlockSlab:
    """One super-level of the blocked schedule: ``B`` mutually independent
    supernodes executed as a batched dense diagonal-block apply plus a padded
    ELL panel update.

    Every block is padded to the level-wide ``T = max block size``; lanes are
    block-major (lane ``bi*T + t`` is row ``t`` of block ``bi``; padded lanes
    carry the sentinel row id ``n``).  The diagonal blocks are stored as
    *inverses* (``x_blk = D⁻¹ (b_blk − Panel · x_prev)``) so the solve is a
    batched GEMM rather than a per-block substitution; padded diagonal lanes
    hold an identity so the batched inverse is well-defined.

    ``blocks``    (B,) supernode ids
    ``rows``      (R,) original row ids, block-major, real rows only
    ``sizes``     (B,) rows per block
    ``dinv``      (B, T, T) float64 inverted diagonal blocks
    ``diag_src``  (B, T, T) int64 source position in ``L.data`` of each dense
                  in-block entry, −1 for structural zeros / padding — the
                  value-only ``refresh`` map for the dense blocks
    ``pad_eye``   (B, T, T) float64 identity on padded diagonal lanes (added
                  before every inversion, build and refresh alike)
    ``cols``      (K, B*T) int32 off-block dependency columns (0-padded)
    ``vals``      (K, B*T) off-block values
    ``val_src``   (K, B*T) int64 source positions in ``L.data``, −1 for pads
    ``lane_row``  (B*T,) int64 original row id per lane, ``n`` for padding
    """

    blocks: np.ndarray
    rows: np.ndarray
    sizes: np.ndarray
    dinv: np.ndarray
    diag_src: np.ndarray
    pad_eye: np.ndarray
    cols: np.ndarray
    vals: np.ndarray
    val_src: np.ndarray
    lane_row: np.ndarray

    @property
    def B(self) -> int:
        return self.dinv.shape[0]

    @property
    def T(self) -> int:
        return self.dinv.shape[1]

    @property
    def R(self) -> int:
        return len(self.rows)

    @property
    def K(self) -> int:
        return self.cols.shape[0]


@dataclasses.dataclass(frozen=True)
class BlockSchedule:
    """Supernodal (blocked) execution schedule: super-levels of dense
    diagonal blocks + off-diagonal panels.  The scalar-row level-set schedule
    is exactly this structure with every block of size 1 — node granularity
    is the only thing that changed."""

    n: int
    nnz: int
    slabs: tuple
    level_of_block: np.ndarray
    supernodes: Supernodes

    @property
    def num_segments(self) -> int:
        return len(self.slabs)

    @property
    def num_blocks(self) -> int:
        return sum(s.B for s in self.slabs)

    def perm(self) -> np.ndarray:
        """Original row id at each position of the blocked execution order
        (super-level by super-level, block-major)."""
        if not self.slabs:
            return np.zeros(0, dtype=np.int64)
        return np.concatenate([s.rows for s in self.slabs]).astype(np.int64)

    def panel_flops(self) -> int:
        """Padded FLOPs of the off-block panel updates (gather-sum over K
        ELL lanes + the RHS subtract)."""
        return sum(2 * s.K * s.B * s.T + s.B * s.T for s in self.slabs)

    def gemm_flops(self) -> int:
        """Dense FLOPs of the batched diagonal-block applies."""
        return sum(2 * s.T * s.T * s.B for s in self.slabs)


def build_block_schedule(
    M: CSRMatrix, supernodes: Supernodes, *, upper: bool = False
) -> BlockSchedule:
    """Build the blocked schedule of a triangular CSR from a supernode
    partition: level the *block-granular* dependency DAG (edge ``sb -> db``
    for any off-block entry coupling the two supernodes), then pack each
    super-level into a :class:`BlockSlab`.

    Correctness never depends on the partition — any contiguous run of rows
    is a valid block (its off-block dependencies are entirely outside the row
    span on the solved side) — so a degenerate all-singleton partition simply
    reproduces the scalar level-set structure with T=1 blocks."""
    n = M.n
    bp = supernodes.block_ptr
    block_of = supernodes.super_of_row
    nb = supernodes.num_supernodes
    indptr, indices, data = M.indptr, M.indices, M.data
    if nb == 0:
        return BlockSchedule(n=n, nnz=M.nnz, slabs=(),
                             level_of_block=np.zeros(0, np.int64),
                             supernodes=supernodes)
    row_of = np.repeat(np.arange(n, dtype=np.int64), M.row_nnz())
    strict = (indices > row_of) if upper else (indices < row_of)
    src_b = block_of[indices[strict]]
    dst_b = block_of[row_of[strict]]
    cross = src_b != dst_b
    edge_keys = np.unique(src_b[cross] * nb + dst_b[cross])
    blevel = _propagate_levels(nb, edge_keys // nb, edge_keys % nb)
    num_levels = int(blevel.max()) + 1 if nb else 0
    order = np.argsort(blevel, kind="stable")
    counts = np.bincount(blevel, minlength=num_levels)
    slabs = []
    off = 0
    for lv in range(num_levels):
        blocks = np.sort(order[off : off + int(counts[lv])])
        off += int(counts[lv])
        sizes = (bp[blocks + 1] - bp[blocks]).astype(np.int64)
        B = len(blocks)
        T = int(sizes.max())
        BT = B * T
        dense = np.zeros((B, T, T), np.float64)
        diag_src = np.full((B, T, T), -1, np.int64)
        pad_eye = np.zeros((B, T, T), np.float64)
        lane_row = np.full(BT, n, np.int64)
        offs = []           # (lane, off-block cols, off-block data positions)
        K = 1
        for bi, k in enumerate(blocks):
            r0, r1 = int(bp[k]), int(bp[k + 1])
            for t, r in enumerate(range(r0, r1)):
                lo, hi = int(indptr[r]), int(indptr[r + 1])
                c = indices[lo:hi]
                pos = np.arange(lo, hi, dtype=np.int64)
                inb = (c >= r0) & (c < r1)
                ci = c[inb] - r0
                dense[bi, t, ci] = data[lo:hi][inb]
                diag_src[bi, t, ci] = pos[inb]
                lane = bi * T + t
                lane_row[lane] = r
                cofs = c[~inb]
                offs.append((lane, cofs, pos[~inb]))
                K = max(K, len(cofs))
            for t in range(r1 - r0, T):
                pad_eye[bi, t, t] = 1.0
        # batched inversion in float64 — padded lanes are identity, so the
        # inverse exists whenever the diagonal does
        dinv = np.linalg.inv(dense + pad_eye)
        cols = np.zeros((K, BT), np.int32)
        vals = np.zeros((K, BT), dtype=M.data.dtype)
        val_src = np.full((K, BT), -1, np.int64)
        for lane, cofs, pofs in offs:
            kk = len(cofs)
            cols[:kk, lane] = cofs
            vals[:kk, lane] = data[pofs]
            val_src[:kk, lane] = pofs
        rows = np.concatenate(
            [np.arange(bp[k], bp[k + 1], dtype=np.int64) for k in blocks])
        slabs.append(BlockSlab(
            blocks=blocks, rows=rows, sizes=sizes, dinv=dinv,
            diag_src=diag_src, pad_eye=pad_eye, cols=cols, vals=vals,
            val_src=val_src, lane_row=lane_row))
    return BlockSchedule(n=n, nnz=M.nnz, slabs=tuple(slabs),
                         level_of_block=blevel, supernodes=supernodes)


# --------------------------------------------------------------------------
# Transform planner
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PlanDecision:
    """Outcome of :func:`plan_strategy` — recorded on the built solver so
    ``strategy="auto"`` choices are auditable.

    ``strategy``  executor picked (serial / levelset / levelset_unroll /
                  pallas_fused / sweep / blocked)
    ``coarsen``   whether schedule coarsening is applied to the winner
    ``rewrite``   rewrite-policy tag ("thin" / "critical_path") when the
                  planner chose to transform the matrix first, else None
    ``costs``     every candidate's modelled per-solve cost; transform
                  combinations are keyed ``<strategy>+rewrite:<tag>+coarsen``
    ``sweep_k``   planned sweep count when the sync-free speculative
                  executor won (``strategy == "sweep"``), else None
    """

    strategy: str
    coarsen: bool
    reason: str
    costs: Dict[str, float]
    rewrite: Optional[str] = None
    sweep_k: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class RewriteCandidate:
    """A priced rewrite alternative handed to :func:`plan_strategy`: the
    schedule of the rewritten system L', its coarsened counterpart, and the
    modelled per-solve cost of the RHS transform ``b' = E b`` (one padded
    ELL SpMV plus one extra dispatch) — the fill-vs-parallelism price of the
    transformation."""

    schedule: Schedule
    coarsened: Optional[Schedule]
    rhs_cost: float


@dataclasses.dataclass(frozen=True)
class SweepCandidate:
    """A priced sync-free sweep alternative handed to :func:`plan_strategy`.

    ``k`` is the sweep count the convergence model certifies
    (:func:`repro.core.sweep.planned_sweeps` — ``min(depth, ⌈log tol /
    log q⌉)`` capped at the configured budget; the caller passes a candidate
    only when that bound lands).  ``ell_k`` is the off-diagonal ELL lane
    width of the whole-matrix ``D + N`` split, ``contraction`` the factor
    ``q = ‖D⁻¹N‖_∞`` — recorded so the decision's reason line is
    auditable."""

    k: int
    ell_k: int
    n: int
    contraction: float


@dataclasses.dataclass(frozen=True)
class BlockedCandidate:
    """A priced supernodal-blocked alternative handed to
    :func:`plan_strategy`, summarizing a built :class:`BlockSchedule`: one
    barrier per super-level, gathered panel FLOPs priced at ``gather_cost``,
    dense diagonal-block FLOPs at the (cheaper) ``gemm_cost``, and a fixed
    ``trsm_cost`` overhead per diagonal block."""

    segments: int
    panel_flops: int
    gemm_flops: int
    num_blocks: int
    supernode_count: int
    mean_block_size: float


def blocked_candidate(bsched: BlockSchedule) -> BlockedCandidate:
    """Pricing summary of a built blocked schedule."""
    sn = bsched.supernodes
    return BlockedCandidate(
        segments=bsched.num_segments,
        panel_flops=bsched.panel_flops(),
        gemm_flops=bsched.gemm_flops(),
        num_blocks=bsched.num_blocks,
        supernode_count=sn.num_supernodes,
        mean_block_size=sn.mean_block_size,
    )


def schedule_cost(schedule: Schedule, *, unroll_threshold: int = 0,
                  segment_cost: float = SEGMENT_COST,
                  step_cost: float = SUBSTEP_COST,
                  flop_cost: float = 1.0) -> float:
    """Modelled per-solve cost of a level-set schedule: executed (padded)
    FLOPs (scaled by the backend's relative ``flop_cost``), per-segment
    launch/sync overhead, and per-chain-sub-step loop overhead for coarsened
    slabs."""
    return (flop_cost * schedule.padded_flops(unroll_threshold)
            + segment_cost * schedule.num_segments
            + step_cost * (schedule.total_depth - schedule.num_segments))


# Spellings plan_strategy accepts for ``backend=`` beyond the calibration
# families themselves: jax platform aliases and the interpret backends
# (which execute on the host and are priced as cpu).
_CALIBRATION_KEY = {
    "cuda": "gpu",
    "rocm": "gpu",
    "interpret": "cpu",
    "interpret:tpu": "cpu",
    "interpret:gpu": "cpu",
}


def _plan_target(backend, interpret):
    """Resolve plan_strategy's ``backend=``/``interpret=`` knobs to
    ``(label, calibration_key, interpret_flag)``.

    ``backend`` may be a resolved :class:`~repro.kernels.backend.KernelBackend`
    (the solver path), a spec string (``cpu``/``tpu``/``gpu``/``cuda``/
    ``rocm``/``interpret``/``interpret:gpu``), or None — which reads
    ``jax.default_backend()``.  A ``cpu`` target is always *priced* as cpu
    even with ``interpret=False``: there is no compiled pallas path on a CPU
    host to price differently."""
    from repro.kernels.backend import KernelBackend

    if isinstance(backend, KernelBackend):
        return backend.name, backend.calibration_key, backend.interpret
    if backend is None:
        import jax

        backend = jax.default_backend()
    label = str(backend).lower()
    key = _CALIBRATION_KEY.get(label, label)
    if key not in ("cpu", "tpu", "gpu"):
        raise ValueError(
            f"unknown planner backend {backend!r}; expected a KernelBackend "
            f"or one of {sorted(('cpu', 'tpu', 'gpu', *_CALIBRATION_KEY))}")
    if interpret is None:
        # named hardware → its compiled lowerings; cpu → the interpreter
        # (the only way pallas executes there)
        interpret = key == "cpu"
    return label, key, interpret


def should_consider_rewrite(analysis: MatrixAnalysis) -> bool:
    """Gate for pricing rewrite candidates inside ``strategy="auto"``:
    equation rewriting targets barrier-dominated schedules with substantial
    thin-level content (the paper's lung2 pathology).  Chain-like matrices
    (levels ~ n) are excluded — the serial scan wins those outright and a
    speculative rewrite of a pure chain just burns fill budget — as are
    schedules too shallow to have barriers worth removing."""
    return (analysis.num_levels >= 8
            and analysis.num_levels <= 0.6 * analysis.n
            and analysis.thin_fraction_2 >= 0.25)


def plan_strategy(
    analysis: MatrixAnalysis,
    schedule: Schedule,
    coarsened: Optional[Schedule] = None,
    *,
    unroll_threshold: int = 4,
    segment_cost: Optional[float] = None,
    backend=None,
    interpret: Optional[bool] = None,
    calibration: Optional[BackendCalibration] = None,
    rewritten: Optional[Dict[str, RewriteCandidate]] = None,
    sweep: Optional[SweepCandidate] = None,
    blocked: Optional[BlockedCandidate] = None,
    precision: str = "native",
) -> PlanDecision:
    """Pick an execution strategy *and matrix transformation* from the
    analysis + schedule cost model.

    ``schedule`` is the uncoarsened schedule of the untransformed system;
    ``coarsened`` its coarsened counterpart when coarsening is on the table.
    ``rewritten`` maps rewrite-policy tags to priced
    :class:`RewriteCandidate` alternatives — rewriting shortens the chain
    (fewer segments on the rewritten schedule) but pays fill (that
    schedule's padded FLOPs) plus the per-solve RHS transform; coarsening
    removes syncs but pays padding.  ``sweep`` prices the sync-free
    speculative executor when its convergence model certifies a sweep count
    (see :class:`SweepCandidate`): ``k`` fused whole-matrix updates plus one
    verification pass, ONE dispatch total — the only candidate whose
    sync-point term does not scale with the level structure at all.  All
    combinations are priced with the same launch-cost/padded-FLOP model, so
    *rewrite vs coarsen vs both vs sweeps* is one ``min()`` over ``costs``.

    Pricing coefficients come from the per-backend calibration table
    (:mod:`repro.core.calibrate`), selected by ``backend`` — a resolved
    :class:`~repro.kernels.backend.KernelBackend`, a spec string, or None
    for ``jax.default_backend()``.  ``calibration`` overrides the table row
    (tests / measured micro-runs); an explicit ``segment_cost`` overrides
    just the launch-cost coefficient.  The fused kernel is a candidate only
    where the calibration says a compiled fused dispatch exists
    (``fused_max_rows > 0``, i.e. never on cpu) and the target is not the
    interpreter — interpret mode is a correctness harness, never a
    performance win; the cost below models the compiled kernel.

    ``precision="mixed"`` prices the guard's bf16-storage mode: every
    gather-bound term is scaled by the backend's ``mixed_gather_discount``
    (value-stream bytes halve; launch and dispatch terms do not), so the
    planner can shift toward gather-bound candidates when the caller
    requested mixed-precision execution.
    """
    backend, cal_key, interpret = _plan_target(backend, interpret)
    cal = calibration if calibration is not None         else get_calibration(cal_key)
    if precision == "mixed":
        # bf16 value storage (guard precision="mixed") halves the value-
        # stream bytes of every gather-bound term; the calibrated discount
        # reflects how much of the gather stream is values vs indices on
        # this backend.  Launch/TRSM/serial-step terms are unaffected —
        # mixed precision cheapens bandwidth, not dispatches.
        cal = dataclasses.replace(
            cal, gather_cost=cal.gather_cost * cal.mixed_gather_discount)
    seg_cost = cal.launch_cost if segment_cost is None else segment_cost

    costs: Dict[str, float] = {}
    # serial lax.scan: one segment, but every row is a latency-bound scan
    # step whose cost grows with the carried vector size.  Transforms never
    # help the scan (rewrite only adds work to it), so serial is priced on
    # the untransformed system only.
    costs["serial"] = analysis.solve_flops + analysis.n * (
        cal.serial_step_cost + cal.serial_step_cost_scale * analysis.n)

    def _levelset_costs(suffix: str, sched: Schedule,
                        co: Optional[Schedule], extra: float) -> None:
        costs[f"levelset{suffix}"] = extra + schedule_cost(
            sched, unroll_threshold=0, segment_cost=seg_cost,
            step_cost=cal.substep_cost, flop_cost=cal.gather_cost)
        costs[f"levelset_unroll{suffix}"] = extra + schedule_cost(
            sched, unroll_threshold=unroll_threshold,
            segment_cost=seg_cost, step_cost=cal.substep_cost,
            flop_cost=cal.gather_cost)
        if co is not None:
            costs[f"levelset{suffix}+coarsen"] = extra + schedule_cost(
                co, unroll_threshold=0, segment_cost=seg_cost,
                step_cost=cal.substep_cost, flop_cost=cal.gather_cost)
            costs[f"levelset_unroll{suffix}+coarsen"] = extra + schedule_cost(
                co, unroll_threshold=unroll_threshold,
                segment_cost=seg_cost, step_cost=cal.substep_cost,
                flop_cost=cal.gather_cost)

    def _fused_cost(suffix: str, sched: Schedule, extra: float) -> None:
        if interpret or analysis.n > cal.fused_max_rows:
            return
        # whole solve in one fused-layout dispatch: padded work bounded by
        # the widest slab's K over all (lane-padded) rows.  The launch term
        # is calibration-shaped: one sequential-grid dispatch on TPU, one
        # launch per wavefront span on GPU.
        kmax = max((s.K for s in sched.slabs), default=1)
        lane = max(cal.lane_width, 1)
        n_pad = -(-analysis.n // lane) * lane
        launches = (sched.total_depth
                    if cal.fused_num_launches == "per_level" else 1)
        costs[f"pallas_fused{suffix}"] = (
            extra + cal.gather_cost * (2 * kmax * n_pad + analysis.n)
            + seg_cost * launches)

    _levelset_costs("", schedule, coarsened, 0.0)
    _fused_cost("", schedule, 0.0)
    for tag, cand in (rewritten or {}).items():
        _levelset_costs(f"+rewrite:{tag}", cand.schedule, cand.coarsened,
                        cand.rhs_cost)
        _fused_cost(f"+rewrite:{tag}", cand.schedule, cand.rhs_cost)
    if sweep is not None:
        # k sweeps + 1 verification pass, each one fused ELL gather-sum over
        # all rows (2*K*n FMA-ish flops + n divides), one dispatch total.
        # The verification readback is the solve's single sync point.
        costs["sweep"] = cal.gather_cost * (sweep.k + 1) * (
            2 * sweep.ell_k * sweep.n + sweep.n) + seg_cost
    if blocked is not None:
        # one barrier per super-level; panel updates are gathered ELL work,
        # diagonal-block applies are contiguous dense flops at the backend's
        # gemm price plus a fixed per-block dispatch overhead
        costs["blocked"] = (
            seg_cost * blocked.segments
            + cal.gather_cost * blocked.panel_flops
            + cal.gemm_cost * blocked.gemm_flops
            + cal.trsm_cost * blocked.num_blocks)

    best = min(costs, key=costs.get)
    parts = best.split("+")
    strategy = parts[0]
    rewrite_tag = next((p[len("rewrite:"):] for p in parts
                        if p.startswith("rewrite:")), None)
    decision = PlanDecision(
        strategy=strategy,
        coarsen="coarsen" in parts,
        rewrite=rewrite_tag,
        sweep_k=sweep.k if (sweep is not None and strategy == "sweep")
        else None,
        reason=(
            # critical_fraction is deliberately NOT formatted here: it is a
            # lazy O(num_levels) computation and the reason line is built on
            # every auto plan, chains included
            f"min modelled cost {costs[best]:.0f} among "
            + ", ".join(f"{k}={v:.0f}" for k, v in sorted(costs.items()))
            + f" (n={analysis.n}, levels={analysis.num_levels}, "
            f"thin_fraction={analysis.thin_fraction_2:.2f}, backend={backend}"
            + (f", precision=mixed(gather x{cal.mixed_gather_discount:g})"
               if precision == "mixed" else "")
            + ")"
        ),
        costs=costs,
    )
    return decision
