"""Guarded execution layer: verify → refine → fallback for every strategy.

The transformed-graph solves divide by eliminated pivots, the packed refresh
path re-uses a compiled schedule with arbitrary new values, and the
speculative / mixed-precision executors are inexact by construction — all of
which can go numerically wrong with no visible failure.  Until this module,
only ``strategy="sweep"`` verified its result; every other executor returned
whatever the kernel produced.  :class:`SolveGuard` makes verified,
self-correcting execution available to ANY built solver:

**Verify.**  One fused componentwise residual pass per solve — the same
``L = D + N`` ELL split and backward-error ratio the sweep executor uses
(:func:`repro.core.sweep.residual_terms`), evaluated against the ORIGINAL
system, so rewrite replay and E-SpMV fill errors are covered end-to-end.
The ratio readback is the guard's single host synchronization point.

**Refine.**  Iterative refinement ``x += solve(r)`` up to
``GuardConfig.refine_steps``: the residual is computed in the work dtype
(fp64 for fp64 RHS) even when the inner solve runs lower precision, which is
what lets a bf16-storage solve recover fp64-class accuracy.  A step is kept
only if the worst finite ratio improves, so divergence or a NaN inner solve
cannot make the answer worse.

**Breakdown policies** (``on_breakdown``): columns still above tolerance
after refinement are handled per policy — ``"refine"`` returns the best
iterate and records the breakdown, ``"fallback"`` re-solves the failed
RHS columns with a lazily built exact solver (pivot-repaired when the value
scan raised an alarm) and splices them in exactly like the sweep executor's
correction, ``"raise"`` raises :class:`GuardBreakdownError`.  A cheap O(nnz)
value scan at build/refresh time (finiteness + zero/sub-``pivot_tol``
pivots) feeds the same policies before a single solve runs.

**Mixed precision** (``precision="mixed"``, threaded through
``SpTRSV.build(..., guard=GuardConfig(precision="mixed"))``): the packed
off-diagonal value buffer is stored in bf16 — half the value-stream bytes,
priced by the calibration table so ``strategy="auto"`` can prefer it on
gather-bound slabs — while the diagonal / inverted-diagonal buffer stays
fp32 and accumulation runs in fp32.  Keeping the diagonal at fp32 matters:
the refinement error-iteration matrix ``(A − Ã)Ã⁻¹`` is triangular with
diagonal equal to the *relative diagonal storage error*, so fp32 diagonal
storage contracts the error ~1e-3–1e-4 per step (3 steps to fp64 tolerance
on a lung2-class factor) where bf16 diagonals stall near 4e-3 per step.
The diagonal is O(n) of O(nnz) total, so the byte saving lives where the
bytes are.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .csr import CSRMatrix
from .sweep import (build_sweep_layout, default_residual_tol, pack_sweep_values,
                    residual_terms)

__all__ = [
    "GuardConfig",
    "GuardStats",
    "GuardBreakdownError",
    "GUARD_BREAKDOWN_POLICIES",
    "GUARD_FALLBACK_STRATEGIES",
    "GUARD_PRECISIONS",
    "scan_values",
    "repair_pivots",
    "SolveGuard",
]

logger = logging.getLogger(__name__)

GUARD_BREAKDOWN_POLICIES = ("refine", "fallback", "raise")
GUARD_PRECISIONS = ("native", "mixed")
# Exact strategies the guard may lazily fall back to.  Host-schedulable
# everywhere (no accelerator-gated kernels) and exact by construction.
GUARD_FALLBACK_STRATEGIES = ("serial", "levelset", "levelset_unroll")


class GuardBreakdownError(RuntimeError):
    """Raised (under ``on_breakdown="raise"``) when a guarded build, refresh
    or solve hits a breakdown: non-finite matrix values, zero/sub-tolerance
    pivots, or a residual still above tolerance after refinement.

    ``columns`` (when solve-time) lists the failing RHS column indices;
    ``ratio`` is the worst componentwise residual ratio observed."""

    def __init__(self, message: str, *, columns=None, ratio=None):
        super().__init__(message)
        self.columns = columns
        self.ratio = ratio


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """Knobs of the guarded execution layer.

    ``residual_tol``  componentwise residual-ratio acceptance threshold;
                      ``None`` → ``128·eps`` of the RHS dtype
                      (:func:`repro.core.sweep.default_residual_tol`)
    ``refine_steps``  max iterative-refinement steps per solve (each is one
                      extra inner solve; a step is kept only if the worst
                      finite ratio improves)
    ``on_breakdown``  policy for columns above tolerance after refinement
                      and for build/refresh value-scan alarms:
                      ``"refine"`` best-effort + stats, ``"fallback"``
                      per-column exact re-solve + splice, ``"raise"``
                      :class:`GuardBreakdownError`
    ``fallback``      exact strategy the ``"fallback"`` policy builds lazily
                      (one of :data:`GUARD_FALLBACK_STRATEGIES`)
    ``precision``     ``"native"`` keeps the built dtype; ``"mixed"`` stores
                      packed off-diagonal values in bf16 + diagonal in fp32,
                      accumulates in fp32, and relies on refinement against
                      the full-precision residual (requires
                      ``layout="permuted"``)
    ``pivot_tol``     relative pivot alarm threshold for the O(nnz) value
                      scan: pivots with ``|d| <= pivot_tol · max|d|`` (or
                      exactly zero / non-finite, always) trip the breakdown
                      policy at build/refresh time
    """

    residual_tol: Optional[float] = None
    refine_steps: int = 2
    on_breakdown: str = "refine"
    fallback: str = "levelset"
    precision: str = "native"
    pivot_tol: float = 0.0

    def __post_init__(self):
        assert self.refine_steps >= 0, self.refine_steps
        assert self.on_breakdown in GUARD_BREAKDOWN_POLICIES, self.on_breakdown
        assert self.fallback in GUARD_FALLBACK_STRATEGIES, self.fallback
        assert self.precision in GUARD_PRECISIONS, self.precision
        assert self.pivot_tol >= 0.0, self.pivot_tol


@dataclasses.dataclass
class GuardStats:
    """Live guard accounting (mutated by :meth:`SolveGuard.solve`).

    ``refine_steps_total`` / ``last_refine_steps`` count refinement inner
    solves; ``fallback_solves`` solves where the exact fallback fired and
    ``fallback_columns`` the RHS columns it replaced; ``breakdown_columns``
    columns that stayed above tolerance after the policy ran (best-effort
    answers); ``pivot_alarms`` build/refresh value-scan trips;
    ``last_residual_ratio`` the worst componentwise ratio of the most recent
    solve — the observable the guard benchmark asserts on."""

    precision: str = "native"
    solves: int = 0
    verified: int = 0
    refine_steps_total: int = 0
    last_refine_steps: int = 0
    fallback_solves: int = 0
    fallback_columns: int = 0
    breakdown_columns: int = 0
    raised: int = 0
    pivot_alarms: int = 0
    last_residual_ratio: float = 0.0

    def report(self) -> dict:
        return dataclasses.asdict(self)


def scan_values(data, diag_src, *, pivot_tol: float = 0.0):
    """O(nnz) value health scan: ``(nonfinite, bad_pivots)`` counts.

    ``diag_src`` indexes the diagonal entries inside ``data``.  A pivot is
    bad when non-finite, exactly zero, or (with ``pivot_tol > 0``) at or
    below ``pivot_tol`` times the largest finite pivot magnitude."""
    data = np.asarray(data)
    nonfinite = int(data.size - np.count_nonzero(np.isfinite(data)))
    d = data[np.asarray(diag_src)]
    dabs = np.abs(d)
    fin = np.isfinite(d)
    ref = float(dabs[fin].max()) if fin.any() else 0.0
    floor = pivot_tol * ref
    bad = int(np.count_nonzero(~fin | (dabs <= floor) | (d == 0)))
    return nonfinite, bad


def repair_pivots(data, diag_src, *, pivot_tol: float = 0.0):
    """Static pivot perturbation (the SuperLU trick): replace non-finite,
    zero, and sub-tolerance pivots with ``±floor`` so an exact fallback on
    the repaired system produces finite, refinable answers even when the
    original factor is structurally broken.  ``floor`` is
    ``max(pivot_tol, √eps) · max finite |pivot|`` with the sign of the
    original pivot (positive for zero/NaN pivots).  Non-finite off-diagonal
    values are zeroed.  Returns ``(repaired_data, n_repaired)``."""
    data = np.array(data, copy=True)
    diag_src = np.asarray(diag_src)
    bad_vals = ~np.isfinite(data)
    data[bad_vals] = 0.0
    d = data[diag_src]
    dabs = np.abs(d)
    pos = dabs[dabs > 0]
    ref = float(pos.max()) if pos.size else 1.0
    eps = float(np.finfo(data.dtype).eps) if np.issubdtype(
        data.dtype, np.floating) else float(np.finfo(np.float64).eps)
    floor = max(pivot_tol, np.sqrt(eps)) * ref
    bad = (dabs <= floor)
    sign = np.where(d < 0, -1.0, 1.0)
    data[diag_src[bad]] = (sign * floor)[bad]
    n_rep = int(bad.sum()) + int(bad_vals.sum() - bad_vals[diag_src].sum())
    return data, n_rep


def _worst_finite(ratio_h: np.ndarray) -> float:
    """Worst ratio over refinable (finite-ratio) columns — loop control for
    the refinement iteration.  NaN/inf columns (non-finite solutions) are
    excluded here so one poisoned RHS column cannot stop the others from
    refining; they are handled by the breakdown policy instead."""
    fin = ratio_h[np.isfinite(ratio_h)]
    return float(fin.max()) if fin.size else 0.0


class SolveGuard:
    """Wraps an inner ``solve(b) -> x`` callable with residual verification,
    iterative refinement, and breakdown handling (see module docstring).

    ``system``           the ORIGINAL triangular factor the result must
                         satisfy (pre-rewrite — end-to-end verification)
    ``upper``            whether ``system`` is solved as its transpose
                         (``Lᵀ x = b``)
    ``inner_solve``      the wrapped solve pipeline (RHS transform included)
    ``fallback_builder`` ``builder(data) -> solve`` building an exact solver
                         for the same pattern with (possibly repaired)
                         ``data``; required for ``on_breakdown="fallback"``

    The guard wrapper is a host function (like the sweep solver's): the
    ratio readback is its one synchronization point per solve, and the
    residual checker itself is a single jitted fused pass.  The solve and
    the check stay TWO dispatches deliberately: jitting them together lets
    XLA fuse the check's SpMV into the per-level solve consumers and
    recompute it level by level, which measures several times slower on CPU
    than the extra launch costs."""

    def __init__(self, system: CSRMatrix, *, upper: bool,
                 config: GuardConfig,
                 inner_solve: Callable,
                 fallback_builder: Optional[Callable] = None,
                 jit: bool = True):
        self.config = config
        self.stats = GuardStats(precision=config.precision)
        self._inner = inner_solve
        self._fallback_builder = fallback_builder
        self._fb: Optional[Callable] = None
        self._layout = build_sweep_layout(system, upper=upper)
        self._cols = jnp.asarray(self._layout.ell.cols)
        self._values = (jnp.asarray(self._layout.ell.vals),
                        jnp.asarray(self._layout.diag))
        self._sys_data = np.asarray(system.data)
        self._pivot_alarm = False

        def check(b, x, values):
            vals, diag = values
            return residual_terms(b, x, vals, diag, self._cols)

        self._check = jax.jit(check) if jit else check
        self._scan("build")

    # ------------------------------------------------------------------
    # build/refresh-time value health
    # ------------------------------------------------------------------
    def _scan(self, where: str) -> None:
        nonfinite, bad_pivots = scan_values(
            self._sys_data, self._layout.diag_src,
            pivot_tol=self.config.pivot_tol)
        self._pivot_alarm = bool(nonfinite or bad_pivots)
        if not self._pivot_alarm:
            return
        self.stats.pivot_alarms += 1
        msg = (f"{nonfinite} non-finite value(s) and {bad_pivots} "
               f"zero/sub-tolerance pivot(s) detected at {where}")
        if self.config.on_breakdown == "raise":
            self.stats.raised += 1
            raise GuardBreakdownError(f"guard: {msg}")
        logger.warning("guard: %s — policy %r handles it at solve time",
                       msg, self.config.on_breakdown)

    def refresh(self, sys_data) -> None:
        """Re-pack the full-precision residual buffers and re-run the value
        scan after a value swap (``SpTRSV.refresh`` calls this).  The lazy
        fallback is dropped so a later breakdown rebuilds it against the new
        values."""
        self._sys_data = np.asarray(sys_data)
        self._values = pack_sweep_values(self._layout, self._sys_data)
        self._fb = None
        self._scan("refresh")

    # ------------------------------------------------------------------
    # solve-time policy machinery
    # ------------------------------------------------------------------
    def _fallback_solve(self) -> Callable:
        if self._fb is None:
            data = self._sys_data
            if self._pivot_alarm:
                data, n_rep = repair_pivots(
                    data, self._layout.diag_src,
                    pivot_tol=self.config.pivot_tol)
                logger.warning(
                    "guard: building exact fallback with %d repaired "
                    "pivot/value(s)", n_rep)
            self._fb = self._fallback_builder(data)
        return self._fb

    def solve(self, b: jnp.ndarray) -> jnp.ndarray:
        cfg = self.config
        stats = self.stats
        b = jnp.asarray(b)
        work_dt = b.dtype
        tol = (cfg.residual_tol if cfg.residual_tol is not None
               else default_residual_tol(work_dt))
        # mixed: inner solves accumulate in fp32; the residual/refinement
        # loop stays in the work dtype (fp64 for fp64 RHS), which is what
        # recovers full accuracy from the low-precision value storage.
        # Native mode calls the inner solve directly — an eager same-dtype
        # astype still dispatches, and at small n those two dispatches cost
        # more than the residual check itself.
        if cfg.precision == "mixed":
            def run(v):
                return self._inner(v.astype(jnp.float32)).astype(work_dt)
        else:
            run = self._inner

        x = run(b)
        r, ratio = self._check(b, x, self._values)
        stats.solves += 1
        ratio_h = np.atleast_1d(np.asarray(ratio))
        worst = _worst_finite(ratio_h)
        steps = 0
        while ((worst > tol or not np.all(np.isfinite(ratio_h)))
               and steps < cfg.refine_steps):
            dx = run(r)
            x2 = x + dx
            r2, ratio2 = self._check(b, x2, self._values)
            ratio2_h = np.atleast_1d(np.asarray(ratio2))
            steps += 1
            w2 = _worst_finite(ratio2_h)
            improved = (w2 < worst
                        or (np.count_nonzero(np.isfinite(ratio2_h))
                            > np.count_nonzero(np.isfinite(ratio_h))))
            if not improved:
                break
            x, r, ratio_h, worst = x2, r2, ratio2_h, w2
        stats.refine_steps_total += steps
        stats.last_refine_steps = steps
        stats.last_residual_ratio = float(
            np.max(np.nan_to_num(ratio_h, nan=np.inf)))
        ok = ratio_h <= tol  # NaN/inf compare False → not ok
        if bool(np.all(ok)):
            stats.verified += 1
            return x
        nbad = int(ok.size - np.count_nonzero(ok))
        if cfg.on_breakdown == "raise":
            stats.raised += 1
            raise GuardBreakdownError(
                f"guard: {nbad}/{ok.size} column(s) above residual tol "
                f"{tol:.1e} after {steps} refinement step(s) "
                f"(worst {stats.last_residual_ratio:.1e})",
                columns=np.flatnonzero(~ok), ratio=stats.last_residual_ratio)
        if cfg.on_breakdown == "fallback" and self._fallback_builder is not None:
            xf = jnp.asarray(self._fallback_solve()(b)).astype(work_dt)
            stats.fallback_solves += 1
            stats.fallback_columns += nbad
            if x.ndim == 1:
                x = xf
            else:
                # keep verified columns, splice exact re-solves in
                x = jnp.where(jnp.asarray(ok)[None, :], x, xf)
            _, ratio3 = self._check(b, x, self._values)
            ratio_h = np.atleast_1d(np.asarray(ratio3))
            stats.last_residual_ratio = float(
                np.max(np.nan_to_num(ratio_h, nan=np.inf)))
            ok = ratio_h <= tol
            if bool(np.all(ok)):
                stats.verified += 1
                return x
            nbad = int(ok.size - np.count_nonzero(ok))
        stats.breakdown_columns += nbad
        logger.warning(
            "guard: %d/%d column(s) above residual tol %.1e after policy "
            "%r (worst %.1e) — returning best effort",
            nbad, ok.size, tol, cfg.on_breakdown, stats.last_residual_ratio)
        return x
