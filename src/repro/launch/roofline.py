"""Roofline-term extraction from a compiled dry-run artifact.

    compute    = HLO_FLOPs / peak_FLOPs            (per chip)
    memory     = HLO_bytes / HBM_bw                (per chip)
    collective = wire_bytes(ring model) / link_bw  (per chip)

``cost_analysis`` supplies FLOPs / bytes-accessed of the partitioned
per-device module.  Collective bytes are NOT in cost_analysis: we parse the
optimized HLO text and apply a ring cost model per op:

    all-gather       (g-1)/g * result_bytes
    reduce-scatter   (g-1)/g * operand_bytes
    all-reduce       2 (g-1)/g * operand_bytes
    all-to-all       (g-1)/g * operand_bytes
    collective-permute   operand_bytes

with g = replica-group size parsed from the op's ``replica_groups``.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

__all__ = ["HW", "Roofline", "collective_bytes", "analyze_compiled",
           "model_flops"]

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 49e9  # ~50 GB/s/link


@dataclasses.dataclass
class HW:
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    link_bw: float = LINK_BW


_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """bytes of 'bf16[128,1024]' (tuple types: sum of components)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def collective_bytes(hlo_text: str, num_devices: int) -> Dict[str, float]:
    """Per-device wire bytes by collective kind (ring model), plus op counts."""
    out = {k: 0.0 for k in _COLL_OPS}
    counts = {k: 0 for k in _COLL_OPS}
    for line in hlo_text.splitlines():
        ls = line.lstrip()
        # match '%x = TYPE op-name(' — exclude -start/-done fragments double count
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)", ls)
        if not m:
            continue
        op = m.group(2)
        base = op.replace("-start", "")
        if base not in _COLL_OPS or op.endswith("-done"):
            continue
        result_t = m.group(1)
        g = _group_size(line, num_devices)
        if g <= 1:
            continue
        rb = _shape_bytes(result_t)
        if base == "all-gather":
            wire = (g - 1) / g * rb
        elif base == "all-reduce":
            wire = 2 * (g - 1) / g * rb          # result == operand size
        elif base == "reduce-scatter":
            wire = (g - 1) * rb                  # operand = g * result
        elif base == "all-to-all":
            wire = (g - 1) / g * rb
        else:  # collective-permute
            wire = rb
        out[base] += wire
        counts[base] += 1
    out["total"] = sum(out[k] for k in _COLL_OPS)
    out["counts"] = counts
    return out


def analytic_flop_correction(cfg, shape) -> float:
    """Global FLOPs hidden inside never-unrolled scans (cost_analysis counts
    a while body once).  Only the sLSTM timestep recurrence qualifies: its
    block-diagonal recurrent matmuls run T iterations.  Per sLSTM layer:
    4 gates × 2 FLOP × B × S × D × dh."""
    n_slstm = sum(1 for k in cfg.kinds() if k == "slstm")
    if not n_slstm:
        return 0.0
    B = shape.global_batch
    S = shape.seq_len if shape.kind != "decode" else 1
    dh = cfg.d_model // cfg.n_state_heads
    return float(n_slstm) * 8.0 * B * S * cfg.d_model * dh


def model_flops(cfg, shape) -> float:
    """6·N_active·D reference FLOPs for the cell (per step, global)."""
    n_active = cfg.active_params_B() * 1e9
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n_active * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n_active * toks
    return 2.0 * n_active * shape.global_batch      # decode: one token


def analyze_compiled(compiled, num_devices: int, hw: HW = HW()) -> dict:
    """Extract the three roofline terms (seconds, per chip).

    Primary numbers come from the trip-count-aware HLO parser
    (``launch.hlo_parse``): XLA's own ``cost_analysis`` counts while bodies
    once, under-reporting any scanned program.  cost_analysis is kept as a
    cross-check field (``xla_cost``)."""
    from .hlo_parse import parse_module

    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    mc = parse_module(hlo, num_devices)
    flops = max(mc.dot_flops, xla_flops)
    nbytes = max(mc.hbm_bytes, xla_bytes)
    coll = dict(mc.collective)
    coll["total"] = mc.total_collective()
    coll["counts"] = {k: int(v) for k, v in mc.coll_counts.items()}
    coll["top"] = [
        {"GB": round(b / 1e9, 3), "kind": k, "type": t, "op": o}
        for b, k, t, o in mc.top_collectives(12)]
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "peak_bytes": int(getattr(ma, "peak_memory_in_bytes", 0) or 0),
        }
    except Exception:
        pass
    terms = {
        "compute_s": flops / hw.peak_flops,
        "memory_s": nbytes / hw.hbm_bw,
        "collective_s": coll["total"] / hw.link_bw,
    }
    dom = max(terms, key=terms.get)
    return {
        "hlo_flops": flops,
        "hlo_bytes": nbytes,
        "xla_cost": {"flops": xla_flops, "bytes": xla_bytes},
        "n_whiles": len(mc.while_info),
        "collective": coll,
        "memory": mem,
        "terms": terms,
        "dominant": dom,
    }
