"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and extract roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-12b \
        --shape train_4k --mesh pod      # 16x16 single pod (256 chips)
    ... --mesh multipod                  # 2x16x16 (512 chips)

Writes JSON results to --out (default benchmarks/results/dryrun).
Exit code 0 iff compile succeeded.
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)
# ^ MUST precede any jax import (jax locks device count at first init).

import argparse
import json
import sys
import time
import traceback


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             *, verbose: bool = True) -> dict:
    import jax
    from repro.configs import SHAPES, get_config, runs_cell, skip_reason
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import analyze_compiled, model_flops
    from repro.launch.specs import make_cell

    # NOTE: cost_analysis counts a `while` body once regardless of trip
    # count; roofline terms therefore come from the trip-count-aware HLO
    # parser (launch.hlo_parse) — scans stay rolled and compiles stay fast.
    cfg = get_config(arch)
    sh = SHAPES[shape_name]
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    if not runs_cell(cfg, shape_name):
        rec["status"] = "skipped"
        rec["reason"] = skip_reason(cfg, shape_name)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    ndev = mesh.size
    t0 = time.time()
    try:
        fn, args = make_cell(arch, shape_name, mesh)
        with mesh:
            lowered = jax.jit(fn).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            ana = analyze_compiled(compiled, ndev)
        mf = model_flops(cfg, sh)
        # cost_analysis flops are per-device on the partitioned module
        hlo_global = ana["hlo_flops"] * ndev
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            devices=ndev,
            analysis=ana,
            model_flops_global=mf,
            useful_ratio=(mf / hlo_global) if hlo_global else None,
        )
        if verbose:
            ma = ana.get("memory") or {}
            print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: OK "
                  f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
            print(f"  memory_analysis: {ma}")
            print(f"  cost_analysis: flops/dev={ana['hlo_flops']:.3e} "
                  f"bytes/dev={ana['hlo_bytes']:.3e}")
            print(f"  terms: {ana['terms']}  dominant={ana['dominant']}")
            print(f"  collectives: { {k: v for k, v in ana['collective'].items() if k != 'counts'} }")
    except Exception as e:  # noqa: BLE001
        rec.update(status="fail", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: FAIL {e}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{arch}__{shape_name}__{mesh_kind}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    args = ap.parse_args(argv)
    rec = run_cell(args.arch, args.shape, args.mesh, args.out)
    sys.exit(0 if rec["status"] in ("ok", "skipped") else 1)


if __name__ == "__main__":
    main()
