"""ShapeDtypeStruct stand-ins for every (arch × shape × mesh) dry-run cell.

No device allocation anywhere: params/optimizer/cache shapes come from
``jax.eval_shape`` and are given NamedShardings; batches are SDS with batch
sharded over the dp axes.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.models.config import ModelConfig
from repro.models.model import DistContext, Model
from repro.models.sharding import (POLICIES, ShardingPolicy, batch_specs,
                                   cache_specs, dp_axes, param_specs)

__all__ = ["make_cell", "input_specs", "opt_specs_like"]


def _sds(shape, dtype, mesh=None, spec=None):
    sharding = NamedSharding(mesh, spec) if mesh is not None else None
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def input_specs(cfg: ModelConfig, shape_name: str, mesh: Mesh,
                *, kind: str | None = None, dp: tuple | None = None) -> dict:
    """Batch SDS tree for a cell (training batch / prompt batch / decode tok)."""
    sh = SHAPES[shape_name]
    kind = kind or sh.kind
    B, S = sh.global_batch, sh.seq_len
    dp = dp or dp_axes(mesh)
    ndp = int(np.prod([mesh.shape[a] for a in dp]))
    bspec = P(dp if len(dp) > 1 else dp[0]) if B % ndp == 0 else P(None)
    bs = (bspec[0],) if B % ndp == 0 else (None,)

    def tok(shape):
        return _sds(shape, jnp.int32, mesh, P(*bs, *([None] * (len(shape) - 1))))

    def emb(shape):
        return _sds(shape, jnp.float32, mesh, P(*bs, *([None] * (len(shape) - 1))))

    if kind == "train":
        S_text = S - cfg.prefix_len if cfg.family == "vlm" else S
        if cfg.family == "audio":
            S_text = S // 2
        batch = {"tokens": tok((B, S_text)), "labels": tok((B, S_text))}
        if cfg.family == "audio":
            batch["enc_embed"] = emb((B, S // 2, cfg.d_model))
        if cfg.family == "vlm":
            batch["patches"] = emb((B, cfg.prefix_len, cfg.d_model))
        return batch
    if kind == "prefill":
        S_text = S - cfg.prefix_len if cfg.family == "vlm" else S
        if cfg.family == "audio":
            S_text = S // 2
        batch = {"tokens": tok((B, S_text))}
        if cfg.family == "audio":
            batch["enc_embed"] = emb((B, S // 2, cfg.d_model))
        if cfg.family == "vlm":
            batch["patches"] = emb((B, cfg.prefix_len, cfg.d_model))
        return batch
    if kind == "decode":
        return {"tokens": tok((B, 1))}
    raise ValueError(kind)


def opt_specs_like(pspecs: Any, opt_shapes: Any) -> Any:
    """Optimizer-state specs: moments mirror their param's spec; factored
    Adafactor stats drop the corresponding dim; scalars replicate."""
    import jax.tree_util as jtu

    pflat = dict(jtu.tree_flatten_with_path(pspecs)[0])

    def lookup(path):
        # path like ('m', <param path...>) or ('f', <param path...>, 'vr')
        return pflat.get(path[1:]) if len(path) > 1 else None

    def one(path, leaf):
        keys = tuple(path)
        head = keys[0].key if hasattr(keys[0], "key") else None
        if head in ("m", "v"):
            spec = pflat.get(keys[1:])
            if spec is not None and len(spec) == leaf.ndim:
                return spec
        if head in ("f", "G"):
            tailkey = keys[-1].key if hasattr(keys[-1], "key") else None
            spec = pflat.get(keys[1:-1]) if tailkey in ("vr", "vc", "v") else None
            if spec is not None:
                if tailkey == "vr" and len(spec) == leaf.ndim + 1:
                    return P(*spec[:-1])
                if tailkey == "vc" and len(spec) == leaf.ndim + 1:
                    return P(*(spec[:-2] + spec[-1:]))
                if tailkey == "v" and len(spec) == leaf.ndim:
                    return spec
        return P(*([None] * leaf.ndim))

    return jtu.tree_map_with_path(one, opt_shapes)


def make_cell(arch: str, shape_name: str, mesh: Mesh,
              policy: str = "auto"):
    """Build (fn, arg_sds) ready for jax.jit(fn).lower(*arg_sds).

    ``policy="auto"``: train cells of non-MoE archs whose global batch
    divides the full mesh use pure-FSDP (ZeRO-3) — Perf iteration 4;
    everything else uses the 2d (FSDP x TP/EP) mapping.
    """
    import os
    cfg = get_config(arch)
    sh = SHAPES[shape_name]
    if policy == "auto":
        full = int(np.prod(list(mesh.shape.values())))
        policy = ("fsdp_only"
                  if sh.kind == "train" and not cfg.n_experts
                  and sh.global_batch % full == 0
                  and not os.environ.get("REPRO_DISABLE_PERF_OPTS")
                  else "2d")
    pol = POLICIES[policy]
    model = Model(cfg, remat=True)
    pod = ("pod",) if "pod" in mesh.axis_names else ()
    dp = pod + pol.dp
    dist = DistContext(mesh=mesh, dp_axes=dp)

    pshapes = jax.eval_shape(model.init, jax.random.key(0))
    pspecs = param_specs(pshapes, mesh, cfg, pol)
    p_sds = jax.tree.map(
        lambda s, sp: _sds(s.shape, s.dtype, mesh, sp), pshapes, pspecs)

    if sh.kind == "train":
        from repro.optim import get_optimizer
        from repro.train.steps import make_train_step

        opt_name = "adafactor" if cfg.name == "arctic-480b" else "adamw"
        optimizer = get_optimizer(opt_name)
        oshapes = jax.eval_shape(optimizer.init, pshapes)
        ospecs = opt_specs_like(pspecs, oshapes)
        o_sds = jax.tree.map(
            lambda s, sp: _sds(s.shape, s.dtype, mesh, sp), oshapes, ospecs)
        batch = input_specs(cfg, shape_name, mesh, dp=dp)
        step = make_train_step(model, optimizer, dist=dist)
        return step, (p_sds, o_sds, batch)

    if sh.kind == "prefill":
        batch = input_specs(cfg, shape_name, mesh)

        def prefill(params, b):
            return model.prefill(params, b, sh.seq_len, dist=dist)

        return prefill, (p_sds, batch)

    # decode
    cshapes = jax.eval_shape(lambda: model.init_cache(sh.global_batch, sh.seq_len))
    cspecs = cache_specs(cshapes, mesh, cfg)
    c_sds = jax.tree.map(
        lambda s, sp: _sds(s.shape, s.dtype, mesh, sp), cshapes, cspecs)
    batch = input_specs(cfg, shape_name, mesh)

    def serve_step(params, tokens, cache):
        return model.decode_step(params, tokens, cache)

    return serve_step, (p_sds, batch["tokens"], c_sds)
