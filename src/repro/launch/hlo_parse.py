"""Trip-count-aware cost model over optimized HLO text.

XLA's ``Executable.cost_analysis()`` counts a ``while`` body ONCE regardless
of trip count, so any scanned program (layers, flash KV blocks, recurrences)
under-reports FLOPs / bytes / collective traffic by the trip count.  This
module re-derives the three roofline inputs from the partitioned, scheduled
HLO text with full while-multiplier propagation:

* **dot FLOPs**: every ``dot`` = 2 · |result| · |contracted dims| (shapes
  from a per-computation symbol table; dots inside fusions are counted via
  their called computations);
* **HBM bytes**: Σ over scheduled instructions of result+operand bytes —
  post-fusion this is a faithful HBM-traffic model (fusion internals stay in
  registers and are *not* counted);
* **collective wire bytes**: ring model per op kind, scaled like everything
  else by the enclosing while trip counts.

Trip counts are read from the while's condition computation (the loop bound
is the ``s32[] constant(N)`` the induction variable compares against).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

__all__ = ["parse_module", "ModuleCosts"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(")
_CALL_ATTR_RE = re.compile(r"(?:calls|to_apply|body|condition)=(%[\w.\-]+)")
# Operand lists print as `op(%a, %b)` on new XLA and `op(f32[8]{0} %a, ...)`
# (types included) on older builds — accept both by requiring a `%` anywhere
# inside the parens rather than immediately after them.
_OPERAND_RE = re.compile(r"\(([^)]*%[^)]*)\)")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")

_COLL = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")
_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "call", "after-all", "custom-call",
               "get-dimension-size", "copy-start", "copy-done",
               # pure layout/dtype ops: fused into consumers on TPU (a
               # standalone `convert` of an int8 KV cache would otherwise
               # count a phantom f32 materialization — measured 500x
               # overcount on the qwen decode cell)
               "convert", "broadcast", "reshape", "transpose", "copy",
               "iota", "bitcast-convert", "pad"}


def _type_info(ts: str, bf16_normalize: bool = False) -> Tuple[int, int]:
    """(total bytes, total elements) of a type string (tuples summed).

    ``bf16_normalize``: the CPU backend's float-normalization pass upcasts
    every bf16 tensor to f32 at compile time (CPUs have no native bf16), so
    the compiled-HLO byte widths overstate TPU traffic 2x for the bf16
    compute path.  De-normalize: f32 tensors of rank >= 3 (activations,
    attention blocks, cotangents) count at bf16 width; rank <= 2 f32
    (master weights, gradient accumulators, optimizer state) stay f32.
    """
    b = e = 0
    for m in _SHAPE_RE.finditer(ts):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        dl = [int(d) for d in dims.split(",") if d]
        n = 1
        for d in dl:
            n *= d
        width = _DTYPE_BYTES[dt]
        if bf16_normalize and dt == "f32" and len(dl) >= 3:
            # raw rank >= 3: activations / attention tiles / stacked-scan
            # cotangents (B_loc can be 1, so do not filter on dim size).
            # Rank <= 2 f32 (weight masters, dW reductions, opt state)
            # keeps f32 width.
            width = 2
        b += n * width
        e += n
    return b, e


def _shape_dims(ts: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(ts)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    rtype: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    symtab: Dict[str, str]


def _split_computations(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if not line:
            continue
        if not line[0].isspace():
            m = _COMP_HDR_RE.match(line)
            if m and "{" in line:
                cur = Computation(m.group(1), [], {})
                comps[cur.name] = cur
                # parameters: shapes recoverable from signature if needed
                continue
            cur = None if line.startswith("}") else cur
            continue
        if cur is None:
            continue
        s = line.strip()
        if s.startswith("}"):
            cur = None
            continue
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        name = dm.group(1)
        rest = line[line.index("=") + 1:].lstrip()
        # result type = leading type tokens up to the op name
        tm = re.match(r"((?:\([^)]*\)|[\w\[\],{}]+)+)\s+([\w\-]+)", rest)
        if not tm:
            continue
        rtype, op = tm.group(1), tm.group(2)
        ops_m = _OPERAND_RE.search(line[line.index(op) + len(op):])
        operands = []
        if ops_m:
            operands = re.findall(r"%[\w.\-]+", ops_m.group(1))
        cur.instrs.append(Instr(name, op, rtype, operands, line))
        cur.symtab[name] = rtype
    return comps


def _trip_count(cond: Computation) -> int:
    best = 1
    for ins in cond.instrs:
        if ins.op == "constant" and ins.rtype.startswith("s32"):
            m = re.search(r"constant\((\d+)\)", ins.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _dot_flops(ins: Instr, symtab: Dict[str, str]) -> float:
    rb, relem = _type_info(ins.rtype)
    lhs_dims = None
    if ins.operands:
        lhs_t = symtab.get(ins.operands[0])
        if lhs_t:
            lhs_dims = _shape_dims(lhs_t)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    contracted = 1
    if m and lhs_dims is not None:
        for d in m.group(1).split(","):
            if d and int(d) < len(lhs_dims):
                contracted *= lhs_dims[int(d)]
    return 2.0 * relem * contracted


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


@dataclasses.dataclass
class ModuleCosts:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_counts: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    while_info: list = dataclasses.field(default_factory=list)
    top_colls: list = dataclasses.field(default_factory=list)
    top_hbm: list = dataclasses.field(default_factory=list)
    # ^ (bytes·mult, op, result type, op_name metadata) — the "profile"
    # used by the §Perf hypothesis loop to attribute traffic

    def total_collective(self) -> float:
        return float(sum(self.collective.values()))

    def top_collectives(self, n: int = 15) -> list:
        return sorted(self.top_colls, reverse=True)[:n]

    def top_hbm_ops(self, n: int = 15) -> list:
        return sorted(self.top_hbm, reverse=True)[:n]


def parse_module(text: str, num_devices: int,
                 bf16_normalize: bool = True) -> ModuleCosts:
    comps = _split_computations(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line[len("ENTRY "):].lstrip())
            if m:
                entry = m.group(1)
            else:
                m2 = re.match(r"ENTRY\s+(%[\w.\-]+)", line)
                entry = m2.group(1) if m2 else None
            break
    if entry is None or entry not in comps:
        # fall back: computation named %main*
        entry = next((n for n in comps if n.startswith("%main")), None)
    out = ModuleCosts()
    if entry is None:
        return out

    fused_flops_cache: Dict[str, float] = {}

    def fusion_flops(cname: str) -> float:
        """dot flops inside a fused computation (bytes NOT counted)."""
        if cname in fused_flops_cache:
            return fused_flops_cache[cname]
        c = comps.get(cname)
        total = 0.0
        if c:
            for ins in c.instrs:
                if ins.op == "dot":
                    total += _dot_flops(ins, c.symtab)
                elif ins.op == "fusion" or ins.op == "call":
                    for callee in _CALL_ATTR_RE.findall(ins.line):
                        total += fusion_flops(callee)
        fused_flops_cache[cname] = total
        return total

    def walk(cname: str, mult: float, depth: int = 0):
        c = comps.get(cname)
        if c is None or depth > 32:
            return
        for ins in c.instrs:
            if ins.op == "while":
                body = cond = None
                bm = re.search(r"body=(%[\w.\-]+)", ins.line)
                cm = re.search(r"condition=(%[\w.\-]+)", ins.line)
                if bm:
                    body = bm.group(1)
                if cm:
                    cond = cm.group(1)
                trips = _trip_count(comps[cond]) if cond in comps else 1
                out.while_info.append((cname, body, trips))
                if body:
                    walk(body, mult * trips, depth + 1)
                continue
            if ins.op in ("call", "conditional"):
                for callee in _CALL_ATTR_RE.findall(ins.line):
                    walk(callee, mult, depth + 1)
                continue
            base = ins.op.replace("-start", "")
            if base in _COLL and not ins.op.endswith("-done"):
                g = _group_size(ins.line, num_devices)
                if g > 1:
                    rb, _ = _type_info(ins.rtype, bf16_normalize)
                    if base == "all-gather":
                        wire = (g - 1) / g * rb
                    elif base == "all-reduce":
                        wire = 2 * (g - 1) / g * rb
                    elif base == "reduce-scatter":
                        wire = (g - 1) * rb
                    elif base == "all-to-all":
                        wire = (g - 1) / g * rb
                    else:
                        wire = rb
                    out.collective[base] += mult * wire
                    out.coll_counts[base] += mult
                    nm = re.search(r'op_name="([^"]*)"', ins.line)
                    out.top_colls.append(
                        (mult * wire, base, ins.rtype[:48],
                         (nm.group(1)[-110:] if nm else cname)))
            if ins.op == "dot":
                out.dot_flops += mult * _dot_flops(ins, c.symtab)
            elif ins.op == "fusion":
                for callee in _CALL_ATTR_RE.findall(ins.line):
                    out.dot_flops += mult * fusion_flops(callee)
            # HBM traffic: result + operands of scheduled (non-control) ops
            if ins.op == "dynamic-update-slice":
                # in-place on TPU (buffer aliasing): traffic = the update
                # slice written + read, not the whole buffer
                if len(ins.operands) >= 2:
                    t = c.symtab.get(ins.operands[1])
                    if t:
                        out.hbm_bytes += mult * 2 * _type_info(t, bf16_normalize)[0]
            elif ins.op not in _SKIP_BYTES and not ins.op.endswith("-done"):
                rb, _ = _type_info(ins.rtype, bf16_normalize)
                ob = 0
                for o in ins.operands:
                    t = c.symtab.get(o)
                    if t:
                        ob += _type_info(t, bf16_normalize)[0]
                tot = mult * (rb + ob)
                out.hbm_bytes += tot
                if tot > 1e9:
                    nm = re.search(r'op_name="([^"]*)"', ins.line)
                    out.top_hbm.append((tot, ins.op, ins.rtype[:48],
                                        (nm.group(1)[-90:] if nm else cname)))
        return

    walk(entry, 1.0)
    out.collective = dict(out.collective)
    out.coll_counts = dict(out.coll_counts)
    return out
