"""Production serving launcher: continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
        [--slots 4] [--requests 16] [--cache 128] [--ckpt <dir>]

Loads params from a checkpoint when given (mesh-agnostic restore), else
random-inits; runs the ServeEngine over a synthetic request stream and
reports throughput.
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache", type=int, default=128)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args(argv)

    import jax
    import numpy as np
    from repro.configs import get_config, smoke_config
    from repro.models.model import Model
    from repro.serve.engine import Request, ServeEngine

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    if args.ckpt:
        from repro.checkpoint import CheckpointManager
        tree, man = CheckpointManager(args.ckpt).restore({"params": params})
        params = tree["params"]
        print(f"[serve] restored step {man['step']} from {args.ckpt}")
    eng = ServeEngine(model, params, batch_slots=args.slots,
                      s_cache=args.cache)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 16))
        r = Request(i, rng.integers(0, cfg.vocab_size, (plen,)).astype(np.int32),
                    max_new=args.max_new)
        reqs.append(r)
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run(max_steps=10_000)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in reqs)
    done = sum(r.done for r in reqs)
    print(f"[serve] {done}/{len(reqs)} requests, {toks} tokens, "
          f"{toks/dt:.1f} tok/s ({eng.steps} steps, {args.slots} slots)")
    return reqs


if __name__ == "__main__":
    main()
