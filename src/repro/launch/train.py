"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b \
        [--smoke] [--steps 100] [--optimizer adamw] [--model-parallel 1] \
        [--resume auto] [--compress-grads]

Builds the mesh from whatever devices exist (`local_mesh` — elastic: the
same checkpoint restores onto any device count), shards params per the
sharding policy, and runs the fault-tolerant Trainer (checkpoint/restart,
straggler watchdog, failure recovery).  On a real pod this is the per-host
entrypoint (jax.distributed.initialize is a no-op single-host here).
"""
from __future__ import annotations

import argparse


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor", "sgd", "tripre"])
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", default="auto", choices=["auto", "none"])
    ap.add_argument("--micro-steps", type=int, default=1)
    args = ap.parse_args(argv)

    import jax
    from repro.configs import get_config, smoke_config
    from repro.data import SyntheticLM
    from repro.launch.mesh import local_mesh
    from repro.models.model import Model
    from repro.optim import get_optimizer
    from repro.train import TrainConfig, Trainer

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = local_mesh(model=args.model_parallel) if jax.device_count() > 1 else None
    print(f"[launch] arch={cfg.name} devices={jax.device_count()} "
          f"mesh={dict(mesh.shape) if mesh else None}")
    model = Model(cfg, remat=not args.smoke)
    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch,
                       family=cfg.family, d_model=cfg.d_model,
                       prefix_len=cfg.prefix_len)
    opt = get_optimizer(args.optimizer, lr=args.lr, total_steps=args.steps)
    tc = TrainConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                     ckpt_dir=args.ckpt_dir, resume=args.resume,
                     micro_steps=args.micro_steps)
    out = Trainer(model, opt, data, tc, mesh=mesh).run()
    print(f"[launch] done at step {out['final_step']}; "
          f"loss {out['history'][0]:.3f} -> {out['history'][-1]:.3f}; "
          f"stragglers={out['straggler_events']} recoveries={out['recoveries']}")
    return out


if __name__ == "__main__":
    main()
