"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run process
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then builds the mesh.

Single pod:  (data=16, model=16)            = 256 chips  (TPU v5e pod)
Multi-pod:   (pod=2, data=16, model=16)     = 512 chips  (2 pods)

The ``pod`` axis is an outer data-parallel axis (gradient all-reduce crosses
the inter-pod links exactly once per step); ``data`` carries batch + FSDP
sharding inside a pod; ``model`` carries tensor/expert parallelism on the
fastest links.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import Mesh

try:  # AxisType / axis_types only exist on newer JAX
    from jax.sharding import AxisType
except ImportError:
    AxisType = None

__all__ = ["make_production_mesh", "make_mesh", "local_mesh"]


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    """jax.make_mesh with explicit Auto axis types (silences the v0.9
    behaviour-change warning; we use in/out_shardings + shard_map, not
    explicit-mode sharding-in-types).  Older builds have neither AxisType
    nor jax.make_mesh's axis_types kwarg — fall back to the plain call."""
    if AxisType is not None:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def local_mesh(model: Optional[int] = None) -> Mesh:
    """Best-effort mesh from whatever devices exist (elastic: the same
    checkpoint restores onto any shape).  Used by train.py/serve.py."""
    n = jax.device_count()
    model = model or 1
    assert n % model == 0, (n, model)
    return make_mesh((n // model, model), ("data", "model"))
