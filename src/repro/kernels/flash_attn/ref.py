"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(q, k, v, valid_len=None, *, causal=True, window=0):
    """q,k,v: (BH, S, hd) -> (BH, Sq, hd); f32 math."""
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= kp <= qp
    if window > 0:
        ok &= kp > qp - window
    if valid_len is not None:
        ok &= kp < valid_len
    s = jnp.where(ok[None], s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
