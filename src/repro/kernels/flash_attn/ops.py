"""Jit'd wrapper: (B, S, H, hd) GQA layout -> padded MHA kernel call."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .kernel import flash_fwd

__all__ = ["flash_attention_kernel"]


def _ceil_to(v: int, m: int) -> int:
    return max(-(-v // m) * m, m)


def flash_attention_kernel(
    q: jnp.ndarray,           # (B, Sq, Hq, hd)
    k: jnp.ndarray,           # (B, Sk, Hkv, hd)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, Sq, Hq, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    if g > 1:                 # GQA: repeat KV heads for the MHA kernel
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    Sq_p, Sk_p = _ceil_to(Sq, block_q), _ceil_to(Sk, block_k)
    hd_p = _ceil_to(hd, 128)
    qp = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, hd_p - hd)))
    kp = jnp.pad(k, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, hd_p - hd)))
    vp = jnp.pad(v, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, hd_p - hd)))
    # rescale: padding hd changes the kernel's hd**-0.5
    qp = qp * jnp.asarray((hd_p / hd) ** 0.5, qp.dtype)

    def bh(x, S):
        return x.transpose(0, 2, 1, 3).reshape(B * Hq, S, hd_p)

    o = flash_fwd(bh(qp, Sq_p), bh(kp, Sk_p), bh(vp, Sk_p),
                  jnp.asarray([Sk], jnp.int32),
                  causal=causal, window=window,
                  block_q=block_q, block_k=block_k, interpret=interpret)
    o = o.reshape(B, Hq, Sq_p, hd_p).transpose(0, 2, 1, 3)
    return o[:, :Sq, :, :hd]
