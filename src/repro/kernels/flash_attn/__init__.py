from .ops import flash_attention_kernel
from .ref import attention_ref

__all__ = ["flash_attention_kernel", "attention_ref"]
