"""Pallas TPU flash attention (forward).

The §Perf memory profiles show attention score tiles as the dominant HBM
traffic once sharding is fixed (pure-XLA attention materializes every
(bq, bk) block).  This kernel keeps the online-softmax state — acc (bq, hd),
m, l (bq,) — in VMEM scratch across the KV grid dimension, so score tiles
never touch HBM: per (batch·head, q-block), HBM traffic is q + streamed
k/v + one output write.

Grid: ``(BH, n_q, n_kv)`` with ``dimension_semantics=(parallel, parallel,
arbitrary)`` — the last (KV) dimension iterates sequentially per TPU core,
which is what makes scratch-carried accumulation legal.  Masking (causal /
sliding window) is applied from global block coordinates; fully-masked
trailing blocks are skipped with ``pl.when`` (they still occupy grid steps —
block-skipping via scalar-prefetch ragged grids is the known follow-up).

MXU alignment: bq, bk multiples of 128; hd padded to 128 by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

__all__ = ["flash_fwd"]

NEG_INF = -1e30


def _fa_kernel(spec_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
               *, bq: int, bk: int, causal: bool, window: int, scale: float):
    qb = pl.program_id(1)
    kb = pl.program_id(2)
    n_kv = pl.num_programs(2)
    seq_off_q = qb * bq
    seq_off_k = kb * bk

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # static-ish skip: with causal masking, blocks fully above the diagonal
    # contribute nothing
    q_pos = seq_off_q + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = seq_off_k + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    live = True
    if causal:
        live = seq_off_k <= seq_off_q + bq - 1
    if window > 0:
        live = jnp.logical_and(live, seq_off_k + bk - 1 > seq_off_q - window)

    @pl.when(live)
    def _step():
        q = q_ref[0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        mask = spec_ref[0] > 0                    # (1,) valid-length flag mode
        del mask
        ok = k_pos < spec_ref[0]                  # valid key positions
        if causal:
            ok = jnp.logical_and(ok, k_pos <= q_pos)
        if window > 0:
            ok = jnp.logical_and(ok, k_pos > q_pos - window)
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot(p.astype(v.dtype), v))
        m_ref[...] = m_new

    @pl.when(kb == n_kv - 1)
    def _fin():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_fwd(
    q: jnp.ndarray,          # (BH, Sq, hd)
    k: jnp.ndarray,          # (BH, Sk, hd)
    v: jnp.ndarray,          # (BH, Sk, hd)
    valid_len: jnp.ndarray,  # (1,) int32 — number of valid key positions
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk)
    grid = (BH, Sq // block_q, Sk // block_k)
    kernel = functools.partial(
        _fa_kernel, bq=block_q, bk=block_k, causal=causal, window=window,
        scale=hd ** -0.5)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, i, j: (0,),
                         memory_space=pltpu.SMEM),             # valid_len
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),   # acc
            pltpu.VMEM((block_q,), jnp.float32),      # m
            pltpu.VMEM((block_q,), jnp.float32),      # l
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.PARALLEL,
                                 pltpu.ARBITRARY),
        ),
        interpret=interpret,
        name="flash_attn_fwd",
    )(valid_len, q, k, v)
