"""Kernel backend abstraction: pluggable TPU / GPU / interpret lowerings.

The paper's framework generates specialized SpTRSV code for one target; the
kernel layer here is specialized per *device family* instead, behind one
small interface.  A :class:`KernelBackend` names

* which **lowering family** a kernel package should use (``platform``:
  ``"tpu"`` = Mosaic lowerings with VMEM-resident operands, ``"gpu"`` =
  pallas-triton lowerings with GMEM gather loads), and
* whether ``pallas_call`` runs in **interpret mode** (``interpret=True`` —
  the correctness harness that executes any lowering on the host CPU).

Every kernel package (``sptrsv_level``, ``sptrsv_fused``, ``spmv_ell``,
``trsm_block``) keeps its lowering-specific code in ``lowering_tpu.py`` /
``lowering_gpu.py`` modules exposing the *same* entry points, and its
``ops.py`` dispatches through :func:`resolve_backend` — so the composition
layers (`SpTRSV.build`, the packed/permuted layout, the planner, serving)
thread a single ``backend=`` knob instead of an ``interpret: bool``.

Backend specs (strings accepted anywhere a ``backend=`` knob appears):

``None``            resolve from ``jax.default_backend()``: ``tpu`` → the
                    compiled TPU lowerings, ``gpu``/``cuda``/``rocm`` → the
                    compiled GPU lowerings, ``cpu`` → the interpret backend
                    (pallas has no CPU codegen; interpret is the only way a
                    pallas strategy can execute there)
``"tpu"``           compiled Mosaic lowerings
``"gpu"``           compiled pallas-triton lowerings (aliases: ``cuda``,
                    ``rocm``)
``"interpret"``     TPU lowerings under the pallas interpreter (the
                    historical ``interpret=True`` harness; alias: ``cpu``,
                    ``interpret:tpu``)
``"interpret:gpu"`` GPU lowerings under the pallas interpreter — how CI
                    exercises the triton-style kernels without a GPU

The legacy ``interpret: bool`` knob maps onto this: ``interpret=True``
wraps the resolved platform's lowerings in the interpreter,
``interpret=False`` forces the compiled path.  :func:`resolve_backend`
implements both so call sites only deal in backends.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Union

__all__ = [
    "KernelBackend",
    "BACKENDS",
    "resolve_backend",
    "default_backend_name",
    "warn_interpret_deprecated",
]

# Lowering families a kernel package must provide.
PLATFORMS = ("tpu", "gpu")


@dataclasses.dataclass(frozen=True)
class KernelBackend:
    """One resolved kernel target.

    ``name``      canonical spec (``tpu`` / ``gpu`` / ``interpret`` /
                  ``interpret:gpu``) — recorded on solvers and in stats
    ``platform``  lowering family to dispatch to (``tpu`` or ``gpu``)
    ``interpret`` run ``pallas_call`` under the interpreter (host CPU)
    """

    name: str
    platform: str
    interpret: bool

    def __post_init__(self):
        assert self.platform in PLATFORMS, self.platform

    @property
    def calibration_key(self) -> str:
        """Which :mod:`repro.core.calibrate` row prices this backend: the
        interpreter executes on the host, so it is priced as ``cpu``."""
        return "cpu" if self.interpret else self.platform

    def interpreted(self) -> "KernelBackend":
        """The interpret-mode twin of this backend (same lowering family)."""
        if self.interpret:
            return self
        name = "interpret" if self.platform == "tpu" else "interpret:gpu"
        return KernelBackend(name=name, platform=self.platform, interpret=True)

    def compiled(self) -> "KernelBackend":
        """The compiled twin of this backend (same lowering family)."""
        if not self.interpret:
            return self
        return KernelBackend(name=self.platform, platform=self.platform,
                             interpret=False)


# Canonical backends, keyed by every accepted spelling.
_TPU = KernelBackend(name="tpu", platform="tpu", interpret=False)
_GPU = KernelBackend(name="gpu", platform="gpu", interpret=False)
_INTERP = KernelBackend(name="interpret", platform="tpu", interpret=True)
_INTERP_GPU = KernelBackend(name="interpret:gpu", platform="gpu",
                            interpret=True)

BACKENDS = {
    "tpu": _TPU,
    "gpu": _GPU,
    "cuda": _GPU,
    "rocm": _GPU,
    "interpret": _INTERP,
    "interpret:tpu": _INTERP,
    "cpu": _INTERP,
    "interpret:gpu": _INTERP_GPU,
}


def default_backend_name() -> str:
    """Canonical backend spec for the current JAX platform.  Kept as its own
    function so tests can monkeypatch ``jax.default_backend`` and assert the
    mapping without real hardware."""
    import jax

    platform = jax.default_backend()
    if platform == "tpu":
        return "tpu"
    if platform in ("gpu", "cuda", "rocm"):
        return "gpu"
    # cpu (and anything unknown): pallas kernels can only run interpreted
    return "interpret"


def resolve_backend(
    spec: Union[None, str, KernelBackend] = None,
    *,
    interpret: Optional[bool] = None,
) -> KernelBackend:
    """Resolve a ``backend=`` knob (and the deprecated ``interpret=`` alias)
    to a :class:`KernelBackend`.

    ``spec=None`` resolves from ``jax.default_backend()`` (see
    :func:`default_backend_name`).  ``interpret`` — when not ``None`` —
    overrides the resolved backend's mode: ``True`` wraps the lowerings in
    the interpreter, ``False`` forces the compiled path (on a CPU host that
    compiled path will fail at lowering time, exactly as the legacy
    ``interpret=False`` did)."""
    if isinstance(spec, KernelBackend):
        bk = spec
    else:
        if spec is None:
            spec = default_backend_name()
        try:
            bk = BACKENDS[spec.lower()]
        except KeyError:
            raise ValueError(
                f"unknown kernel backend {spec!r}; expected one of "
                f"{sorted(set(BACKENDS))}") from None
    if interpret is True:
        bk = bk.interpreted()
    elif interpret is False:
        bk = bk.compiled()
    return bk


def warn_interpret_deprecated(where: str) -> None:
    """One-release deprecation notice for the old ``interpret: bool`` knob."""
    warnings.warn(
        f"{where}: the interpret= knob is deprecated; pass backend="
        "('tpu' | 'gpu' | 'interpret' | 'interpret:gpu', or None to resolve "
        "from jax.default_backend()) instead.  interpret=True maps to the "
        "interpret backend; interpret=False forces the compiled lowering.",
        DeprecationWarning,
        stacklevel=3,
    )
