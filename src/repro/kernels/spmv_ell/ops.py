"""Backend-dispatched wrapper: CSR -> padded ELL, then the Pallas SpMV on
the selected backend (TPU Mosaic, pallas-triton, or either interpreted)."""
from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.codegen import build_ell
from repro.core.csr import CSRMatrix
from repro.kernels.backend import resolve_backend

from . import lowering_gpu, lowering_tpu

__all__ = ["make_spmv", "select_lowering"]


def select_lowering(backend=None):
    """Lowering module for a backend spec — the single dispatch point the
    backend-matrix CI job asserts on."""
    bk = resolve_backend(backend)
    return lowering_gpu if bk.platform == "gpu" else lowering_tpu


def _ceil_to(v: int, m: int) -> int:
    return max(int(np.ceil(v / m) * m), m)


def make_spmv(
    M: CSRMatrix,
    *,
    backend=None,
    interpret: Optional[bool] = None,
    block: int = 1024,
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    bk = resolve_backend(backend, interpret=interpret)
    low = select_lowering(bk)
    ell = build_ell(M)
    n = M.n
    n_pad = _ceil_to(n, block)
    m_pad = _ceil_to(M.shape[1], 128)
    cols = np.zeros((ell.K, n_pad), np.int32)
    cols[:, :n] = ell.cols
    vals = np.zeros((ell.K, n_pad), np.float32)
    vals[:, :n] = ell.vals
    cols_d, vals_d = jnp.asarray(cols), jnp.asarray(vals)

    def matvec(v: jnp.ndarray) -> jnp.ndarray:
        dt = v.dtype
        v_pad = jnp.zeros((m_pad,), dt).at[: v.shape[0]].set(v)
        y = low.spmv(v_pad, cols_d, vals_d.astype(dt), block=block,
                     interpret=bk.interpret)
        return y[:n]

    return matvec
