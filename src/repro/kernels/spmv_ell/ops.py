"""Jit'd wrapper: CSR -> padded ELL, then the Pallas SpMV."""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.core.codegen import build_ell
from repro.core.csr import CSRMatrix

from .kernel import spmv

__all__ = ["make_spmv"]


def _ceil_to(v: int, m: int) -> int:
    return max(int(np.ceil(v / m) * m), m)


def make_spmv(
    M: CSRMatrix, *, interpret: bool = True, block: int = 1024
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    ell = build_ell(M)
    n = M.n
    n_pad = _ceil_to(n, block)
    m_pad = _ceil_to(M.shape[1], 128)
    cols = np.zeros((ell.K, n_pad), np.int32)
    cols[:, :n] = ell.cols
    vals = np.zeros((ell.K, n_pad), np.float32)
    vals[:, :n] = ell.vals
    cols_d, vals_d = jnp.asarray(cols), jnp.asarray(vals)

    def matvec(v: jnp.ndarray) -> jnp.ndarray:
        dt = v.dtype
        v_pad = jnp.zeros((m_pad,), dt).at[: v.shape[0]].set(v)
        y = spmv(v_pad, cols_d, vals_d.astype(dt), block=block, interpret=interpret)
        return y[:n]

    return matvec
