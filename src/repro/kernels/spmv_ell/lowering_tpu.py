"""Pallas TPU kernel: ELL SpMV  y = M v  (transposed slab layout (K, n)).

Used for (a) the rewriting method's per-solve RHS update ``b' = E b`` — one
fully parallel pass, and (b) matvecs in the iterative-solver examples.

Grid walks column blocks of the slab (rows of y); ``v`` is VMEM-resident in
full.  Memory-bound: bytes = (2*K*n)*4 slab + n*4 in/out; the K loop is
unrolled (K static — matrix-specialized program).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

__all__ = ["spmv_kernel", "spmv"]


def spmv_kernel(v_ref, cols_ref, vals_ref, out_ref):
    v = v_ref[...]
    K, C = cols_ref.shape
    acc = jnp.zeros((C,), v.dtype)
    for k in range(K):
        acc = acc + vals_ref[k, :] * jnp.take(v, cols_ref[k, :], mode="clip")
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def spmv(
    v_pad: jnp.ndarray,   # (m_pad,) input vector, padded
    cols: jnp.ndarray,    # (K, n_pad)
    vals: jnp.ndarray,    # (K, n_pad)
    *,
    block: int = 1024,
    interpret: bool = True,
) -> jnp.ndarray:
    K, n_pad = cols.shape
    assert n_pad % block == 0, (n_pad, block)
    m_pad = v_pad.shape[0]
    return pl.pallas_call(
        spmv_kernel,
        grid=(n_pad // block,),
        in_specs=[
            pl.BlockSpec((m_pad,), lambda i: (0,)),
            pl.BlockSpec((K, block), lambda i: (0, i)),
            pl.BlockSpec((K, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), v_pad.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=(pltpu.PARALLEL,),
        ),
        interpret=interpret,
        name="spmv_ell",
    )(v_pad, cols, vals)
