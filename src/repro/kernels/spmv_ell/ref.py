"""Pure-jnp oracle for ELL SpMV."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["spmv_ref"]


def spmv_ref(v_pad, cols, vals):
    return jnp.sum(vals * v_pad[cols], axis=0)
