"""GPU (pallas-triton) lowering: ELL SpMV  y = M v.

Twin of :mod:`.lowering_tpu` with the Mosaic-isms removed: gather loads
(``pl.load`` with an index array) from the GMEM-resident input vector
replace ``jnp.take`` over a VMEM-resident copy, the grid is an ordinary
parallel launch (SpMV has no cross-block dependence), and there are no
TPU compiler params.  Same signature, layout, and padding contract.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["spmv_kernel", "spmv"]


def spmv_kernel(v_ref, cols_ref, vals_ref, out_ref):
    K, C = cols_ref.shape
    acc = jnp.zeros((C,), out_ref.dtype)
    for k in range(K):
        acc = acc + vals_ref[k, :] * pl.load(v_ref, (cols_ref[k, :],))
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def spmv(
    v_pad: jnp.ndarray,   # (m_pad,) input vector, padded
    cols: jnp.ndarray,    # (K, n_pad)
    vals: jnp.ndarray,    # (K, n_pad)
    *,
    block: int = 1024,
    interpret: bool = True,
) -> jnp.ndarray:
    K, n_pad = cols.shape
    assert n_pad % block == 0, (n_pad, block)
    m_pad = v_pad.shape[0]
    return pl.pallas_call(
        spmv_kernel,
        grid=(n_pad // block,),
        in_specs=[
            pl.BlockSpec((m_pad,), lambda i: (0,)),
            pl.BlockSpec((K, block), lambda i: (0, i)),
            pl.BlockSpec((K, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), v_pad.dtype),
        interpret=interpret,
        name="spmv_ell_gpu",
    )(v_pad, cols, vals)
