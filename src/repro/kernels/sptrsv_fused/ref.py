"""Pure-jnp oracle for the fused kernel: sequential chunk loop over the
permuted layout (same math, no Pallas)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["fused_solve_ref"]


def fused_solve_ref(bl_perm, cols, vals, diag, *, chunk: int = 512):
    """Single- or multi-RHS (bl_perm (n_pad,) or (n_pad, m)) oracle."""
    K, n_pad = cols.shape
    batched = bl_perm.ndim == 2
    x = jnp.zeros(bl_perm.shape, bl_perm.dtype)
    for c in range(n_pad // chunk):
        sl = slice(c * chunk, (c + 1) * chunk)
        v = vals[:, sl, None] if batched else vals[:, sl]
        d = diag[sl, None] if batched else diag[sl]
        s = jnp.sum(v * x[cols[:, sl]], axis=0)
        x = x.at[sl].set((bl_perm[sl] - s) / d)
    return x
