"""Pure-jnp oracle for the fused kernel: sequential chunk loop over the
permuted layout (same math, no Pallas)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["fused_solve_ref"]


def fused_solve_ref(bl_perm, cols, vals, diag, *, chunk: int = 512):
    K, n_pad = cols.shape
    x = jnp.zeros((n_pad,), bl_perm.dtype)
    for c in range(n_pad // chunk):
        sl = slice(c * chunk, (c + 1) * chunk)
        s = jnp.sum(vals[:, sl] * x[cols[:, sl]], axis=0)
        x = x.at[sl].set((bl_perm[sl] - s) / diag[sl])
    return x
