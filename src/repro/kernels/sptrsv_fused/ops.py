"""Backend-dispatched wrapper: pack a Schedule into the fused level-order
layout and solve it — one sequential-grid ``pallas_call`` on the TPU
backend, a level-scheduled launch walk of the same layout on the GPU
backend (see the lowering modules).

Direction-agnostic: backward (transpose) schedules permute rows by *reverse*
level order, so all dependency positions still precede their consumers in
the grid walk; padding slots gather val-0 entries against the zero-initialized
VMEM scratch and contribute nothing."""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.codegen import Schedule
from repro.kernels.backend import resolve_backend

from . import lowering_gpu, lowering_tpu

__all__ = ["FusedLayout", "build_layout", "make_solver", "make_packed_solver",
           "select_lowering"]


def select_lowering(backend=None):
    """Lowering module for a backend spec — the single dispatch point the
    backend-matrix CI job asserts on."""
    bk = resolve_backend(backend)
    return lowering_gpu if bk.platform == "gpu" else lowering_tpu


@dataclasses.dataclass(frozen=True)
class FusedLayout:
    """Level-order permuted ELL layout with chunk-aligned level boundaries.

    ``perm_rows[p]`` = original row at position p (pad -> n).
    ``pos[i]``       = position of original row i.
    ``cols``         (K, n_pad) dependency *positions* (pad: points at a
                     pad position whose value is always 0).
    ``val_src``/``diag_src`` map packed values back to the source matrix's
    ``data`` indices (-1 padding) — the value-only refresh maps.
    ``spans``        chunk-aligned ``(offset, padded_rows)`` of each
                     wavefront — the launch boundaries of the GPU
                     (level-scheduled) lowering; the TPU grid walk ignores
                     them.
    """

    n: int
    n_pad: int
    chunk: int
    K: int
    perm_rows: np.ndarray
    pos: np.ndarray
    cols: np.ndarray
    vals: np.ndarray
    diag: np.ndarray
    val_src: Optional[np.ndarray] = None
    diag_src: Optional[np.ndarray] = None
    spans: tuple = ()

    @property
    def padded_flops(self) -> int:
        return 2 * self.K * self.n_pad + self.n_pad


def build_layout(schedule: Schedule, chunk: int = 512) -> FusedLayout:
    n = schedule.n
    # A coarsened slab's sub-slabs are NOT mutually independent, so the
    # chunk walk must keep every wavefront in its own chunk-aligned span —
    # expand chains back to their sub-slabs (the fused solve is already a
    # single segment; coarsening has nothing left to merge here).
    slabs = [sub for slab in schedule.slabs for sub in slab.sub_slabs()]
    K = max(s.K for s in slabs)
    # positions: wavefronts in order, each padded to a chunk multiple
    spans = []
    off = 0
    for slab in slabs:
        r_pad = int(np.ceil(slab.R / chunk) * chunk)
        spans.append((off, r_pad))
        off += r_pad
    n_pad = off
    perm_rows = np.full((n_pad,), n, dtype=np.int32)
    pos = np.zeros((n + 1,), dtype=np.int64)
    for (o, _), slab in zip(spans, slabs):
        perm_rows[o : o + slab.R] = slab.rows
        pos[slab.rows] = np.arange(o, o + slab.R)
    pos[n] = n_pad - 1  # scratch row maps to the last pad position

    val_dtype = slabs[0].vals.dtype
    cols = np.zeros((K, n_pad), dtype=np.int32)
    vals = np.zeros((K, n_pad), dtype=val_dtype)
    diag = np.ones((n_pad,), dtype=val_dtype)
    val_src = np.full((K, n_pad), -1, dtype=np.int64)
    diag_src = np.full((n_pad,), -1, dtype=np.int64)
    for (o, _), slab in zip(spans, slabs):
        k = slab.K
        # remap dependency columns (original row ids) to positions
        cols[:k, o : o + slab.R] = pos[slab.cols]
        vals[:k, o : o + slab.R] = slab.vals
        diag[o : o + slab.R] = slab.diag
        if slab.val_src is not None:
            val_src[:k, o : o + slab.R] = slab.val_src
            diag_src[o : o + slab.R] = slab.diag_src
    return FusedLayout(
        n=n, n_pad=n_pad, chunk=chunk, K=K,
        perm_rows=perm_rows, pos=pos, cols=cols, vals=vals, diag=diag,
        val_src=val_src, diag_src=diag_src,
        spans=tuple((int(o), int(rp)) for o, rp in spans),
    )


def make_solver(
    schedule: Schedule,
    *,
    backend=None,
    interpret: Optional[bool] = None,
    chunk: int = 512,
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    bk = resolve_backend(backend, interpret=interpret)
    low = select_lowering(bk)
    lay = build_layout(schedule, chunk)
    perm_rows = jnp.asarray(lay.perm_rows)
    pos = jnp.asarray(lay.pos[: lay.n])
    cols = jnp.asarray(lay.cols)
    vals = jnp.asarray(lay.vals)
    diag = jnp.asarray(lay.diag)

    kw = {"spans": lay.spans} if bk.platform == "gpu" else {}

    def solve(b: jnp.ndarray) -> jnp.ndarray:
        """b: (n,) or (n, m) — one fused dispatch either way (TPU: one
        sequential-grid kernel; GPU: one launch per wavefront span)."""
        dt = b.dtype
        kern = low.fused_solve_batched if b.ndim == 2 else low.fused_solve
        b_ext = jnp.concatenate([b, jnp.zeros((1,) + b.shape[1:], dt)])
        bl_perm = b_ext[perm_rows]  # pad rows -> b_ext[n] = 0
        xp = kern(
            bl_perm, cols, vals.astype(dt), diag.astype(dt),
            chunk=lay.chunk, interpret=bk.interpret, **kw,
        )
        return xp[pos]

    return solve


def make_packed_solver(
    schedule: Schedule,
    *,
    backend=None,
    interpret: Optional[bool] = None,
    chunk: int = 512,
):
    """Refresh-capable fused solver: identical kernel and layout to
    :func:`make_solver` (the fused kernel already executes in permuted
    space), but the packed ``vals``/``diag`` buffers ride as runtime
    arguments so a value-only refresh swaps them without re-tracing.

    Returns ``(solve(b, values), values0, repack, layout)``."""
    bk = resolve_backend(backend, interpret=interpret)
    low = select_lowering(bk)
    lay = build_layout(schedule, chunk)
    perm_rows = jnp.asarray(lay.perm_rows)
    pos = jnp.asarray(lay.pos[: lay.n])
    cols = jnp.asarray(lay.cols)
    values0 = (jnp.asarray(lay.vals), jnp.asarray(lay.diag))
    vsrc, dsrc = lay.val_src, lay.diag_src

    def repack(target_data):
        from repro.core.packed import gather_src

        return (jnp.asarray(gather_src(target_data, vsrc, 0.0, lay.vals.dtype)),
                jnp.asarray(gather_src(target_data, dsrc, 1.0, lay.diag.dtype)))

    kw = {"spans": lay.spans} if bk.platform == "gpu" else {}

    def solve(b: jnp.ndarray, values) -> jnp.ndarray:
        """b: (n,) or (n, m) — one fused dispatch either way."""
        vals, diag = values
        dt = b.dtype
        kern = low.fused_solve_batched if b.ndim == 2 else low.fused_solve
        b_ext = jnp.concatenate([b, jnp.zeros((1,) + b.shape[1:], dt)])
        bl_perm = b_ext[perm_rows]  # pad rows -> b_ext[n] = 0
        xp = kern(
            bl_perm, cols, vals.astype(dt), diag.astype(dt),
            chunk=lay.chunk, interpret=bk.interpret, **kw,
        )
        return xp[pos]

    return solve, values0, repack, lay
