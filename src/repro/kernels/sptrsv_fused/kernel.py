"""Back-compat shim: the TPU (Mosaic) lowering moved to
:mod:`.lowering_tpu` when the kernel layer grew the backend abstraction
(:mod:`repro.kernels.backend`); the pallas-triton twin is
:mod:`.lowering_gpu`.  Import from the lowering modules (or dispatch via
``ops.make_solver(..., backend=...)``) in new code."""
from .lowering_tpu import (  # noqa: F401
    fused_kernel,
    fused_kernel_batched,
    fused_solve,
    fused_solve_batched,
)

__all__ = ["fused_kernel", "fused_solve", "fused_kernel_batched",
           "fused_solve_batched"]
