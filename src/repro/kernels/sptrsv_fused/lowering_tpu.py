"""TPU (Mosaic) lowering: the ENTIRE SpTRSV in one ``pallas_call``.

This is the ``platform="tpu"`` implementation behind
:mod:`repro.kernels.backend`; the pallas-triton twin (level-scheduled
launches over the same fused layout — a GPU has no sequential grid to ride)
lives in :mod:`.lowering_gpu`.

This is the TPU-native analogue of the paper's synchronization-barrier
removal, taken to its limit: instead of one kernel launch (CPU: one barrier)
per level, the whole solve is a single kernel whose grid walks fixed-size
row *chunks* in level order.  TPU grid steps with ``ARBITRARY`` dimension
semantics execute **sequentially on one core**, which is exactly the
dependence order we need — cross-level ordering is enforced by the grid, and
``x`` never leaves VMEM.

Layout trick that removes dynamic scatter: rows are stored in **level-order
permutation**.  Chunk ``c`` writes positions ``[c*C, (c+1)*C)`` of the
permuted solution — a contiguous dynamic-offset store (supported) instead of
an arbitrary scatter (not supported).  Dependency columns are remapped to
positions, so gathers read the same permuted vector.  Chunks never straddle a
level boundary (codegen pads), so every gather hits positions written by
earlier grid steps.

VMEM working set: x_perm scratch (n_pad f32) + one (K, C) cols/vals block +
three (C,) vectors — fits for n up to ~3M rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

__all__ = ["fused_kernel", "fused_solve", "fused_kernel_batched",
           "fused_solve_batched"]


def _chunk_start(c, C):
    """Dynamic store offset in the platform's default integer dtype.
    ``program_id`` is int32; with jax_enable_x64 the other index components
    of a multi-axis ``pl.store`` default to int64, and interpret-mode
    ``dynamic_slice`` rejects mixed index dtypes."""
    return (c * C).astype(jnp.asarray(0).dtype)


def fused_kernel(bl_ref, cols_ref, vals_ref, diag_ref, out_ref, x_scr):
    """Grid step = one chunk of C rows inside a single level.

    bl/diag: (C,), cols/vals: (K, C); out: (n_pad,) written incrementally;
    x_scr: (n_pad,) VMEM scratch holding the permuted solution so far.
    """
    c = pl.program_id(0)
    C = bl_ref.shape[0]

    @pl.when(c == 0)
    def _init():
        x_scr[...] = jnp.zeros_like(x_scr)

    x = x_scr[...]
    acc = bl_ref[...]
    K = cols_ref.shape[0]
    for k in range(K):  # unrolled; K static (matrix-specialized program)
        acc = acc - vals_ref[k, :] * jnp.take(x, cols_ref[k, :], mode="clip")
    xl = acc / diag_ref[...]
    # contiguous dynamic-offset store — no scatter needed
    start = _chunk_start(c, C)
    pl.store(x_scr, (pl.dslice(start, C),), xl)
    pl.store(out_ref, (pl.dslice(start, C),), xl)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def fused_solve(
    bl_perm: jnp.ndarray,   # (n_pad,) b in level-order positions
    cols: jnp.ndarray,      # (K, n_pad) deps remapped to positions
    vals: jnp.ndarray,      # (K, n_pad)
    diag: jnp.ndarray,      # (n_pad,)
    *,
    chunk: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    K, n_pad = cols.shape
    assert n_pad % chunk == 0
    grid = (n_pad // chunk,)
    return pl.pallas_call(
        fused_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((chunk,), lambda c: (c,)),      # bl
            pl.BlockSpec((K, chunk), lambda c: (0, c)),  # cols
            pl.BlockSpec((K, chunk), lambda c: (0, c)),  # vals
            pl.BlockSpec((chunk,), lambda c: (c,)),      # diag
        ],
        # full-length output; each step stores its chunk
        out_specs=pl.BlockSpec((n_pad,), lambda c: (0,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), bl_perm.dtype),
        scratch_shapes=[pltpu.VMEM((n_pad,), bl_perm.dtype)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=(pltpu.ARBITRARY,),  # sequential grid = dep order
        ),
        interpret=interpret,
        name="sptrsv_fused",
    )(bl_perm, cols, vals, diag)


def fused_kernel_batched(bl_ref, cols_ref, vals_ref, diag_ref, out_ref, x_scr):
    """Multi-RHS grid step: one chunk of C rows × all m columns.

    bl: (C, m), cols/vals: (K, C), diag: (C,); out/x_scr: (n_pad, m).
    Same contiguous-store layout trick as the single-RHS kernel — the chunk
    writes rows [c*C, (c+1)*C) of the permuted solution, now as a (C, m)
    block whose minor (lane) dimension is the batch."""
    c = pl.program_id(0)
    C = bl_ref.shape[0]

    @pl.when(c == 0)
    def _init():
        x_scr[...] = jnp.zeros_like(x_scr)

    x = x_scr[...]                      # (n_pad, m)
    acc = bl_ref[...]                   # (C, m)
    K = cols_ref.shape[0]
    for k in range(K):  # unrolled; K static (matrix-specialized program)
        dep = jnp.take(x, cols_ref[k, :], axis=0, mode="clip")  # (C, m)
        acc = acc - vals_ref[k, :][:, None] * dep
    xl = acc / diag_ref[...][:, None]
    # contiguous dynamic-offset store along rows — no scatter needed
    start = _chunk_start(c, C)
    pl.store(x_scr, (pl.dslice(start, C), slice(None)), xl)
    pl.store(out_ref, (pl.dslice(start, C), slice(None)), xl)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def fused_solve_batched(
    bl_perm: jnp.ndarray,   # (n_pad, m) b in level-order positions
    cols: jnp.ndarray,      # (K, n_pad) deps remapped to positions
    vals: jnp.ndarray,      # (K, n_pad)
    diag: jnp.ndarray,      # (n_pad,)
    *,
    chunk: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    K, n_pad = cols.shape
    m = bl_perm.shape[1]
    assert n_pad % chunk == 0
    grid = (n_pad // chunk,)
    return pl.pallas_call(
        fused_kernel_batched,
        grid=grid,
        in_specs=[
            pl.BlockSpec((chunk, m), lambda c: (c, 0)),  # bl
            pl.BlockSpec((K, chunk), lambda c: (0, c)),  # cols
            pl.BlockSpec((K, chunk), lambda c: (0, c)),  # vals
            pl.BlockSpec((chunk,), lambda c: (c,)),      # diag
        ],
        # full-length output; each step stores its chunk of rows
        out_specs=pl.BlockSpec((n_pad, m), lambda c: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, m), bl_perm.dtype),
        scratch_shapes=[pltpu.VMEM((n_pad, m), bl_perm.dtype)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=(pltpu.ARBITRARY,),  # sequential grid = dep order
        ),
        interpret=interpret,
        name="sptrsv_fused_batched",
    )(bl_perm, cols, vals, diag)
