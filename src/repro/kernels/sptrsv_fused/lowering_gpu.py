"""GPU (pallas-triton) lowering of the fused SpTRSV layout.

A GPU grid gives no cross-block ordering guarantee, so the TPU trick —
one ``pallas_call`` whose sequential (``ARBITRARY``) grid walks chunks in
dependence order with ``x`` resident in VMEM — does not port.  What ports
is the layout: rows stay in **level-order permutation** with chunk-aligned
wavefront spans and dependency columns pre-remapped to positions, and the
executor walks the spans with one pallas-triton launch per wavefront
(the CSR level-scheduled shape of SNIPPETS.md Snippet 1 and of cuSPARSE's
``csrsv2``: kernel-launch boundaries are the only synchronization, all
thread blocks inside a launch are independent).

Because every span is a contiguous position range, each launch's solution
lands with a static-offset ``dynamic_update_slice`` — the same no-scatter
property the TPU grid walk has — and the flat ``cols``/``vals``/``diag``
buffers are sliced per span at trace time, so the value-only refresh path
(buffers as runtime jit arguments) works unchanged.

The per-span compute kernel is exactly the GPU level kernel
(:mod:`repro.kernels.sptrsv_level.lowering_gpu`): gather loads from the
GMEM-resident permuted solution, unrolled static-K FMA, one divide.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.sptrsv_level.lowering_gpu import (
    level_solve_blocks,
    level_solve_blocks_batched,
)

__all__ = ["fused_solve", "fused_solve_batched"]


@functools.partial(jax.jit, static_argnames=("chunk", "spans", "interpret"))
def fused_solve(
    bl_perm: jnp.ndarray,   # (n_pad,) b in level-order positions
    cols: jnp.ndarray,      # (K, n_pad) deps remapped to positions
    vals: jnp.ndarray,      # (K, n_pad)
    diag: jnp.ndarray,      # (n_pad,)
    *,
    chunk: int = 512,
    spans: tuple = (),      # ((off, r_pad), ...) chunk-aligned wavefronts
    interpret: bool = True,
) -> jnp.ndarray:
    """Level-scheduled walk of the fused layout; one launch per wavefront."""
    K, n_pad = cols.shape
    assert spans, "GPU fused lowering needs the layout's wavefront spans"
    x = jnp.zeros((n_pad,), bl_perm.dtype)
    for off, rp in spans:
        bl_s = lax.slice_in_dim(bl_perm, off, off + rp)
        cols_s = lax.slice(cols, (0, off), (K, off + rp))
        vals_s = lax.slice(vals, (0, off), (K, off + rp))
        diag_s = lax.slice_in_dim(diag, off, off + rp)
        xl = level_solve_blocks(
            x, bl_s, cols_s, vals_s, diag_s,
            block_rows=min(chunk, rp), interpret=interpret,
        )
        x = lax.dynamic_update_slice_in_dim(x, xl, off, 0)
    return x


@functools.partial(jax.jit, static_argnames=("chunk", "spans", "interpret"))
def fused_solve_batched(
    bl_perm: jnp.ndarray,   # (n_pad, m) b in level-order positions
    cols: jnp.ndarray,      # (K, n_pad) deps remapped to positions
    vals: jnp.ndarray,      # (K, n_pad)
    diag: jnp.ndarray,      # (n_pad,)
    *,
    chunk: int = 512,
    spans: tuple = (),
    interpret: bool = True,
) -> jnp.ndarray:
    """Multi-RHS level-scheduled walk; the batch rides the lane dimension of
    every per-wavefront launch."""
    K, n_pad = cols.shape
    assert spans, "GPU fused lowering needs the layout's wavefront spans"
    m = bl_perm.shape[1]
    x = jnp.zeros((n_pad, m), bl_perm.dtype)
    for off, rp in spans:
        bl_s = lax.slice(bl_perm, (off, 0), (off + rp, m))
        cols_s = lax.slice(cols, (0, off), (K, off + rp))
        vals_s = lax.slice(vals, (0, off), (K, off + rp))
        diag_s = lax.slice_in_dim(diag, off, off + rp)
        xl = level_solve_blocks_batched(
            x, bl_s, cols_s, vals_s, diag_s,
            block_rows=min(chunk, rp), interpret=interpret,
        )
        x = lax.dynamic_update_slice(x, xl, (off, 0))
    return x
