"""Pallas TPU kernel: batched dense diagonal-block apply for block SpTRSV.

The paper lists "forming dense blocks to improve the locality" (ref [22]:
dense BLAS on off-diagonal blocks) as a planned optimization.  On TPU the
profitable mapping is the MXU: diagonal blocks of size T are inverted once at
preprocessing time, and the solve applies

    x_blk = Dinv_blk @ (b_blk - s_blk)

as a batched (T, T) @ (T,) product.  The kernel computes a batch of such
products per grid step (one (BB, T, T) tile), keeping everything in VMEM and
feeding the MXU with T=128-aligned tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

__all__ = ["block_apply_kernel", "block_apply"]


def block_apply_kernel(dinv_ref, rhs_ref, out_ref):
    """dinv: (BB, T, T), rhs: (BB, T) -> out: (BB, T)."""
    d = dinv_ref[...]
    r = rhs_ref[...]
    # batched matvec on the MXU: (BB, T, T) @ (BB, T, 1)
    out_ref[...] = jax.lax.dot_general(
        d, r[..., None],
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )[..., 0].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("batch_block", "interpret"))
def block_apply(
    dinv: jnp.ndarray,  # (NB, T, T) precomputed block inverses
    rhs: jnp.ndarray,   # (NB, T)
    *,
    batch_block: int = 8,
    interpret: bool = True,
) -> jnp.ndarray:
    NB, T, _ = dinv.shape
    assert NB % batch_block == 0, (NB, batch_block)
    return pl.pallas_call(
        block_apply_kernel,
        grid=(NB // batch_block,),
        in_specs=[
            pl.BlockSpec((batch_block, T, T), lambda i: (i, 0, 0)),
            pl.BlockSpec((batch_block, T), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((batch_block, T), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((NB, T), rhs.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=(pltpu.PARALLEL,),
        ),
        interpret=interpret,
        name="trsm_block_apply",
    )(dinv, rhs)
