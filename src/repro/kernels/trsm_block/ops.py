"""Jit'd wrapper: block-dense SpTRSV path.

Partitions the matrix into contiguous row blocks of size T; diagonal T×T
blocks are densified and inverted at preprocessing (host), off-block
dependencies stay in ELL slabs.  Solve walks blocks sequentially:

    s_blk  = ELL_offblock @ x          (gather/FMA — spmv-style)
    x_blk  = Dinv_blk @ (b_blk - s_blk)   (MXU kernel)

Profitable when the matrix has dense-ish diagonal blocks (banded /
reordered matrices — the paper's ref [22] scenario).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.csr import CSRMatrix
from repro.kernels.backend import resolve_backend

from . import lowering_gpu, lowering_tpu

__all__ = ["make_block_apply", "make_block_solver", "select_lowering"]


def select_lowering(backend=None):
    """Lowering module for a backend spec — the single dispatch point the
    backend-matrix CI job asserts on."""
    bk = resolve_backend(backend)
    return lowering_gpu if bk.platform == "gpu" else lowering_tpu


def _dot_apply(dinv: jnp.ndarray, rhs: jnp.ndarray) -> jnp.ndarray:
    """Reference batched block apply: (B, T, T) @ (B, T[, m]) via
    ``dot_general``, accumulating in the RHS dtype (float64-exact under
    x64 — the interpret/CPU path the differential fuzz relies on)."""
    r = rhs[..., None] if rhs.ndim == 2 else rhs
    out = jax.lax.dot_general(
        dinv.astype(rhs.dtype), r,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=rhs.dtype,
    )
    return out[..., 0] if rhs.ndim == 2 else out


def make_block_apply(backend=None, *, kernel: str = "auto",
                     batch_block: int = 8) -> Callable:
    """Batched diagonal-block apply ``(B, T, T) × (B, T[, m]) -> (B, T[, m])``
    for the blocked (supernodal) executors.

    ``kernel`` picks the implementation:

    * ``"auto"``   — the pallas lowering on compiled tpu/gpu backends, the
      ``dot_general`` path under the interpreter / on CPU (the pallas
      interpreter is a correctness harness, far too slow for a hot loop);
    * ``"pallas"`` — force the backend's pallas lowering (interpret-mode
      backends run it under the interpreter — the CI path that exercises
      both lowering families);
    * ``"jnp"``    — force the ``dot_general`` path.

    The pallas kernels are single-vector ``(NB, T)``; batched RHS always
    takes the ``dot_general`` path.  ``NB`` is padded up to a
    ``batch_block`` multiple with identity blocks / zero rows to satisfy the
    kernel's grid, and the pad is sliced off the result.  The kernels
    accumulate in float32 — fine for f32 solves; float64 pipelines should
    keep ``kernel="auto"``/``"jnp"`` off-hardware."""
    assert kernel in ("auto", "pallas", "jnp"), kernel
    bk = resolve_backend(backend)
    use_pallas = kernel == "pallas" or (kernel == "auto" and not bk.interpret)
    low = select_lowering(bk)

    def apply(dinv: jnp.ndarray, rhs: jnp.ndarray) -> jnp.ndarray:
        if not use_pallas or rhs.ndim != 2:
            return _dot_apply(dinv, rhs)
        B, T = rhs.shape
        bb = min(batch_block, B) if B else 1
        B_pad = -(-B // bb) * bb
        if B_pad != B:
            pad = B_pad - B
            dinv = jnp.concatenate(
                [dinv, jnp.broadcast_to(jnp.eye(T, dtype=dinv.dtype),
                                        (pad, T, T))])
            rhs = jnp.concatenate([rhs, jnp.zeros((pad, T), rhs.dtype)])
        out = low.block_apply(dinv.astype(rhs.dtype), rhs,
                              batch_block=bb, interpret=bk.interpret)
        return out[:B]

    return apply


def make_block_solver(
    L: CSRMatrix,
    *,
    T: int = 128,
    backend=None,
    interpret: Optional[bool] = None,
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    bk = resolve_backend(backend, interpret=interpret)
    low = select_lowering(bk)
    n = L.n
    nb = int(np.ceil(n / T))
    n_pad = nb * T
    dense = np.zeros((nb, T, T), np.float64)
    # off-block deps in ELL per block row
    off_cols, off_vals, maxk = [], [], 1
    for b in range(nb):
        oc, ov = [], []
        for r in range(b * T, min((b + 1) * T, n)):
            c, v = L.row(r)
            inblk = c >= b * T
            dense[b, r - b * T, c[inblk] - b * T] = v[inblk]
            oc.append(c[~inblk])
            ov.append(v[~inblk])
        k = max((len(x) for x in oc), default=0)
        maxk = max(maxk, k)
        off_cols.append(oc)
        off_vals.append(ov)
    for b in range(nb):  # pad rows beyond n: identity
        for r in range(T):
            if b * T + r >= n:
                dense[b, r, r] = 1.0
    dinv = np.stack([np.linalg.inv(dense[b]) for b in range(nb)])
    cols = np.zeros((nb, maxk, T), np.int32)
    vals = np.zeros((nb, maxk, T), np.float32)
    for b in range(nb):
        for r, (oc, ov) in enumerate(zip(off_cols[b], off_vals[b])):
            cols[b, : len(oc), r] = oc
            vals[b, : len(ov), r] = ov
    dinv_d = jnp.asarray(dinv.astype(np.float32))
    cols_d = jnp.asarray(cols)
    vals_d = jnp.asarray(vals)

    def solve(b_vec: jnp.ndarray) -> jnp.ndarray:
        dt = b_vec.dtype
        bp = jnp.zeros((n_pad,), dt).at[:n].set(b_vec)
        x = jnp.zeros((n_pad,), dt)
        for blk in range(nb):
            s = jnp.sum(vals_d[blk].astype(dt) * x[cols_d[blk]], axis=0)  # (T,)
            rhs = (bp[blk * T : (blk + 1) * T] - s)[None, :]  # (1, T)
            xb = low.block_apply(
                dinv_d[blk][None].astype(dt), rhs, batch_block=1,
                interpret=bk.interpret,
            )[0]
            x = x.at[blk * T : (blk + 1) * T].set(xb)
        return x[:n]

    return solve
