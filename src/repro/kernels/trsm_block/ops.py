"""Jit'd wrapper: block-dense SpTRSV path.

Partitions the matrix into contiguous row blocks of size T; diagonal T×T
blocks are densified and inverted at preprocessing (host), off-block
dependencies stay in ELL slabs.  Solve walks blocks sequentially:

    s_blk  = ELL_offblock @ x          (gather/FMA — spmv-style)
    x_blk  = Dinv_blk @ (b_blk - s_blk)   (MXU kernel)

Profitable when the matrix has dense-ish diagonal blocks (banded /
reordered matrices — the paper's ref [22] scenario).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.csr import CSRMatrix
from repro.kernels.backend import resolve_backend

from . import lowering_gpu, lowering_tpu

__all__ = ["make_block_solver", "select_lowering"]


def select_lowering(backend=None):
    """Lowering module for a backend spec — the single dispatch point the
    backend-matrix CI job asserts on."""
    bk = resolve_backend(backend)
    return lowering_gpu if bk.platform == "gpu" else lowering_tpu


def make_block_solver(
    L: CSRMatrix,
    *,
    T: int = 128,
    backend=None,
    interpret: Optional[bool] = None,
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    bk = resolve_backend(backend, interpret=interpret)
    low = select_lowering(bk)
    n = L.n
    nb = int(np.ceil(n / T))
    n_pad = nb * T
    dense = np.zeros((nb, T, T), np.float64)
    # off-block deps in ELL per block row
    off_cols, off_vals, maxk = [], [], 1
    for b in range(nb):
        oc, ov = [], []
        for r in range(b * T, min((b + 1) * T, n)):
            c, v = L.row(r)
            inblk = c >= b * T
            dense[b, r - b * T, c[inblk] - b * T] = v[inblk]
            oc.append(c[~inblk])
            ov.append(v[~inblk])
        k = max((len(x) for x in oc), default=0)
        maxk = max(maxk, k)
        off_cols.append(oc)
        off_vals.append(ov)
    for b in range(nb):  # pad rows beyond n: identity
        for r in range(T):
            if b * T + r >= n:
                dense[b, r, r] = 1.0
    dinv = np.stack([np.linalg.inv(dense[b]) for b in range(nb)])
    cols = np.zeros((nb, maxk, T), np.int32)
    vals = np.zeros((nb, maxk, T), np.float32)
    for b in range(nb):
        for r, (oc, ov) in enumerate(zip(off_cols[b], off_vals[b])):
            cols[b, : len(oc), r] = oc
            vals[b, : len(ov), r] = ov
    dinv_d = jnp.asarray(dinv.astype(np.float32))
    cols_d = jnp.asarray(cols)
    vals_d = jnp.asarray(vals)

    def solve(b_vec: jnp.ndarray) -> jnp.ndarray:
        dt = b_vec.dtype
        bp = jnp.zeros((n_pad,), dt).at[:n].set(b_vec)
        x = jnp.zeros((n_pad,), dt)
        for blk in range(nb):
            s = jnp.sum(vals_d[blk].astype(dt) * x[cols_d[blk]], axis=0)  # (T,)
            rhs = (bp[blk * T : (blk + 1) * T] - s)[None, :]  # (1, T)
            xb = low.block_apply(
                dinv_d[blk][None].astype(dt), rhs, batch_block=1,
                interpret=bk.interpret,
            )[0]
            x = x.at[blk * T : (blk + 1) * T].set(xb)
        return x[:n]

    return solve
