"""Pure-jnp oracle for the batched block apply."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["block_apply_ref"]


def block_apply_ref(dinv, rhs):
    return jnp.einsum("bij,bj->bi", dinv, rhs)
