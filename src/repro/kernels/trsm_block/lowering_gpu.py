"""GPU (pallas-triton) lowering: batched dense diagonal-block apply.

Twin of :mod:`.lowering_tpu` with the Mosaic-isms removed: the batched
matvec maps to tensor-core ``dot`` instead of the MXU, the grid is an
ordinary parallel launch, and there are no TPU compiler params.  Same
signature, tiling, and padding contract.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["block_apply_kernel", "block_apply"]


def block_apply_kernel(dinv_ref, rhs_ref, out_ref):
    """dinv: (BB, T, T), rhs: (BB, T) -> out: (BB, T)."""
    d = dinv_ref[...]
    r = rhs_ref[...]
    out_ref[...] = jax.lax.dot_general(
        d, r[..., None],
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )[..., 0].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("batch_block", "interpret"))
def block_apply(
    dinv: jnp.ndarray,  # (NB, T, T) precomputed block inverses
    rhs: jnp.ndarray,   # (NB, T)
    *,
    batch_block: int = 8,
    interpret: bool = True,
) -> jnp.ndarray:
    NB, T, _ = dinv.shape
    assert NB % batch_block == 0, (NB, batch_block)
    return pl.pallas_call(
        block_apply_kernel,
        grid=(NB // batch_block,),
        in_specs=[
            pl.BlockSpec((batch_block, T, T), lambda i: (i, 0, 0)),
            pl.BlockSpec((batch_block, T), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((batch_block, T), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((NB, T), rhs.dtype),
        interpret=interpret,
        name="trsm_block_apply_gpu",
    )(dinv, rhs)
