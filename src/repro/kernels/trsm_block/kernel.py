"""Back-compat shim: the TPU (Mosaic) lowering moved to
:mod:`.lowering_tpu` when the kernel layer grew the backend abstraction
(:mod:`repro.kernels.backend`); the pallas-triton twin is
:mod:`.lowering_gpu`.  Import from the lowering modules (or dispatch via
``ops.make_block_solver(..., backend=...)``) in new code."""
from .lowering_tpu import block_apply, block_apply_kernel  # noqa: F401

__all__ = ["block_apply_kernel", "block_apply"]
