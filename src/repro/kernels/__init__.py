"""Pallas kernels for the SpTRSV hot paths (the compute layer the paper
optimizes with generated code):

* ``sptrsv_level``  — one level (wavefront) as gather/FMA/reduce over an ELL slab
* ``sptrsv_fused``  — the whole solve in ONE dispatch: a single sequential-grid
                      pallas_call on TPU (x resident in VMEM — the TPU analogue
                      of removing all synchronization barriers), a
                      level-scheduled launch walk of the same layout on GPU
* ``spmv_ell``      — ELL SpMV (the rewriting method's per-solve b' = E b)
* ``trsm_block``    — batched dense diagonal-block apply (MXU / tensor cores)

Each package: ``lowering_tpu.py`` (Mosaic) and ``lowering_gpu.py``
(pallas-triton) exposing the same entry points, ``ops.py`` (jit wrapper that
dispatches on a ``backend=`` knob via :mod:`repro.kernels.backend`), and
``ref.py`` (pure-jnp oracle).  ``kernel.py`` remains as a back-compat shim
re-exporting the TPU lowering.  Both lowering families are validated under
the pallas interpreter on CPU (``backend="interpret"`` / ``"interpret:gpu"``);
TPU v5e and CUDA GPUs are the compiled targets.
"""
from repro.kernels.backend import (  # noqa: F401
    BACKENDS,
    KernelBackend,
    default_backend_name,
    resolve_backend,
)

__all__ = ["KernelBackend", "BACKENDS", "resolve_backend",
           "default_backend_name"]
