"""Pallas TPU kernels for the SpTRSV hot paths (the compute layer the paper
optimizes with generated code):

* ``sptrsv_level``  — one level (wavefront) as gather/FMA/reduce over an ELL slab
* ``sptrsv_fused``  — the whole solve in ONE pallas_call, x resident in VMEM
                      (the TPU analogue of removing all synchronization barriers)
* ``spmv_ell``      — ELL SpMV (the rewriting method's per-solve b' = E b)
* ``trsm_block``    — batched dense diagonal-block apply (MXU; paper ref [22])

Each package: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper),
ref.py (pure-jnp oracle).  Kernels are validated in interpret mode on CPU;
TPU v5e is the lowering target.
"""
