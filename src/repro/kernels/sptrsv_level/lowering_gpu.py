"""GPU (pallas-triton) lowering: one SpTRSV level in ELL-slab form.

Same entry points and semantics as :mod:`.lowering_tpu`, with the memory
model a Triton SpTRSV actually uses (the CSR level-scheduled shape of the
SNIPPETS.md Snippet 1 exemplar and cuSPARSE's level-scheduled solve):

* ``x`` is **not** staged into on-chip memory — it stays a global-memory
  operand and each dependency is a gather **load** (``pl.load`` with an
  int32 index vector → per-lane pointer arithmetic in Triton), because a
  GPU has no VMEM-sized scratch to hold a whole solution vector;
* the grid maps row blocks of the level to thread blocks (one
  ``program_id`` axis, all blocks independent — level scheduling provides
  the only synchronization, between kernel launches);
* the K loop is unrolled at trace time exactly like the TPU lowering — K
  is a per-level compile-time constant, the "generated code" is
  specialized per level.

Block sizes should be powers of two for the real Triton lowering
(``tl.arange`` constraint); the shared padding helper in ``ops.py`` already
rounds row blocks to 128-multiples, which CI exercises through the
interpret backend (``backend="interpret:gpu"``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "level_kernel",
    "level_solve_blocks",
    "level_kernel_batched",
    "level_solve_blocks_batched",
]


def level_kernel(x_ref, bl_ref, cols_ref, vals_ref, diag_ref, out_ref):
    """One (K, BR) slab block; x_ref: full solution vector in GMEM."""
    acc = bl_ref[...]
    K = cols_ref.shape[0]
    for k in range(K):  # unrolled: K is static per level
        acc = acc - vals_ref[k, :] * pl.load(x_ref, (cols_ref[k, :],))
    out_ref[...] = acc / diag_ref[...]


def level_kernel_batched(x_ref, bl_ref, cols_ref, vals_ref, diag_ref, out_ref):
    """Multi-RHS variant: x_ref (n_pad, m) in GMEM, bl/out (BR, m).

    The gather pulls whole (m,) solution rows via a broadcast 2-D index
    load — rows from the ELL columns, all m batch columns per row."""
    acc = bl_ref[...]                    # (BR, m)
    K, _ = cols_ref.shape
    m = bl_ref.shape[1]
    batch_ix = jnp.arange(m, dtype=jnp.int32)[None, :]
    for k in range(K):  # unrolled: K is static per level
        dep = pl.load(x_ref, (cols_ref[k, :][:, None], batch_ix))  # (BR, m)
        acc = acc - vals_ref[k, :][:, None] * dep
    out_ref[...] = acc / diag_ref[...][:, None]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def level_solve_blocks(
    x_pad: jnp.ndarray,    # (n_pad,) current solution incl. scratch slot
    bl: jnp.ndarray,       # (R_pad,) b gathered at the level's rows
    cols: jnp.ndarray,     # (K, R_pad) int32
    vals: jnp.ndarray,     # (K, R_pad)
    diag: jnp.ndarray,     # (R_pad,)
    *,
    block_rows: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """Solve one level; returns xl (R_pad,).  Same contract as the TPU
    lowering — ops-layer packing is backend-agnostic."""
    K, R = cols.shape
    assert R % block_rows == 0, (R, block_rows)
    n_pad = x_pad.shape[0]
    grid = (R // block_rows,)
    return pl.pallas_call(
        level_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_pad,), lambda i: (0,)),            # x: full, GMEM
            pl.BlockSpec((block_rows,), lambda i: (i,)),       # bl
            pl.BlockSpec((K, block_rows), lambda i: (0, i)),   # cols
            pl.BlockSpec((K, block_rows), lambda i: (0, i)),   # vals
            pl.BlockSpec((block_rows,), lambda i: (i,)),       # diag
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((R,), x_pad.dtype),
        interpret=interpret,
        name="sptrsv_level_gpu",
    )(x_pad, bl, cols, vals, diag)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def level_solve_blocks_batched(
    x_pad: jnp.ndarray,    # (n_pad, m) current solution incl. scratch row
    bl: jnp.ndarray,       # (R_pad, m) b gathered at the level's rows
    cols: jnp.ndarray,     # (K, R_pad) int32
    vals: jnp.ndarray,     # (K, R_pad)
    diag: jnp.ndarray,     # (R_pad,)
    *,
    block_rows: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """Solve one level for m RHS columns at once; returns xl (R_pad, m)."""
    K, R = cols.shape
    assert R % block_rows == 0, (R, block_rows)
    n_pad, m = x_pad.shape
    grid = (R // block_rows,)
    return pl.pallas_call(
        level_kernel_batched,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_pad, m), lambda i: (0, 0)),            # x: full
            pl.BlockSpec((block_rows, m), lambda i: (i, 0)),       # bl
            pl.BlockSpec((K, block_rows), lambda i: (0, i)),       # cols
            pl.BlockSpec((K, block_rows), lambda i: (0, i)),       # vals
            pl.BlockSpec((block_rows,), lambda i: (i,)),           # diag
        ],
        out_specs=pl.BlockSpec((block_rows, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, m), x_pad.dtype),
        interpret=interpret,
        name="sptrsv_level_batched_gpu",
    )(x_pad, bl, cols, vals, diag)
