"""Back-compat shim: the TPU (Mosaic) lowering moved to
:mod:`.lowering_tpu` when the kernel layer grew the backend abstraction
(:mod:`repro.kernels.backend`); the pallas-triton twin is
:mod:`.lowering_gpu`.  Import from the lowering modules (or dispatch via
``ops.make_solver(..., backend=...)``) in new code."""
from .lowering_tpu import (  # noqa: F401
    level_kernel,
    level_kernel_batched,
    level_solve_blocks,
    level_solve_blocks_batched,
)

__all__ = [
    "level_kernel",
    "level_solve_blocks",
    "level_kernel_batched",
    "level_solve_blocks_batched",
]
