"""Jit'd wrapper: whole-matrix level-set solve using the level kernel."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codegen import Schedule

from .kernel import level_solve_blocks

__all__ = ["make_solver"]


def _ceil_to(v: int, m: int) -> int:
    return int(np.ceil(v / m) * m) if v else m


def make_solver(
    schedule: Schedule, *, interpret: bool = True, block_rows: int = 512
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Build solve(b) that runs one Pallas kernel per level."""
    n = schedule.n
    n_pad = _ceil_to(n + 1, 128)
    packed = []
    for slab in schedule.slabs:
        R_pad = _ceil_to(slab.R, block_rows if slab.R > block_rows // 4 else 128)
        br = min(block_rows, R_pad)
        rows = np.full((R_pad,), n, dtype=np.int32)
        rows[: slab.R] = slab.rows
        cols = np.zeros((slab.K, R_pad), np.int32)
        cols[:, : slab.R] = slab.cols
        vals = np.zeros((slab.K, R_pad), np.float32)
        vals[:, : slab.R] = slab.vals
        diag = np.ones((R_pad,), np.float32)
        diag[: slab.R] = slab.diag
        packed.append(
            (
                jnp.asarray(rows),
                jnp.asarray(cols),
                jnp.asarray(vals),
                jnp.asarray(diag),
                br,
            )
        )

    def solve(b: jnp.ndarray) -> jnp.ndarray:
        dt = b.dtype
        b_ext = jnp.concatenate([b, jnp.zeros((1,), dt)])
        x = jnp.zeros((n_pad,), dt)
        for rows, cols, vals, diag, br in packed:
            bl = b_ext[jnp.minimum(rows, n)]
            xl = level_solve_blocks(
                x, bl, cols, vals.astype(dt), diag.astype(dt),
                block_rows=br, interpret=interpret,
            )
            x = x.at[rows].set(xl)
            x = x.at[n].set(0.0)  # pad rows target the scratch slot
        return x[:n]

    return solve
