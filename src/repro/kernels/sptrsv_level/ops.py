"""Jit'd wrapper: whole-matrix level-set solve using the level kernel.

Direction-agnostic: a backward (transpose) :class:`Schedule` — column-packed
slabs over reverse level sets — runs through the same kernels; nothing here
assumes which triangle the slabs came from.

Coarsened schedules (slabs with ``depth > 1``, :mod:`repro.core.coarsen`)
execute the intra-slab chain as ONE ``fori_loop`` whose body launches the
level kernel on a uniform stacked sub-slab — the XLA program holds one
kernel call per *super*-level instead of one per level, so program size and
trace/compile time stop scaling with the level count."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codegen import Schedule, stack_sub_slabs

from .kernel import level_solve_blocks, level_solve_blocks_batched

__all__ = ["make_solver"]


def _ceil_to(v: int, m: int) -> int:
    return int(np.ceil(v / m) * m) if v else m


def make_solver(
    schedule: Schedule, *, interpret: bool = True, block_rows: int = 512
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Build solve(b) that runs one Pallas kernel per segment (one per level,
    or one per coarsened chain via ``fori_loop``)."""
    n = schedule.n
    n_pad = _ceil_to(n + 1, 128)
    packed = []
    for slab in schedule.slabs:
        if slab.depth > 1:
            # chain: stack sub-slabs to a uniform (d, K, R_pad) block so one
            # fori_loop'd kernel call covers the whole segment
            rows_s, cols_s, vals_s, diag_s = stack_sub_slabs(slab, n)
            rmax = rows_s.shape[1]
            R_pad = _ceil_to(rmax, block_rows if rmax > block_rows // 4 else 128)
            br = min(block_rows, R_pad)
            d = slab.depth
            rows = np.full((d, R_pad), n, dtype=np.int32)
            rows[:, :rmax] = rows_s
            cols = np.zeros((d, slab.K, R_pad), np.int32)
            cols[:, :, :rmax] = cols_s
            vals = np.zeros((d, slab.K, R_pad), slab.vals.dtype)
            vals[:, :, :rmax] = vals_s
            diag = np.ones((d, R_pad), slab.diag.dtype)
            diag[:, :rmax] = diag_s
        else:
            R_pad = _ceil_to(slab.R, block_rows if slab.R > block_rows // 4 else 128)
            br = min(block_rows, R_pad)
            rows = np.full((R_pad,), n, dtype=np.int32)
            rows[: slab.R] = slab.rows
            cols = np.zeros((slab.K, R_pad), np.int32)
            cols[:, : slab.R] = slab.cols
            # keep the matrix dtype — hard-coding f32 here would silently
            # truncate f64 factors at pack time
            vals = np.zeros((slab.K, R_pad), slab.vals.dtype)
            vals[:, : slab.R] = slab.vals
            diag = np.ones((R_pad,), slab.diag.dtype)
            diag[: slab.R] = slab.diag
        packed.append(
            (
                slab.depth,
                jnp.asarray(rows),
                jnp.asarray(cols),
                jnp.asarray(vals),
                jnp.asarray(diag),
                br,
            )
        )

    def solve(b: jnp.ndarray) -> jnp.ndarray:
        """b: (n,) or (n, m) — batched RHS solve all columns in one pass."""
        dt = b.dtype
        kern = level_solve_blocks_batched if b.ndim == 2 else level_solve_blocks
        b_ext = jnp.concatenate([b, jnp.zeros((1,) + b.shape[1:], dt)])
        x = jnp.zeros((n_pad,) + b.shape[1:], dt)

        def step(x, rows, cols, vals, diag, br):
            bl = b_ext[jnp.minimum(rows, n)]
            xl = kern(
                x, bl, cols, vals.astype(dt), diag.astype(dt),
                block_rows=br, interpret=interpret,
            )
            x = x.at[rows].set(xl)
            return x.at[n].set(0.0)  # pad rows target the scratch slot

        for depth, rows, cols, vals, diag, br in packed:
            if depth == 1:
                x = step(x, rows, cols, vals, diag, br)
            else:
                x = jax.lax.fori_loop(
                    0, depth,
                    lambda t, xc, _r=rows, _c=cols, _v=vals, _d=diag, _br=br:
                        step(xc, _r[t], _c[t], _v[t], _d[t], _br),
                    x,
                )
        return x[:n]

    return solve
