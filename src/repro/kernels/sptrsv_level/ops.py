"""Jit'd wrapper: whole-matrix level-set solve using the level kernel.

Direction-agnostic: a backward (transpose) :class:`Schedule` — column-packed
slabs over reverse level sets — runs through the same kernels; nothing here
assumes which triangle the slabs came from."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codegen import Schedule

from .kernel import level_solve_blocks, level_solve_blocks_batched

__all__ = ["make_solver"]


def _ceil_to(v: int, m: int) -> int:
    return int(np.ceil(v / m) * m) if v else m


def make_solver(
    schedule: Schedule, *, interpret: bool = True, block_rows: int = 512
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Build solve(b) that runs one Pallas kernel per level."""
    n = schedule.n
    n_pad = _ceil_to(n + 1, 128)
    packed = []
    for slab in schedule.slabs:
        R_pad = _ceil_to(slab.R, block_rows if slab.R > block_rows // 4 else 128)
        br = min(block_rows, R_pad)
        rows = np.full((R_pad,), n, dtype=np.int32)
        rows[: slab.R] = slab.rows
        cols = np.zeros((slab.K, R_pad), np.int32)
        cols[:, : slab.R] = slab.cols
        # keep the matrix dtype — hard-coding f32 here would silently
        # truncate f64 factors at pack time
        vals = np.zeros((slab.K, R_pad), slab.vals.dtype)
        vals[:, : slab.R] = slab.vals
        diag = np.ones((R_pad,), slab.diag.dtype)
        diag[: slab.R] = slab.diag
        packed.append(
            (
                jnp.asarray(rows),
                jnp.asarray(cols),
                jnp.asarray(vals),
                jnp.asarray(diag),
                br,
            )
        )

    def solve(b: jnp.ndarray) -> jnp.ndarray:
        """b: (n,) or (n, m) — batched RHS solve all columns in one pass."""
        dt = b.dtype
        kern = level_solve_blocks_batched if b.ndim == 2 else level_solve_blocks
        b_ext = jnp.concatenate([b, jnp.zeros((1,) + b.shape[1:], dt)])
        x = jnp.zeros((n_pad,) + b.shape[1:], dt)
        for rows, cols, vals, diag, br in packed:
            bl = b_ext[jnp.minimum(rows, n)]
            xl = kern(
                x, bl, cols, vals.astype(dt), diag.astype(dt),
                block_rows=br, interpret=interpret,
            )
            x = x.at[rows].set(xl)
            x = x.at[n].set(0.0)  # pad rows target the scratch slot
        return x[:n]

    return solve
