"""Backend-dispatched wrapper: whole-matrix level-set solve using the level
kernel.

``make_solver(schedule, backend=...)`` packs the schedule once (the packing
is lowering-agnostic) and dispatches each segment to the selected backend's
level kernel — TPU Mosaic (:mod:`.lowering_tpu`), pallas-triton
(:mod:`.lowering_gpu`), or either under the pallas interpreter
(``backend="interpret"`` / ``"interpret:gpu"``).

Direction-agnostic: a backward (transpose) :class:`Schedule` — column-packed
slabs over reverse level sets — runs through the same kernels; nothing here
assumes which triangle the slabs came from.

Coarsened schedules (slabs with ``depth > 1``, :mod:`repro.core.coarsen`)
execute the intra-slab chain as ONE ``fori_loop`` whose body launches the
level kernel on a uniform stacked sub-slab — the XLA program holds one
kernel call per *super*-level instead of one per level, so program size and
trace/compile time stop scaling with the level count."""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codegen import Schedule, stack_sub_slabs
from repro.core.packed import build_packed_layout, pack_values
from repro.kernels.backend import resolve_backend

from . import lowering_gpu, lowering_tpu

__all__ = ["make_solver", "make_packed_solver", "select_lowering"]


def select_lowering(backend=None):
    """Lowering module for a backend spec — the single dispatch point the
    backend-matrix CI job asserts on."""
    bk = resolve_backend(backend)
    return lowering_gpu if bk.platform == "gpu" else lowering_tpu


def _ceil_to(v: int, m: int) -> int:
    return int(np.ceil(v / m) * m) if v else m


def make_solver(
    schedule: Schedule,
    *,
    backend=None,
    interpret: Optional[bool] = None,
    block_rows: int = 512,
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Build solve(b) that runs one Pallas kernel per segment (one per level,
    or one per coarsened chain via ``fori_loop``) on the given backend.
    ``interpret`` is the deprecated boolean alias (see
    :func:`repro.kernels.backend.resolve_backend`)."""
    bk = resolve_backend(backend, interpret=interpret)
    low = select_lowering(bk)
    n = schedule.n
    n_pad = _ceil_to(n + 1, 128)
    packed = []
    for slab in schedule.slabs:
        if slab.depth > 1:
            # chain: stack sub-slabs to a uniform (d, K, R_pad) block so one
            # fori_loop'd kernel call covers the whole segment
            rows_s, cols_s, vals_s, diag_s = stack_sub_slabs(slab, n)
            rmax = rows_s.shape[1]
            R_pad = _ceil_to(rmax, block_rows if rmax > block_rows // 4 else 128)
            br = min(block_rows, R_pad)
            d = slab.depth
            rows = np.full((d, R_pad), n, dtype=np.int32)
            rows[:, :rmax] = rows_s
            cols = np.zeros((d, slab.K, R_pad), np.int32)
            cols[:, :, :rmax] = cols_s
            vals = np.zeros((d, slab.K, R_pad), slab.vals.dtype)
            vals[:, :, :rmax] = vals_s
            diag = np.ones((d, R_pad), slab.diag.dtype)
            diag[:, :rmax] = diag_s
        else:
            R_pad = _ceil_to(slab.R, block_rows if slab.R > block_rows // 4 else 128)
            br = min(block_rows, R_pad)
            rows = np.full((R_pad,), n, dtype=np.int32)
            rows[: slab.R] = slab.rows
            cols = np.zeros((slab.K, R_pad), np.int32)
            cols[:, : slab.R] = slab.cols
            # keep the matrix dtype — hard-coding f32 here would silently
            # truncate f64 factors at pack time
            vals = np.zeros((slab.K, R_pad), slab.vals.dtype)
            vals[:, : slab.R] = slab.vals
            diag = np.ones((R_pad,), slab.diag.dtype)
            diag[: slab.R] = slab.diag
        packed.append(
            (
                slab.depth,
                jnp.asarray(rows),
                jnp.asarray(cols),
                jnp.asarray(vals),
                jnp.asarray(diag),
                br,
            )
        )

    def solve(b: jnp.ndarray) -> jnp.ndarray:
        """b: (n,) or (n, m) — batched RHS solve all columns in one pass."""
        dt = b.dtype
        kern = (low.level_solve_blocks_batched if b.ndim == 2
                else low.level_solve_blocks)
        b_ext = jnp.concatenate([b, jnp.zeros((1,) + b.shape[1:], dt)])
        x = jnp.zeros((n_pad,) + b.shape[1:], dt)

        def step(x, rows, cols, vals, diag, br):
            bl = b_ext[jnp.minimum(rows, n)]
            xl = kern(
                x, bl, cols, vals.astype(dt), diag.astype(dt),
                block_rows=br, interpret=bk.interpret,
            )
            x = x.at[rows].set(xl)
            return x.at[n].set(0.0)  # pad rows target the scratch slot

        for depth, rows, cols, vals, diag, br in packed:
            if depth == 1:
                x = step(x, rows, cols, vals, diag, br)
            else:
                x = jax.lax.fori_loop(
                    0, depth,
                    lambda t, xc, _r=rows, _c=cols, _v=vals, _d=diag, _br=br:
                        step(xc, _r[t], _c[t], _v[t], _d[t], _br),
                    x,
                )
        return x[:n]

    return solve


def make_packed_solver(
    schedule: Schedule,
    *,
    backend=None,
    interpret: Optional[bool] = None,
    block_rows: int = 512,
):
    """Permuted-space packed variant: one kernel call per segment, but the
    level's solution lands with a contiguous ``dynamic_update_slice`` at a
    static offset instead of a row-id scatter, ``b`` is permuted once at
    entry, and the slab values stream from one flat runtime buffer (so
    ``SpTRSV.refresh`` swaps values without re-tracing any kernel).

    Returns ``(solve(b, values), values0, repack, layout)``."""
    bk = resolve_backend(backend, interpret=interpret)
    low = select_lowering(bk)

    def _pad(r):
        return _ceil_to(r, block_rows if r > block_rows // 4 else 128)

    layout = build_packed_layout(
        schedule, pad_rows=_pad, pad_chain_rows=_pad,
        block_rows_for=lambda rp: min(block_rows, rp))
    n, n_pad = layout.n, layout.n_pad
    n_x = _ceil_to(n_pad, 128)
    cols_flat = jnp.asarray(layout.cols_flat)
    perm = jnp.asarray(layout.perm)
    pos = jnp.asarray(layout.pos)
    values0 = (jnp.asarray(layout.vals_flat), jnp.asarray(layout.diag_flat))

    def repack(target_data):
        vf, df = pack_values(layout, target_data)
        return jnp.asarray(vf), jnp.asarray(df)

    def solve(b: jnp.ndarray, values) -> jnp.ndarray:
        """b: (n,) or (n, m) — batched RHS solve all columns in one pass."""
        vals_flat, diag_flat = values
        dt = b.dtype
        vf = vals_flat.astype(dt)
        df = diag_flat.astype(dt)
        kern = (low.level_solve_blocks_batched if b.ndim == 2
                else low.level_solve_blocks)
        bhat = b[perm]
        if n_pad > n:
            bhat = jnp.concatenate(
                [bhat, jnp.zeros((n_pad - n,) + b.shape[1:], dt)])
        x = jnp.zeros((n_x,) + b.shape[1:], dt)
        for seg in layout.segments:
            K, Rp, br = seg.K, seg.R_pad, seg.block_rows
            if seg.kind == "chain":
                d = seg.depth
                cols_c = jax.lax.slice_in_dim(
                    cols_flat, seg.col_off, seg.col_off + d * K * Rp
                ).reshape(d, K, Rp)
                vals_c = jax.lax.slice_in_dim(
                    vf, seg.val_off, seg.val_off + d * K * Rp
                ).reshape(d, K, Rp)
                diag_c = jax.lax.slice_in_dim(
                    df, seg.diag_off, seg.diag_off + d * Rp).reshape(d, Rp)
                sub = jnp.asarray(seg.sub_offs)

                def body(t, xc, _c=cols_c, _v=vals_c, _d=diag_c, _sub=sub,
                         _Rp=Rp, _br=br):
                    o = _sub[t]
                    bw = jax.lax.dynamic_slice_in_dim(bhat, o, _Rp)
                    xl = kern(xc, bw, _c[t], _v[t], _d[t],
                              block_rows=_br, interpret=bk.interpret)
                    return jax.lax.dynamic_update_slice_in_dim(xc, xl, o, 0)

                x = jax.lax.fori_loop(0, d, body, x)
            else:
                cols_s = jax.lax.slice_in_dim(
                    cols_flat, seg.col_off, seg.col_off + K * Rp).reshape(K, Rp)
                vals_s = jax.lax.slice_in_dim(
                    vf, seg.val_off, seg.val_off + K * Rp).reshape(K, Rp)
                diag_s = jax.lax.slice_in_dim(
                    df, seg.diag_off, seg.diag_off + Rp)
                bw = jax.lax.slice_in_dim(bhat, seg.off, seg.off + Rp)
                xl = kern(x, bw, cols_s, vals_s, diag_s,
                          block_rows=br, interpret=bk.interpret)
                x = jax.lax.dynamic_update_slice_in_dim(x, xl, seg.off, 0)
        return x[pos]

    return solve, values0, repack, layout
