"""TPU (Mosaic) lowering: one SpTRSV level (wavefront) in ELL-slab form.

This is the ``platform="tpu"`` implementation behind
:mod:`repro.kernels.backend`; the pallas-triton twin with the same entry
points lives in :mod:`.lowering_gpu`.

The level's rows are independent, so the kernel is a vectorized
gather / FMA / reduce / divide over a ``(K, R)`` slab:

    s[r]  = sum_k vals[k, r] * x[cols[k, r]]
    xl[r] = (bl[r] - s[r]) / diag[r]

Tiling: the row dimension R maps to TPU lanes; the grid walks row blocks of
``block_rows`` (multiple of 128).  The full (padded) ``x`` vector is resident
in VMEM for every block — n up to ~3M rows fits the ~16 MiB VMEM budget at
f32.  The K loop is unrolled at trace time (K is a per-level compile-time
constant — the "generated code" is specialized per level, exactly like the
paper's per-level functions).

TPU lowering note: ``jnp.take`` from a VMEM-resident vector lowers to the
Mosaic dynamic-gather path (v4+).  The scatter of solved values back into x
happens *outside* the kernel (x.at[rows].set) where XLA handles it; the
kernel covers the bandwidth-dominant gather/FMA stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

__all__ = [
    "level_kernel",
    "level_solve_blocks",
    "level_kernel_batched",
    "level_solve_blocks_batched",
]


def level_kernel(x_ref, bl_ref, cols_ref, vals_ref, diag_ref, out_ref):
    """One (K, BR) slab block.  x_ref: full padded x in VMEM."""
    x = x_ref[...]
    acc = bl_ref[...]
    K = cols_ref.shape[0]
    for k in range(K):  # unrolled: K is static per level
        acc = acc - vals_ref[k, :] * jnp.take(x, cols_ref[k, :], mode="clip")
    out_ref[...] = acc / diag_ref[...]


def level_kernel_batched(x_ref, bl_ref, cols_ref, vals_ref, diag_ref, out_ref):
    """Multi-RHS variant: x_ref (n_pad, m), bl/out (BR, m), cols/vals (K, BR).

    The row gather pulls whole (m,) solution rows, so the innermost (lane)
    dimension is the batch — thin levels stop underfeeding the vector unit
    once m reaches the lane width."""
    x = x_ref[...]                       # (n_pad, m)
    acc = bl_ref[...]                    # (BR, m)
    K = cols_ref.shape[0]
    for k in range(K):  # unrolled: K is static per level
        dep = jnp.take(x, cols_ref[k, :], axis=0, mode="clip")  # (BR, m)
        acc = acc - vals_ref[k, :][:, None] * dep
    out_ref[...] = acc / diag_ref[...][:, None]


@functools.partial(
    jax.jit, static_argnames=("block_rows", "interpret")
)
def level_solve_blocks(
    x_pad: jnp.ndarray,    # (n_pad,) current solution incl. scratch slot
    bl: jnp.ndarray,       # (R_pad,) b gathered at the level's rows
    cols: jnp.ndarray,     # (K, R_pad) int32
    vals: jnp.ndarray,     # (K, R_pad)
    diag: jnp.ndarray,     # (R_pad,)
    *,
    block_rows: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """Solve one level; returns xl (R_pad,)."""
    K, R = cols.shape
    assert R % block_rows == 0, (R, block_rows)
    n_pad = x_pad.shape[0]
    grid = (R // block_rows,)
    return pl.pallas_call(
        level_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_pad,), lambda i: (0,)),            # x: full
            pl.BlockSpec((block_rows,), lambda i: (i,)),       # bl
            pl.BlockSpec((K, block_rows), lambda i: (0, i)),   # cols
            pl.BlockSpec((K, block_rows), lambda i: (0, i)),   # vals
            pl.BlockSpec((block_rows,), lambda i: (i,)),       # diag
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((R,), x_pad.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=(pltpu.PARALLEL,),  # blocks of a level are independent
        ),
        interpret=interpret,
        name="sptrsv_level",
    )(x_pad, bl, cols, vals, diag)


@functools.partial(
    jax.jit, static_argnames=("block_rows", "interpret")
)
def level_solve_blocks_batched(
    x_pad: jnp.ndarray,    # (n_pad, m) current solution incl. scratch row
    bl: jnp.ndarray,       # (R_pad, m) b gathered at the level's rows
    cols: jnp.ndarray,     # (K, R_pad) int32
    vals: jnp.ndarray,     # (K, R_pad)
    diag: jnp.ndarray,     # (R_pad,)
    *,
    block_rows: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """Solve one level for m RHS columns at once; returns xl (R_pad, m)."""
    K, R = cols.shape
    assert R % block_rows == 0, (R, block_rows)
    n_pad, m = x_pad.shape
    grid = (R // block_rows,)
    return pl.pallas_call(
        level_kernel_batched,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_pad, m), lambda i: (0, 0)),            # x: full
            pl.BlockSpec((block_rows, m), lambda i: (i, 0)),       # bl
            pl.BlockSpec((K, block_rows), lambda i: (0, i)),       # cols
            pl.BlockSpec((K, block_rows), lambda i: (0, i)),       # vals
            pl.BlockSpec((block_rows,), lambda i: (i,)),           # diag
        ],
        out_specs=pl.BlockSpec((block_rows, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, m), x_pad.dtype),
        compiler_params=compat.CompilerParams(
            dimension_semantics=(pltpu.PARALLEL,),  # blocks of a level are independent
        ),
        interpret=interpret,
        name="sptrsv_level_batched",
    )(x_pad, bl, cols, vals, diag)
