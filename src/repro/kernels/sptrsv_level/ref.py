"""Pure-jnp oracle for the level kernel."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["level_solve_ref"]


def level_solve_ref(x_pad, bl, cols, vals, diag):
    """xl[r] = (bl[r] - sum_k vals[k,r] * x[cols[k,r]]) / diag[r]

    Handles both single-RHS (x_pad (n_pad,)) and batched (x_pad (n_pad, m))
    layouts, mirroring the kernel pair."""
    if x_pad.ndim == 2:
        s = jnp.sum(vals[..., None] * x_pad[cols], axis=0)
        return (bl - s) / diag[:, None]
    s = jnp.sum(vals * x_pad[cols], axis=0)
    return (bl - s) / diag
