"""GPipe-style pipeline parallelism with ``shard_map`` + ``ppermute``.

The production meshes assigned to this paper carry (pod, data, model) axes —
no pipe axis — so PP ships as an optional feature (off by default), validated
on small virtual meshes by tests.  Schedule: GPipe with M microbatches over
P stages; bubble fraction (P-1)/(M+P-1).

Implementation: every device holds one stage's params.  The microbatch
stream rotates through stages with ``ppermute``; each device applies its
stage to whatever activation it currently holds.  After M+P-1 ticks all
microbatches passed all stages.  Activations for the backward pass come from
``jax.vjp`` inside the stage (XLA keeps them live per-stage — stage-local
rematerialization is the standard follow-up, hooked via ``remat_stage``).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["gpipe_stage_fn", "make_gpipe"]


def gpipe_stage_fn(stage_apply: Callable, num_stages: int, axis: str,
                   *, remat_stage: bool = True):
    """Build the shard_map body: (stage_params, microbatches) -> outputs.

    ``stage_apply(params, x)``: one stage on one microbatch.
    Microbatch tensor: (M, mb, ...) sharded so each device sees all M.
    """
    apply = jax.checkpoint(stage_apply) if remat_stage else stage_apply

    def body(params, mbs):
        # params: this device's stage slice — shard_map keeps the sharded
        # leading axis at local size 1; squeeze it.  mbs: (M, mb, d) replicated
        params = jax.tree.map(lambda a: a[0], params)
        stage = jax.lax.axis_index(axis)
        M = mbs.shape[0]
        T = M + num_stages - 1
        mb_shape = mbs.shape[1:]

        def tick(carry, t):
            buf, outs = carry     # buf: activation currently held (mb, d)
            # stage 0 injects microbatch t (if any)
            inject = jnp.where(t < M, t, M - 1)
            x_in = jnp.where(stage == 0, mbs[inject], buf)
            y = apply(params, x_in)
            # mark validity: stage s works on microbatch t-s
            valid = (t - stage >= 0) & (t - stage < M)
            y = jnp.where(valid, y, buf)
            # last stage emits finished microbatch
            out_idx = jnp.where(t - (num_stages - 1) >= 0, t - (num_stages - 1), 0)
            emit = (stage == num_stages - 1) & valid
            outs = outs.at[out_idx].set(jnp.where(emit, y, outs[out_idx]))
            # rotate activations downstream
            perm = [(i, (i + 1) % num_stages) for i in range(num_stages)]
            buf = jax.lax.ppermute(y, axis, perm)
            return (buf, outs), None

        buf0 = jnp.zeros(mb_shape, mbs.dtype)
        outs0 = jnp.zeros((M,) + mb_shape, mbs.dtype)
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0), jnp.arange(T))
        # outputs live on the last stage; broadcast so every device returns them
        outs = jax.lax.psum(
            jnp.where(stage == num_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    return body


def make_gpipe(stage_apply: Callable, mesh: Mesh, axis: str = "pipe",
               *, num_stages: int | None = None, remat_stage: bool = True):
    """stage_params (P, ...) + microbatches (M, mb, d) -> outputs (M, mb, d)."""
    P_ = num_stages or int(mesh.shape[axis])
    body = gpipe_stage_fn(stage_apply, P_, axis, remat_stage=remat_stage)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P()),       # stage params sharded; microbatches repl.
        out_specs=P(),
        check_vma=False,
    )
    return fn
