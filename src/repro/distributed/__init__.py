from .compress import CompressionState, compressed_allreduce, make_compressed_grad_fn
from .pipeline import gpipe_stage_fn, make_gpipe

__all__ = ["CompressionState", "compressed_allreduce", "make_compressed_grad_fn",
           "gpipe_stage_fn", "make_gpipe"]
