"""Gradient compression: int8 quantize -> all_reduce -> dequantize, with
error-feedback residual (1-bit-Adam-style EF so compression error does not
accumulate as bias).

At 512 chips the cross-pod gradient all-reduce is the only collective on the
slow inter-pod links; int8 cuts its wire bytes 4x vs f32 (2x vs bf16) at the
cost of one extra abs-max pass.  Selectable per-run (``--compress-grads``),
measured in EXPERIMENTS.md §Perf (multipod hillclimb).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["CompressionState", "compressed_allreduce", "make_compressed_grad_fn"]


@dataclasses.dataclass
class CompressionState:
    residual: Any           # error-feedback residual, like grads (f32)

    @staticmethod
    def init(grads_like):
        return CompressionState(
            jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def _quant(g: jnp.ndarray):
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_allreduce(g: jnp.ndarray, residual: jnp.ndarray, axis: str):
    """One tensor: EF-int8 psum over ``axis`` (inside shard_map)."""
    g = g.astype(jnp.float32) + residual
    q, scale = _quant(g)
    deq = q.astype(jnp.float32) * scale
    new_residual = g - deq
    # int8 values psum directly (sum of int8 fits s32); scales psum'd too —
    # per-peer scales differ, so sum(q_i * s_i) != s * sum(q_i).  We trade
    # exactness for wire bytes: send q (1B) + scale (4B/tensor) and let each
    # peer reconstruct with a shared max-scale.  Error lands in EF residual.
    smax = jax.lax.pmax(scale, axis)
    q_rescaled = jnp.round(deq / smax).astype(jnp.int32)
    total = jax.lax.psum(q_rescaled, axis).astype(jnp.float32) * smax
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    return total / n, new_residual


def make_compressed_grad_fn(mesh, axis: str = "pod"):
    """Tree-level wrapper: all-reduce grads over ``axis`` with EF-int8.
    Used when the training step keeps grads sharded per-pod and performs the
    cross-pod reduction explicitly (shard_map region)."""
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P

    def reduce_tree(grads, state: CompressionState):
        def one(g, r):
            spec = P(*([None] * g.ndim))
            f = shard_map(
                lambda gg, rr: compressed_allreduce(gg, rr, axis),
                mesh=mesh, in_specs=(spec, spec), out_specs=(spec, spec),
                check_vma=False)
            return f(g, r)

        out = jax.tree.map(one, grads, state.residual)
        new_g = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_r = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_g, CompressionState(new_r)

    return reduce_tree
