"""Multi-tenant solve service: the front-end that composes the registry
and the per-factor engines.

Composition (one process, three layers):

* :class:`repro.serve.SolverRegistry` — which factors are resident, LRU +
  byte-budget eviction, cold serial pairs + background planned builds;
* :class:`repro.serve.SolveEngine` — one per resident pattern, drains its
  admission queue as power-of-base-bucketed multi-RHS batches per
  direction (the per-factor worker);
* :class:`SolveService` (this module) — tenant bookkeeping on top:
  ``register`` admits a tenant's factor, ``submit`` enqueues RHS vectors,
  ``step``/``run`` continuously batch queued requests *across tenants* —
  two tenants sharing a (pattern, dtype) land in the same engine queue and
  are answered by one batched dispatch — and ``stats`` aggregates
  per-tenant counters, registry counters, and solve/build latency
  histograms into one dashboard dict.

Sharing semantics: the registry holds one *numeric* factor per (pattern,
dtype) at a time.  Tenants sharing a key share values — a ``refresh``
applies to all of them, after the queue drains (in-flight requests are
answered against the values they were submitted against).  Failures stay
per-request: one tenant's breakdown (e.g. a guarded solver's
``GuardBreakdownError`` on a bad RHS) is carried on that request's
``error`` and never poisons co-batched neighbours.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import CSRMatrix
from .engine import SolveRequest
from .metrics import LatencyHistogram
from .registry import SolverEntry, SolverRegistry

__all__ = ["SolveService", "TenantState"]


@dataclasses.dataclass
class TenantState:
    """Per-tenant bookkeeping: the registry key + factor the tenant is
    currently bound to, its outstanding requests, and counters."""

    name: str
    key: Optional[str] = None
    factor: Optional[CSRMatrix] = None   # host CSR; shares entry's arrays
    outstanding: List[SolveRequest] = dataclasses.field(default_factory=list)
    submitted: int = 0
    completed: int = 0
    failed: int = 0
    refreshes: int = 0
    registrations: int = 0

    def stats(self) -> dict:
        return {
            "key": self.key,
            "queue_depth": len(self.outstanding),
            "submitted": self.submitted,
            "completed": self.completed,
            "failed": self.failed,
            "refreshes": self.refreshes,
            "registrations": self.registrations,
        }


class SolveService:
    """Multi-tenant continuous-batching front-end over a
    :class:`SolverRegistry`.

    Pass an existing ``registry`` or any :class:`SolverRegistry` keyword
    arguments (``strategy=``, ``max_bytes=``, ``background=``, ...) to
    build one.  The service is single-front-end-threaded by design — one
    thread calls ``register``/``submit``/``step`` — while planned builds
    run on the registry's background workers."""

    def __init__(self, *, registry: Optional[SolverRegistry] = None,
                 **registry_kwargs):
        if registry is not None and registry_kwargs:
            raise ValueError(
                "pass either a registry or registry kwargs, not both: "
                f"{sorted(registry_kwargs)}")
        self.registry = registry if registry is not None \
            else SolverRegistry(**registry_kwargs)
        self._tenants: Dict[str, TenantState] = {}
        self.solve_hist = LatencyHistogram()
        self.steps = 0
        self.batches_completed = 0

    # -- tenant lifecycle --------------------------------------------------
    def _tenant(self, name: str) -> TenantState:
        st = self._tenants.get(name)
        if st is None:
            st = self._tenants[name] = TenantState(name)
        return st

    def register(self, tenant: str, L: CSRMatrix) -> str:
        """Bind ``tenant`` to a factor and admit it to the registry
        (pattern hit → O(nnz) value refresh; miss → cold pair now +
        background planned build).  Returns the registry key.  Re-register
        to rotate a tenant onto a different factor."""
        st = self._tenant(tenant)
        entry = self.registry.get(L)
        st.key = entry.key
        st.factor = entry.pattern
        st.registrations += 1
        return entry.key

    def refresh(self, tenant: str, new_values, *,
                validate: bool = True) -> None:
        """Same-pattern numeric refresh of the tenant's factor (O(nnz)
        onto the compiled executables; the entry queue drains first).
        Visible to every tenant sharing the key — see the module
        docstring's sharing semantics."""
        st = self._tenants.get(tenant)
        if st is None or st.key is None:
            raise ValueError(f"tenant {tenant!r} has no registered factor")
        entry = self._entry(st)
        entry.refresh(new_values, validate=validate)
        st.factor = entry.pattern
        st.refreshes += 1

    def _entry(self, st: TenantState) -> SolverEntry:
        """The tenant's resident entry — re-admitted through the registry
        (cold path + background rebuild) if it was evicted while idle."""
        entry = self.registry.lookup(st.key)
        if entry is None:
            entry = self.registry.get(st.factor)
            st.key = entry.key
            st.factor = entry.pattern
        return entry

    # -- request path ------------------------------------------------------
    def submit(self, tenant: str, b: np.ndarray, *,
               transpose: bool = False) -> SolveRequest:
        """Enqueue one RHS for the tenant's current factor.  The request
        joins the shared per-(pattern, dtype) engine queue and is answered
        by the next drained batch — by the cold serial pair if the planned
        build has not promoted yet."""
        st = self._tenants.get(tenant)
        if st is None or st.key is None:
            raise ValueError(f"tenant {tenant!r} has no registered factor — "
                             "call register(tenant, L) first")
        entry = self._entry(st)
        req = entry.engine.submit(b, transpose=transpose, tenant=tenant)
        st.outstanding.append(req)
        st.submitted += 1
        return req

    def _sweep_completed(self) -> None:
        for st in self._tenants.values():
            if not st.outstanding:
                continue
            still = []
            for r in st.outstanding:
                if not r.done:
                    still.append(r)
                elif r.error is None:
                    st.completed += 1
                else:
                    st.failed += 1
            st.outstanding = still

    def step(self) -> int:
        """One continuous-batching round: every entry with queued requests
        drains one batch per direction (requests from different tenants
        co-batched).  Records per-batch solve latency; returns requests
        completed this round."""
        total = 0
        for key in self.registry.keys():
            entry = self.registry.lookup(key)
            if entry is None or not entry.engine.queue:
                continue
            with entry.lock:     # exclude concurrent refresh/promotion
                t0 = time.perf_counter()
                done = entry.engine.step()
                if done:
                    self.solve_hist.record(time.perf_counter() - t0)
                    self.batches_completed += 1
            total += done
        self.steps += 1
        self._sweep_completed()
        return total

    def run(self, max_steps: int = 10_000) -> int:
        """Drain every queue; returns total requests completed."""
        total = 0
        for _ in range(max_steps):
            done = self.step()
            total += done
            if not done:
                break
        return total

    def queue_depth(self) -> int:
        return sum(len(st.outstanding) for st in self._tenants.values())

    # -- observability -----------------------------------------------------
    def stats(self) -> dict:
        """One dashboard dict: service-wide counters + solve-latency
        histogram, the registry's hit/miss/promotion/eviction/build view,
        and per-tenant counters."""
        tenants = {name: st.stats() for name, st in self._tenants.items()}
        return {
            "tenants": len(tenants),
            "queue_depth": self.queue_depth(),
            "submitted": sum(t["submitted"] for t in tenants.values()),
            "completed": sum(t["completed"] for t in tenants.values()),
            "failed": sum(t["failed"] for t in tenants.values()),
            "steps": self.steps,
            "batches_completed": self.batches_completed,
            "solve_latency": self.solve_hist.summary(),
            "registry": self.registry.stats(),
            "per_tenant": tenants,
        }
