from .engine import ServeEngine, Request, SolveEngine, SolveRequest

__all__ = ["ServeEngine", "Request", "SolveEngine", "SolveRequest"]
