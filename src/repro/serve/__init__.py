"""Serving tier: single-factor micro-batching engines plus the
multi-tenant solve service.

* :mod:`repro.serve.engine` — :class:`SolveEngine`, the per-factor worker
  (power-of-base bucketed multi-RHS batching, per-request failure
  isolation, atomic solver promotion) and the LLM :class:`ServeEngine`;
* :mod:`repro.serve.registry` — :class:`SolverRegistry`, the LRU of built
  solver pairs keyed by sparsity-pattern hash (+ dtype) with byte-budget
  eviction, cold serial pairs, and background planned builds;
* :mod:`repro.serve.service` — :class:`SolveService`, the multi-tenant
  continuous-batching front-end composing the two;
* :mod:`repro.serve.metrics` — :class:`LatencyHistogram`.
"""
from .engine import ServeEngine, Request, SolveEngine, SolveRequest
from .metrics import LatencyHistogram
from .registry import SolverEntry, SolverRegistry, pattern_key
from .service import SolveService, TenantState

__all__ = [
    "ServeEngine",
    "Request",
    "SolveEngine",
    "SolveRequest",
    "LatencyHistogram",
    "SolverEntry",
    "SolverRegistry",
    "pattern_key",
    "SolveService",
    "TenantState",
]
