"""Pattern-keyed compiled-solver registry — the memory tier of the
multi-tenant solve service.

The paper's economics (expensive per-matrix analysis amortized over many
solves of the same factor) only pay off at fleet scale if the serving tier
can hold *many* built factors at once and route streams of same-pattern
numeric refreshes onto already-compiled executables.  That routing is what
:class:`SolverRegistry` does:

* **Key** — :meth:`repro.core.CSRMatrix.pattern_hash` (structure only)
  plus the value dtype: two tenants sharing a sparsity pattern and dtype
  share one compiled solver pair and one admission queue.
* **Hit** — the factor's *values* are swapped onto the resident pair with
  one O(nnz) ``refresh`` (queue drained first, executables reused — no
  analysis, no re-trace, no re-compile).
* **Miss** — a cheap ``strategy="serial"`` pair (:meth:`repro.core.SpTRSV.
  build_cold`) is stood up inline so cold traffic is answered immediately,
  while the planned (``strategy="auto"``) build runs on a background worker
  thread and is **promoted atomically** onto the entry's engine when it
  lands (:meth:`repro.serve.SolveEngine.swap_solvers`).  Values refreshed
  while the build is in flight are re-applied to the built pair before the
  swap, so promotion never resurrects stale numerics.
* **Eviction** — LRU, bounded both by entry count and by resident packed
  bytes (each solver's ``stats()["packed_bytes"]``).  Entries with queued
  requests and the entry just touched are never evicted; an in-flight
  background build whose entry was evicted is discarded on completion.
"""
from __future__ import annotations

import dataclasses
import logging
import threading
import time
from collections import OrderedDict
from typing import Optional

import numpy as np

from repro.compat import enable_x64
from repro.core import CSRMatrix, SpTRSV
from .engine import SolveEngine
from .metrics import LatencyHistogram


def _x64_enabled() -> bool:
    """Whether the *calling thread* sees 64-bit JAX types.  ``jax.
    enable_x64`` is a thread-local config context: a background build
    worker does NOT inherit it, and a planned pair traced without it would
    silently pack float32 value buffers for a float64 factor.  The
    registry snapshots the admitting thread's setting and re-applies it on
    the worker."""
    import jax

    return bool(jax.dtypes.canonicalize_dtype(np.float64) == np.float64)

__all__ = ["SolverEntry", "SolverRegistry", "pattern_key"]

logger = logging.getLogger(__name__)


def pattern_key(L: CSRMatrix) -> str:
    """Registry key of a factor: sparsity-pattern digest + value dtype.

    The dtype is part of the key because the compiled executables are
    dtype-specialized — an f32 and an f64 tenant sharing a pattern still
    need distinct solver pairs (and distinct jit-cache entries)."""
    return f"{L.pattern_hash()}:{np.dtype(L.dtype).name}"


class SolverEntry:
    """One resident factor: a :class:`SolveEngine` over the current solver
    pair, the latest values, and the cold/ready promotion state.

    ``state`` is ``"cold"`` (serving through the serial pair while the
    planned build is pending/in flight) or ``"ready"`` (planned pair
    promoted).  ``ready_event`` fires at promotion — or at build failure,
    with ``build_error`` set — so callers can wait deterministically."""

    def __init__(self, key: str, L: CSRMatrix, engine: SolveEngine, *,
                 cold_build_seconds: float):
        self.key = key
        self.pattern = L            # values updated on every refresh
        self.engine = engine
        self.state = "cold"
        self.lock = threading.RLock()
        self.version = 0            # bumps on every value refresh
        self.evicted = False
        self.ready_event = threading.Event()
        self.build_error: Optional[Exception] = None
        self.cold_build_seconds = cold_build_seconds
        self.planned_build_seconds: Optional[float] = None
        self.value_refreshes = 0
        self.cold_completed = 0     # requests answered before promotion
        self.last_used = time.monotonic()

    @property
    def packed_bytes(self) -> int:
        """Resident packed-buffer footprint of the entry's current pair —
        what the registry's byte budget charges."""
        total = 0
        for s in (self.engine.solver, self.engine.solver_t):
            if s is None:
                continue
            pb = s.stats()["packed_bytes"]
            total += int(pb) if pb else 0
        return total

    def refresh(self, new_values, *, validate: bool = True) -> None:
        """O(nnz) value swap onto the resident compiled pair (drains the
        engine queue first — see :meth:`SolveEngine.refresh`) and record
        the new values as the entry's latest, so an in-flight background
        build re-applies them before promotion."""
        data = (np.asarray(new_values.data)
                if isinstance(new_values, CSRMatrix)
                else np.asarray(new_values))
        with self.lock:
            self.engine.refresh(data, validate=validate)
            p = self.pattern
            self.pattern = CSRMatrix(p.indptr, p.indices,
                                     data.astype(p.dtype, copy=False),
                                     p.shape)
            self.version += 1
            self.value_refreshes += 1

    def wait_ready(self, timeout: Optional[float] = None) -> bool:
        """Block until the planned build promoted (or failed — then the
        entry keeps serving through the cold pair and ``build_error`` says
        why).  Returns the event state."""
        return self.ready_event.wait(timeout)

    def stats(self) -> dict:
        with self.lock:
            return {
                "state": self.state,
                "packed_bytes": self.packed_bytes,
                "queue_depth": len(self.engine.queue),
                "solved": self.engine.solved,
                "failed": self.engine.failed,
                "cold_completed": (self.cold_completed
                                   if self.state == "ready"
                                   else self.engine.solved
                                   + self.engine.failed),
                "value_refreshes": self.value_refreshes,
                "cold_build_s": self.cold_build_seconds,
                "planned_build_s": self.planned_build_seconds,
                "strategy": self.engine.solver.strategy,
                "build_error": (repr(self.build_error)
                                if self.build_error else None),
            }


class SolverRegistry:
    """LRU registry of built :class:`SpTRSV` pairs keyed by sparsity
    pattern (+ dtype).  See the module docstring for the hit/miss/eviction
    contract.

    ``max_entries`` / ``max_bytes`` bound residency (``None`` = unbounded);
    ``background=False`` runs the planned build inline on admission (the
    deterministic mode tests use); ``build_gate`` is an optional
    :class:`threading.Event` every background worker waits on before
    building — a test/benchmark hook that makes "cold traffic answered
    while the build is in flight" reproducible instead of a race.
    ``**build_kwargs`` (``guard=``, ``backend=``, ...) apply to the cold
    and the planned build alike."""

    def __init__(self, *, strategy: str = "auto",
                 transpose_too: bool = True,
                 max_entries: Optional[int] = None,
                 max_bytes: Optional[int] = None,
                 max_batch: int = 64, bucket_base: int = 2,
                 background: bool = True,
                 build_gate: Optional[threading.Event] = None,
                 **build_kwargs):
        if max_entries is not None and max_entries < 1:
            raise ValueError(f"max_entries must be >= 1; got {max_entries}")
        if max_bytes is not None and max_bytes < 0:
            raise ValueError(f"max_bytes must be >= 0; got {max_bytes}")
        self.strategy = strategy
        self.transpose_too = transpose_too
        self.max_entries = max_entries
        self.max_bytes = max_bytes
        self.max_batch = max_batch
        self.bucket_base = bucket_base
        self.background = background
        self.build_gate = build_gate
        self.build_kwargs = build_kwargs
        self._entries: "OrderedDict[str, SolverEntry]" = OrderedDict()
        self._lock = threading.Lock()
        self._threads: list = []
        self.hits = 0
        self.misses = 0
        self.promotions = 0
        self.evictions = 0
        self.build_failures = 0
        self.cold_build_hist = LatencyHistogram()
        self.planned_build_hist = LatencyHistogram()

    # -- admission ---------------------------------------------------------
    def get(self, L: CSRMatrix) -> SolverEntry:
        """Admit a factor: pattern hit → O(nnz) value refresh onto the
        resident pair (skipped when the values are bit-identical); miss →
        inline cold serial pair + background planned build.  Returns the
        (possibly brand-new) entry, marked most-recently-used."""
        key = pattern_key(L)
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                self._entries.move_to_end(key)
                entry.last_used = time.monotonic()
                self.hits += 1
        if entry is not None:
            if not np.array_equal(entry.pattern.data, L.data):
                entry.refresh(L.data)
            return entry
        return self._admit_miss(key, L)

    def lookup(self, key: str) -> Optional[SolverEntry]:
        """Fetch a resident entry by key without admission side effects
        (no refresh, no build, no hit/miss accounting; LRU order *is*
        touched — a lookup is a use)."""
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                self._entries.move_to_end(key)
                entry.last_used = time.monotonic()
            return entry

    def _admit_miss(self, key: str, L: CSRMatrix) -> SolverEntry:
        # cold pair inline — this is what answers the first request NOW;
        # the serial scan build is O(nnz) analysis + one lax.scan trace
        t0 = time.perf_counter()
        fwd, bwd = SpTRSV.build_cold(L, transpose_too=self.transpose_too,
                                     **self.build_kwargs)
        cold_s = time.perf_counter() - t0
        engine = SolveEngine(fwd, bwd, max_batch=self.max_batch,
                             bucket_base=self.bucket_base)
        entry = SolverEntry(key, L, engine, cold_build_seconds=cold_s)
        with self._lock:
            raced = self._entries.get(key)
            if raced is not None:      # another thread admitted it first
                self._entries.move_to_end(key)
                self.hits += 1
                return raced
            self._entries[key] = entry
            self.misses += 1
            self.cold_build_hist.record(cold_s)
            self._evict_to_budget(protect=key)
        if self.strategy == "serial":
            # the planned build IS the cold build — promote in place
            with entry.lock:
                entry.state = "ready"
                entry.planned_build_seconds = cold_s
            entry.ready_event.set()
            with self._lock:
                self.promotions += 1
        elif self.background:
            # jax.enable_x64 is thread-local — snapshot the admitting
            # thread's setting and re-apply it on the worker, or the
            # planned pair would trace/pack at float32 (see _x64_enabled)
            x64 = _x64_enabled()

            def _worker(entry=entry, x64=x64):
                if x64:
                    with enable_x64():
                        self._build_and_promote(entry)
                else:
                    self._build_and_promote(entry)

            t = threading.Thread(target=_worker, daemon=True,
                                 name=f"solver-build-{key[:12]}")
            with self._lock:
                self._threads.append(t)
            t.start()
        else:
            self._build_and_promote(entry)
        return entry

    # -- background build + atomic promotion -------------------------------
    def _build_planned(self, L: CSRMatrix):
        """The planned (expensive) build — split out so tests can
        monkeypatch it to stall or fail deterministically."""
        if self.transpose_too:
            return SpTRSV.build_pair(L, strategy=self.strategy,
                                     **self.build_kwargs)
        return (SpTRSV.build(L, strategy=self.strategy,
                             **self.build_kwargs), None)

    def _build_and_promote(self, entry: SolverEntry) -> None:
        if self.build_gate is not None:
            self.build_gate.wait()
        with entry.lock:
            snapshot, built_version = entry.pattern, entry.version
        t0 = time.perf_counter()
        try:
            fwd, bwd = self._build_planned(snapshot)
            while True:
                # promotion and budget re-enforcement are one atomic unit
                # under the registry lock (lock order: registry -> entry,
                # same as admission/eviction) so an observer never reads a
                # transiently over-budget resident footprint
                with self._lock:
                    with entry.lock:
                        if entry.evicted:
                            logger.info(
                                "registry: discarding planned build for "
                                "evicted entry %s", entry.key)
                            return
                        if entry.version == built_version:
                            # atomic promotion: the engine's next drained
                            # batch runs on the planned executables; queued
                            # requests are preserved, answers are
                            # value-identical
                            entry.engine.swap_solvers(fwd, bwd)
                            entry.cold_completed = (entry.engine.solved
                                                    + entry.engine.failed)
                            entry.state = "ready"
                            entry.planned_build_seconds = (
                                time.perf_counter() - t0)
                            self.promotions += 1
                            self.planned_build_hist.record(
                                entry.planned_build_seconds)
                            self._evict_to_budget(protect=entry.key)
                            break
                        snapshot, built_version = (entry.pattern,
                                                   entry.version)
                # values moved while we built: O(nnz) refresh of the built
                # pair OUTSIDE the locks, then re-check
                fwd.refresh(snapshot.data)
                if bwd is not None:
                    bwd.refresh(snapshot.data)
        except Exception as exc:   # noqa: BLE001 — keep serving cold
            logger.warning("registry: planned build for %s failed (%r); "
                           "entry keeps serving through the cold serial "
                           "pair", entry.key, exc)
            entry.build_error = exc
            with self._lock:
                self.build_failures += 1
            entry.ready_event.set()
            return
        entry.ready_event.set()

    # -- eviction ----------------------------------------------------------
    def resident_bytes(self) -> int:
        with self._lock:
            return sum(e.packed_bytes for e in self._entries.values())

    def _evict_to_budget(self, *, protect: str) -> None:
        """Evict LRU entries until both budgets hold.  Never evicts the
        just-touched entry (``protect``) or an entry with queued requests —
        so the resident total can exceed ``max_bytes`` only when a single
        protected/busy entry does on its own.  Caller holds ``_lock``."""
        def over():
            if (self.max_entries is not None
                    and len(self._entries) > self.max_entries):
                return True
            return (self.max_bytes is not None
                    and sum(e.packed_bytes for e in self._entries.values())
                    > self.max_bytes)

        while over():
            victim = None
            for key, e in self._entries.items():   # iteration = LRU order
                if key == protect or len(e.engine.queue):
                    continue
                victim = key
                break
            if victim is None:
                logger.warning(
                    "registry: over budget but every other entry has "
                    "queued work — deferring eviction")
                return
            e = self._entries.pop(victim)
            with e.lock:
                e.evicted = True
            self.evictions += 1
            logger.info("registry: evicted %s (%d bytes)", victim,
                        e.packed_bytes)

    # -- bookkeeping -------------------------------------------------------
    def wait_idle(self, timeout: Optional[float] = None) -> bool:
        """Join every background build thread (tests/benchmarks).  Returns
        False if any thread is still alive after ``timeout``."""
        with self._lock:
            threads = list(self._threads)
        deadline = (time.monotonic() + timeout) if timeout is not None \
            else None
        for t in threads:
            t.join(None if deadline is None
                   else max(0.0, deadline - time.monotonic()))
            if t.is_alive():
                return False
        with self._lock:
            self._threads = [t for t in self._threads if t.is_alive()]
        return True

    def keys(self) -> list:
        with self._lock:
            return list(self._entries.keys())

    def stats(self) -> dict:
        """Registry-wide counters + per-entry state, one dict for the
        dashboard: hit/miss/promotion/eviction counts, resident byte
        footprint vs budget, build-latency histograms, and each entry's
        :meth:`SolverEntry.stats`."""
        with self._lock:
            entries = {k: e for k, e in self._entries.items()}
            out = {
                "hits": self.hits,
                "misses": self.misses,
                "promotions": self.promotions,
                "evictions": self.evictions,
                "build_failures": self.build_failures,
                "entries": len(entries),
                "max_entries": self.max_entries,
                "max_bytes": self.max_bytes,
                "cold_build": self.cold_build_hist.summary(),
                "planned_build": self.planned_build_hist.summary(),
            }
        per_entry = {k: e.stats() for k, e in entries.items()}
        out["resident_packed_bytes"] = sum(
            s["packed_bytes"] for s in per_entry.values())
        out["per_entry"] = per_entry
        return out
