"""Serving-tier metrics primitives.

One class, deliberately tiny: a fixed-bucket log2 latency histogram that
both the :class:`repro.serve.SolverRegistry` (cold/planned build times) and
the :class:`repro.serve.SolveService` (per-batch solve times) record into.
Dashboards read :meth:`LatencyHistogram.summary` out of ``stats()`` — no
external metrics dependency, no unbounded sample retention.
"""
from __future__ import annotations

import math

__all__ = ["LatencyHistogram"]


class LatencyHistogram:
    """Log2-bucketed latency histogram over seconds.

    Buckets span ``[2^lo_exp, 2^hi_exp)`` seconds (defaults cover 1 µs to
    ~65 s); samples outside the range clamp into the edge buckets.  O(1)
    record, O(buckets) summary, exact count/sum/min/max on the side so the
    mean is not quantized.
    """

    def __init__(self, *, lo_exp: int = -20, hi_exp: int = 6):
        if hi_exp <= lo_exp:
            raise ValueError(
                f"hi_exp must exceed lo_exp; got [{lo_exp}, {hi_exp}]")
        self.lo_exp = lo_exp
        self.hi_exp = hi_exp
        self.counts = [0] * (hi_exp - lo_exp)
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = 0.0

    def record(self, seconds: float) -> None:
        s = float(seconds)
        if not (s >= 0.0) or math.isinf(s):   # rejects NaN too
            raise ValueError(f"latency must be finite and >= 0; got {s}")
        self.count += 1
        self.total += s
        self.min = min(self.min, s)
        self.max = max(self.max, s)
        e = math.frexp(s)[1] - 1 if s > 0.0 else self.lo_exp
        idx = min(max(e - self.lo_exp, 0), len(self.counts) - 1)
        self.counts[idx] += 1

    def quantile(self, q: float) -> float:
        """Upper bucket edge containing the q-quantile (0 when empty) —
        a conservative (pessimistic) latency estimate, which is the right
        bias for an SLO check."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1]; got {q}")
        if self.count == 0:
            return 0.0
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank and c:
                return min(2.0 ** (self.lo_exp + i + 1), self.max)
        return self.max

    def summary(self) -> dict:
        """JSON-able digest: count / mean / min / max / p50 / p95 / p99."""
        return {
            "count": self.count,
            "mean_s": self.total / self.count if self.count else 0.0,
            "min_s": self.min if self.count else 0.0,
            "max_s": self.max,
            "p50_s": self.quantile(0.50),
            "p95_s": self.quantile(0.95),
            "p99_s": self.quantile(0.99),
        }
