"""Serving engines: LLM prefill/decode with continuous batching, and a
micro-batching front-end for matrix-specialized SpTRSV solves.

A fixed pool of ``B`` decode slots; finished sequences are replaced from the
admission queue each step (continuous batching).  Per-slot state lives in
one batched KV cache; admission re-prefills the joining slot only (padded
prompt prefill into slot-sliced cache writes).

For the production meshes the engine jits ``prefill`` and ``decode_step``
with cache shardings from ``models.sharding.cache_specs`` (int8 KV for
qwen decode_32k per assignment).

The SpTRSV half of this module is the **per-factor worker** of the
multi-tenant solve service: :class:`SolveEngine` owns one factor pair
(forward + optional transpose), micro-batches same-direction requests into
power-of-base width buckets, isolates per-request failures, and supports
atomic solver promotion (:meth:`SolveEngine.swap_solvers`) so a
:class:`repro.serve.SolverRegistry` can replace the cheap cold serial pair
with the planned build without dropping queued requests.  The
:class:`repro.serve.SolveService` composes one engine per resident sparsity
pattern and continuously batches requests *across* tenants through them.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import TYPE_CHECKING, Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

if TYPE_CHECKING:  # SolveEngine must stay importable without the model stack
    from ..models.model import Model

__all__ = ["Request", "ServeEngine", "SolveRequest", "SolveEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (P,) int32
    max_new: int = 16
    out: Optional[list] = None
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, params, *, batch_slots: int = 4,
                 s_cache: int = 128, eos_id: int = -1):
        self.model = model
        self.params = params
        self.B = batch_slots
        self.s_cache = s_cache
        self.eos = eos_id
        self.queue: deque = deque()
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.remaining = np.zeros(batch_slots, np.int32)
        self._decode = jax.jit(model.decode_step)
        # batched prefill for initial fill; per-slot joins reuse it with B=1
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, self.s_cache))
        self.cache = model.init_cache(batch_slots, s_cache)
        self.tokens = jnp.zeros((batch_slots, 1), jnp.int32)
        self.steps = 0

    # -- admission ------------------------------------------------------------
    def submit(self, req: Request):
        req.out = []
        self.queue.append(req)

    def _join(self, slot: int, req: Request):
        """Prefill a single joining request and splice its state into the
        batched cache at ``slot``."""
        prompt = jnp.asarray(req.prompt[None], jnp.int32)
        logits, cache1 = self._prefill(self.params, {"tokens": prompt})
        # splice slot 0 of cache1 into our batched cache
        def splice(big, small):
            if big.ndim == 0 or big.shape == small.shape and big.ndim <= 1:
                return big
            # leading dims may include a stacked reps axis; batch is axis 0
            # for unstacked leaves and axis 1 for stacked ones — detect via rank
            if small.shape[0] == 1 and big.shape[0] != 1 and big.ndim == small.ndim:
                return big.at[slot].set(small[0])
            if big.ndim == small.ndim and small.shape[1] == 1:
                return big.at[:, slot].set(small[:, 0])
            return big

        new_blocks = jax.tree.map(splice, self.cache["blocks"], cache1["blocks"])
        new_tail = jax.tree.map(splice, self.cache["tail"], cache1["tail"])
        self.cache = dict(self.cache, blocks=new_blocks, tail=new_tail)
        # NOTE: per-slot idx differs; the engine uses max idx and masks via
        # cache validity — acceptable for the fixed-length demo; production
        # per-slot positions are a documented TODO (paged attention).
        self.cache["idx"] = jnp.maximum(self.cache["idx"], cache1["idx"])
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        self.tokens = self.tokens.at[slot, 0].set(tok[0])
        self.slots[slot] = req
        self.remaining[slot] = req.max_new
        req.out.append(int(tok[0]))

    # -- main loop -------------------------------------------------------------
    def step(self):
        # admit
        for i in range(self.B):
            if self.slots[i] is None and self.queue:
                self._join(i, self.queue.popleft())
        if all(s is None for s in self.slots):
            return False
        logits, self.cache = self._decode(self.params, self.tokens, self.cache)
        nxt = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
        self.tokens = nxt[:, None]
        self.steps += 1
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(nxt[i])
            req.out.append(tok)
            self.remaining[i] -= 1
            if self.remaining[i] <= 0 or tok == self.eos:
                req.done = True
                self.slots[i] = None
        return True

    def run(self, max_steps: int = 1000):
        while self.step() and self.steps < max_steps:
            pass


# ==========================================================================
# Batched SpTRSV serving
# ==========================================================================
@dataclasses.dataclass
class SolveRequest:
    """One RHS vector to solve against the engine's fixed factor L.

    ``transpose=True`` requests the backward sweep ``Lᵀ x = b`` (requires the
    engine to hold a transpose solver).

    On completion exactly one of ``x`` / ``error`` is set: a request whose
    solve raised (e.g. a guarded solver's ``GuardBreakdownError``, or a
    non-finite RHS) carries the exception in ``error`` with ``done=True``
    and ``x=None`` — failures are isolated per request, they never poison
    co-batched neighbours (see ``SolveEngine._solve_group``).

    ``tenant`` is an opaque caller tag the multi-tenant
    :class:`repro.serve.SolveService` uses for per-tenant accounting;
    the engine itself never branches on it."""

    rid: int
    b: np.ndarray                   # (n,)
    transpose: bool = False
    tenant: Optional[str] = None
    x: Optional[np.ndarray] = None  # set when done (unless error)
    done: bool = False
    error: Optional[Exception] = None


class SolveEngine:
    """Micro-batching front-end for a matrix-specialized :class:`SpTRSV`.

    The paper's economics — expensive per-matrix analysis amortized over many
    solves of the same L — extend to serving: requests that share L are
    drained from an admission queue and solved as one multi-RHS batch
    ``L X = B``, so per-level launch overhead and the lane underfill of thin
    levels amortize over the batch width.

    An optional ``solver_t`` (typically the second half of
    ``SpTRSV.build_pair``) serves transpose requests ``Lᵀ x = b``; each
    drained step batches the two directions separately (they are distinct
    specialized executors) but drains them from one queue.

    Batch widths are rounded up to the next bucket (powers of ``bucket_base``
    up to ``max_batch``, padding columns with zeros) so the jit cache stays
    bounded: at most log(max_batch) compiled variants per direction, not one
    per queue depth.

    :meth:`refresh` swaps in new factor **values** of the same sparsity
    pattern across both directions (``SpTRSV.refresh``): the symbolic
    schedule, permutation, and compiled executables — including every
    already-compiled batch bucket — are all reused, so a serving tier
    re-doing numeric factorization (each PCG/IC refactor step) pays one
    O(nnz) value re-pack instead of a rebuild.
    """

    def __init__(self, solver, solver_t=None, *, max_batch: int = 64,
                 bucket_base: int = 2):
        # real ValueErrors, not asserts: a serving tier runs under
        # ``python -O`` too, and a stripped assert here would let a
        # mis-sized engine silently corrupt batch buffers downstream
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1; got {max_batch}")
        if solver_t is not None and solver_t.n != solver.n:
            raise ValueError(
                f"solver_t solves a {solver_t.n}-row system but solver "
                f"solves {solver.n} rows — the pair must share one factor")
        self.solver = solver
        self.solver_t = solver_t
        self.max_batch = max_batch
        self.bucket_base = max(2, bucket_base)
        self.queue: deque = deque()
        self.solved = 0
        self.failed = 0
        self.batches = 0
        self._next_rid = 0

    @classmethod
    def from_matrix(cls, L, *, strategy: str = "auto", transpose_too: bool = True,
                    max_batch: int = 64, bucket_base: int = 2, **build_kwargs):
        """Stand up a serving engine straight from a factor.

        Defaults to ``strategy="auto"`` — the transform planner picks the
        executor, whether to coarsen the schedule, AND whether to rewrite
        the matrix first (``thin`` vs ``critical_path`` policy) per matrix,
        which is the right default for a serving tier that sees arbitrary
        factors.  ``transpose_too=True`` builds the backward solver from the
        same shared analysis (``SpTRSV.build_pair``) so transpose requests
        are servable.  Extra keyword arguments (``backend=``, ``rewrite=``,
        ``coarsen=``, ``bucket_pad_ratio=``, ...) pass through to the
        builder; an explicit ``rewrite=`` overrides the planner's transform
        choice, and ``backend=`` pins the kernel lowering family (default:
        resolved from ``jax.default_backend()``)."""
        from repro.core import SpTRSV

        if transpose_too:
            fwd, bwd = SpTRSV.build_pair(L, strategy=strategy, **build_kwargs)
        else:
            fwd, bwd = SpTRSV.build(L, strategy=strategy, **build_kwargs), None
        return cls(fwd, bwd, max_batch=max_batch, bucket_base=bucket_base)

    def stats(self) -> dict:
        """Serving-tier view of the engine: per-direction solver stats
        (strategy, layout, packed bytes, rewrite policy, planner decision —
        see ``SpTRSV.stats``) plus queue/batch counters, so a deployment
        dashboard reads one dict instead of poking solver internals."""
        return {
            "forward": self.solver.stats(),
            "backward": self.solver_t.stats() if self.solver_t else None,
            "queue_depth": len(self.queue),
            "solved": self.solved,
            "failed": self.failed,
            "batches": self.batches,
            "max_batch": self.max_batch,
        }

    def swap_solvers(self, solver, solver_t=None) -> None:
        """Atomically replace the engine's solver pair (the registry's
        cold-to-planned *promotion*).  The replacement must solve the same
        system size and keep the transpose direction servable if the engine
        already serves it — queued transpose requests must not be stranded.
        In-flight batches are unaffected: ``_solve_group`` reads the solver
        reference once at drain time."""
        if solver.n != self.solver.n:
            raise ValueError(
                f"promoted solver solves {solver.n} rows but this engine "
                f"serves a {self.solver.n}-row factor")
        if self.solver_t is not None and solver_t is None:
            raise ValueError(
                "engine serves transpose requests but the promoted pair "
                "has no transpose solver")
        if solver_t is not None and solver_t.n != solver.n:
            raise ValueError(
                f"promoted solver_t solves {solver_t.n} rows but solver "
                f"solves {solver.n} rows — the pair must share one factor")
        self.solver = solver
        if solver_t is not None:
            self.solver_t = solver_t

    def refresh(self, new_values, *, validate: bool = True) -> "SolveEngine":
        """Value-only numeric refresh of the engine's factor: new ``data``
        for the same sparsity pattern (array aligned with the original L's
        CSR storage, or a pattern-identical ``CSRMatrix``).

        The queue is **drained first**: every request already submitted is
        solved against the factor it was submitted against, then the values
        swap in for subsequent solves (reusing the already-compiled
        executables via ``SpTRSV.refresh``).  Without the drain, in-flight
        requests would silently be answered with a factor that did not exist
        when they were enqueued.

        ``validate`` forwards to ``SpTRSV.refresh``'s O(nnz) value health
        scan (finiteness + zero-pivot); ``validate=False`` admits suspect
        values and leaves them to a guarded solver's breakdown policy."""
        self.run()
        self.solver.refresh(new_values, validate=validate)
        if self.solver_t is not None:
            self.solver_t.refresh(new_values, validate=validate)
        return self

    def submit(self, b: np.ndarray, *, transpose: bool = False,
               tenant: Optional[str] = None) -> SolveRequest:
        b = np.asarray(b)
        # these were asserts — stripped under ``python -O``, a wrong-length
        # RHS would silently write a truncated/broadcast column into the
        # batch buffer and corrupt every co-batched neighbour
        if b.ndim != 1 or b.shape[0] != self.solver.n:
            raise ValueError(
                f"RHS must be a ({self.solver.n},) vector; got shape "
                f"{b.shape}")
        if transpose and self.solver_t is None:
            raise ValueError(
                "transpose request but engine was built without a "
                "transpose solver (pass solver_t= or transpose_too=True)")
        req = SolveRequest(rid=self._next_rid, b=b, transpose=transpose,
                           tenant=tenant)
        self._next_rid += 1
        self.queue.append(req)
        return req

    def _bucket(self, width: int) -> int:
        """Smallest power-of-base bucket >= width, capped at max_batch."""
        m = 1
        while m < width:
            m *= self.bucket_base
        return min(m, self.max_batch)

    def _solve_group(self, solver, reqs) -> None:
        m = self._bucket(len(reqs))
        # the batch buffer is allocated in the SOLVER's dtype, not
        # result_type over the requests: one float64 request would up-cast
        # the whole bucket and miss every jit-cache entry compiled at the
        # solver's dtype (a fresh trace + compile per mixed batch)
        B = np.zeros((solver.n, m), dtype=solver.dtype)
        for j, r in enumerate(reqs):
            B[:, j] = r.b
        try:
            X = np.asarray(solver.solve_batched(jnp.asarray(B)))
        except Exception:
            # One bad RHS (or one guarded column over tolerance under
            # on_breakdown="raise") must not poison the whole micro-batch:
            # re-solve each request alone so healthy co-batched neighbours
            # still get answers and only the culprits carry the exception.
            # Each re-solve goes through the width-1 *bucket* (an (n, 1)
            # buffer at the solver's dtype) — a bare 1-D solve here would
            # trace one fresh executor per RHS dtype and bypass the bounded
            # jit-cache discipline the buckets exist for — and counts in
            # ``batches`` like every other executor dispatch, so the
            # counters stay consistent between the happy and fallback paths
            # (1 failed batched attempt + len(reqs) width-1 re-solves).
            self.batches += 1
            for r in reqs:
                b1 = np.zeros((solver.n, 1), dtype=solver.dtype)
                b1[:, 0] = r.b
                try:
                    r.x = np.asarray(
                        solver.solve_batched(jnp.asarray(b1)))[:, 0]
                except Exception as exc:
                    r.error = exc
                self.batches += 1
                r.done = True
            return
        for j, r in enumerate(reqs):
            r.x = X[:, j]
            r.done = True
        self.batches += 1

    def step(self) -> int:
        """Drain up to ``max_batch`` queued requests, batched per direction
        (forward / transpose).  Returns the number of requests completed
        (0 if the queue is empty).  Requests that complete with ``error``
        set count in ``failed``, not ``solved`` — ``stats()["solved"]``
        must mean answers, not attempts, or a breakdown-heavy tenant would
        read as healthy throughput on the dashboard."""
        if not self.queue:
            return 0
        take = min(len(self.queue), self.max_batch)
        reqs = [self.queue.popleft() for _ in range(take)]
        fwd = [r for r in reqs if not r.transpose]
        bwd = [r for r in reqs if r.transpose]
        if fwd:
            self._solve_group(self.solver, fwd)
        if bwd:
            self._solve_group(self.solver_t, bwd)
        ok = sum(1 for r in reqs if r.error is None)
        self.solved += ok
        self.failed += take - ok
        return take

    def run(self) -> int:
        """Solve everything queued; returns total completed."""
        total = 0
        while self.queue:
            total += self.step()
        return total
