"""Deterministic synthetic token pipeline with host sharding + prefetch.

Production shape: each host owns a disjoint slice of the global batch
(``host_id/num_hosts``), the stream is a pure function of (seed, step) so a
restarted/re-meshed job regenerates exactly the batches it would have seen
(elastic restart needs no data checkpoint beyond the step counter).

The generator is a mixture of Zipfian unigrams and a repeated-ngram process,
so the LM loss actually *decreases* during the example runs (pure uniform
noise would pin loss at log V).  A background thread keeps a bounded
prefetch queue — backpressure-free: a slow consumer never blocks generation
beyond ``depth`` (straggler isolation on the input side).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np

__all__ = ["Batch", "SyntheticLM", "make_loader"]


@dataclasses.dataclass
class Batch:
    tokens: np.ndarray            # (B, S) int32
    labels: np.ndarray            # (B, S) int32 (next-token, -1 = masked)
    step: int
    extras: Optional[dict] = None   # modality stubs (enc_embed / patches)


class SyntheticLM:
    def __init__(self, vocab: int, seq_len: int, global_batch: int, *,
                 seed: int = 0, host_id: int = 0, num_hosts: int = 1,
                 family: str = "dense", d_model: int = 0, prefix_len: int = 0):
        assert global_batch % num_hosts == 0
        self.vocab = vocab
        self.seq = seq_len
        self.local_batch = global_batch // num_hosts
        self.seed = seed
        self.host_id = host_id
        self.family = family
        self.d_model = d_model
        self.prefix_len = prefix_len
        # fixed zipf table
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        self.probs = (1.0 / ranks ** 1.1)
        self.probs /= self.probs.sum()

    def batch(self, step: int) -> Batch:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        B, S = self.local_batch, self.seq
        toks = rng.choice(self.vocab, size=(B, S), p=self.probs).astype(np.int32)
        # inject learnable structure: repeat a random earlier span
        for b in range(B):
            if S >= 32:
                w = int(rng.integers(8, min(17, S // 4 + 1)))
                src = int(rng.integers(0, S - 2 * w))
                dst = int(rng.integers(src + w, S - w + 1))
                toks[b, dst : dst + w] = toks[b, src : src + w]
        labels = np.concatenate([toks[:, 1:], np.full((B, 1), -1, np.int32)], 1)
        extras = {}
        if self.family == "audio":
            extras["enc_embed"] = rng.standard_normal(
                (B, S, self.d_model), dtype=np.float32)
        if self.family == "vlm":
            extras["patches"] = rng.standard_normal(
                (B, self.prefix_len, self.d_model), dtype=np.float32)
        return Batch(toks, labels, step, extras or None)


def make_loader(ds: SyntheticLM, start_step: int = 0, *,
                depth: int = 2) -> Iterator[Batch]:
    """Prefetching iterator; deterministic resume from ``start_step``."""
    q: queue.Queue = queue.Queue(maxsize=depth)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            try:
                q.put(ds.batch(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=worker, daemon=True)
    t.start()

    class _Iter:
        def __iter__(self):
            return self

        def __next__(self):
            return q.get()

        def close(self):
            stop.set()

    return _Iter()
