from .pipeline import SyntheticLM, Batch, make_loader

__all__ = ["SyntheticLM", "Batch", "make_loader"]
