"""Mesh-agnostic atomic checkpointing with async save.

* **Atomic**: writes go to ``<dir>/tmp.<step>/`` and are renamed to
  ``<dir>/step_<step>/`` only after the manifest is fsynced — a job killed
  mid-save leaves a tmp dir that restore ignores (tested).
* **Mesh-agnostic / elastic**: arrays are stored unsharded (npz, one file
  per pytree leaf path hash bucket); restore re-shards onto whatever mesh
  the new job built — 8→4→8 device round-trip is tested.
* **Async**: ``save_async`` snapshots to host memory synchronously (cheap)
  and writes in a daemon thread, overlapping I/O with the next train steps;
  ``wait()`` joins before the next save or exit.
* **Manifest**: JSON with step, config fingerprint, mesh shape at save, and
  a content checksum per shard file for corruption detection.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager", "save_pytree", "restore_pytree"]


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(p), leaf) for p, leaf in flat], treedef


def save_pytree(tree: Any, path: str, *, manifest_extra: Optional[dict] = None):
    os.makedirs(path, exist_ok=True)
    flat, _ = _flatten(tree)
    arrays = {}
    meta = {}
    for name, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        key = hashlib.md5(name.encode()).hexdigest()[:16]
        orig_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or orig_dtype in ("bfloat16", "float8_e4m3fn",
                                                   "float8_e5m2"):
            # npz cannot round-trip ml_dtypes — store widened, restore casts
            arr = arr.astype(np.float32)
        arrays[key] = arr
        meta[name] = {"key": key, "shape": list(arr.shape), "dtype": orig_dtype,
                      "sum": float(np.sum(arr.astype(np.float64))) if arr.size else 0.0}
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    manifest = {"leaves": meta, "saved_at": time.time()}
    manifest.update(manifest_extra or {})
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())


def restore_pytree(template: Any, path: str, *, shardings: Any = None) -> Any:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat, treedef = _flatten(template)
    out = []
    shard_flat = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(flat))
    for (name, leaf), sh in zip(flat, shard_flat):
        info = manifest["leaves"][name]
        arr = data[info["key"]]
        assert tuple(arr.shape) == tuple(leaf.shape), (name, arr.shape, leaf.shape)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.device_put(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- discovery -----------------------------------------------------------
    def steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, d, "manifest.json")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # -- save ------------------------------------------------------------------
    def _write(self, host_tree, step: int, extra: dict):
        tmp = os.path.join(self.dir, f"tmp.{step}")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        save_pytree(host_tree, tmp, manifest_extra=dict(extra, step=step))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def save(self, tree: Any, step: int, **extra):
        host = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)
        self._write(host, step, extra)

    def save_async(self, tree: Any, step: int, **extra):
        self.wait()
        host = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)
        self._thread = threading.Thread(
            target=self._write, args=(host, step, extra), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def restore(self, template: Any, step: Optional[int] = None, *,
                shardings: Any = None):
        step = step if step is not None else self.latest_step()
        assert step is not None, f"no checkpoint found in {self.dir}"
        path = os.path.join(self.dir, f"step_{step}")
        tree = restore_pytree(template, path, shardings=shardings)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        return tree, manifest
