"""Criticality-guided rewriting + transform planner benchmark.

Measures the three claims of the selective-rewriting PR on a lung2-class
matrix:

* **vectorized rewrite engine** — the batched NumPy/CSR elimination rounds
  against the seed-era per-row dict loop.  Reported at two boundaries:
  ``engine`` times the elimination+materialization phase that the
  vectorization actually replaced (``_rewrite_loop`` vs
  ``_rewrite_vectorized`` — the policy selection, L' level analysis and
  criticality stats around it are shared by both engines verbatim), and
  ``end_to_end`` times the full ``rewrite_matrix`` call per engine.
  ``--smoke`` asserts the engine phase is **>= 10x** faster.
* **critical_path policy** — weighted critical path before/after for
  ``policy="thin"`` vs ``policy="critical_path"``; ``--smoke`` asserts the
  criticality-guided rewrite cuts the weighted critical path **>= 25%**
  within the default fill budget.
* **transform planner** — ``strategy="auto"`` decisions (rewrite vs coarsen
  vs both, with full candidate cost tables) across matrix classes, plus a
  value-only replay timing (the array-form plan's O(nnz) refresh path).

Usage::

    python -m benchmarks.rewrite_planner              # full lung2 scale
    python -m benchmarks.rewrite_planner --smoke      # CI smoke w/ asserts
    python -m benchmarks.rewrite_planner --smoke --json BENCH_rewrite_planner.json
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro.core import RewriteConfig, SpTRSV, replay_rewrite_values, rewrite_matrix
from repro.core.csr import CSRMatrix
from repro.core.levels import build_level_sets
from repro.core.rewrite import _participants, _rewrite_loop, _rewrite_vectorized
from repro.sparse import banded_lower, chain_matrix, lung2_like, random_lower

try:  # runnable both as `python -m benchmarks.rewrite_planner` and as a file
    from .common import emit, flush_csv, write_bench_json
except ImportError:  # pragma: no cover
    from common import emit, flush_csv, write_bench_json


def _best_of(f, reps, *args, **kwargs):
    best, out = float("inf"), None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = f(*args, **kwargs)
        best = min(best, time.perf_counter() - t0)
    return best, out


def run(*, smoke: bool = False, json_path: str = ""):
    print("== rewrite_planner: criticality-guided rewriting + transform planner ==")
    # full lung2 scale in both modes: the engine-speedup margin grows with
    # size (the dict loop's Python constants dominate more), so smoke runs
    # the same matrix and just trims repetitions
    L = lung2_like(scale=1.0, dtype=np.float64)
    levels = build_level_sets(L)
    emit("rewrite_planner.rows", L.n)
    emit("rewrite_planner.nnz", L.nnz)
    results: dict = {"n": L.n, "nnz": L.nnz}

    # --- engine comparison: dict loop vs batched vectorized rounds --------
    cfg = RewriteConfig(thin_threshold=2)
    diag = L.diagonal()
    part = _participants(L, levels, cfg, upper=False)
    reps = 3 if smoke else 5
    t_vec_eng, _ = _best_of(_rewrite_vectorized, reps, L, levels, cfg,
                            upper=False, part=part, diag=diag)
    t_loop_eng, _ = _best_of(_rewrite_loop, 1, L, levels, cfg,
                             upper=False, part=part, diag=diag)
    t_vec_e2e, res_v = _best_of(
        rewrite_matrix, reps, L, levels, RewriteConfig(engine="vectorized"))
    t_loop_e2e, res_l = _best_of(
        rewrite_matrix, 1, L, levels, RewriteConfig(engine="loop"))
    assert res_v.stats.nnz_after == res_l.stats.nnz_after  # same decisions
    eng_ratio = t_loop_eng / t_vec_eng
    e2e_ratio = t_loop_e2e / t_vec_e2e
    emit("rewrite_planner.engine.loop_s", round(t_loop_eng, 4), "s")
    emit("rewrite_planner.engine.vectorized_s", round(t_vec_eng, 4), "s")
    emit("rewrite_planner.engine.speedup", round(eng_ratio, 1), "x")
    emit("rewrite_planner.end_to_end.loop_s", round(t_loop_e2e, 4), "s")
    emit("rewrite_planner.end_to_end.vectorized_s", round(t_vec_e2e, 4), "s")
    emit("rewrite_planner.end_to_end.speedup", round(e2e_ratio, 1), "x")
    results["engine"] = dict(loop_s=t_loop_eng, vectorized_s=t_vec_eng,
                             speedup=eng_ratio)
    results["end_to_end"] = dict(loop_s=t_loop_e2e, vectorized_s=t_vec_e2e,
                                 speedup=e2e_ratio)

    # --- policy comparison: thin vs critical_path --------------------------
    results["policies"] = {}
    for policy in ("thin", "critical_path"):
        t_build, res = _best_of(
            rewrite_matrix, reps, L, levels, RewriteConfig(policy=policy))
        s = res.stats
        cp_red = s.critical_path_reduction
        emit(f"rewrite_planner.{policy}.build_s", round(t_build, 4), "s")
        emit(f"rewrite_planner.{policy}.critical_path",
             f"{s.critical_path_before} -> {s.critical_path_after}",
             note=f"-{100*cp_red:.1f}%")
        emit(f"rewrite_planner.{policy}.rows_rewritten", s.rows_rewritten)
        emit(f"rewrite_planner.{policy}.fill_ratio",
             round(s.nnz_after / s.nnz_before, 3))
        results["policies"][policy] = dict(
            build_s=t_build,
            critical_path_before=s.critical_path_before,
            critical_path_after=s.critical_path_after,
            critical_path_reduction=cp_red,
            rows_rewritten=s.rows_rewritten,
            nnz_before=s.nnz_before, nnz_after=s.nnz_after,
            levels_before=s.levels_before, levels_after=s.levels_after,
            eliminations_skipped=s.eliminations_skipped)

    # --- value-only replay (array-form plan) -------------------------------
    rng = np.random.default_rng(1)
    d2 = L.data + 0.05 * rng.standard_normal(L.nnz)
    d2[L.indptr[1:] - 1] += 2.0
    L2 = CSRMatrix(L.indptr, L.indices, d2, L.shape)
    t_replay, _ = _best_of(replay_rewrite_values, reps, L2, res_v.plan,
                           res_v.L, res_v.E)
    emit("rewrite_planner.replay_s", round(t_replay, 4), "s",
         note=f"{t_vec_e2e/t_replay:.1f}x faster than a fresh rewrite")
    results["replay"] = dict(replay_s=t_replay,
                             vs_fresh_rewrite=t_vec_e2e / t_replay)

    # --- transform planner decisions across matrix classes -----------------
    mats = {
        "lung2": lung2_like(scale=0.1 if smoke else 0.25, dtype=np.float32),
        "chain": chain_matrix(2000, dtype=np.float32),
        "random": random_lower(2000, avg_offdiag=3.0, seed=0, dtype=np.float32),
        "banded": banded_lower(1500, bandwidth=8, seed=1, dtype=np.float32),
    }
    results["planner"] = {}
    rng = np.random.default_rng(0)
    for name, M in mats.items():
        t0 = time.perf_counter()
        s = SpTRSV.build(M, strategy="auto")
        build_s = time.perf_counter() - t0
        b = rng.standard_normal(M.n).astype(np.float32)
        err = float(np.abs(
            np.asarray(s.solve(jnp.asarray(b)))
            - np.asarray(SpTRSV.build(M, strategy="serial")
                         .solve(jnp.asarray(b)))).max())
        emit(f"rewrite_planner.auto.{name}",
             f"{s.strategy}"
             + (f"+rewrite:{s.plan.rewrite}" if s.plan.rewrite else "")
             + ("+coarsen" if s.plan.coarsen else ""),
             note=f"build {build_s:.2f}s, err {err:.1e}")
        results["planner"][name] = dict(
            strategy=s.strategy, rewrite=s.plan.rewrite,
            coarsen=s.plan.coarsen, build_s=build_s, err=err,
            costs={k: float(v) for k, v in s.plan.costs.items()})

    if smoke:
        # Acceptance (ISSUE 5): criticality-guided rewrite cuts the weighted
        # critical path >= 25% within the default fill budget, and the
        # batched engine replaces the dict loop at >= 10x.
        cp = results["policies"]["critical_path"]
        assert cp["critical_path_reduction"] >= 0.25, cp
        assert cp["nnz_after"] <= RewriteConfig().max_fill_ratio * cp["nnz_before"], cp
        assert eng_ratio >= 10.0, (
            f"vectorized engine only {eng_ratio:.1f}x faster than the dict "
            f"loop ({t_vec_eng:.3f}s vs {t_loop_eng:.3f}s)")
        # end-to-end (shared analysis included on both sides) must also win
        # clearly — guards a regression hiding in the shared phases
        assert e2e_ratio >= 2.0, (t_loop_e2e, t_vec_e2e)
        # the planner must transform the lung2-class matrix and leave the
        # chain to a sequential executor (the serial scan, or the sync-free
        # sweep once its candidate is priced) without rewriting it
        assert results["planner"]["lung2"]["rewrite"] is not None
        assert results["planner"]["chain"]["strategy"] in ("serial", "sweep")
        assert results["planner"]["chain"]["rewrite"] is None
        for name, row in results["planner"].items():
            assert row["err"] < 1e-4, (name, row["err"])
        print("  smoke assertions passed (critical path -"
              f"{100*cp['critical_path_reduction']:.0f}%, engine "
              f"{eng_ratio:.1f}x, planner transforms recorded)")

    if json_path:
        write_bench_json(json_path, "rewrite_planner", results,
                         n=L.n, nnz=L.nnz)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller matrix + acceptance assertions (CI)")
    ap.add_argument("--json", default="", help="write results JSON here")
    ap.add_argument("--csv", default="")
    args = ap.parse_args()
    run(smoke=args.smoke, json_path=args.json)
    if args.csv:
        flush_csv(args.csv)
