"""Shared benchmark helpers: wall-time measurement + CSV emission."""
from __future__ import annotations

import time

import jax
import numpy as np

ROWS = []


def timeit(fn, *args, iters: int = 10, warmup: int = 3) -> float:
    """Median seconds/call after warmup (jit-compiled callables)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, value, unit: str = "", **extra):
    ROWS.append({"name": name, "value": value, "unit": unit, **extra})
    ex = " ".join(f"{k}={v}" for k, v in extra.items())
    print(f"  {name:<44s} {value:>14} {unit:<10s} {ex}")


def flush_csv(path: str):
    import csv, os
    os.makedirs(os.path.dirname(path), exist_ok=True)
    keys = sorted({k for r in ROWS for k in r})
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys)
        w.writeheader()
        w.writerows(ROWS)
