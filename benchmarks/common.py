"""Shared benchmark helpers: wall-time measurement + CSV/JSON emission.

The JSON side defines the repo's **shared perf-trajectory schema**: every
``BENCH_*.json`` artifact is ``{"schema": [...], "records": [...]}`` where
each record carries ``name`` (dotted metric group), ``backend`` (resolved
kernel backend the run executed on), ``n`` / ``nnz`` (problem size),
``metric`` (leaf key) and ``value`` — so CI can diff trajectories across
benchmarks without per-script parsers.
"""
from __future__ import annotations

import json
import numbers
import time

import jax
import numpy as np

ROWS = []

BENCH_SCHEMA = ("name", "backend", "n", "nnz", "metric", "value")


def timeit(fn, *args, iters: int = 10, warmup: int = 3) -> float:
    """Median seconds/call after warmup (jit-compiled callables)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, value, unit: str = "", **extra):
    ROWS.append({"name": name, "value": value, "unit": unit, **extra})
    ex = " ".join(f"{k}={v}" for k, v in extra.items())
    print(f"  {name:<44s} {value:>14} {unit:<10s} {ex}")


def flush_csv(path: str):
    import csv, os
    os.makedirs(os.path.dirname(path), exist_ok=True)
    keys = sorted({k for r in ROWS for k in r})
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys)
        w.writeheader()
        w.writerows(ROWS)


def _scalar(v):
    """JSON-able scalar or None (numpy scalars coerced; arrays rejected)."""
    if isinstance(v, (bool, str)) or v is None:
        return v
    if isinstance(v, numbers.Integral):
        return int(v)
    if isinstance(v, numbers.Real):
        return float(v)
    return None


def to_records(prefix: str, results, *, backend=None, n=None, nnz=None):
    """Flatten a nested result dict into shared-schema records: the dotted
    path is split as name (all but the leaf) + metric (the leaf); non-scalar
    leaves (schedules, arrays) are skipped."""
    if backend is None:
        from repro.kernels.backend import default_backend_name

        backend = default_backend_name()
    recs = []

    def walk(name, v):
        if isinstance(v, dict):
            for k, w in v.items():
                walk(f"{name}.{k}" if name else str(k), w)
            return
        sv = _scalar(v)
        if sv is None and v is not None:
            return
        head, _, metric = name.rpartition(".")
        recs.append({"name": f"{prefix}.{head}" if head else prefix,
                     "backend": backend, "n": n, "nnz": nnz,
                     "metric": metric or name, "value": sv})

    walk("", results)
    return recs


def write_bench_json(path: str, prefix: str, results, *,
                     backend=None, n=None, nnz=None):
    """Write a shared-schema ``BENCH_*.json`` perf-trajectory artifact."""
    payload = {
        "schema": list(BENCH_SCHEMA),
        "records": to_records(prefix, results, backend=backend, n=n, nnz=nnz),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"  wrote {path} ({len(payload['records'])} records)")
