"""Paper Fig. 6: #levels and #FLOPs before/after equation rewriting.

Paper (lung2, 109,460 rows / 492,564 nnz / 478 levels, 94% thin):
    levels 478 -> 66 (-86% synchronization barriers), FLOPs +10%.
We reproduce on the structural twin `lung2_like` (SuiteSparse is offline)
plus the chain / IC(0)-Poisson workloads, and validate the same regime:
large barrier reduction at single-digit-% FLOP increase.
"""
from __future__ import annotations

import numpy as np

from repro.core import RewriteConfig, rewrite_matrix
from repro.core.levels import build_level_sets
from repro.sparse import chain_matrix, ic0_factor, lung2_like, poisson2d

from .common import emit


def run(full_scale: bool = True):
    print("== fig6_levels: equation rewriting level/FLOP transformation ==")
    mats = {
        "lung2_like": lung2_like(scale=1.0 if full_scale else 0.1),
        "chain_4096": chain_matrix(4096),
        "ic0_poisson_64x64": ic0_factor(poisson2d(64, 64)),
    }
    results = {}
    for name, L in mats.items():
        lv = build_level_sets(L)
        res = rewrite_matrix(L, lv, RewriteConfig(thin_threshold=2))
        st = res.stats
        emit(f"{name}.rows", L.n)
        emit(f"{name}.nnz", L.nnz)
        emit(f"{name}.levels_before", st.levels_before)
        emit(f"{name}.levels_after", st.levels_after)
        emit(f"{name}.barrier_reduction", f"{100*st.level_reduction:.1f}", "%")
        emit(f"{name}.flops_before", st.flops_before)
        emit(f"{name}.flops_after", st.flops_after)
        emit(f"{name}.flop_increase", f"{100*st.flop_increase:.1f}", "%")
        emit(f"{name}.thin_fraction", f"{100*lv.thin_fraction(2):.1f}", "%")
        results[name] = st

    st = results["lung2_like"]
    # paper-claims validation (structural twin): 478->66 = -86%; +10% FLOPs.
    # FLOP overhead is scale-dependent (fill-in amortizes over fat levels),
    # so the +10% regime check applies at full scale only.
    assert st.levels_before > 400, st.levels_before
    assert st.level_reduction > 0.80, st.summary()
    if full_scale:
        assert st.flop_increase < 0.20, st.summary()
    print(f"  [paper check] lung2-like: {st.summary()}")
    print(f"  [paper claim] lung2     : levels 478 -> 66 (-86.2%), FLOPs +10%")
    return results


if __name__ == "__main__":
    run()
