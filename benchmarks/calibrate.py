"""Calibration micro-run: measure the planner's pricing coefficients on the
live device and write them as a ``calibration.json`` table.

:func:`repro.core.coarsen.plan_strategy` prices strategies in
FLOP-equivalents using per-backend coefficients
(:mod:`repro.core.calibrate`).  The shipped defaults are conservative; this
micro-run replaces the row for the *current* backend family with measured
numbers:

* **gather throughput** — reference flops/second of the padded ELL
  gather-FMA the level executors are made of (a jitted ``spmv_ref``-shaped
  contraction).  This anchors the FLOP-equivalent unit.
* **launch cost** — wall time of one dispatch of a trivially small jitted
  kernel, converted to FLOP-equivalents at the measured gather throughput.
  This is the per-segment barrier price.
* **serial step cost** — per-row wall time of the ``lax.scan`` serial
  solver at two sizes, split into the base + scale-with-n model the planner
  uses (latency-bound rows; the carried x vector falls out of cache as n
  grows).
* **gemm cost** — relative price of one dense batched-GEMM flop of the
  blocked executor's diagonal-block apply, measured against the gather
  reference (contiguous flops are cheaper than gathered ones everywhere,
  dramatically so on MXU hardware).
* **trsm cost** — fixed per-diagonal-block overhead of the batched block
  apply, from a two-point linear fit over the batch dimension.

Unmeasured keys (lane width, fused dispatch shape and row bound) keep the
shipped defaults for the family — they are device *facts*, not timings.

Usage::

    python -m benchmarks.calibrate                     # print the row
    python -m benchmarks.calibrate --json calibration.json
    python -m benchmarks.calibrate --smoke --bench-json BENCH_calibrate.json
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SpTRSV
from repro.core.calibrate import (
    DEFAULT_CALIBRATIONS,
    get_calibration,
    save_calibrations,
)
from repro.kernels.backend import resolve_backend
from repro.sparse import chain_matrix

try:  # runnable both as `python -m benchmarks.calibrate` and as a file
    from .common import emit, timeit, write_bench_json
except ImportError:  # pragma: no cover
    from common import emit, timeit, write_bench_json


def _gather_flops_per_s(n: int = 1 << 16, K: int = 8, iters: int = 20):
    """Reference throughput of the padded ELL gather-FMA contraction."""
    rng = np.random.default_rng(0)
    cols = jnp.asarray(rng.integers(0, n, size=(K, n)).astype(np.int32))
    vals = jnp.asarray(rng.standard_normal((K, n)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal(n).astype(np.float32))

    @jax.jit
    def gather_fma(v, cols, vals):
        return jnp.sum(vals * v[cols], axis=0)

    t = timeit(gather_fma, v, cols, vals, iters=iters, warmup=5)
    return 2.0 * K * n / t


def _launch_seconds(iters: int = 50):
    """Per-dispatch overhead: one trivially small jitted kernel."""
    x = jnp.zeros((8,), jnp.float32)

    @jax.jit
    def tiny(x):
        return x + 1.0

    return timeit(tiny, x, iters=iters, warmup=5)


def _serial_row_seconds(n: int, iters: int = 5):
    """Per-row wall time of the lax.scan serial solver at size n."""
    L = chain_matrix(n, dtype=np.float32)
    s = SpTRSV.build(L, strategy="serial")
    b = jnp.asarray(np.random.default_rng(1).standard_normal(n)
                    .astype(np.float32))
    return timeit(s.solve, b, iters=iters, warmup=2) / n


def _block_apply_seconds(B: int, T: int = 32, iters: int = 20):
    """Wall time of the blocked executor's batched diagonal-block apply
    ``(B, T, T) x (B, T) -> (B, T)`` at batch size B."""
    from repro.kernels.trsm_block.ops import make_block_apply

    rng = np.random.default_rng(2)
    dinv = jnp.asarray(rng.standard_normal((B, T, T)).astype(np.float32))
    rhs = jnp.asarray(rng.standard_normal((B, T)).astype(np.float32))
    apply = jax.jit(make_block_apply(None))
    return timeit(apply, dinv, rhs, iters=iters, warmup=5)


def run(*, json_path: str = "", smoke: bool = False, bench_json: str = ""):
    print("== calibrate: planner pricing coefficients (micro-run) ==")
    bk = resolve_backend(None)
    key = bk.calibration_key
    base = get_calibration(key)
    it_scale = 3 if smoke else 1

    flops_per_s = _gather_flops_per_s(iters=max(20 // it_scale, 5))
    launch_s = _launch_seconds(iters=max(50 // it_scale, 10))
    launch_cost = launch_s * flops_per_s
    n_small, n_big = (1 << 10, 1 << 13) if smoke else (1 << 11, 1 << 15)
    row_small = _serial_row_seconds(n_small)
    row_big = _serial_row_seconds(n_big)
    # fit per-row cost = base + scale * n (FLOP-equivalents)
    scale = max((row_big - row_small) / (n_big - n_small), 0.0) * flops_per_s
    serial_base = max(row_small * flops_per_s - scale * n_small, 1.0)

    # blocked-executor coefficients: dense flop price from the marginal cost
    # per diagonal block (a two-point fit over the batch dimension strips the
    # dispatch overhead), per-block overhead from the intercept.
    T = 32
    b_small, b_big = (64, 256) if smoke else (128, 512)
    t_small = _block_apply_seconds(b_small, T=T, iters=max(20 // it_scale, 5))
    t_big = _block_apply_seconds(b_big, T=T, iters=max(20 // it_scale, 5))
    per_block_s = max((t_big - t_small) / (b_big - b_small), 0.0)
    gemm_cost = max(per_block_s * flops_per_s / (2.0 * T * T), 1e-4)
    intercept_s = max(t_small - per_block_s * b_small, 0.0)
    trsm_cost = max(intercept_s * flops_per_s / b_small, 1.0)

    measured = dataclasses.replace(
        base,
        launch_cost=round(launch_cost, 1),
        gather_cost=1.0,  # the gather micro-run defines the reference unit
        serial_step_cost=round(serial_base, 2),
        serial_step_cost_scale=round(scale, 4),
        gemm_cost=round(gemm_cost, 4),
        trsm_cost=round(trsm_cost, 2),
        source="measured",
    )
    emit("calibrate.backend", bk.name, family=key)
    emit("calibrate.gather_gflops", round(flops_per_s / 1e9, 3), "GFLOP/s")
    emit("calibrate.launch_us", round(launch_s * 1e6, 2), "us")
    emit("calibrate.launch_cost", measured.launch_cost, "flop-eq")
    emit("calibrate.serial_step_cost", measured.serial_step_cost, "flop-eq")
    emit("calibrate.serial_step_cost_scale", measured.serial_step_cost_scale)
    emit("calibrate.gemm_cost", measured.gemm_cost, "flop-eq/flop")
    emit("calibrate.trsm_cost", measured.trsm_cost, "flop-eq/block")

    table = dict(DEFAULT_CALIBRATIONS)
    table[key] = measured
    if json_path:
        save_calibrations(json_path, table)
        print(f"  wrote {json_path}")
    if bench_json:
        write_bench_json(
            bench_json, "calibrate",
            {key: {f.name: getattr(measured, f.name)
                   for f in dataclasses.fields(measured)},
             "gather_gflops": flops_per_s / 1e9,
             "launch_us": launch_s * 1e6},
            backend=bk.name)
    return table


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer iterations / smaller scan sizes (CI)")
    ap.add_argument("--json", default="",
                    help="write the refreshed calibration table here")
    ap.add_argument("--bench-json", default="",
                    help="write a shared-schema BENCH_*.json trajectory "
                         "artifact of the measured row")
    args = ap.parse_args()
    run(json_path=args.json, smoke=args.smoke, bench_json=args.bench_json)
