"""Aggregate dry-run JSONs into the §Roofline table (markdown + CSV)."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")

COLS = ("arch", "shape", "mesh", "status", "compute_s", "memory_s",
        "collective_s", "dominant", "useful", "coll_GB", "flops_T")


def load():
    recs = []
    for p in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        try:
            recs.append(json.load(open(p)))
        except Exception:
            pass
    return recs


def row(r):
    if r.get("status") != "ok":
        return {"arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
                "status": r.get("status", "?"),
                "note": (r.get("reason") or r.get("error", ""))[:60]}
    t = r["analysis"]["terms"]
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "status": "ok",
        "compute_s": f"{t['compute_s']:.3f}",
        "memory_s": f"{t['memory_s']:.3f}",
        "collective_s": f"{t['collective_s']:.3f}",
        "dominant": r["analysis"]["dominant"].replace("_s", ""),
        "useful": f"{r['useful_ratio']:.2f}" if r.get("useful_ratio") else "-",
        "coll_GB": f"{r['analysis']['collective']['total']/1e9:.1f}",
        "flops_T": f"{r['analysis']['hlo_flops']/1e12:.1f}",
    }


def markdown_table(recs, mesh="pod"):
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful 6ND/HLO | status |\n"
           "|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    for r in sorted((x for x in recs if x["mesh"] == mesh),
                    key=lambda x: (x["arch"], order.get(x["shape"], 9))):
        d = row(r)
        if d["status"] == "ok":
            lines.append(
                f"| {d['arch']} | {d['shape']} | {d['compute_s']} | "
                f"{d['memory_s']} | {d['collective_s']} | {d['dominant']} | "
                f"{d['useful']} | ok |")
        else:
            lines.append(
                f"| {d['arch']} | {d['shape']} | - | - | - | - | - | "
                f"{d['status']}: {d.get('note','')} |")
    return "\n".join(lines)


def run(full_scale: bool = True):
    print("== roofline: dry-run aggregation ==")
    recs = load()
    ok = sum(1 for r in recs if r.get("status") == "ok")
    sk = sum(1 for r in recs if r.get("status") == "skipped")
    fail = len(recs) - ok - sk
    print(f"  cells: {ok} ok / {sk} skipped / {fail} failed "
          f"(of {len(recs)} recorded)")
    for mesh in ("pod", "multipod"):
        n = sum(1 for r in recs if r["mesh"] == mesh and r.get("status") == "ok")
        print(f"  {mesh}: {n} compiled")
    out = os.path.join(RESULTS, "..", "roofline_table.md")
    with open(out, "w") as f:
        for mesh in ("pod", "multipod"):
            f.write(f"### mesh = {mesh}\n\n")
            f.write(markdown_table(recs, mesh))
            f.write("\n\n")
    print(f"  table -> {os.path.abspath(out)}")
    return {"ok": ok, "skipped": sk, "failed": fail}


if __name__ == "__main__":
    run()
