"""Speculative sweep benchmark: sync points, solve time, residual quality.

The level-set executor — even coarsened — pays one host/device barrier per
schedule segment.  The ``sweep`` strategy replaces the whole dependency
schedule with k data-parallel Jacobi sweeps ``x <- D^{-1}(b - N x)`` over
*all* rows: zero intra-solve barriers, one residual-verification readback
per solve, and an exact fallback for the (certified-away) non-converged
case.  On a lung2-class matrix that trades ~hundreds of barrier-separated
segments for a single fused region.

Reported per configuration:

* ``sync_points``   barriers per solve (schedule segments; 1 for sweep —
  the verification readback)
* ``build_s``       analysis + trace + compile time
* ``solve_s``       median per-solve wall time
* ``max_err``       vs the row-serial oracle solve
* ``residual``      sweep's componentwise residual ratio vs its tolerance

``--smoke`` runs a scaled-down matrix and *asserts* the PR-6 acceptance
criteria: >= 5x fewer sync points than the coarsened level-set schedule,
residual within the verification tolerance, and zero fallback solves — a
CI guard against convergence or certification regressions the unit tests
cannot see.  ``--json PATH`` writes the result dict for artifact diffing.

Usage::

    python -m benchmarks.sweep                               # lung2-scale
    python -m benchmarks.sweep --smoke --json BENCH_sweep.json   # CI
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core import SpTRSV
from repro.core.sweep import default_residual_tol
from repro.sparse import lung2_like

try:  # runnable both as `python -m benchmarks.sweep` and as a file
    from .common import emit, flush_csv, timeit, write_bench_json
except ImportError:  # pragma: no cover
    from common import emit, flush_csv, timeit, write_bench_json


def run(*, smoke: bool = False, json_path: str = ""):
    print("== sweep: speculative solve-then-correct vs level-set ==")
    if smoke:
        L = lung2_like(scale=0.05, fat_levels=6, thin_run=10, dtype=np.float32)
        iters, warmup = 10, 2
    else:
        L = lung2_like(scale=1.0, dtype=np.float32)
        iters, warmup = 5, 2
    emit("sweep.rows", L.n)
    emit("sweep.nnz", L.nnz)

    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal(L.n).astype(np.float32))
    oracle = np.asarray(SpTRSV.build(L, strategy="serial").solve(b))
    results: dict = {"rows": L.n, "nnz": L.nnz}

    # coarsened level-set baseline: one barrier per schedule segment
    t0 = time.perf_counter()
    s_ls = SpTRSV.build(L, strategy="levelset", coarsen=True)
    s_ls.solve(b).block_until_ready()
    ls_build = time.perf_counter() - t0
    ls_sync = s_ls.schedule.num_segments
    ls_solve = timeit(s_ls.solve, b, iters=iters, warmup=warmup)
    ls_err = float(np.abs(np.asarray(s_ls.solve(b)) - oracle).max())
    emit("sweep.levelset.sync_points", ls_sync)
    emit("sweep.levelset.build_s", round(ls_build, 4), "s")
    emit("sweep.levelset.solve_s", f"{ls_solve:.3e}", "s")
    emit("sweep.levelset.max_err", f"{ls_err:.2e}")
    results["levelset"] = dict(sync_points=ls_sync, build_s=ls_build,
                               solve_s=ls_solve, err=ls_err)

    # speculative sweep: zero intra-solve barriers, one verification readback
    t0 = time.perf_counter()
    s_sw = SpTRSV.build(L, strategy="sweep")
    s_sw.solve(b).block_until_ready()
    sw_build = time.perf_counter() - t0
    sw_solve = timeit(s_sw.solve, b, iters=iters, warmup=warmup)
    sw_err = float(np.abs(np.asarray(s_sw.solve(b)) - oracle).max())
    st = s_sw.sweep_stats
    tol = default_residual_tol(L.dtype)
    sw_sync = 1  # the verification readback; the k sweeps share one region
    emit("sweep.sweep.sync_points", sw_sync)
    emit("sweep.sweep.k", st.k)
    emit("sweep.sweep.build_s", round(sw_build, 4), "s")
    emit("sweep.sweep.solve_s", f"{sw_solve:.3e}", "s")
    emit("sweep.sweep.max_err", f"{sw_err:.2e}")
    emit("sweep.sweep.residual_ratio", f"{st.last_residual_ratio:.2e}",
         tol=f"{tol:.2e}")
    emit("sweep.sweep.fallback_solves", st.fallback_solves)
    results["sweep"] = dict(sync_points=sw_sync, k=st.k, build_s=sw_build,
                            solve_s=sw_solve, err=sw_err,
                            residual_ratio=st.last_residual_ratio,
                            residual_tol=tol,
                            fallback_solves=st.fallback_solves)

    ratio = ls_sync / sw_sync
    emit("sweep.sync_reduction", round(ratio, 1), "x")
    emit("sweep.solve_speedup", round(ls_solve / sw_solve, 3), "x")
    results["sync_reduction"] = ratio
    results["solve_speedup"] = ls_solve / sw_solve

    # auto planner on the same matrix: record what it picked and why
    s_auto = SpTRSV.build(L, strategy="auto")
    err_auto = float(np.abs(np.asarray(s_auto.solve(b)) - oracle).max())
    emit("sweep.auto.strategy", s_auto.strategy,
         planned_sweeps=s_auto.plan.sweep_k)
    emit("sweep.auto.max_err", f"{err_auto:.2e}")
    results["auto"] = dict(strategy=s_auto.strategy,
                           planned_sweeps=s_auto.plan.sweep_k, err=err_auto)

    if smoke:
        # PR-6 acceptance: the speculative path must beat the coarsened
        # schedule on sync points by >= 5x on a lung2-class matrix, stay
        # within its own verification tolerance (so no solve ever falls
        # back), and match the oracle to fp tolerance.
        assert ratio >= 5.0, f"sync reduction {ratio:.1f}x < 5x"
        assert st.last_residual_ratio <= tol, (
            f"residual {st.last_residual_ratio:.2e} > tol {tol:.2e}")
        assert st.fallback_solves == 0, st.report()
        assert sw_err < 1e-4, sw_err
        assert err_auto < 1e-4, err_auto
        print("  smoke assertions passed "
              f"({ratio:.0f}x fewer sync points, residual "
              f"{st.last_residual_ratio:.1e} <= {tol:.1e}, 0 fallbacks)")

    if json_path:
        write_bench_json(json_path, "sweep", results,
                         n=results["rows"], nnz=results["nnz"])
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small matrix + acceptance assertions (CI)")
    ap.add_argument("--json", default="", help="write results JSON here")
    ap.add_argument("--csv", default="")
    args = ap.parse_args()
    run(smoke=args.smoke, json_path=args.json)
    if args.csv:
        flush_csv(args.csv)
