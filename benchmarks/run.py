"""Benchmark harness: one module per paper table/figure + TPU-adaptation
benches.  ``python -m benchmarks.run [--quick]`` prints every metric and
writes benchmarks/results/bench.csv.

  fig6_levels    paper Fig. 6 (levels/FLOPs before-after rewriting)
  exp1_codegen   paper §V experiment 1 (generated vs handwritten, serial)
  exp2_rewrite   paper §V experiment 2 (rewritten end-to-end)
  kernels_bench  Pallas kernel structure + sanity timings
  dist_solve     distributed solve collective counts (8 virtual devices)
  roofline       aggregates dry-run JSONs into the §Roofline table
  train_bench    tokens/s of the smoke-scale end-to-end train step
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def train_bench(full_scale: bool):
    print("== train_bench: end-to-end smoke train step ==")
    import jax
    from repro.configs import smoke_config
    from repro.data import SyntheticLM
    from repro.models.model import Model
    from repro.optim import get_optimizer
    from repro.train.steps import make_train_step
    from .common import emit, timeit

    for arch in ("gemma3-1b", "recurrentgemma-2b", "llama4-scout-17b-a16e"):
        cfg = smoke_config(arch)
        model = Model(cfg, remat=False)
        params = model.init(jax.random.key(0))
        opt = get_optimizer("adamw")
        state = opt.init(params)
        B, S = (8, 128) if full_scale else (2, 32)
        data = SyntheticLM(cfg.vocab_size, S, B)
        b = data.batch(0)
        batch = {"tokens": b.tokens, "labels": b.labels}
        step = jax.jit(make_train_step(model, opt))
        t = timeit(lambda: step(params, state, batch), iters=3, warmup=1)
        emit(f"train.{arch}.ms_per_step", f"{t*1e3:.1f}", "ms",
             toks_per_s=f"{B*S/t:.0f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced matrix scale (CI-speed)")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    full = not args.quick

    import jax
    jax.config.update("jax_num_cpu_devices", 8)   # dist_solve needs a mesh

    from . import dist_solve, exp1_codegen, exp2_rewrite, fig6_levels, \
        kernels_bench, roofline
    from .common import flush_csv

    suites = {
        "fig6_levels": fig6_levels.run,
        "exp1_codegen": exp1_codegen.run,
        "exp2_rewrite": exp2_rewrite.run,
        "kernels_bench": kernels_bench.run,
        "dist_solve": dist_solve.run,
        "roofline": roofline.run,
        "train_bench": train_bench,
    }
    names = args.only.split(",") if args.only else list(suites)
    for name in names:
        suites[name](full)
        print()
    flush_csv(os.path.join(os.path.dirname(__file__), "results", "bench.csv"))
    print("bench.csv written")


if __name__ == "__main__":
    main()
