"""Guarded execution benchmark: verification overhead, mixed-precision
refinement quality, and the breakdown machinery under injected faults.

The guard layer (``SpTRSV.build(..., guard=...)``) adds exactly one fused
componentwise residual pass + one ratio readback per solve.  This benchmark
prices that guarantee and checks the two claims the robustness PR makes:

* **Overhead** — a guarded fp64 solve on a lung2-class factor costs at most
  a few percent over the unguarded solve (the residual pass is one ELL
  SpMV against hundreds of barrier-separated level launches);
* **Mixed precision** — bf16 value storage + fp32 accumulation + iterative
  refinement against the fp64 residual recovers fp64-class componentwise
  accuracy (``<= 128·eps(fp64)``) within a small, fixed number of
  refinement steps.

``--smoke`` asserts both (guarded fp64 overhead <= 1.15x unguarded;
bf16+refine residual within ``128·eps(fp64)`` in <= 3 steps) plus that the
fallback breakdown path actually fires under an injected zero pivot — the
CI tie-in for the fault harness.  ``--json PATH`` writes the shared-schema
perf-trajectory artifact.

Usage::

    python -m benchmarks.guard                              # lung2-scale
    python -m benchmarks.guard --smoke --json BENCH_guard.json   # CI
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.compat import enable_x64
from repro.core import GuardConfig, SpTRSV
from repro.core.sweep import default_residual_tol
from repro.sparse import inject_values, lung2_like

try:  # runnable both as `python -m benchmarks.guard` and as a file
    from .common import emit, flush_csv, timeit, write_bench_json
except ImportError:  # pragma: no cover
    from common import emit, flush_csv, timeit, write_bench_json

MAX_OVERHEAD = 1.15
MAX_REFINE_STEPS = 3


def run(*, smoke: bool = False, json_path: str = ""):
    print("== guard: verified execution overhead + mixed-precision refine ==")
    with enable_x64():
        if smoke:
            # Deep level structure (~1.1k levels) like real lung2: the solve
            # is launch-bound, the residual check is one fused SpMV — the
            # regime the overhead bound is a claim about.
            L = lung2_like(scale=0.05, fat_levels=20, thin_run=60,
                           dtype=np.float64)
            iters, warmup = 10, 3
        else:
            L = lung2_like(scale=1.0, dtype=np.float64)
            iters, warmup = 5, 2
        emit("guard.rows", L.n)
        emit("guard.nnz", L.nnz)
        tol = default_residual_tol(np.float64)
        emit("guard.residual_tol", f"{tol:.2e}")
        results: dict = {"rows": L.n, "nnz": L.nnz, "residual_tol": tol}

        rng = np.random.default_rng(0)
        b = jnp.asarray(rng.standard_normal(L.n))

        # -- unguarded fp64 baseline ------------------------------------
        t0 = time.perf_counter()
        s_plain = SpTRSV.build(L, strategy="levelset")
        s_plain.solve(b).block_until_ready()
        plain_build = time.perf_counter() - t0
        plain_solve = timeit(s_plain.solve, b, iters=iters, warmup=warmup)
        emit("guard.unguarded.build_s", round(plain_build, 4), "s")
        emit("guard.unguarded.solve_s", f"{plain_solve:.3e}", "s")
        results["unguarded"] = dict(build_s=plain_build, solve_s=plain_solve)

        # -- guarded fp64: one residual pass + one readback per solve ----
        t0 = time.perf_counter()
        s_g = SpTRSV.build(L, strategy="levelset", guard=True)
        np.asarray(s_g.solve(b))  # guard solve returns post-readback
        g_build = time.perf_counter() - t0
        g_solve = timeit(s_g.solve, b, iters=iters, warmup=warmup)
        st = s_g.guard.stats
        overhead = g_solve / plain_solve
        emit("guard.guarded.build_s", round(g_build, 4), "s")
        emit("guard.guarded.solve_s", f"{g_solve:.3e}", "s")
        emit("guard.guarded.overhead", round(overhead, 3), "x")
        emit("guard.guarded.residual_ratio", f"{st.last_residual_ratio:.2e}",
             tol=f"{tol:.2e}")
        emit("guard.guarded.refine_steps", st.last_refine_steps)
        results["guarded"] = dict(
            build_s=g_build, solve_s=g_solve, overhead=overhead,
            residual_ratio=st.last_residual_ratio,
            refine_steps=st.last_refine_steps, verified=st.verified)

        # -- mixed precision: bf16 values + fp32 accum + fp64 refinement -
        t0 = time.perf_counter()
        s_mx = SpTRSV.build(
            L, strategy="levelset",
            guard=GuardConfig(precision="mixed",
                              refine_steps=MAX_REFINE_STEPS))
        np.asarray(s_mx.solve(b))
        mx_build = time.perf_counter() - t0
        mx_solve = timeit(s_mx.solve, b, iters=iters, warmup=warmup)
        stm = s_mx.guard.stats
        emit("guard.mixed.build_s", round(mx_build, 4), "s")
        emit("guard.mixed.solve_s", f"{mx_solve:.3e}", "s")
        emit("guard.mixed.residual_ratio", f"{stm.last_residual_ratio:.2e}",
             tol=f"{tol:.2e}")
        emit("guard.mixed.refine_steps", stm.last_refine_steps,
             max=MAX_REFINE_STEPS)
        emit("guard.mixed.verified", stm.verified)
        results["mixed"] = dict(
            build_s=mx_build, solve_s=mx_solve,
            residual_ratio=stm.last_residual_ratio,
            refine_steps=stm.last_refine_steps, verified=stm.verified)

        # -- breakdown machinery: injected zero pivot must route through
        #    the pivot-repaired fallback and stay finite -------------------
        s_fb = SpTRSV.build(L, strategy="levelset",
                            guard=GuardConfig(on_breakdown="fallback",
                                              refine_steps=1))
        s_fb.refresh(inject_values(L, "zero_pivot", seed=7), validate=False)
        x_fb = np.asarray(s_fb.solve(b))
        stf = s_fb.guard.stats
        emit("guard.fallback.fired", stf.fallback_solves)
        emit("guard.fallback.pivot_alarms", stf.pivot_alarms)
        emit("guard.fallback.finite", bool(np.isfinite(x_fb).all()))
        results["fallback"] = dict(
            fired=stf.fallback_solves, pivot_alarms=stf.pivot_alarms,
            finite=bool(np.isfinite(x_fb).all()))

        if smoke:
            # PR-9 acceptance: bf16 storage + refinement recovers fp64-class
            # componentwise accuracy within the step budget, the guarded
            # fp64 path costs <= 1.15x the unguarded one, and the injected
            # zero-pivot breakdown actually exercises the fallback.
            assert stm.verified == stm.solves, stm.report()
            assert stm.last_residual_ratio <= tol, (
                f"mixed residual {stm.last_residual_ratio:.2e} > "
                f"tol {tol:.2e}")
            assert stm.last_refine_steps <= MAX_REFINE_STEPS, stm.report()
            assert overhead <= MAX_OVERHEAD, (
                f"guarded overhead {overhead:.3f}x > {MAX_OVERHEAD}x")
            assert st.verified == st.solves, st.report()
            assert st.last_refine_steps == 0, st.report()
            assert stf.fallback_solves == 1, stf.report()
            assert stf.pivot_alarms >= 1, stf.report()
            assert np.isfinite(x_fb).all()
            print("  smoke assertions passed "
                  f"(overhead {overhead:.3f}x <= {MAX_OVERHEAD}x, mixed "
                  f"residual {stm.last_residual_ratio:.1e} <= {tol:.1e} in "
                  f"{stm.last_refine_steps} step(s), fallback fired)")

        if json_path:
            write_bench_json(json_path, "guard", results,
                             n=results["rows"], nnz=results["nnz"])
        return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small matrix + acceptance assertions (CI)")
    ap.add_argument("--json", default="", help="write results JSON here")
    ap.add_argument("--csv", default="")
    args = ap.parse_args()
    run(smoke=args.smoke, json_path=args.json)
    if args.csv:
        flush_csv(args.csv)
