"""Fill EXPERIMENTS.md placeholders from the dry-run result dirs."""
from __future__ import annotations

import glob
import json
import os

HERE = os.path.dirname(__file__)
OPT = os.path.join(HERE, "results", "dryrun")
BASE = os.path.join(HERE, "results", "dryrun_baseline")
# the first complete 68-cell pass (pre-accounting-fix ruler): used as the
# compile-status fallback for any cell the final-ruler re-run didn't reach
ARCHIVE = os.path.join(HERE, "results", "archive", "dryrun_v2_full")
EXP = os.path.join(HERE, "..", "EXPERIMENTS.md")

ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def load(d):
    out = {}
    for p in glob.glob(os.path.join(d, "*.json")):
        try:
            r = json.load(open(p))
            out[(r["arch"], r["shape"], r["mesh"])] = r
        except Exception:
            pass
    return out


def status_table(opt):
    lines = ["| arch | train_4k | prefill_32k | decode_32k | long_500k |",
             "|---|---|---|---|---|"]
    archs = sorted({k[0] for k in opt})
    for a in archs:
        row = [a]
        for sh in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            pod = opt.get((a, sh, "pod"), {}).get("status", "?")
            mp = opt.get((a, sh, "multipod"), {}).get("status", "?")
            mark = {"ok": "✓", "archive-ok": "✓*", "skipped": "skip", "?": "—"}
            row.append(f"{mark.get(pod, pod)}/{mark.get(mp, mp)}")
        lines.append("| " + " | ".join(row) + " |")
    lines.append("")
    lines.append("(cell = pod/multipod; ✓ = compiled in the final-ruler pass, "
                 "✓* = compiled in the full compile-coherence pass; every "
                 "non-skip cell compiled — `memory_analysis`/`cost_analysis` "
                 "in `benchmarks/results/dryrun*/*.json`)")
    return "\n".join(lines)


def roofline_table(opt, base, mesh):
    hdr = ("| arch | shape | compute s | memory s | collective s | bound s "
           "(base→opt) | dominant | fraction | useful |")
    sep = "|---|---|---|---|---|---|---|---|---|"
    lines = [hdr, sep]
    for (a, sh, m), r in sorted(opt.items(),
                                key=lambda kv: (kv[0][0], ORDER.get(kv[0][1], 9))):
        if m != mesh:
            continue
        if r.get("status") == "skipped":
            lines.append(f"| {a} | {sh} | — | — | — | — | — | — | skip: "
                         f"{r.get('reason','')[:45]} |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {a} | {sh} | — | — | — | — | — | — | {r.get('status')} |")
            continue
        t = r["analysis"]["terms"]
        bound = max(t.values())
        frac = t["compute_s"] / bound if bound else 0
        b = base.get((a, sh, m))
        bb = ""
        if b and b.get("status") == "ok":
            bbound = max(b["analysis"]["terms"].values())
            bb = f"{bbound:.2f}→"
        lines.append(
            f"| {a} | {sh} | {t['compute_s']:.3f} | {t['memory_s']:.3f} | "
            f"{t['collective_s']:.3f} | {bb}{bound:.2f} | "
            f"{r['analysis']['dominant'].replace('_s','')} | {100*frac:.0f}% | "
            f"{r['useful_ratio']:.2f} |")
    return "\n".join(lines)


def main():
    opt, base = load(OPT), load(BASE)
    arc = load(ARCHIVE)
    # compile-status fallback for cells the final-ruler re-run didn't reach
    for k, r in arc.items():
        if k not in opt:
            r = dict(r)
            if r.get("status") == "ok":
                r["status"] = "archive-ok"
                r.pop("analysis", None)
            opt[k] = r
    txt = open(EXP).read()
    txt = txt.replace("STATUS_TABLE_PLACEHOLDER", status_table(opt))
    roof = ("### Single pod (16×16 = 256 chips) — optimized framework, "
            "baseline bound shown as `base→opt`\n\n"
            + roofline_table(opt, base, "pod")
            + "\n\n### Multi-pod (2×16×16 = 512 chips)\n\n"
            + roofline_table(opt, base, "multipod"))
    txt = txt.replace("ROOFLINE_TABLE_PLACEHOLDER", roof)
    open(EXP, "w").write(txt)
    n_ok = sum(1 for r in opt.values() if r.get("status") == "ok")
    n_skip = sum(1 for r in opt.values() if r.get("status") == "skipped")
    n_base = sum(1 for r in base.values() if r.get("status") == "ok")
    print(f"filled: {n_ok} ok / {n_skip} skip optimized, {n_base} baseline cells")


if __name__ == "__main__":
    main()
